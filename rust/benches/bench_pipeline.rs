//! End-to-end step latency through the PJRT runtime (the L3 hot path):
//! measures the full train-step execute plus the coordinator's marshalling
//! overhead on the smallest artifact config.  Requires `make artifacts`.
//!
//! This is the bench behind EXPERIMENTS.md §Perf's "coordinator overhead"
//! number: everything outside `execute` must stay < 5% of the step.

use slope::backend::ParallelPolicy;
use slope::config::{Method, RunConfig};
use slope::coordinator::Trainer;
use slope::util::bench::{bench, emit_json, print_header};
use std::time::Instant;

fn main() -> slope::Result<()> {
    // `cargo bench` passes a `--bench` flag to harness=false binaries; skip flags.
    let mut positional = std::env::args().skip(1).filter(|a| !a.starts_with('-'));
    let model = positional.next().unwrap_or_else(|| "gpt-nano-half-depth".into());
    // Second positional = kernel-engine threads (0 = auto).  The policy is
    // carried on RunConfig for forward-compat, but the AOT execute path
    // does not consume it yet (ROADMAP "Policy into the AOT path"), so the
    // JSON rows below are emitted at threads=1 — the truthful value for
    // this measurement.
    let threads: usize = positional.next().and_then(|v| v.parse().ok()).unwrap_or(0);
    let policy = ParallelPolicy::with_threads(threads);
    let cfg = RunConfig {
        model: model.clone(),
        method: Method::Slope,
        steps: 1,
        lazy_fraction: 0.0,
        eval_every: 1000,
        parallel: policy,
        ..Default::default()
    };
    let mut t = Trainer::new(cfg)?;
    t.init()?;
    t.train()?; // warm: compiles + first execution

    print_header(&format!("bench_pipeline — {model} step loop"));
    // Full step (batch slice + marshal + execute + readback).
    let (b, s1) = t.manifest.train_tokens_shape();
    let mut rng = slope::util::Rng::seed_from_u64(1);
    let full = bench("full step", 2, 12, || {
        let batch = t.corpus.train_batch(b, s1 - 1, &mut rng);
        t.store.put_i32("tokens", &[b, s1], &batch.tokens).unwrap();
        t.session.borrow_mut().run("train_step", &mut t.store).unwrap();
        let _ = t.store.read_scalar_f32("loss").unwrap();
    });
    // Marshal-only (no execute): batch + literal construction + readback.
    let marshal = bench("marshal only", 2, 12, || {
        let batch = t.corpus.train_batch(b, s1 - 1, &mut rng);
        t.store.put_i32("tokens", &[b, s1], &batch.tokens).unwrap();
        let _ = t.store.read_scalar_f32("loss").unwrap();
    });
    // threads=1: the AOT step does not run on the kernel engine (yet).
    emit_json("bench_pipeline", &format!("{model}/full-step"), 1, &full);
    emit_json("bench_pipeline", &format!("{model}/marshal-only"), 1, &marshal);
    println!("policy        : {:>10} thr (CPU backend kernels only; AOT step is single-stream)",
             policy.effective_threads());
    println!("full step     : {:>10.2} ms", full.median_ms());
    println!("marshal only  : {:>10.3} ms", marshal.median_ms());
    println!("L3 overhead   : {:>10.2} %", marshal.median_ns / full.median_ns * 100.0);

    // Eval + forward latency for the serving path.
    let t0 = Instant::now();
    t.eval_point(0)?;
    println!("eval (4 batches): {:>8.2} ms", t0.elapsed().as_secs_f64() * 1e3);
    Ok(())
}
