//! End-to-end step latency through the PJRT runtime (the L3 hot path):
//! measures the full train-step execute plus the coordinator's marshalling
//! overhead on the smallest artifact config.  Requires `make artifacts`.
//!
//! This is the bench behind EXPERIMENTS.md §Perf's "coordinator overhead"
//! number: everything outside `execute` must stay < 5% of the step.

use slope::config::{Method, RunConfig};
use slope::coordinator::Trainer;
use slope::util::bench::{bench, print_header};
use std::time::Instant;

fn main() -> slope::Result<()> {
    // `cargo bench` passes a `--bench` flag to harness=false binaries; skip flags.
    let model = std::env::args()
        .skip(1)
        .find(|a| !a.starts_with('-'))
        .unwrap_or_else(|| "gpt-nano-half-depth".into());
    let cfg = RunConfig {
        model: model.clone(),
        method: Method::Slope,
        steps: 1,
        lazy_fraction: 0.0,
        eval_every: 1000,
        ..Default::default()
    };
    let mut t = Trainer::new(cfg)?;
    t.init()?;
    t.train()?; // warm: compiles + first execution

    print_header(&format!("bench_pipeline — {model} step loop"));
    // Full step (batch slice + marshal + execute + readback).
    let (b, s1) = t.manifest.train_tokens_shape();
    let mut rng = slope::util::Rng::seed_from_u64(1);
    let full = bench("full step", 2, 12, || {
        let batch = t.corpus.train_batch(b, s1 - 1, &mut rng);
        t.store.put_i32("tokens", &[b, s1], &batch.tokens).unwrap();
        t.session.borrow_mut().run("train_step", &mut t.store).unwrap();
        let _ = t.store.read_scalar_f32("loss").unwrap();
    });
    // Marshal-only (no execute): batch + literal construction + readback.
    let marshal = bench("marshal only", 2, 12, || {
        let batch = t.corpus.train_batch(b, s1 - 1, &mut rng);
        t.store.put_i32("tokens", &[b, s1], &batch.tokens).unwrap();
        let _ = t.store.read_scalar_f32("loss").unwrap();
    });
    println!("full step     : {:>10.2} ms", full.median_ms());
    println!("marshal only  : {:>10.3} ms", marshal.median_ms());
    println!("L3 overhead   : {:>10.2} %", marshal.median_ns / full.median_ns * 100.0);

    // Eval + forward latency for the serving path.
    let t0 = Instant::now();
    t.eval_point(0)?;
    println!("eval (4 batches): {:>8.2} ms", t0.elapsed().as_secs_f64() * 1e3);
    Ok(())
}
