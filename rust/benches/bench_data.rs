//! Data-pipeline throughput: corpus generation and batch slicing must never
//! bottleneck the step loop (L3 perf target: batcher ≥ 10⁶ tok/s).  The
//! pipeline is single-threaded by design (the kernel engine owns the
//! cores), so the threads column is the constant 1; the reuse row is the
//! allocation-free `train_batch_into` the trainer's step loop calls.
//! Set `SLOPE_BENCH_JSON` for the machine-readable rows.

use slope::data::{Corpus, CorpusSpec};
use slope::util::bench::{bench, bench_auto, black_box, emit_json, print_header, print_result};
use slope::util::Rng;

fn main() {
    print_header("bench_data — corpus generation + batcher (threads: 1)");
    let gen = bench("generate 256k-token corpus", 1, 5, || {
        black_box(Corpus::generate(CorpusSpec::for_vocab(512, 0)));
    });
    print_result(&gen);
    emit_json("bench_data", "generate-256k", 1, &gen);
    println!("  → {:.1}M tok/s generation",
             (1 << 18) as f64 / (gen.median_ns / 1e9) / 1e6);

    let corpus = Corpus::generate(CorpusSpec::for_vocab(512, 0));
    let mut rng = Rng::seed_from_u64(0);
    let b = bench_auto("train_batch 8×129", 100.0, || {
        black_box(corpus.train_batch(8, 128, &mut rng));
    });
    print_result(&b);
    emit_json("bench_data", "train_batch-8x129", 1, &b);
    let toks = 8.0 * 129.0;
    println!("  → {:.1}M tok/s batching", toks / (b.median_ns / 1e9) / 1e6);

    // Allocation-free batcher (the step loop's path): same draws, reused
    // buffer.
    let mut rng2 = Rng::seed_from_u64(0);
    let mut buf: Vec<i32> = vec![];
    let binto = bench_auto("train_batch_into 8×129", 100.0, || {
        corpus.train_batch_into(8, 128, &mut rng2, &mut buf);
        black_box(&buf);
    });
    print_result(&binto);
    emit_json("bench_data", "train_batch_into-8x129", 1, &binto);
    println!("  → {:.1}M tok/s batching (reused buffer, {:+.1}% vs alloc)",
             toks / (binto.median_ns / 1e9) / 1e6,
             (b.median_ns / binto.median_ns - 1.0) * 100.0);

    let cz = bench_auto("cloze_batch 8×128", 100.0, || {
        black_box(corpus.cloze_batch(8, 128, 3));
    });
    print_result(&cz);
    emit_json("bench_data", "cloze_batch-8x128", 1, &cz);
}
