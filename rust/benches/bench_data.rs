//! Data-pipeline throughput: corpus generation and batch slicing must never
//! bottleneck the step loop (L3 perf target: batcher ≥ 10⁶ tok/s).

use slope::data::{Corpus, CorpusSpec};
use slope::util::bench::{bench, bench_auto, black_box, print_header, print_result};
use slope::util::Rng;

fn main() {
    print_header("bench_data — corpus generation + batcher");
    let gen = bench("generate 256k-token corpus", 1, 5, || {
        black_box(Corpus::generate(CorpusSpec::for_vocab(512, 0)));
    });
    print_result(&gen);
    println!("  → {:.1}M tok/s generation",
             (1 << 18) as f64 / (gen.median_ns / 1e9) / 1e6);

    let corpus = Corpus::generate(CorpusSpec::for_vocab(512, 0));
    let mut rng = Rng::seed_from_u64(0);
    let b = bench_auto("train_batch 8×129", 100.0, || {
        black_box(corpus.train_batch(8, 128, &mut rng));
    });
    print_result(&b);
    let toks = 8.0 * 129.0;
    println!("  → {:.1}M tok/s batching", toks / (b.median_ns / 1e9) / 1e6);

    let cz = bench_auto("cloze_batch 8×128", 100.0, || {
        black_box(corpus.cloze_batch(8, 128, 3));
    });
    print_result(&cz);
}
