//! SpMM vs dense GEMM across LLM-relevant shapes — the CPU-measured
//! counterpart of the paper's Figure 3a (shape-dependent SpMM speedup).
//!
//! Roles match the paper: attention (d→d), upsample (d→4d), downsample
//! (4d→d).  The structured 2:4 kernel does half the MACs and streams half
//! the weight bytes; the printed speedup column is the measured analogue of
//! Fig 3a's y-axis.

use slope::backend::{gemm_nt, spmm_rowmajor};
use slope::sparsity::{random_row_mask, CompressedNm, NmScheme};
use slope::tensor::Matrix;
use slope::util::bench::{bench_auto, black_box, print_header};
use slope::util::Rng;

fn main() {
    let mut rng = Rng::seed_from_u64(0);
    print_header("bench_spmm — dense vs 2:4 compressed (batch 64)");
    println!("{:<28} {:>12} {:>12} {:>9}", "shape", "dense", "spmm", "speedup");
    for (name, d_out, d_in) in [
        ("attention 256×256", 256usize, 256usize),
        ("attention 512×512", 512, 512),
        ("upsample 256→1024", 1024, 256),
        ("upsample 512→2048", 2048, 512),
        ("downsample 1024→256", 256, 1024),
        ("downsample 2048→512", 512, 2048),
    ] {
        let x = Matrix::randn(64, d_in, 1.0, &mut rng);
        let w = Matrix::randn(d_out, d_in, 1.0, &mut rng);
        let mask = random_row_mask(d_out, d_in, NmScheme::TWO_FOUR, &mut rng);
        let wm = mask.apply(&w);
        let c = CompressedNm::compress(&w, &mask, NmScheme::TWO_FOUR);
        let dense = bench_auto("dense", 120.0, || {
            black_box(gemm_nt(black_box(&x), black_box(&wm)));
        });
        let sparse = bench_auto("spmm", 120.0, || {
            black_box(spmm_rowmajor(black_box(&x), black_box(&c)));
        });
        println!(
            "{:<28} {:>10.2}us {:>10.2}us {:>8.2}x",
            name,
            dense.median_us(),
            sparse.median_us(),
            dense.median_ns / sparse.median_ns
        );
    }
    println!("\n(2:4 halves MACs and weight bytes; CPU speedup < 2x because the\n gather-indexed access costs more per element than streaming — the\n hardware analogue is the metadata decode sparse tensor cores do for free)");
}
