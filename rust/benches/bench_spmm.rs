//! SpMM vs dense GEMM across LLM-relevant shapes — the CPU-measured
//! counterpart of the paper's Figure 3a (shape-dependent SpMM speedup),
//! now swept across kernel-engine thread counts.
//!
//! Roles match the paper: attention (d→d), upsample (d→4d), downsample
//! (4d→d).  The structured 2:4 kernel does half the MACs and streams half
//! the weight bytes (plus an 8×-smaller packed metadata plane); the
//! printed speedup columns are the measured analogue of Fig 3a's y-axis,
//! per thread count.  Set `SLOPE_BENCH_JSON` for the machine-readable
//! perf trajectory.

use slope::backend::{gemm_nt_with, simd_level, spmm_prepacked_with_at, spmm_rowmajor_with,
                     spmm_rowmajor_with_at, ParallelPolicy, SimdLevel};
use slope::sparsity::{random_row_mask, CompressedNm, NmScheme, PrepackedNm};
use slope::tensor::Matrix;
use slope::util::bench::{bench_auto, black_box, emit_json, print_header};
use slope::util::Rng;

const THREADS: [usize; 3] = [1, 2, 4];

fn main() {
    let mut rng = Rng::seed_from_u64(0);
    print_header("bench_spmm — dense vs 2:4 compressed (batch 64), by threads");
    println!("simd level: {} (SLOPE_SIMD to override)", simd_level());
    println!(
        "{:<28} {:>3} {:>12} {:>12} {:>9} {:>9}",
        "shape", "thr", "dense", "spmm", "vs dense", "vs 1thr"
    );
    for (name, d_out, d_in) in [
        ("attention 256×256", 256usize, 256usize),
        ("attention 512×512", 512, 512),
        ("upsample 256→1024", 1024, 256),
        ("upsample 512→2048", 2048, 512),
        ("downsample 1024→256", 256, 1024),
        ("downsample 2048→512", 512, 2048),
    ] {
        let x = Matrix::randn(64, d_in, 1.0, &mut rng);
        let w = Matrix::randn(d_out, d_in, 1.0, &mut rng);
        let mask = random_row_mask(d_out, d_in, NmScheme::TWO_FOUR, &mut rng);
        let wm = mask.apply(&w);
        let c = CompressedNm::compress(&w, &mask, NmScheme::TWO_FOUR);
        let mut spmm_1thr_ns = f64::NAN;
        for threads in THREADS {
            // Width-scaled fork floor (same derivation the CLI applies).
            let p = ParallelPolicy::for_width(threads, d_in);
            let dense = bench_auto("dense", 120.0, || {
                black_box(gemm_nt_with(black_box(&x), black_box(&wm), &p));
            });
            let sparse = bench_auto("spmm", 120.0, || {
                black_box(spmm_rowmajor_with(black_box(&x), black_box(&c), &p));
            });
            if threads == 1 {
                spmm_1thr_ns = sparse.median_ns;
            }
            emit_json("bench_spmm", &format!("{name}/dense"), threads, &dense);
            emit_json("bench_spmm", &format!("{name}/spmm"), threads, &sparse);
            println!(
                "{:<28} {:>3} {:>10.2}us {:>10.2}us {:>8.2}x {:>8.2}x",
                name,
                threads,
                dense.median_us(),
                sparse.median_us(),
                dense.median_ns / sparse.median_ns,
                spmm_1thr_ns / sparse.median_ns
            );
        }
        // Level-split series at one thread: the scalar reference vs the
        // auto-detected level, same shape and policy, so the trajectory
        // attributes any spmm movement to the dispatch level that ran.
        // (On non-AVX2 hardware `auto` degenerates to scalar and the two
        // rows coincide — the series still exists, which is what the
        // archive step enforces.)
        let p1 = ParallelPolicy::for_width(1, d_in);
        let scalar = bench_auto("simd-scalar", 120.0, || {
            black_box(spmm_rowmajor_with_at(SimdLevel::Scalar, black_box(&x), black_box(&c),
                                            &p1));
        });
        let auto = bench_auto("simd-auto", 120.0, || {
            black_box(spmm_rowmajor_with_at(simd_level(), black_box(&x), black_box(&c), &p1));
        });
        // Prepacked fused plane at the same level/policy: isolates the
        // register-blocked micro-tile + single-stream layout win over the
        // per-dot compressed path (the output is pinned bit-identical, so
        // any delta is pure layout/blocking, not arithmetic).
        let pre = PrepackedNm::prepack(&c);
        let prepacked = bench_auto("simd-prepacked", 120.0, || {
            black_box(spmm_prepacked_with_at(simd_level(), black_box(&x), black_box(&pre),
                                             &p1));
        });
        emit_json("bench_spmm", &format!("simd/{name}/scalar"), 1, &scalar);
        emit_json("bench_spmm", &format!("simd/{name}/auto"), 1, &auto);
        emit_json("bench_spmm", &format!("simd/{name}/prepacked"), 1, &prepacked);
        println!(
            "{:<28} {:>3} {:>12} {:>10.2}us {:>8.2}x {:>9}",
            format!("  simd {} vs scalar", simd_level()),
            1,
            "",
            auto.median_us(),
            scalar.median_ns / auto.median_ns,
            ""
        );
        println!(
            "{:<28} {:>3} {:>12} {:>10.2}us {:>8.2}x {:>9}",
            "  prepacked vs compressed",
            1,
            "",
            prepacked.median_us(),
            auto.median_ns / prepacked.median_ns,
            ""
        );
    }
    println!("\n(2:4 halves MACs and weight bytes; CPU speedup vs dense < 2x at one\n thread because the gather-indexed access costs more per element than\n streaming — the hardware analogue is the metadata decode sparse tensor\n cores do for free.  The vs-1thr column is the kernel engine's scaling.)");
}
