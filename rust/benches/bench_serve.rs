//! Serving-path latency across batch sizes and kernel-engine thread
//! counts — the measured counterpart of the paper's Table-2 serving
//! claim, and the acceptance gauge for the column-striped `batch = 1`
//! partition: with output-column stripes a single-request forward must
//! scale with worker count (the vs-1thr column), where the old row-only
//! split pinned it to one core.
//!
//! Shape: one upsample+downsample MLP block (512↔2048, 2:4 sparse +
//! rank-16 LoRA) — the default bench shape.  Set `SLOPE_BENCH_JSON` for
//! the machine-readable perf trajectory.

use slope::backend::{ParallelPolicy, SparseBackend, SpmmAlgo};
use slope::serve::{BatchPolicy, LoraAdapter, ServeEngine, ServeLayer};
use slope::sparsity::{random_row_mask, NmScheme};
use slope::tensor::Matrix;
use slope::util::bench::{bench_auto, black_box, emit_json, print_header};
use slope::util::Rng;
use std::time::Duration;

const THREADS: [usize; 3] = [1, 2, 4];
const BATCHES: [usize; 3] = [1, 4, 16];
const D: usize = 512;
const F: usize = 2048;
const RANK: usize = 16;

fn engine(threads: usize, rng: &mut Rng) -> ServeEngine {
    let policy = ParallelPolicy::for_width(threads, D);
    let mut layers = Vec::new();
    for (d_out, d_in) in [(F, D), (D, F)] {
        let w = Matrix::randn(d_out, d_in, 1.0 / (d_in as f32).sqrt(), rng);
        let mask = random_row_mask(d_out, d_in, NmScheme::TWO_FOUR, rng);
        let be = SparseBackend::setup(&w, mask, NmScheme::TWO_FOUR, SpmmAlgo::RowMajor, policy);
        let lora = LoraAdapter {
            up: Matrix::randn(d_out, RANK, 0.1, rng),
            down: Matrix::randn(RANK, d_in, 0.1, rng),
        };
        layers.push(ServeLayer::new(be, Some(lora)).expect("bench layer"));
    }
    // max_batch is set per measurement below; max_wait never binds because
    // the bench always submits a full batch before polling.
    ServeEngine::new(layers, BatchPolicy::new(16, Duration::from_secs(1))).expect("bench engine")
}

fn main() {
    let mut rng = Rng::seed_from_u64(0);
    print_header("bench_serve — coalesced forward latency (512↔2048 2:4 + rank-16 LoRA)");
    println!(
        "{:<16} {:>3} {:>12} {:>12} {:>9}",
        "case", "thr", "per-batch", "per-req", "vs 1thr"
    );
    for batch in BATCHES {
        let inputs: Vec<Vec<f32>> =
            (0..batch).map(|_| (0..D).map(|_| rng.normal_f32(0.5)).collect()).collect();
        let mut one_thr_ns = f64::NAN;
        for threads in THREADS {
            let mut eng = engine(threads, &mut Rng::seed_from_u64(7));
            let r = bench_auto(&format!("serve b{batch} t{threads}"), 120.0, || {
                for input in &inputs {
                    eng.submit(input.clone(), Duration::ZERO).expect("submit");
                }
                black_box(eng.flush(Duration::ZERO));
            });
            if threads == 1 {
                one_thr_ns = r.median_ns;
            }
            emit_json("bench_serve", &format!("batch{batch}/forward"), threads, &r);
            println!(
                "{:<16} {:>3} {:>10.2}us {:>10.2}us {:>8.2}x",
                format!("batch {batch}"),
                threads,
                r.median_ns / 1e3,
                r.median_ns / 1e3 / batch as f64,
                one_thr_ns / r.median_ns
            );
        }
    }
    println!("\n(batch=1 rows are the column-striped partition: the kernel stripes\n output columns across the pool, so single-request latency scales with\n threads; batch≥4 rows row-partition like training.  vs-1thr ≳ 1.5x at\n 4 threads on ≥4 hardware cores is the serving acceptance bar.)");
}
