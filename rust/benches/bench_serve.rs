//! Serving-path latency across batch sizes, kernel-engine thread counts,
//! and — new with the `ServeModel` redesign — both serving backends:
//!
//! * **kernel-stack** (cases `batch{B}/forward`, series unchanged for
//!   trajectory continuity): one upsample+downsample MLP block
//!   (512↔2048, 2:4 sparse + rank-16 LoRA) on warm `ServeLayer`s;
//! * **manifest** (cases `manifest/batch{B}/forward`): a checkpointed
//!   synthetic transformer served through `AotModel` — restore from
//!   packed v2 planes, token staging, host kernel executor — i.e. the
//!   full `slope serve --manifest` data path.
//!
//! * **decode** (cases `decode/batch{B}/step`): the KV-cached per-token
//!   hot path — one coalesced incremental decode step over `B` live
//!   sequences on the host kernel executor (the exact math behind
//!   `slope generate` / `slope serve --decode`), measured at a fixed
//!   mid-context position via `KvCache::truncate` rollback so the cost
//!   is a steady-state per-token number.  A position sweep is printed
//!   alongside: step time must stay flat as the sequence grows (the KV
//!   cache turns O(len·d²) recompute into O(d²) + O(len·d)), which is
//!   the acceptance gauge for autoregressive serving.  The decode mode
//!   also archives the paged-pool dtype series (`kv/<dtype>/batch{B}/
//!   step` for f32|f16|int8 at 1 thread): the same steady-state step on
//!   a pool of that plane storage, with the per-sequence pool bytes
//!   printed so the latency cost of quantized KV is always read next to
//!   its memory win.  Decode mode further archives the prefix-cache
//!   pair (`prefix/{on,off}/batch{B}/step`): open-loop prefill over a
//!   zipf prompt mix sharing a system preamble, with the radix prefix
//!   cache on vs off and the hit-rate / tokens-saved counters printed
//!   beside the latency delta.
//!
//! The batch=1 rows are the acceptance gauge for the column-striped
//! partition: a single-request forward must scale with worker count
//! (vs-1thr column).  Set `SLOPE_BENCH_JSON` for the machine-readable
//! perf trajectory; `SLOPE_BENCH_SERVE_MODE=kernel|manifest|decode|all`
//! restricts the sweep (default all; `both` is the legacy alias for
//! all).

use slope::backend::{simd_level, ParallelPolicy, SparseBackend, SpmmAlgo};
use slope::coordinator::checkpoint;
use slope::runtime::{write_synthetic_artifact, HostModel, KvCache, KvDtype, KvPoolConfig,
                     Manifest, SynthSpec};
use slope::serve::{AotModel, BatchPolicy, LoraAdapter, ServeEngine, ServeLayer, ServeModel};
use slope::sparsity::{random_row_mask, NmScheme};
use slope::tensor::Matrix;
use slope::util::bench::{bench_auto, black_box, emit_json, print_header};
use slope::util::Rng;
use std::time::Duration;

const THREADS: [usize; 3] = [1, 2, 4];
const BATCHES: [usize; 3] = [1, 4, 16];
const D: usize = 512;
const F: usize = 2048;
const RANK: usize = 16;

/// A zipf(s)-popular index stream over `n_prompts` ranks: the prompt-mix
/// shape real serving front-ends see (a few hot prompts, a long tail),
/// driven by the crate Rng so the trace is reproducible.
fn zipf_trace(rng: &mut Rng, n_prompts: usize, len: usize, s: f64) -> Vec<usize> {
    let weights: Vec<f64> = (1..=n_prompts).map(|r| 1.0 / (r as f64).powf(s)).collect();
    let total: f64 = weights.iter().sum();
    (0..len)
        .map(|_| {
            let mut u = rng.below(1 << 20) as f64 / (1u64 << 20) as f64 * total;
            let mut idx = 0usize;
            while idx + 1 < n_prompts && u > weights[idx] {
                u -= weights[idx];
                idx += 1;
            }
            idx
        })
        .collect()
}

fn kernel_engine(threads: usize, rng: &mut Rng) -> ServeEngine {
    let policy = ParallelPolicy::for_width(threads, D);
    let mut layers = Vec::new();
    for (d_out, d_in) in [(F, D), (D, F)] {
        let w = Matrix::randn(d_out, d_in, 1.0 / (d_in as f32).sqrt(), rng);
        let mask = random_row_mask(d_out, d_in, NmScheme::TWO_FOUR, rng);
        let be = SparseBackend::setup(&w, mask, NmScheme::TWO_FOUR, SpmmAlgo::RowMajor, policy);
        let lora = LoraAdapter {
            up: Matrix::randn(d_out, RANK, 0.1, rng),
            down: Matrix::randn(RANK, d_in, 0.1, rng),
        };
        layers.push(ServeLayer::new(be, Some(lora)).expect("bench layer"));
    }
    // max_batch 16 covers every measured fill; max_wait never binds
    // because the bench always submits a full batch before polling.
    ServeEngine::new(layers, BatchPolicy::new(16, Duration::from_secs(1)))
        .expect("bench engine")
}

/// Measure one engine at one (batch, threads) point: submit `inputs`,
/// flush, record.
fn measure<M: ServeModel>(eng: &mut ServeEngine<M>, case: &str, batch: usize, threads: usize,
                          inputs: &[Vec<f32>], one_thr_ns: &mut f64) {
    let r = bench_auto(&format!("serve {case} b{batch} t{threads}"), 120.0, || {
        for input in inputs {
            eng.submit(input.clone(), Duration::ZERO).expect("submit");
        }
        black_box(eng.flush(Duration::ZERO).expect("flush"));
    });
    if threads == 1 {
        *one_thr_ns = r.median_ns;
    }
    let json_case = if case == "kernel" {
        format!("batch{batch}/forward") // stable pre-redesign series name
    } else {
        format!("{case}/batch{batch}/forward")
    };
    emit_json("bench_serve", &json_case, threads, &r);
    println!(
        "{:<22} {:>3} {:>10.2}us {:>10.2}us {:>8.2}x",
        format!("{case} batch {batch}"),
        threads,
        r.median_ns / 1e3,
        r.median_ns / 1e3 / batch as f64,
        *one_thr_ns / r.median_ns
    );
}

fn main() {
    let mode = std::env::var("SLOPE_BENCH_SERVE_MODE").unwrap_or_else(|_| "all".into());
    let all = mode == "all" || mode == "both"; // `both` = legacy alias
    let run_kernel = mode == "kernel" || all;
    let run_manifest = mode == "manifest" || all;
    let run_decode = mode == "decode" || all;
    let mut rng = Rng::seed_from_u64(0);
    print_header("bench_serve — coalesced forward latency (both ServeModel backends)");
    println!("simd level: {} (SLOPE_SIMD to override)", simd_level());
    println!(
        "{:<22} {:>3} {:>12} {:>12} {:>9}",
        "case", "thr", "per-batch", "per-req", "vs 1thr"
    );

    if run_kernel {
        for batch in BATCHES {
            let inputs: Vec<Vec<f32>> =
                (0..batch).map(|_| (0..D).map(|_| rng.normal_f32(0.5)).collect()).collect();
            let mut one_thr_ns = f64::NAN;
            for threads in THREADS {
                let mut eng = kernel_engine(threads, &mut Rng::seed_from_u64(7));
                measure(&mut eng, "kernel", batch, threads, &inputs, &mut one_thr_ns);
            }
        }
    }

    if run_manifest {
        // A self-contained synthetic artifact (manifest + packed-plane
        // checkpoint): the full `slope serve --manifest` restore-and-serve
        // path, sized so the smoke budget stays in seconds.
        let dir = std::env::temp_dir().join("slope_bench_serve_manifest");
        let spec = SynthSpec {
            name: "bench-synth".into(),
            vocab: 256,
            n_layer: 2,
            n_head: 4,
            d_model: 64,
            d_ff: 256,
            seq_len: 16,
            batch_size: 16,
            rank: 8,
            seed: 7,
        };
        write_synthetic_artifact(&dir, &spec).expect("synthetic artifact");
        for batch in BATCHES {
            let inputs: Vec<Vec<f32>> = (0..batch)
                .map(|_| (0..spec.seq_len).map(|_| rng.below(spec.vocab) as f32).collect())
                .collect();
            let mut one_thr_ns = f64::NAN;
            for threads in THREADS {
                let policy = ParallelPolicy::for_width(threads, spec.d_model);
                let model = AotModel::open(&dir, policy).expect("aot model");
                let mut eng = ServeEngine::with_model(
                    model,
                    BatchPolicy::new(16, Duration::from_secs(1)),
                )
                .expect("aot engine");
                measure(&mut eng, "manifest", batch, threads, &inputs, &mut one_thr_ns);
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    if run_decode {
        // The per-token hot path: a wider-context synthetic artifact so
        // the position sweep has room to show flatness.
        let dir = std::env::temp_dir().join("slope_bench_serve_decode");
        let spec = SynthSpec {
            name: "bench-decode".into(),
            vocab: 256,
            n_layer: 2,
            n_head: 4,
            d_model: 64,
            d_ff: 256,
            seq_len: 64,
            batch_size: 16,
            rank: 8,
            seed: 11,
        };
        write_synthetic_artifact(&dir, &spec).expect("synthetic artifact");
        let manifest = Manifest::load(&dir).expect("manifest");
        let (store, packed) = checkpoint::load_model_checkpoint(&dir).expect("checkpoint");
        let prompt: Vec<i32> =
            (0..8).map(|_| rng.below(spec.vocab) as i32).collect();
        let step_pos = spec.seq_len / 2;

        // Archived series: one coalesced decode step over B sequences,
        // rolled back to a fixed mid-context position each iteration.
        for batch in BATCHES {
            let mut one_thr_ns = f64::NAN;
            for threads in THREADS {
                let policy = ParallelPolicy::for_width(threads, spec.d_model);
                let mut hm = HostModel::from_store(&manifest, &store, &packed, policy)
                    .expect("host model");
                let mut y = Matrix::zeros(0, 0);
                let mut caches: Vec<KvCache> = (0..batch)
                    .map(|_| {
                        let mut c = hm.new_kv_cache();
                        hm.prefill_into(&prompt, &mut c, &mut y).expect("prefill");
                        c
                    })
                    .collect();
                // Walk every sequence to the measurement position.
                let mut tokens: Vec<i32> = (0..batch).map(|i| (i % 19) as i32).collect();
                while caches[0].len() < step_pos {
                    hm.decode_step_into(&tokens, &mut caches, &mut y).expect("walk");
                    for (i, t) in tokens.iter_mut().enumerate() {
                        *t = (*t + 1 + i as i32) % spec.vocab as i32;
                    }
                }
                let base_len = caches[0].len();
                let r = bench_auto(
                    &format!("serve decode b{batch} t{threads}"),
                    120.0,
                    || {
                        hm.decode_step_into(&tokens, &mut caches, &mut y).expect("step");
                        black_box(&y);
                        for c in caches.iter_mut() {
                            c.truncate(base_len);
                        }
                    },
                );
                if threads == 1 {
                    one_thr_ns = r.median_ns;
                }
                emit_json("bench_serve", &format!("decode/batch{batch}/step"), threads, &r);
                println!(
                    "{:<22} {:>3} {:>10.2}us {:>10.2}us {:>8.2}x",
                    format!("decode batch {batch}"),
                    threads,
                    r.median_ns / 1e3,
                    r.median_ns / 1e3 / batch as f64,
                    one_thr_ns / r.median_ns
                );
            }
        }

        // Archived paged-pool dtype series: the identical steady-state
        // step, but with the KV planes stored in each pool dtype.  One
        // thread — the dtype axis is about plane storage cost (dequant
        // on read + quantize on write), not the thread sweep the plain
        // decode series already carries.
        println!("\npaged KV pool by dtype (1 thr; bytes are per-sequence pool charge):");
        for dtype in [KvDtype::F32, KvDtype::F16, KvDtype::Int8] {
            for batch in BATCHES {
                let policy = ParallelPolicy::for_width(1, spec.d_model);
                let kv = KvPoolConfig { dtype, ..KvPoolConfig::default() };
                let mut hm =
                    HostModel::from_store_with_kv(&manifest, &store, &packed, policy, kv)
                        .expect("host model");
                let mut y = Matrix::zeros(0, 0);
                let mut caches: Vec<KvCache> = (0..batch)
                    .map(|_| {
                        let mut c = hm.new_kv_cache();
                        hm.prefill_into(&prompt, &mut c, &mut y).expect("prefill");
                        c
                    })
                    .collect();
                let mut tokens: Vec<i32> = (0..batch).map(|i| (i % 19) as i32).collect();
                while caches[0].len() < step_pos {
                    hm.decode_step_into(&tokens, &mut caches, &mut y).expect("walk");
                    for (i, t) in tokens.iter_mut().enumerate() {
                        *t = (*t + 1 + i as i32) % spec.vocab as i32;
                    }
                }
                let base_len = caches[0].len();
                let r = bench_auto(
                    &format!("serve kv/{} b{batch}", dtype.label()),
                    120.0,
                    || {
                        hm.decode_step_into(&tokens, &mut caches, &mut y).expect("step");
                        black_box(&y);
                        for c in caches.iter_mut() {
                            c.truncate(base_len);
                        }
                    },
                );
                emit_json(
                    "bench_serve",
                    &format!("kv/{}/batch{batch}/step", dtype.label()),
                    1,
                    &r,
                );
                println!(
                    "{:<22} {:>3} {:>10.2}us {:>10.2}us {:>8} B",
                    format!("kv/{} batch {}", dtype.label(), batch),
                    1,
                    r.median_ns / 1e3,
                    r.median_ns / 1e3 / batch as f64,
                    caches[0].bytes()
                );
            }
        }

        // Archived prefix-cache pair: an open-loop stream of
        // zipf-popular prompts sharing a long system preamble,
        // prefilled with the radix prefix cache on vs off (1 thread —
        // the cache axis is about skipped prefill math, not the thread
        // sweep).  Cache-on cost per step drops with the hit rate (only
        // the unmatched suffix is embedded/projected/attended), and the
        // printed row ties the latency win to the cache's own counters
        // so savings are always read next to the hit rate that bought
        // them.
        println!("\nprefix-cache prefill, zipf prompt mix (1 thr):");
        let n_prompts = 16usize;
        let preamble: Vec<i32> =
            (0..48).map(|_| rng.below(spec.vocab) as i32).collect();
        let zipf_prompts: Vec<Vec<i32>> = (0..n_prompts)
            .map(|_| {
                let mut p = preamble.clone();
                p.extend((0..8).map(|_| rng.below(spec.vocab) as i32));
                p
            })
            .collect();
        let trace = zipf_trace(&mut rng, n_prompts, 256, 1.1);
        for batch in BATCHES {
            let policy = ParallelPolicy::for_width(1, spec.d_model);
            let mut rows = Vec::new();
            for enabled in [false, true] {
                let kv = KvPoolConfig {
                    prefix_cache: enabled.then_some(256),
                    ..KvPoolConfig::default()
                };
                let mut hm =
                    HostModel::from_store_with_kv(&manifest, &store, &packed, policy, kv)
                        .expect("host model");
                let mut y = Matrix::zeros(0, 0);
                let mut cursor = 0usize;
                let tag = if enabled { "on" } else { "off" };
                let r = bench_auto(&format!("serve prefix/{tag} b{batch}"), 120.0, || {
                    for _ in 0..batch {
                        let p = &zipf_prompts[trace[cursor % trace.len()]];
                        cursor += 1;
                        let mut c = hm.new_kv_cache();
                        hm.prefill_into(p, &mut c, &mut y).expect("prefill");
                        black_box(&y);
                    }
                });
                emit_json("bench_serve", &format!("prefix/{tag}/batch{batch}/step"), 1, &r);
                rows.push((r.median_ns, hm.kv_pool().prefix_stats()));
            }
            let (off_ns, on_ns) = (rows[0].0, rows[1].0);
            let st = rows[1].1.as_ref().expect("prefix stats with the cache on");
            println!(
                "{:<22} {:>3} off {:>8.2}us  on {:>8.2}us  {:>5.2}x  hit {:>3.0}%  \
                 saved {} tok",
                format!("prefix batch {batch}"),
                1,
                off_ns / 1e3,
                on_ns / 1e3,
                off_ns / on_ns,
                st.hit_rate() * 100.0,
                st.tokens_saved
            );
        }

        // O(1)-in-position evidence: per-step cost along the context at
        // batch 1 (printed, not archived — positions are a sweep, not a
        // trajectory series).  The KV cache keeps this flat; full-prefix
        // recompute would grow linearly.
        let policy = ParallelPolicy::for_width(1, spec.d_model);
        let mut hm =
            HostModel::from_store(&manifest, &store, &packed, policy).expect("host model");
        let mut y = Matrix::zeros(0, 0);
        let mut cache = hm.new_kv_cache();
        hm.prefill_into(&prompt, &mut cache, &mut y).expect("prefill");
        let mut tok = 1i32;
        let mut pos_rows: Vec<(usize, f64)> = Vec::new();
        let sweep = [12usize, 24, 40, 60];
        for &target in &sweep {
            while cache.len() < target {
                hm.decode_step_into(&[tok], std::slice::from_mut(&mut cache), &mut y)
                    .expect("walk");
                tok = (tok + 1) % spec.vocab as i32;
            }
            let base_len = cache.len();
            let r = bench_auto(&format!("serve decode pos {target}"), 60.0, || {
                hm.decode_step_into(&[tok], std::slice::from_mut(&mut cache), &mut y)
                    .expect("step");
                black_box(&y);
                cache.truncate(base_len);
            });
            pos_rows.push((target, r.median_ns));
        }
        println!("\ndecode step cost along the context (batch 1, 1 thr):");
        for (pos, ns) in &pos_rows {
            println!("  position {:>3}: {:>10.2}us", pos, ns / 1e3);
        }
        let (first, last) = (pos_rows[0].1, pos_rows[pos_rows.len() - 1].1);
        println!(
            "  pos {} vs pos {}: {:.2}x  (flat ⇒ per-token cost is O(1) in generated-token \
             count; recompute would be ~{:.1}x)",
            pos_rows[0].0,
            pos_rows[pos_rows.len() - 1].0,
            last / first,
            pos_rows[pos_rows.len() - 1].0 as f64 / pos_rows[0].0 as f64
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    println!("\n(kernel batch=1 rows exercise the column-striped partition — stripe\n widths are quad-rounded so narrow stripes keep the 2:4 four-row ILP;\n manifest rows run the checkpointed transformer through AotModel's host\n kernel executor, the `slope serve --manifest` data path; decode rows are\n the KV-cached per-token step behind `slope generate`, flat in position.\n vs-1thr ≳ 1.5x at 4 threads on ≥4 hardware cores is the serving\n acceptance bar.)");
}
