//! Naive (4-call) vs fused (Eq. 11, 2-call) adapter inference — the
//! measured-CPU half of the paper's Appendix C/D (Table 7): fusion wins
//! by eliminating the separate add pass and raising arithmetic intensity.
//! The workspace column is the allocation-free serving call
//! (`SparseBackend::lora_fused_ws`) the dynamic batcher uses.  Set
//! `SLOPE_BENCH_JSON` for the machine-readable rows.

use slope::backend::{lora_fused, lora_naive, ParallelPolicy, SparseBackend, SpmmAlgo};
use slope::sparsity::{random_row_mask, NmScheme};
use slope::tensor::Matrix;
use slope::util::bench::{bench_auto, black_box, emit_json, print_header};
use slope::util::Rng;

const THREADS: [usize; 2] = [1, 4];

fn main() {
    let mut rng = Rng::seed_from_u64(2);
    print_header("bench_lora_fusion — naive 4-call vs fused 2-call (Eq. 11)");
    println!(
        "{:<20} {:>3} {:>12} {:>12} {:>12} {:>8}",
        "shape (d, rank)", "thr", "naive", "fused", "fused_ws", "gain"
    );
    for (d, r) in [(256usize, 4usize), (256, 16), (512, 8), (512, 32), (1024, 16)] {
        let x = Matrix::randn(64, d, 1.0, &mut rng);
        let w = Matrix::randn(d, d, 1.0, &mut rng);
        let mask = random_row_mask(d, d, NmScheme::TWO_FOUR, &mut rng);
        let lo_up = Matrix::randn(d, r, 0.2, &mut rng);
        let lo_down = Matrix::randn(r, d, 0.2, &mut rng);
        for threads in THREADS {
            let p = ParallelPolicy::with_threads(threads);
            let mut be = SparseBackend::setup(&w, mask.clone(), NmScheme::TWO_FOUR,
                                              SpmmAlgo::RowMajor, p);
            let c = be.w.clone();
            let naive = bench_auto("naive", 120.0, || {
                black_box(lora_naive(black_box(&x), &c, &lo_up, &lo_down,
                                     SpmmAlgo::RowMajor, &p));
            });
            let fused = bench_auto("fused", 120.0, || {
                black_box(lora_fused(black_box(&x), &c, &lo_up, &lo_down,
                                     SpmmAlgo::RowMajor, &p));
            });
            let fused_ws = bench_auto("fused_ws", 120.0, || {
                black_box(be.lora_fused_ws(black_box(&x), &lo_up, &lo_down));
            });
            let case = format!("d={d} r={r}");
            emit_json("bench_lora_fusion", &format!("{case}/naive"), threads, &naive);
            emit_json("bench_lora_fusion", &format!("{case}/fused"), threads, &fused);
            emit_json("bench_lora_fusion", &format!("{case}/fused_ws"), threads, &fused_ws);
            println!(
                "{:<20} {:>3} {:>10.2}us {:>10.2}us {:>10.2}us {:>6.1}%",
                case, threads,
                naive.median_us(), fused.median_us(), fused_ws.median_us(),
                (naive.median_ns / fused.median_ns - 1.0) * 100.0
            );
        }
    }
}
