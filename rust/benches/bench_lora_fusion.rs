//! Naive (4-call) vs fused (Eq. 11, 2-call) adapter inference — the
//! measured-CPU half of the paper's Appendix C/D (Table 7): fusion wins by
//! eliminating the separate add pass and raising arithmetic intensity.

use slope::backend::{lora_fused, lora_naive, SpmmAlgo};
use slope::sparsity::{random_row_mask, CompressedNm, NmScheme};
use slope::tensor::Matrix;
use slope::util::bench::{bench_auto, black_box, print_header};
use slope::util::Rng;

fn main() {
    let mut rng = Rng::seed_from_u64(2);
    print_header("bench_lora_fusion — naive 4-call vs fused 2-call (Eq. 11)");
    println!("{:<26} {:>12} {:>12} {:>9}", "shape (d, rank)", "naive", "fused", "gain");
    for (d, r) in [(256usize, 4usize), (256, 16), (512, 8), (512, 32), (1024, 16)] {
        let x = Matrix::randn(64, d, 1.0, &mut rng);
        let w = Matrix::randn(d, d, 1.0, &mut rng);
        let mask = random_row_mask(d, d, NmScheme::TWO_FOUR, &mut rng);
        let c = CompressedNm::compress(&w, &mask, NmScheme::TWO_FOUR);
        let lo_up = Matrix::randn(d, r, 0.2, &mut rng);
        let lo_down = Matrix::randn(r, d, 0.2, &mut rng);
        let naive = bench_auto("naive", 120.0, || {
            black_box(lora_naive(black_box(&x), &c, &lo_up, &lo_down, SpmmAlgo::RowMajor));
        });
        let fused = bench_auto("fused", 120.0, || {
            black_box(lora_fused(black_box(&x), &c, &lo_up, &lo_down, SpmmAlgo::RowMajor));
        });
        println!(
            "{:<26} {:>10.2}us {:>10.2}us {:>7.1}%",
            format!("d={d} r={r}"),
            naive.median_us(), fused.median_us(),
            (naive.median_ns / fused.median_ns - 1.0) * 100.0
        );
    }
}
