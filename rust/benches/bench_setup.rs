//! Setup (prune + compress) vs multiply cost — the measured-CPU half of the
//! paper's Figure 5 (Appendix B): the asymmetry that makes static masks
//! (SLoPe) amortize and dynamic masks (FST/Bi-Mask/SR-STE) bleed.  The
//! multiply column is swept over kernel-engine threads (setup itself is a
//! one-off, serial by design).  Set `SLOPE_BENCH_JSON` for the
//! machine-readable rows.

use slope::backend::{spmm_rowmajor_with, ParallelPolicy};
use slope::sparsity::{magnitude_row_mask, random_row_mask, CompressedNm, NmScheme};
use slope::tensor::Matrix;
use slope::util::bench::{bench_auto, black_box, emit_json, print_header};
use slope::util::Rng;

const THREADS: [usize; 2] = [1, 4];

fn main() {
    let mut rng = Rng::seed_from_u64(1);
    print_header("bench_setup — compress (setup) vs one multiply, square matrices");
    println!("{:<8} {:>3} {:>14} {:>14} {:>14} {:>8}",
             "dim", "thr", "mask search", "compress", "multiply", "ratio");
    for d in [128usize, 256, 512, 1024] {
        let x = Matrix::randn(64, d, 1.0, &mut rng);
        let w = Matrix::randn(d, d, 1.0, &mut rng);
        let mask = random_row_mask(d, d, NmScheme::TWO_FOUR, &mut rng);
        let c0 = CompressedNm::compress(&w, &mask, NmScheme::TWO_FOUR);
        let search = bench_auto("search", 100.0, || {
            black_box(magnitude_row_mask(black_box(&w), NmScheme::TWO_FOUR));
        });
        let compress = bench_auto("compress", 100.0, || {
            black_box(CompressedNm::compress(black_box(&w), black_box(&mask),
                                             NmScheme::TWO_FOUR));
        });
        emit_json("bench_setup", &format!("d={d}/search"), 1, &search);
        emit_json("bench_setup", &format!("d={d}/compress"), 1, &compress);
        for threads in THREADS {
            let p = ParallelPolicy::with_threads(threads);
            let mult = bench_auto("mult", 100.0, || {
                black_box(spmm_rowmajor_with(black_box(&x), black_box(&c0), &p));
            });
            emit_json("bench_setup", &format!("d={d}/mult"), threads, &mult);
            let setup = search.median_ns + compress.median_ns;
            println!(
                "{:<8} {:>3} {:>12.2}us {:>12.2}us {:>12.2}us {:>7.1}x",
                d, threads, search.median_us(), compress.median_us(),
                mult.median_us(), setup / mult.median_ns
            );
        }
    }
    println!("\n(static masks pay setup ONCE per run; dynamic-mask methods pay it\n every refresh — multiply the ratio column by the refresh rate.  More\n threads shrink the multiply, making dynamic-mask refresh relatively\n even MORE expensive)");
}
