//! Setup (prune + compress) vs multiply cost — the measured-CPU half of the
//! paper's Figure 5 (Appendix B): the asymmetry that makes static masks
//! (SLoPe) amortize and dynamic masks (FST/Bi-Mask/SR-STE) bleed.

use slope::backend::spmm_rowmajor;
use slope::sparsity::{magnitude_row_mask, random_row_mask, CompressedNm, NmScheme};
use slope::tensor::Matrix;
use slope::util::bench::{bench_auto, black_box, print_header};
use slope::util::Rng;

fn main() {
    let mut rng = Rng::seed_from_u64(1);
    print_header("bench_setup — compress (setup) vs one multiply, square matrices");
    println!("{:<12} {:>14} {:>14} {:>14} {:>8}", "dim", "mask search", "compress", "multiply", "ratio");
    for d in [128usize, 256, 512, 1024] {
        let x = Matrix::randn(64, d, 1.0, &mut rng);
        let w = Matrix::randn(d, d, 1.0, &mut rng);
        let mask = random_row_mask(d, d, NmScheme::TWO_FOUR, &mut rng);
        let c0 = CompressedNm::compress(&w, &mask, NmScheme::TWO_FOUR);
        let search = bench_auto("search", 100.0, || {
            black_box(magnitude_row_mask(black_box(&w), NmScheme::TWO_FOUR));
        });
        let compress = bench_auto("compress", 100.0, || {
            black_box(CompressedNm::compress(black_box(&w), black_box(&mask), NmScheme::TWO_FOUR));
        });
        let mult = bench_auto("mult", 100.0, || {
            black_box(spmm_rowmajor(black_box(&x), black_box(&c0)));
        });
        let setup = search.median_ns + compress.median_ns;
        println!(
            "{:<12} {:>12.2}us {:>12.2}us {:>12.2}us {:>7.1}x",
            d, search.median_us(), compress.median_us(), mult.median_us(),
            setup / mult.median_ns
        );
    }
    println!("\n(static masks pay setup ONCE per run; dynamic-mask methods pay it\n every refresh — multiply the ratio column by the refresh rate)");
}
