//! Square-tile sweep for SpMM — the measured-CPU half of the paper's §2.4
//! upsample-tiling optimization (Table 8 / Appendix E).  On CPU the win is
//! cache locality; on the A100 it is cuSPARSELt's shape sweet-spot — both
//! favor square tiles.

use slope::backend::{spmm_rowmajor, spmm_tiled};
use slope::sparsity::{random_row_mask, CompressedNm, NmScheme};
use slope::tensor::Matrix;
use slope::util::bench::{bench_auto, black_box, print_header};
use slope::util::Rng;

fn main() {
    let mut rng = Rng::seed_from_u64(3);
    print_header("bench_tiling — upsample SpMM, square output tiles (batch 256)");
    // Upsample shape: d_in=512 → d_out=2048 (aspect 4, the cliff candidate).
    let x = Matrix::randn(256, 512, 1.0, &mut rng);
    let w = Matrix::randn(2048, 512, 1.0, &mut rng);
    let mask = random_row_mask(2048, 512, NmScheme::TWO_FOUR, &mut rng);
    let c = CompressedNm::compress(&w, &mask, NmScheme::TWO_FOUR);
    let base = bench_auto("row-major", 200.0, || {
        black_box(spmm_rowmajor(black_box(&x), black_box(&c)));
    });
    println!("{:<16} {:>12} {:>9}", "variant", "median", "vs base");
    println!("{:<16} {:>10.2}us {:>8.2}x", "row-major", base.median_us(), 1.0);
    for tile in [8usize, 16, 32, 64, 128, 256] {
        let r = bench_auto("tiled", 200.0, || {
            black_box(spmm_tiled(black_box(&x), black_box(&c), tile));
        });
        println!("{:<16} {:>10.2}us {:>8.2}x",
                 format!("tile {tile}"), r.median_us(), base.median_ns / r.median_ns);
    }
}
