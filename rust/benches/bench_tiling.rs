//! Square-tile sweep for SpMM — the measured-CPU half of the paper's §2.4
//! upsample-tiling optimization (Table 8 / Appendix E), crossed with the
//! kernel engine's thread count.  On CPU the tiling win is cache
//! locality; on the A100 it is cuSPARSELt's shape sweet-spot — both favor
//! square tiles.  Set `SLOPE_BENCH_JSON` for the machine-readable rows.

use slope::backend::{spmm_rowmajor_with, spmm_tiled_with, ParallelPolicy};
use slope::sparsity::{random_row_mask, CompressedNm, NmScheme};
use slope::tensor::Matrix;
use slope::util::bench::{bench_auto, black_box, emit_json, print_header};
use slope::util::Rng;

const THREADS: [usize; 2] = [1, 4];

fn main() {
    let mut rng = Rng::seed_from_u64(3);
    print_header("bench_tiling — upsample SpMM, square output tiles (batch 256)");
    // Upsample shape: d_in=512 → d_out=2048 (aspect 4, the cliff candidate).
    let x = Matrix::randn(256, 512, 1.0, &mut rng);
    let w = Matrix::randn(2048, 512, 1.0, &mut rng);
    let mask = random_row_mask(2048, 512, NmScheme::TWO_FOUR, &mut rng);
    let c = CompressedNm::compress(&w, &mask, NmScheme::TWO_FOUR);
    println!("{:<16} {:>3} {:>12} {:>9}", "variant", "thr", "median", "vs base");
    for threads in THREADS {
        let p = ParallelPolicy::for_width(threads, 512);
        let base = bench_auto("row-major", 200.0, || {
            black_box(spmm_rowmajor_with(black_box(&x), black_box(&c), &p));
        });
        emit_json("bench_tiling", "row-major", threads, &base);
        println!("{:<16} {:>3} {:>10.2}us {:>8.2}x", "row-major", threads,
                 base.median_us(), 1.0);
        for tile in [8usize, 16, 32, 64, 128, 256] {
            let r = bench_auto("tiled", 200.0, || {
                black_box(spmm_tiled_with(black_box(&x), black_box(&c), tile, &p));
            });
            emit_json("bench_tiling", &format!("tile-{tile}"), threads, &r);
            println!("{:<16} {:>3} {:>10.2}us {:>8.2}x",
                     format!("tile {tile}"), threads,
                     r.median_us(), base.median_ns / r.median_ns);
        }
    }
}
