//! Native host training-step latency across kernel-engine thread counts —
//! the perf trajectory of the double-pruned backward pass.
//!
//! Two archived series, matching the bench_serve convention (CI's
//! trajectory archive fails if either is missing):
//! * `train/step` — one sparse-phase `train_step` (Eq. 4–6 forward +
//!   backward through the packed kernels, AdamW epilogue);
//! * `train_lora/step` — one lazy-phase `train_step_lora` (adapters
//!   riding along, second AdamW chain).
//!
//! Threads sweep {1, 2, 4}: the step's GEMM/SpMM work runs under the
//! worker pool, so the vs-1thr column is the acceptance gauge for
//! threaded training (hand loops — layer norm, attention softmax chain,
//! optimizer — stay serial and deterministic).  Steady state reuses the
//! tape and every staging pool, so the loop is allocation-free past the
//! first iteration.

use slope::backend::{simd_level, ParallelPolicy};
use slope::runtime::{write_host_train_artifact, HostTrainModel, Manifest};
use slope::util::bench::{bench_auto, black_box, emit_json, print_header};
use slope::util::Rng;

const THREADS: [usize; 3] = [1, 2, 4];

fn main() {
    let dir = std::env::temp_dir().join("slope_bench_train_artifact");
    std::fs::remove_dir_all(&dir).ok();
    write_host_train_artifact(&dir, "bench-train").expect("fabricate artifact");
    let manifest = Manifest::load(&dir).expect("manifest");
    let c = manifest.config.clone();
    let mut rng = Rng::seed_from_u64(0);
    let tokens: Vec<i32> = (0..c.batch_size * (c.seq_len + 1))
        .map(|_| rng.below(c.vocab_size) as i32)
        .collect();

    print_header("bench_train — host train step (double-pruned backward)");
    println!("simd level: {} (SLOPE_SIMD to override)", simd_level());
    println!(
        "{:<26} {:>3} {:>12} {:>12} {:>9}",
        "case", "thr", "per-step", "per-seq", "vs 1thr"
    );
    for (case, with_lora) in [("train/step", false), ("train_lora/step", true)] {
        let mut one_thr_ns = f64::NAN;
        for threads in THREADS {
            let policy = ParallelPolicy::for_width(threads, c.d_model);
            let mut model =
                HostTrainModel::init(&manifest, 7, policy).expect("host train model");
            if with_lora {
                model.lora_init(9).expect("lora init");
            }
            // Warm the tape/pools so the measured loop is steady-state.
            let r = bench_auto(&format!("{case} t{threads}"), 180.0, || {
                let loss = if with_lora {
                    model.train_step_lora(&tokens).expect("step")
                } else {
                    model.train_step(&tokens).expect("step")
                };
                black_box(loss);
            });
            if threads == 1 {
                one_thr_ns = r.median_ns;
            }
            emit_json("bench_train", case, threads, &r);
            println!(
                "{:<26} {:>3} {:>10.2}us {:>10.2}us {:>8.2}x",
                case,
                threads,
                r.median_ns / 1e3,
                r.median_ns / 1e3 / c.batch_size as f64,
                one_thr_ns / r.median_ns
            );
        }
    }
    println!(
        "\n(each step = full forward tape + cross-entropy + backward through\n \
         LN/attention/GELU with the Eq.-6 packed W^(R,C) grad_input and the\n \
         Eq.-5 masked packed grad_weight, plus the AdamW epilogue in\n \
         compressed space — the native `slope train` hot path.)"
    );
    std::fs::remove_dir_all(&dir).ok();
}
