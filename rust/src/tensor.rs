//! Minimal row-major `f32` matrix used by the CPU sparse backend, the
//! synthetic-data pipeline and the checkpoint code.
//!
//! This is intentionally small: the heavy lifting happens either inside the
//! AOT-compiled HLO (training/inference) or in [`crate::backend`]'s blocked
//! kernels (the cuSPARSELt-role CPU implementation).

use crate::util::Rng;

/// Dense row-major matrix of `f32`.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Default for Matrix {
    /// Empty 0×0 matrix — the unsized state of reusable kernel workspaces.
    fn default() -> Self {
        Matrix::zeros(0, 0)
    }
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Self { rows, cols, data }
    }

    /// Fill with iid N(0, scale²) Gaussian values from the supplied RNG.
    pub fn randn(rows: usize, cols: usize, scale: f32, rng: &mut Rng) -> Self {
        let data = (0..rows * cols).map(|_| rng.normal_f32(scale)).collect();
        Self { rows, cols, data }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out.data[c * self.rows + r] = self.data[r * self.cols + c];
            }
        }
        out
    }

    /// Fraction of non-zero entries.
    pub fn density(&self) -> f64 {
        let nnz = self.data.iter().filter(|v| **v != 0.0).count();
        nnz as f64 / self.data.len().max(1) as f64
    }

    /// Element-wise multiply (e.g. apply a 0/1 mask).
    pub fn hadamard(&self, other: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| a * b)
            .collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    /// Max |a - b| over all elements.
    pub fn max_abs_diff(&self, other: &Matrix) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Frobenius norm.
    pub fn frob(&self) -> f32 {
        self.data.iter().map(|v| v * v).sum::<f32>().sqrt()
    }
}

/// Cosine similarity between two equally-shaped matrices viewed as vectors
/// (used for the Figure-3b adapter-convergence metric).
pub fn cosine_similarity(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    let dot: f32 = a.iter().zip(b).map(|(x, y)| x * y).sum();
    let na: f32 = a.iter().map(|x| x * x).sum::<f32>().sqrt();
    let nb: f32 = b.iter().map(|x| x * x).sum::<f32>().sqrt();
    if na == 0.0 || nb == 0.0 {
        0.0
    } else {
        dot / (na * nb)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Rng::seed_from_u64(0);
        let m = Matrix::randn(7, 5, 1.0, &mut rng);
        assert_eq!(m.transpose().transpose(), m);
    }

    #[test]
    fn randn_moments() {
        let mut rng = Rng::seed_from_u64(1);
        let m = Matrix::randn(200, 200, 2.0, &mut rng);
        let mean: f32 = m.data.iter().sum::<f32>() / m.data.len() as f32;
        let var: f32 = m.data.iter().map(|v| (v - mean).powi(2)).sum::<f32>()
            / m.data.len() as f32;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 4.0).abs() < 0.2, "var {var}");
    }

    #[test]
    fn density_and_hadamard() {
        let m = Matrix::from_vec(2, 2, vec![1.0, 0.0, 2.0, 0.0]);
        assert_eq!(m.density(), 0.5);
        let h = m.hadamard(&Matrix::from_vec(2, 2, vec![2.0, 3.0, 0.0, 1.0]));
        assert_eq!(h.data, vec![2.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn cosine() {
        assert!((cosine_similarity(&[1.0, 0.0], &[2.0, 0.0]) - 1.0).abs() < 1e-6);
        assert!(cosine_similarity(&[1.0, 0.0], &[0.0, 1.0]).abs() < 1e-6);
    }
}
