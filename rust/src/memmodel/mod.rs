//! Bit-exact memory accounting (paper §3.1 "Memory footprint analysis" and
//! Table 3).
//!
//! Per dense-equivalent parameter of a 2:4-pruned linear:
//! * **Dense training**: 16 (fp16 weight) + 16 (fp16 grad) + 2×32 (fp32
//!   Adam moments) = 96 bits.
//! * **SLoPe training**: the weight AND its transpose stored compressed —
//!   2 × (16·N/M + index) where index = ⌈log₂ C(M,N)⌉/M bits per dense
//!   element (Eq. 7) — plus a 1-bit mask, N/M-sparse fp16 gradients and
//!   N/M-sparse fp32 Adam moments.
//! * **Dense inference**: 16 bits; **SLoPe inference**: 16·N/M + index
//!   bits (+ fp16 adapters at rank r: 16·(d_in+d_out)·r per layer).
//!
//! Non-pruned tensors (embeddings, norms, biases, the first linear, the
//! head) are charged at full dense rates, which is why measured ratios sit
//! slightly above the closed-form 2:4 numbers — exactly the effect the
//! paper notes under Table 3.
//!
//! Autoregressive serving adds **decode state**: an f32 KV cache of
//! `layers × 2 × seq × d` per sequence ([`kv_cache_bytes`], the
//! contiguous-slab reference).  Sparsity compresses weights, not
//! activations, so that cache charges both sides of the ratio equally
//! ([`inference_memory_with_decode`]) — the paper's 0.61× inference
//! claim re-derived with generation state included.  The paged runtime
//! pool ([`crate::runtime::KvBlockPool`]) is charged block-granularly
//! instead ([`kv_block_bytes`] / [`kv_pool_bytes`], matching the pool's
//! own accounting bit-for-bit), and
//! [`inference_memory_with_paged_decode`] re-derives the serving ratio
//! with the SLoPe side's cache paged and optionally f16/int8-quantized.
//! When the pool's prefix cache shares prompt blocks copy-on-write,
//! [`kv_shared_prefix_bytes`] charges the shared whole-block prefix once
//! and per-sequence suffixes separately — again matching the pool's live
//! byte count exactly.

use crate::config::zoo::ModelShape;
use crate::sparsity::NmScheme;

/// Bits per dense element of index metadata for a scheme (Eq. 7).
pub fn index_bits_per_elem(s: NmScheme) -> f64 {
    s.index_bits_per_group() as f64 / s.m as f64
}

/// Bits per dense element of the *packed in-RAM* metadata the CPU backend
/// actually stores (`ceil(log2 M)` bits per kept value — the hardware
/// rounding of the Eq.-7 entropy bound; see `sparsity::CompressedNm`).
pub fn packed_index_bits_per_elem(s: NmScheme) -> f64 {
    s.offset_bits() as f64 * s.n as f64 / s.m as f64
}

/// Packed metadata bytes a `rows × cols` `CompressedNm` stores (rows are
/// byte-aligned) — the true backend charge, vs. the 2 bytes per kept
/// value of a `u16` absolute-index plane.
pub fn packed_metadata_bytes(rows: usize, cols: usize, s: NmScheme) -> usize {
    let kept_per_row = cols / s.m * s.n;
    rows * ((kept_per_row * s.offset_bits() as usize + 7) / 8)
}

/// Bytes a `rows × cols` [`crate::sparsity::PrepackedNm`] fused stream
/// stores — the charge for the prepacked forward operand the runtime
/// builds once per pruned linear (values interleaved with decode
/// metadata in `u32` slots; ~1.2× the compressed plane for 2:4, traded
/// for the register-blocked SpMM's single-stream access pattern).
pub fn prepacked_plane_bytes(rows: usize, cols: usize, s: NmScheme) -> usize {
    rows * crate::sparsity::PrepackedNm::row_stride_for(cols, s) * 4
}

/// Training-state bits per dense-equivalent element of a *pruned* linear.
pub fn slope_train_bits_per_elem(s: NmScheme) -> f64 {
    let dens = s.density();
    let value_bits = 16.0 * dens + index_bits_per_elem(s);
    let both_copies = 2.0 * value_bits; // W and Wᵀ (Algorithm 1 lines 3–4)
    let mask = 1.0;
    let grads = 16.0 * dens;
    let opt = 2.0 * 32.0 * dens;
    both_copies + mask + grads + opt
}

pub const DENSE_TRAIN_BITS: f64 = 16.0 + 16.0 + 64.0;
pub const DENSE_INFER_BITS: f64 = 16.0;

/// Dense f32 training state per element on the **host training path**
/// (`runtime::host_train`): weight + gradient + two Adam moments, all
/// f32 (the CPU engine trains in f32, not fp16).
pub const DENSE_HOST_TRAIN_BITS: f64 = 4.0 * 32.0;

/// Live f32 host-path training bits per dense-equivalent element of a
/// pruned linear — charging exactly what [`crate::runtime::HostTrainModel`]
/// keeps resident per pruned weight:
/// * `W^R` and the `W^{R,C}` transpose, each packed values (`32·ρ`) +
///   the Eq.-7 bit-packed offset plane;
/// * the packed masked gradient (same layout);
/// * the `w_t` pad bitset (1 bit per packed slot = `ρ` bits/elem);
/// * masked Adam moments (`2·32·ρ` — the §3.1 2×-reduced optimizer
///   state, stored slot-aligned with the packed values).
///
/// The dense ∇W staging is a *shared* transient (one buffer per distinct
/// shape, reused by every linear), so it amortizes instead of scaling
/// with parameter count and is excluded here — the same treatment the
/// paper gives its fused prune-and-compress kernel's intermediate.
pub fn host_train_bits_per_elem(s: NmScheme) -> f64 {
    let rho = s.density();
    let packed_plane = 32.0 * rho + packed_index_bits_per_elem(s);
    let copies = 2.0 * packed_plane; // W^R and W^{R,C}ᵀ
    let grad = packed_plane; // packed masked ∇W
    let pad_bits = rho; // w_t pad bitset
    let moments = 2.0 * 32.0 * rho;
    copies + grad + pad_bits + moments
}

/// Host-path training ratio for a pure-2:4 pruned linear — the live-size
/// re-derivation of the paper's 0.63× training-memory claim at f32 rates
/// (2:4: 83.5 / 128 ≈ 0.652, inside the Table-3 0.63–0.68 band).
pub fn host_train_ratio(s: NmScheme) -> f64 {
    host_train_bits_per_elem(s) / DENSE_HOST_TRAIN_BITS
}

/// Table-3 training column re-derived from the host path's live rates:
/// pruned linears at [`host_train_bits_per_elem`], the dense remainder at
/// [`DENSE_HOST_TRAIN_BITS`].
pub fn host_training_memory(shape: &ModelShape, s: NmScheme) -> MemoryReport {
    let (pruned, dense_rest) = split_params(shape);
    let dense_bits = (pruned + dense_rest) * DENSE_HOST_TRAIN_BITS;
    let slope_bits =
        pruned * host_train_bits_per_elem(s) + dense_rest * DENSE_HOST_TRAIN_BITS;
    MemoryReport { dense_bits, slope_bits }
}

/// Inference bits per dense-equivalent element of a pruned linear.
pub fn slope_infer_bits_per_elem(s: NmScheme) -> f64 {
    16.0 * s.density() + index_bits_per_elem(s)
}

/// Closed-form §3.1 ratios for a pure-2:4 model (no dense remainder).
pub fn theoretical_train_ratio(s: NmScheme) -> f64 {
    slope_train_bits_per_elem(s) / DENSE_TRAIN_BITS
}

pub fn theoretical_infer_ratio(s: NmScheme) -> f64 {
    slope_infer_bits_per_elem(s) / DENSE_INFER_BITS
}

/// Memory accounting for a full model shape.
#[derive(Clone, Copy, Debug)]
pub struct MemoryReport {
    pub dense_bits: f64,
    pub slope_bits: f64,
}

impl MemoryReport {
    pub fn ratio(&self) -> f64 {
        self.slope_bits / self.dense_bits
    }

    pub fn dense_gib(&self) -> f64 {
        self.dense_bits / 8.0 / (1u64 << 30) as f64
    }

    pub fn slope_gib(&self) -> f64 {
        self.slope_bits / 8.0 / (1u64 << 30) as f64
    }
}

fn split_params(shape: &ModelShape) -> (f64, f64) {
    // (prunable linear elements, dense-remainder elements).
    let prunable = (shape.n_layer * shape.block_linear_params()) as f64;
    let dense_rest = (shape.total_params() as f64) - prunable;
    // First linear after the input stays dense (paper §3.2): move one qkv.
    let d = shape.d_model;
    let kv = shape.n_kv_head * shape.head_dim();
    let first_qkv = (d * (d + 2 * kv)) as f64;
    (prunable - first_qkv, dense_rest + first_qkv)
}

/// Table-3 training column: end-to-end training memory ratio.
pub fn training_memory(shape: &ModelShape, s: NmScheme) -> MemoryReport {
    let (pruned, dense_rest) = split_params(shape);
    let dense_bits = (pruned + dense_rest) * DENSE_TRAIN_BITS;
    let slope_bits = pruned * slope_train_bits_per_elem(s) + dense_rest * DENSE_TRAIN_BITS;
    MemoryReport { dense_bits, slope_bits }
}

/// Table-3 inference column at a given adapter-rank ratio (rank/d_model).
pub fn inference_memory(shape: &ModelShape, s: NmScheme, rank_ratio: f64) -> MemoryReport {
    let (pruned, dense_rest) = split_params(shape);
    let dense_bits = (pruned + dense_rest) * DENSE_INFER_BITS;
    let mut slope_bits = pruned * slope_infer_bits_per_elem(s) + dense_rest * DENSE_INFER_BITS;
    if rank_ratio > 0.0 {
        let r = (shape.d_model as f64 * rank_ratio).round();
        // One (L, R) pair per pruned linear: 16·(d_in + d_out)·r bits.
        let d = shape.d_model as f64;
        let kv = (shape.n_kv_head * shape.head_dim()) as f64;
        let ff = shape.d_ff as f64;
        let mut per_block = (d + (d + 2.0 * kv)) * r + (d + d) * r; // qkv + proj
        per_block += if shape.gated_mlp {
            (d + 2.0 * ff) * r + (ff + d) * r
        } else {
            (d + ff) * r + (ff + d) * r
        };
        slope_bits += shape.n_layer as f64 * per_block * 16.0;
    }
    MemoryReport { dense_bits, slope_bits }
}

/// Raw bytes of ONE sequence's KV cache at full context:
/// `layers × 2 × seq_len × d_kv × 4` (f32 K and V planes) — exactly what
/// [`crate::runtime::KvCache`] allocates (`d_kv = d_model` for the MHA
/// models we serve; pass `n_kv_head · head_dim` for GQA shapes).
pub fn kv_cache_bytes(n_layer: usize, seq_len: usize, d_kv: usize) -> usize {
    n_layer * 2 * seq_len * d_kv * 4
}

/// Table-3 inference column **with decode state included**: the weight
/// footprint of [`inference_memory`] plus the f32 KV cache for `batch`
/// concurrent sequences at context `seq_len`.  Dense and SLoPe
/// deployments carry the *same* cache (sparsity compresses weights, not
/// activations), so both sides gain the identical term and the ratio
/// relaxes toward 1 as `batch × seq_len` grows — the honest re-derivation
/// of the paper's 0.61× inference-memory claim under autoregressive
/// serving.
pub fn inference_memory_with_decode(shape: &ModelShape, s: NmScheme, rank_ratio: f64,
                                    seq_len: usize, batch: usize) -> MemoryReport {
    let mut report = inference_memory(shape, s, rank_ratio);
    let d_kv = shape.n_kv_head * shape.head_dim();
    let kv_bits = (batch * kv_cache_bytes(shape.n_layer, seq_len, d_kv)) as f64 * 8.0;
    report.dense_bits += kv_bits;
    report.slope_bits += kv_bits;
    report
}

/// Resident bytes of ONE block in a paged KV pool: K+V rows for every
/// layer over `block_tokens` positions at the dtype's width, plus the
/// per-(layer, plane) f32 scales int8 carries — exactly
/// [`crate::runtime::KvBlockPool`]'s `block_bytes`.
pub fn kv_block_bytes(n_layer: usize, block_tokens: usize, d_kv: usize,
                      dtype: crate::runtime::KvDtype) -> usize {
    let elems = n_layer * 2 * block_tokens * d_kv;
    let scales = match dtype {
        crate::runtime::KvDtype::Int8 => n_layer * 2 * 4,
        _ => 0,
    };
    elems * dtype.elem_bytes() + scales
}

/// Block-granular bytes of ONE sequence at context `seq_len` in a paged
/// pool: whole blocks, so a partial tail block is charged in full (the
/// allocator's real cost, unlike the element-exact [`kv_cache_bytes`]).
pub fn kv_pool_bytes(n_layer: usize, seq_len: usize, d_kv: usize, block_tokens: usize,
                     dtype: crate::runtime::KvDtype) -> usize {
    let blocks = seq_len / block_tokens + usize::from(seq_len % block_tokens != 0);
    blocks * kv_block_bytes(n_layer, block_tokens, d_kv, dtype)
}

/// Block-granular bytes of `batch` sequences that SHARE a cached prompt
/// prefix of `prefix_len` tokens in a prefix-caching pool: the prefix's
/// whole blocks are resident once (copy-on-write sharing), each sequence
/// pays only its suffix blocks.  Only `prefix_len / block_tokens` *whole*
/// blocks are shareable — the partial tail of a prefix is private to each
/// sequence, which is why the shared term floors rather than ceils.
/// Setting `prefix_len = 0` (or `batch = 1` with `prefix_len = seq_len`
/// rounding away) recovers `batch ×` [`kv_pool_bytes`].
pub fn kv_shared_prefix_bytes(n_layer: usize, prefix_len: usize, seq_len: usize, batch: usize,
                              d_kv: usize, block_tokens: usize,
                              dtype: crate::runtime::KvDtype) -> usize {
    assert!(prefix_len <= seq_len, "prefix cannot exceed the sequence");
    let bb = kv_block_bytes(n_layer, block_tokens, d_kv, dtype);
    let shared = prefix_len / block_tokens;
    let per_seq = seq_len / block_tokens + usize::from(seq_len % block_tokens != 0);
    shared * bb + batch * (per_seq - shared) * bb
}

/// [`inference_memory_with_decode`] with the SLoPe side's cache **paged
/// and (optionally) quantized**: the dense baseline keeps its contiguous
/// f32 slab per sequence, the SLoPe deployment charges
/// `batch × `[`kv_pool_bytes`] at the pool's block size and dtype.  At
/// f32 the two charges differ only by tail-block rounding; at int8 the
/// cache term shrinks ~4× and the ratio recovers toward the weight-only
/// claim even at large `batch × seq_len`.
pub fn inference_memory_with_paged_decode(shape: &ModelShape, s: NmScheme, rank_ratio: f64,
                                          seq_len: usize, batch: usize, block_tokens: usize,
                                          dtype: crate::runtime::KvDtype) -> MemoryReport {
    let mut report = inference_memory(shape, s, rank_ratio);
    let d_kv = shape.n_kv_head * shape.head_dim();
    report.dense_bits += (batch * kv_cache_bytes(shape.n_layer, seq_len, d_kv)) as f64 * 8.0;
    report.slope_bits +=
        (batch * kv_pool_bytes(shape.n_layer, seq_len, d_kv, block_tokens, dtype)) as f64 * 8.0;
    report
}

/// FST training memory (Table 3 shows FST > 1.0): dense weights PLUS the
/// compressed sparse copies and transposable-mask metadata coexist.
pub fn fst_training_memory(shape: &ModelShape, s: NmScheme) -> MemoryReport {
    let (pruned_all, dense_rest) = split_params(shape);
    // FST prunes MLP only.
    let mlp_frac = {
        let d = shape.d_model as f64;
        let ff = shape.d_ff as f64;
        let kv = (shape.n_kv_head * shape.head_dim()) as f64;
        let mlp = if shape.gated_mlp { 3.0 * d * ff } else { 2.0 * d * ff };
        let all = d * (d + 2.0 * kv) + d * d + mlp;
        mlp / all
    };
    let mlp = pruned_all * mlp_frac;
    let rest = pruned_all - mlp + dense_rest;
    let dense_bits = (pruned_all + dense_rest) * DENSE_TRAIN_BITS;
    // Dense states everywhere + 2 compressed copies + masks on MLP weights.
    let extra = mlp * (2.0 * (16.0 * s.density() + index_bits_per_elem(s)) + 1.0);
    let fst_bits = (mlp + rest) * DENSE_TRAIN_BITS + extra;
    MemoryReport { dense_bits, slope_bits: fst_bits }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::zoo::*;

    const S24: NmScheme = NmScheme::TWO_FOUR;

    #[test]
    fn closed_form_ratios_match_section_31() {
        // §3.1: training reduced to ≈63%, inference to ≈59% for pure 2:4.
        let tr = theoretical_train_ratio(S24);
        assert!((tr - 0.609).abs() < 0.02, "train ratio {tr}");
        let inf = theoretical_infer_ratio(S24);
        assert!((inf - 35.0 / 64.0).abs() < 1e-9, "infer ratio {inf}"); // (2·16+3)/4·16
    }

    #[test]
    fn table3_training_band() {
        // Table 3 training: 0.63–0.68 across the sweep set.
        for m in SPEEDUP_MODELS {
            let r = training_memory(&m, S24).ratio();
            assert!(r > 0.58 && r < 0.72, "{}: {r:.3}", m.name);
        }
    }

    #[test]
    fn table3_inference_band_and_rank_ordering() {
        for m in SPEEDUP_MODELS {
            let r0 = inference_memory(&m, S24, 0.0).ratio();
            let r1 = inference_memory(&m, S24, 0.0156).ratio();
            let r6 = inference_memory(&m, S24, 0.0625).ratio();
            assert!(r0 > 0.55 && r0 < 0.75, "{}: {r0:.3}", m.name);
            assert!(r0 < r1 && r1 < r6, "{}: {r0:.3} {r1:.3} {r6:.3}", m.name);
        }
    }

    #[test]
    fn fst_training_memory_exceeds_dense() {
        // Table 3: FST training column is 1.15–1.27 (overhead, not saving).
        for m in SPEEDUP_MODELS {
            let r = fst_training_memory(&m, S24).ratio();
            assert!(r > 1.05 && r < 1.35, "{}: {r:.3}", m.name);
        }
    }

    #[test]
    fn sparser_schemes_save_more() {
        let m = OPT_13B;
        let r24 = training_memory(&m, NmScheme::new(2, 4)).ratio();
        let r28 = training_memory(&m, NmScheme::new(2, 8)).ratio();
        assert!(r28 < r24);
    }

    #[test]
    fn kv_cache_charge_matches_the_runtime_and_relaxes_the_ratio() {
        use crate::runtime::{KvBlockPool, KvDtype, KvPoolConfig, DEFAULT_KV_BLOCK_TOKENS};
        // The closed-form charge is exactly what the decode runtime holds
        // at full context (128 divides the default block size, so the
        // paged charge has no tail rounding and matches the element-exact
        // slab formula too).
        let (l, s, d) = (4usize, 128usize, 96usize);
        let pool = KvBlockPool::new(l, d, KvPoolConfig::default());
        let mut cache = pool.new_cache(s);
        cache.reserve(s).unwrap();
        cache.set_len(s);
        assert_eq!(
            cache.bytes(),
            kv_pool_bytes(l, s, d, DEFAULT_KV_BLOCK_TOKENS, KvDtype::F32)
        );
        assert_eq!(cache.bytes(), kv_cache_bytes(l, s, d));
        assert_eq!(kv_cache_bytes(l, s, d), l * 2 * s * d * 4);
        // Truncation returns whole blocks and the accounting follows.
        cache.truncate(s / 2);
        assert_eq!(
            cache.bytes(),
            kv_pool_bytes(l, s / 2, d, DEFAULT_KV_BLOCK_TOKENS, KvDtype::F32),
            "freed blocks must leave the byte charge"
        );
        // Decode state is sparsity-blind: both sides gain the same bits,
        // so the ratio sits strictly between the weight-only ratio and 1,
        // and grows monotonically with context and batch.
        let m = OPT_13B;
        let r0 = inference_memory(&m, S24, 0.0156).ratio();
        let r1 = inference_memory_with_decode(&m, S24, 0.0156, 2048, 1).ratio();
        let r8 = inference_memory_with_decode(&m, S24, 0.0156, 2048, 8).ratio();
        let r_long = inference_memory_with_decode(&m, S24, 0.0156, 8192, 8).ratio();
        let r_big = inference_memory_with_decode(&m, S24, 0.0156, 8192, 64).ratio();
        assert!(r0 < r1 && r1 < r8 && r8 < r_long && r_long < r_big && r_big < 1.0,
                "{r0:.4} {r1:.4} {r8:.4} {r_long:.4} {r_big:.4}");
        // A single full-context sequence leaves the weight term dominant,
        // so the headline claim survives with decode state included...
        assert!(r1 < 0.70, "0.61x-claim band with one sequence of state: {r1:.3}");
        // ...while a batch of 8 full-context f32 caches rivals the fp16
        // weights themselves — the quantitative case for paging/quantizing
        // the cache that the report now makes visible.
        assert!(r8 > 0.75, "batched decode state must dominate: {r8:.3}");
    }

    #[test]
    fn paged_int8_cache_cuts_kv_bytes_over_3x_and_recovers_the_ratio() {
        use crate::runtime::{KvBlockPool, KvDtype, KvPoolConfig};
        // The closed-form block charge is exactly the pool's, per dtype.
        let (l, bt, d) = (4usize, 16usize, 96usize);
        for dtype in [KvDtype::F32, KvDtype::F16, KvDtype::Int8] {
            let pool = KvBlockPool::new(
                l, d,
                KvPoolConfig { block_tokens: bt, dtype, ..KvPoolConfig::default() },
            );
            assert_eq!(pool.block_bytes(), kv_block_bytes(l, bt, d, dtype), "{dtype:?}");
        }
        // int8 at full context sits ≥ 3× below the f32 charge (the
        // acceptance bar; actually ≈ 3.99× — the scales cost 32 B per
        // 48 KiB-worth of f32 rows), and f16 exactly halves it.
        let s = 2048usize;
        let f32b = kv_pool_bytes(l, s, d, bt, KvDtype::F32);
        let i8b = kv_pool_bytes(l, s, d, bt, KvDtype::Int8);
        assert!(f32b >= 3 * i8b, "int8 reduction below 3x: {f32b} vs {i8b}");
        assert_eq!(kv_pool_bytes(l, s, d, bt, KvDtype::F16) * 2, f32b);
        // Under batched full-context serving the ratio collapses with an
        // f32 cache (both sides carry it) but recovers with the SLoPe
        // side quantized — the serving-memory lever the pool exists for.
        let m = OPT_13B;
        let slab = inference_memory_with_decode(&m, S24, 0.0156, 8192, 64).ratio();
        let paged_f32 =
            inference_memory_with_paged_decode(&m, S24, 0.0156, 8192, 64, bt, KvDtype::F32)
                .ratio();
        let paged_i8 =
            inference_memory_with_paged_decode(&m, S24, 0.0156, 8192, 64, bt, KvDtype::Int8)
                .ratio();
        assert!(
            (paged_f32 - slab).abs() < 1e-9,
            "8192 % 16 == 0: f32 paging adds no tail rounding ({paged_f32} vs {slab})"
        );
        assert!(
            paged_i8 < paged_f32 && paged_i8 < 0.70,
            "int8 cache must recover the headline band: {paged_i8:.3} vs {paged_f32:.3}"
        );
    }

    #[test]
    fn shared_prefix_charge_matches_a_prefix_caching_pool() {
        use crate::runtime::{KvBlockPool, KvDtype, KvPoolConfig};
        let (l, bt, d) = (4usize, 16usize, 96usize);
        let pool = KvBlockPool::new(
            l, d,
            KvPoolConfig { block_tokens: bt, prefix_cache: Some(64), ..KvPoolConfig::default() },
        );
        // One sequence computes a 40-token prompt (2 whole blocks plus a
        // tail) and publishes it; three more attach the cached 32-token
        // prefix and allocate only their private tail block.
        let prompt: Vec<i32> = (0..40).collect();
        let mut seqs = Vec::new();
        let mut first = pool.new_cache(64);
        first.reserve(40).unwrap();
        first.set_len(40);
        first.publish_prefix(&prompt);
        seqs.push(first);
        for _ in 0..3 {
            let mut c = pool.new_cache(64);
            assert_eq!(c.attach_prefix(&prompt), 32, "two whole blocks hit");
            c.reserve(40).unwrap();
            c.set_len(40);
            seqs.push(c);
        }
        // 2 shared blocks charged once + 4 private tails = 6 resident
        // blocks, not the 12 a shareless pool would hold — and the
        // closed form is exactly the pool's live byte count.
        let st = pool.stats();
        assert_eq!(st.bytes_in_use, kv_shared_prefix_bytes(l, 32, 40, 4, d, bt, KvDtype::F32));
        assert_eq!(st.bytes_in_use, 6 * st.block_bytes);
        assert!(st.bytes_in_use < 4 * kv_pool_bytes(l, 40, d, bt, KvDtype::F32));
        // With nothing shareable the closed form degrades to batch × pool.
        assert_eq!(
            kv_shared_prefix_bytes(l, 0, 40, 4, d, bt, KvDtype::F32),
            4 * kv_pool_bytes(l, 40, d, bt, KvDtype::F32)
        );
        drop(seqs);
        pool.clear_prefix_cache();
        assert_eq!(pool.stats().blocks_in_use, 0);
    }

    #[test]
    fn host_training_state_rederives_the_063x_claim_from_live_sizes() {
        use crate::backend::ParallelPolicy;
        use crate::runtime::{write_host_train_artifact, HostTrainModel, Manifest};
        // Closed form first: f32 host rates land inside the Table-3
        // training band (0.63–0.68 at 2:4; the f32 number is 83.5/128).
        let r = host_train_ratio(S24);
        assert!((r - 83.5 / 128.0).abs() < 1e-9, "host 2:4 rate: {r}");
        assert!(r > 0.58 && r < 0.72, "host train ratio {r}");
        for m in SPEEDUP_MODELS {
            let full = host_training_memory(&m, S24).ratio();
            assert!(full > 0.60 && full < 0.80, "{}: {full:.3}", m.name);
        }
        // Live sizes: build an actual host training model and charge the
        // bytes it really holds for the pruned linears.
        let dir = std::env::temp_dir().join("slope_memmodel_host_train_test");
        std::fs::remove_dir_all(&dir).ok();
        write_host_train_artifact(&dir, "mem-derive").unwrap();
        let manifest = Manifest::load(&dir).unwrap();
        let model =
            HostTrainModel::init(&manifest, 7, ParallelPolicy::serial()).unwrap();
        let sb = model.state_bytes();
        assert!(sb.pruned_bytes > 0 && sb.pruned_dense_bytes > 0);
        // Measured packed/dense ratio must re-derive the closed-form rate
        // (1% slack: the pad bitset rounds up to whole u64 words).
        let live = sb.pruned_bytes as f64 / sb.pruned_dense_bytes as f64;
        let want = host_train_ratio(S24);
        assert!(
            (live - want).abs() < 0.01,
            "live packed ratio {live:.4} vs closed form {want:.4}"
        );
        assert!(live > 0.58 && live < 0.72, "live host train ratio {live:.4}");
        // Dense-equivalent charge is literally 4 f32 planes per element.
        let (d, f, l) = (32usize, 64, 2); // SynthSpec::default shape
        let pruned_elems = l * (3 * d * d + d * d + 2 * d * f) - 3 * d * d; // layer-0 qkv dense
        assert_eq!(sb.pruned_dense_bytes, pruned_elems * 16);
        // The shared ∇W staging stays transient-sized: a few distinct
        // shapes, not one per linear.
        assert!(sb.workspace_bytes <= 4 * (3 * d * d + d * d + 2 * d * f) * 4);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn packed_metadata_charge_matches_backend_and_shrinks_4x() {
        use crate::sparsity::{random_row_mask, CompressedNm};
        use crate::tensor::Matrix;
        use crate::util::Rng;
        // The packed rate for 2:4 is 2 bits per kept value = 1 bit per
        // dense element.
        assert!((packed_index_bits_per_elem(S24) - 1.0).abs() < 1e-12);
        // Charge an actual compressed weight at the packed rate and check
        // it against what the backend really stores.
        let (rows, cols) = (96, 512);
        let mut rng = Rng::seed_from_u64(0);
        let w = Matrix::randn(rows, cols, 1.0, &mut rng);
        let mask = random_row_mask(rows, cols, S24, &mut rng);
        let c = CompressedNm::compress(&w, &mask, S24);
        assert_eq!(c.meta_bytes(), packed_metadata_bytes(rows, cols, S24));
        // ≥ 4× smaller than the pre-engine u16 absolute-index plane
        // (2 bytes per kept value); for 2:4 the actual factor is 8×.
        let u16_plane_bytes = rows * (cols / 2) * 2;
        assert!(
            u16_plane_bytes >= 4 * c.meta_bytes(),
            "packed plane {} vs u16 plane {}",
            c.meta_bytes(),
            u16_plane_bytes
        );
        assert_eq!(u16_plane_bytes / c.meta_bytes(), 8);
    }

    #[test]
    fn prepacked_charge_matches_live_stream_for_all_schemes_and_tails() {
        use crate::sparsity::{random_row_mask, CompressedNm, NmScheme, PrepackedNm};
        use crate::tensor::Matrix;
        use crate::util::Rng;
        let schemes = [NmScheme::new(1, 2), NmScheme::TWO_FOUR, NmScheme::new(2, 8)];
        let mut rng = Rng::seed_from_u64(7);
        for s in schemes {
            // Cover the fused-2:4 pair / trailing-byte / half-byte tails.
            for groups in [1usize, 2, 3, 5, 8] {
                let (rows, cols) = (5, groups * s.m);
                let w = Matrix::randn(rows, cols, 1.0, &mut rng);
                let mask = random_row_mask(rows, cols, s, &mut rng);
                let c = CompressedNm::compress(&w, &mask, s);
                let p = PrepackedNm::prepack(&c);
                assert_eq!(
                    p.stream_bytes(),
                    prepacked_plane_bytes(rows, cols, s),
                    "scheme {s} groups {groups}"
                );
            }
        }
        // 2:4 fused stream is a bounded constant factor over the
        // compressed plane: 10 u32 slots per byte pair vs 8 values
        // (32 B) + 2 meta bytes = 40/34 ≈ 1.18×.
        let (rows, cols) = (8, 256);
        let w = Matrix::randn(rows, cols, 1.0, &mut rng);
        let mask = random_row_mask(rows, cols, S24, &mut rng);
        let c = CompressedNm::compress(&w, &mask, S24);
        let compressed = c.values.len() * 4 + c.meta_bytes();
        let pre = prepacked_plane_bytes(rows, cols, S24);
        assert!(pre > compressed && pre * 10 <= compressed * 12,
                "prepacked {pre} vs compressed {compressed}");
    }
}
