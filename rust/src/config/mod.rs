//! Run configuration: which AOT-compiled model config to drive, with what
//! schedule, method and evaluation cadence.  Consumed by the CLI
//! (`slope train --config ...`), the examples and the experiment harness.

pub mod zoo;

use crate::backend::ParallelPolicy;
use std::path::PathBuf;

/// Training method selector (which AOT executable family drives the run).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    /// SLoPe: static random N:M masks + double-pruned backward (the paper).
    Slope,
    /// Dense baseline (ones masks through the same executable).
    Dense,
    /// Extended SR-STE: dynamic magnitude masks + decay regularizer.
    Srste,
    /// Extended SR-STE followed by lazy adapters: the dynamic run is
    /// projected onto its final magnitude mask, then adapters train for the
    /// lazy tail (the "E-SR-STE + adapters" rows of Table 4).
    SrsteLora,
    /// Dense pretrain → Wanda one-shot prune at the end.
    Wanda,
    /// Figure-9 pruning-target variants.
    Fig9(Fig9Variant),
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Fig9Variant {
    WeightStatic,
    WeightDynamic,
    InputStatic,
    InputDynamic,
    GradoutDynamic,
}

impl Fig9Variant {
    pub fn exe_name(&self) -> &'static str {
        match self {
            Fig9Variant::WeightStatic => "train_step_fig9_weight_static",
            Fig9Variant::WeightDynamic => "train_step_fig9_weight_dynamic",
            Fig9Variant::InputStatic => "train_step_fig9_input_static",
            Fig9Variant::InputDynamic => "train_step_fig9_input_dynamic",
            Fig9Variant::GradoutDynamic => "train_step_fig9_gradout_dynamic",
        }
    }
}

/// One pretraining run.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// Artifact config directory name (e.g. "gpt-nano").
    pub model: String,
    pub method: Method,
    /// Total optimizer steps (overrides the manifest's schedule length for
    /// CPU-budget control; LR schedule still follows the manifest).
    pub steps: usize,
    /// Final fraction run with lazy adapters (paper: 0.01); 0 disables.
    pub lazy_fraction: f64,
    /// Evaluate every N steps.
    pub eval_every: usize,
    /// Validation batches per evaluation.
    pub eval_batches: usize,
    /// Data/mask seed.
    pub seed: u64,
    /// Artifact root.
    pub artifacts: PathBuf,
    /// Where to write metrics (JSON lines).
    pub out_dir: PathBuf,
    /// When set, write a serving checkpoint (store planes + packed
    /// compressed-weight planes + a manifest copy) into this directory at
    /// every eval point — the artifact `slope serve --manifest` restores.
    /// Each checkpoint point also writes a full **training** checkpoint
    /// (moments, adapter chain, step/RNG state) under `<dir>/train/`.
    pub checkpoint_dir: Option<PathBuf>,
    /// When set, restore the newest valid training checkpoint from this
    /// directory and continue the run from its step (bitwise identical to
    /// the uninterrupted run).  Defaults `checkpoint_dir` to the same
    /// directory so the resumed run keeps checkpointing in place.
    pub resume: Option<PathBuf>,
    /// Training-checkpoint retention: keep the newest K `step_*`
    /// directories under `<checkpoint_dir>/train/`.
    pub keep_checkpoints: usize,
    /// Kernel-engine parallelism for every CPU backend call this run
    /// makes (threads = 0 ⇒ auto-detect hardware threads).
    pub parallel: ParallelPolicy,
}

impl Default for RunConfig {
    fn default() -> Self {
        Self {
            model: "gpt-nano".into(),
            method: Method::Slope,
            steps: 200,
            lazy_fraction: 0.05,
            eval_every: 25,
            eval_batches: 4,
            seed: 0,
            artifacts: PathBuf::from("artifacts"),
            out_dir: PathBuf::from("runs"),
            checkpoint_dir: None,
            resume: None,
            keep_checkpoints: 3,
            parallel: ParallelPolicy::auto(),
        }
    }
}

impl RunConfig {
    pub fn lazy_steps(&self) -> usize {
        ((self.steps as f64 * self.lazy_fraction).round() as usize).min(self.steps)
    }

    pub fn sparse_steps(&self) -> usize {
        self.steps - self.lazy_steps()
    }

    pub fn manifest_path(&self) -> PathBuf {
        self.artifacts.join(&self.model).join("manifest.json")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lazy_split_matches_paper_1pct() {
        let cfg = RunConfig { steps: 1000, lazy_fraction: 0.01, ..Default::default() };
        assert_eq!(cfg.lazy_steps(), 10);
        assert_eq!(cfg.sparse_steps(), 990);
    }

    #[test]
    fn fig9_exe_names_cover_variants() {
        for v in [Fig9Variant::WeightStatic, Fig9Variant::WeightDynamic,
                  Fig9Variant::InputStatic, Fig9Variant::InputDynamic,
                  Fig9Variant::GradoutDynamic] {
            assert!(v.exe_name().starts_with("train_step_fig9_"));
        }
    }
}
