//! Full-size model shape inventories for the performance and memory models
//! (Tables 2/3/7/8/12, Figures 3a/5/6) plus the CNN inventories used by
//! the Bi-Mask overhead table (Table 10).
//!
//! These are the *public architecture shapes* of the models the paper
//! benchmarks; no weights are involved — the perf/memory models only need
//! per-layer GEMM dimensions.

/// Decoder-only (or encoder) transformer shape for the analytic models.
#[derive(Clone, Copy, Debug)]
pub struct ModelShape {
    pub name: &'static str,
    pub d_model: usize,
    pub n_layer: usize,
    pub n_head: usize,
    /// KV heads (GQA); == n_head for MHA models.
    pub n_kv_head: usize,
    pub d_ff: usize,
    /// Gated MLP (LLaMA/Mistral SwiGLU: up, gate, down) vs 2-matrix GELU.
    pub gated_mlp: bool,
    pub vocab: usize,
    /// Approximate parameter count in billions (reporting only).
    pub params_b: f64,
}

impl ModelShape {
    pub const fn head_dim(&self) -> usize {
        self.d_model / self.n_head
    }

    /// Suggested kernel-engine policy for this shape — the zoo-facing
    /// alias for [`crate::backend::ParallelPolicy::for_width`] at this
    /// model's `d_model` (the CLI derives the same policy from a loaded
    /// manifest's width; the kernel benches from the benched width).
    pub fn recommended_policy(&self, threads: usize) -> crate::backend::ParallelPolicy {
        crate::backend::ParallelPolicy::for_width(threads, self.d_model)
    }

    /// Dense parameter count of the prunable linear weights per block.
    pub fn block_linear_params(&self) -> usize {
        let d = self.d_model;
        let kv = self.n_kv_head * self.head_dim();
        let qkv = d * d + 2 * d * kv; // q + k + v
        let proj = d * d;
        let mlp = if self.gated_mlp { 3 * d * self.d_ff } else { 2 * d * self.d_ff };
        qkv + proj + mlp
    }

    /// Total dense params (linears + embeddings + norms, approximate).
    pub fn total_params(&self) -> usize {
        self.n_layer * (self.block_linear_params() + 4 * self.d_model)
            + self.vocab * self.d_model
            + 2 * self.d_model
    }
}

pub const OPT_2_6B: ModelShape = ModelShape {
    name: "OPT-2.6B", d_model: 2560, n_layer: 32, n_head: 32, n_kv_head: 32,
    d_ff: 10240, gated_mlp: false, vocab: 50272, params_b: 2.6,
};
pub const OPT_6_6B: ModelShape = ModelShape {
    name: "OPT-6.6B", d_model: 4096, n_layer: 32, n_head: 32, n_kv_head: 32,
    d_ff: 16384, gated_mlp: false, vocab: 50272, params_b: 6.7,
};
pub const OPT_13B: ModelShape = ModelShape {
    name: "OPT-13B", d_model: 5120, n_layer: 40, n_head: 40, n_kv_head: 40,
    d_ff: 20480, gated_mlp: false, vocab: 50272, params_b: 13.0,
};
pub const OPT_30B: ModelShape = ModelShape {
    name: "OPT-30B", d_model: 7168, n_layer: 48, n_head: 56, n_kv_head: 56,
    d_ff: 28672, gated_mlp: false, vocab: 50272, params_b: 30.0,
};
pub const OPT_66B: ModelShape = ModelShape {
    name: "OPT-66B", d_model: 9216, n_layer: 64, n_head: 72, n_kv_head: 72,
    d_ff: 36864, gated_mlp: false, vocab: 50272, params_b: 66.0,
};
pub const LLAMA3_8B: ModelShape = ModelShape {
    name: "LLaMA-3-8B", d_model: 4096, n_layer: 32, n_head: 32, n_kv_head: 8,
    d_ff: 14336, gated_mlp: true, vocab: 128256, params_b: 8.0,
};
pub const MISTRAL_7B: ModelShape = ModelShape {
    name: "Mistral-v0.3-7B", d_model: 4096, n_layer: 32, n_head: 32, n_kv_head: 8,
    d_ff: 14336, gated_mlp: true, vocab: 32768, params_b: 7.2,
};
pub const GPT2_SMALL: ModelShape = ModelShape {
    name: "GPT2-Small", d_model: 768, n_layer: 12, n_head: 12, n_kv_head: 12,
    d_ff: 3072, gated_mlp: false, vocab: 50257, params_b: 0.117,
};
pub const GPT2_LARGE: ModelShape = ModelShape {
    name: "GPT2-Large", d_model: 1280, n_layer: 36, n_head: 20, n_kv_head: 20,
    d_ff: 5120, gated_mlp: false, vocab: 50257, params_b: 0.774,
};
pub const BERT_LARGE: ModelShape = ModelShape {
    name: "BERT-Large", d_model: 1024, n_layer: 24, n_head: 16, n_kv_head: 16,
    d_ff: 4096, gated_mlp: false, vocab: 30522, params_b: 0.355,
};

/// The Table 2/3 sweep set.
pub const SPEEDUP_MODELS: [ModelShape; 7] = [
    OPT_66B, OPT_30B, OPT_13B, OPT_6_6B, OPT_2_6B, LLAMA3_8B, MISTRAL_7B,
];

/// One conv/fc layer as a GEMM (im2col view) for the Table-10 CNN set.
#[derive(Clone, Copy, Debug)]
pub struct CnnLayer {
    /// GEMM m (output pixels × batch), n (out channels), k (in·kh·kw).
    pub m: usize,
    pub n: usize,
    pub k: usize,
}

/// Compact CNN inventories (representative per-stage shapes × counts) for
/// the Bi-Mask slowdown reproduction (Table 10).
pub struct CnnShape {
    pub name: &'static str,
    pub dataset: &'static str,
    pub layers: &'static [(CnnLayer, usize)],
}

const B32: usize = 128; // CIFAR batch
const BIMG: usize = 64; // ImageNet batch

pub const MOBILENET_V2: CnnShape = CnnShape {
    name: "MobileNet-V2", dataset: "CIFAR10",
    layers: &[
        (CnnLayer { m: B32 * 1024, n: 96, k: 32 * 9 }, 4),
        (CnnLayer { m: B32 * 256, n: 192, k: 64 * 9 }, 8),
        (CnnLayer { m: B32 * 64, n: 384, k: 96 * 9 }, 12),
        (CnnLayer { m: B32 * 16, n: 960, k: 160 * 9 }, 8),
    ],
};
pub const RESNET_32: CnnShape = CnnShape {
    name: "ResNet-32", dataset: "CIFAR10",
    layers: &[
        (CnnLayer { m: B32 * 1024, n: 16, k: 16 * 9 }, 10),
        (CnnLayer { m: B32 * 256, n: 32, k: 32 * 9 }, 10),
        (CnnLayer { m: B32 * 64, n: 64, k: 64 * 9 }, 10),
    ],
};
pub const VGG19: CnnShape = CnnShape {
    name: "VGG19", dataset: "CIFAR10",
    layers: &[
        (CnnLayer { m: B32 * 1024, n: 64, k: 64 * 9 }, 2),
        (CnnLayer { m: B32 * 256, n: 128, k: 128 * 9 }, 2),
        (CnnLayer { m: B32 * 64, n: 256, k: 256 * 9 }, 4),
        (CnnLayer { m: B32 * 16, n: 512, k: 512 * 9 }, 8),
    ],
};
pub const RESNET_18: CnnShape = CnnShape {
    name: "ResNet-18", dataset: "ImageNet",
    layers: &[
        (CnnLayer { m: BIMG * 3136, n: 64, k: 64 * 9 }, 4),
        (CnnLayer { m: BIMG * 784, n: 128, k: 128 * 9 }, 4),
        (CnnLayer { m: BIMG * 196, n: 256, k: 256 * 9 }, 4),
        (CnnLayer { m: BIMG * 49, n: 512, k: 512 * 9 }, 4),
    ],
};
pub const RESNET_50: CnnShape = CnnShape {
    name: "ResNet-50", dataset: "ImageNet",
    layers: &[
        (CnnLayer { m: BIMG * 3136, n: 256, k: 64 * 9 }, 9),
        (CnnLayer { m: BIMG * 784, n: 512, k: 128 * 9 }, 12),
        (CnnLayer { m: BIMG * 196, n: 1024, k: 256 * 9 }, 18),
        (CnnLayer { m: BIMG * 49, n: 2048, k: 512 * 9 }, 9),
    ],
};

pub const BIMASK_MODELS: [&CnnShape; 5] =
    [&MOBILENET_V2, &RESNET_32, &VGG19, &RESNET_18, &RESNET_50];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_counts_are_in_the_right_ballpark() {
        for m in SPEEDUP_MODELS {
            let est = m.total_params() as f64 / 1e9;
            assert!(
                est > 0.55 * m.params_b && est < 1.45 * m.params_b,
                "{}: est {est:.2}B vs nominal {}B", m.name, m.params_b
            );
        }
    }

    #[test]
    fn gqa_models_have_fewer_kv_heads() {
        assert!(LLAMA3_8B.n_kv_head < LLAMA3_8B.n_head);
        assert_eq!(OPT_66B.n_kv_head, OPT_66B.n_head);
    }

    #[test]
    fn recommended_policy_scales_fork_floor_with_width() {
        let small = GPT2_SMALL.recommended_policy(8);
        let big = OPT_66B.recommended_policy(8);
        assert_eq!(small.threads, 8);
        assert!(small.min_rows_per_task <= big.min_rows_per_task);
        assert!(big.min_rows_per_task <= 64);
        assert!(small.min_rows_per_task >= 4);
    }
}
