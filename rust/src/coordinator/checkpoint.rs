//! Checkpointing: persist a subset of the literal store to a simple
//! length-prefixed binary format (`.slopeckpt`) and restore it.
//!
//! Format (little endian):
//! ```text
//!   magic   "SLPE" u32-version
//!   count   u32
//!   repeat: name_len u32 | name bytes | dtype u8 (0=f32, 1=i32)
//!           ndims u32 | dims u64×ndims | raw data
//! ```

use crate::runtime::Store;
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"SLPE";
const VERSION: u32 = 1;

/// Save every store tensor whose name starts with one of `prefixes`.
pub fn save(store: &Store, prefixes: &[&str], path: &Path) -> crate::Result<usize> {
    let names: Vec<String> = store
        .names()
        .into_iter()
        .filter(|n| prefixes.iter().any(|p| n.starts_with(p)))
        .map(|s| s.to_string())
        .collect();
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    f.write_all(MAGIC)?;
    f.write_all(&VERSION.to_le_bytes())?;
    f.write_all(&(names.len() as u32).to_le_bytes())?;
    for name in &names {
        let lit = store.get(name)?;
        let shape = lit.array_shape().map_err(|e| crate::eyre!("{e}"))?;
        let dims: Vec<u64> = shape.dims().iter().map(|d| *d as u64).collect();
        let ty = lit.ty().map_err(|e| crate::eyre!("{e}"))?;
        f.write_all(&(name.len() as u32).to_le_bytes())?;
        f.write_all(name.as_bytes())?;
        match ty {
            xla::ElementType::F32 => {
                f.write_all(&[0u8])?;
                f.write_all(&(dims.len() as u32).to_le_bytes())?;
                for d in &dims {
                    f.write_all(&d.to_le_bytes())?;
                }
                for v in lit.to_vec::<f32>().map_err(|e| crate::eyre!("{e}"))? {
                    f.write_all(&v.to_le_bytes())?;
                }
            }
            xla::ElementType::S32 => {
                f.write_all(&[1u8])?;
                f.write_all(&(dims.len() as u32).to_le_bytes())?;
                for d in &dims {
                    f.write_all(&d.to_le_bytes())?;
                }
                for v in lit.to_vec::<i32>().map_err(|e| crate::eyre!("{e}"))? {
                    f.write_all(&v.to_le_bytes())?;
                }
            }
            other => return Err(crate::eyre!("checkpoint: unsupported dtype {other:?}")),
        }
    }
    Ok(names.len())
}

/// Load a checkpoint into the store (overwrites same-name tensors).
pub fn load(store: &mut Store, path: &Path) -> crate::Result<usize> {
    let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
    let mut magic = [0u8; 4];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(crate::eyre!("not a slope checkpoint: {}", path.display()));
    }
    let version = read_u32(&mut f)?;
    if version != VERSION {
        return Err(crate::eyre!("unsupported checkpoint version {version}"));
    }
    let count = read_u32(&mut f)? as usize;
    for _ in 0..count {
        let name_len = read_u32(&mut f)? as usize;
        let mut name = vec![0u8; name_len];
        f.read_exact(&mut name)?;
        let name = String::from_utf8(name).map_err(|e| crate::eyre!("{e}"))?;
        let mut dtype = [0u8; 1];
        f.read_exact(&mut dtype)?;
        let ndims = read_u32(&mut f)? as usize;
        let mut dims = Vec::with_capacity(ndims);
        for _ in 0..ndims {
            let mut b = [0u8; 8];
            f.read_exact(&mut b)?;
            dims.push(u64::from_le_bytes(b) as usize);
        }
        let n: usize = dims.iter().product::<usize>().max(1);
        match dtype[0] {
            0 => {
                let mut data = vec![0f32; n];
                let mut b = [0u8; 4];
                for v in data.iter_mut() {
                    f.read_exact(&mut b)?;
                    *v = f32::from_le_bytes(b);
                }
                store.put_f32(&name, &dims, &data)?;
            }
            1 => {
                let mut data = vec![0i32; n];
                let mut b = [0u8; 4];
                for v in data.iter_mut() {
                    f.read_exact(&mut b)?;
                    *v = i32::from_le_bytes(b);
                }
                store.put_i32(&name, &dims, &data)?;
            }
            other => return Err(crate::eyre!("bad dtype tag {other}")),
        }
    }
    Ok(count)
}

fn read_u32<R: Read>(r: &mut R) -> crate::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let mut store = Store::new();
        store.put_f32("params.a", &[2, 3], &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]).unwrap();
        store.put_i32("tokens", &[4], &[1, 2, 3, 4]).unwrap();
        store.put_f32("opt.b", &[1], &[9.0]).unwrap();
        let tmp = std::env::temp_dir().join("slope_ckpt_test.slopeckpt");
        let n = save(&store, &["params.", "opt."], &tmp).unwrap();
        assert_eq!(n, 2, "tokens must be excluded by prefix filter");
        let mut fresh = Store::new();
        let m = load(&mut fresh, &tmp).unwrap();
        assert_eq!(m, 2);
        assert_eq!(fresh.read_f32("params.a").unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(fresh.read_f32("opt.b").unwrap(), vec![9.0]);
        std::fs::remove_file(tmp).ok();
    }
}
