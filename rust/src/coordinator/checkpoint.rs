//! Checkpointing: persist a subset of the literal store to a simple
//! length-prefixed binary format (`.slopeckpt`) and restore it.
//!
//! ## Format v3 (little endian)
//!
//! ```text
//!   magic    "SLPE"
//!   version  u32 (=3)
//!   count    u32
//!   repeat:  name_len u32 | name bytes | dtype u8 (0=f32, 1=i32, 2=u8)
//!            ndims u32 | dims u64×ndims | raw data
//!            crc u32            — CRC-32/IEEE of this record (header+data)
//!   footer   "SLPF" | crc u32   — CRC-32/IEEE of every preceding byte
//! ```
//!
//! Version history: v1 is the bare record stream (f32/i32 only); v2 adds
//! the `u8` dtype (tag 2) for the Eq.-7 bit-packed metadata plane of
//! compressed weights; v3 adds the per-record CRCs and the file footer.
//! v1/v2 files still load (with a logged warning — no integrity check is
//! possible), so pre-v3 checkpoints stay restorable.
//!
//! Every file is written **crash-safely** through
//! [`crate::util::faultfs::write_atomic`]: temp file in the same
//! directory → `sync_all` → atomic rename → parent-directory fsync.  A
//! torn or bit-flipped file therefore either never replaces its
//! predecessor, or is caught at load time by the checksums; `load` parses
//! the whole file into a scratch store and absorbs it only on full
//! success, so a corrupt checkpoint can never leave the live [`Store`]
//! partially populated.  Structural failures surface as [`CkptError`]
//! (downcastable from [`crate::Error`]).
//!
//! A [`CompressedNm`] serializes as three records — `<name>.values` (f32
//! `[rows, kcols]`), `<name>.meta` (u8 `[rows, row_meta_bytes]`, the byte
//! layout `python/compile/sparsity.py` mirrors), and `<name>.scheme` (i32
//! `[n, m, rows, cols]`) — via [`save_packed_weights`] /
//! [`load_packed_weights`].
//!
//! On top of the raw formats sit two directory layouts:
//!
//! * **Serving checkpoints** ([`save_model_checkpoint`] /
//!   [`load_model_checkpoint`]): store planes + packed `CompressedNm`
//!   planes + a manifest copy — what `slope serve --manifest <dir>`
//!   restores without re-running compression.
//! * **Training checkpoints** ([`save_train_checkpoint`] /
//!   [`load_train_checkpoint`]): the FULL resumable state — `params.*`,
//!   `opt.*` compressed-space moments, `masks.*`, the adapter chain
//!   (`lora.*` / `lora_opt.*`), plus a [`TrainMeta`] sidecar (step
//!   counter, schedule position, RNG state) — under
//!   `<dir>/train/step_NNNNNNNN/`, with a `LATEST` pointer that advances
//!   only after the written files re-read and verify, and a keep-last-K
//!   retention sweep.  `slope train --resume <dir>` restores the newest
//!   valid step and continues bitwise-identically.

use crate::runtime::{Manifest, Store, SPARSE_WEIGHTS};
use crate::sparsity::{CompressedNm, Mask, NmScheme};
use crate::util::crc32::crc32;
use crate::util::{faultfs, json, Json};
use std::collections::{HashMap, HashSet};
use std::path::{Path, PathBuf};

const MAGIC: &[u8; 4] = b"SLPE";
const FOOTER_MAGIC: &[u8; 4] = b"SLPF";
const VERSION: u32 = 3;

/// Caps a bit-flipped length field before it becomes a giant allocation:
/// every length is validated against the actual bytes remaining, and
/// names additionally against this bound.
const MAX_NAME_LEN: usize = 1 << 12;
const MAX_NDIMS: usize = 8;

/// Store-plane file inside a serving-checkpoint directory.
pub const MODEL_FILE: &str = "model.slopeckpt";
/// Packed compressed-weight planes beside [`MODEL_FILE`].
pub const PACKED_FILE: &str = "model.packed.slopeckpt";
/// Training-checkpoint subdirectory inside a checkpoint directory.
pub const TRAIN_DIR: &str = "train";
/// Full training-state planes inside one `step_NNNNNNNN` directory.
pub const TRAIN_FILE: &str = "train_state.slopeckpt";
/// Trainer-side metadata (step, schedule, RNG) beside [`TRAIN_FILE`].
pub const TRAIN_META_FILE: &str = "train_meta.json";
/// Pointer file naming the newest verified `step_NNNNNNNN` directory.
pub const LATEST_FILE: &str = "LATEST";
/// Store prefixes a training checkpoint persists — everything the host
/// executor rebuilds model state from, moments and adapter chain included.
pub const TRAIN_PREFIXES: [&str; 5] = ["params.", "opt.", "masks.", "lora.", "lora_opt."];

// ---- structured errors --------------------------------------------------

/// Structured checkpoint failures — every corrupt-file shape `load` can
/// detect.  Boxed into [`crate::Error`]; tests downcast to assert the
/// exact failure class.
#[derive(Clone, Debug, PartialEq)]
pub enum CkptError {
    /// The file does not start with the `SLPE` magic.
    NotACheckpoint { path: PathBuf },
    /// The version field is 0 or newer than this build understands.
    UnsupportedVersion { version: u32 },
    /// The file ends before the structure it promises (torn write).
    Truncated { offset: usize, detail: String },
    /// A record's CRC does not match its bytes (bit rot / flip).
    CorruptRecord { name: String, offset: usize },
    /// The file footer is missing, mis-tagged, or its CRC mismatches.
    CorruptFooter { detail: String },
    /// Two records share a name (a valid writer never emits this).
    DuplicateRecord { name: String },
    /// Any other structural violation (bad dtype tag, oversized length
    /// field, non-UTF-8 name, trailing garbage, …).
    Malformed { detail: String },
}

impl std::fmt::Display for CkptError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CkptError::NotACheckpoint { path } => {
                write!(f, "not a slope checkpoint: {}", path.display())
            }
            CkptError::UnsupportedVersion { version } => {
                write!(f, "unsupported checkpoint version {version}")
            }
            CkptError::Truncated { offset, detail } => {
                write!(f, "checkpoint truncated at byte {offset}: {detail}")
            }
            CkptError::CorruptRecord { name, offset } => {
                write!(f, "checkpoint record {name:?} at byte {offset} fails its CRC")
            }
            CkptError::CorruptFooter { detail } => {
                write!(f, "checkpoint footer invalid: {detail}")
            }
            CkptError::DuplicateRecord { name } => {
                write!(f, "duplicate checkpoint record {name:?}")
            }
            CkptError::Malformed { detail } => write!(f, "malformed checkpoint: {detail}"),
        }
    }
}

impl std::error::Error for CkptError {}

// ---- serializer ---------------------------------------------------------

/// In-memory builder for one checkpoint file (header + records + footer);
/// the finished byte image goes through [`faultfs::write_atomic`].
struct FileWriter {
    buf: Vec<u8>,
    count: u32,
    version: u32,
}

impl FileWriter {
    fn new(version: u32) -> Self {
        let mut buf = Vec::new();
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&version.to_le_bytes());
        buf.extend_from_slice(&0u32.to_le_bytes()); // count, patched in finish()
        Self { buf, count: 0, version }
    }

    fn record(&mut self, name: &str, dtype: u8, dims: &[u64], data: impl FnOnce(&mut Vec<u8>)) {
        let start = self.buf.len();
        self.buf.extend_from_slice(&(name.len() as u32).to_le_bytes());
        self.buf.extend_from_slice(name.as_bytes());
        self.buf.push(dtype);
        self.buf.extend_from_slice(&(dims.len() as u32).to_le_bytes());
        for d in dims {
            self.buf.extend_from_slice(&d.to_le_bytes());
        }
        data(&mut self.buf);
        if self.version >= 3 {
            let crc = crc32(&self.buf[start..]);
            self.buf.extend_from_slice(&crc.to_le_bytes());
        }
        self.count += 1;
    }

    fn store_record(&mut self, store: &Store, name: &str) -> crate::Result<()> {
        let lit = store.get(name)?;
        let shape = lit.array_shape().map_err(|e| crate::eyre!("{e}"))?;
        let dims: Vec<u64> = shape.dims().iter().map(|d| *d as u64).collect();
        match lit.ty().map_err(|e| crate::eyre!("{e}"))? {
            xla::ElementType::F32 => {
                let v = lit.to_vec::<f32>().map_err(|e| crate::eyre!("{e}"))?;
                self.record(name, 0, &dims, |buf| {
                    for x in &v {
                        buf.extend_from_slice(&x.to_le_bytes());
                    }
                });
            }
            xla::ElementType::S32 => {
                let v = lit.to_vec::<i32>().map_err(|e| crate::eyre!("{e}"))?;
                self.record(name, 1, &dims, |buf| {
                    for x in &v {
                        buf.extend_from_slice(&x.to_le_bytes());
                    }
                });
            }
            other => return Err(crate::eyre!("checkpoint: unsupported dtype {other:?}")),
        }
        Ok(())
    }

    fn finish(mut self) -> Vec<u8> {
        let count = self.count.to_le_bytes();
        self.buf[8..12].copy_from_slice(&count);
        if self.version >= 3 {
            let crc = crc32(&self.buf);
            self.buf.extend_from_slice(FOOTER_MAGIC);
            self.buf.extend_from_slice(&crc.to_le_bytes());
        }
        self.buf
    }
}

// ---- parser -------------------------------------------------------------

enum RecData {
    F32(Vec<f32>),
    I32(Vec<i32>),
    U8(Vec<u8>),
}

struct Record {
    name: String,
    dims: Vec<usize>,
    data: RecData,
}

struct Parsed {
    version: u32,
    records: Vec<Record>,
    /// Byte offset where each record starts, plus the offset right after
    /// the last record (= footer start on v3 files).
    boundaries: Vec<usize>,
}

struct Cur<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Cur<'a> {
    fn remaining(&self) -> usize {
        self.b.len() - self.i
    }

    fn need(&self, n: usize, what: &str) -> Result<(), CkptError> {
        if self.remaining() < n {
            return Err(CkptError::Truncated {
                offset: self.i,
                detail: format!("need {n} bytes for {what}, {} left", self.remaining()),
            });
        }
        Ok(())
    }

    fn bytes(&mut self, n: usize, what: &str) -> Result<&'a [u8], CkptError> {
        self.need(n, what)?;
        let out = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(out)
    }

    fn u8(&mut self, what: &str) -> Result<u8, CkptError> {
        Ok(self.bytes(1, what)?[0])
    }

    fn u32(&mut self, what: &str) -> Result<u32, CkptError> {
        let b = self.bytes(4, what)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self, what: &str) -> Result<u64, CkptError> {
        let b = self.bytes(8, what)?;
        Ok(u64::from_le_bytes(b.try_into().unwrap()))
    }
}

/// Parse a whole checkpoint file, verifying structure and (v3) checksums
/// BEFORE any caller sees a record — a corrupt file yields a
/// [`CkptError`] and nothing else.  Every length field is validated
/// against the bytes actually present before it sizes an allocation, so
/// a bit-flipped length cannot trigger a huge `vec!`.
fn parse_file(path: &Path) -> crate::Result<Parsed> {
    let bytes = std::fs::read(path)
        .map_err(|e| crate::eyre!("reading checkpoint {}: {e}", path.display()))?;
    let mut cur = Cur { b: &bytes, i: 0 };
    if cur.remaining() < 4 || &bytes[..4] != MAGIC {
        return Err(CkptError::NotACheckpoint { path: path.to_path_buf() }.into());
    }
    cur.i = 4;
    let version = cur.u32("version")?;
    if version == 0 || version > VERSION {
        return Err(CkptError::UnsupportedVersion { version }.into());
    }
    if version < VERSION {
        eprintln!(
            "[checkpoint] {} is format v{version} (pre-checksum); loading without \
             integrity verification",
            path.display()
        );
    }
    let count = cur.u32("record count")? as usize;
    let mut records = Vec::new();
    let mut boundaries = Vec::new();
    let mut seen: HashSet<String> = HashSet::new();
    for idx in 0..count {
        let start = cur.i;
        boundaries.push(start);
        let name_len = cur.u32("record name length")? as usize;
        if name_len > MAX_NAME_LEN {
            return Err(CkptError::Malformed {
                detail: format!("record {idx}: name length {name_len} exceeds {MAX_NAME_LEN}"),
            }
            .into());
        }
        let name = std::str::from_utf8(cur.bytes(name_len, "record name")?)
            .map_err(|_| CkptError::Malformed {
                detail: format!("record {idx}: name is not UTF-8"),
            })?
            .to_string();
        let dtype = cur.u8("dtype tag")?;
        let elem_size = match dtype {
            0 | 1 => 4usize,
            2 => 1,
            other => {
                return Err(CkptError::Malformed {
                    detail: format!("record {name:?}: bad dtype tag {other}"),
                }
                .into())
            }
        };
        let ndims = cur.u32("ndims")? as usize;
        if ndims > MAX_NDIMS {
            return Err(CkptError::Malformed {
                detail: format!("record {name:?}: {ndims} dims exceeds {MAX_NDIMS}"),
            }
            .into());
        }
        let mut dims = Vec::with_capacity(ndims);
        let mut elems = 1usize;
        for _ in 0..ndims {
            let d = cur.u64("dim")? as usize;
            elems = elems.checked_mul(d).ok_or_else(|| CkptError::Malformed {
                detail: format!("record {name:?}: dims overflow"),
            })?;
            dims.push(d);
        }
        let data_len = elems.checked_mul(elem_size).ok_or_else(|| CkptError::Malformed {
            detail: format!("record {name:?}: size overflow"),
        })?;
        let raw = cur.bytes(data_len, "record data")?;
        let data = match dtype {
            0 => RecData::F32(
                raw.chunks_exact(4).map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]])).collect(),
            ),
            1 => RecData::I32(
                raw.chunks_exact(4).map(|b| i32::from_le_bytes([b[0], b[1], b[2], b[3]])).collect(),
            ),
            _ => RecData::U8(raw.to_vec()),
        };
        if version >= 3 {
            let body_end = cur.i;
            let want = cur.u32("record CRC")?;
            if crc32(&bytes[start..body_end]) != want {
                return Err(CkptError::CorruptRecord { name, offset: start }.into());
            }
        }
        if !seen.insert(name.clone()) {
            return Err(CkptError::DuplicateRecord { name }.into());
        }
        records.push(Record { name, dims, data });
    }
    boundaries.push(cur.i);
    if version >= 3 {
        let footer_start = cur.i;
        let tag = cur.bytes(4, "footer magic").map_err(|_| CkptError::CorruptFooter {
            detail: "file ends before the footer".into(),
        })?;
        if tag != FOOTER_MAGIC {
            return Err(CkptError::CorruptFooter { detail: "bad footer magic".into() }.into());
        }
        let want = cur.u32("footer CRC").map_err(|_| CkptError::CorruptFooter {
            detail: "file ends inside the footer".into(),
        })?;
        if crc32(&bytes[..footer_start]) != want {
            return Err(CkptError::CorruptFooter { detail: "file CRC mismatch".into() }.into());
        }
    }
    if cur.remaining() != 0 {
        return Err(CkptError::Malformed {
            detail: format!("{} trailing bytes after the last record", cur.remaining()),
        }
        .into());
    }
    Ok(Parsed { version, records, boundaries })
}

/// Byte offsets of every record boundary in a checkpoint file (record
/// starts, plus the end of the last record) — the truncation sweep in
/// `tests/crash_recovery.rs` tears a copy at each of these.
pub fn record_boundaries(path: &Path) -> crate::Result<Vec<usize>> {
    Ok(parse_file(path)?.boundaries)
}

// ---- raw store files ----------------------------------------------------

/// Save every store tensor whose name starts with one of `prefixes`
/// (format v3, written atomically).
pub fn save(store: &Store, prefixes: &[&str], path: &Path) -> crate::Result<usize> {
    let names: Vec<&str> = store
        .names()
        .into_iter()
        .filter(|n| prefixes.iter().any(|p| n.starts_with(p)))
        .collect();
    let mut w = FileWriter::new(VERSION);
    for name in &names {
        w.store_record(store, name)?;
    }
    faultfs::write_atomic(path, &w.finish())?;
    Ok(names.len())
}

/// [`save`] in format v2 (no checksums) — the back-compat fixture writer
/// the corrupt-load tests use to prove pre-v3 files still restore.
pub fn save_as_v2(store: &Store, prefixes: &[&str], path: &Path) -> crate::Result<usize> {
    let names: Vec<&str> = store
        .names()
        .into_iter()
        .filter(|n| prefixes.iter().any(|p| n.starts_with(p)))
        .collect();
    let mut w = FileWriter::new(2);
    for name in &names {
        w.store_record(store, name)?;
    }
    faultfs::write_atomic(path, &w.finish())?;
    Ok(names.len())
}

/// Load a checkpoint into the store (overwrites same-name tensors).
/// All-or-nothing: the file parses and verifies into a scratch store
/// first, so on error the live store is untouched.
pub fn load(store: &mut Store, path: &Path) -> crate::Result<usize> {
    let parsed = parse_file(path)?;
    let mut scratch = Store::new();
    for rec in &parsed.records {
        match &rec.data {
            RecData::F32(v) => scratch.put_f32(&rec.name, &rec.dims, v)?,
            RecData::I32(v) => scratch.put_i32(&rec.name, &rec.dims, v)?,
            RecData::U8(_) => {
                return Err(CkptError::Malformed {
                    detail: format!(
                        "record {:?} is a u8 plane; the store holds f32/i32 only \
                         (packed planes load via load_packed_weights)",
                        rec.name
                    ),
                }
                .into())
            }
        }
    }
    let n = parsed.records.len();
    store.absorb(scratch);
    Ok(n)
}

// ---- packed compressed-weight planes ------------------------------------

/// Save compressed weights with their bit-packed metadata plane — the
/// artifact-shipping path for the Eq.-7 layout (values f32, offsets u8,
/// scheme/shape i32).  Names must be unique.
pub fn save_packed_weights(planes: &[(&str, &CompressedNm)], path: &Path) -> crate::Result<usize> {
    let mut w = FileWriter::new(VERSION);
    for (name, c) in planes {
        let values: &[f32] = &c.values;
        w.record(&format!("{name}.values"), 0, &[c.rows as u64, c.kcols() as u64], |buf| {
            for v in values {
                buf.extend_from_slice(&v.to_le_bytes());
            }
        });
        w.record(&format!("{name}.meta"), 2, &[c.rows as u64, c.row_meta_bytes() as u64], |buf| {
            buf.extend_from_slice(&c.meta);
        });
        let scheme = [c.scheme.n as i32, c.scheme.m as i32, c.rows as i32, c.cols as i32];
        w.record(&format!("{name}.scheme"), 1, &[4], |buf| {
            for v in scheme {
                buf.extend_from_slice(&v.to_le_bytes());
            }
        });
    }
    faultfs::write_atomic(path, &w.finish())?;
    Ok(planes.len())
}

/// Load compressed weights saved by [`save_packed_weights`], rebuilding
/// each [`CompressedNm`] (values + packed metadata plane) by name.
/// Assembly is hash-keyed by plane prefix (O(n)); duplicate plane names
/// are a [`CkptError::DuplicateRecord`], not a silent first-match.
pub fn load_packed_weights(path: &Path) -> crate::Result<Vec<(String, CompressedNm)>> {
    let parsed = parse_file(path)?;
    if parsed.version < 2 {
        return Err(crate::eyre!(
            "packed planes need checkpoint version ≥ 2, got {}",
            parsed.version
        ));
    }
    let mut values: HashMap<String, Vec<f32>> = HashMap::new();
    let mut metas: HashMap<String, Vec<u8>> = HashMap::new();
    let mut schemes: Vec<(String, Vec<i32>)> = Vec::new();
    for rec in parsed.records {
        let split = rec.name.rsplit_once('.');
        match (rec.data, split) {
            (RecData::F32(d), Some((prefix, "values"))) => {
                if values.insert(prefix.to_string(), d).is_some() {
                    return Err(CkptError::DuplicateRecord { name: rec.name.clone() }.into());
                }
            }
            (RecData::U8(d), Some((prefix, "meta"))) => {
                if metas.insert(prefix.to_string(), d).is_some() {
                    return Err(CkptError::DuplicateRecord { name: rec.name.clone() }.into());
                }
            }
            (RecData::I32(d), Some((prefix, "scheme"))) => {
                schemes.push((prefix.to_string(), d));
            }
            _ => {
                return Err(crate::eyre!("unexpected packed record {:?}", rec.name));
            }
        }
    }
    let mut out = Vec::with_capacity(schemes.len());
    for (prefix, s) in schemes {
        crate::ensure!(s.len() == 4, "malformed scheme record for {prefix:?}");
        let (n, m, rows, cols) = (s[0] as usize, s[1] as usize, s[2] as usize, s[3] as usize);
        crate::ensure!(n >= 1 && n <= m && m <= 256 && cols % m == 0,
                       "invalid {n}:{m} scheme for {prefix:?}");
        let vals = values
            .remove(&prefix)
            .ok_or_else(|| crate::eyre!("missing values plane for {prefix:?}"))?;
        let meta = metas
            .remove(&prefix)
            .ok_or_else(|| crate::eyre!("missing meta plane for {prefix:?}"))?;
        let c = CompressedNm { rows, cols, scheme: NmScheme::new(n, m), values: vals, meta };
        crate::ensure!(
            c.values.len() == rows * c.kcols() && c.meta.len() == rows * c.row_meta_bytes(),
            "inconsistent packed planes for {prefix:?}"
        );
        out.push((prefix, c));
    }
    crate::ensure!(
        values.is_empty() && metas.is_empty(),
        "packed planes without a scheme record: {:?}",
        values.keys().chain(metas.keys()).collect::<Vec<_>>()
    );
    Ok(out)
}

// ---- serving-checkpoint directories -----------------------------------

/// Write a **serving checkpoint** for the manifest's model into `dir`:
///
/// * [`MODEL_FILE`] — every `params.*` / `masks.*` / `lora.*` store
///   tensor (the state the `forward`/`forward_lora` executables read);
/// * [`PACKED_FILE`] — one pre-compressed [`CompressedNm`] plane
///   (values + Eq.-7 bit-packed metadata) per pruned block weight, so a
///   restore ([`load_model_checkpoint`] → `HostModel`/`AotModel`) skips
///   the compress step entirely.
///
/// Weights whose checkpointed mask is not a valid N:M pattern for the
/// manifest's per-half scheme (e.g. the dense baseline's all-ones masks,
/// or a dynamic-mask method mid-run) are skipped from the packed file —
/// they restore through the dense path instead.  Returns
/// `(store_tensors, packed_planes)` written.
pub fn save_model_checkpoint(store: &Store, manifest: &Manifest,
                             dir: &Path) -> crate::Result<(usize, usize)> {
    let tensors = save(store, &["params.", "masks.", "lora."], &dir.join(MODEL_FILE))?;
    let mut planes: Vec<(String, CompressedNm)> = Vec::new();
    for layer in 0..manifest.config.n_layer {
        for wname in SPARSE_WEIGHTS {
            if let Some(c) = packed_plane_from_store(store, manifest, layer, wname)? {
                planes.push((format!("params.blocks.{layer}.{wname}"), c));
            }
        }
    }
    let refs: Vec<(&str, &CompressedNm)> =
        planes.iter().map(|(name, c)| (name.as_str(), c)).collect();
    save_packed_weights(&refs, &dir.join(PACKED_FILE))?;
    Ok((tensors, planes.len()))
}

/// Decode `masks.blocks.<layer>.<wname>_r` and compress the matching
/// stored weight under the manifest's per-half scheme — the single
/// definition of "which planes pack", shared by the checkpoint writer and
/// the host executor's restore (so save and restore can never disagree).
/// `Ok(None)` means the weight serves dense: unpruned by policy, planes
/// absent from the store, or a mask that is not a valid N:M pattern
/// (dense baselines / dynamic-mask methods mid-run).  A shape-mismatched
/// mask is corrupt state and errors.
pub fn packed_plane_from_store(store: &Store, manifest: &Manifest, layer: usize,
                               wname: &str) -> crate::Result<Option<CompressedNm>> {
    if !manifest.is_pruned(layer, wname) {
        return Ok(None);
    }
    let pname = format!("params.blocks.{layer}.{wname}");
    let mname = format!("masks.blocks.{layer}.{wname}_r");
    if !store.contains(&pname) || !store.contains(&mname) {
        return Ok(None);
    }
    let w = store.read_matrix(&pname)?;
    let mm = store.read_matrix(&mname)?;
    crate::ensure!(
        (mm.rows, mm.cols) == (w.rows, w.cols),
        "mask {mname} is {}x{}, weight is {}x{}",
        mm.rows, mm.cols, w.rows, w.cols
    );
    let (n, m) = manifest.scheme_for_layer(layer);
    let scheme = NmScheme::new(n, m);
    if w.cols % scheme.m != 0 {
        return Ok(None);
    }
    let mask = Mask {
        rows: mm.rows,
        cols: mm.cols,
        keep: mm.data.iter().map(|v| *v != 0.0).collect(),
    };
    if !mask.check_row_nm(scheme) {
        return Ok(None);
    }
    Ok(Some(CompressedNm::compress(&w, &mask, scheme)))
}

/// Restore a serving checkpoint directory: the literal store plus the
/// packed planes keyed by weight name (empty map when the packed file is
/// absent — pre-packing checkpoints restore via re-compression).
pub fn load_model_checkpoint(dir: &Path)
                             -> crate::Result<(Store, HashMap<String, CompressedNm>)> {
    let model_path = dir.join(MODEL_FILE);
    crate::ensure!(
        model_path.exists(),
        "no serving checkpoint at {} (train with --checkpoint-dir first)",
        model_path.display()
    );
    let mut store = Store::new();
    load(&mut store, &model_path)?;
    let mut packed = HashMap::new();
    let packed_path = dir.join(PACKED_FILE);
    if packed_path.exists() {
        for (name, c) in load_packed_weights(&packed_path)? {
            packed.insert(name, c);
        }
    }
    Ok((store, packed))
}

// ---- training checkpoints (resume) --------------------------------------

/// Trainer-side state beside the store planes: everything the step loop
/// needs beyond tensors to continue bitwise-identically.
#[derive(Clone, Debug, PartialEq)]
pub struct TrainMeta {
    /// Last completed optimizer step.
    pub step: usize,
    /// The run's total scheduled steps (fixes the LR/phase schedule).
    pub steps: usize,
    /// The run's lazy-adapter fraction (fixes the phase-flip step).
    pub lazy_fraction: f64,
    /// Data/mask seed of the run.
    pub seed: u64,
    /// Whether the lazy adapters were already activated.
    pub lora_active: bool,
    /// Data-sampler RNG state as of this step ([`crate::util::Rng::state`]).
    pub rng: ([u64; 4], Option<f64>),
}

impl TrainMeta {
    /// Serialize as one JSON line plus a `crc32:` trailer line, so a
    /// bit-flip in the sidecar is as detectable as one in the tensor
    /// file.  u64/f64 values ride as decimal-bit strings: JSON numbers
    /// are f64 and would round 64-bit integers.
    fn to_file_string(&self) -> String {
        let (s, spare) = self.rng;
        let body = json::obj(vec![
            ("step", json::num(self.step as f64)),
            ("steps", json::num(self.steps as f64)),
            ("lazy_fraction_bits", json::s(self.lazy_fraction.to_bits().to_string())),
            ("seed", json::s(self.seed.to_string())),
            ("lora_active", Json::Bool(self.lora_active)),
            ("rng_s", json::arr(s.iter().map(|w| json::s(w.to_string())))),
            (
                "rng_spare_bits",
                match spare {
                    Some(v) => json::s(v.to_bits().to_string()),
                    None => Json::Null,
                },
            ),
        ])
        .to_string();
        let crc = crc32(body.as_bytes());
        format!("{body}\ncrc32:{crc:08x}\n")
    }

    fn from_file_string(text: &str) -> crate::Result<TrainMeta> {
        let mut lines = text.lines();
        let body = lines
            .next()
            .ok_or_else(|| crate::eyre!("train meta: empty file"))?;
        let crc_line = lines
            .next()
            .ok_or_else(|| crate::eyre!("train meta: missing crc32 trailer"))?;
        let want = crc_line
            .strip_prefix("crc32:")
            .and_then(|h| u32::from_str_radix(h.trim(), 16).ok())
            .ok_or_else(|| crate::eyre!("train meta: bad crc32 trailer {crc_line:?}"))?;
        if crc32(body.as_bytes()) != want {
            return Err(CkptError::CorruptRecord { name: TRAIN_META_FILE.into(), offset: 0 }.into());
        }
        let j = Json::parse(body)?;
        let parse_u64 = |v: &Json, what: &str| -> crate::Result<u64> {
            v.as_str()
                .ok_or_else(|| crate::eyre!("train meta: {what} not a string"))?
                .parse::<u64>()
                .map_err(|e| crate::eyre!("train meta: bad {what}: {e}"))
        };
        let rng_arr = j.req("rng_s")?.as_arr().ok_or_else(|| crate::eyre!("rng_s not an array"))?;
        crate::ensure!(rng_arr.len() == 4, "train meta: rng_s needs 4 words");
        let mut s = [0u64; 4];
        for (i, w) in rng_arr.iter().enumerate() {
            s[i] = parse_u64(w, "rng_s word")?;
        }
        let spare = match j.req("rng_spare_bits")? {
            Json::Null => None,
            v => Some(f64::from_bits(parse_u64(v, "rng_spare_bits")?)),
        };
        Ok(TrainMeta {
            step: j.req_usize("step")?,
            steps: j.req_usize("steps")?,
            lazy_fraction: f64::from_bits(parse_u64(j.req("lazy_fraction_bits")?,
                                                    "lazy_fraction_bits")?),
            seed: parse_u64(j.req("seed")?, "seed")?,
            lora_active: j.req_bool("lora_active")?,
            rng: (s, spare),
        })
    }
}

fn step_dir_name(step: usize) -> String {
    format!("step_{step:08}")
}

/// `(step, path)` for every `step_NNNNNNNN` directory, newest first.
fn list_step_dirs(train_root: &Path) -> Vec<(usize, PathBuf)> {
    let mut out: Vec<(usize, PathBuf)> = Vec::new();
    let Ok(entries) = std::fs::read_dir(train_root) else {
        return out;
    };
    for entry in entries.flatten() {
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        if let Some(step) = name.strip_prefix("step_").and_then(|s| s.parse::<usize>().ok()) {
            if entry.path().is_dir() {
                out.push((step, entry.path()));
            }
        }
    }
    out.sort_by(|a, b| b.0.cmp(&a.0));
    out
}

/// Re-read and fully verify one step directory (structure + checksums of
/// both files); returns its meta.  This is the gate `LATEST` advances
/// behind — and the recovery walk's validity check.
fn verify_step_dir(step_dir: &Path) -> crate::Result<TrainMeta> {
    parse_file(&step_dir.join(TRAIN_FILE))?;
    let text = std::fs::read_to_string(step_dir.join(TRAIN_META_FILE))
        .map_err(|e| crate::eyre!("reading {}: {e}", step_dir.join(TRAIN_META_FILE).display()))?;
    TrainMeta::from_file_string(&text)
}

/// Write a full **training checkpoint** under `dir/train/step_NNNNNNNN/`:
/// every [`TRAIN_PREFIXES`] store plane (params, compressed-space
/// moments, masks, adapters and their AdamW chain) plus the [`TrainMeta`]
/// sidecar.  The `LATEST` pointer advances only after the just-written
/// files re-read and verify — a torn or bit-flipped write is deleted and
/// surfaces as an error, leaving `LATEST` on the previous valid step.
/// Afterwards prunes all but the newest `keep_last` step directories.
pub fn save_train_checkpoint(store: &Store, meta: &TrainMeta, dir: &Path,
                             keep_last: usize) -> crate::Result<PathBuf> {
    let train_root = dir.join(TRAIN_DIR);
    let step_dir = train_root.join(step_dir_name(meta.step));
    std::fs::create_dir_all(&step_dir)
        .map_err(|e| crate::eyre!("creating {}: {e}", step_dir.display()))?;
    let write = (|| -> crate::Result<()> {
        save(store, &TRAIN_PREFIXES, &step_dir.join(TRAIN_FILE))?;
        faultfs::write_atomic(&step_dir.join(TRAIN_META_FILE),
                              meta.to_file_string().as_bytes())?;
        // Verify-after-write: the pointer must never name a checkpoint
        // that does not load.
        let back = verify_step_dir(&step_dir)?;
        crate::ensure!(back == *meta, "train checkpoint verify: meta mismatch after write");
        Ok(())
    })();
    if let Err(e) = write {
        std::fs::remove_dir_all(&step_dir).ok();
        return Err(crate::eyre!(
            "train checkpoint at step {} failed verification and was discarded: {e}",
            meta.step
        ));
    }
    faultfs::write_atomic(&train_root.join(LATEST_FILE), step_dir_name(meta.step).as_bytes())?;
    // Retention: keep the newest `keep_last` steps (at least the one just
    // written).  Best-effort — a failed removal never fails the save.
    for (_, old) in list_step_dirs(&train_root).into_iter().skip(keep_last.max(1)) {
        if old != step_dir {
            std::fs::remove_dir_all(&old).ok();
        }
    }
    Ok(step_dir)
}

/// Restore the newest **valid** training checkpoint under `dir`: try the
/// `LATEST` pointer first, then every `step_NNNNNNNN` directory newest
/// first, skipping (with a logged warning) any that fails verification —
/// so recovery after a torn or corrupted write lands on the last good
/// state instead of erroring out.  Errors only when no valid checkpoint
/// exists at all.
pub fn load_train_checkpoint(dir: &Path) -> crate::Result<(Store, TrainMeta)> {
    walk_valid_checkpoints(dir, |step_dir, meta| {
        let mut store = Store::new();
        load(&mut store, &step_dir.join(TRAIN_FILE))?;
        Ok((store, meta))
    })
}

/// The newest valid checkpoint's [`TrainMeta`] without loading tensors —
/// how `slope train --resume` learns the run's schedule and seed before
/// constructing the trainer.
pub fn peek_train_meta(dir: &Path) -> crate::Result<TrainMeta> {
    walk_valid_checkpoints(dir, |_, meta| Ok(meta))
}

fn walk_valid_checkpoints<T>(
    dir: &Path,
    mut use_checkpoint: impl FnMut(&Path, TrainMeta) -> crate::Result<T>,
) -> crate::Result<T> {
    let train_root = dir.join(TRAIN_DIR);
    crate::ensure!(
        train_root.is_dir(),
        "no training checkpoint under {} (train with --checkpoint-dir first)",
        dir.display()
    );
    let mut candidates: Vec<PathBuf> = Vec::new();
    if let Ok(pointer) = std::fs::read_to_string(train_root.join(LATEST_FILE)) {
        let target = train_root.join(pointer.trim());
        if target.is_dir() {
            candidates.push(target);
        } else {
            eprintln!(
                "[checkpoint] LATEST points at missing {:?}; falling back to a scan",
                pointer.trim()
            );
        }
    }
    for (_, path) in list_step_dirs(&train_root) {
        if !candidates.contains(&path) {
            candidates.push(path);
        }
    }
    let mut last_err: Option<crate::Error> = None;
    for step_dir in &candidates {
        match verify_step_dir(step_dir).and_then(|meta| use_checkpoint(step_dir, meta)) {
            Ok(v) => return Ok(v),
            Err(e) => {
                eprintln!(
                    "[checkpoint] skipping invalid checkpoint {}: {e}",
                    step_dir.display()
                );
                last_err = Some(e);
            }
        }
    }
    Err(match last_err {
        Some(e) => crate::eyre!(
            "no valid training checkpoint under {} (all {} candidates failed; last: {e})",
            dir.display(),
            candidates.len()
        ),
        None => crate::eyre!("no training checkpoint under {}", dir.display()),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparsity::random_row_mask;
    use crate::tensor::Matrix;
    use crate::util::Rng;

    #[test]
    fn packed_weights_roundtrip_with_metadata_plane() {
        let mut rng = Rng::seed_from_u64(9);
        let mut planes = vec![];
        for (name, (n, m), rows, cols) in [("blocks.0.wq", (2usize, 4usize), 8usize, 16usize),
                                           ("blocks.0.wup", (2, 8), 4, 24)] {
            let s = NmScheme::new(n, m);
            let w = Matrix::randn(rows, cols, 1.0, &mut rng);
            let mask = random_row_mask(rows, cols, s, &mut rng);
            planes.push((name, CompressedNm::compress(&w, &mask, s)));
        }
        let tmp = std::env::temp_dir().join("slope_packed_ckpt_test.slopeckpt");
        let refs: Vec<(&str, &CompressedNm)> =
            planes.iter().map(|(n, c)| (*n, c)).collect();
        assert_eq!(save_packed_weights(&refs, &tmp).unwrap(), 2);
        let back = load_packed_weights(&tmp).unwrap();
        assert_eq!(back.len(), 2);
        for (name, c) in &planes {
            let (_, got) = back.iter().find(|(n, _)| n == name).unwrap();
            assert_eq!(got, c, "{name}: values AND packed metadata must round-trip");
        }
        std::fs::remove_file(tmp).ok();
    }

    #[test]
    fn model_checkpoint_roundtrips_with_packed_planes() {
        use crate::runtime::manifest::{ModelConfig, TrainParams};
        let dir = std::env::temp_dir().join("slope_model_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let manifest = Manifest {
            config: ModelConfig {
                name: "tiny".into(),
                vocab_size: 8,
                n_layer: 1,
                n_head: 1,
                d_model: 8,
                d_ff: 8,
                seq_len: 4,
                batch_size: 2,
                adapter_rank: 0,
                first_half_sparsity: (2, 4),
                second_half_sparsity: (2, 4),
                prune_attn: true,
                prune_mlp: true,
                n_params_dense: 0,
            },
            train: TrainParams {
                lr: 0.0,
                weight_decay: 0.0,
                warmup_steps: 0,
                total_steps: 0,
                lazy_fraction: 0.0,
                srste_decay: 0.0,
                beta1: 0.9,
                beta2: 0.95,
                grad_clip: 1.0,
            },
            sparsity_format: None,
            executables: std::collections::HashMap::new(),
            dir: dir.clone(),
        };
        let mut rng = Rng::seed_from_u64(5);
        let mut store = Store::new();
        let w = Matrix::randn(8, 8, 1.0, &mut rng);
        let mask = random_row_mask(8, 8, NmScheme::TWO_FOUR, &mut rng);
        let wm = mask.apply(&w);
        store.put_f32("params.blocks.0.wproj", &[8, 8], &wm.data).unwrap();
        store.put_f32("masks.blocks.0.wproj_r", &[8, 8], &mask.to_matrix().data).unwrap();
        // Ones mask (dense baseline): not 2:4 ⇒ restored dense, no plane.
        store.put_f32("params.blocks.0.wup", &[8, 8], &w.data).unwrap();
        store.put_f32("masks.blocks.0.wup_r", &[8, 8], &vec![1.0; 64]).unwrap();
        let (tensors, planes) = save_model_checkpoint(&store, &manifest, &dir).unwrap();
        assert_eq!(tensors, 4);
        assert_eq!(planes, 1, "only the valid 2:4 mask ships a packed plane");
        let (back, packed) = load_model_checkpoint(&dir).unwrap();
        assert_eq!(
            back.read_f32("params.blocks.0.wproj").unwrap(),
            store.read_f32("params.blocks.0.wproj").unwrap()
        );
        let want = CompressedNm::compress(&wm, &mask, NmScheme::TWO_FOUR);
        assert_eq!(packed.get("params.blocks.0.wproj").unwrap(), &want,
                   "packed plane must restore the exact compressed operand");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn roundtrip() {
        let mut store = Store::new();
        store.put_f32("params.a", &[2, 3], &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]).unwrap();
        store.put_i32("tokens", &[4], &[1, 2, 3, 4]).unwrap();
        store.put_f32("opt.b", &[1], &[9.0]).unwrap();
        let tmp = std::env::temp_dir().join("slope_ckpt_test.slopeckpt");
        let n = save(&store, &["params.", "opt."], &tmp).unwrap();
        assert_eq!(n, 2, "tokens must be excluded by prefix filter");
        let mut fresh = Store::new();
        let m = load(&mut fresh, &tmp).unwrap();
        assert_eq!(m, 2);
        assert_eq!(fresh.read_f32("params.a").unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(fresh.read_f32("opt.b").unwrap(), vec![9.0]);
        std::fs::remove_file(tmp).ok();
    }

    #[test]
    fn v2_file_loads_without_checksums() {
        let mut store = Store::new();
        store.put_f32("params.a", &[3], &[1.0, 2.0, 3.0]).unwrap();
        let tmp = std::env::temp_dir().join("slope_ckpt_v2_test.slopeckpt");
        save_as_v2(&store, &["params."], &tmp).unwrap();
        let mut fresh = Store::new();
        assert_eq!(load(&mut fresh, &tmp).unwrap(), 1);
        assert_eq!(fresh.read_f32("params.a").unwrap(), vec![1.0, 2.0, 3.0]);
        std::fs::remove_file(tmp).ok();
    }

    #[test]
    fn bitflip_is_detected_and_leaves_store_untouched() {
        let mut store = Store::new();
        store.put_f32("params.a", &[4], &[1.0, 2.0, 3.0, 4.0]).unwrap();
        let tmp = std::env::temp_dir().join("slope_ckpt_flip_test.slopeckpt");
        save(&store, &["params."], &tmp).unwrap();
        let mut bytes = std::fs::read(&tmp).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x10;
        std::fs::write(&tmp, &bytes).unwrap();
        let mut fresh = Store::new();
        let err = load(&mut fresh, &tmp).unwrap_err();
        assert!(err.downcast_ref::<CkptError>().is_some(), "structured error, got: {err}");
        assert!(fresh.names().is_empty(), "corrupt load must not populate the store");
        std::fs::remove_file(tmp).ok();
    }

    #[test]
    fn train_meta_file_roundtrip_and_crc() {
        let meta = TrainMeta {
            step: 7,
            steps: 12,
            lazy_fraction: 0.34,
            seed: u64::MAX - 3,
            lora_active: true,
            rng: ([1, u64::MAX, 3, 4], Some(-1.25e-7)),
        };
        let text = meta.to_file_string();
        assert_eq!(TrainMeta::from_file_string(&text).unwrap(), meta);
        let flipped = text.replace("\"step\":7", "\"step\":9");
        assert!(TrainMeta::from_file_string(&flipped).is_err(), "crc must catch edits");
    }

    #[test]
    fn train_checkpoint_retention_and_latest() {
        let dir = std::env::temp_dir().join("slope_train_ckpt_test");
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        let mut store = Store::new();
        store.put_f32("params.a", &[2], &[1.0, 2.0]).unwrap();
        store.put_f32("opt.m.a", &[2], &[0.1, 0.2]).unwrap();
        let meta = |step: usize| TrainMeta {
            step,
            steps: 10,
            lazy_fraction: 0.0,
            seed: 1,
            lora_active: false,
            rng: ([5, 6, 7, 8], None),
        };
        for step in [1usize, 2, 3] {
            store.put_f32("params.a", &[2], &[step as f32, 2.0]).unwrap();
            save_train_checkpoint(&store, &meta(step), &dir, 2).unwrap();
        }
        let root = dir.join(TRAIN_DIR);
        assert!(!root.join("step_00000001").exists(), "retention prunes beyond keep-last 2");
        assert!(root.join("step_00000002").exists() && root.join("step_00000003").exists());
        assert_eq!(std::fs::read_to_string(root.join(LATEST_FILE)).unwrap(), "step_00000003");
        let (back, m) = load_train_checkpoint(&dir).unwrap();
        assert_eq!(m, meta(3));
        assert_eq!(back.read_f32("params.a").unwrap(), vec![3.0, 2.0]);
        assert_eq!(peek_train_meta(&dir).unwrap().step, 3);
        // Corrupt the newest: recovery falls back to step 2.
        let newest = root.join("step_00000003").join(TRAIN_FILE);
        let mut bytes = std::fs::read(&newest).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x01;
        std::fs::write(&newest, &bytes).unwrap();
        let (back, m) = load_train_checkpoint(&dir).unwrap();
        assert_eq!(m.step, 2, "fallback must land on the previous valid step");
        assert_eq!(back.read_f32("params.a").unwrap(), vec![2.0, 2.0]);
        std::fs::remove_dir_all(&dir).ok();
    }
}
