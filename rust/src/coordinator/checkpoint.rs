//! Checkpointing: persist a subset of the literal store to a simple
//! length-prefixed binary format (`.slopeckpt`) and restore it.
//!
//! Format (little endian):
//! ```text
//!   magic   "SLPE" u32-version
//!   count   u32
//!   repeat: name_len u32 | name bytes | dtype u8 (0=f32, 1=i32, 2=u8)
//!           ndims u32 | dims u64×ndims | raw data
//! ```
//!
//! Version 2 adds the `u8` dtype (tag 2), used to ship the Eq.-7
//! bit-packed metadata plane of compressed weights: a
//! [`CompressedNm`] serializes as three records —
//! `<name>.values` (f32 `[rows, kcols]`), `<name>.meta` (u8
//! `[rows, row_meta_bytes]`, the byte layout `python/compile/sparsity.py`
//! mirrors), and `<name>.scheme` (i32 `[n, m, rows, cols]`) — via
//! [`save_packed_weights`] / [`load_packed_weights`].  Version-1 files
//! load unchanged.
//!
//! On top of the raw formats sit **serving-checkpoint directories**
//! ([`save_model_checkpoint`] / [`load_model_checkpoint`]): the trainer
//! writes one at every eval checkpoint when `--checkpoint-dir` is set —
//! store planes plus the pruned weights' packed `CompressedNm` planes —
//! and `slope serve --manifest <dir>` restores it without re-running
//! compression.

use crate::runtime::{Manifest, Store, SPARSE_WEIGHTS};
use crate::sparsity::{CompressedNm, Mask, NmScheme};
use std::collections::HashMap;
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"SLPE";
const VERSION: u32 = 2;

/// Store-plane file inside a serving-checkpoint directory.
pub const MODEL_FILE: &str = "model.slopeckpt";
/// Packed compressed-weight planes (format v2) beside [`MODEL_FILE`].
pub const PACKED_FILE: &str = "model.packed.slopeckpt";

/// Save every store tensor whose name starts with one of `prefixes`.
pub fn save(store: &Store, prefixes: &[&str], path: &Path) -> crate::Result<usize> {
    let names: Vec<String> = store
        .names()
        .into_iter()
        .filter(|n| prefixes.iter().any(|p| n.starts_with(p)))
        .map(|s| s.to_string())
        .collect();
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    f.write_all(MAGIC)?;
    f.write_all(&VERSION.to_le_bytes())?;
    f.write_all(&(names.len() as u32).to_le_bytes())?;
    for name in &names {
        let lit = store.get(name)?;
        let shape = lit.array_shape().map_err(|e| crate::eyre!("{e}"))?;
        let dims: Vec<u64> = shape.dims().iter().map(|d| *d as u64).collect();
        let ty = lit.ty().map_err(|e| crate::eyre!("{e}"))?;
        f.write_all(&(name.len() as u32).to_le_bytes())?;
        f.write_all(name.as_bytes())?;
        match ty {
            xla::ElementType::F32 => {
                f.write_all(&[0u8])?;
                f.write_all(&(dims.len() as u32).to_le_bytes())?;
                for d in &dims {
                    f.write_all(&d.to_le_bytes())?;
                }
                for v in lit.to_vec::<f32>().map_err(|e| crate::eyre!("{e}"))? {
                    f.write_all(&v.to_le_bytes())?;
                }
            }
            xla::ElementType::S32 => {
                f.write_all(&[1u8])?;
                f.write_all(&(dims.len() as u32).to_le_bytes())?;
                for d in &dims {
                    f.write_all(&d.to_le_bytes())?;
                }
                for v in lit.to_vec::<i32>().map_err(|e| crate::eyre!("{e}"))? {
                    f.write_all(&v.to_le_bytes())?;
                }
            }
            other => return Err(crate::eyre!("checkpoint: unsupported dtype {other:?}")),
        }
    }
    Ok(names.len())
}

/// Load a checkpoint into the store (overwrites same-name tensors).
pub fn load(store: &mut Store, path: &Path) -> crate::Result<usize> {
    let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
    let mut magic = [0u8; 4];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(crate::eyre!("not a slope checkpoint: {}", path.display()));
    }
    let version = read_u32(&mut f)?;
    if version == 0 || version > VERSION {
        return Err(crate::eyre!("unsupported checkpoint version {version}"));
    }
    let count = read_u32(&mut f)? as usize;
    for _ in 0..count {
        let name_len = read_u32(&mut f)? as usize;
        let mut name = vec![0u8; name_len];
        f.read_exact(&mut name)?;
        let name = String::from_utf8(name).map_err(|e| crate::eyre!("{e}"))?;
        let mut dtype = [0u8; 1];
        f.read_exact(&mut dtype)?;
        let ndims = read_u32(&mut f)? as usize;
        let mut dims = Vec::with_capacity(ndims);
        for _ in 0..ndims {
            let mut b = [0u8; 8];
            f.read_exact(&mut b)?;
            dims.push(u64::from_le_bytes(b) as usize);
        }
        let n: usize = dims.iter().product::<usize>().max(1);
        match dtype[0] {
            0 => {
                let mut data = vec![0f32; n];
                let mut b = [0u8; 4];
                for v in data.iter_mut() {
                    f.read_exact(&mut b)?;
                    *v = f32::from_le_bytes(b);
                }
                store.put_f32(&name, &dims, &data)?;
            }
            1 => {
                let mut data = vec![0i32; n];
                let mut b = [0u8; 4];
                for v in data.iter_mut() {
                    f.read_exact(&mut b)?;
                    *v = i32::from_le_bytes(b);
                }
                store.put_i32(&name, &dims, &data)?;
            }
            other => return Err(crate::eyre!("bad dtype tag {other}")),
        }
    }
    Ok(count)
}

fn read_u32<R: Read>(r: &mut R) -> crate::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

// ---- packed compressed-weight planes (version 2) ----------------------

fn write_record_header<W: Write>(f: &mut W, name: &str, dtype: u8,
                                 dims: &[u64]) -> crate::Result<()> {
    f.write_all(&(name.len() as u32).to_le_bytes())?;
    f.write_all(name.as_bytes())?;
    f.write_all(&[dtype])?;
    f.write_all(&(dims.len() as u32).to_le_bytes())?;
    for d in dims {
        f.write_all(&d.to_le_bytes())?;
    }
    Ok(())
}

/// Save compressed weights with their bit-packed metadata plane — the
/// artifact-shipping path for the Eq.-7 layout (values f32, offsets u8,
/// scheme/shape i32).  Names must be unique.
pub fn save_packed_weights(planes: &[(&str, &CompressedNm)], path: &Path) -> crate::Result<usize> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    f.write_all(MAGIC)?;
    f.write_all(&VERSION.to_le_bytes())?;
    f.write_all(&((planes.len() * 3) as u32).to_le_bytes())?;
    for (name, c) in planes {
        write_record_header(&mut f, &format!("{name}.values"), 0,
                            &[c.rows as u64, c.kcols() as u64])?;
        for v in &c.values {
            f.write_all(&v.to_le_bytes())?;
        }
        write_record_header(&mut f, &format!("{name}.meta"), 2,
                            &[c.rows as u64, c.row_meta_bytes() as u64])?;
        f.write_all(&c.meta)?;
        write_record_header(&mut f, &format!("{name}.scheme"), 1, &[4])?;
        for v in [c.scheme.n as i32, c.scheme.m as i32, c.rows as i32, c.cols as i32] {
            f.write_all(&v.to_le_bytes())?;
        }
    }
    Ok(planes.len())
}

/// Load compressed weights saved by [`save_packed_weights`], rebuilding
/// each [`CompressedNm`] (values + packed metadata plane) by name.
pub fn load_packed_weights(path: &Path) -> crate::Result<Vec<(String, CompressedNm)>> {
    let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
    let mut magic = [0u8; 4];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(crate::eyre!("not a slope checkpoint: {}", path.display()));
    }
    let version = read_u32(&mut f)?;
    if version < 2 || version > VERSION {
        return Err(crate::eyre!("packed planes need checkpoint version ≥ 2, got {version}"));
    }
    let count = read_u32(&mut f)? as usize;
    // Collect raw records, then assemble by prefix.
    let mut values: Vec<(String, Vec<f32>)> = vec![];
    let mut metas: Vec<(String, Vec<u8>)> = vec![];
    let mut schemes: Vec<(String, Vec<i32>)> = vec![];
    for _ in 0..count {
        let name_len = read_u32(&mut f)? as usize;
        let mut name = vec![0u8; name_len];
        f.read_exact(&mut name)?;
        let name = String::from_utf8(name).map_err(|e| crate::eyre!("{e}"))?;
        let mut dtype = [0u8; 1];
        f.read_exact(&mut dtype)?;
        let ndims = read_u32(&mut f)? as usize;
        let mut n = 1usize;
        for _ in 0..ndims {
            let mut b = [0u8; 8];
            f.read_exact(&mut b)?;
            n *= u64::from_le_bytes(b) as usize;
        }
        match (dtype[0], name.rsplit_once('.')) {
            (0, Some((prefix, "values"))) => {
                let mut data = vec![0f32; n];
                let mut b = [0u8; 4];
                for v in data.iter_mut() {
                    f.read_exact(&mut b)?;
                    *v = f32::from_le_bytes(b);
                }
                values.push((prefix.to_string(), data));
            }
            (2, Some((prefix, "meta"))) => {
                let mut data = vec![0u8; n];
                f.read_exact(&mut data)?;
                metas.push((prefix.to_string(), data));
            }
            (1, Some((prefix, "scheme"))) => {
                let mut data = vec![0i32; n];
                let mut b = [0u8; 4];
                for v in data.iter_mut() {
                    f.read_exact(&mut b)?;
                    *v = i32::from_le_bytes(b);
                }
                schemes.push((prefix.to_string(), data));
            }
            (d, _) => return Err(crate::eyre!("unexpected packed record {name:?} dtype {d}")),
        }
    }
    let mut out = Vec::with_capacity(schemes.len());
    for (prefix, s) in schemes {
        crate::ensure!(s.len() == 4, "malformed scheme record for {prefix:?}");
        let (n, m, rows, cols) =
            (s[0] as usize, s[1] as usize, s[2] as usize, s[3] as usize);
        crate::ensure!(n >= 1 && n <= m && m <= 256 && cols % m == 0,
                       "invalid {n}:{m} scheme for {prefix:?}");
        let vals = values
            .iter()
            .find(|(p, _)| *p == prefix)
            .ok_or_else(|| crate::eyre!("missing values plane for {prefix:?}"))?;
        let meta = metas
            .iter()
            .find(|(p, _)| *p == prefix)
            .ok_or_else(|| crate::eyre!("missing meta plane for {prefix:?}"))?;
        let c = CompressedNm {
            rows,
            cols,
            scheme: NmScheme::new(n, m),
            values: vals.1.clone(),
            meta: meta.1.clone(),
        };
        crate::ensure!(
            c.values.len() == rows * c.kcols() && c.meta.len() == rows * c.row_meta_bytes(),
            "inconsistent packed planes for {prefix:?}"
        );
        out.push((prefix, c));
    }
    Ok(out)
}

// ---- serving-checkpoint directories -----------------------------------

/// Write a **serving checkpoint** for the manifest's model into `dir`:
///
/// * [`MODEL_FILE`] — every `params.*` / `masks.*` / `lora.*` store
///   tensor (the state the `forward`/`forward_lora` executables read);
/// * [`PACKED_FILE`] — one pre-compressed [`CompressedNm`] plane
///   (values + Eq.-7 bit-packed metadata) per pruned block weight, so a
///   restore ([`load_model_checkpoint`] → `HostModel`/`AotModel`) skips
///   the compress step entirely.
///
/// Weights whose checkpointed mask is not a valid N:M pattern for the
/// manifest's per-half scheme (e.g. the dense baseline's all-ones masks,
/// or a dynamic-mask method mid-run) are skipped from the packed file —
/// they restore through the dense path instead.  Returns
/// `(store_tensors, packed_planes)` written.
pub fn save_model_checkpoint(store: &Store, manifest: &Manifest,
                             dir: &Path) -> crate::Result<(usize, usize)> {
    let tensors = save(store, &["params.", "masks.", "lora."], &dir.join(MODEL_FILE))?;
    let mut planes: Vec<(String, CompressedNm)> = Vec::new();
    for layer in 0..manifest.config.n_layer {
        for wname in SPARSE_WEIGHTS {
            if let Some(c) = packed_plane_from_store(store, manifest, layer, wname)? {
                planes.push((format!("params.blocks.{layer}.{wname}"), c));
            }
        }
    }
    let refs: Vec<(&str, &CompressedNm)> =
        planes.iter().map(|(name, c)| (name.as_str(), c)).collect();
    save_packed_weights(&refs, &dir.join(PACKED_FILE))?;
    Ok((tensors, planes.len()))
}

/// Decode `masks.blocks.<layer>.<wname>_r` and compress the matching
/// stored weight under the manifest's per-half scheme — the single
/// definition of "which planes pack", shared by the checkpoint writer and
/// the host executor's restore (so save and restore can never disagree).
/// `Ok(None)` means the weight serves dense: unpruned by policy, planes
/// absent from the store, or a mask that is not a valid N:M pattern
/// (dense baselines / dynamic-mask methods mid-run).  A shape-mismatched
/// mask is corrupt state and errors.
pub fn packed_plane_from_store(store: &Store, manifest: &Manifest, layer: usize,
                               wname: &str) -> crate::Result<Option<CompressedNm>> {
    if !manifest.is_pruned(layer, wname) {
        return Ok(None);
    }
    let pname = format!("params.blocks.{layer}.{wname}");
    let mname = format!("masks.blocks.{layer}.{wname}_r");
    if !store.contains(&pname) || !store.contains(&mname) {
        return Ok(None);
    }
    let w = store.read_matrix(&pname)?;
    let mm = store.read_matrix(&mname)?;
    crate::ensure!(
        (mm.rows, mm.cols) == (w.rows, w.cols),
        "mask {mname} is {}x{}, weight is {}x{}",
        mm.rows, mm.cols, w.rows, w.cols
    );
    let (n, m) = manifest.scheme_for_layer(layer);
    let scheme = NmScheme::new(n, m);
    if w.cols % scheme.m != 0 {
        return Ok(None);
    }
    let mask = Mask {
        rows: mm.rows,
        cols: mm.cols,
        keep: mm.data.iter().map(|v| *v != 0.0).collect(),
    };
    if !mask.check_row_nm(scheme) {
        return Ok(None);
    }
    Ok(Some(CompressedNm::compress(&w, &mask, scheme)))
}

/// Restore a serving checkpoint directory: the literal store plus the
/// packed planes keyed by weight name (empty map when the packed file is
/// absent — pre-packing checkpoints restore via re-compression).
pub fn load_model_checkpoint(dir: &Path)
                             -> crate::Result<(Store, HashMap<String, CompressedNm>)> {
    let model_path = dir.join(MODEL_FILE);
    crate::ensure!(
        model_path.exists(),
        "no serving checkpoint at {} (train with --checkpoint-dir first)",
        model_path.display()
    );
    let mut store = Store::new();
    load(&mut store, &model_path)?;
    let mut packed = HashMap::new();
    let packed_path = dir.join(PACKED_FILE);
    if packed_path.exists() {
        for (name, c) in load_packed_weights(&packed_path)? {
            packed.insert(name, c);
        }
    }
    Ok((store, packed))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparsity::random_row_mask;
    use crate::tensor::Matrix;
    use crate::util::Rng;

    #[test]
    fn packed_weights_roundtrip_with_metadata_plane() {
        let mut rng = Rng::seed_from_u64(9);
        let mut planes = vec![];
        for (name, (n, m), rows, cols) in [("blocks.0.wq", (2usize, 4usize), 8usize, 16usize),
                                           ("blocks.0.wup", (2, 8), 4, 24)] {
            let s = NmScheme::new(n, m);
            let w = Matrix::randn(rows, cols, 1.0, &mut rng);
            let mask = random_row_mask(rows, cols, s, &mut rng);
            planes.push((name, CompressedNm::compress(&w, &mask, s)));
        }
        let tmp = std::env::temp_dir().join("slope_packed_ckpt_test.slopeckpt");
        let refs: Vec<(&str, &CompressedNm)> =
            planes.iter().map(|(n, c)| (*n, c)).collect();
        assert_eq!(save_packed_weights(&refs, &tmp).unwrap(), 2);
        let back = load_packed_weights(&tmp).unwrap();
        assert_eq!(back.len(), 2);
        for (name, c) in &planes {
            let (_, got) = back.iter().find(|(n, _)| n == name).unwrap();
            assert_eq!(got, c, "{name}: values AND packed metadata must round-trip");
        }
        std::fs::remove_file(tmp).ok();
    }

    #[test]
    fn model_checkpoint_roundtrips_with_packed_planes() {
        use crate::runtime::manifest::{ModelConfig, TrainParams};
        let dir = std::env::temp_dir().join("slope_model_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let manifest = Manifest {
            config: ModelConfig {
                name: "tiny".into(),
                vocab_size: 8,
                n_layer: 1,
                n_head: 1,
                d_model: 8,
                d_ff: 8,
                seq_len: 4,
                batch_size: 2,
                adapter_rank: 0,
                first_half_sparsity: (2, 4),
                second_half_sparsity: (2, 4),
                prune_attn: true,
                prune_mlp: true,
                n_params_dense: 0,
            },
            train: TrainParams {
                lr: 0.0,
                weight_decay: 0.0,
                warmup_steps: 0,
                total_steps: 0,
                lazy_fraction: 0.0,
                srste_decay: 0.0,
                beta1: 0.9,
                beta2: 0.95,
                grad_clip: 1.0,
            },
            sparsity_format: None,
            executables: std::collections::HashMap::new(),
            dir: dir.clone(),
        };
        let mut rng = Rng::seed_from_u64(5);
        let mut store = Store::new();
        let w = Matrix::randn(8, 8, 1.0, &mut rng);
        let mask = random_row_mask(8, 8, NmScheme::TWO_FOUR, &mut rng);
        let wm = mask.apply(&w);
        store.put_f32("params.blocks.0.wproj", &[8, 8], &wm.data).unwrap();
        store.put_f32("masks.blocks.0.wproj_r", &[8, 8], &mask.to_matrix().data).unwrap();
        // Ones mask (dense baseline): not 2:4 ⇒ restored dense, no plane.
        store.put_f32("params.blocks.0.wup", &[8, 8], &w.data).unwrap();
        store.put_f32("masks.blocks.0.wup_r", &[8, 8], &vec![1.0; 64]).unwrap();
        let (tensors, planes) = save_model_checkpoint(&store, &manifest, &dir).unwrap();
        assert_eq!(tensors, 4);
        assert_eq!(planes, 1, "only the valid 2:4 mask ships a packed plane");
        let (back, packed) = load_model_checkpoint(&dir).unwrap();
        assert_eq!(
            back.read_f32("params.blocks.0.wproj").unwrap(),
            store.read_f32("params.blocks.0.wproj").unwrap()
        );
        let want = CompressedNm::compress(&wm, &mask, NmScheme::TWO_FOUR);
        assert_eq!(packed.get("params.blocks.0.wproj").unwrap(), &want,
                   "packed plane must restore the exact compressed operand");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn roundtrip() {
        let mut store = Store::new();
        store.put_f32("params.a", &[2, 3], &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]).unwrap();
        store.put_i32("tokens", &[4], &[1, 2, 3, 4]).unwrap();
        store.put_f32("opt.b", &[1], &[9.0]).unwrap();
        let tmp = std::env::temp_dir().join("slope_ckpt_test.slopeckpt");
        let n = save(&store, &["params.", "opt."], &tmp).unwrap();
        assert_eq!(n, 2, "tokens must be excluded by prefix filter");
        let mut fresh = Store::new();
        let m = load(&mut fresh, &tmp).unwrap();
        assert_eq!(m, 2);
        assert_eq!(fresh.read_f32("params.a").unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(fresh.read_f32("opt.b").unwrap(), vec![9.0]);
        std::fs::remove_file(tmp).ok();
    }
}
