//! Pretraining coordinator — the L3 orchestrator.
//!
//! Owns the step loop over the AOT train-step executables, the SLoPe phase
//! schedule (99% sparse → final 1% with lazy low-rank adapters), baseline
//! drivers (dense / Extended SR-STE / Wanda / Figure-9 variants),
//! evaluation cadence, metric capture (loss curve, mask churn, adapter
//! convergence) and checkpointing.

pub mod checkpoint;
pub mod metrics;
pub mod trainer;

pub use metrics::{EvalRec, Metrics, StepRec};
pub use trainer::{TrainOutcome, Trainer};
