//! The training orchestrator: drives one pretraining run end-to-end
//! through the AOT executables.
//!
//! Phase schedule (the paper's headline recipe):
//! ```text
//!   steps 0 .. (1-λ)·T   : train_step        (sparse, Eq. 4–6)
//!   step  (1-λ)·T        : lora_init         (lazy adapters appear)
//!   steps .. T           : train_step_lora   (sparse + adapters)
//! ```
//! with λ = `lazy_fraction` (paper: 1%).  Baselines reuse the same loop
//! with a different executable and mask policy (see [`Method`]).

use crate::config::{Fig9Variant, Method, RunConfig};
use crate::coordinator::checkpoint;
use crate::coordinator::metrics::{AdapterRec, ChurnRec, ClozeRec, EvalRec, Metrics, StepRec};
use crate::data::{Corpus, CorpusSpec};
use crate::eval::{cloze_score, perplexity};
use crate::runtime::{Manifest, Session, SessionHandle, Store};
use crate::tensor::cosine_similarity;
use crate::util::Rng;
use std::time::Instant;

pub struct Trainer {
    pub cfg: RunConfig,
    /// Shared (process-cached) compiled session for this artifact config.
    pub session: SessionHandle,
    /// Cloned manifest (avoids locking for read-only schema queries).
    pub manifest: Manifest,
    pub store: Store,
    pub corpus: Corpus,
    pub metrics: Metrics,
    rng: Rng,
    lora_active: bool,
    /// Last completed step restored from a `--resume` checkpoint (0 on a
    /// fresh run); the step loop continues at `start_step + 1`.
    start_step: usize,
    /// Reusable token staging for the step loop (allocation-free steps).
    tokens_buf: Vec<i32>,
    /// Packed mask snapshots for the SR-STE churn metric.
    churn_snapshots: Vec<(usize, Vec<u64>)>,
    /// Adapter snapshots (down, up) for the Fig-3b convergence metric.
    adapter_snapshots: Vec<(usize, Vec<f32>, Vec<f32>)>,
}

#[derive(Clone, Debug)]
pub struct TrainOutcome {
    pub final_loss: f32,
    pub final_perplexity: f64,
    pub cloze_accuracy: f64,
    pub mean_step_ms: f64,
    pub coordinator_overhead: f64,
}

impl Trainer {
    pub fn new(mut cfg: RunConfig) -> crate::Result<Self> {
        if let Some(resume) = &cfg.resume {
            // A resumed run opens the checkpoint directory itself as its
            // artifact dir: the manifest copy written at checkpoint time
            // (no HLO beside it) routes the session to the host executor,
            // and the restored state comes from `<resume>/train/`.
            crate::ensure!(
                resume.join("manifest.json").exists(),
                "--resume {}: no manifest.json there (not a checkpoint directory; \
                 train with --checkpoint-dir first)",
                resume.display()
            );
            if cfg.checkpoint_dir.is_none() {
                cfg.checkpoint_dir = Some(resume.clone());
            }
        }
        let dir = match &cfg.resume {
            Some(resume) => resume.clone(),
            None => cfg.artifacts.join(&cfg.model),
        };
        if !dir.join("manifest.json").exists() {
            // No AOT artifacts: fabricate a host-trainable config in
            // place (manifest only — `init` creates the state) instead of
            // failing on the manifest load.  The session below routes to
            // the host kernel executor, so the whole run is pure rust.
            // Methods that need PJRT-only executables (SR-STE's dynamic
            // masks, Wanda calibration, the Fig-9 variants) are rejected
            // HERE — before any steps run — rather than failing at their
            // first missing executable mid-run.
            crate::ensure!(
                matches!(cfg.method, Method::Slope | Method::Dense),
                "method {:?} needs PJRT-only executables, and {} has no artifacts; \
                 run `make artifacts` first (the host executor covers slope/dense only \
                 — see ROADMAP §Training open items)",
                cfg.method,
                dir.display()
            );
            eprintln!(
                "[trainer] no artifacts at {}: fabricating a host-trainable config \
                 (native kernel-engine training; run `make artifacts` for the PJRT route)",
                dir.display()
            );
            crate::runtime::write_host_train_artifact(&dir, &cfg.model)?;
        }
        let session = Session::open_cached(&dir)?;
        let manifest = session.borrow().manifest.clone();
        let vocab = manifest.config.vocab_size;
        let corpus = Corpus::generate(CorpusSpec::for_vocab(vocab, cfg.seed ^ 0xC0FFEE));
        let run_name = format!("{}-{}", cfg.model, method_tag(&cfg.method));
        Ok(Self {
            rng: Rng::seed_from_u64(cfg.seed),
            metrics: Metrics::new(run_name),
            session,
            manifest,
            store: Store::new(),
            corpus,
            cfg,
            lora_active: false,
            start_step: 0,
            tokens_buf: vec![],
            churn_snapshots: vec![],
            adapter_snapshots: vec![],
        })
    }

    fn run_exe(&mut self, name: &str) -> crate::Result<()> {
        self.session.borrow_mut().run(name, &mut self.store)
    }

    fn has_exe(&self, name: &str) -> bool {
        self.manifest.executables.contains_key(name)
    }

    /// Pre-compile the executables a run will touch so step wall-times
    /// measure steady-state execution, not XLA compilation.
    fn warmup(&mut self, lazy_enabled: bool) -> crate::Result<()> {
        let mut names: Vec<String> = vec![self.step_exe().to_string(),
                                          "eval_step".into(), "forward".into()];
        if lazy_enabled {
            for n in ["lora_init", "train_step_lora", "eval_step_lora", "forward_lora"] {
                names.push(n.into());
            }
        }
        if matches!(self.cfg.method, Method::Srste | Method::SrsteLora) {
            names.push("srste_masks".into());
            names.push("magnitude_masks".into());
        }
        if matches!(self.cfg.method, Method::Wanda) {
            names.push("wanda_masks".into());
        }
        let mut sess = self.session.borrow_mut();
        for n in &names {
            if sess.manifest.executables.contains_key(n) {
                sess.prepare(n)?;
            }
        }
        Ok(())
    }

    /// Initialize model state (params/opt/masks) via the `init`
    /// executable of whichever executor the session resolved to, then
    /// apply the method's mask policy.
    pub fn init(&mut self) -> crate::Result<()> {
        // Thread the run's parallelism into the session so everything
        // executed through it — the host executor's kernel calls, on real
        // PJRT the intra-op hint — obeys the same `--threads` the L3
        // kernels do.  Say which executor is active: the difference is
        // material (native double-pruned backward vs compiled HLO).
        let kind = {
            let mut sess = self.session.borrow_mut();
            sess.set_parallel(self.cfg.parallel);
            sess.executor_kind()
        };
        eprintln!("[trainer] executor: {} (simd: {})", kind.describe(),
                  crate::backend::simd_level());
        self.store.put_scalar_i32("seed", self.cfg.seed as i32);
        if let Some(resume) = self.cfg.resume.clone() {
            // Restored state replaces `init` wholesale: params, moments,
            // masks and the adapter chain come from the checkpoint (the
            // executor re-ingests them on its first step), so neither the
            // init executable nor the mask policy may run — both would
            // overwrite the restored run.
            return self.restore_from(&resume);
        }
        self.run_exe("init")?;
        match self.cfg.method {
            Method::Slope => {}
            Method::Dense | Method::Wanda => self.force_ones_masks()?,
            Method::Srste | Method::SrsteLora => {} // masks unused by train_step_srste
            Method::Fig9(_) => {
                self.run_exe("fig9_init")?;
            }
        }
        Ok(())
    }

    /// Restore the newest valid training checkpoint under `dir` and
    /// position the run to continue at its step + 1.  The restored RNG
    /// state, step counter and schedule make the continuation bitwise
    /// identical to the uninterrupted run (the crate's kernels are
    /// bit-deterministic across thread counts).
    fn restore_from(&mut self, dir: &std::path::Path) -> crate::Result<()> {
        let (loaded, meta) = checkpoint::load_train_checkpoint(dir)?;
        crate::ensure!(
            meta.seed == self.cfg.seed,
            "--resume: checkpoint was trained with seed {}, this run is configured \
             with seed {} (the data stream would diverge); pass --seed {}",
            meta.seed,
            self.cfg.seed,
            meta.seed
        );
        if meta.steps != self.cfg.steps
            || meta.lazy_fraction.to_bits() != self.cfg.lazy_fraction.to_bits()
        {
            eprintln!(
                "[trainer] warning: resumed schedule (steps={}, lazy={}) differs from \
                 the checkpoint's (steps={}, lazy={}); the phase flip shifts and the \
                 run is no longer bitwise-reproducible against the original",
                self.cfg.steps, self.cfg.lazy_fraction, meta.steps, meta.lazy_fraction
            );
        }
        self.store.absorb(loaded);
        self.rng = Rng::from_state(meta.rng.0, meta.rng.1);
        self.lora_active = meta.lora_active;
        self.start_step = meta.step;
        eprintln!(
            "[trainer] resumed from {} at step {}/{} ({} phase)",
            dir.display(),
            meta.step,
            self.cfg.steps,
            if meta.lora_active { "lora" } else { "sparse" }
        );
        Ok(())
    }

    /// Replace every `masks.*` tensor with ones (dense baseline / Wanda
    /// pre-prune phase) — fabricated host-side, no executable needed.
    fn force_ones_masks(&mut self) -> crate::Result<()> {
        let specs: Vec<_> = self
            .manifest
            .exe("train_step")?
            .inputs
            .iter()
            .filter(|t| t.name.starts_with("masks."))
            .cloned()
            .collect();
        for spec in specs {
            self.store.put_const(&spec, 1.0)?;
        }
        Ok(())
    }

    fn step_exe(&self) -> &'static str {
        match (&self.cfg.method, self.lora_active) {
            (Method::Srste, _) | (Method::SrsteLora, false) => "train_step_srste",
            (Method::Fig9(v), _) => fig9_exe(*v),
            (_, true) => "train_step_lora",
            (_, false) => "train_step",
        }
    }

    /// Run the full schedule; returns the outcome summary.
    pub fn train(&mut self) -> crate::Result<TrainOutcome> {
        let lazy_enabled = self.cfg.lazy_steps() > 0
            && matches!(self.cfg.method,
                        Method::Slope | Method::Dense | Method::SrsteLora)
            && self.has_exe("train_step_lora");
        self.warmup(lazy_enabled)?;
        // NOTE: on the host route the policy governs the train-step
        // kernels themselves; on PJRT it only configures the CPU kernel
        // backend + session-hosted serving (xla-rs 0.1.6 exposes no
        // intra-op knob, so AOT train steps stay single-stream) — say
        // which, rather than implying threaded AOT steps.
        let threaded_steps = self.session.borrow().executor_kind()
            == crate::runtime::ExecutorKind::HostKernels;
        eprintln!(
            "[trainer] parallel policy: {} thread(s) ({})",
            self.cfg.parallel.effective_threads(),
            if threaded_steps {
                "host kernel engine: forward AND backward run under this policy"
            } else {
                "CPU backend kernels + session-hosted serving; AOT train steps are \
                 single-stream"
            }
        );
        if self.start_step == 0 {
            self.eval_point(0)?;
            // Checkpoint at EVERY eval point, step 0 included — a
            // `--steps 0` run (or one that diverges before the first
            // cadence point) must still leave a servable checkpoint
            // behind.  A resumed run skips the step-0 points: they
            // already happened in the original run.
            self.checkpoint_point(0)?;
        }
        let flip_at = self.cfg.sparse_steps();

        let (b, s1) = self.manifest.train_tokens_shape();
        let mut last_loss = f32::NAN;
        for step in (self.start_step + 1)..=self.cfg.steps {
            if lazy_enabled && !self.lora_active && step > flip_at {
                self.activate_lora()?;
            }
            let wall0 = Instant::now();
            // Allocation-free batch staging: the buffer is grown once and
            // refilled in place every step.
            self.corpus
                .train_batch_into(b, s1 - 1, &mut self.rng, &mut self.tokens_buf);
            self.store.put_i32("tokens", &[b, s1], &self.tokens_buf)?;
            let exe = self.step_exe();
            let exec0 = Instant::now();
            self.run_exe(exe)?;
            let exec_ms = exec0.elapsed().as_secs_f64() * 1e3;
            last_loss = self.store.read_scalar_f32("loss")?;
            self.metrics.steps.push(StepRec {
                step,
                loss: last_loss,
                wall_ms: wall0.elapsed().as_secs_f64() * 1e3,
                exec_ms,
                phase: if self.lora_active { "lora" } else { "sparse" },
            });
            if !last_loss.is_finite() {
                eprintln!("[trainer] step {step}: loss diverged ({last_loss}); stopping");
                break;
            }
            if step % self.cfg.eval_every == 0 || step == self.cfg.steps {
                self.eval_point(step)?;
                self.checkpoint_point(step)?;
            }
            if matches!(self.cfg.method, Method::Srste | Method::SrsteLora)
                && !self.lora_active
                && step % self.churn_every() == 0
            {
                self.snapshot_srste_masks(step)?;
            }
            if self.lora_active && (step - flip_at) % 2 == 0 {
                self.snapshot_adapters(step)?;
            }
        }

        if matches!(self.cfg.method, Method::Wanda) {
            self.apply_wanda_masks()?;
            self.eval_point(self.cfg.steps + 1)?;
            // The one-shot masks changed the servable model: re-checkpoint.
            self.checkpoint_point(self.cfg.steps + 1)?;
        }
        self.finalize_churn();
        self.finalize_adapters();
        let (acc, rank) = self.cloze_point(self.cfg.steps)?;
        let _ = rank;

        Ok(TrainOutcome {
            final_loss: last_loss,
            final_perplexity: self.metrics.final_perplexity().unwrap_or(f64::NAN),
            cloze_accuracy: acc,
            mean_step_ms: self.metrics.mean_step_wall_ms(),
            coordinator_overhead: self.metrics.coordinator_overhead(),
        })
    }

    /// Eval-cadence checkpoint (when `--checkpoint-dir` is set): the
    /// **serving** checkpoint (store planes + packed `CompressedNm`
    /// planes + a manifest copy, self-contained for
    /// `slope serve --manifest`) plus a full **training** checkpoint
    /// under `<dir>/train/` — moments, adapter chain, step counter and
    /// RNG state — so `slope train --resume <dir>` continues the run
    /// bitwise-identically.  All files go through the crash-safe atomic
    /// writer; the train `LATEST` pointer only advances after the new
    /// step directory re-reads and verifies.
    fn checkpoint_point(&mut self, step: usize) -> crate::Result<()> {
        let Some(dir) = self.cfg.checkpoint_dir.clone() else {
            return Ok(());
        };
        self.refresh_dynamic_masks()?;
        std::fs::create_dir_all(&dir)?;
        let (tensors, planes) =
            checkpoint::save_model_checkpoint(&self.store, &self.manifest, &dir)?;
        // Copy the manifest the session actually loaded (its recorded
        // `dir`), not a re-derived artifacts/<model> path.
        self.manifest.copy_into(&dir)?;
        let meta = checkpoint::TrainMeta {
            step,
            steps: self.cfg.steps,
            lazy_fraction: self.cfg.lazy_fraction,
            seed: self.cfg.seed,
            lora_active: self.lora_active,
            // RNG state AFTER this step's batch draw: the resumed loop
            // continues at step+1 with exactly the next batch.
            rng: self.rng.state(),
        };
        checkpoint::save_train_checkpoint(&self.store, &meta, &dir, self.cfg.keep_checkpoints)?;
        eprintln!(
            "[trainer] step {step}: checkpoint ({tensors} tensors, {planes} packed \
             planes, train state) -> {}",
            dir.display()
        );
        Ok(())
    }

    /// Phase flip at the (1−λ)·T mark: materialize the lazy adapters.
    fn activate_lora(&mut self) -> crate::Result<()> {
        if matches!(self.cfg.method, Method::SrsteLora) {
            // Project the dynamic run onto its converged magnitude mask so
            // the lazy phase (and eval) run the sparse executables.
            self.run_exe("magnitude_masks")?;
            eprintln!("[trainer] SR-STE projected onto magnitude N:M masks");
        }
        self.store.put_scalar_i32("seed", (self.cfg.seed as i32) ^ 0x10AD);
        self.run_exe("lora_init")?;
        self.lora_active = true;
        eprintln!(
            "[trainer] lazy low-rank adapters activated (rank {}) at step {}",
            self.manifest.config.adapter_rank,
            self.cfg.sparse_steps()
        );
        Ok(())
    }

    /// SR-STE stores dense weights; project onto the current magnitude
    /// mask before running the sparse eval/forward executables (this is
    /// exactly what its STE forward computes).
    fn refresh_dynamic_masks(&mut self) -> crate::Result<()> {
        if matches!(self.cfg.method, Method::Srste | Method::SrsteLora)
            && !self.lora_active
            && self.has_exe("magnitude_masks")
        {
            self.run_exe("magnitude_masks")?;
        }
        Ok(())
    }

    /// Validation NLL/perplexity via the eval executable.
    pub fn eval_point(&mut self, step: usize) -> crate::Result<f64> {
        self.refresh_dynamic_masks()?;
        let (b, s1) = self.manifest.train_tokens_shape();
        let exe = if self.lora_active { "eval_step_lora" } else { "eval_step" };
        if !self.has_exe(exe) {
            return Ok(f64::NAN);
        }
        let mut nlls = vec![];
        for i in 0..self.cfg.eval_batches {
            let batch = self.corpus.val_batch(b, s1 - 1, i);
            self.store.put_i32("tokens", &[b, s1], &batch.tokens)?;
            self.run_exe(exe)?;
            nlls.push(self.store.read_scalar_f32("loss")?);
        }
        let nll = nlls.iter().map(|v| *v as f64).sum::<f64>() / nlls.len().max(1) as f64;
        let ppl = perplexity(&nlls);
        self.metrics.evals.push(EvalRec { step, val_nll: nll, perplexity: ppl });
        Ok(ppl)
    }

    /// Cloze probe via the forward executable (downstream stand-in).
    pub fn cloze_point(&mut self, step: usize) -> crate::Result<(f64, f64)> {
        self.refresh_dynamic_masks()?;
        let exe = if self.lora_active { "forward_lora" } else { "forward" };
        if !self.has_exe(exe) {
            return Ok((f64::NAN, f64::NAN));
        }
        let c = &self.manifest.config;
        let (b, s, v) = (c.batch_size, c.seq_len, c.vocab_size);
        let mut accs = vec![];
        let mut ranks = vec![];
        for i in 0..self.cfg.eval_batches {
            let (batch, answers) = self.corpus.cloze_batch(b, s, i);
            self.store.put_i32("tokens", &[b, s], &batch.tokens)?;
            self.run_exe(exe)?;
            let logits = self.store.read_f32("logits")?;
            let (acc, rank) = cloze_score(&logits, b, s, v, &answers);
            accs.push(acc);
            ranks.push(rank);
        }
        let acc = accs.iter().sum::<f64>() / accs.len().max(1) as f64;
        let rank = ranks.iter().sum::<f64>() / ranks.len().max(1) as f64;
        self.metrics.cloze.push(ClozeRec { step, accuracy: acc, mean_rank: rank });
        Ok((acc, rank))
    }

    // -- Wanda -------------------------------------------------------------

    /// One-shot Wanda prune of the (dense-trained) model: calibration
    /// forward → activation-scaled N:M masks, installed for evaluation.
    fn apply_wanda_masks(&mut self) -> crate::Result<()> {
        let c = &self.manifest.config;
        let (b, s) = (c.batch_size, c.seq_len);
        let batch = self.corpus.val_batch(b, s - 1, 0);
        // wanda_masks wants (B, S) tokens.
        let mut toks = batch.tokens.clone();
        toks.truncate(b * s);
        // val_batch gives s tokens per row only if asked; rebuild exactly:
        let batch = self.corpus.val_batch(b, s, 0);
        toks = batch
            .tokens
            .chunks(s + 1)
            .flat_map(|row| row[..s].iter().copied())
            .collect();
        self.store.put_i32("tokens", &[b, s], &toks)?;
        self.run_exe("wanda_masks")?;
        eprintln!("[trainer] applied Wanda one-shot masks");
        Ok(())
    }

    // -- SR-STE mask churn (Figure 4) ---------------------------------------

    fn churn_every(&self) -> usize {
        (self.cfg.steps / 24).max(1)
    }

    fn snapshot_srste_masks(&mut self, step: usize) -> crate::Result<()> {
        self.run_exe("srste_masks")?;
        let names: Vec<String> = self
            .manifest
            .exe("srste_masks")?
            .outputs
            .iter()
            .map(|t| t.name.clone())
            .collect();
        let mut bits: Vec<u64> = vec![];
        let mut acc = 0u64;
        let mut nbits = 0;
        for name in names {
            for v in self.store.read_f32(&name)? {
                acc = (acc << 1) | u64::from(v != 0.0);
                nbits += 1;
                if nbits == 64 {
                    bits.push(acc);
                    acc = 0;
                    nbits = 0;
                }
            }
        }
        if nbits > 0 {
            bits.push(acc << (64 - nbits));
        }
        let prev_changed = self
            .churn_snapshots
            .last()
            .map(|(_, prev)| hamming_frac(prev, &bits))
            .unwrap_or(0.0);
        self.churn_snapshots.push((step, bits));
        self.metrics.churn.push(ChurnRec {
            step,
            frac_changed_vs_prev: prev_changed,
            frac_changed_vs_final: f64::NAN,
        });
        Ok(())
    }

    fn finalize_churn(&mut self) {
        if let Some((_, converged)) = self.churn_snapshots.last().cloned() {
            for (rec, (_, snap)) in self.metrics.churn.iter_mut().zip(&self.churn_snapshots) {
                rec.frac_changed_vs_final = hamming_frac(snap, &converged);
            }
        }
    }

    // -- Adapter convergence (Figure 3b) -------------------------------------

    fn snapshot_adapters(&mut self, step: usize) -> crate::Result<()> {
        let mut down = vec![];
        let mut up = vec![];
        let names: Vec<String> = self
            .manifest
            .exe("lora_init")?
            .outputs
            .iter()
            .filter(|t| t.name.starts_with("lora."))
            .map(|t| t.name.clone())
            .collect();
        for name in names {
            let v = self.store.read_f32(&name)?;
            if name.ends_with("_down") {
                down.extend(v);
            } else {
                up.extend(v);
            }
        }
        self.adapter_snapshots.push((step, down, up));
        Ok(())
    }

    fn finalize_adapters(&mut self) {
        if let Some((_, fd, fu)) = self.adapter_snapshots.last().cloned() {
            for (step, d, u) in &self.adapter_snapshots {
                self.metrics.adapters.push(AdapterRec {
                    step: *step,
                    cos_down: cosine_similarity(d, &fd) as f64,
                    cos_up: cosine_similarity(u, &fu) as f64,
                });
            }
        }
    }
}

fn hamming_frac(a: &[u64], b: &[u64]) -> f64 {
    let diff: u32 = a.iter().zip(b).map(|(x, y)| (x ^ y).count_ones()).sum();
    diff as f64 / (64 * a.len().max(1)) as f64
}

fn fig9_exe(v: Fig9Variant) -> &'static str {
    v.exe_name()
}

fn method_tag(m: &Method) -> String {
    match m {
        Method::Slope => "slope".into(),
        Method::Dense => "dense".into(),
        Method::Srste => "srste".into(),
        Method::SrsteLora => "srste-lora".into(),
        Method::Wanda => "wanda".into(),
        Method::Fig9(v) => format!("fig9-{}", v.exe_name().trim_start_matches("train_step_fig9_")),
    }
}
