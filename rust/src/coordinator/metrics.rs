//! Run metrics: per-step loss, evaluation points, SR-STE mask churn
//! (Figure 4), adapter convergence (Figure 3b).  Serialized as JSON for
//! the experiment harness and EXPERIMENTS.md.

use crate::util::json::{arr, num, obj, s, Json};
use std::path::Path;

#[derive(Clone, Debug)]
pub struct StepRec {
    pub step: usize,
    pub loss: f32,
    /// Wall time of the whole step (ms).
    pub wall_ms: f64,
    /// Time spent inside the PJRT execute (ms) — the L3-overhead metric is
    /// `1 - exec_ms/wall_ms`.
    pub exec_ms: f64,
    pub phase: &'static str,
}

#[derive(Clone, Debug)]
pub struct EvalRec {
    pub step: usize,
    pub val_nll: f64,
    pub perplexity: f64,
}

#[derive(Clone, Debug)]
pub struct ClozeRec {
    pub step: usize,
    pub accuracy: f64,
    pub mean_rank: f64,
}

/// Mask difference vs the previous snapshot and vs the converged (final)
/// mask — Figure 4 plots the latter.
#[derive(Clone, Debug)]
pub struct ChurnRec {
    pub step: usize,
    pub frac_changed_vs_prev: f64,
    /// Filled in post-hoc once the converged mask is known.
    pub frac_changed_vs_final: f64,
}

/// Cosine similarity of the adapters at `step` vs the converged adapters
/// (Figure 3b), split by factor role.
#[derive(Clone, Debug)]
pub struct AdapterRec {
    pub step: usize,
    pub cos_down: f64,
    pub cos_up: f64,
}

#[derive(Debug, Default)]
pub struct Metrics {
    pub run_name: String,
    pub steps: Vec<StepRec>,
    pub evals: Vec<EvalRec>,
    pub cloze: Vec<ClozeRec>,
    pub churn: Vec<ChurnRec>,
    pub adapters: Vec<AdapterRec>,
}

impl Metrics {
    pub fn new(run_name: impl Into<String>) -> Self {
        Self { run_name: run_name.into(), ..Default::default() }
    }

    pub fn final_perplexity(&self) -> Option<f64> {
        self.evals.last().map(|e| e.perplexity)
    }

    pub fn mean_step_wall_ms(&self) -> f64 {
        if self.steps.is_empty() {
            return 0.0;
        }
        self.steps.iter().map(|s| s.wall_ms).sum::<f64>() / self.steps.len() as f64
    }

    /// Fraction of step wall-time spent outside the PJRT execute — the L3
    /// coordinator-overhead figure reported in EXPERIMENTS.md §Perf.
    pub fn coordinator_overhead(&self) -> f64 {
        let wall: f64 = self.steps.iter().map(|s| s.wall_ms).sum();
        let exec: f64 = self.steps.iter().map(|s| s.exec_ms).sum();
        if wall == 0.0 {
            0.0
        } else {
            1.0 - exec / wall
        }
    }

    /// Serialize via the in-tree JSON writer.
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("run_name", s(self.run_name.clone())),
            ("steps", arr(self.steps.iter().map(|r| obj(vec![
                ("step", num(r.step as f64)),
                ("loss", num(r.loss as f64)),
                ("wall_ms", num(r.wall_ms)),
                ("exec_ms", num(r.exec_ms)),
                ("phase", s(r.phase)),
            ])))),
            ("evals", arr(self.evals.iter().map(|r| obj(vec![
                ("step", num(r.step as f64)),
                ("val_nll", num(r.val_nll)),
                ("perplexity", num(r.perplexity)),
            ])))),
            ("cloze", arr(self.cloze.iter().map(|r| obj(vec![
                ("step", num(r.step as f64)),
                ("accuracy", num(r.accuracy)),
                ("mean_rank", num(r.mean_rank)),
            ])))),
            ("churn", arr(self.churn.iter().map(|r| obj(vec![
                ("step", num(r.step as f64)),
                ("frac_changed_vs_prev", num(r.frac_changed_vs_prev)),
                ("frac_changed_vs_final", num(r.frac_changed_vs_final)),
            ])))),
            ("adapters", arr(self.adapters.iter().map(|r| obj(vec![
                ("step", num(r.step as f64)),
                ("cos_down", num(r.cos_down)),
                ("cos_up", num(r.cos_up)),
            ])))),
        ])
    }

    pub fn save(&self, dir: &Path) -> crate::Result<std::path::PathBuf> {
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("{}.json", self.run_name));
        std::fs::write(&path, self.to_json().to_string())?;
        Ok(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn overhead_math() {
        let mut m = Metrics::new("t");
        m.steps.push(StepRec { step: 0, loss: 1.0, wall_ms: 10.0, exec_ms: 9.0, phase: "sparse" });
        m.steps.push(StepRec { step: 1, loss: 1.0, wall_ms: 10.0, exec_ms: 10.0, phase: "sparse" });
        assert!((m.coordinator_overhead() - 0.05).abs() < 1e-9);
        assert!((m.mean_step_wall_ms() - 10.0).abs() < 1e-9);
    }
}
