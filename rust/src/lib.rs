//! # SLoPe — Double-Pruned Sparse Plus Lazy Low-Rank Adapter Pretraining
//!
//! Rust L3 coordinator for the three-layer (rust → JAX → Pallas, AOT via
//! PJRT) reproduction of *SLoPe* (ICLR 2025).  See `DESIGN.md` for the
//! system inventory and the per-experiment index.
//!
//! Layer map:
//! * [`runtime`]     — loads `artifacts/*.hlo.txt` (lowered once by
//!   `python/compile/aot.py`) and executes them on the PJRT CPU client.
//! * [`coordinator`] — the pretraining orchestrator: phase schedule (99%
//!   sparse → 1% lazy low-rank adapters), baselines, metrics.
//! * [`sparsity`] / [`backend`] — the N:M math and the Algorithm-1 sparse
//!   kernel backend (CPU reference for cuSPARSELt's role).
//! * [`perfmodel`] / [`memmodel`] — the calibrated A100 analytical
//!   simulator and the bit-exact memory accounting that regenerate the
//!   paper's speedup/memory tables.
//! * [`serve`]       — the serving subsystem: coalescing batcher, warm
//!   sparse+LoRA layer engine, KV-cached continuous-batching decode, and
//!   split request/per-token latency stats (`slope serve`,
//!   `slope generate`).
//! * [`data`] / [`eval`] — synthetic pretraining corpus and evaluation.
//! * [`util`]        — offline substrates (PRNG, JSON, bench harness,
//!   property testing); see DESIGN.md §2.

pub mod backend;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod exps;
pub mod memmodel;
pub mod perfmodel;
pub mod runtime;
pub mod serve;
pub mod sparsity;
pub mod tensor;
pub mod util;

/// Boxed dynamic error — the std-only stand-in for `anyhow::Error`
/// (DESIGN.md §2: this build environment is fully offline, so no external
/// error crate; `?` still converts any `std::error::Error` via `From`).
pub type Error = Box<dyn std::error::Error + Send + Sync + 'static>;

/// Crate-wide result alias (the `anyhow::Result` role).
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Error-construction macro (kept under the familiar name).
#[macro_export]
macro_rules! eyre {
    ($($t:tt)*) => { $crate::Error::from(format!($($t)*)) };
}

/// `anyhow::ensure!` stand-in: early-return an error unless `cond` holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            return Err($crate::eyre!($($t)*));
        }
    };
}
