//! `slope` — the L3 coordinator CLI (hand-rolled arg parsing; this build
//! environment is offline, see DESIGN.md §2).
//!
//! ```text
//! slope train --model gpt-nano --method slope --steps 200     # one pretraining run
//! slope exp fig2 --steps 120                                  # regenerate a paper table/figure
//! slope exp all-perf                                          # all analytic tables at once
//! slope info --model gpt-nano                                 # inspect a manifest
//! slope list                                                  # available artifact configs
//! ```

use slope::backend::{ParallelPolicy, PartitionStrategy};
use slope::config::{Fig9Variant, Method, RunConfig};
use slope::coordinator::{checkpoint, Trainer};
use slope::exps::{self, ExpArgs};
use slope::runtime::{KvDtype, KvPoolConfig, Manifest};
use slope::serve::{Admission, AotModel, BatchPolicy, DecodeAdmission, DecodeEngine,
                   DecodeModel, DecodePolicy, KernelDecodeModel, LoraAdapter, Overload,
                   QueuePolicy, Sampler, ServeEngine, ServeLayer, ServeModel, StatsSummary};
use slope::util::{Json, Rng};
use std::collections::HashMap;
use std::path::PathBuf;
use std::time::{Duration, Instant};

const USAGE: &str = "\
slope — SLoPe (ICLR'25) rust coordinator

USAGE:
  slope train [--model M] [--method METH] [--steps N] [--lazy-fraction F]
              [--eval-every N] [--seed S] [--artifacts DIR] [--out-dir DIR]
              [--checkpoint-dir DIR]           # serving + training ckpts at evals
              [--resume DIR]                   # continue from newest valid ckpt
              [--keep-checkpoints K]           # training-ckpt retention (default 3)
              [--threads T] [--partition P]    # kernel engine; 0 = auto

  slope serve [--manifest DIR]                 # serve a checkpointed model
              [--layers L] [--d-model D] [--d-ff F] [--rank R]  # synthetic stack
              [--requests N] [--max-batch B] [--max-wait-ms MS]
              [--request-timeout-ms MS]        # per-request deadline (0 = none)
              [--producers N]                  # async admission, N producer threads
              [--queue-cap N] [--overload O]   # bounded admission (shed/backpressure)
              [--decode]                       # continuous-batching generation mode
              [--max-new-tokens N] [--prompt-len P] [--temp T] [--eos ID]
              [--kv-block N] [--kv-dtype DT]   # paged KV cache (decode route)
              [--kv-pool-blocks N]             # pool bound (0 = grow on demand)
              [--prefix-cache]                 # radix-tree KV prefix reuse
              [--prefix-cache-blocks N]        # cache capacity (0 = off)
              [--threads T] [--partition P] [--seed S]
              # dynamic-batched sparse+LoRA serving; --manifest points at a
              # directory holding manifest.json + model.slopeckpt (what
              # `slope train --checkpoint-dir` writes)

  slope generate --manifest DIR                # KV-cached autoregressive decode
              [--max-new-tokens N] [--max-batch B] [--requests K]
              [--prompt-len P] [--prompt \"1,2,3\"] [--temp T] [--eos ID]
              [--kv-block N] [--kv-dtype DT] [--kv-pool-blocks N]
              [--prefix-cache] [--prefix-cache-blocks N]
              [--threads T] [--partition P] [--seed S]

  slope exp <ID> [--steps N] [--seed S] [--artifacts DIR] [--out-dir DIR]
  slope info [--model M] [--artifacts DIR]
  slope list [--artifacts DIR]

METH: slope | dense | srste | srste-lora | wanda | fig9:<variant>
P:    auto | rows | cols                       # kernel partition strategy
O:    reject | block                           # overload policy for --queue-cap
DT:   f32 | f16 | int8                         # KV-cache plane storage
ID:   table2|table3|table4|table5|table6|table7|table8|table9|table10|table12
      fig2|fig3a|fig3b|fig4|fig5|fig6|fig7|fig8|fig9|fig10|mem|all-perf
";

/// Minimal `--key value` flag parser (positional args returned separately).
struct Flags {
    map: HashMap<String, String>,
    positional: Vec<String>,
}

/// Flags that are boolean switches (value optional, default "true");
/// every other flag still requires an explicit value.
const BOOL_FLAGS: [&str; 2] = ["decode", "prefix-cache"];

impl Flags {
    fn parse(args: &[String]) -> slope::Result<Self> {
        let mut map = HashMap::new();
        let mut positional = vec![];
        let mut i = 0;
        while i < args.len() {
            if let Some(key) = args[i].strip_prefix("--") {
                match args.get(i + 1) {
                    Some(v) if !v.starts_with("--") => {
                        map.insert(key.to_string(), v.clone());
                        i += 2;
                    }
                    // A boolean switch followed by another flag (or
                    // nothing) stands alone, e.g. `--decode`.
                    _ if BOOL_FLAGS.contains(&key) => {
                        map.insert(key.to_string(), "true".to_string());
                        i += 1;
                    }
                    _ => return Err(slope::eyre!("flag --{key} needs a value")),
                }
            } else {
                positional.push(args[i].clone());
                i += 1;
            }
        }
        Ok(Self { map, positional })
    }

    fn get(&self, key: &str, default: &str) -> String {
        self.map.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    /// Boolean switch: present (with any value but "false") = set.
    fn flag_set(&self, key: &str) -> bool {
        self.map.get(key).map(|v| v != "false").unwrap_or(false)
    }

    fn usize(&self, key: &str, default: usize) -> slope::Result<usize> {
        match self.map.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| slope::eyre!("--{key}: {e}")),
        }
    }

    fn f64(&self, key: &str, default: f64) -> slope::Result<f64> {
        match self.map.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| slope::eyre!("--{key}: {e}")),
        }
    }
}

/// KV-pool configuration for the decode routes: environment defaults
/// (`SLOPE_KV_DTYPE` / `SLOPE_KV_BLOCK` / `SLOPE_PREFIX_CACHE`)
/// overridden by the explicit `--kv-dtype`, `--kv-block`,
/// `--kv-pool-blocks`, `--prefix-cache`, and `--prefix-cache-blocks`
/// flags.
fn kv_config(flags: &Flags) -> slope::Result<KvPoolConfig> {
    let mut kv = KvPoolConfig::from_env();
    if let Some(v) = flags.map.get("kv-dtype") {
        kv.dtype = KvDtype::parse(v)?;
    }
    if flags.map.contains_key("kv-block") {
        let bt = flags.usize("kv-block", 0)?;
        slope::ensure!(bt > 0, "--kv-block must be a positive token count");
        kv.block_tokens = bt;
    }
    if flags.map.contains_key("kv-pool-blocks") {
        let cap = flags.usize("kv-pool-blocks", 0)?;
        kv.max_blocks = (cap > 0).then_some(cap);
    }
    // `--prefix-cache` switches the cache on at the default capacity;
    // `--prefix-cache-blocks N` sets an explicit bound (0 = off) and
    // wins when both are given.
    if flags.flag_set("prefix-cache") {
        kv.prefix_cache = Some(slope::runtime::DEFAULT_PREFIX_CACHE_BLOCKS);
    }
    if flags.map.contains_key("prefix-cache-blocks") {
        let cap = flags.usize("prefix-cache-blocks", 0)?;
        kv.prefix_cache = (cap > 0).then_some(cap);
    }
    Ok(kv)
}

/// Print the uniform serving summary block (inline and admission modes).
fn print_serve_summary(done: usize, s: &StatsSummary, max_batch: usize) {
    println!("{}", s.report(done, max_batch));
}

/// Drive a serving engine end-to-end over synthetic open-loop traffic —
/// generic over the [`ServeModel`], shared by the kernel-stack and
/// manifest paths.  `producers == 0` runs the classic inline
/// submit/poll loop; `producers >= 1` routes everything through the
/// async admission front-end with that many concurrent producer threads
/// (the tail-latency-under-contention measurement).  Under a bounded
/// `queue` with the reject policy, shed submissions are counted rather
/// than treated as failures — overload is the behaviour being measured.
fn serve_run<M, F, G>(build: F, make_input: G, n_requests: usize, producers: usize,
                      policy: BatchPolicy, queue: QueuePolicy, seed: u64,
                      request_timeout: Option<Duration>) -> slope::Result<()>
where
    M: ServeModel + 'static,
    F: FnOnce() -> slope::Result<ServeEngine<M>> + Send + 'static,
    G: Fn(&mut Rng) -> Vec<f32> + Send + Clone + 'static,
{
    if producers == 0 {
        let mut eng = build()?;
        println!("model      : {}", eng.model().describe());
        let mut rng = Rng::seed_from_u64(seed);
        let done = eng.run_open_loop_with_deadline(n_requests, || make_input(&mut rng),
                                                   request_timeout)?;
        let s = eng.stats().summary();
        print_serve_summary(done, &s, eng.policy().max_batch);
        return Ok(());
    }

    let adm = Admission::spawn_with_opts(build, Admission::tick_for(policy.max_wait), queue,
                                         request_timeout);
    let base = n_requests / producers;
    let extra = n_requests % producers;
    let mut handles = Vec::with_capacity(producers);
    for p in 0..producers {
        let client = adm.client();
        let make_input = make_input.clone();
        let quota = base + usize::from(p < extra);
        handles.push(std::thread::spawn(move || -> (usize, usize, usize) {
            let mut rng = Rng::seed_from_u64(seed ^ (0x9E37_79B9 + p as u64));
            let (mut submitted, mut shed) = (0usize, 0usize);
            for i in 0..quota {
                match client.submit(i as u64, make_input(&mut rng)) {
                    Ok(()) => submitted += 1,
                    Err(_) => shed += 1, // bounded-reject overload signal
                }
            }
            let (mut got, mut failed) = (0usize, 0usize);
            for _ in 0..submitted {
                match client.recv() {
                    Ok(_) => got += 1,
                    Err(_) => failed += 1,
                }
            }
            (got, shed, failed)
        }));
    }
    let (mut done, mut shed, mut failed) = (0usize, 0usize, 0usize);
    for h in handles {
        let (g, s, f) = h.join().map_err(|_| slope::eyre!("producer thread panicked"))?;
        done += g;
        shed += s;
        failed += f;
    }
    let s = adm.finish()?;
    println!("producers  : {producers} concurrent (open-loop, async admission)");
    print_serve_summary(done, &s, policy.max_batch);
    report_drops(shed, failed, queue);
    Ok(())
}

/// Decode-mode counterpart of [`serve_run`]: open-loop prompt traffic
/// through the continuous-batching [`DecodeEngine`] — inline
/// (`producers == 0`) or via the async [`DecodeAdmission`] front-end.
fn serve_decode_run<M, F, G>(build: F, make_prompt: G, n_requests: usize, producers: usize,
                             max_batch: usize, queue: QueuePolicy, seed: u64,
                             request_timeout: Option<Duration>) -> slope::Result<()>
where
    M: DecodeModel + 'static,
    F: FnOnce() -> slope::Result<DecodeEngine<M>> + Send + 'static,
    G: Fn(&mut Rng) -> Vec<i32> + Send + Clone + 'static,
{
    if producers == 0 {
        let mut eng = build()?;
        println!("model      : {}", eng.model().describe_decode());
        let mut rng = Rng::seed_from_u64(seed);
        let start = Instant::now();
        let (mut done, mut shed) = (0usize, 0usize);
        for _ in 0..n_requests {
            let now = start.elapsed();
            let deadline = request_timeout.map(|t| now + t);
            match eng.submit_with_deadline(make_prompt(&mut rng), None, now, deadline) {
                Ok(_) => {}
                Err(_) => shed += 1, // inline engines can only shed
            }
            done += eng.step(start.elapsed())?.len();
        }
        while eng.active() > 0 {
            done += eng.step(start.elapsed())?.len();
        }
        let s = eng.stats().summary();
        print_serve_summary(done, &s, eng.policy().max_batch);
        report_drops(shed, 0, queue);
        return Ok(());
    }

    let adm = DecodeAdmission::spawn_with_opts(build, Duration::from_micros(200), queue,
                                               request_timeout);
    let base = n_requests / producers;
    let extra = n_requests % producers;
    let mut handles = Vec::with_capacity(producers);
    for p in 0..producers {
        let client = adm.client();
        let make_prompt = make_prompt.clone();
        let quota = base + usize::from(p < extra);
        handles.push(std::thread::spawn(move || -> (usize, usize, usize) {
            let mut rng = Rng::seed_from_u64(seed ^ (0xA11CE + p as u64));
            let (mut submitted, mut shed) = (0usize, 0usize);
            for i in 0..quota {
                match client.submit(i as u64, make_prompt(&mut rng), None) {
                    Ok(()) => submitted += 1,
                    Err(_) => shed += 1,
                }
            }
            let (mut got, mut failed) = (0usize, 0usize);
            for _ in 0..submitted {
                match client.recv() {
                    Ok(_) => got += 1,
                    Err(_) => failed += 1,
                }
            }
            (got, shed, failed)
        }));
    }
    let (mut done, mut shed, mut failed) = (0usize, 0usize, 0usize);
    for h in handles {
        let (g, s, f) = h.join().map_err(|_| slope::eyre!("producer thread panicked"))?;
        done += g;
        shed += s;
        failed += f;
    }
    let s = adm.finish()?;
    println!("producers  : {producers} concurrent (open-loop, async decode admission)");
    print_serve_summary(done, &s, max_batch);
    report_drops(shed, failed, queue);
    Ok(())
}

/// Print dropped-request diagnostics so the summary never silently
/// undercounts: `shed` = rejected at the (bounded) admission queue,
/// `failed` = submitted but answered with an error reply.
fn report_drops(shed: usize, failed: usize, queue: QueuePolicy) {
    if shed > 0 {
        println!(
            "shed       : {shed} requests (queue cap {:?}, {:?})",
            queue.cap, queue.overload
        );
    }
    if failed > 0 {
        println!("failed     : {failed} requests (error replies)");
    }
}

/// Parse a `--prompt "1,2,3"` token list (commas and/or whitespace).
fn parse_tokens(s: &str) -> slope::Result<Vec<i32>> {
    s.split(|c: char| c == ',' || c.is_whitespace())
        .filter(|t| !t.is_empty())
        .map(|t| t.parse::<i32>().map_err(|e| slope::eyre!("token {t:?}: {e}")))
        .collect()
}

fn parse_overload(s: &str) -> slope::Result<Overload> {
    Ok(match s {
        "reject" => Overload::Reject,
        "block" => Overload::Block,
        other => return Err(slope::eyre!("unknown overload policy {other:?}\n{USAGE}")),
    })
}

fn parse_partition(s: &str) -> slope::Result<PartitionStrategy> {
    Ok(match s {
        "auto" => PartitionStrategy::Auto,
        "rows" => PartitionStrategy::Rows,
        "cols" => PartitionStrategy::Cols,
        other => return Err(slope::eyre!("unknown partition strategy {other:?}\n{USAGE}")),
    })
}

fn parse_method(s: &str) -> slope::Result<Method> {
    Ok(match s {
        "slope" => Method::Slope,
        "dense" => Method::Dense,
        "srste" => Method::Srste,
        "srste-lora" => Method::SrsteLora,
        "wanda" => Method::Wanda,
        "fig9:weight_static" => Method::Fig9(Fig9Variant::WeightStatic),
        "fig9:weight_dynamic" => Method::Fig9(Fig9Variant::WeightDynamic),
        "fig9:input_static" => Method::Fig9(Fig9Variant::InputStatic),
        "fig9:input_dynamic" => Method::Fig9(Fig9Variant::InputDynamic),
        "fig9:gradout_dynamic" => Method::Fig9(Fig9Variant::GradoutDynamic),
        other => return Err(slope::eyre!("unknown method {other:?}\n{USAGE}")),
    })
}

fn main() -> slope::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first().map(|s| s.as_str()) else {
        print!("{USAGE}");
        return Ok(());
    };
    let flags = Flags::parse(&argv[1..])?;
    let artifacts = PathBuf::from(flags.get("artifacts", "artifacts"));
    let out_dir = PathBuf::from(flags.get("out-dir", "runs"));

    match cmd {
        "train" => {
            let resume = flags.map.get("resume").map(PathBuf::from);
            // A resumed run defaults its schedule/seed flags to the
            // checkpoint's recorded values, so a bare `slope train
            // --resume D` continues the original run exactly; explicit
            // flags still override (the Trainer hard-errors on a seed
            // mismatch and warns on schedule drift).
            let ckpt_meta = match &resume {
                Some(dir) => Some(checkpoint::peek_train_meta(dir)?),
                None => None,
            };
            let (d_steps, d_lazy, d_seed) = match &ckpt_meta {
                Some(m) => (m.steps, m.lazy_fraction, m.seed),
                None => (200, 0.05, 0),
            };
            let cfg = RunConfig {
                model: flags.get("model", "gpt-nano"),
                method: parse_method(&flags.get("method", "slope"))?,
                steps: flags.usize("steps", d_steps)?,
                lazy_fraction: flags.f64("lazy-fraction", d_lazy)?,
                eval_every: flags.usize("eval-every", 25)?,
                eval_batches: flags.usize("eval-batches", 4)?,
                seed: flags.usize("seed", d_seed as usize)? as u64,
                artifacts,
                out_dir: out_dir.clone(),
                checkpoint_dir: flags.map.get("checkpoint-dir").map(PathBuf::from),
                resume,
                keep_checkpoints: flags.usize("keep-checkpoints", 3)?.max(1),
                parallel: ParallelPolicy::with_threads(flags.usize("threads", 0)?)
                    .with_partition(parse_partition(&flags.get("partition", "auto"))?),
            };
            let mut t = Trainer::new(cfg)?;
            // Refine the fork floor now that the manifest's width is known
            // (the partition strategy flag is preserved).
            let partition = t.cfg.parallel.partition;
            t.cfg.parallel = ParallelPolicy::for_width(
                t.cfg.parallel.threads,
                t.manifest.config.d_model,
            )
            .with_partition(partition);
            t.init()?;
            let outcome = t.train()?;
            let path = t.metrics.save(&out_dir)?;
            println!("\n== run complete ==");
            println!("final loss        : {:.4}", outcome.final_loss);
            println!("final perplexity  : {:.3}", outcome.final_perplexity);
            println!("cloze accuracy    : {:.1}%", outcome.cloze_accuracy * 100.0);
            println!("mean step wall    : {:.1} ms", outcome.mean_step_ms);
            println!("coordinator ovhd  : {:.2}%", outcome.coordinator_overhead * 100.0);
            println!("metrics           : {}", path.display());
        }
        "serve" => {
            let n_requests = flags.usize("requests", 256)?;
            let max_batch = flags.usize("max-batch", 8)?;
            let max_wait = Duration::from_secs_f64(flags.f64("max-wait-ms", 2.0)? / 1e3);
            let threads = flags.usize("threads", 0)?;
            let partition = parse_partition(&flags.get("partition", "auto"))?;
            let seed = flags.usize("seed", 0)? as u64;
            let producers = flags.usize("producers", 0)?;
            let batch_policy = BatchPolicy::new(max_batch, max_wait);
            let queue = match flags.map.get("queue-cap") {
                None => QueuePolicy::unbounded(),
                Some(_) => QueuePolicy::bounded(
                    flags.usize("queue-cap", 64)?.max(1),
                    parse_overload(&flags.get("overload", "reject"))?,
                ),
            };
            let request_timeout = {
                let ms = flags.f64("request-timeout-ms", 0.0)?;
                (ms > 0.0).then(|| Duration::from_secs_f64(ms / 1e3))
            };

            if flags.flag_set("decode") {
                // Continuous-batching generation mode.
                let max_new = flags.usize("max-new-tokens", 16)?;
                let temp = flags.f64("temp", 0.0)?;
                let sampler = if temp > 0.0 {
                    Sampler::Temperature(temp as f32)
                } else {
                    Sampler::Greedy
                };
                let eos = match flags.map.get("eos") {
                    Some(v) => {
                        Some(v.parse::<i32>().map_err(|e| slope::eyre!("--eos: {e}"))?)
                    }
                    None => None,
                };
                // Inline engines bound their own waiting queue; the async
                // front-end bounds the channel instead.  An inline engine
                // has no producer to park, so it can only shed — refuse
                // the contradictory combination rather than silently
                // rejecting what the user asked to block.
                if producers == 0
                    && queue.cap.is_some()
                    && queue.overload == Overload::Block
                {
                    return Err(slope::eyre!(
                        "--overload block needs --producers >= 1 (inline decode can only \
                         shed; use --overload reject)"
                    ));
                }
                let inline_cap = if producers == 0 { queue.cap } else { None };
                if let Some(dir) = flags.map.get("manifest").map(PathBuf::from) {
                    let m = Manifest::load(&dir)?;
                    let (vocab, seq) = (m.config.vocab_size, m.config.seq_len);
                    let eff_batch = max_batch.min(m.config.batch_size.max(1));
                    let prompt_len = flags
                        .usize("prompt-len", (seq / 2).max(1))?
                        .clamp(1, seq.saturating_sub(1).max(1));
                    let policy = ParallelPolicy::for_width(threads, m.config.d_model)
                        .with_partition(partition);
                    let dpolicy = DecodePolicy {
                        max_batch: eff_batch,
                        max_new_tokens: max_new,
                        eos,
                        sampler,
                        seed,
                        queue_cap: inline_cap,
                    };
                    let kv = kv_config(&flags)?;
                    println!(
                        "== slope serve --decode --manifest {} ({}) — max_batch \
                         {eff_batch}, max_new {max_new}, prompt {prompt_len}, {} thr, \
                         kv {}/{} tok/blk ==",
                        dir.display(),
                        m.config.name,
                        policy.effective_threads(),
                        kv.dtype.label(),
                        kv.block_tokens,
                    );
                    serve_decode_run(
                        move || {
                            let model = AotModel::open_with_kv(&dir, policy, kv)?;
                            eprintln!("[serve] {}", model.describe_decode());
                            DecodeEngine::new(model, dpolicy)
                        },
                        move |rng: &mut Rng| {
                            (0..prompt_len).map(|_| rng.below(vocab) as i32).collect()
                        },
                        n_requests,
                        producers,
                        eff_batch,
                        queue,
                        seed,
                        request_timeout,
                    )?;
                } else {
                    let d_model = flags.usize("d-model", 256)?;
                    let d_ff = flags.usize("d-ff", 1024)?;
                    let rank = flags.usize("rank", 8)?;
                    let vocab = flags.usize("vocab", 512)?;
                    let prompt_len = flags.usize("prompt-len", 8)?.max(1);
                    let max_seq = prompt_len + max_new;
                    let policy = ParallelPolicy::for_width(threads, d_model)
                        .with_partition(partition);
                    let dpolicy = DecodePolicy {
                        max_batch,
                        max_new_tokens: max_new,
                        eos,
                        sampler,
                        seed,
                        queue_cap: inline_cap,
                    };
                    println!(
                        "== slope serve --decode: synthetic kernel-decode (d {d_model}, \
                         vocab {vocab}, 2:4 + rank {rank}) — max_batch {max_batch}, \
                         max_new {max_new}, {} thr ==",
                        policy.effective_threads(),
                    );
                    serve_decode_run(
                        move || {
                            let model = KernelDecodeModel::synthetic(
                                vocab, d_model, d_ff, rank, max_seq, policy, seed,
                            )?;
                            DecodeEngine::new(model, dpolicy)
                        },
                        move |rng: &mut Rng| {
                            (0..prompt_len).map(|_| rng.below(vocab) as i32).collect()
                        },
                        n_requests,
                        producers,
                        max_batch,
                        queue,
                        seed,
                        request_timeout,
                    )?;
                }
            } else if let Some(dir) = flags.map.get("manifest").map(PathBuf::from) {
                // Manifest-backed path: a checkpointed transformer served
                // through its `forward`/`forward_lora` semantics.  Clamp
                // the policy to the compiled batch up front so every
                // report (inline and admission) shows the effective cap.
                let m = Manifest::load(&dir)?;
                let (vocab, seq) = (m.config.vocab_size, m.config.seq_len);
                let eff_batch = max_batch.min(m.config.batch_size.max(1));
                let batch_policy = BatchPolicy::new(eff_batch, max_wait);
                let policy = ParallelPolicy::for_width(threads, m.config.d_model)
                    .with_partition(partition);
                println!(
                    "== slope serve --manifest {} ({}) — max_batch {eff_batch}, \
                     max_wait {:.1} ms, {} thr, {partition:?} ==",
                    dir.display(),
                    m.config.name,
                    max_wait.as_secs_f64() * 1e3,
                    policy.effective_threads(),
                );
                serve_run(
                    move || {
                        let model = AotModel::open(&dir, policy)?;
                        eprintln!("[serve] {}", model.describe());
                        ServeEngine::with_model(model, batch_policy)
                    },
                    move |rng: &mut Rng| {
                        (0..seq).map(|_| rng.below(vocab) as f32).collect()
                    },
                    n_requests,
                    producers,
                    batch_policy,
                    queue,
                    seed,
                    request_timeout,
                )?;
            } else {
                // Synthetic kernel-stack path: alternating
                // d_model → d_ff → d_model … sparse+LoRA layers.
                let n_layers = flags.usize("layers", 2)?;
                let d_model = flags.usize("d-model", 256)?;
                let d_ff = flags.usize("d-ff", 1024)?;
                let rank = flags.usize("rank", 8)?;
                let policy =
                    ParallelPolicy::for_width(threads, d_model).with_partition(partition);
                println!(
                    "== slope serve: {n_layers} layers ({d_model}↔{d_ff}, 2:4, rank {rank}) — \
                     max_batch {max_batch}, max_wait {:.1} ms, {} thr, {partition:?} ==",
                    max_wait.as_secs_f64() * 1e3,
                    policy.effective_threads(),
                );
                serve_run(
                    move || {
                        use slope::sparsity::{random_row_mask, NmScheme};
                        use slope::tensor::Matrix;
                        let mut rng = Rng::seed_from_u64(seed);
                        let mut layers = Vec::with_capacity(n_layers.max(1));
                        let mut d_in = d_model;
                        for i in 0..n_layers.max(1) {
                            let d_out = if i % 2 == 0 { d_ff } else { d_model };
                            let w = Matrix::randn(d_out, d_in, 1.0 / (d_in as f32).sqrt(),
                                                  &mut rng);
                            let mask =
                                random_row_mask(d_out, d_in, NmScheme::TWO_FOUR, &mut rng);
                            let be = slope::backend::SparseBackend::setup(
                                &w, mask, NmScheme::TWO_FOUR,
                                slope::backend::SpmmAlgo::RowMajor, policy,
                            );
                            let lora = (rank > 0).then(|| LoraAdapter {
                                up: Matrix::randn(d_out, rank, 0.1, &mut rng),
                                down: Matrix::randn(rank, d_in, 0.1, &mut rng),
                            });
                            layers.push(ServeLayer::new(be, lora)?);
                            d_in = d_out;
                        }
                        ServeEngine::new(layers, batch_policy)
                    },
                    move |rng: &mut Rng| {
                        (0..d_model).map(|_| rng.normal() as f32 * 0.5).collect()
                    },
                    n_requests,
                    producers,
                    batch_policy,
                    queue,
                    seed,
                    request_timeout,
                )?;
            }
        }
        "generate" => {
            let dir = flags
                .map
                .get("manifest")
                .map(PathBuf::from)
                .ok_or_else(|| slope::eyre!("generate needs --manifest DIR\n{USAGE}"))?;
            let m = Manifest::load(&dir)?;
            let threads = flags.usize("threads", 0)?;
            let partition = parse_partition(&flags.get("partition", "auto"))?;
            let policy = ParallelPolicy::for_width(threads, m.config.d_model)
                .with_partition(partition);
            let seed = flags.usize("seed", 0)? as u64;
            let max_new = flags.usize("max-new-tokens", 16)?;
            let max_batch = flags.usize("max-batch", 8)?.max(1);
            let temp = flags.f64("temp", 0.0)?;
            let eos = match flags.map.get("eos") {
                Some(v) => Some(v.parse::<i32>().map_err(|e| slope::eyre!("--eos: {e}"))?),
                None => None,
            };
            let sampler = if temp > 0.0 {
                Sampler::Temperature(temp as f32)
            } else {
                Sampler::Greedy
            };
            let seq = m.config.seq_len;
            let prompt_len = flags
                .usize("prompt-len", (seq / 2).max(1))?
                .clamp(1, seq.saturating_sub(1).max(1));
            let requests = flags.usize("requests", 4)?.max(1);
            let prompts: Vec<Vec<i32>> = match flags.map.get("prompt") {
                Some(p) => vec![parse_tokens(p)?],
                None => {
                    let mut rng = Rng::seed_from_u64(seed);
                    (0..requests)
                        .map(|_| {
                            (0..prompt_len)
                                .map(|_| rng.below(m.config.vocab_size) as i32)
                                .collect()
                        })
                        .collect()
                }
            };
            let kv = kv_config(&flags)?;
            println!(
                "== slope generate --manifest {} ({}) — max_new {max_new}, \
                 max_batch {max_batch}, {} thr, kv {}/{} tok/blk ==",
                dir.display(),
                m.config.name,
                policy.effective_threads(),
                kv.dtype.label(),
                kv.block_tokens,
            );
            let model = AotModel::open_with_kv(&dir, policy, kv)?;
            println!("model      : {}", model.describe_decode());
            let dpolicy = DecodePolicy {
                max_batch,
                max_new_tokens: max_new,
                eos,
                sampler,
                seed,
                queue_cap: None,
            };
            let mut eng = DecodeEngine::new(model, dpolicy)?;
            let start = Instant::now();
            for p in prompts {
                eng.submit(p, None, start.elapsed())?;
            }
            let mut done = eng.run_to_completion(start)?;
            done.sort_by_key(|g| g.id);
            for g in &done {
                let toks: Vec<String> = g.tokens.iter().map(|t| t.to_string()).collect();
                println!(
                    "gen {:>3}  prompt[{:>3}] +{:<3} {:<9} {}",
                    g.id,
                    g.prompt_len,
                    g.tokens.len(),
                    format!("{:?}", g.finish),
                    toks.join(" ")
                );
            }
            let served = done.len();
            let s = eng.stats().summary();
            print_serve_summary(served, &s, eng.policy().max_batch);
        }
        "exp" => {
            let id = flags
                .positional
                .first()
                .ok_or_else(|| slope::eyre!("exp needs an id\n{USAGE}"))?;
            let args = ExpArgs {
                artifacts,
                out_dir,
                steps: flags.usize("steps", 120)?,
                seed: flags.usize("seed", 0)? as u64,
            };
            exps::run(id, &args)?;
        }
        "info" => {
            let model = flags.get("model", "gpt-nano");
            let m = Manifest::load(&artifacts.join(&model))?;
            let c = &m.config;
            println!("config {}: d={} L={} heads={} ffn={} seq={} batch={} vocab={} rank={}",
                     c.name, c.d_model, c.n_layer, c.n_head, c.d_ff, c.seq_len,
                     c.batch_size, c.vocab_size, c.adapter_rank);
            println!("~{:.2}M dense params; sparsity {}:{} / {}:{}; prune attn={} mlp={}",
                     c.n_params_dense as f64 / 1e6,
                     c.first_half_sparsity.0, c.first_half_sparsity.1,
                     c.second_half_sparsity.0, c.second_half_sparsity.1,
                     c.prune_attn, c.prune_mlp);
            let mut names: Vec<_> = m.executables.keys().collect();
            names.sort();
            for n in names {
                let e = &m.executables[n];
                println!("  {n:<36} {} in / {} out", e.inputs.len(), e.outputs.len());
            }
        }
        "list" => {
            let index = Json::parse(&std::fs::read_to_string(artifacts.join("index.json"))?)?;
            for (name, info) in index.as_obj().into_iter().flatten() {
                let sets: Vec<&str> = info
                    .get("sets")
                    .and_then(|s| s.as_arr())
                    .map(|a| a.iter().filter_map(|x| x.as_str()).collect())
                    .unwrap_or_default();
                println!("{name:<22} {}", sets.join(","));
            }
        }
        "--help" | "-h" | "help" => print!("{USAGE}"),
        other => {
            print!("{USAGE}");
            return Err(slope::eyre!("unknown command {other:?}"));
        }
    }
    Ok(())
}
