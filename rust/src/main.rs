//! `slope` — the L3 coordinator CLI (hand-rolled arg parsing; this build
//! environment is offline, see DESIGN.md §2).
//!
//! ```text
//! slope train --model gpt-nano --method slope --steps 200     # one pretraining run
//! slope exp fig2 --steps 120                                  # regenerate a paper table/figure
//! slope exp all-perf                                          # all analytic tables at once
//! slope info --model gpt-nano                                 # inspect a manifest
//! slope list                                                  # available artifact configs
//! ```

use slope::backend::{ParallelPolicy, PartitionStrategy};
use slope::config::{Fig9Variant, Method, RunConfig};
use slope::coordinator::Trainer;
use slope::exps::{self, ExpArgs};
use slope::runtime::Manifest;
use slope::util::Json;
use std::collections::HashMap;
use std::path::PathBuf;

const USAGE: &str = "\
slope — SLoPe (ICLR'25) rust coordinator

USAGE:
  slope train [--model M] [--method METH] [--steps N] [--lazy-fraction F]
              [--eval-every N] [--seed S] [--artifacts DIR] [--out-dir DIR]
              [--threads T] [--partition P]    # kernel engine; 0 = auto

  slope serve [--layers L] [--d-model D] [--d-ff F] [--rank R]
              [--requests N] [--max-batch B] [--max-wait-ms MS]
              [--threads T] [--partition P] [--seed S]
              # dynamic-batched sparse+LoRA serving on the kernel engine

  slope exp <ID> [--steps N] [--seed S] [--artifacts DIR] [--out-dir DIR]
  slope info [--model M] [--artifacts DIR]
  slope list [--artifacts DIR]

METH: slope | dense | srste | srste-lora | wanda | fig9:<variant>
P:    auto | rows | cols                       # kernel partition strategy
ID:   table2|table3|table4|table5|table6|table7|table8|table9|table10|table12
      fig2|fig3a|fig3b|fig4|fig5|fig6|fig7|fig8|fig9|fig10|mem|all-perf
";

/// Minimal `--key value` flag parser (positional args returned separately).
struct Flags {
    map: HashMap<String, String>,
    positional: Vec<String>,
}

impl Flags {
    fn parse(args: &[String]) -> slope::Result<Self> {
        let mut map = HashMap::new();
        let mut positional = vec![];
        let mut i = 0;
        while i < args.len() {
            if let Some(key) = args[i].strip_prefix("--") {
                let val = args
                    .get(i + 1)
                    .ok_or_else(|| slope::eyre!("flag --{key} needs a value"))?;
                map.insert(key.to_string(), val.clone());
                i += 2;
            } else {
                positional.push(args[i].clone());
                i += 1;
            }
        }
        Ok(Self { map, positional })
    }

    fn get(&self, key: &str, default: &str) -> String {
        self.map.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    fn usize(&self, key: &str, default: usize) -> slope::Result<usize> {
        match self.map.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| slope::eyre!("--{key}: {e}")),
        }
    }

    fn f64(&self, key: &str, default: f64) -> slope::Result<f64> {
        match self.map.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| slope::eyre!("--{key}: {e}")),
        }
    }
}

fn parse_partition(s: &str) -> slope::Result<PartitionStrategy> {
    Ok(match s {
        "auto" => PartitionStrategy::Auto,
        "rows" => PartitionStrategy::Rows,
        "cols" => PartitionStrategy::Cols,
        other => return Err(slope::eyre!("unknown partition strategy {other:?}\n{USAGE}")),
    })
}

fn parse_method(s: &str) -> slope::Result<Method> {
    Ok(match s {
        "slope" => Method::Slope,
        "dense" => Method::Dense,
        "srste" => Method::Srste,
        "srste-lora" => Method::SrsteLora,
        "wanda" => Method::Wanda,
        "fig9:weight_static" => Method::Fig9(Fig9Variant::WeightStatic),
        "fig9:weight_dynamic" => Method::Fig9(Fig9Variant::WeightDynamic),
        "fig9:input_static" => Method::Fig9(Fig9Variant::InputStatic),
        "fig9:input_dynamic" => Method::Fig9(Fig9Variant::InputDynamic),
        "fig9:gradout_dynamic" => Method::Fig9(Fig9Variant::GradoutDynamic),
        other => return Err(slope::eyre!("unknown method {other:?}\n{USAGE}")),
    })
}

fn main() -> slope::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first().map(|s| s.as_str()) else {
        print!("{USAGE}");
        return Ok(());
    };
    let flags = Flags::parse(&argv[1..])?;
    let artifacts = PathBuf::from(flags.get("artifacts", "artifacts"));
    let out_dir = PathBuf::from(flags.get("out-dir", "runs"));

    match cmd {
        "train" => {
            let cfg = RunConfig {
                model: flags.get("model", "gpt-nano"),
                method: parse_method(&flags.get("method", "slope"))?,
                steps: flags.usize("steps", 200)?,
                lazy_fraction: flags.f64("lazy-fraction", 0.05)?,
                eval_every: flags.usize("eval-every", 25)?,
                eval_batches: flags.usize("eval-batches", 4)?,
                seed: flags.usize("seed", 0)? as u64,
                artifacts,
                out_dir: out_dir.clone(),
                parallel: ParallelPolicy::with_threads(flags.usize("threads", 0)?)
                    .with_partition(parse_partition(&flags.get("partition", "auto"))?),
            };
            let mut t = Trainer::new(cfg)?;
            // Refine the fork floor now that the manifest's width is known
            // (the partition strategy flag is preserved).
            let partition = t.cfg.parallel.partition;
            t.cfg.parallel = ParallelPolicy::for_width(
                t.cfg.parallel.threads,
                t.manifest.config.d_model,
            )
            .with_partition(partition);
            t.init()?;
            let outcome = t.train()?;
            let path = t.metrics.save(&out_dir)?;
            println!("\n== run complete ==");
            println!("final loss        : {:.4}", outcome.final_loss);
            println!("final perplexity  : {:.3}", outcome.final_perplexity);
            println!("cloze accuracy    : {:.1}%", outcome.cloze_accuracy * 100.0);
            println!("mean step wall    : {:.1} ms", outcome.mean_step_ms);
            println!("coordinator ovhd  : {:.2}%", outcome.coordinator_overhead * 100.0);
            println!("metrics           : {}", path.display());
        }
        "serve" => {
            use slope::serve::{BatchPolicy, LoraAdapter, ServeEngine, ServeLayer};
            use slope::sparsity::{random_row_mask, NmScheme};
            use slope::tensor::Matrix;
            use slope::util::Rng;
            use std::time::{Duration, Instant};

            let n_layers = flags.usize("layers", 2)?;
            let d_model = flags.usize("d-model", 256)?;
            let d_ff = flags.usize("d-ff", 1024)?;
            let rank = flags.usize("rank", 8)?;
            let n_requests = flags.usize("requests", 256)?;
            let max_batch = flags.usize("max-batch", 8)?;
            let max_wait = Duration::from_secs_f64(flags.f64("max-wait-ms", 2.0)? / 1e3);
            let threads = flags.usize("threads", 0)?;
            let partition = parse_partition(&flags.get("partition", "auto"))?;
            let seed = flags.usize("seed", 0)? as u64;

            let policy =
                ParallelPolicy::for_width(threads, d_model).with_partition(partition);
            let mut rng = Rng::seed_from_u64(seed);
            // Alternating d_model → d_ff → d_model … sparse+LoRA stack.
            let mut layers = Vec::with_capacity(n_layers.max(1));
            let mut d_in = d_model;
            for i in 0..n_layers.max(1) {
                let d_out = if i % 2 == 0 { d_ff } else { d_model };
                let w = Matrix::randn(d_out, d_in, 1.0 / (d_in as f32).sqrt(), &mut rng);
                let mask = random_row_mask(d_out, d_in, NmScheme::TWO_FOUR, &mut rng);
                let be = slope::backend::SparseBackend::setup(
                    &w, mask, NmScheme::TWO_FOUR, slope::backend::SpmmAlgo::RowMajor, policy,
                );
                let lora = (rank > 0).then(|| LoraAdapter {
                    up: Matrix::randn(d_out, rank, 0.1, &mut rng),
                    down: Matrix::randn(rank, d_in, 0.1, &mut rng),
                });
                layers.push(ServeLayer::new(be, lora)?);
                d_in = d_out;
            }
            let mut eng = ServeEngine::new(layers, BatchPolicy::new(max_batch, max_wait))?;
            println!(
                "== slope serve: {n_layers} layers ({d_model}↔{d_ff}, 2:4, rank {rank}) — \
                 max_batch {max_batch}, max_wait {:.1} ms, {} thr, {partition:?} ==",
                max_wait.as_secs_f64() * 1e3,
                policy.effective_threads(),
            );
            // Synthetic open-loop traffic: submit all requests, polling the
            // engine after each so batches coalesce under real time.
            let d_req = eng.d_in();
            let start = Instant::now();
            let mut done = 0usize;
            for _ in 0..n_requests {
                let input: Vec<f32> = (0..d_req).map(|_| rng.normal() as f32 * 0.5).collect();
                eng.submit(input, start.elapsed())?;
                done += eng.poll(start.elapsed()).len();
            }
            // End of stream: drain the tail without waiting out max_wait.
            done += eng.flush(start.elapsed()).len();
            let s = eng.stats().summary();
            println!("served     : {done} requests in {} batches", s.batches);
            println!("batch fill : {:.2} / {max_batch}", s.mean_batch_fill);
            println!("latency    : p50 {:.3} ms   p95 {:.3} ms", s.p50_ms, s.p95_ms);
            println!("throughput : {:.0} req/s", s.req_per_s);
        }
        "exp" => {
            let id = flags
                .positional
                .first()
                .ok_or_else(|| slope::eyre!("exp needs an id\n{USAGE}"))?;
            let args = ExpArgs {
                artifacts,
                out_dir,
                steps: flags.usize("steps", 120)?,
                seed: flags.usize("seed", 0)? as u64,
            };
            exps::run(id, &args)?;
        }
        "info" => {
            let model = flags.get("model", "gpt-nano");
            let m = Manifest::load(&artifacts.join(&model))?;
            let c = &m.config;
            println!("config {}: d={} L={} heads={} ffn={} seq={} batch={} vocab={} rank={}",
                     c.name, c.d_model, c.n_layer, c.n_head, c.d_ff, c.seq_len,
                     c.batch_size, c.vocab_size, c.adapter_rank);
            println!("~{:.2}M dense params; sparsity {}:{} / {}:{}; prune attn={} mlp={}",
                     c.n_params_dense as f64 / 1e6,
                     c.first_half_sparsity.0, c.first_half_sparsity.1,
                     c.second_half_sparsity.0, c.second_half_sparsity.1,
                     c.prune_attn, c.prune_mlp);
            let mut names: Vec<_> = m.executables.keys().collect();
            names.sort();
            for n in names {
                let e = &m.executables[n];
                println!("  {n:<36} {} in / {} out", e.inputs.len(), e.outputs.len());
            }
        }
        "list" => {
            let index = Json::parse(&std::fs::read_to_string(artifacts.join("index.json"))?)?;
            for (name, info) in index.as_obj().into_iter().flatten() {
                let sets: Vec<&str> = info
                    .get("sets")
                    .and_then(|s| s.as_arr())
                    .map(|a| a.iter().filter_map(|x| x.as_str()).collect())
                    .unwrap_or_default();
                println!("{name:<22} {}", sets.join(","));
            }
        }
        "--help" | "-h" | "help" => print!("{USAGE}"),
        other => {
            print!("{USAGE}");
            return Err(slope::eyre!("unknown command {other:?}"));
        }
    }
    Ok(())
}
