//! `slope` — the L3 coordinator CLI (hand-rolled arg parsing; this build
//! environment is offline, see DESIGN.md §2).
//!
//! ```text
//! slope train --model gpt-nano --method slope --steps 200     # one pretraining run
//! slope exp fig2 --steps 120                                  # regenerate a paper table/figure
//! slope exp all-perf                                          # all analytic tables at once
//! slope info --model gpt-nano                                 # inspect a manifest
//! slope list                                                  # available artifact configs
//! ```

use slope::backend::{ParallelPolicy, PartitionStrategy};
use slope::config::{Fig9Variant, Method, RunConfig};
use slope::coordinator::Trainer;
use slope::exps::{self, ExpArgs};
use slope::runtime::Manifest;
use slope::serve::{Admission, AotModel, BatchPolicy, LoraAdapter, ServeEngine, ServeLayer,
                   ServeModel, StatsSummary};
use slope::util::{Json, Rng};
use std::collections::HashMap;
use std::path::PathBuf;
use std::time::Duration;

const USAGE: &str = "\
slope — SLoPe (ICLR'25) rust coordinator

USAGE:
  slope train [--model M] [--method METH] [--steps N] [--lazy-fraction F]
              [--eval-every N] [--seed S] [--artifacts DIR] [--out-dir DIR]
              [--checkpoint-dir DIR]           # serving checkpoints at evals
              [--threads T] [--partition P]    # kernel engine; 0 = auto

  slope serve [--manifest DIR]                 # serve a checkpointed model
              [--layers L] [--d-model D] [--d-ff F] [--rank R]  # synthetic stack
              [--requests N] [--max-batch B] [--max-wait-ms MS]
              [--producers N]                  # async admission, N producer threads
              [--threads T] [--partition P] [--seed S]
              # dynamic-batched sparse+LoRA serving; --manifest points at a
              # directory holding manifest.json + model.slopeckpt (what
              # `slope train --checkpoint-dir` writes)

  slope exp <ID> [--steps N] [--seed S] [--artifacts DIR] [--out-dir DIR]
  slope info [--model M] [--artifacts DIR]
  slope list [--artifacts DIR]

METH: slope | dense | srste | srste-lora | wanda | fig9:<variant>
P:    auto | rows | cols                       # kernel partition strategy
ID:   table2|table3|table4|table5|table6|table7|table8|table9|table10|table12
      fig2|fig3a|fig3b|fig4|fig5|fig6|fig7|fig8|fig9|fig10|mem|all-perf
";

/// Minimal `--key value` flag parser (positional args returned separately).
struct Flags {
    map: HashMap<String, String>,
    positional: Vec<String>,
}

impl Flags {
    fn parse(args: &[String]) -> slope::Result<Self> {
        let mut map = HashMap::new();
        let mut positional = vec![];
        let mut i = 0;
        while i < args.len() {
            if let Some(key) = args[i].strip_prefix("--") {
                let val = args
                    .get(i + 1)
                    .ok_or_else(|| slope::eyre!("flag --{key} needs a value"))?;
                map.insert(key.to_string(), val.clone());
                i += 2;
            } else {
                positional.push(args[i].clone());
                i += 1;
            }
        }
        Ok(Self { map, positional })
    }

    fn get(&self, key: &str, default: &str) -> String {
        self.map.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    fn usize(&self, key: &str, default: usize) -> slope::Result<usize> {
        match self.map.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| slope::eyre!("--{key}: {e}")),
        }
    }

    fn f64(&self, key: &str, default: f64) -> slope::Result<f64> {
        match self.map.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| slope::eyre!("--{key}: {e}")),
        }
    }
}

/// Print the uniform serving summary block (inline and admission modes).
fn print_serve_summary(done: usize, s: &StatsSummary, max_batch: usize) {
    println!("{}", s.report(done, max_batch));
}

/// Drive a serving engine end-to-end over synthetic open-loop traffic —
/// generic over the [`ServeModel`], shared by the kernel-stack and
/// manifest paths.  `producers == 0` runs the classic inline
/// submit/poll loop; `producers >= 1` routes everything through the
/// async admission front-end with that many concurrent producer threads
/// (the tail-latency-under-contention measurement).
fn serve_run<M, F, G>(build: F, make_input: G, n_requests: usize, producers: usize,
                      policy: BatchPolicy, seed: u64) -> slope::Result<()>
where
    M: ServeModel + 'static,
    F: FnOnce() -> slope::Result<ServeEngine<M>> + Send + 'static,
    G: Fn(&mut Rng) -> Vec<f32> + Send + Clone + 'static,
{
    if producers == 0 {
        let mut eng = build()?;
        println!("model      : {}", eng.model().describe());
        let mut rng = Rng::seed_from_u64(seed);
        let done = eng.run_open_loop(n_requests, || make_input(&mut rng))?;
        let s = eng.stats().summary();
        print_serve_summary(done, &s, eng.policy().max_batch);
        return Ok(());
    }

    let adm = Admission::spawn(build, Admission::tick_for(policy.max_wait));
    let base = n_requests / producers;
    let extra = n_requests % producers;
    let mut handles = Vec::with_capacity(producers);
    for p in 0..producers {
        let client = adm.client();
        let make_input = make_input.clone();
        let quota = base + usize::from(p < extra);
        handles.push(std::thread::spawn(move || -> slope::Result<usize> {
            let mut rng = Rng::seed_from_u64(seed ^ (0x9E37_79B9 + p as u64));
            for i in 0..quota {
                client.submit(i as u64, make_input(&mut rng))?;
            }
            for _ in 0..quota {
                client.recv()?;
            }
            Ok(quota)
        }));
    }
    let mut done = 0usize;
    for h in handles {
        done += h.join().map_err(|_| slope::eyre!("producer thread panicked"))??;
    }
    let s = adm.finish()?;
    println!("producers  : {producers} concurrent (open-loop, async admission)");
    print_serve_summary(done, &s, policy.max_batch);
    Ok(())
}

fn parse_partition(s: &str) -> slope::Result<PartitionStrategy> {
    Ok(match s {
        "auto" => PartitionStrategy::Auto,
        "rows" => PartitionStrategy::Rows,
        "cols" => PartitionStrategy::Cols,
        other => return Err(slope::eyre!("unknown partition strategy {other:?}\n{USAGE}")),
    })
}

fn parse_method(s: &str) -> slope::Result<Method> {
    Ok(match s {
        "slope" => Method::Slope,
        "dense" => Method::Dense,
        "srste" => Method::Srste,
        "srste-lora" => Method::SrsteLora,
        "wanda" => Method::Wanda,
        "fig9:weight_static" => Method::Fig9(Fig9Variant::WeightStatic),
        "fig9:weight_dynamic" => Method::Fig9(Fig9Variant::WeightDynamic),
        "fig9:input_static" => Method::Fig9(Fig9Variant::InputStatic),
        "fig9:input_dynamic" => Method::Fig9(Fig9Variant::InputDynamic),
        "fig9:gradout_dynamic" => Method::Fig9(Fig9Variant::GradoutDynamic),
        other => return Err(slope::eyre!("unknown method {other:?}\n{USAGE}")),
    })
}

fn main() -> slope::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first().map(|s| s.as_str()) else {
        print!("{USAGE}");
        return Ok(());
    };
    let flags = Flags::parse(&argv[1..])?;
    let artifacts = PathBuf::from(flags.get("artifacts", "artifacts"));
    let out_dir = PathBuf::from(flags.get("out-dir", "runs"));

    match cmd {
        "train" => {
            let cfg = RunConfig {
                model: flags.get("model", "gpt-nano"),
                method: parse_method(&flags.get("method", "slope"))?,
                steps: flags.usize("steps", 200)?,
                lazy_fraction: flags.f64("lazy-fraction", 0.05)?,
                eval_every: flags.usize("eval-every", 25)?,
                eval_batches: flags.usize("eval-batches", 4)?,
                seed: flags.usize("seed", 0)? as u64,
                artifacts,
                out_dir: out_dir.clone(),
                checkpoint_dir: flags.map.get("checkpoint-dir").map(PathBuf::from),
                parallel: ParallelPolicy::with_threads(flags.usize("threads", 0)?)
                    .with_partition(parse_partition(&flags.get("partition", "auto"))?),
            };
            let mut t = Trainer::new(cfg)?;
            // Refine the fork floor now that the manifest's width is known
            // (the partition strategy flag is preserved).
            let partition = t.cfg.parallel.partition;
            t.cfg.parallel = ParallelPolicy::for_width(
                t.cfg.parallel.threads,
                t.manifest.config.d_model,
            )
            .with_partition(partition);
            t.init()?;
            let outcome = t.train()?;
            let path = t.metrics.save(&out_dir)?;
            println!("\n== run complete ==");
            println!("final loss        : {:.4}", outcome.final_loss);
            println!("final perplexity  : {:.3}", outcome.final_perplexity);
            println!("cloze accuracy    : {:.1}%", outcome.cloze_accuracy * 100.0);
            println!("mean step wall    : {:.1} ms", outcome.mean_step_ms);
            println!("coordinator ovhd  : {:.2}%", outcome.coordinator_overhead * 100.0);
            println!("metrics           : {}", path.display());
        }
        "serve" => {
            let n_requests = flags.usize("requests", 256)?;
            let max_batch = flags.usize("max-batch", 8)?;
            let max_wait = Duration::from_secs_f64(flags.f64("max-wait-ms", 2.0)? / 1e3);
            let threads = flags.usize("threads", 0)?;
            let partition = parse_partition(&flags.get("partition", "auto"))?;
            let seed = flags.usize("seed", 0)? as u64;
            let producers = flags.usize("producers", 0)?;
            let batch_policy = BatchPolicy::new(max_batch, max_wait);

            if let Some(dir) = flags.map.get("manifest").map(PathBuf::from) {
                // Manifest-backed path: a checkpointed transformer served
                // through its `forward`/`forward_lora` semantics.  Clamp
                // the policy to the compiled batch up front so every
                // report (inline and admission) shows the effective cap.
                let m = Manifest::load(&dir)?;
                let (vocab, seq) = (m.config.vocab_size, m.config.seq_len);
                let eff_batch = max_batch.min(m.config.batch_size.max(1));
                let batch_policy = BatchPolicy::new(eff_batch, max_wait);
                let policy = ParallelPolicy::for_width(threads, m.config.d_model)
                    .with_partition(partition);
                println!(
                    "== slope serve --manifest {} ({}) — max_batch {eff_batch}, \
                     max_wait {:.1} ms, {} thr, {partition:?} ==",
                    dir.display(),
                    m.config.name,
                    max_wait.as_secs_f64() * 1e3,
                    policy.effective_threads(),
                );
                serve_run(
                    move || {
                        let model = AotModel::open(&dir, policy)?;
                        eprintln!("[serve] {}", model.describe());
                        ServeEngine::with_model(model, batch_policy)
                    },
                    move |rng: &mut Rng| {
                        (0..seq).map(|_| rng.below(vocab) as f32).collect()
                    },
                    n_requests,
                    producers,
                    batch_policy,
                    seed,
                )?;
            } else {
                // Synthetic kernel-stack path: alternating
                // d_model → d_ff → d_model … sparse+LoRA layers.
                let n_layers = flags.usize("layers", 2)?;
                let d_model = flags.usize("d-model", 256)?;
                let d_ff = flags.usize("d-ff", 1024)?;
                let rank = flags.usize("rank", 8)?;
                let policy =
                    ParallelPolicy::for_width(threads, d_model).with_partition(partition);
                println!(
                    "== slope serve: {n_layers} layers ({d_model}↔{d_ff}, 2:4, rank {rank}) — \
                     max_batch {max_batch}, max_wait {:.1} ms, {} thr, {partition:?} ==",
                    max_wait.as_secs_f64() * 1e3,
                    policy.effective_threads(),
                );
                serve_run(
                    move || {
                        use slope::sparsity::{random_row_mask, NmScheme};
                        use slope::tensor::Matrix;
                        let mut rng = Rng::seed_from_u64(seed);
                        let mut layers = Vec::with_capacity(n_layers.max(1));
                        let mut d_in = d_model;
                        for i in 0..n_layers.max(1) {
                            let d_out = if i % 2 == 0 { d_ff } else { d_model };
                            let w = Matrix::randn(d_out, d_in, 1.0 / (d_in as f32).sqrt(),
                                                  &mut rng);
                            let mask =
                                random_row_mask(d_out, d_in, NmScheme::TWO_FOUR, &mut rng);
                            let be = slope::backend::SparseBackend::setup(
                                &w, mask, NmScheme::TWO_FOUR,
                                slope::backend::SpmmAlgo::RowMajor, policy,
                            );
                            let lora = (rank > 0).then(|| LoraAdapter {
                                up: Matrix::randn(d_out, rank, 0.1, &mut rng),
                                down: Matrix::randn(rank, d_in, 0.1, &mut rng),
                            });
                            layers.push(ServeLayer::new(be, lora)?);
                            d_in = d_out;
                        }
                        ServeEngine::new(layers, batch_policy)
                    },
                    move |rng: &mut Rng| {
                        (0..d_model).map(|_| rng.normal() as f32 * 0.5).collect()
                    },
                    n_requests,
                    producers,
                    batch_policy,
                    seed,
                )?;
            }
        }
        "exp" => {
            let id = flags
                .positional
                .first()
                .ok_or_else(|| slope::eyre!("exp needs an id\n{USAGE}"))?;
            let args = ExpArgs {
                artifacts,
                out_dir,
                steps: flags.usize("steps", 120)?,
                seed: flags.usize("seed", 0)? as u64,
            };
            exps::run(id, &args)?;
        }
        "info" => {
            let model = flags.get("model", "gpt-nano");
            let m = Manifest::load(&artifacts.join(&model))?;
            let c = &m.config;
            println!("config {}: d={} L={} heads={} ffn={} seq={} batch={} vocab={} rank={}",
                     c.name, c.d_model, c.n_layer, c.n_head, c.d_ff, c.seq_len,
                     c.batch_size, c.vocab_size, c.adapter_rank);
            println!("~{:.2}M dense params; sparsity {}:{} / {}:{}; prune attn={} mlp={}",
                     c.n_params_dense as f64 / 1e6,
                     c.first_half_sparsity.0, c.first_half_sparsity.1,
                     c.second_half_sparsity.0, c.second_half_sparsity.1,
                     c.prune_attn, c.prune_mlp);
            let mut names: Vec<_> = m.executables.keys().collect();
            names.sort();
            for n in names {
                let e = &m.executables[n];
                println!("  {n:<36} {} in / {} out", e.inputs.len(), e.outputs.len());
            }
        }
        "list" => {
            let index = Json::parse(&std::fs::read_to_string(artifacts.join("index.json"))?)?;
            for (name, info) in index.as_obj().into_iter().flatten() {
                let sets: Vec<&str> = info
                    .get("sets")
                    .and_then(|s| s.as_arr())
                    .map(|a| a.iter().filter_map(|x| x.as_str()).collect())
                    .unwrap_or_default();
                println!("{name:<22} {}", sets.join(","));
            }
        }
        "--help" | "-h" | "help" => print!("{USAGE}"),
        other => {
            print!("{USAGE}");
            return Err(slope::eyre!("unknown command {other:?}"));
        }
    }
    Ok(())
}
