//! Evaluation: validation perplexity and the cloze probe (the downstream
//! stand-in for the paper's zero-shot tasks — DESIGN.md §2).

/// Aggregate mean NLL values into perplexity.
pub fn perplexity(nlls: &[f32]) -> f64 {
    if nlls.is_empty() {
        return f64::NAN;
    }
    let mean = nlls.iter().map(|v| *v as f64).sum::<f64>() / nlls.len() as f64;
    mean.exp()
}

/// Score a cloze batch from full logits.
///
/// `logits`: flattened (B, S, V); the probe answer for row `i` is scored at
/// the final position (S-1).  Returns (top-1 accuracy, mean answer rank).
pub fn cloze_score(logits: &[f32], b: usize, s: usize, v: usize, answers: &[i32]) -> (f64, f64) {
    assert_eq!(logits.len(), b * s * v);
    assert_eq!(answers.len(), b);
    let mut correct = 0usize;
    let mut rank_sum = 0.0;
    for row in 0..b {
        let off = row * s * v + (s - 1) * v;
        let last = &logits[off..off + v];
        let ans = answers[row] as usize;
        let ans_score = last[ans];
        let mut better = 0usize;
        let mut best = 0usize;
        for (tok, &sc) in last.iter().enumerate() {
            if sc > ans_score {
                better += 1;
            }
            if sc > last[best] {
                best = tok;
            }
        }
        if best == ans {
            correct += 1;
        }
        rank_sum += (better + 1) as f64;
    }
    (correct as f64 / b as f64, rank_sum / b as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perplexity_of_uniform_nll() {
        let nll = (8f32).ln();
        assert!((perplexity(&[nll, nll]) - 8.0).abs() < 1e-4);
    }

    #[test]
    fn cloze_scores_argmax_at_final_position() {
        // b=2, s=2, v=3. Row 0 answer 1 (correct), row 1 answer 0 (rank 2).
        let mut logits = vec![0.0f32; 2 * 2 * 3];
        logits[3 + 1] = 5.0; // row0 pos1 tok1 best
        logits[6 + 3 + 2] = 5.0; // row1 pos1 tok2 best
        logits[6 + 3] = 1.0; // row1 answer tok0 second
        let (acc, rank) = cloze_score(&logits, 2, 2, 3, &[1, 0]);
        assert!((acc - 0.5).abs() < 1e-9);
        assert!((rank - 1.5).abs() < 1e-9);
    }
}
