//! [`ServeModel`] — the one serving contract every engine backend
//! implements, and its two production implementations.
//!
//! The redesign splits *what* is served from *how* requests are admitted:
//! [`crate::serve::ServeEngine`] owns admission (batcher, stats, staging)
//! and is generic over a `ServeModel`, which owns the math:
//!
//! * [`KernelStackModel`] — a stack of warm [`ServeLayer`]s (compressed
//!   2:4 weight + optional fused LoRA adapter each) driven directly on
//!   the CPU kernel engine, ping-ponging activations through two reusable
//!   buffers.  The synthetic-stack path `slope serve` always had.
//! * [`AotModel`] — a checkpointed transformer behind a manifest: opens
//!   the artifact's cached [`Session`], restores the serving checkpoint
//!   (packed v2 planes included), and runs the manifest's
//!   `forward`/`forward_lora` per coalesced batch — through PJRT when the
//!   executables compile, else through the in-process
//!   [`HostModel`] kernel executor (the offline path, bit-for-bit the
//!   same checkpoint).  Requests are token sequences (`d_in = seq_len`,
//!   each feature an integral token id); responses are the last
//!   position's next-token logits (`d_out = vocab`), copied out through a
//!   reusable staging buffer.
//!
//! Both implementations are **row-independent**: a request's output does
//! not depend on which batch it was coalesced into — the invariant that
//! makes dynamic batching and the async admission front-end
//! ([`crate::serve::admission`]) transparent to clients.
//!
//! **Autoregressive decode** adds a second contract, [`DecodeModel`]
//! (`prefill` / `decode_step` / `free_seq` over [`SeqId`] handles, plus
//! the greedy/temperature [`Sampler`] hook), for the continuous-batching
//! scheduler [`crate::serve::DecodeEngine`].  [`AotModel`] implements it
//! over the host executor's per-sequence [`KvCache`] — incremental steps
//! bit-identical to full recompute — with a padded full-recompute replay
//! keeping the PJRT route functional; [`KernelDecodeModel`] is the
//! synthetic kernel-stack analog (a recurrent 2:4 stack) for tests and
//! the no-checkpoint CLI path.  The decode invariant is
//! **sequence-independence**: a sequence's stream never depends on its
//! batch-mates, so sequences joining/leaving mid-stream are invisible in
//! the generated text.

use crate::backend::{ensure_out, gemm_nt_into, lora_fused_seq, ParallelPolicy, SparseBackend,
                     SpmmAlgo};
use crate::coordinator::checkpoint;
use crate::runtime::{HostModel, KvCache, KvPoolConfig, KvPoolStats, Manifest,
                     PrefixCacheStats, Session,
                     SessionHandle};
use crate::sparsity::{random_row_mask, NmScheme};
use crate::tensor::Matrix;
use crate::util::Rng;
use std::path::Path;

/// A model the serving engine can drive: a pure coalesced-batch function
/// plus the shape and policy metadata admission needs.
pub trait ServeModel {
    /// Features per request row.
    fn d_in(&self) -> usize;

    /// Features per response row.
    fn d_out(&self) -> usize;

    /// Run one coalesced batch: `x` is `(k, d_in)` (one request per row),
    /// `y` is resized to `(k, d_out)` and overwritten.  Must be
    /// row-independent and allocation-free at a warm fill.
    fn forward_batch_into(&mut self, x: &Matrix, y: &mut Matrix) -> crate::Result<()>;

    /// Hard cap on the coalesced batch, when the backend has one (an AOT
    /// executable is compiled for a fixed batch).  The engine clamps its
    /// `BatchPolicy.max_batch` to this.
    fn max_batch(&self) -> Option<usize> {
        None
    }

    /// Validate one request's payload beyond its length (the engine
    /// checks length).  Called at submit time, so a malformed request is
    /// rejected individually instead of poisoning the coalesced batch it
    /// would have landed in.
    fn validate_request(&self, _input: &[f32]) -> crate::Result<()> {
        Ok(())
    }

    /// One-line description for stats headers and the CLI.
    fn describe(&self) -> String;
}

/// A LoRA adapter pair for one layer (Eq. 11): `up = L: (d_out, r)`,
/// `down = R: (r, d_in)`.
#[derive(Clone, Debug)]
pub struct LoraAdapter {
    pub up: Matrix,
    pub down: Matrix,
}

/// One serving layer: a warm sparse weight and an optional adapter.
pub struct ServeLayer {
    pub backend: SparseBackend,
    pub lora: Option<LoraAdapter>,
    /// Rank staging for the fused LoRA path (grown once).
    t: Matrix,
}

impl ServeLayer {
    pub fn new(backend: SparseBackend, lora: Option<LoraAdapter>) -> crate::Result<Self> {
        if let Some(l) = &lora {
            crate::ensure!(
                l.up.rows == backend.w.rows && l.down.cols == backend.w.cols
                    && l.up.cols == l.down.rows,
                "lora shapes (up {}x{}, down {}x{}) do not fit layer {}x{}",
                l.up.rows, l.up.cols, l.down.rows, l.down.cols,
                backend.w.rows, backend.w.cols
            );
        }
        Ok(Self { backend, lora, t: Matrix::zeros(0, 0) })
    }

    pub fn d_in(&self) -> usize {
        self.backend.w.cols
    }

    pub fn d_out(&self) -> usize {
        self.backend.w.rows
    }

    /// `y = x · Wᵀ (+ x · Rᵀ · Lᵀ)` into a caller-owned output — the
    /// Eq.-11 fused serving sequence ([`lora_fused_seq`], shared with the
    /// backend workspace path) through reusable buffers.
    fn forward_into(&mut self, x: &Matrix, y: &mut Matrix) {
        match &self.lora {
            Some(l) => lora_fused_seq(self.backend.algo, &self.backend.policy, &self.backend.w,
                                      x, &l.up, &l.down, &mut self.t, y),
            None => self.backend.forward_into(x, y),
        }
    }
}

/// The warm kernel-stack backend: a validated chain of [`ServeLayer`]s
/// plus the ping-pong activation buffers between them (module docs).
pub struct KernelStackModel {
    layers: Vec<ServeLayer>,
    /// Ping-pong activation buffers between layers (grown once).
    bufs: [Matrix; 2],
}

impl KernelStackModel {
    /// Validate the chain (each layer's `d_in` must equal the previous
    /// layer's `d_out`) and take ownership of the stack.
    pub fn new(layers: Vec<ServeLayer>) -> crate::Result<Self> {
        crate::ensure!(!layers.is_empty(), "kernel-stack model needs at least one layer");
        for pair in layers.windows(2) {
            crate::ensure!(
                pair[1].d_in() == pair[0].d_out(),
                "layer dims do not chain: {} -> {}",
                pair[0].d_out(),
                pair[1].d_in()
            );
        }
        Ok(Self { layers, bufs: [Matrix::zeros(0, 0), Matrix::zeros(0, 0)] })
    }

    pub fn layers(&self) -> &[ServeLayer] {
        &self.layers
    }

    /// Pointer to the first ping-pong buffer's storage — the test hook
    /// pinning "steady state performs no reallocation".
    #[cfg(test)]
    pub(crate) fn buf_ptr(&self) -> *const f32 {
        self.bufs[0].data.as_ptr()
    }
}

impl ServeModel for KernelStackModel {
    fn d_in(&self) -> usize {
        self.layers[0].d_in()
    }

    fn d_out(&self) -> usize {
        self.layers[self.layers.len() - 1].d_out()
    }

    fn forward_batch_into(&mut self, x: &Matrix, y: &mut Matrix) -> crate::Result<()> {
        crate::ensure!(x.cols == self.d_in(), "batch dim {} != d_in {}", x.cols, self.d_in());
        ensure_out(y, x.rows, self.d_out());
        let n = self.layers.len();
        // Ping-pong through the stack: layer i reads bufs[(i−1) % 2] (or
        // the staging input for i == 0) and writes bufs[i % 2], except the
        // final layer, which writes straight into the caller's output.
        for i in 0..n {
            let last = i + 1 == n;
            let [b0, b1] = &mut self.bufs;
            let (src, dst): (&Matrix, &mut Matrix) = match (i == 0, i % 2 == 0, last) {
                (true, _, true) => (x, &mut *y),
                (true, _, false) => (x, &mut *b0),
                (false, true, true) => (&*b1, &mut *y),
                (false, true, false) => (&*b1, &mut *b0),
                (false, false, true) => (&*b0, &mut *y),
                (false, false, false) => (&*b0, &mut *b1),
            };
            self.layers[i].forward_into(src, dst);
        }
        Ok(())
    }

    fn describe(&self) -> String {
        let lora = self.layers.iter().filter(|l| l.lora.is_some()).count();
        format!(
            "kernel-stack: {} layers ({} -> {}), {} with LoRA, {} {} thread(s), simd {}",
            self.layers.len(),
            self.d_in(),
            self.d_out(),
            lora,
            self.layers[0].backend.scheme,
            self.layers[0].backend.policy.effective_threads(),
            crate::backend::simd_level()
        )
    }
}

/// Execution route an [`AotModel`] resolved to at open time.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AotPath {
    /// The manifest's executable compiled: batches run via `Session::run`.
    Pjrt,
    /// PJRT unavailable (offline stub / checkpoint dir without HLO):
    /// batches run on the in-process [`HostModel`] kernel executor.
    HostKernels,
}

/// A checkpointed transformer served through its manifest (module docs).
///
/// Open with a directory holding `manifest.json` plus a serving
/// checkpoint ([`checkpoint::save_model_checkpoint`]'s layout — what
/// `slope train --checkpoint-dir` writes).
pub struct AotModel {
    manifest: Manifest,
    session: SessionHandle,
    /// The checkpoint store feeding `Session::run` — `None` on the
    /// host-kernel route, which copies what it needs into [`HostModel`]
    /// at open (keeping both would double resident model memory).
    store: Option<crate::runtime::Store>,
    host: Option<HostModel>,
    exe: String,
    path: AotPath,
    packed_restored: usize,
    /// Reusable decoded-token staging for each batch.
    tokens: Vec<i32>,
    /// Reusable logits copy-out staging (PJRT path).
    logits: Vec<f32>,
    /// Live decode sequences (KV caches on the host route, token
    /// histories on the PJRT route) behind slot-reusing handles.
    seqs: SeqSlab<SeqState>,
    /// Scratch for batched host-route decode: caches are taken out of
    /// the slab for the step and returned afterwards (reused buffer, no
    /// steady-state allocation).
    dec_caches: Vec<KvCache>,
    /// Recycled host-route caches: `free_seq` parks them here and
    /// `prefill` reuses them (`prefill_into` resets), so steady-state
    /// traffic allocates no KV planes once the pool is warm.
    cache_pool: Vec<KvCache>,
    /// Prompt positions the prefix cache served in the most recent
    /// successful `prefill` (see `DecodeModel::last_prefill_tokens_saved`).
    last_prefill_saved: usize,
}

/// Per-sequence decode state (see [`DecodeModel`] impl on [`AotModel`]).
enum SeqState {
    /// Host-kernel route: the KV planes incremental decode attends over.
    Host(KvCache),
    /// PJRT route: the token history replayed (right-padded) through the
    /// compiled full forward each step.
    Pjrt(Vec<i32>),
}

impl AotModel {
    /// Open `dir` (manifest + serving checkpoint), probe the PJRT path
    /// once, and fall back to the host kernel executor when the probe
    /// fails.  `policy` governs the host executor's kernel calls and is
    /// recorded on the session (`Session::set_parallel`).  The KV pool
    /// honors the `SLOPE_KV_DTYPE` / `SLOPE_KV_BLOCK` environment
    /// overrides; use [`AotModel::open_with_kv`] for explicit control.
    pub fn open(dir: &Path, policy: ParallelPolicy) -> crate::Result<Self> {
        Self::open_with_kv(dir, policy, KvPoolConfig::from_env())
    }

    /// [`AotModel::open`] with an explicit KV-pool configuration (block
    /// size, plane dtype, optional block cap) for the host decode route.
    pub fn open_with_kv(dir: &Path, policy: ParallelPolicy,
                        kv: KvPoolConfig) -> crate::Result<Self> {
        let session = Session::open_cached(dir)?;
        session.borrow_mut().set_parallel(policy);
        let manifest = session.borrow().manifest.clone();
        let (store, packed) = checkpoint::load_model_checkpoint(dir)?;
        let has_lora = store.names().iter().any(|n| n.starts_with("lora."));
        // Refuse a checkpoint whose adapters the PJRT route could not
        // honor: silently serving base-only weights there while the host
        // executor applied the adapters would make the two routes compute
        // different functions from the same checkpoint.
        crate::ensure!(
            !has_lora || manifest.executables.contains_key("forward_lora"),
            "checkpoint carries lora.* adapters but manifest {} has no forward_lora executable",
            manifest.config.name
        );
        let exe = if has_lora { "forward_lora" } else { "forward" };
        crate::ensure!(
            manifest.executables.contains_key(exe),
            "manifest {} has no inference executable",
            manifest.config.name
        );
        // One-time probe: a host-route session (no HLO beside the
        // checkpoint) or a compile failure (offline xla stub) routes
        // every batch to the serving-tuned host executor.
        let probe: Result<(), String> = {
            let mut sess = session.borrow_mut();
            match sess.executor_kind() {
                crate::runtime::ExecutorKind::HostKernels => {
                    Err("session resolved to the host route (no HLO artifacts)".into())
                }
                crate::runtime::ExecutorKind::Pjrt => {
                    sess.prepare(exe).map_err(|e| e.to_string())
                }
            }
        };
        let packed_restored = packed.len();
        let (host, store, path) = match probe {
            Ok(()) => (None, Some(store), AotPath::Pjrt),
            Err(why) => {
                eprintln!(
                    "[serve] PJRT unavailable for {} ({why}); using the host kernel executor",
                    dir.display()
                );
                let hm = HostModel::from_store_with_kv(&manifest, &store, &packed, policy, kv)?;
                // The host executor owns its operand copies; drop the
                // checkpoint store rather than keeping the model resident
                // twice.
                (Some(hm), None, AotPath::HostKernels)
            }
        };
        Ok(Self {
            manifest,
            session,
            store,
            host,
            exe: exe.to_string(),
            path,
            packed_restored,
            tokens: Vec::new(),
            logits: Vec::new(),
            seqs: SeqSlab::new(),
            dec_caches: Vec::new(),
            cache_pool: Vec::new(),
            last_prefill_saved: 0,
        })
    }

    /// Which execution route `open` resolved to.
    pub fn path(&self) -> AotPath {
        self.path
    }

    /// Packed v2 planes the restore consumed without re-compression.
    pub fn packed_restored(&self) -> usize {
        self.packed_restored
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Encode a token sequence as an engine request row (`f32` ids).
    pub fn encode_tokens(tokens: &[i32]) -> Vec<f32> {
        tokens.iter().map(|t| *t as f32).collect()
    }

    /// PJRT route: pad the coalesced tokens to the compiled batch, run
    /// the executable, and copy the last position's logits out of the
    /// result through the reusable staging buffer.
    fn forward_pjrt(&mut self, k: usize, y: &mut Matrix) -> crate::Result<()> {
        let (bb, s) = self.manifest.forward_tokens_shape();
        let vocab = self.manifest.config.vocab_size;
        crate::ensure!(k <= bb, "batch {k} exceeds the compiled batch size {bb}");
        self.tokens.resize(bb * s, 0);
        let store = self
            .store
            .as_mut()
            .ok_or_else(|| crate::eyre!("PJRT route has no checkpoint store"))?;
        store.put_i32("tokens", &[bb, s], &self.tokens)?;
        self.session.borrow_mut().run(&self.exe, store)?;
        store.read_f32_into("logits", &mut self.logits)?;
        crate::ensure!(
            self.logits.len() == bb * s * vocab,
            "logits are {} long, expected {}x{}x{}",
            self.logits.len(), bb, s, vocab
        );
        for r in 0..k {
            let off = (r * s + (s - 1)) * vocab;
            y.row_mut(r).copy_from_slice(&self.logits[off..off + vocab]);
        }
        Ok(())
    }

    /// PJRT decode route: stage each token history right-padded into the
    /// compiled `(B, S)` token buffer, run the forward executable once,
    /// and copy row `i`'s logits at its last *real* position.  Correct —
    /// causal attention means padding positions never influence an
    /// earlier position's logits — but O(S) per generated token; the
    /// KV-cached host route is the fast path, this keeps real-XLA builds
    /// functional for `slope generate`.
    fn pjrt_hist_logits(&mut self, hists: &[Vec<i32>], y: &mut Matrix) -> crate::Result<()> {
        let (bb, s) = self.manifest.forward_tokens_shape();
        let vocab = self.manifest.config.vocab_size;
        let k = hists.len();
        crate::ensure!(k <= bb, "decode batch {k} exceeds the compiled batch size {bb}");
        self.tokens.clear();
        self.tokens.resize(bb * s, 0);
        for (r, hist) in hists.iter().enumerate() {
            crate::ensure!(
                !hist.is_empty() && hist.len() <= s,
                "history of {} tokens outside 1..={s}",
                hist.len()
            );
            self.tokens[r * s..r * s + hist.len()].copy_from_slice(hist);
        }
        let store = self
            .store
            .as_mut()
            .ok_or_else(|| crate::eyre!("PJRT route has no checkpoint store"))?;
        store.put_i32("tokens", &[bb, s], &self.tokens)?;
        self.session.borrow_mut().run(&self.exe, store)?;
        store.read_f32_into("logits", &mut self.logits)?;
        crate::ensure!(
            self.logits.len() == bb * s * vocab,
            "logits are {} long, expected {}x{}x{}",
            self.logits.len(), bb, s, vocab
        );
        ensure_out(y, k, vocab);
        for (r, hist) in hists.iter().enumerate() {
            let off = (r * s + (hist.len() - 1)) * vocab;
            y.row_mut(r).copy_from_slice(&self.logits[off..off + vocab]);
        }
        Ok(())
    }
}

impl ServeModel for AotModel {
    fn d_in(&self) -> usize {
        self.manifest.config.seq_len
    }

    fn d_out(&self) -> usize {
        self.manifest.config.vocab_size
    }

    fn forward_batch_into(&mut self, x: &Matrix, y: &mut Matrix) -> crate::Result<()> {
        let (k, s) = (x.rows, self.manifest.config.seq_len);
        let vocab = self.manifest.config.vocab_size;
        crate::ensure!(x.cols == s, "request carries {} features, seq_len is {s}", x.cols);
        self.tokens.clear();
        for r in 0..k {
            for &v in x.row(r) {
                let t = v.round() as i64;
                crate::ensure!(
                    v.is_finite() && t >= 0 && (t as usize) < vocab,
                    "token id {v} outside vocab 0..{vocab}"
                );
                self.tokens.push(t as i32);
            }
        }
        ensure_out(y, k, vocab);
        if let Some(hm) = self.host.as_mut() {
            return hm.forward_last_logits_into(&self.tokens, k, y);
        }
        self.forward_pjrt(k, y)
    }

    fn max_batch(&self) -> Option<usize> {
        // The PJRT executable is compiled for the manifest's batch; the
        // host executor keeps the same cap so both routes batch alike.
        Some(self.manifest.config.batch_size)
    }

    /// Reject malformed token payloads at submit, before they can
    /// coalesce with (and fail alongside) well-formed requests: every
    /// feature must be a finite, integral id inside the vocab (NaN would
    /// otherwise saturate to token 0 and be served silently).
    fn validate_request(&self, input: &[f32]) -> crate::Result<()> {
        let vocab = self.manifest.config.vocab_size;
        for &v in input {
            crate::ensure!(
                v.is_finite() && v == v.round(),
                "token id {v} is not an integral id"
            );
            let t = v as i64;
            crate::ensure!(
                t >= 0 && (t as usize) < vocab,
                "token id {v} outside vocab 0..{vocab}"
            );
        }
        Ok(())
    }

    fn describe(&self) -> String {
        let c = &self.manifest.config;
        format!(
            "aot:{} ({}L d{} ff{} heads{} vocab{} seq{}; {}, {} packed planes)",
            c.name, c.n_layer, c.d_model, c.d_ff, c.n_head, c.vocab_size, c.seq_len,
            match self.path {
                AotPath::Pjrt => "pjrt".to_string(),
                AotPath::HostKernels => format!(
                    "host kernels (simd {}), {} thread(s)",
                    crate::backend::simd_level(),
                    self.host.as_ref().map(|h| h.policy().effective_threads()).unwrap_or(1)
                ),
            },
            self.packed_restored
        )
    }
}

// ---- autoregressive decode surface ------------------------------------

/// Handle to a live decode sequence on a [`DecodeModel`].  Handles are
/// reused after [`DecodeModel::free_seq`], so holding a stale one is a
/// scheduler bug (the slab rejects unknown ids, not recycled ones).
pub type SeqId = u64;

/// Slot-reusing table of live per-sequence state — bounded by the peak
/// concurrent sequence count, not by how many sequences ever ran.
struct SeqSlab<T> {
    slots: Vec<Option<T>>,
    free: Vec<usize>,
}

impl<T> SeqSlab<T> {
    fn new() -> Self {
        Self { slots: Vec::new(), free: Vec::new() }
    }

    fn insert(&mut self, v: T) -> SeqId {
        match self.free.pop() {
            Some(i) => {
                debug_assert!(self.slots[i].is_none());
                self.slots[i] = Some(v);
                i as SeqId
            }
            None => {
                self.slots.push(Some(v));
                (self.slots.len() - 1) as SeqId
            }
        }
    }

    fn get(&self, id: SeqId) -> Option<&T> {
        self.slots.get(id as usize).and_then(|s| s.as_ref())
    }

    fn get_mut(&mut self, id: SeqId) -> Option<&mut T> {
        self.slots.get_mut(id as usize).and_then(|s| s.as_mut())
    }

    /// Take the state out for a batched step; it must be [`SeqSlab::put`]
    /// back.  A second take of the same id (a duplicate in the batch)
    /// fails here, before any state is touched.
    fn take(&mut self, id: SeqId) -> crate::Result<T> {
        self.slots
            .get_mut(id as usize)
            .and_then(|s| s.take())
            .ok_or_else(|| crate::eyre!("unknown or duplicate sequence handle {id}"))
    }

    fn put(&mut self, id: SeqId, v: T) {
        self.slots[id as usize] = Some(v);
    }

    fn remove(&mut self, id: SeqId) -> crate::Result<T> {
        let v = self.take(id)?;
        self.free.push(id as usize);
        Ok(v)
    }

    /// Live sequences (slots currently occupied).
    fn live(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }
}

/// Token-selection rule applied to one logits row — the generation
/// loop's sampling hook.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Sampler {
    /// Deterministic argmax (first index wins ties).
    Greedy,
    /// Softmax sampling at the given temperature (`<= 0` degenerates to
    /// greedy).  Draws come from the caller's per-sequence RNG, so a
    /// sequence's token stream never depends on its batch-mates.
    Temperature(f32),
}

impl Sampler {
    pub fn sample(&self, logits: &[f32], rng: &mut Rng) -> i32 {
        debug_assert!(!logits.is_empty());
        match *self {
            Sampler::Greedy => argmax(logits),
            Sampler::Temperature(t) if t <= 0.0 => argmax(logits),
            Sampler::Temperature(t) => {
                // Max-subtracted softmax CDF walk in f64 (one pass for the
                // denominator, one for the draw) — no allocation.
                let maxv = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                let denom: f64 =
                    logits.iter().map(|l| (((l - maxv) / t) as f64).exp()).sum();
                let target = rng.f64() * denom;
                let mut acc = 0.0f64;
                for (i, l) in logits.iter().enumerate() {
                    acc += (((l - maxv) / t) as f64).exp();
                    if acc >= target {
                        return i as i32;
                    }
                }
                (logits.len() - 1) as i32
            }
        }
    }
}

fn argmax(xs: &[f32]) -> i32 {
    let mut best = 0usize;
    for (i, v) in xs.iter().enumerate() {
        if *v > xs[best] {
            best = i;
        }
    }
    best as i32
}

/// Every id must sit inside `0..vocab` — the one token-range check both
/// decode backends share (prompt validation and per-step tokens alike).
fn check_token_ids(tokens: &[i32], vocab: usize) -> crate::Result<()> {
    for &t in tokens {
        crate::ensure!(
            t >= 0 && (t as usize) < vocab,
            "token id {t} outside vocab 0..{vocab}"
        );
    }
    Ok(())
}

/// A model that generates autoregressively: per-sequence state behind
/// opaque handles, a prompt prefill, and a coalesced single-token decode
/// step — the per-token hot path the continuous-batching scheduler
/// ([`crate::serve::DecodeEngine`]) drives.
///
/// Implementations must be **sequence-independent**: a sequence's logits
/// depend only on its own tokens, never on which batch-mates shared its
/// decode step — the invariant that makes continuous batching (sequences
/// joining and leaving mid-stream) invisible in the generated text,
/// pinned in `tests/decode.rs`.
pub trait DecodeModel {
    /// Vocabulary size (logits row width).
    fn vocab(&self) -> usize;

    /// Hard context bound: prompt + generated tokens per sequence.
    fn max_seq_len(&self) -> usize;

    /// Cap on sequences per coalesced decode step, when the backend has
    /// one (the AOT route is compiled for a fixed batch).
    fn max_decode_batch(&self) -> Option<usize> {
        None
    }

    /// Validate a prompt at submit time (so a malformed request is
    /// rejected individually, never failing a shared decode step).
    fn validate_prompt(&self, prompt: &[i32]) -> crate::Result<()>;

    /// Run the prompt, allocate a sequence, write its last-position
    /// logits (`1 × vocab`) into `logits`, and return the handle.
    fn prefill(&mut self, prompt: &[i32], logits: &mut Matrix) -> crate::Result<SeqId>;

    /// One decode step for a coalesced batch: sequence `seqs[i]` consumes
    /// `tokens[i]`; `logits` is resized to `(k, vocab)` with row `i`
    /// holding sequence `i`'s next-token logits.  Handles must be
    /// distinct.  On error no sequence's state has advanced.
    fn decode_step(&mut self, seqs: &[SeqId], tokens: &[i32],
                   logits: &mut Matrix) -> crate::Result<()>;

    /// Release a sequence's state (its handle may be reused).
    fn free_seq(&mut self, seq: SeqId) -> crate::Result<()>;

    /// Tokens currently held by a live sequence (prompt + decoded), or
    /// `None` for a freed/unknown handle.
    fn seq_tokens(&self, seq: SeqId) -> Option<usize>;

    /// Live (prefilled, not yet freed) sequences.
    fn live_seqs(&self) -> usize;

    /// KV block-pool occupancy, when the backend pages its caches
    /// (`None` for poolless backends like the recurrent kernel stack).
    fn kv_pool_stats(&self) -> Option<KvPoolStats> {
        None
    }

    /// Prefix-cache counters, when the backend's pool carries a prefix
    /// cache (`None` otherwise — the gate on the `ServeStats` line).
    fn prefix_cache_stats(&self) -> Option<PrefixCacheStats> {
        None
    }

    /// Prompt positions the backend's prefix cache served (instead of
    /// recomputing) in the most recent successful `prefill` — 0 for
    /// cacheless backends.
    fn last_prefill_tokens_saved(&self) -> usize {
        0
    }

    /// One-line description for stats headers and the CLI.
    fn describe_decode(&self) -> String;
}

impl AotModel {
    /// Restore host-route caches taken for a failed batched step.
    fn restore_taken(&mut self, seqs: &[SeqId]) {
        for (j, c) in self.dec_caches.drain(..).enumerate() {
            self.seqs.put(seqs[j], SeqState::Host(c));
        }
    }
}

impl DecodeModel for AotModel {
    fn vocab(&self) -> usize {
        self.manifest.config.vocab_size
    }

    fn max_seq_len(&self) -> usize {
        self.manifest.config.seq_len
    }

    fn max_decode_batch(&self) -> Option<usize> {
        // The PJRT replay is compiled for the manifest's batch; the host
        // route keeps the same cap so both routes schedule alike.
        Some(self.manifest.config.batch_size)
    }

    fn validate_prompt(&self, prompt: &[i32]) -> crate::Result<()> {
        let (vocab, s) = (self.manifest.config.vocab_size, self.manifest.config.seq_len);
        crate::ensure!(!prompt.is_empty(), "empty prompt");
        crate::ensure!(
            prompt.len() <= s,
            "prompt of {} tokens exceeds the {s}-token context",
            prompt.len()
        );
        check_token_ids(prompt, vocab)
    }

    fn prefill(&mut self, prompt: &[i32], logits: &mut Matrix) -> crate::Result<SeqId> {
        self.validate_prompt(prompt)?;
        if let Some(hm) = self.host.as_mut() {
            let mut cache =
                self.cache_pool.pop().unwrap_or_else(|| hm.new_kv_cache());
            match hm.prefill_into_saved(prompt, &mut cache, logits) {
                Ok(saved) => self.last_prefill_saved = saved,
                Err(e) => {
                    // The failed prefill left the cache empty (shared
                    // prefix references released) — safe to recycle.
                    self.cache_pool.push(cache);
                    return Err(e);
                }
            }
            return Ok(self.seqs.insert(SeqState::Host(cache)));
        }
        self.last_prefill_saved = 0;
        let hists = vec![prompt.to_vec()];
        self.pjrt_hist_logits(&hists, logits)?;
        let hist = hists.into_iter().next().expect("one history");
        Ok(self.seqs.insert(SeqState::Pjrt(hist)))
    }

    fn decode_step(&mut self, seqs: &[SeqId], tokens: &[i32],
                   logits: &mut Matrix) -> crate::Result<()> {
        let k = seqs.len();
        crate::ensure!(k > 0, "empty decode batch");
        crate::ensure!(tokens.len() == k, "{} tokens for {k} sequences", tokens.len());
        check_token_ids(tokens, self.manifest.config.vocab_size)?;
        if self.host.is_some() {
            self.dec_caches.clear();
            for &id in seqs {
                match self.seqs.take(id) {
                    Ok(SeqState::Host(c)) => self.dec_caches.push(c),
                    Ok(other) => {
                        self.seqs.put(id, other);
                        self.restore_taken(seqs);
                        return Err(crate::eyre!(
                            "sequence {id} is not a host-route sequence"
                        ));
                    }
                    Err(e) => {
                        self.restore_taken(seqs);
                        return Err(e);
                    }
                }
            }
            let hm = self.host.as_mut().expect("host route");
            let r = hm.decode_step_into(tokens, &mut self.dec_caches, logits);
            self.restore_taken(seqs);
            return r;
        }
        // PJRT route: append each sequence's token and replay the padded
        // full forward (see `pjrt_hist_logits`).
        let mut hists: Vec<Vec<i32>> = Vec::with_capacity(k);
        for &id in seqs {
            match self.seqs.take(id) {
                Ok(SeqState::Pjrt(h)) => hists.push(h),
                Ok(other) => {
                    self.seqs.put(id, other);
                    for (j, h) in hists.drain(..).enumerate() {
                        self.seqs.put(seqs[j], SeqState::Pjrt(h));
                    }
                    return Err(crate::eyre!("sequence {id} is not a PJRT-route sequence"));
                }
                Err(e) => {
                    for (j, h) in hists.drain(..).enumerate() {
                        self.seqs.put(seqs[j], SeqState::Pjrt(h));
                    }
                    return Err(e);
                }
            }
        }
        let s = self.manifest.config.seq_len;
        let mut r: crate::Result<()> = Ok(());
        for h in hists.iter() {
            if h.len() >= s {
                r = Err(crate::eyre!("sequence context window full ({s} tokens)"));
                break;
            }
        }
        if r.is_ok() {
            for (h, &t) in hists.iter_mut().zip(tokens) {
                h.push(t);
            }
            r = self.pjrt_hist_logits(&hists, logits);
            if r.is_err() {
                // A failed replay must not advance any sequence.
                for h in hists.iter_mut() {
                    h.pop();
                }
            }
        }
        for (j, h) in hists.into_iter().enumerate() {
            self.seqs.put(seqs[j], SeqState::Pjrt(h));
        }
        r
    }

    fn free_seq(&mut self, seq: SeqId) -> crate::Result<()> {
        if let SeqState::Host(mut cache) = self.seqs.remove(seq)? {
            // Return the blocks to the shared pool *now* (a parked cache
            // must not pin KV memory), then recycle the empty view for
            // the next prefill.
            cache.reset();
            self.cache_pool.push(cache);
        }
        Ok(())
    }

    fn seq_tokens(&self, seq: SeqId) -> Option<usize> {
        self.seqs.get(seq).map(|st| match st {
            SeqState::Host(c) => c.len(),
            SeqState::Pjrt(h) => h.len(),
        })
    }

    fn live_seqs(&self) -> usize {
        self.seqs.live()
    }

    fn kv_pool_stats(&self) -> Option<KvPoolStats> {
        self.host.as_ref().map(|hm| hm.kv_pool().stats())
    }

    fn prefix_cache_stats(&self) -> Option<PrefixCacheStats> {
        self.host.as_ref().and_then(|hm| hm.kv_pool().prefix_stats())
    }

    fn last_prefill_tokens_saved(&self) -> usize {
        self.last_prefill_saved
    }

    fn describe_decode(&self) -> String {
        format!(
            "{} — decode: {}",
            ServeModel::describe(self),
            match (self.path, self.host.as_ref()) {
                (AotPath::HostKernels, Some(hm)) => format!(
                    "KV-cached incremental (host kernels; paged {} blocks of {} tokens{})",
                    hm.kv_pool().dtype().label(),
                    hm.kv_pool().block_tokens(),
                    if hm.kv_pool().prefix_enabled() { ", prefix-cached" } else { "" }
                ),
                (AotPath::HostKernels, None) =>
                    "KV-cached incremental (host kernels)".to_string(),
                (AotPath::Pjrt, _) =>
                    "padded full-recompute replay (PJRT, O(S)/token)".to_string(),
            }
        )
    }
}

/// Synthetic kernel-stack decode analog: a recurrent sparse stack over a
/// token embedding.  Per-sequence state is one hidden row `h`; each step
/// computes `h' = tanh(stack(h + emb[token]))` and `logits = h' · embᵀ`,
/// batching live rows through the same warm 2:4 [`ServeLayer`] chain
/// [`KernelStackModel`] serves.  Not a transformer — the test/CLI
/// stand-in that exercises the continuous-batching scheduler and the
/// coalesced kernel hot path without a checkpoint, and (being
/// row-independent) obeys the same join/leave-invariance contract.
pub struct KernelDecodeModel {
    /// Token embedding, `(vocab, d)` — also the tied logits head.
    emb: Matrix,
    stack: KernelStackModel,
    max_seq: usize,
    seqs: SeqSlab<RnnSeq>,
    /// Staged step inputs, `(k, d)` (grown once per fill).
    x: Matrix,
    /// Stack outputs, `(k, d)`.
    hbuf: Matrix,
    policy: ParallelPolicy,
}

struct RnnSeq {
    h: Vec<f32>,
    len: usize,
}

impl KernelDecodeModel {
    /// `emb` is `(vocab, d)`; the stack must chain `d → … → d`.
    pub fn new(layers: Vec<ServeLayer>, emb: Matrix, max_seq: usize) -> crate::Result<Self> {
        let stack = KernelStackModel::new(layers)?;
        crate::ensure!(
            stack.d_in() == emb.cols && stack.d_out() == emb.cols,
            "stack must chain d→d over the embedding width {} (got {}→{})",
            emb.cols,
            stack.d_in(),
            stack.d_out()
        );
        crate::ensure!(max_seq >= 2, "max_seq must admit a prompt and a generated token");
        let policy = stack.layers()[0].backend.policy;
        Ok(Self {
            emb,
            stack,
            max_seq,
            seqs: SeqSlab::new(),
            x: Matrix::zeros(0, 0),
            hbuf: Matrix::zeros(0, 0),
            policy,
        })
    }

    /// Random instance (2:4 layers, optional rank-`rank` LoRA on the
    /// first) — the decode analog of `slope serve`'s synthetic stack.
    /// `d` and `d_ff` must be 2:4-groupable (divisible by 4).
    pub fn synthetic(vocab: usize, d: usize, d_ff: usize, rank: usize, max_seq: usize,
                     policy: ParallelPolicy, seed: u64) -> crate::Result<Self> {
        crate::ensure!(d % 4 == 0 && d_ff % 4 == 0, "dims must be 2:4 groupable");
        let mut rng = Rng::seed_from_u64(seed);
        let mut layers = Vec::new();
        let mut d_in = d;
        for (i, d_out) in [d_ff, d].into_iter().enumerate() {
            let w = Matrix::randn(d_out, d_in, 1.0 / (d_in as f32).sqrt(), &mut rng);
            let mask = random_row_mask(d_out, d_in, NmScheme::TWO_FOUR, &mut rng);
            let be = SparseBackend::setup(&w, mask, NmScheme::TWO_FOUR, SpmmAlgo::RowMajor,
                                          policy);
            let lora = (rank > 0 && i == 0).then(|| LoraAdapter {
                up: Matrix::randn(d_out, rank, 0.1, &mut rng),
                down: Matrix::randn(rank, d_in, 0.1, &mut rng),
            });
            layers.push(ServeLayer::new(be, lora)?);
            d_in = d_out;
        }
        let emb = Matrix::randn(vocab, d, 0.5, &mut rng);
        Self::new(layers, emb, max_seq)
    }

    /// Advance the `k` rows staged in `self.x` through the stack:
    /// `hbuf = tanh(stack(x))`.
    fn advance_rows(&mut self) -> crate::Result<()> {
        self.stack.forward_batch_into(&self.x, &mut self.hbuf)?;
        for v in self.hbuf.data.iter_mut() {
            *v = v.tanh();
        }
        Ok(())
    }
}

impl DecodeModel for KernelDecodeModel {
    fn vocab(&self) -> usize {
        self.emb.rows
    }

    fn max_seq_len(&self) -> usize {
        self.max_seq
    }

    fn validate_prompt(&self, prompt: &[i32]) -> crate::Result<()> {
        crate::ensure!(!prompt.is_empty(), "empty prompt");
        crate::ensure!(
            prompt.len() <= self.max_seq,
            "prompt of {} tokens exceeds the {}-token context",
            prompt.len(),
            self.max_seq
        );
        check_token_ids(prompt, self.emb.rows)
    }

    fn prefill(&mut self, prompt: &[i32], logits: &mut Matrix) -> crate::Result<SeqId> {
        self.validate_prompt(prompt)?;
        let d = self.emb.cols;
        let mut h = vec![0.0f32; d];
        for &t in prompt {
            ensure_out(&mut self.x, 1, d);
            {
                let e = self.emb.row(t as usize);
                let xr = self.x.row_mut(0);
                for j in 0..d {
                    xr[j] = h[j] + e[j];
                }
            }
            self.advance_rows()?;
            h.copy_from_slice(self.hbuf.row(0));
        }
        ensure_out(&mut self.x, 1, d);
        self.x.row_mut(0).copy_from_slice(&h);
        ensure_out(logits, 1, self.emb.rows);
        gemm_nt_into(&self.x, &self.emb, logits, &self.policy);
        Ok(self.seqs.insert(RnnSeq { h, len: prompt.len() }))
    }

    fn decode_step(&mut self, seqs: &[SeqId], tokens: &[i32],
                   logits: &mut Matrix) -> crate::Result<()> {
        let k = seqs.len();
        crate::ensure!(k > 0, "empty decode batch");
        crate::ensure!(tokens.len() == k, "{} tokens for {k} sequences", tokens.len());
        let (d, vocab) = (self.emb.cols, self.emb.rows);
        check_token_ids(tokens, vocab)?;
        // Validate and stage every row before mutating any sequence.
        ensure_out(&mut self.x, k, d);
        for (i, &id) in seqs.iter().enumerate() {
            let st = self
                .seqs
                .get(id)
                .ok_or_else(|| crate::eyre!("unknown sequence handle {id}"))?;
            crate::ensure!(
                st.len < self.max_seq,
                "sequence {id}: context window full ({} tokens)",
                self.max_seq
            );
            let e = self.emb.row(tokens[i] as usize);
            let xr = self.x.row_mut(i);
            for j in 0..d {
                xr[j] = st.h[j] + e[j];
            }
        }
        self.advance_rows()?;
        ensure_out(logits, k, vocab);
        gemm_nt_into(&self.hbuf, &self.emb, logits, &self.policy);
        for (i, &id) in seqs.iter().enumerate() {
            let st = self.seqs.get_mut(id).expect("validated above");
            st.h.copy_from_slice(self.hbuf.row(i));
            st.len += 1;
        }
        Ok(())
    }

    fn free_seq(&mut self, seq: SeqId) -> crate::Result<()> {
        self.seqs.remove(seq).map(|_| ())
    }

    fn seq_tokens(&self, seq: SeqId) -> Option<usize> {
        self.seqs.get(seq).map(|st| st.len)
    }

    fn live_seqs(&self) -> usize {
        self.seqs.live()
    }

    fn describe_decode(&self) -> String {
        format!(
            "kernel-decode: recurrent {}-layer 2:4 stack, d {}, vocab {}, context {}, \
             {} thread(s)",
            self.stack.layers().len(),
            self.emb.cols,
            self.emb.rows,
            self.max_seq,
            self.policy.effective_threads()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::SpmmAlgo;
    use crate::runtime::{write_synthetic_artifact, SynthSpec};
    use crate::sparsity::{random_row_mask, NmScheme};
    use crate::util::Rng;

    fn layer(d_out: usize, d_in: usize, rank: usize, rng: &mut Rng) -> ServeLayer {
        let w = Matrix::randn(d_out, d_in, 1.0, rng);
        let mask = random_row_mask(d_out, d_in, NmScheme::TWO_FOUR, rng);
        let be = SparseBackend::setup(&w, mask, NmScheme::TWO_FOUR, SpmmAlgo::RowMajor,
                                      ParallelPolicy::with_threads(2));
        let lora = (rank > 0).then(|| LoraAdapter {
            up: Matrix::randn(d_out, rank, 0.3, rng),
            down: Matrix::randn(rank, d_in, 0.3, rng),
        });
        ServeLayer::new(be, lora).unwrap()
    }

    #[test]
    fn kernel_stack_validates_chaining() {
        let mut rng = Rng::seed_from_u64(1);
        assert!(KernelStackModel::new(vec![]).is_err());
        let bad = vec![layer(24, 16, 0, &mut rng), layer(16, 32, 0, &mut rng)];
        assert!(KernelStackModel::new(bad).is_err());
        let ok = vec![layer(24, 16, 0, &mut rng), layer(16, 24, 0, &mut rng)];
        let m = KernelStackModel::new(ok).unwrap();
        assert_eq!((m.d_in(), m.d_out()), (16, 16));
        assert!(m.max_batch().is_none());
    }

    #[test]
    fn kernel_stack_forward_writes_final_layer_into_output() {
        let mut rng = Rng::seed_from_u64(2);
        for depth in [1usize, 2, 3] {
            let mut layers = Vec::new();
            let mut d_in = 16;
            for i in 0..depth {
                let d_out = if i % 2 == 0 { 24 } else { 16 };
                layers.push(layer(d_out, d_in, if i == 0 { 4 } else { 0 }, &mut rng));
                d_in = d_out;
            }
            // Dense reference.
            let x = Matrix::randn(3, 16, 1.0, &mut rng);
            let mut want = x.clone();
            for l in &layers {
                let mut y = crate::backend::gemm_nt(&want, &l.backend.dense_weight());
                if let Some(a) = &l.lora {
                    let t = crate::backend::gemm_nt(&want, &a.down);
                    let y2 = crate::backend::gemm_nt(&t, &a.up);
                    for (o, v) in y.data.iter_mut().zip(&y2.data) {
                        *o += v;
                    }
                }
                want = y;
            }
            let mut m = KernelStackModel::new(layers).unwrap();
            let mut y = Matrix::zeros(0, 0);
            m.forward_batch_into(&x, &mut y).unwrap();
            assert_eq!((y.rows, y.cols), (want.rows, want.cols), "depth {depth}");
            assert!(y.max_abs_diff(&want) < 1e-3, "depth {depth}");
        }
    }

    #[test]
    fn sampler_greedy_and_temperature() {
        let logits = [0.1f32, 2.0, 1.9, -3.0];
        let mut rng = Rng::seed_from_u64(0);
        assert_eq!(Sampler::Greedy.sample(&logits, &mut rng), 1);
        assert_eq!(Sampler::Temperature(0.0).sample(&logits, &mut rng), 1,
                   "non-positive temperature degenerates to greedy");
        // At a low temperature the distribution concentrates on the top
        // two logits; every draw must be a valid index.
        let mut counts = [0usize; 4];
        for _ in 0..2000 {
            let t = Sampler::Temperature(0.5).sample(&logits, &mut rng);
            counts[t as usize] += 1;
        }
        assert!(counts[1] + counts[2] > 1800, "mass on the top logits: {counts:?}");
        assert!(counts[1] > counts[2], "higher logit draws more: {counts:?}");
        // Determinism: same seed, same stream.
        let mut a = Rng::seed_from_u64(7);
        let mut b = Rng::seed_from_u64(7);
        for _ in 0..50 {
            assert_eq!(Sampler::Temperature(1.0).sample(&logits, &mut a),
                       Sampler::Temperature(1.0).sample(&logits, &mut b));
        }
    }

    #[test]
    fn kernel_decode_model_sequences_and_slots() {
        let mut m = KernelDecodeModel::synthetic(32, 16, 32, 4, 8,
                                                 ParallelPolicy::serial(), 3)
            .unwrap();
        assert!(m.validate_prompt(&[]).is_err());
        assert!(m.validate_prompt(&[32]).is_err(), "out-of-vocab prompt rejected");
        assert!(m.validate_prompt(&[0; 9]).is_err(), "over-long prompt rejected");
        let mut logits = Matrix::zeros(0, 0);
        let a = m.prefill(&[1, 2, 3], &mut logits).unwrap();
        assert_eq!((logits.rows, logits.cols), (1, 32));
        let b = m.prefill(&[4], &mut logits).unwrap();
        assert_ne!(a, b);
        assert_eq!(m.live_seqs(), 2);
        assert_eq!(m.seq_tokens(a), Some(3));
        m.decode_step(&[a, b], &[5, 6], &mut logits).unwrap();
        assert_eq!((logits.rows, logits.cols), (2, 32));
        assert_eq!(m.seq_tokens(a), Some(4));
        assert_eq!(m.seq_tokens(b), Some(2));
        m.free_seq(a).unwrap();
        assert!(m.free_seq(a).is_err(), "double free rejected");
        assert_eq!(m.seq_tokens(a), None);
        // The freed slot is recycled for the next sequence.
        let c = m.prefill(&[7], &mut logits).unwrap();
        assert_eq!(c, a, "slot reuse");
        assert_eq!(m.live_seqs(), 2);
        // Unknown handle in a batch leaves every sequence unadvanced.
        assert!(m.decode_step(&[b, 99], &[1, 1], &mut logits).is_err());
        assert_eq!(m.seq_tokens(b), Some(2));
    }

    #[test]
    fn aot_decode_surface_matches_recompute_and_reuses_slots() {
        let dir = std::env::temp_dir().join("slope_aot_decode_unit_test");
        let spec = SynthSpec { seed: 12, ..SynthSpec::default() };
        write_synthetic_artifact(&dir, &spec).unwrap();
        let mut m = AotModel::open(&dir, ParallelPolicy::with_threads(2)).unwrap();
        assert_eq!(m.max_decode_batch(), Some(spec.batch_size));
        assert_eq!((m.vocab(), m.max_seq_len()), (spec.vocab, spec.seq_len));
        let mut rng = Rng::seed_from_u64(4);
        let prompt: Vec<i32> = (0..4).map(|_| rng.below(spec.vocab) as i32).collect();
        let mut logits = Matrix::zeros(0, 0);
        let seq = m.prefill(&prompt, &mut logits).unwrap();
        assert_eq!(m.seq_tokens(seq), Some(4));
        // Greedy-decode 3 tokens through the trait; pin each step against
        // the full-prefix recompute of the same token stream.
        let mut toks = prompt.clone();
        for _ in 0..3 {
            let next = logits
                .row(0)
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0 as i32;
            toks.push(next);
            m.decode_step(&[seq], &[next], &mut logits).unwrap();
            // Bit-exact reference: the host model's ragged full recompute
            // over the same token stream.
            let manifest = Manifest::load(&dir).unwrap();
            let (store, packed) = checkpoint::load_model_checkpoint(&dir).unwrap();
            let mut hm = HostModel::from_store(&manifest, &store, &packed,
                                               ParallelPolicy::with_threads(2))
                .unwrap();
            let mut want = Matrix::zeros(0, 0);
            hm.forward_prefix_logits_into(&toks, &mut want).unwrap();
            assert_eq!(logits.data, want.data, "decode step diverged at {}", toks.len());
        }
        assert_eq!(m.seq_tokens(seq), Some(7));
        let stats = m.kv_pool_stats().expect("host route pages its caches");
        assert!(stats.blocks_in_use > 0, "a live sequence holds blocks");
        m.free_seq(seq).unwrap();
        assert_eq!(m.live_seqs(), 0);
        let stats = m.kv_pool_stats().unwrap();
        assert_eq!(stats.blocks_in_use, 0, "free_seq returns blocks to the pool");
        let seq2 = m.prefill(&prompt, &mut logits).unwrap();
        assert_eq!(seq2, seq, "freed slot is recycled");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn aot_model_restores_packed_planes_and_serves() {
        let dir = std::env::temp_dir().join("slope_aot_model_unit_test");
        let spec = SynthSpec { seed: 11, ..SynthSpec::default() };
        write_synthetic_artifact(&dir, &spec).unwrap();
        let mut m = AotModel::open(&dir, ParallelPolicy::with_threads(2)).unwrap();
        assert_eq!(m.path(), AotPath::HostKernels, "offline stub must fall back");
        assert_eq!(m.packed_restored(), 2 * 4 - 1);
        assert_eq!((m.d_in(), m.d_out()), (spec.seq_len, spec.vocab));
        assert_eq!(m.max_batch(), Some(spec.batch_size));
        let mut rng = Rng::seed_from_u64(0);
        let toks: Vec<i32> = (0..spec.seq_len).map(|_| rng.below(spec.vocab) as i32).collect();
        let x = Matrix::from_vec(1, spec.seq_len, AotModel::encode_tokens(&toks));
        let mut y = Matrix::zeros(0, 0);
        m.forward_batch_into(&x, &mut y).unwrap();
        assert_eq!((y.rows, y.cols), (1, spec.vocab));
        assert!(y.data.iter().all(|v| v.is_finite()));
        // Out-of-vocab tokens: rejected by the submit-time validator AND
        // (defensively) by the batch path itself.
        let bad_row = vec![spec.vocab as f32; spec.seq_len];
        assert!(m.validate_request(&bad_row).is_err());
        let bad = Matrix::from_vec(1, spec.seq_len, bad_row);
        assert!(m.forward_batch_into(&bad, &mut y).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
