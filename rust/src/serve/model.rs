//! [`ServeModel`] — the one serving contract every engine backend
//! implements, and its two production implementations.
//!
//! The redesign splits *what* is served from *how* requests are admitted:
//! [`crate::serve::ServeEngine`] owns admission (batcher, stats, staging)
//! and is generic over a `ServeModel`, which owns the math:
//!
//! * [`KernelStackModel`] — a stack of warm [`ServeLayer`]s (compressed
//!   2:4 weight + optional fused LoRA adapter each) driven directly on
//!   the CPU kernel engine, ping-ponging activations through two reusable
//!   buffers.  The synthetic-stack path `slope serve` always had.
//! * [`AotModel`] — a checkpointed transformer behind a manifest: opens
//!   the artifact's cached [`Session`], restores the serving checkpoint
//!   (packed v2 planes included), and runs the manifest's
//!   `forward`/`forward_lora` per coalesced batch — through PJRT when the
//!   executables compile, else through the in-process
//!   [`HostModel`] kernel executor (the offline path, bit-for-bit the
//!   same checkpoint).  Requests are token sequences (`d_in = seq_len`,
//!   each feature an integral token id); responses are the last
//!   position's next-token logits (`d_out = vocab`), copied out through a
//!   reusable staging buffer.
//!
//! Both implementations are **row-independent**: a request's output does
//! not depend on which batch it was coalesced into — the invariant that
//! makes dynamic batching and the async admission front-end
//! ([`crate::serve::admission`]) transparent to clients.

use crate::backend::{ensure_out, lora_fused_seq, ParallelPolicy, SparseBackend};
use crate::coordinator::checkpoint;
use crate::runtime::{HostModel, Manifest, Session, SessionHandle};
use crate::tensor::Matrix;
use std::path::Path;

/// A model the serving engine can drive: a pure coalesced-batch function
/// plus the shape and policy metadata admission needs.
pub trait ServeModel {
    /// Features per request row.
    fn d_in(&self) -> usize;

    /// Features per response row.
    fn d_out(&self) -> usize;

    /// Run one coalesced batch: `x` is `(k, d_in)` (one request per row),
    /// `y` is resized to `(k, d_out)` and overwritten.  Must be
    /// row-independent and allocation-free at a warm fill.
    fn forward_batch_into(&mut self, x: &Matrix, y: &mut Matrix) -> crate::Result<()>;

    /// Hard cap on the coalesced batch, when the backend has one (an AOT
    /// executable is compiled for a fixed batch).  The engine clamps its
    /// `BatchPolicy.max_batch` to this.
    fn max_batch(&self) -> Option<usize> {
        None
    }

    /// Validate one request's payload beyond its length (the engine
    /// checks length).  Called at submit time, so a malformed request is
    /// rejected individually instead of poisoning the coalesced batch it
    /// would have landed in.
    fn validate_request(&self, _input: &[f32]) -> crate::Result<()> {
        Ok(())
    }

    /// One-line description for stats headers and the CLI.
    fn describe(&self) -> String;
}

/// A LoRA adapter pair for one layer (Eq. 11): `up = L: (d_out, r)`,
/// `down = R: (r, d_in)`.
#[derive(Clone, Debug)]
pub struct LoraAdapter {
    pub up: Matrix,
    pub down: Matrix,
}

/// One serving layer: a warm sparse weight and an optional adapter.
pub struct ServeLayer {
    pub backend: SparseBackend,
    pub lora: Option<LoraAdapter>,
    /// Rank staging for the fused LoRA path (grown once).
    t: Matrix,
}

impl ServeLayer {
    pub fn new(backend: SparseBackend, lora: Option<LoraAdapter>) -> crate::Result<Self> {
        if let Some(l) = &lora {
            crate::ensure!(
                l.up.rows == backend.w.rows && l.down.cols == backend.w.cols
                    && l.up.cols == l.down.rows,
                "lora shapes (up {}x{}, down {}x{}) do not fit layer {}x{}",
                l.up.rows, l.up.cols, l.down.rows, l.down.cols,
                backend.w.rows, backend.w.cols
            );
        }
        Ok(Self { backend, lora, t: Matrix::zeros(0, 0) })
    }

    pub fn d_in(&self) -> usize {
        self.backend.w.cols
    }

    pub fn d_out(&self) -> usize {
        self.backend.w.rows
    }

    /// `y = x · Wᵀ (+ x · Rᵀ · Lᵀ)` into a caller-owned output — the
    /// Eq.-11 fused serving sequence ([`lora_fused_seq`], shared with the
    /// backend workspace path) through reusable buffers.
    fn forward_into(&mut self, x: &Matrix, y: &mut Matrix) {
        match &self.lora {
            Some(l) => lora_fused_seq(self.backend.algo, &self.backend.policy, &self.backend.w,
                                      x, &l.up, &l.down, &mut self.t, y),
            None => self.backend.forward_into(x, y),
        }
    }
}

/// The warm kernel-stack backend: a validated chain of [`ServeLayer`]s
/// plus the ping-pong activation buffers between them (module docs).
pub struct KernelStackModel {
    layers: Vec<ServeLayer>,
    /// Ping-pong activation buffers between layers (grown once).
    bufs: [Matrix; 2],
}

impl KernelStackModel {
    /// Validate the chain (each layer's `d_in` must equal the previous
    /// layer's `d_out`) and take ownership of the stack.
    pub fn new(layers: Vec<ServeLayer>) -> crate::Result<Self> {
        crate::ensure!(!layers.is_empty(), "kernel-stack model needs at least one layer");
        for pair in layers.windows(2) {
            crate::ensure!(
                pair[1].d_in() == pair[0].d_out(),
                "layer dims do not chain: {} -> {}",
                pair[0].d_out(),
                pair[1].d_in()
            );
        }
        Ok(Self { layers, bufs: [Matrix::zeros(0, 0), Matrix::zeros(0, 0)] })
    }

    pub fn layers(&self) -> &[ServeLayer] {
        &self.layers
    }

    /// Pointer to the first ping-pong buffer's storage — the test hook
    /// pinning "steady state performs no reallocation".
    #[cfg(test)]
    pub(crate) fn buf_ptr(&self) -> *const f32 {
        self.bufs[0].data.as_ptr()
    }
}

impl ServeModel for KernelStackModel {
    fn d_in(&self) -> usize {
        self.layers[0].d_in()
    }

    fn d_out(&self) -> usize {
        self.layers[self.layers.len() - 1].d_out()
    }

    fn forward_batch_into(&mut self, x: &Matrix, y: &mut Matrix) -> crate::Result<()> {
        crate::ensure!(x.cols == self.d_in(), "batch dim {} != d_in {}", x.cols, self.d_in());
        ensure_out(y, x.rows, self.d_out());
        let n = self.layers.len();
        // Ping-pong through the stack: layer i reads bufs[(i−1) % 2] (or
        // the staging input for i == 0) and writes bufs[i % 2], except the
        // final layer, which writes straight into the caller's output.
        for i in 0..n {
            let last = i + 1 == n;
            let [b0, b1] = &mut self.bufs;
            let (src, dst): (&Matrix, &mut Matrix) = match (i == 0, i % 2 == 0, last) {
                (true, _, true) => (x, &mut *y),
                (true, _, false) => (x, &mut *b0),
                (false, true, true) => (&*b1, &mut *y),
                (false, true, false) => (&*b1, &mut *b0),
                (false, false, true) => (&*b0, &mut *y),
                (false, false, false) => (&*b0, &mut *b1),
            };
            self.layers[i].forward_into(src, dst);
        }
        Ok(())
    }

    fn describe(&self) -> String {
        let lora = self.layers.iter().filter(|l| l.lora.is_some()).count();
        format!(
            "kernel-stack: {} layers ({} -> {}), {} with LoRA, {} {} thread(s)",
            self.layers.len(),
            self.d_in(),
            self.d_out(),
            lora,
            self.layers[0].backend.scheme,
            self.layers[0].backend.policy.effective_threads()
        )
    }
}

/// Execution route an [`AotModel`] resolved to at open time.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AotPath {
    /// The manifest's executable compiled: batches run via `Session::run`.
    Pjrt,
    /// PJRT unavailable (offline stub / checkpoint dir without HLO):
    /// batches run on the in-process [`HostModel`] kernel executor.
    HostKernels,
}

/// A checkpointed transformer served through its manifest (module docs).
///
/// Open with a directory holding `manifest.json` plus a serving
/// checkpoint ([`checkpoint::save_model_checkpoint`]'s layout — what
/// `slope train --checkpoint-dir` writes).
pub struct AotModel {
    manifest: Manifest,
    session: SessionHandle,
    /// The checkpoint store feeding `Session::run` — `None` on the
    /// host-kernel route, which copies what it needs into [`HostModel`]
    /// at open (keeping both would double resident model memory).
    store: Option<crate::runtime::Store>,
    host: Option<HostModel>,
    exe: String,
    path: AotPath,
    packed_restored: usize,
    /// Reusable decoded-token staging for each batch.
    tokens: Vec<i32>,
    /// Reusable logits copy-out staging (PJRT path).
    logits: Vec<f32>,
}

impl AotModel {
    /// Open `dir` (manifest + serving checkpoint), probe the PJRT path
    /// once, and fall back to the host kernel executor when the probe
    /// fails.  `policy` governs the host executor's kernel calls and is
    /// recorded on the session (`Session::set_parallel`).
    pub fn open(dir: &Path, policy: ParallelPolicy) -> crate::Result<Self> {
        let session = Session::open_cached(dir)?;
        session.borrow_mut().set_parallel(policy);
        let manifest = session.borrow().manifest.clone();
        let (store, packed) = checkpoint::load_model_checkpoint(dir)?;
        let has_lora = store.names().iter().any(|n| n.starts_with("lora."));
        // Refuse a checkpoint whose adapters the PJRT route could not
        // honor: silently serving base-only weights there while the host
        // executor applied the adapters would make the two routes compute
        // different functions from the same checkpoint.
        crate::ensure!(
            !has_lora || manifest.executables.contains_key("forward_lora"),
            "checkpoint carries lora.* adapters but manifest {} has no forward_lora executable",
            manifest.config.name
        );
        let exe = if has_lora { "forward_lora" } else { "forward" };
        crate::ensure!(
            manifest.executables.contains_key(exe),
            "manifest {} has no inference executable",
            manifest.config.name
        );
        // One-time probe: a compile failure (offline xla stub, or no HLO
        // beside the checkpoint) routes every batch to the host executor.
        let probe: Result<(), String> = {
            let mut sess = session.borrow_mut();
            sess.exe(exe).map(|_| ()).map_err(|e| e.to_string())
        };
        let packed_restored = packed.len();
        let (host, store, path) = match probe {
            Ok(()) => (None, Some(store), AotPath::Pjrt),
            Err(why) => {
                eprintln!(
                    "[serve] PJRT unavailable for {} ({why}); using the host kernel executor",
                    dir.display()
                );
                let hm = HostModel::from_store(&manifest, &store, &packed, policy)?;
                // The host executor owns its operand copies; drop the
                // checkpoint store rather than keeping the model resident
                // twice.
                (Some(hm), None, AotPath::HostKernels)
            }
        };
        Ok(Self {
            manifest,
            session,
            store,
            host,
            exe: exe.to_string(),
            path,
            packed_restored,
            tokens: Vec::new(),
            logits: Vec::new(),
        })
    }

    /// Which execution route `open` resolved to.
    pub fn path(&self) -> AotPath {
        self.path
    }

    /// Packed v2 planes the restore consumed without re-compression.
    pub fn packed_restored(&self) -> usize {
        self.packed_restored
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Encode a token sequence as an engine request row (`f32` ids).
    pub fn encode_tokens(tokens: &[i32]) -> Vec<f32> {
        tokens.iter().map(|t| *t as f32).collect()
    }

    /// PJRT route: pad the coalesced tokens to the compiled batch, run
    /// the executable, and copy the last position's logits out of the
    /// result through the reusable staging buffer.
    fn forward_pjrt(&mut self, k: usize, y: &mut Matrix) -> crate::Result<()> {
        let (bb, s) = self.manifest.forward_tokens_shape();
        let vocab = self.manifest.config.vocab_size;
        crate::ensure!(k <= bb, "batch {k} exceeds the compiled batch size {bb}");
        self.tokens.resize(bb * s, 0);
        let store = self
            .store
            .as_mut()
            .ok_or_else(|| crate::eyre!("PJRT route has no checkpoint store"))?;
        store.put_i32("tokens", &[bb, s], &self.tokens)?;
        self.session.borrow_mut().run(&self.exe, store)?;
        store.read_f32_into("logits", &mut self.logits)?;
        crate::ensure!(
            self.logits.len() == bb * s * vocab,
            "logits are {} long, expected {}x{}x{}",
            self.logits.len(), bb, s, vocab
        );
        for r in 0..k {
            let off = (r * s + (s - 1)) * vocab;
            y.row_mut(r).copy_from_slice(&self.logits[off..off + vocab]);
        }
        Ok(())
    }
}

impl ServeModel for AotModel {
    fn d_in(&self) -> usize {
        self.manifest.config.seq_len
    }

    fn d_out(&self) -> usize {
        self.manifest.config.vocab_size
    }

    fn forward_batch_into(&mut self, x: &Matrix, y: &mut Matrix) -> crate::Result<()> {
        let (k, s) = (x.rows, self.manifest.config.seq_len);
        let vocab = self.manifest.config.vocab_size;
        crate::ensure!(x.cols == s, "request carries {} features, seq_len is {s}", x.cols);
        self.tokens.clear();
        for r in 0..k {
            for &v in x.row(r) {
                let t = v.round() as i64;
                crate::ensure!(
                    v.is_finite() && t >= 0 && (t as usize) < vocab,
                    "token id {v} outside vocab 0..{vocab}"
                );
                self.tokens.push(t as i32);
            }
        }
        ensure_out(y, k, vocab);
        if let Some(hm) = self.host.as_mut() {
            return hm.forward_last_logits_into(&self.tokens, k, y);
        }
        self.forward_pjrt(k, y)
    }

    fn max_batch(&self) -> Option<usize> {
        // The PJRT executable is compiled for the manifest's batch; the
        // host executor keeps the same cap so both routes batch alike.
        Some(self.manifest.config.batch_size)
    }

    /// Reject malformed token payloads at submit, before they can
    /// coalesce with (and fail alongside) well-formed requests: every
    /// feature must be a finite, integral id inside the vocab (NaN would
    /// otherwise saturate to token 0 and be served silently).
    fn validate_request(&self, input: &[f32]) -> crate::Result<()> {
        let vocab = self.manifest.config.vocab_size;
        for &v in input {
            crate::ensure!(
                v.is_finite() && v == v.round(),
                "token id {v} is not an integral id"
            );
            let t = v as i64;
            crate::ensure!(
                t >= 0 && (t as usize) < vocab,
                "token id {v} outside vocab 0..{vocab}"
            );
        }
        Ok(())
    }

    fn describe(&self) -> String {
        let c = &self.manifest.config;
        format!(
            "aot:{} ({}L d{} ff{} heads{} vocab{} seq{}; {}, {} packed planes)",
            c.name, c.n_layer, c.d_model, c.d_ff, c.n_head, c.vocab_size, c.seq_len,
            match self.path {
                AotPath::Pjrt => "pjrt".to_string(),
                AotPath::HostKernels => format!(
                    "host kernels, {} thread(s)",
                    self.host.as_ref().map(|h| h.policy().effective_threads()).unwrap_or(1)
                ),
            },
            self.packed_restored
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::SpmmAlgo;
    use crate::runtime::{write_synthetic_artifact, SynthSpec};
    use crate::sparsity::{random_row_mask, NmScheme};
    use crate::util::Rng;

    fn layer(d_out: usize, d_in: usize, rank: usize, rng: &mut Rng) -> ServeLayer {
        let w = Matrix::randn(d_out, d_in, 1.0, rng);
        let mask = random_row_mask(d_out, d_in, NmScheme::TWO_FOUR, rng);
        let be = SparseBackend::setup(&w, mask, NmScheme::TWO_FOUR, SpmmAlgo::RowMajor,
                                      ParallelPolicy::with_threads(2));
        let lora = (rank > 0).then(|| LoraAdapter {
            up: Matrix::randn(d_out, rank, 0.3, rng),
            down: Matrix::randn(rank, d_in, 0.3, rng),
        });
        ServeLayer::new(be, lora).unwrap()
    }

    #[test]
    fn kernel_stack_validates_chaining() {
        let mut rng = Rng::seed_from_u64(1);
        assert!(KernelStackModel::new(vec![]).is_err());
        let bad = vec![layer(24, 16, 0, &mut rng), layer(16, 32, 0, &mut rng)];
        assert!(KernelStackModel::new(bad).is_err());
        let ok = vec![layer(24, 16, 0, &mut rng), layer(16, 24, 0, &mut rng)];
        let m = KernelStackModel::new(ok).unwrap();
        assert_eq!((m.d_in(), m.d_out()), (16, 16));
        assert!(m.max_batch().is_none());
    }

    #[test]
    fn kernel_stack_forward_writes_final_layer_into_output() {
        let mut rng = Rng::seed_from_u64(2);
        for depth in [1usize, 2, 3] {
            let mut layers = Vec::new();
            let mut d_in = 16;
            for i in 0..depth {
                let d_out = if i % 2 == 0 { 24 } else { 16 };
                layers.push(layer(d_out, d_in, if i == 0 { 4 } else { 0 }, &mut rng));
                d_in = d_out;
            }
            // Dense reference.
            let x = Matrix::randn(3, 16, 1.0, &mut rng);
            let mut want = x.clone();
            for l in &layers {
                let mut y = crate::backend::gemm_nt(&want, &l.backend.dense_weight());
                if let Some(a) = &l.lora {
                    let t = crate::backend::gemm_nt(&want, &a.down);
                    let y2 = crate::backend::gemm_nt(&t, &a.up);
                    for (o, v) in y.data.iter_mut().zip(&y2.data) {
                        *o += v;
                    }
                }
                want = y;
            }
            let mut m = KernelStackModel::new(layers).unwrap();
            let mut y = Matrix::zeros(0, 0);
            m.forward_batch_into(&x, &mut y).unwrap();
            assert_eq!((y.rows, y.cols), (want.rows, want.cols), "depth {depth}");
            assert!(y.max_abs_diff(&want) < 1e-3, "depth {depth}");
        }
    }

    #[test]
    fn aot_model_restores_packed_planes_and_serves() {
        let dir = std::env::temp_dir().join("slope_aot_model_unit_test");
        let spec = SynthSpec { seed: 11, ..SynthSpec::default() };
        write_synthetic_artifact(&dir, &spec).unwrap();
        let mut m = AotModel::open(&dir, ParallelPolicy::with_threads(2)).unwrap();
        assert_eq!(m.path(), AotPath::HostKernels, "offline stub must fall back");
        assert_eq!(m.packed_restored(), 2 * 4 - 1);
        assert_eq!((m.d_in(), m.d_out()), (spec.seq_len, spec.vocab));
        assert_eq!(m.max_batch(), Some(spec.batch_size));
        let mut rng = Rng::seed_from_u64(0);
        let toks: Vec<i32> = (0..spec.seq_len).map(|_| rng.below(spec.vocab) as i32).collect();
        let x = Matrix::from_vec(1, spec.seq_len, AotModel::encode_tokens(&toks));
        let mut y = Matrix::zeros(0, 0);
        m.forward_batch_into(&x, &mut y).unwrap();
        assert_eq!((y.rows, y.cols), (1, spec.vocab));
        assert!(y.data.iter().all(|v| v.is_finite()));
        // Out-of-vocab tokens: rejected by the submit-time validator AND
        // (defensively) by the batch path itself.
        let bad_row = vec![spec.vocab as f32; spec.seq_len];
        assert!(m.validate_request(&bad_row).is_err());
        let bad = Matrix::from_vec(1, spec.seq_len, bad_row);
        assert!(m.forward_batch_into(&bad, &mut y).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
