//! The serving engine: admission (batcher + stats + staging) generic
//! over a [`ServeModel`].
//!
//! [`ServeEngine<M>`] owns the coalescing [`Batcher`], the latency
//! telemetry, and the reusable request-staging/output matrices; the model
//! owns the math.  `ServeEngine::new` builds the classic kernel-stack
//! engine ([`KernelStackModel`]); [`ServeEngine::with_model`] accepts any
//! backend — notably [`crate::serve::AotModel`] for checkpointed
//! manifest-backed transformers.  A model with a compiled batch cap
//! ([`ServeModel::max_batch`]) clamps the batch policy at construction.
//!
//! The engine is clocked externally (`now` = [`Duration`] since engine
//! start): [`ServeEngine::submit`] enqueues, [`ServeEngine::poll`]
//! dispatches at most one batch when the [`Batcher`] says one is due, and
//! [`ServeEngine::flush`] drains.  Latency = queue wait (virtual, from
//! the caller's clock) + compute (measured).  The CLI (`slope serve`) and
//! `examples/inference_serve.rs` drive it with `start.elapsed()`; the
//! async admission front-end ([`crate::serve::admission`]) wraps it in a
//! dispatch thread; tests drive it with synthetic timelines.

use crate::backend::ensure_out;
use crate::serve::batcher::{BatchPolicy, Batcher, Request};
use crate::serve::model::{KernelStackModel, ServeLayer, ServeModel};
use crate::serve::stats::ServeStats;
use crate::tensor::Matrix;
use std::time::{Duration, Instant};

/// A completed request.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub output: Vec<f32>,
    /// Time spent coalescing in the queue.
    pub queued: Duration,
    /// Queue wait + batch compute.
    pub latency: Duration,
}

/// The serving engine (see module docs).
pub struct ServeEngine<M: ServeModel = KernelStackModel> {
    model: M,
    batcher: Batcher,
    stats: ServeStats,
    staging: Matrix,
    out: Matrix,
    /// Reusable drain buffer for the dispatch loop.
    batch_buf: Vec<Request>,
    next_id: u64,
}

impl ServeEngine<KernelStackModel> {
    /// Build the kernel-stack engine over a validated layer stack (each
    /// layer's `d_in` must equal the previous layer's `d_out`).
    pub fn new(layers: Vec<ServeLayer>, policy: BatchPolicy) -> crate::Result<Self> {
        Self::with_model(KernelStackModel::new(layers)?, policy)
    }
}

impl<M: ServeModel> ServeEngine<M> {
    /// Build an engine over any [`ServeModel`].  `policy.max_batch` is
    /// clamped to the model's compiled batch cap when it has one.
    pub fn with_model(model: M, policy: BatchPolicy) -> crate::Result<Self> {
        let mut policy = policy;
        if let Some(cap) = model.max_batch() {
            crate::ensure!(cap >= 1, "model reports a zero batch cap");
            if policy.max_batch > cap {
                policy = BatchPolicy::new(cap, policy.max_wait);
            }
        }
        Ok(Self {
            model,
            batcher: Batcher::new(policy),
            stats: ServeStats::default(),
            staging: Matrix::zeros(0, 0),
            out: Matrix::zeros(0, 0),
            batch_buf: Vec::new(),
            next_id: 0,
        })
    }

    pub fn model(&self) -> &M {
        &self.model
    }

    pub fn d_in(&self) -> usize {
        self.model.d_in()
    }

    pub fn d_out(&self) -> usize {
        self.model.d_out()
    }

    /// The (possibly model-clamped) batch policy in effect.
    pub fn policy(&self) -> BatchPolicy {
        self.batcher.policy()
    }

    pub fn pending(&self) -> usize {
        self.batcher.len()
    }

    pub fn stats(&self) -> &ServeStats {
        &self.stats
    }

    /// Enqueue one request (`input` is a `d_in` feature row); returns its
    /// id.  `now` is the caller's engine-relative clock.  Rejection here
    /// (wrong length, or the model's [`ServeModel::validate_request`]) is
    /// per-request; batch dispatch never sees a malformed payload.
    pub fn submit(&mut self, input: Vec<f32>, now: Duration) -> crate::Result<u64> {
        crate::ensure!(
            input.len() == self.d_in(),
            "request dim {} != engine d_in {}",
            input.len(),
            self.d_in()
        );
        self.model.validate_request(&input)?;
        let id = self.next_id;
        self.next_id += 1;
        self.batcher.push(Request { id, input, submitted: now });
        Ok(id)
    }

    /// Dispatch at most one coalesced batch if the batcher says one is
    /// due at `now`; returns the completed responses (empty when not yet
    /// due).  A model error leaves the engine usable; the failed batch's
    /// requests are dropped.
    pub fn poll(&mut self, now: Duration) -> crate::Result<Vec<Response>> {
        if !self.batcher.ready(now) {
            return Ok(Vec::new());
        }
        self.forward_batch(now)
    }

    /// Drain the queue regardless of policy (shutdown / end of stream).
    pub fn flush(&mut self, now: Duration) -> crate::Result<Vec<Response>> {
        let mut out = Vec::new();
        while !self.batcher.is_empty() {
            out.extend(self.forward_batch(now)?);
        }
        Ok(out)
    }

    /// Drive a synthetic open-loop stream on the real clock: submit `n`
    /// inputs from `make_input`, polling after each so batches coalesce
    /// under real time, then flush the tail.  Returns the number of
    /// completed responses (the single-submitter loop the CLI and
    /// `examples/inference_serve.rs` share; stats accumulate on the
    /// engine as usual).
    pub fn run_open_loop<G>(&mut self, n: usize, mut make_input: G) -> crate::Result<usize>
    where
        G: FnMut() -> Vec<f32>,
    {
        let start = Instant::now();
        let mut done = 0usize;
        for _ in 0..n {
            self.submit(make_input(), start.elapsed())?;
            done += self.poll(start.elapsed())?.len();
        }
        done += self.flush(start.elapsed())?.len();
        Ok(done)
    }

    /// Run one coalesced forward.  Steady state (same fill as the
    /// previous batch) performs no heap allocation inside the engine or
    /// the kernels: the drain buffer, staging and output matrices are
    /// shape-checked and reused.
    fn forward_batch(&mut self, now: Duration) -> crate::Result<Vec<Response>> {
        let mut batch = std::mem::take(&mut self.batch_buf);
        self.batcher.take_batch_into(&mut batch);
        let k = batch.len();
        if k == 0 {
            self.batch_buf = batch;
            return Ok(Vec::new());
        }
        let d_in = self.model.d_in();
        ensure_out(&mut self.staging, k, d_in);
        for (row, req) in batch.iter().enumerate() {
            self.staging.row_mut(row).copy_from_slice(&req.input);
        }
        let t0 = Instant::now();
        let r = self.model.forward_batch_into(&self.staging, &mut self.out);
        if let Err(e) = r {
            batch.clear();
            self.batch_buf = batch;
            return Err(e);
        }
        let compute = t0.elapsed();
        debug_assert_eq!((self.out.rows, self.out.cols), (k, self.model.d_out()));
        let responses: Vec<Response> = batch
            .iter()
            .enumerate()
            .map(|(row, req)| {
                let queued = now.saturating_sub(req.submitted);
                Response {
                    id: req.id,
                    output: self.out.row(row).to_vec(),
                    queued,
                    latency: queued + compute,
                }
            })
            .collect();
        self.stats.record_batch(
            now,
            compute,
            responses.iter().map(|r| r.latency),
        );
        batch.clear();
        self.batch_buf = batch;
        Ok(responses)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{gemm_nt, ParallelPolicy, SparseBackend, SpmmAlgo};
    use crate::serve::model::LoraAdapter;
    use crate::sparsity::{random_row_mask, NmScheme};
    use crate::util::Rng;

    const MS: Duration = Duration::from_millis(1);

    fn layer(d_out: usize, d_in: usize, rank: usize, threads: usize,
             rng: &mut Rng) -> ServeLayer {
        let w = Matrix::randn(d_out, d_in, 1.0, rng);
        let mask = random_row_mask(d_out, d_in, NmScheme::TWO_FOUR, rng);
        let be = SparseBackend::setup(&w, mask, NmScheme::TWO_FOUR, SpmmAlgo::RowMajor,
                                      ParallelPolicy::with_threads(threads));
        let lora = (rank > 0).then(|| LoraAdapter {
            up: Matrix::randn(d_out, rank, 0.3, rng),
            down: Matrix::randn(rank, d_in, 0.3, rng),
        });
        ServeLayer::new(be, lora).unwrap()
    }

    /// Dense reference for one layer stack: `x · (W masked)ᵀ + x·Rᵀ·Lᵀ`.
    fn reference(layers: &[ServeLayer], x: &Matrix) -> Matrix {
        let mut cur = x.clone();
        for l in layers {
            let mut y = gemm_nt(&cur, &l.backend.dense_weight());
            if let Some(a) = &l.lora {
                let t = gemm_nt(&cur, &a.down);
                let y2 = gemm_nt(&t, &a.up);
                for (o, v) in y.data.iter_mut().zip(&y2.data) {
                    *o += v;
                }
            }
            cur = y;
        }
        cur
    }

    #[test]
    fn engine_output_matches_dense_reference() {
        let mut rng = Rng::seed_from_u64(0);
        let layers = vec![layer(24, 16, 4, 2, &mut rng), layer(16, 24, 0, 2, &mut rng)];
        let x = Matrix::randn(3, 16, 1.0, &mut rng);
        let want = reference(&layers, &x);
        let mut eng =
            ServeEngine::new(layers, BatchPolicy::new(3, Duration::from_millis(1))).unwrap();
        for r in 0..3 {
            eng.submit(x.row(r).to_vec(), Duration::ZERO).unwrap();
        }
        let resp = eng.poll(Duration::ZERO).unwrap();
        assert_eq!(resp.len(), 3, "full batch dispatches at once");
        for (row, r) in resp.iter().enumerate() {
            let got = Matrix::from_vec(1, want.cols, r.output.clone());
            let wrow = Matrix::from_vec(1, want.cols, want.row(row).to_vec());
            assert!(got.max_abs_diff(&wrow) < 1e-4, "row {row}");
        }
    }

    #[test]
    fn mismatched_dims_rejected() {
        let mut rng = Rng::seed_from_u64(1);
        let layers = vec![layer(24, 16, 0, 1, &mut rng), layer(16, 32, 0, 1, &mut rng)];
        assert!(ServeEngine::new(layers, BatchPolicy::default()).is_err());
        let mut eng = ServeEngine::new(vec![layer(8, 16, 0, 1, &mut rng)],
                                       BatchPolicy::default())
            .unwrap();
        assert!(eng.submit(vec![0.0; 7], Duration::ZERO).is_err());
    }

    #[test]
    fn coalescing_honors_max_batch_and_max_wait() {
        let mut rng = Rng::seed_from_u64(2);
        let mut eng = ServeEngine::new(vec![layer(8, 16, 0, 1, &mut rng)],
                                       BatchPolicy::new(4, 10 * MS))
            .unwrap();
        // Three requests at t=0: not a full batch, wait below max_wait.
        for _ in 0..3 {
            eng.submit(vec![0.5; 16], Duration::ZERO).unwrap();
        }
        assert!(eng.poll(5 * MS).unwrap().is_empty(),
                "partial batch below max_wait holds");
        // Fourth request completes the batch: dispatch on the next poll.
        eng.submit(vec![0.5; 16], 6 * MS).unwrap();
        let r = eng.poll(6 * MS).unwrap();
        assert_eq!(r.len(), 4, "max_batch reached ⇒ immediate dispatch");
        assert_eq!(r[0].queued, 6 * MS);
        assert_eq!(r[3].queued, Duration::ZERO);
        // Two stragglers: held until the oldest has waited max_wait.
        eng.submit(vec![0.5; 16], 8 * MS).unwrap();
        eng.submit(vec![0.5; 16], 9 * MS).unwrap();
        assert!(eng.poll(17 * MS).unwrap().is_empty(), "9 ms < max_wait");
        let r = eng.poll(18 * MS).unwrap();
        assert_eq!(r.len(), 2, "max_wait exceeded ⇒ partial dispatch");
        assert!(r[0].queued >= 10 * MS);
        assert_eq!(eng.pending(), 0);
        assert_eq!(eng.stats().served(), 6);
    }

    #[test]
    fn steady_state_reuses_buffers() {
        let mut rng = Rng::seed_from_u64(3);
        let mut eng = ServeEngine::new(vec![layer(32, 16, 4, 2, &mut rng),
                                            layer(16, 32, 0, 2, &mut rng)],
                                       BatchPolicy::new(2, MS))
            .unwrap();
        for _ in 0..2 {
            eng.submit(vec![0.1; 16], Duration::ZERO).unwrap();
        }
        eng.poll(Duration::ZERO).unwrap();
        let staging_ptr = eng.staging.data.as_ptr();
        let out_ptr = eng.out.data.as_ptr();
        let buf_ptr = eng.model().buf_ptr();
        for _ in 0..2 {
            eng.submit(vec![0.2; 16], MS).unwrap();
        }
        eng.poll(MS).unwrap();
        assert_eq!(eng.staging.data.as_ptr(), staging_ptr, "staging must not realloc");
        assert_eq!(eng.out.data.as_ptr(), out_ptr, "output must not realloc");
        assert_eq!(eng.model().buf_ptr(), buf_ptr, "activation buffer must not realloc");
    }

    #[test]
    fn flush_drains_everything() {
        let mut rng = Rng::seed_from_u64(4);
        let mut eng = ServeEngine::new(vec![layer(8, 16, 0, 1, &mut rng)],
                                       BatchPolicy::new(4, Duration::from_secs(1)))
            .unwrap();
        for _ in 0..10 {
            eng.submit(vec![1.0; 16], Duration::ZERO).unwrap();
        }
        let r = eng.flush(MS).unwrap();
        assert_eq!(r.len(), 10);
        assert_eq!(eng.pending(), 0);
        let s = eng.stats().summary();
        assert_eq!(s.batches, 3, "10 requests at max_batch 4 ⇒ 4+4+2");
    }

    #[test]
    fn model_batch_cap_clamps_policy() {
        struct Tiny;
        impl ServeModel for Tiny {
            fn d_in(&self) -> usize {
                2
            }
            fn d_out(&self) -> usize {
                2
            }
            fn forward_batch_into(&mut self, x: &Matrix, y: &mut Matrix) -> crate::Result<()> {
                ensure_out(y, x.rows, 2);
                y.data.copy_from_slice(&x.data);
                Ok(())
            }
            fn max_batch(&self) -> Option<usize> {
                Some(3)
            }
            fn describe(&self) -> String {
                "tiny".into()
            }
        }
        let mut eng = ServeEngine::with_model(Tiny, BatchPolicy::new(64, MS)).unwrap();
        assert_eq!(eng.policy().max_batch, 3, "policy clamps to the compiled batch");
        for _ in 0..7 {
            eng.submit(vec![1.0, 2.0], Duration::ZERO).unwrap();
        }
        let r = eng.flush(Duration::ZERO).unwrap();
        assert_eq!(r.len(), 7);
        assert_eq!(eng.stats().summary().batches, 3, "7 at cap 3 ⇒ 3+3+1");
    }
}
