//! The serving engine: admission (batcher + stats + staging) generic
//! over a [`ServeModel`].
//!
//! [`ServeEngine<M>`] owns the coalescing [`Batcher`], the latency
//! telemetry, and the reusable request-staging/output matrices; the model
//! owns the math.  `ServeEngine::new` builds the classic kernel-stack
//! engine ([`KernelStackModel`]); [`ServeEngine::with_model`] accepts any
//! backend — notably [`crate::serve::AotModel`] for checkpointed
//! manifest-backed transformers.  A model with a compiled batch cap
//! ([`ServeModel::max_batch`]) clamps the batch policy at construction.
//!
//! The engine is clocked externally (`now` = [`Duration`] since engine
//! start): [`ServeEngine::submit`] enqueues, [`ServeEngine::poll`]
//! dispatches at most one batch when the [`Batcher`] says one is due, and
//! [`ServeEngine::flush`] drains.  Latency = queue wait (virtual, from
//! the caller's clock) + compute (measured).  The CLI (`slope serve`) and
//! `examples/inference_serve.rs` drive it with `start.elapsed()`; the
//! async admission front-end ([`crate::serve::admission`]) wraps it in a
//! dispatch thread; tests drive it with synthetic timelines.

use crate::backend::ensure_out;
use crate::serve::batcher::{BatchPolicy, Batcher, Request};
use crate::serve::model::{DecodeModel, KernelStackModel, Sampler, SeqId, ServeLayer,
                          ServeModel};
use crate::serve::stats::ServeStats;
use crate::tensor::Matrix;
use crate::util::Rng;
use std::collections::VecDeque;
use std::time::{Duration, Instant};

/// A completed request.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub output: Vec<f32>,
    /// Time spent coalescing in the queue.
    pub queued: Duration,
    /// Queue wait + batch compute.
    pub latency: Duration,
    /// True when the request expired past its deadline before dispatch:
    /// `output` is empty and no forward compute was spent on it.
    pub deadline_expired: bool,
}

/// The serving engine (see module docs).
pub struct ServeEngine<M: ServeModel = KernelStackModel> {
    model: M,
    batcher: Batcher,
    stats: ServeStats,
    staging: Matrix,
    out: Matrix,
    /// Reusable drain buffer for the dispatch loop.
    batch_buf: Vec<Request>,
    next_id: u64,
}

impl ServeEngine<KernelStackModel> {
    /// Build the kernel-stack engine over a validated layer stack (each
    /// layer's `d_in` must equal the previous layer's `d_out`).
    pub fn new(layers: Vec<ServeLayer>, policy: BatchPolicy) -> crate::Result<Self> {
        Self::with_model(KernelStackModel::new(layers)?, policy)
    }
}

impl<M: ServeModel> ServeEngine<M> {
    /// Build an engine over any [`ServeModel`].  `policy.max_batch` is
    /// clamped to the model's compiled batch cap when it has one.
    pub fn with_model(model: M, policy: BatchPolicy) -> crate::Result<Self> {
        let mut policy = policy;
        if let Some(cap) = model.max_batch() {
            crate::ensure!(cap >= 1, "model reports a zero batch cap");
            if policy.max_batch > cap {
                policy = BatchPolicy::new(cap, policy.max_wait);
            }
        }
        Ok(Self {
            model,
            batcher: Batcher::new(policy),
            stats: ServeStats::default(),
            staging: Matrix::zeros(0, 0),
            out: Matrix::zeros(0, 0),
            batch_buf: Vec::new(),
            next_id: 0,
        })
    }

    pub fn model(&self) -> &M {
        &self.model
    }

    pub fn d_in(&self) -> usize {
        self.model.d_in()
    }

    pub fn d_out(&self) -> usize {
        self.model.d_out()
    }

    /// The (possibly model-clamped) batch policy in effect.
    pub fn policy(&self) -> BatchPolicy {
        self.batcher.policy()
    }

    pub fn pending(&self) -> usize {
        self.batcher.len()
    }

    pub fn stats(&self) -> &ServeStats {
        &self.stats
    }

    /// Enqueue one request (`input` is a `d_in` feature row); returns its
    /// id.  `now` is the caller's engine-relative clock.  Rejection here
    /// (wrong length, or the model's [`ServeModel::validate_request`]) is
    /// per-request; batch dispatch never sees a malformed payload.
    pub fn submit(&mut self, input: Vec<f32>, now: Duration) -> crate::Result<u64> {
        self.submit_with_deadline(input, now, None)
    }

    /// [`ServeEngine::submit`] with an engine-relative deadline: if the
    /// request is still queued when a batch dispatches at or past this
    /// instant, it is expired (empty [`Response`] flagged
    /// `deadline_expired`) instead of consuming forward compute.
    pub fn submit_with_deadline(&mut self, input: Vec<f32>, now: Duration,
                                deadline: Option<Duration>) -> crate::Result<u64> {
        crate::ensure!(
            input.len() == self.d_in(),
            "request dim {} != engine d_in {}",
            input.len(),
            self.d_in()
        );
        self.model.validate_request(&input)?;
        let id = self.next_id;
        self.next_id += 1;
        self.batcher.push(Request { id, input, submitted: now, deadline });
        Ok(id)
    }

    /// Dispatch at most one coalesced batch if the batcher says one is
    /// due at `now`; returns the completed responses (empty when not yet
    /// due).  A model error leaves the engine usable; the failed batch's
    /// requests are dropped.
    pub fn poll(&mut self, now: Duration) -> crate::Result<Vec<Response>> {
        if !self.batcher.ready(now) {
            return Ok(Vec::new());
        }
        self.forward_batch(now)
    }

    /// Drain the queue regardless of policy (shutdown / end of stream).
    pub fn flush(&mut self, now: Duration) -> crate::Result<Vec<Response>> {
        let mut out = Vec::new();
        while !self.batcher.is_empty() {
            out.extend(self.forward_batch(now)?);
        }
        Ok(out)
    }

    /// Drive a synthetic open-loop stream on the real clock: submit `n`
    /// inputs from `make_input`, polling after each so batches coalesce
    /// under real time, then flush the tail.  Returns the number of
    /// completed responses (the single-submitter loop the CLI and
    /// `examples/inference_serve.rs` share; stats accumulate on the
    /// engine as usual).
    pub fn run_open_loop<G>(&mut self, n: usize, make_input: G) -> crate::Result<usize>
    where
        G: FnMut() -> Vec<f32>,
    {
        self.run_open_loop_with_deadline(n, make_input, None)
    }

    /// [`ServeEngine::run_open_loop`] with a per-request deadline budget:
    /// each submission's deadline is its submit time plus `budget`
    /// (`None` = no deadlines, the classic loop).
    pub fn run_open_loop_with_deadline<G>(&mut self, n: usize, mut make_input: G,
                                          budget: Option<Duration>) -> crate::Result<usize>
    where
        G: FnMut() -> Vec<f32>,
    {
        let start = Instant::now();
        let mut done = 0usize;
        for _ in 0..n {
            let now = start.elapsed();
            self.submit_with_deadline(make_input(), now, budget.map(|b| now + b))?;
            done += self.poll(start.elapsed())?.len();
        }
        done += self.flush(start.elapsed())?.len();
        Ok(done)
    }

    /// Run one coalesced forward.  Steady state (same fill as the
    /// previous batch) performs no heap allocation inside the engine or
    /// the kernels: the drain buffer, staging and output matrices are
    /// shape-checked and reused.
    fn forward_batch(&mut self, now: Duration) -> crate::Result<Vec<Response>> {
        let mut batch = std::mem::take(&mut self.batch_buf);
        self.batcher.take_batch_into(&mut batch);
        if batch.is_empty() {
            self.batch_buf = batch;
            return Ok(Vec::new());
        }
        // Expire requests past their deadline before staging: they get an
        // empty flagged response and never touch the model, so a stale
        // backlog cannot consume forward compute.
        let expired = |req: &Request| matches!(req.deadline, Some(d) if now >= d);
        let mut responses: Vec<Response> = Vec::new();
        let mut n_expired = 0usize;
        for req in batch.iter().filter(|r| expired(r)) {
            let queued = now.saturating_sub(req.submitted);
            responses.push(Response {
                id: req.id,
                output: Vec::new(),
                queued,
                latency: queued,
                deadline_expired: true,
            });
            n_expired += 1;
        }
        if n_expired > 0 {
            self.stats.record_deadline_expired(n_expired);
        }
        let k = batch.len() - n_expired;
        if k == 0 {
            batch.clear();
            self.batch_buf = batch;
            return Ok(responses);
        }
        let d_in = self.model.d_in();
        ensure_out(&mut self.staging, k, d_in);
        for (row, req) in batch.iter().filter(|r| !expired(r)).enumerate() {
            self.staging.row_mut(row).copy_from_slice(&req.input);
        }
        let t0 = Instant::now();
        let r = self.model.forward_batch_into(&self.staging, &mut self.out);
        if let Err(e) = r {
            batch.clear();
            self.batch_buf = batch;
            return Err(e);
        }
        let compute = t0.elapsed();
        debug_assert_eq!((self.out.rows, self.out.cols), (k, self.model.d_out()));
        let first_live = responses.len();
        for (row, req) in batch.iter().filter(|r| !expired(r)).enumerate() {
            let queued = now.saturating_sub(req.submitted);
            responses.push(Response {
                id: req.id,
                output: self.out.row(row).to_vec(),
                queued,
                latency: queued + compute,
                deadline_expired: false,
            });
        }
        self.stats.record_batch(
            now,
            compute,
            responses[first_live..].iter().map(|r| r.latency),
        );
        batch.clear();
        self.batch_buf = batch;
        Ok(responses)
    }
}

// ---- continuous-batching decode scheduler ------------------------------

/// Scheduling policy for [`DecodeEngine`].
#[derive(Clone, Copy, Debug)]
pub struct DecodePolicy {
    /// Maximum sequences decoding concurrently (one coalesced step per
    /// [`DecodeEngine::step`]); clamped to the model's
    /// [`DecodeModel::max_decode_batch`] at construction.
    pub max_batch: usize,
    /// Default generated-token cap per request (a request may lower it;
    /// the model's context bound always applies).
    pub max_new_tokens: usize,
    /// Stop token: a sequence finishes when it samples this id (the id is
    /// included in the output).
    pub eos: Option<i32>,
    /// Token-selection rule (greedy / temperature).
    pub sampler: Sampler,
    /// Base seed for the per-sequence sampling RNGs (each sequence draws
    /// from `seed ⊕ request-id`, so streams are independent of batching).
    pub seed: u64,
    /// Bound on the waiting queue: a submit beyond it is rejected with an
    /// error (the inline engine can only shed; the async front-end adds a
    /// blocking alternative — see [`crate::serve::admission`]).
    pub queue_cap: Option<usize>,
}

impl Default for DecodePolicy {
    fn default() -> Self {
        Self {
            max_batch: 8,
            max_new_tokens: 32,
            eos: None,
            sampler: Sampler::Greedy,
            seed: 0,
            queue_cap: None,
        }
    }
}

/// Why a generation stopped.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    /// Sampled the policy's stop token.
    Eos,
    /// Hit its generated-token cap (request cap, policy cap, or the
    /// model's context bound — whichever bound first).
    MaxTokens,
    /// Expired past its per-request deadline: dropped from the waiting
    /// queue (no tokens) or from the running batch (partial tokens), its
    /// sequence freed.  Counted in [`ServeStats`]' `deadline_expired`,
    /// not in `served`.
    Deadline,
    /// Cancelled by the client ([`DecodeEngine::cancel`] /
    /// `DecodeClient::cancel`): finished on the next step — no tokens if
    /// it was still waiting, the partial tokens if it was running — with
    /// its sequence (KV blocks, prefix-cache references) freed.  Counted
    /// in [`ServeStats`]' `cancelled`, not in `served`.
    Cancelled,
}

/// A completed generation request.
#[derive(Clone, Debug)]
pub struct Generation {
    pub id: u64,
    pub prompt_len: usize,
    /// Generated tokens only (the prompt is not echoed); includes the
    /// stop token when [`FinishReason::Eos`].
    pub tokens: Vec<i32>,
    pub finish: FinishReason,
    /// Time spent waiting for a prefill slot.
    pub queued: Duration,
    /// Submit → final token.
    pub latency: Duration,
    /// Prompt positions this request's prefill served from the
    /// backend's prefix cache instead of recomputing (0 without a
    /// cache, on a miss, or when no prefill ran).
    pub prefill_tokens_saved: usize,
}

struct WaitingGen {
    id: u64,
    prompt: Vec<i32>,
    max_new: usize,
    submitted: Duration,
    deadline: Option<Duration>,
}

struct RunningGen {
    id: u64,
    seq: SeqId,
    prompt_len: usize,
    max_new: usize,
    submitted: Duration,
    queued: Duration,
    deadline: Option<Duration>,
    tokens: Vec<i32>,
    rng: Rng,
    prefill_saved: usize,
}

/// The continuous-batching decode scheduler: sequences join the running
/// batch as soon as a slot frees (prefill), share one coalesced
/// [`DecodeModel::decode_step`] per [`DecodeEngine::step`] call, and
/// leave individually on EOS or their token cap — no batch-of-requests
/// barrier, so a short generation never waits for a long batch-mate.
///
/// Externally clocked like [`ServeEngine`] (`now` = caller's engine-
/// relative [`Duration`]): `submit` enqueues, `step` advances the world
/// by one admission round + one decode step and returns whatever
/// finished.  Because every [`DecodeModel`] is sequence-independent and
/// sampling RNGs are per-sequence, the token streams are identical to
/// solo runs regardless of how sequences joined and left (pinned in
/// `tests/decode.rs`) — only latency moves, which is what
/// [`ServeStats`]' split request/per-token windows measure.
pub struct DecodeEngine<M: DecodeModel> {
    model: M,
    policy: DecodePolicy,
    waiting: VecDeque<WaitingGen>,
    running: Vec<RunningGen>,
    stats: ServeStats,
    /// Reusable logits staging (`k × vocab` at the current fill).
    logits: Matrix,
    step_seqs: Vec<SeqId>,
    step_tokens: Vec<i32>,
    /// Ids marked by [`DecodeEngine::cancel`], swept on the next step.
    cancelled: std::collections::HashSet<u64>,
    next_id: u64,
}

impl<M: DecodeModel> DecodeEngine<M> {
    /// Build the scheduler; `policy.max_batch` is clamped to the model's
    /// compiled decode-batch cap when it has one.
    pub fn new(model: M, policy: DecodePolicy) -> crate::Result<Self> {
        let mut policy = policy;
        crate::ensure!(policy.max_batch >= 1, "max_batch must be at least 1");
        crate::ensure!(policy.max_new_tokens >= 1, "max_new_tokens must be at least 1");
        if let Some(cap) = model.max_decode_batch() {
            crate::ensure!(cap >= 1, "model reports a zero decode-batch cap");
            policy.max_batch = policy.max_batch.min(cap);
        }
        Ok(Self {
            model,
            policy,
            waiting: VecDeque::new(),
            running: Vec::new(),
            stats: ServeStats::default(),
            logits: Matrix::zeros(0, 0),
            step_seqs: Vec::new(),
            step_tokens: Vec::new(),
            cancelled: std::collections::HashSet::new(),
            next_id: 0,
        })
    }

    pub fn model(&self) -> &M {
        &self.model
    }

    /// The (possibly model-clamped) policy in effect.
    pub fn policy(&self) -> DecodePolicy {
        self.policy
    }

    /// Requests waiting for a prefill slot.
    pub fn pending(&self) -> usize {
        self.waiting.len()
    }

    /// Sequences currently in the running batch.
    pub fn running(&self) -> usize {
        self.running.len()
    }

    /// Requests anywhere in flight (waiting + running).
    pub fn active(&self) -> usize {
        self.waiting.len() + self.running.len()
    }

    pub fn stats(&self) -> &ServeStats {
        &self.stats
    }

    /// Enqueue one generation request; returns its id.  `max_new` caps
    /// this request's generated tokens (`None` = policy default); the
    /// model's context bound clamps it either way.  Rejection (queue cap,
    /// malformed prompt, no room to generate) is per-request — a bad
    /// prompt can never fail a shared decode step.
    pub fn submit(&mut self, prompt: Vec<i32>, max_new: Option<usize>,
                  now: Duration) -> crate::Result<u64> {
        self.submit_with_deadline(prompt, max_new, now, None)
    }

    /// [`DecodeEngine::submit`] with an engine-relative deadline: past
    /// this instant the request is dropped with
    /// [`FinishReason::Deadline`] — from the waiting queue before any
    /// prefill, or from the running batch with its partial tokens.
    pub fn submit_with_deadline(&mut self, prompt: Vec<i32>, max_new: Option<usize>,
                                now: Duration,
                                deadline: Option<Duration>) -> crate::Result<u64> {
        if let Some(cap) = self.policy.queue_cap {
            crate::ensure!(
                self.waiting.len() < cap,
                "admission queue full ({cap} waiting); request shed"
            );
        }
        self.model.validate_prompt(&prompt)?;
        let bound = self.model.max_seq_len();
        crate::ensure!(
            prompt.len() < bound,
            "prompt of {} tokens leaves no room to generate within the {bound}-token context",
            prompt.len()
        );
        let requested = max_new.unwrap_or(self.policy.max_new_tokens);
        crate::ensure!(requested >= 1, "max_new_tokens must be at least 1");
        // A request may lower the cap, never raise it past the policy's;
        // the model's context bound applies either way.
        let max_new = requested
            .min(self.policy.max_new_tokens)
            .min(bound - prompt.len());
        let id = self.next_id;
        self.next_id += 1;
        self.waiting.push_back(WaitingGen { id, prompt, max_new, submitted: now, deadline });
        Ok(id)
    }

    /// Request cancellation of an in-flight generation.  The next `step`
    /// finishes it with [`FinishReason::Cancelled`] — no tokens if it was
    /// still waiting (no prefill spent), the partial tokens so far if it
    /// was running — and frees its sequence, returning its KV blocks and
    /// prefix-cache references to the pool.  Returns `false` when `id` is
    /// not in flight (unknown, already finished, or already delivered):
    /// cancelling is then a no-op, never an error.
    pub fn cancel(&mut self, id: u64) -> bool {
        let in_flight = self.waiting.iter().any(|w| w.id == id)
            || self.running.iter().any(|r| r.id == id);
        if in_flight {
            self.cancelled.insert(id);
        }
        in_flight
    }

    /// Advance the world: admit waiting requests into free slots (one
    /// prefill each, first token sampled), then run ONE coalesced decode
    /// step over the running batch.  Returns the generations that
    /// finished during this step (possibly empty).  A prefill error
    /// re-queues the request at the head and surfaces (after delivering
    /// any generations that already finished this step); a decode-step
    /// error fails the running batch (sequences freed, error returned)
    /// but leaves the engine serviceable.
    pub fn step(&mut self, now: Duration) -> crate::Result<Vec<Generation>> {
        let mut done = Vec::new();
        let mut admit_err: Option<crate::Error> = None;
        // Cancellation + deadline expiry, waiting side: a cancelled or
        // expired request leaves the queue unserved (no prefill compute)
        // — rotate the queue once so survivor order is preserved.
        for _ in 0..self.waiting.len() {
            let req = self.waiting.pop_front().expect("length-bounded loop");
            let finish = if self.cancelled.remove(&req.id) {
                self.stats.record_cancelled(1);
                Some(FinishReason::Cancelled)
            } else if matches!(req.deadline, Some(d) if now >= d) {
                self.stats.record_deadline_expired(1);
                Some(FinishReason::Deadline)
            } else {
                None
            };
            match finish {
                Some(finish) => {
                    let queued = now.saturating_sub(req.submitted);
                    done.push(Generation {
                        id: req.id,
                        prompt_len: req.prompt.len(),
                        tokens: Vec::new(),
                        finish,
                        queued,
                        latency: queued,
                        prefill_tokens_saved: 0,
                    });
                }
                None => self.waiting.push_back(req),
            }
        }
        // Admission: prefill into free slots — sequences join the running
        // batch mid-stream, the "continuous" in continuous batching.
        while self.running.len() < self.policy.max_batch {
            let Some(req) = self.waiting.pop_front() else { break };
            let t0 = Instant::now();
            let seq = match self.model.prefill(&req.prompt, &mut self.logits) {
                Ok(seq) => seq,
                Err(e) => {
                    // Don't lose the request: back to the head of the
                    // queue.  KV-pool exhaustion with sequences still
                    // running is backpressure, not failure — running
                    // sequences free blocks as they finish, so the next
                    // step retries silently.  Anything else (including
                    // exhaustion with nothing running, which could never
                    // clear) surfaces as an error; the next step retries,
                    // so a transient failure self-heals and a persistent
                    // one keeps erroring visibly.
                    self.waiting.push_front(req);
                    if crate::runtime::is_pool_exhausted(&e) && !self.running.is_empty() {
                        break;
                    }
                    admit_err = Some(e);
                    break;
                }
            };
            let compute = t0.elapsed();
            self.stats.record_prefill(now, compute);
            let mut run = RunningGen {
                id: req.id,
                seq,
                prompt_len: req.prompt.len(),
                max_new: req.max_new,
                submitted: req.submitted,
                queued: now.saturating_sub(req.submitted),
                deadline: req.deadline,
                tokens: Vec::with_capacity(req.max_new),
                rng: Rng::seed_from_u64(
                    self.policy.seed ^ req.id.wrapping_mul(0x9E37_79B9_7F4A_7C15),
                ),
                prefill_saved: self.model.last_prefill_tokens_saved(),
            };
            let first = self.policy.sampler.sample(self.logits.row(0), &mut run.rng);
            run.tokens.push(first);
            match finish_of(&run, self.policy.eos) {
                Some(reason) => {
                    // Best-effort: a free failure must not discard the
                    // finished generation (the slab rejects double frees
                    // on its own).
                    let _ = self.model.free_seq(run.seq);
                    done.push(complete(run, reason, now, compute));
                }
                None => self.running.push(run),
            }
        }
        if let Some(e) = admit_err {
            // Deliver any work that already completed this step; with
            // nothing to deliver, surface the prefill failure now (it
            // resurfaces on the next step either way — the request is
            // still at the head of the queue).
            if done.is_empty() {
                return Err(e);
            }
            for g in &done {
                if served(g.finish) {
                    self.stats.record_generation(g.latency);
                }
            }
            return Ok(done);
        }
        // Cancellation + deadline expiry, running side: drop cancelled or
        // expired sequences (partial tokens delivered, KV slot freed)
        // before spending a coalesced decode step on them.
        let mut i = 0;
        while i < self.running.len() {
            if self.cancelled.remove(&self.running[i].id) {
                // `remove` keeps batch order stable, like the finish path.
                let run = self.running.remove(i);
                let _ = self.model.free_seq(run.seq);
                self.stats.record_cancelled(1);
                done.push(complete(run, FinishReason::Cancelled, now, Duration::ZERO));
            } else if matches!(self.running[i].deadline, Some(d) if now >= d) {
                let run = self.running.remove(i);
                let _ = self.model.free_seq(run.seq);
                self.stats.record_deadline_expired(1);
                done.push(complete(run, FinishReason::Deadline, now, Duration::ZERO));
            } else {
                i += 1;
            }
        }
        // One coalesced decode step over every running sequence.
        if !self.running.is_empty() {
            self.step_seqs.clear();
            self.step_tokens.clear();
            for r in &self.running {
                self.step_seqs.push(r.seq);
                self.step_tokens.push(*r.tokens.last().expect("running implies a token"));
            }
            let t0 = Instant::now();
            if let Err(e) =
                self.model.decode_step(&self.step_seqs, &self.step_tokens, &mut self.logits)
            {
                // Fail the batch but keep the engine serviceable.
                for r in self.running.drain(..) {
                    let _ = self.model.free_seq(r.seq);
                }
                return Err(e);
            }
            let compute = t0.elapsed();
            let k = self.step_seqs.len();
            self.stats.record_decode_step(now, compute, k);
            // `row` walks the fixed logits rows (the step's original
            // batch order); `i` tracks the shrinking `running` vec — a
            // mid-batch removal must not shift later sequences onto the
            // wrong logits row.
            let mut i = 0;
            for row in 0..k {
                {
                    let run = &mut self.running[i];
                    let tok =
                        self.policy.sampler.sample(self.logits.row(row), &mut run.rng);
                    run.tokens.push(tok);
                }
                match finish_of(&self.running[i], self.policy.eos) {
                    Some(reason) => {
                        // `remove` (not swap_remove) keeps batch order
                        // stable, so step composition stays deterministic.
                        let run = self.running.remove(i);
                        // Best-effort free: never discard a finished
                        // generation over a release failure.
                        let _ = self.model.free_seq(run.seq);
                        done.push(complete(run, reason, now, compute));
                    }
                    None => i += 1,
                }
            }
            // Mid-generation deadline enforcement: a budget that lapsed
            // *during* this coalesced step finishes its sequence now —
            // partial tokens delivered, KV blocks freed immediately for
            // waiting admissions — instead of spending another step's
            // compute before the entry sweep would catch it.
            let eff = now + compute;
            let mut i = 0;
            while i < self.running.len() {
                if matches!(self.running[i].deadline, Some(d) if eff >= d) {
                    let run = self.running.remove(i);
                    let _ = self.model.free_seq(run.seq);
                    self.stats.record_deadline_expired(1);
                    done.push(complete(run, FinishReason::Deadline, now, compute));
                } else {
                    i += 1;
                }
            }
        }
        if let Some(ps) = self.model.kv_pool_stats() {
            self.stats.record_kv_pool(&ps);
        }
        if let Some(pc) = self.model.prefix_cache_stats() {
            self.stats.record_prefix_cache(&pc);
        }
        for g in &done {
            if served(g.finish) {
                self.stats.record_generation(g.latency);
            }
        }
        Ok(done)
    }

    /// Drive every in-flight request to completion on the real clock —
    /// the `slope generate` loop.
    pub fn run_to_completion(&mut self, start: Instant) -> crate::Result<Vec<Generation>> {
        let mut out = Vec::new();
        while self.active() > 0 {
            out.extend(self.step(start.elapsed())?);
        }
        Ok(out)
    }
}

/// Whether a finish reason counts toward `served` (vs the shed paths —
/// deadline expiry and client cancellation — tallied separately).
fn served(finish: FinishReason) -> bool {
    !matches!(finish, FinishReason::Deadline | FinishReason::Cancelled)
}

fn finish_of(run: &RunningGen, eos: Option<i32>) -> Option<FinishReason> {
    let last = *run.tokens.last().expect("at least one sampled token");
    if eos == Some(last) {
        return Some(FinishReason::Eos);
    }
    if run.tokens.len() >= run.max_new {
        return Some(FinishReason::MaxTokens);
    }
    None
}

fn complete(run: RunningGen, finish: FinishReason, now: Duration,
            compute: Duration) -> Generation {
    Generation {
        id: run.id,
        prompt_len: run.prompt_len,
        tokens: run.tokens,
        finish,
        queued: run.queued,
        latency: now.saturating_sub(run.submitted) + compute,
        prefill_tokens_saved: run.prefill_saved,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{gemm_nt, ParallelPolicy, SparseBackend, SpmmAlgo};
    use crate::serve::model::LoraAdapter;
    use crate::sparsity::{random_row_mask, NmScheme};
    use crate::util::Rng;

    const MS: Duration = Duration::from_millis(1);

    fn layer(d_out: usize, d_in: usize, rank: usize, threads: usize,
             rng: &mut Rng) -> ServeLayer {
        let w = Matrix::randn(d_out, d_in, 1.0, rng);
        let mask = random_row_mask(d_out, d_in, NmScheme::TWO_FOUR, rng);
        let be = SparseBackend::setup(&w, mask, NmScheme::TWO_FOUR, SpmmAlgo::RowMajor,
                                      ParallelPolicy::with_threads(threads));
        let lora = (rank > 0).then(|| LoraAdapter {
            up: Matrix::randn(d_out, rank, 0.3, rng),
            down: Matrix::randn(rank, d_in, 0.3, rng),
        });
        ServeLayer::new(be, lora).unwrap()
    }

    /// Dense reference for one layer stack: `x · (W masked)ᵀ + x·Rᵀ·Lᵀ`.
    fn reference(layers: &[ServeLayer], x: &Matrix) -> Matrix {
        let mut cur = x.clone();
        for l in layers {
            let mut y = gemm_nt(&cur, &l.backend.dense_weight());
            if let Some(a) = &l.lora {
                let t = gemm_nt(&cur, &a.down);
                let y2 = gemm_nt(&t, &a.up);
                for (o, v) in y.data.iter_mut().zip(&y2.data) {
                    *o += v;
                }
            }
            cur = y;
        }
        cur
    }

    #[test]
    fn engine_output_matches_dense_reference() {
        let mut rng = Rng::seed_from_u64(0);
        let layers = vec![layer(24, 16, 4, 2, &mut rng), layer(16, 24, 0, 2, &mut rng)];
        let x = Matrix::randn(3, 16, 1.0, &mut rng);
        let want = reference(&layers, &x);
        let mut eng =
            ServeEngine::new(layers, BatchPolicy::new(3, Duration::from_millis(1))).unwrap();
        for r in 0..3 {
            eng.submit(x.row(r).to_vec(), Duration::ZERO).unwrap();
        }
        let resp = eng.poll(Duration::ZERO).unwrap();
        assert_eq!(resp.len(), 3, "full batch dispatches at once");
        for (row, r) in resp.iter().enumerate() {
            let got = Matrix::from_vec(1, want.cols, r.output.clone());
            let wrow = Matrix::from_vec(1, want.cols, want.row(row).to_vec());
            assert!(got.max_abs_diff(&wrow) < 1e-4, "row {row}");
        }
    }

    #[test]
    fn mismatched_dims_rejected() {
        let mut rng = Rng::seed_from_u64(1);
        let layers = vec![layer(24, 16, 0, 1, &mut rng), layer(16, 32, 0, 1, &mut rng)];
        assert!(ServeEngine::new(layers, BatchPolicy::default()).is_err());
        let mut eng = ServeEngine::new(vec![layer(8, 16, 0, 1, &mut rng)],
                                       BatchPolicy::default())
            .unwrap();
        assert!(eng.submit(vec![0.0; 7], Duration::ZERO).is_err());
    }

    #[test]
    fn coalescing_honors_max_batch_and_max_wait() {
        let mut rng = Rng::seed_from_u64(2);
        let mut eng = ServeEngine::new(vec![layer(8, 16, 0, 1, &mut rng)],
                                       BatchPolicy::new(4, 10 * MS))
            .unwrap();
        // Three requests at t=0: not a full batch, wait below max_wait.
        for _ in 0..3 {
            eng.submit(vec![0.5; 16], Duration::ZERO).unwrap();
        }
        assert!(eng.poll(5 * MS).unwrap().is_empty(),
                "partial batch below max_wait holds");
        // Fourth request completes the batch: dispatch on the next poll.
        eng.submit(vec![0.5; 16], 6 * MS).unwrap();
        let r = eng.poll(6 * MS).unwrap();
        assert_eq!(r.len(), 4, "max_batch reached ⇒ immediate dispatch");
        assert_eq!(r[0].queued, 6 * MS);
        assert_eq!(r[3].queued, Duration::ZERO);
        // Two stragglers: held until the oldest has waited max_wait.
        eng.submit(vec![0.5; 16], 8 * MS).unwrap();
        eng.submit(vec![0.5; 16], 9 * MS).unwrap();
        assert!(eng.poll(17 * MS).unwrap().is_empty(), "9 ms < max_wait");
        let r = eng.poll(18 * MS).unwrap();
        assert_eq!(r.len(), 2, "max_wait exceeded ⇒ partial dispatch");
        assert!(r[0].queued >= 10 * MS);
        assert_eq!(eng.pending(), 0);
        assert_eq!(eng.stats().served(), 6);
    }

    #[test]
    fn steady_state_reuses_buffers() {
        let mut rng = Rng::seed_from_u64(3);
        let mut eng = ServeEngine::new(vec![layer(32, 16, 4, 2, &mut rng),
                                            layer(16, 32, 0, 2, &mut rng)],
                                       BatchPolicy::new(2, MS))
            .unwrap();
        for _ in 0..2 {
            eng.submit(vec![0.1; 16], Duration::ZERO).unwrap();
        }
        eng.poll(Duration::ZERO).unwrap();
        let staging_ptr = eng.staging.data.as_ptr();
        let out_ptr = eng.out.data.as_ptr();
        let buf_ptr = eng.model().buf_ptr();
        for _ in 0..2 {
            eng.submit(vec![0.2; 16], MS).unwrap();
        }
        eng.poll(MS).unwrap();
        assert_eq!(eng.staging.data.as_ptr(), staging_ptr, "staging must not realloc");
        assert_eq!(eng.out.data.as_ptr(), out_ptr, "output must not realloc");
        assert_eq!(eng.model().buf_ptr(), buf_ptr, "activation buffer must not realloc");
    }

    #[test]
    fn flush_drains_everything() {
        let mut rng = Rng::seed_from_u64(4);
        let mut eng = ServeEngine::new(vec![layer(8, 16, 0, 1, &mut rng)],
                                       BatchPolicy::new(4, Duration::from_secs(1)))
            .unwrap();
        for _ in 0..10 {
            eng.submit(vec![1.0; 16], Duration::ZERO).unwrap();
        }
        let r = eng.flush(MS).unwrap();
        assert_eq!(r.len(), 10);
        assert_eq!(eng.pending(), 0);
        let s = eng.stats().summary();
        assert_eq!(s.batches, 3, "10 requests at max_batch 4 ⇒ 4+4+2");
    }

    /// Deterministic fake decode model: logits are one-hot at
    /// `(last_token + 1) % vocab`, so greedy generation counts upward —
    /// every scheduling edge is predictable.
    struct Arith {
        seqs: Vec<Option<(i32, usize)>>,
    }

    impl Arith {
        const VOCAB: usize = 16;
        const MAX_SEQ: usize = 8;

        fn new() -> Self {
            Self { seqs: Vec::new() }
        }

        fn one_hot(tok: i32, row: &mut [f32]) {
            row.fill(0.0);
            row[(tok as usize + 1) % Self::VOCAB] = 1.0;
        }
    }

    impl DecodeModel for Arith {
        fn vocab(&self) -> usize {
            Self::VOCAB
        }
        fn max_seq_len(&self) -> usize {
            Self::MAX_SEQ
        }
        fn validate_prompt(&self, prompt: &[i32]) -> crate::Result<()> {
            crate::ensure!(!prompt.is_empty(), "empty prompt");
            crate::ensure!(prompt.len() <= Self::MAX_SEQ, "prompt too long");
            Ok(())
        }
        fn prefill(&mut self, prompt: &[i32], logits: &mut Matrix) -> crate::Result<SeqId> {
            ensure_out(logits, 1, Self::VOCAB);
            let last = *prompt.last().expect("validated");
            Self::one_hot(last, logits.row_mut(0));
            self.seqs.push(Some((last, prompt.len())));
            Ok((self.seqs.len() - 1) as SeqId)
        }
        fn decode_step(&mut self, seqs: &[SeqId], tokens: &[i32],
                       logits: &mut Matrix) -> crate::Result<()> {
            ensure_out(logits, seqs.len(), Self::VOCAB);
            for (i, (&id, &tok)) in seqs.iter().zip(tokens).enumerate() {
                let st = self.seqs[id as usize]
                    .as_mut()
                    .ok_or_else(|| crate::eyre!("freed seq {id}"))?;
                crate::ensure!(st.1 < Self::MAX_SEQ, "context full");
                *st = (tok, st.1 + 1);
                Self::one_hot(tok, logits.row_mut(i));
            }
            Ok(())
        }
        fn free_seq(&mut self, seq: SeqId) -> crate::Result<()> {
            crate::ensure!(self.seqs[seq as usize].take().is_some(), "double free");
            Ok(())
        }
        fn seq_tokens(&self, seq: SeqId) -> Option<usize> {
            self.seqs.get(seq as usize).and_then(|s| s.map(|x| x.1))
        }
        fn live_seqs(&self) -> usize {
            self.seqs.iter().filter(|s| s.is_some()).count()
        }
        fn describe_decode(&self) -> String {
            "arith".into()
        }
    }

    #[test]
    fn decode_engine_generates_caps_and_stops_on_eos() {
        let policy = DecodePolicy { max_batch: 2, max_new_tokens: 4, ..Default::default() };
        let mut eng = DecodeEngine::new(Arith::new(), policy).unwrap();
        // Greedy from prompt [3]: 4, 5, 6, 7 — capped at max_new 4.
        eng.submit(vec![3], None, Duration::ZERO).unwrap();
        // Per-request cap of 2: 4, 5.
        eng.submit(vec![3], Some(2), Duration::ZERO).unwrap();
        // Third waits for a slot (max_batch 2), then generates 11, 12.
        eng.submit(vec![10], Some(2), Duration::ZERO).unwrap();
        let mut done = Vec::new();
        let start = Instant::now();
        while eng.active() > 0 {
            done.extend(eng.step(start.elapsed()).unwrap());
        }
        done.sort_by_key(|g| g.id);
        assert_eq!(done.len(), 3);
        assert_eq!(done[0].tokens, vec![4, 5, 6, 7]);
        assert_eq!(done[0].finish, FinishReason::MaxTokens);
        assert_eq!(done[1].tokens, vec![4, 5]);
        assert_eq!(done[2].tokens, vec![11, 12]);
        assert_eq!(eng.model().live_seqs(), 0, "every sequence freed");
        let s = eng.stats().summary();
        assert_eq!(s.served, 3);
        assert_eq!(s.prefills, 3);
        // 8 generated tokens total; the first of each came from prefill.
        assert_eq!(s.tokens_out, 8 - 3);
        // EOS: from [3] with eos 6 → 4, 5, 6 (inclusive).
        let policy = DecodePolicy { max_batch: 2, max_new_tokens: 8, eos: Some(6),
                                    ..Default::default() };
        let mut eng = DecodeEngine::new(Arith::new(), policy).unwrap();
        eng.submit(vec![3], None, Duration::ZERO).unwrap();
        let mut done = Vec::new();
        while eng.active() > 0 {
            done.extend(eng.step(Duration::ZERO).unwrap());
        }
        assert_eq!(done[0].tokens, vec![4, 5, 6]);
        assert_eq!(done[0].finish, FinishReason::Eos);
    }

    #[test]
    fn decode_engine_queue_cap_sheds_and_context_bound_clamps() {
        let policy = DecodePolicy { max_batch: 1, queue_cap: Some(2), ..Default::default() };
        let mut eng = DecodeEngine::new(Arith::new(), policy).unwrap();
        eng.submit(vec![1], None, Duration::ZERO).unwrap();
        eng.submit(vec![1], None, Duration::ZERO).unwrap();
        let err = eng.submit(vec![1], None, Duration::ZERO).unwrap_err();
        assert!(err.to_string().contains("queue full"), "{err}");
        // A full-context prompt leaves no room to generate.
        let long = vec![0i32; Arith::MAX_SEQ];
        assert!(eng.submit(long, None, Duration::ZERO).is_err());
        // A 7-token prompt in an 8-token context clamps max_new to 1.
        let mut eng =
            DecodeEngine::new(Arith::new(), DecodePolicy::default()).unwrap();
        eng.submit(vec![0; 7], Some(5), Duration::ZERO).unwrap();
        let mut done = Vec::new();
        while eng.active() > 0 {
            done.extend(eng.step(Duration::ZERO).unwrap());
        }
        assert_eq!(done[0].tokens.len(), 1, "context bound clamps the request cap");
    }

    #[test]
    fn expired_requests_skip_compute_and_flag_the_response() {
        let mut rng = Rng::seed_from_u64(5);
        let mut eng = ServeEngine::new(vec![layer(8, 16, 0, 1, &mut rng)],
                                       BatchPolicy::new(4, MS))
            .unwrap();
        // One request with a 3 ms budget, one without; dispatch at 10 ms:
        // the budgeted one expires, the other is served.
        let stale = eng
            .submit_with_deadline(vec![0.5; 16], Duration::ZERO, Some(3 * MS))
            .unwrap();
        let fresh = eng.submit(vec![0.5; 16], Duration::ZERO).unwrap();
        let mut r = eng.poll(10 * MS).unwrap();
        assert_eq!(r.len(), 2);
        r.sort_by_key(|resp| resp.id);
        assert_eq!(r[0].id, stale);
        assert!(r[0].deadline_expired);
        assert!(r[0].output.is_empty(), "no compute spent on an expired request");
        assert_eq!(r[1].id, fresh);
        assert!(!r[1].deadline_expired);
        assert_eq!(r[1].output.len(), 8);
        let s = eng.stats().summary();
        assert_eq!(s.deadline_expired, 1);
        assert_eq!(s.served, 1, "the expired request is not served");
        // An all-expired batch never calls the model.
        eng.submit_with_deadline(vec![0.5; 16], 20 * MS, Some(21 * MS)).unwrap();
        let r = eng.flush(30 * MS).unwrap();
        assert!(r.iter().all(|resp| resp.deadline_expired));
        assert_eq!(eng.stats().summary().deadline_expired, 2);
        assert_eq!(eng.stats().summary().batches, 1, "no batch dispatched for it");
    }

    #[test]
    fn decode_deadline_drops_waiting_and_running_sequences() {
        // Waiting-side expiry: max_batch 1, so the second request queues;
        // its 2 ms budget lapses before a slot frees.
        let policy = DecodePolicy { max_batch: 1, max_new_tokens: 4, ..Default::default() };
        let mut eng = DecodeEngine::new(Arith::new(), policy).unwrap();
        let slow = eng.submit(vec![3], None, Duration::ZERO).unwrap();
        let starved = eng
            .submit_with_deadline(vec![9], None, Duration::ZERO, Some(2 * MS))
            .unwrap();
        let mut done = Vec::new();
        let mut t = Duration::ZERO;
        while eng.active() > 0 {
            done.extend(eng.step(t).unwrap());
            t += 5 * MS;
        }
        done.sort_by_key(|g| g.id);
        assert_eq!(done.len(), 2);
        assert_eq!(done[0].id, slow);
        assert_eq!(done[0].finish, FinishReason::MaxTokens);
        assert_eq!(done[0].tokens, vec![4, 5, 6, 7]);
        assert_eq!(done[1].id, starved);
        assert_eq!(done[1].finish, FinishReason::Deadline);
        assert!(done[1].tokens.is_empty(), "expired before any prefill");
        assert_eq!(eng.model().live_seqs(), 0, "every sequence freed");
        let s = eng.stats().summary();
        assert_eq!(s.deadline_expired, 1);
        assert_eq!(s.served, 1, "deadline drops are not served");
        assert_eq!(s.prefills, 1, "the starved request never prefilled");

        // Running-side expiry: a 7 ms budget lapses mid-generation — the
        // partial stream is delivered and the KV slot freed.
        let policy = DecodePolicy { max_batch: 2, max_new_tokens: 8, ..Default::default() };
        let mut eng = DecodeEngine::new(Arith::new(), policy).unwrap();
        eng.submit_with_deadline(vec![3], None, Duration::ZERO, Some(7 * MS)).unwrap();
        let mut done = Vec::new();
        done.extend(eng.step(Duration::ZERO).unwrap()); // prefill 4, decode 5
        done.extend(eng.step(5 * MS).unwrap()); // decode: token 6
        done.extend(eng.step(10 * MS).unwrap()); // expired before decoding
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].finish, FinishReason::Deadline);
        assert_eq!(done[0].tokens, vec![4, 5, 6], "partial tokens survive expiry");
        assert_eq!(eng.active(), 0);
        assert_eq!(eng.model().live_seqs(), 0, "expired sequence freed");
        assert_eq!(eng.stats().summary().deadline_expired, 1);
    }

    #[test]
    fn deadline_lapsing_during_a_step_finishes_mid_generation() {
        let policy = DecodePolicy { max_batch: 2, max_new_tokens: 8, ..Default::default() };
        let mut eng = DecodeEngine::new(Arith::new(), policy).unwrap();
        eng.submit_with_deadline(vec![3], None, Duration::ZERO,
                                 Some(Duration::from_nanos(1)))
            .unwrap();
        // The clock is pinned at zero, so the waiting- and running-side
        // entry sweeps (which compare against `now`) never fire; only the
        // post-step check, which adds the measured compute, can expire it.
        let done = eng.step(Duration::ZERO).unwrap();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].finish, FinishReason::Deadline);
        assert_eq!(done[0].tokens, vec![4, 5], "partial tokens survive expiry");
        assert_eq!(eng.active(), 0);
        assert_eq!(eng.model().live_seqs(), 0, "sequence state freed immediately");
        let s = eng.stats().summary();
        assert_eq!(s.deadline_expired, 1);
        assert_eq!(s.served, 0, "a mid-generation expiry is not served");
    }

    #[test]
    fn cancel_drops_waiting_and_running_requests() {
        let policy = DecodePolicy { max_batch: 1, max_new_tokens: 8, ..Default::default() };
        let mut eng = DecodeEngine::new(Arith::new(), policy).unwrap();
        let run = eng.submit(vec![3], None, Duration::ZERO).unwrap();
        let queued = eng.submit(vec![9], None, Duration::ZERO).unwrap();
        assert!(!eng.cancel(999), "unknown id is a no-op");
        // Step once: `run` admits and decodes (tokens 4, 5); `queued`
        // waits behind max_batch 1.
        assert!(eng.step(Duration::ZERO).unwrap().is_empty());
        // Cancel the waiting request: it leaves on the next step with no
        // tokens and no prefill spent.
        assert!(eng.cancel(queued));
        let done = eng.step(Duration::ZERO).unwrap();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].id, queued);
        assert_eq!(done[0].finish, FinishReason::Cancelled);
        assert!(done[0].tokens.is_empty(), "cancelled before any prefill");
        // Cancel the running request mid-generation: the partial stream
        // is delivered and its sequence freed before the next decode.
        assert!(eng.cancel(run));
        let done = eng.step(Duration::ZERO).unwrap();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].id, run);
        assert_eq!(done[0].finish, FinishReason::Cancelled);
        assert_eq!(done[0].tokens, vec![4, 5, 6], "partial tokens survive cancel");
        assert_eq!(done[0].prefill_tokens_saved, 0, "no cache on this model");
        assert_eq!(eng.active(), 0);
        assert_eq!(eng.model().live_seqs(), 0, "cancelled sequence freed");
        assert!(!eng.cancel(run), "already delivered: no-op");
        let s = eng.stats().summary();
        assert_eq!(s.cancelled, 2);
        assert_eq!(s.served, 0, "cancellations are not served");
        assert_eq!(s.prefills, 1, "the queued request never prefilled");
    }

    /// [`Arith`] behind a `cap`-sequence "pool": a prefill past the cap
    /// fails with the pool-exhaustion marker, like a bounded
    /// [`crate::runtime::KvBlockPool`].
    struct CappedArith {
        inner: Arith,
        cap: usize,
    }

    impl DecodeModel for CappedArith {
        fn vocab(&self) -> usize {
            self.inner.vocab()
        }
        fn max_seq_len(&self) -> usize {
            self.inner.max_seq_len()
        }
        fn validate_prompt(&self, prompt: &[i32]) -> crate::Result<()> {
            self.inner.validate_prompt(prompt)
        }
        fn prefill(&mut self, prompt: &[i32], logits: &mut Matrix) -> crate::Result<SeqId> {
            crate::ensure!(
                self.inner.live_seqs() < self.cap,
                "kv pool exhausted: 0 free of {} block(s)",
                self.cap
            );
            self.inner.prefill(prompt, logits)
        }
        fn decode_step(&mut self, seqs: &[SeqId], tokens: &[i32],
                       logits: &mut Matrix) -> crate::Result<()> {
            self.inner.decode_step(seqs, tokens, logits)
        }
        fn free_seq(&mut self, seq: SeqId) -> crate::Result<()> {
            self.inner.free_seq(seq)
        }
        fn seq_tokens(&self, seq: SeqId) -> Option<usize> {
            self.inner.seq_tokens(seq)
        }
        fn live_seqs(&self) -> usize {
            self.inner.live_seqs()
        }
        fn describe_decode(&self) -> String {
            "capped-arith".into()
        }
    }

    #[test]
    fn pool_exhaustion_backpressures_instead_of_failing_the_queue() {
        // A 1-sequence pool under a 4-wide batch policy: the second
        // request's prefill hits the exhausted pool and must wait (no
        // error) until the first sequence finishes and frees its blocks.
        let policy = DecodePolicy { max_batch: 4, max_new_tokens: 2, ..Default::default() };
        let mut eng =
            DecodeEngine::new(CappedArith { inner: Arith::new(), cap: 1 }, policy).unwrap();
        let a = eng.submit(vec![3], None, Duration::ZERO).unwrap();
        let b = eng.submit(vec![9], None, Duration::ZERO).unwrap();
        let mut done = Vec::new();
        let mut rounds = 0;
        while eng.active() > 0 {
            done.extend(eng.step(Duration::ZERO).unwrap());
            rounds += 1;
            assert!(rounds < 20, "backpressure must converge");
        }
        done.sort_by_key(|g| g.id);
        assert_eq!(done.len(), 2, "both requests complete serially");
        assert_eq!((done[0].id, done[0].tokens.clone()), (a, vec![4, 5]));
        assert_eq!((done[1].id, done[1].tokens.clone()), (b, vec![10, 11]));
        assert_eq!(eng.stats().summary().served, 2);
        // With nothing running, the same failure could never clear — it
        // surfaces as an error instead of spinning forever.
        let mut eng =
            DecodeEngine::new(CappedArith { inner: Arith::new(), cap: 0 }, policy).unwrap();
        eng.submit(vec![3], None, Duration::ZERO).unwrap();
        let err = eng.step(Duration::ZERO).unwrap_err();
        assert!(crate::runtime::is_pool_exhausted(&err), "{err}");
    }

    #[test]
    fn model_batch_cap_clamps_policy() {
        struct Tiny;
        impl ServeModel for Tiny {
            fn d_in(&self) -> usize {
                2
            }
            fn d_out(&self) -> usize {
                2
            }
            fn forward_batch_into(&mut self, x: &Matrix, y: &mut Matrix) -> crate::Result<()> {
                ensure_out(y, x.rows, 2);
                y.data.copy_from_slice(&x.data);
                Ok(())
            }
            fn max_batch(&self) -> Option<usize> {
                Some(3)
            }
            fn describe(&self) -> String {
                "tiny".into()
            }
        }
        let mut eng = ServeEngine::with_model(Tiny, BatchPolicy::new(64, MS)).unwrap();
        assert_eq!(eng.policy().max_batch, 3, "policy clamps to the compiled batch");
        for _ in 0..7 {
            eng.submit(vec![1.0, 2.0], Duration::ZERO).unwrap();
        }
        let r = eng.flush(Duration::ZERO).unwrap();
        assert_eq!(r.len(), 7);
        assert_eq!(eng.stats().summary().batches, 3, "7 at cap 3 ⇒ 3+3+1");
    }
}
