//! The serving engine: warm sparse layers + coalescing batcher + stats.
//!
//! [`ServeEngine`] owns a stack of [`ServeLayer`]s — each a warm
//! [`SparseBackend`] (compressed weight + workspace + kernel policy) with
//! an optional fused LoRA adapter — and drives coalesced forward batches
//! through them with zero steady-state allocations: the input staging
//! matrix, every layer's activation buffer, and the LoRA rank staging are
//! grown once at the first batch of a given fill and reused thereafter.
//!
//! The engine is clocked externally (`now` = [`Duration`] since engine
//! start): [`ServeEngine::submit`] enqueues, [`ServeEngine::poll`]
//! dispatches at most one batch when the [`Batcher`] says one is due, and
//! [`ServeEngine::flush`] drains.  Latency = queue wait (virtual, from
//! the caller's clock) + compute (measured).  The CLI (`slope serve`) and
//! `examples/inference_serve.rs` drive it with `start.elapsed()`; tests
//! drive it with synthetic timelines.

use crate::backend::{ensure_out, lora_fused_seq, SparseBackend};
use crate::serve::batcher::{BatchPolicy, Batcher, Request};
use crate::serve::stats::ServeStats;
use crate::tensor::Matrix;
use std::time::{Duration, Instant};

/// A LoRA adapter pair for one layer (Eq. 11): `L: (d_out, r)`,
/// `R: (r, d_in)`.
#[derive(Clone, Debug)]
pub struct LoraAdapter {
    pub up: Matrix,
    pub down: Matrix,
}

/// One serving layer: a warm sparse weight and an optional adapter.
pub struct ServeLayer {
    pub backend: SparseBackend,
    pub lora: Option<LoraAdapter>,
    /// Rank staging for the fused LoRA path (grown once).
    t: Matrix,
}

impl ServeLayer {
    pub fn new(backend: SparseBackend, lora: Option<LoraAdapter>) -> crate::Result<Self> {
        if let Some(l) = &lora {
            crate::ensure!(
                l.up.rows == backend.w.rows && l.down.cols == backend.w.cols
                    && l.up.cols == l.down.rows,
                "lora shapes (up {}x{}, down {}x{}) do not fit layer {}x{}",
                l.up.rows, l.up.cols, l.down.rows, l.down.cols,
                backend.w.rows, backend.w.cols
            );
        }
        Ok(Self { backend, lora, t: Matrix::zeros(0, 0) })
    }

    pub fn d_in(&self) -> usize {
        self.backend.w.cols
    }

    pub fn d_out(&self) -> usize {
        self.backend.w.rows
    }

    /// `y = x · Wᵀ (+ x · Rᵀ · Lᵀ)` into a caller-owned output — the
    /// Eq.-11 fused serving sequence ([`lora_fused_seq`], shared with the
    /// backend workspace path) through reusable buffers.
    fn forward_into(&mut self, x: &Matrix, y: &mut Matrix) {
        match &self.lora {
            Some(l) => lora_fused_seq(self.backend.algo, &self.backend.policy, &self.backend.w,
                                      x, &l.up, &l.down, &mut self.t, y),
            None => self.backend.forward_into(x, y),
        }
    }
}

/// A completed request.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub output: Vec<f32>,
    /// Time spent coalescing in the queue.
    pub queued: Duration,
    /// Queue wait + batch compute.
    pub latency: Duration,
}

/// The serving engine (see module docs).
pub struct ServeEngine {
    layers: Vec<ServeLayer>,
    batcher: Batcher,
    stats: ServeStats,
    staging: Matrix,
    /// Ping-pong activation buffers between layers.
    bufs: [Matrix; 2],
    next_id: u64,
}

impl ServeEngine {
    /// Build an engine over a validated layer stack (each layer's `d_in`
    /// must equal the previous layer's `d_out`).
    pub fn new(layers: Vec<ServeLayer>, policy: BatchPolicy) -> crate::Result<Self> {
        crate::ensure!(!layers.is_empty(), "serve engine needs at least one layer");
        for pair in layers.windows(2) {
            crate::ensure!(
                pair[1].d_in() == pair[0].d_out(),
                "layer dims do not chain: {} -> {}",
                pair[0].d_out(),
                pair[1].d_in()
            );
        }
        Ok(Self {
            layers,
            batcher: Batcher::new(policy),
            stats: ServeStats::default(),
            staging: Matrix::zeros(0, 0),
            bufs: [Matrix::zeros(0, 0), Matrix::zeros(0, 0)],
            next_id: 0,
        })
    }

    pub fn d_in(&self) -> usize {
        self.layers[0].d_in()
    }

    pub fn d_out(&self) -> usize {
        self.layers[self.layers.len() - 1].d_out()
    }

    pub fn pending(&self) -> usize {
        self.batcher.len()
    }

    pub fn stats(&self) -> &ServeStats {
        &self.stats
    }

    /// Enqueue one request (`input` is a `d_in` feature row); returns its
    /// id.  `now` is the caller's engine-relative clock.
    pub fn submit(&mut self, input: Vec<f32>, now: Duration) -> crate::Result<u64> {
        crate::ensure!(
            input.len() == self.d_in(),
            "request dim {} != engine d_in {}",
            input.len(),
            self.d_in()
        );
        let id = self.next_id;
        self.next_id += 1;
        self.batcher.push(Request { id, input, submitted: now });
        Ok(id)
    }

    /// Dispatch at most one coalesced batch if the batcher says one is
    /// due at `now`; returns the completed responses (empty when not yet
    /// due).
    pub fn poll(&mut self, now: Duration) -> Vec<Response> {
        if !self.batcher.ready(now) {
            return Vec::new();
        }
        let batch = self.batcher.take_batch();
        self.forward_batch(batch, now)
    }

    /// Drain the queue regardless of policy (shutdown / end of stream).
    pub fn flush(&mut self, now: Duration) -> Vec<Response> {
        let mut out = Vec::new();
        while !self.batcher.is_empty() {
            let batch = self.batcher.take_batch();
            out.extend(self.forward_batch(batch, now));
        }
        out
    }

    /// Run one coalesced forward.  Steady state (same fill as the
    /// previous batch) performs no heap allocation inside the kernels:
    /// staging and activation buffers are shape-checked and reused.
    fn forward_batch(&mut self, batch: Vec<Request>, now: Duration) -> Vec<Response> {
        let k = batch.len();
        if k == 0 {
            return Vec::new();
        }
        let d_in = self.d_in();
        ensure_out(&mut self.staging, k, d_in);
        for (row, req) in batch.iter().enumerate() {
            self.staging.row_mut(row).copy_from_slice(&req.input);
        }
        let t0 = Instant::now();
        // Ping-pong through the layer stack: layer i reads bufs[i%2 ^ 1]
        // (or staging for i == 0) and writes bufs[i%2].
        for i in 0..self.layers.len() {
            let (x, y): (&Matrix, &mut Matrix) = if i == 0 {
                let [b0, _] = &mut self.bufs;
                (&self.staging, b0)
            } else if i % 2 == 1 {
                let [b0, b1] = &mut self.bufs;
                (b0, b1)
            } else {
                let [b0, b1] = &mut self.bufs;
                (b1, b0)
            };
            self.layers[i].forward_into(x, y);
        }
        let compute = t0.elapsed();
        let last = (self.layers.len() - 1) % 2;
        let out = &self.bufs[last];
        let responses: Vec<Response> = batch
            .iter()
            .enumerate()
            .map(|(row, req)| {
                let queued = now.saturating_sub(req.submitted);
                Response {
                    id: req.id,
                    output: out.row(row).to_vec(),
                    queued,
                    latency: queued + compute,
                }
            })
            .collect();
        self.stats.record_batch(
            now,
            compute,
            responses.iter().map(|r| r.latency),
        );
        responses
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{gemm_nt, ParallelPolicy, SpmmAlgo};
    use crate::sparsity::{random_row_mask, NmScheme};
    use crate::util::Rng;

    const MS: Duration = Duration::from_millis(1);

    fn layer(d_out: usize, d_in: usize, rank: usize, threads: usize,
             rng: &mut Rng) -> ServeLayer {
        let w = Matrix::randn(d_out, d_in, 1.0, rng);
        let mask = random_row_mask(d_out, d_in, NmScheme::TWO_FOUR, rng);
        let be = SparseBackend::setup(&w, mask, NmScheme::TWO_FOUR, SpmmAlgo::RowMajor,
                                      ParallelPolicy::with_threads(threads));
        let lora = (rank > 0).then(|| LoraAdapter {
            up: Matrix::randn(d_out, rank, 0.3, rng),
            down: Matrix::randn(rank, d_in, 0.3, rng),
        });
        ServeLayer::new(be, lora).unwrap()
    }

    /// Dense reference for one layer: `x · (W masked)ᵀ + x·Rᵀ·Lᵀ`.
    fn reference(layers: &[ServeLayer], x: &Matrix) -> Matrix {
        let mut cur = x.clone();
        for l in layers {
            let mut y = gemm_nt(&cur, &l.backend.dense_weight());
            if let Some(a) = &l.lora {
                let t = gemm_nt(&cur, &a.down);
                let y2 = gemm_nt(&t, &a.up);
                for (o, v) in y.data.iter_mut().zip(&y2.data) {
                    *o += v;
                }
            }
            cur = y;
        }
        cur
    }

    #[test]
    fn engine_output_matches_dense_reference() {
        let mut rng = Rng::seed_from_u64(0);
        let layers = vec![layer(24, 16, 4, 2, &mut rng), layer(16, 24, 0, 2, &mut rng)];
        let x = Matrix::randn(3, 16, 1.0, &mut rng);
        let want = reference(&layers, &x);
        let mut eng =
            ServeEngine::new(layers, BatchPolicy::new(3, Duration::from_millis(1))).unwrap();
        for r in 0..3 {
            eng.submit(x.row(r).to_vec(), Duration::ZERO).unwrap();
        }
        let resp = eng.poll(Duration::ZERO);
        assert_eq!(resp.len(), 3, "full batch dispatches at once");
        for (row, r) in resp.iter().enumerate() {
            let got = Matrix::from_vec(1, want.cols, r.output.clone());
            let wrow = Matrix::from_vec(1, want.cols, want.row(row).to_vec());
            assert!(got.max_abs_diff(&wrow) < 1e-4, "row {row}");
        }
    }

    #[test]
    fn mismatched_dims_rejected() {
        let mut rng = Rng::seed_from_u64(1);
        let layers = vec![layer(24, 16, 0, 1, &mut rng), layer(16, 32, 0, 1, &mut rng)];
        assert!(ServeEngine::new(layers, BatchPolicy::default()).is_err());
        let mut eng = ServeEngine::new(vec![layer(8, 16, 0, 1, &mut rng)],
                                       BatchPolicy::default())
            .unwrap();
        assert!(eng.submit(vec![0.0; 7], Duration::ZERO).is_err());
    }

    #[test]
    fn coalescing_honors_max_batch_and_max_wait() {
        let mut rng = Rng::seed_from_u64(2);
        let mut eng = ServeEngine::new(vec![layer(8, 16, 0, 1, &mut rng)],
                                       BatchPolicy::new(4, 10 * MS))
            .unwrap();
        // Three requests at t=0: not a full batch, wait below max_wait.
        for _ in 0..3 {
            eng.submit(vec![0.5; 16], Duration::ZERO).unwrap();
        }
        assert!(eng.poll(5 * MS).is_empty(), "partial batch below max_wait holds");
        // Fourth request completes the batch: dispatch on the next poll.
        eng.submit(vec![0.5; 16], 6 * MS).unwrap();
        let r = eng.poll(6 * MS);
        assert_eq!(r.len(), 4, "max_batch reached ⇒ immediate dispatch");
        assert_eq!(r[0].queued, 6 * MS);
        assert_eq!(r[3].queued, Duration::ZERO);
        // Two stragglers: held until the oldest has waited max_wait.
        eng.submit(vec![0.5; 16], 8 * MS).unwrap();
        eng.submit(vec![0.5; 16], 9 * MS).unwrap();
        assert!(eng.poll(17 * MS).is_empty(), "9 ms < max_wait");
        let r = eng.poll(18 * MS);
        assert_eq!(r.len(), 2, "max_wait exceeded ⇒ partial dispatch");
        assert!(r[0].queued >= 10 * MS);
        assert_eq!(eng.pending(), 0);
        assert_eq!(eng.stats().served(), 6);
    }

    #[test]
    fn steady_state_reuses_buffers() {
        let mut rng = Rng::seed_from_u64(3);
        let mut eng = ServeEngine::new(vec![layer(32, 16, 4, 2, &mut rng)],
                                       BatchPolicy::new(2, MS))
            .unwrap();
        for _ in 0..2 {
            eng.submit(vec![0.1; 16], Duration::ZERO).unwrap();
        }
        eng.poll(Duration::ZERO);
        let staging_ptr = eng.staging.data.as_ptr();
        let buf_ptr = eng.bufs[0].data.as_ptr();
        for _ in 0..2 {
            eng.submit(vec![0.2; 16], MS).unwrap();
        }
        eng.poll(MS);
        assert_eq!(eng.staging.data.as_ptr(), staging_ptr, "staging must not realloc");
        assert_eq!(eng.bufs[0].data.as_ptr(), buf_ptr, "activation buffer must not realloc");
    }

    #[test]
    fn flush_drains_everything() {
        let mut rng = Rng::seed_from_u64(4);
        let mut eng = ServeEngine::new(vec![layer(8, 16, 0, 1, &mut rng)],
                                       BatchPolicy::new(4, Duration::from_secs(1)))
            .unwrap();
        for _ in 0..10 {
            eng.submit(vec![1.0; 16], Duration::ZERO).unwrap();
        }
        let r = eng.flush(MS);
        assert_eq!(r.len(), 10);
        assert_eq!(eng.pending(), 0);
        let s = eng.stats().summary();
        assert_eq!(s.batches, 3, "10 requests at max_batch 4 ⇒ 4+4+2");
    }
}
