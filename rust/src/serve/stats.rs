//! Serving telemetry: per-request latency quantiles, batch-fill, and
//! throughput — the measured counterpart of the paper's Table-2
//! inference-speedup claim, reported the way serving systems report it
//! (p50/p95/p99 + req/s) rather than as a single kernel median.
//!
//! Generation serving adds a second, **separate** distribution: per-token
//! decode-step latency.  A generation request's end-to-end latency mixes
//! queueing, prefill, and every decode step it lived through; the
//! per-token number is what the continuous-batching scheduler actually
//! controls, so the two are windowed and reported independently
//! (`latency` vs `tok latency` in [`StatsSummary::report`]), plus a
//! tokens/s decode rate.

use std::time::Duration;

/// Latency samples retained for quantiles: a ring of the most recent
/// requests, so a long-lived engine's stats stay O(window) in memory
/// while counters (`served`, `batches`, throughput) remain exact.
const LATENCY_WINDOW: usize = 1 << 16;

/// Bounded quantile window (ring buffer of the last [`LATENCY_WINDOW`]
/// samples, in milliseconds).
#[derive(Debug, Default)]
struct LatencyWindow {
    ms: Vec<f64>,
    /// Next ring slot to overwrite once the window is full.
    next: usize,
}

impl LatencyWindow {
    fn push(&mut self, v_ms: f64) {
        if self.ms.len() < LATENCY_WINDOW {
            self.ms.push(v_ms);
        } else {
            self.ms[self.next] = v_ms;
            self.next = (self.next + 1) % LATENCY_WINDOW;
        }
    }

    fn sorted(&self) -> Vec<f64> {
        let mut s = self.ms.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        s
    }
}

/// Accumulated serving statistics (monotone; one per engine lifetime).
#[derive(Debug, Default)]
pub struct ServeStats {
    /// Per-request end-to-end latencies (queue wait + compute; for
    /// generations, submit → final token).
    requests: LatencyWindow,
    /// Per-token decode-step latencies (one sample per token per step).
    decode: LatencyWindow,
    batches: usize,
    served: usize,
    /// Tokens emitted by decode steps (generation workload only).
    tokens_out: usize,
    decode_steps: usize,
    prefills: usize,
    /// Requests that expired past their per-request deadline unserved
    /// (dropped from the queue, or mid-generation) — counted here and
    /// kept OUT of the latency windows and `served`, so expiry under
    /// overload cannot flatter the quantiles.
    deadline_expired: usize,
    /// Requests cancelled by the client before finishing (dropped from
    /// the queue, or mid-generation with partial tokens) — like deadline
    /// expiries, kept out of the latency windows and `served`.
    cancelled: usize,
    /// KV block-pool telemetry (paged decode backends only): occupancy
    /// gauges hold the latest snapshot, `kv_peak_blocks` the high-water
    /// mark, and the failure/recycle counters mirror the pool's own
    /// monotone counts.
    kv_recorded: bool,
    kv_blocks_in_use: usize,
    kv_bytes_in_use: usize,
    kv_peak_blocks: usize,
    kv_alloc_failures: u64,
    kv_blocks_recycled: u64,
    /// Prefix-cache telemetry (prefix-caching decode backends only):
    /// the pool's monotone counters are copied through, the block
    /// gauges hold the latest snapshot.
    prefix_recorded: bool,
    prefix_lookups: u64,
    prefix_hits: u64,
    prefix_tokens_saved: u64,
    prefix_evictions: u64,
    prefix_cached_blocks: usize,
    prefix_shared_blocks: usize,
    compute: Duration,
    /// Engine-relative time of the first/last dispatch observed.
    first_dispatch: Option<Duration>,
    last_dispatch: Duration,
}

/// A point-in-time summary of [`ServeStats`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct StatsSummary {
    pub served: usize,
    pub batches: usize,
    /// Mean requests per dispatched batch.
    pub mean_batch_fill: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    /// Tail latency — the number the async admission front-end exists to
    /// measure under concurrent producers.
    pub p99_ms: f64,
    /// Requests per second over the dispatch span (compute-time based
    /// when the span is degenerate, e.g. a single batch).
    pub req_per_s: f64,
    // -- generation (zero when the engine served no decode steps) --
    /// Tokens emitted by decode steps.
    pub tokens_out: usize,
    pub decode_steps: usize,
    pub prefills: usize,
    /// Mean sequences per coalesced decode step.
    pub mean_decode_fill: f64,
    /// Per-token decode-step latency quantiles — separate from the
    /// request distribution above.
    pub decode_p50_ms: f64,
    pub decode_p95_ms: f64,
    pub decode_p99_ms: f64,
    /// Generated tokens per second over the dispatch span.
    pub tok_per_s: f64,
    /// Requests dropped past their per-request deadline (not in
    /// `served` or any latency window).
    pub deadline_expired: usize,
    /// Requests cancelled by the client before finishing (not in
    /// `served` or any latency window).
    pub cancelled: usize,
    // -- KV block pool (all zero when the backend is not paged) --
    /// True when the engine's backend reported pool occupancy at least
    /// once (gates the report line).
    pub kv_recorded: bool,
    /// Blocks held by live sequences at the latest observation.
    pub kv_blocks_in_use: usize,
    /// `kv_blocks_in_use × block_bytes` at the latest observation.
    pub kv_bytes_in_use: usize,
    /// High-water mark of in-use blocks across the engine's lifetime.
    pub kv_peak_blocks: usize,
    /// Reservations the pool refused at its configured bound.
    pub kv_alloc_failures: u64,
    /// Allocations served by recycling freed blocks (vs. arena growth).
    pub kv_blocks_recycled: u64,
    // -- prefix cache (all zero when the backend has none) --
    /// True when the engine's backend reported prefix-cache stats at
    /// least once (gates the report line).
    pub prefix_recorded: bool,
    /// Prefill-time cache probes.
    pub prefix_lookups: u64,
    /// Probes that matched at least one cached block.
    pub prefix_hits: u64,
    /// `prefix_hits / prefix_lookups` (0 with no lookups).
    pub prefix_hit_rate: f64,
    /// Prompt positions served from the cache instead of recomputed.
    pub prefix_tokens_saved: u64,
    /// Cached chains evicted (LRU at capacity, or under pool pressure).
    pub prefix_evictions: u64,
    /// Blocks the cache index holds at the latest observation.
    pub prefix_cached_blocks: usize,
    /// Blocks shared by 2+ holders at the latest observation.
    pub prefix_shared_blocks: usize,
}

impl ServeStats {
    /// Record one dispatched batch: its fill, the compute wall time, and
    /// each request's end-to-end latency (queue wait + compute).
    pub fn record_batch(&mut self, now: Duration, compute: Duration,
                        latencies: impl IntoIterator<Item = Duration>) {
        for l in latencies {
            self.requests.push(l.as_secs_f64() * 1e3);
            self.served += 1;
        }
        self.batches += 1;
        self.mark_dispatch(now, compute);
    }

    /// Record one coalesced decode step of `fill` sequences: each of the
    /// `fill` tokens emitted waited `compute` for its step, so the
    /// per-token window gains `fill` samples of the step's wall time.
    pub fn record_decode_step(&mut self, now: Duration, compute: Duration, fill: usize) {
        let ms = compute.as_secs_f64() * 1e3;
        for _ in 0..fill {
            self.decode.push(ms);
        }
        self.tokens_out += fill;
        self.decode_steps += 1;
        self.mark_dispatch(now, compute);
    }

    /// Record one prompt prefill (counted and charged to the compute
    /// span; prefill cost never pollutes the per-token decode window).
    pub fn record_prefill(&mut self, now: Duration, compute: Duration) {
        self.prefills += 1;
        self.mark_dispatch(now, compute);
    }

    /// Record one completed generation request's end-to-end latency
    /// (submit → final token) in the request window.
    pub fn record_generation(&mut self, latency: Duration) {
        self.requests.push(latency.as_secs_f64() * 1e3);
        self.served += 1;
    }

    /// Record `n` requests expired past their deadline unserved.
    pub fn record_deadline_expired(&mut self, n: usize) {
        self.deadline_expired += n;
    }

    /// Record `n` requests cancelled by the client before finishing.
    pub fn record_cancelled(&mut self, n: usize) {
        self.cancelled += n;
    }

    /// Record one KV block-pool observation (paged decode backends call
    /// this once per engine step): occupancy gauges overwrite with the
    /// snapshot, the peak keeps its high-water mark, and the pool's own
    /// monotone counters are copied through.
    pub fn record_kv_pool(&mut self, s: &crate::runtime::KvPoolStats) {
        self.kv_recorded = true;
        self.kv_blocks_in_use = s.blocks_in_use;
        self.kv_bytes_in_use = s.bytes_in_use;
        self.kv_peak_blocks = self.kv_peak_blocks.max(s.peak_blocks);
        self.kv_alloc_failures = s.alloc_failures;
        self.kv_blocks_recycled = s.blocks_recycled;
    }

    /// Record one prefix-cache observation (prefix-caching decode
    /// backends call this once per engine step): the pool's monotone
    /// counters are copied through, the block gauges overwrite with the
    /// snapshot.
    pub fn record_prefix_cache(&mut self, s: &crate::runtime::PrefixCacheStats) {
        self.prefix_recorded = true;
        self.prefix_lookups = s.lookups;
        self.prefix_hits = s.hits;
        self.prefix_tokens_saved = s.tokens_saved;
        self.prefix_evictions = s.evictions;
        self.prefix_cached_blocks = s.cached_blocks;
        self.prefix_shared_blocks = s.shared_blocks;
    }

    fn mark_dispatch(&mut self, now: Duration, compute: Duration) {
        self.compute += compute;
        self.first_dispatch.get_or_insert(now);
        self.last_dispatch = self.last_dispatch.max(now + compute);
    }

    pub fn served(&self) -> usize {
        self.served
    }

    pub fn tokens_out(&self) -> usize {
        self.tokens_out
    }

    pub fn deadline_expired(&self) -> usize {
        self.deadline_expired
    }

    /// Request-latency quantile in milliseconds over the retained window
    /// (`p` in `[0, 1]`); 0 when empty.  Point query —
    /// [`ServeStats::summary`] computes all quantiles from one sort.
    pub fn quantile_ms(&self, p: f64) -> f64 {
        quantile_of_sorted(&self.requests.sorted(), p)
    }

    pub fn summary(&self) -> StatsSummary {
        let span = match self.first_dispatch {
            Some(first) => (self.last_dispatch.saturating_sub(first)).as_secs_f64(),
            None => 0.0,
        };
        let wall = if span > 0.0 { span } else { self.compute.as_secs_f64() };
        let sorted = self.requests.sorted();
        let dec_sorted = self.decode.sorted();
        StatsSummary {
            served: self.served,
            batches: self.batches,
            mean_batch_fill: if self.batches == 0 {
                0.0
            } else {
                self.served as f64 / self.batches as f64
            },
            p50_ms: quantile_of_sorted(&sorted, 0.50),
            p95_ms: quantile_of_sorted(&sorted, 0.95),
            p99_ms: quantile_of_sorted(&sorted, 0.99),
            req_per_s: if wall > 0.0 { self.served as f64 / wall } else { 0.0 },
            tokens_out: self.tokens_out,
            decode_steps: self.decode_steps,
            prefills: self.prefills,
            mean_decode_fill: if self.decode_steps == 0 {
                0.0
            } else {
                self.tokens_out as f64 / self.decode_steps as f64
            },
            decode_p50_ms: quantile_of_sorted(&dec_sorted, 0.50),
            decode_p95_ms: quantile_of_sorted(&dec_sorted, 0.95),
            decode_p99_ms: quantile_of_sorted(&dec_sorted, 0.99),
            tok_per_s: if wall > 0.0 { self.tokens_out as f64 / wall } else { 0.0 },
            deadline_expired: self.deadline_expired,
            cancelled: self.cancelled,
            kv_recorded: self.kv_recorded,
            kv_blocks_in_use: self.kv_blocks_in_use,
            kv_bytes_in_use: self.kv_bytes_in_use,
            kv_peak_blocks: self.kv_peak_blocks,
            kv_alloc_failures: self.kv_alloc_failures,
            kv_blocks_recycled: self.kv_blocks_recycled,
            prefix_recorded: self.prefix_recorded,
            prefix_lookups: self.prefix_lookups,
            prefix_hits: self.prefix_hits,
            prefix_hit_rate: if self.prefix_lookups == 0 {
                0.0
            } else {
                self.prefix_hits as f64 / self.prefix_lookups as f64
            },
            prefix_tokens_saved: self.prefix_tokens_saved,
            prefix_evictions: self.prefix_evictions,
            prefix_cached_blocks: self.prefix_cached_blocks,
            prefix_shared_blocks: self.prefix_shared_blocks,
        }
    }
}

impl StatsSummary {
    /// The uniform multi-line serving report the CLI and the serving
    /// example both print — one definition, so their output cannot
    /// drift.  `served` is the caller's completed-response count and
    /// `max_batch` the effective coalescing cap.  Generation engines
    /// (decode steps recorded) get the per-token block appended; pure
    /// request engines keep the classic four lines.
    pub fn report(&self, served: usize, max_batch: usize) -> String {
        let mut out = if self.batches > 0 {
            format!(
                "served     : {served} requests in {} batches\n\
                 batch fill : {:.2} / {max_batch}\n",
                self.batches, self.mean_batch_fill
            )
        } else {
            format!("served     : {served} requests\n")
        };
        out.push_str(&format!(
            "latency    : p50 {:.3} ms   p95 {:.3} ms   p99 {:.3} ms\n\
             throughput : {:.0} req/s",
            self.p50_ms, self.p95_ms, self.p99_ms, self.req_per_s
        ));
        if self.decode_steps > 0 {
            out.push_str(&format!(
                "\ngeneration : {} tokens in {} decode steps ({} prefills), \
                 decode fill {:.2} / {max_batch}\n\
                 tok latency: p50 {:.3} ms   p95 {:.3} ms   p99 {:.3} ms\n\
                 decode rate: {:.0} tok/s",
                self.tokens_out, self.decode_steps, self.prefills, self.mean_decode_fill,
                self.decode_p50_ms, self.decode_p95_ms, self.decode_p99_ms, self.tok_per_s
            ));
        }
        if self.deadline_expired > 0 {
            out.push_str(&format!(
                "\ndeadlines  : {} requests expired unserved",
                self.deadline_expired
            ));
        }
        if self.cancelled > 0 {
            out.push_str(&format!(
                "\ncancelled  : {} requests cancelled by the client",
                self.cancelled
            ));
        }
        if self.kv_recorded {
            out.push_str(&format!(
                "\nkv pool    : {} blocks in use ({:.2} MiB), peak {}, \
                 {} recycled, {} alloc failures",
                self.kv_blocks_in_use,
                self.kv_bytes_in_use as f64 / (1024.0 * 1024.0),
                self.kv_peak_blocks,
                self.kv_blocks_recycled,
                self.kv_alloc_failures
            ));
        }
        if self.prefix_recorded {
            out.push_str(&format!(
                "\nprefix     : {}/{} hits ({:.0}%), {} prefill tokens saved, \
                 {} cached / {} shared blocks, {} evicted",
                self.prefix_hits,
                self.prefix_lookups,
                self.prefix_hit_rate * 100.0,
                self.prefix_tokens_saved,
                self.prefix_cached_blocks,
                self.prefix_shared_blocks,
                self.prefix_evictions
            ));
        }
        out
    }
}

/// Lower-nearest quantile of an ascending slice; 0 when empty.
fn quantile_of_sorted(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    sorted[((sorted.len() - 1) as f64 * p) as usize]
}

#[cfg(test)]
mod tests {
    use super::*;

    const MS: Duration = Duration::from_millis(1);

    #[test]
    fn quantiles_and_fill() {
        let mut s = ServeStats::default();
        // Two batches: fills 4 and 2, latencies 1..=6 ms.
        s.record_batch(10 * MS, 2 * MS, (1..=4).map(|i| i * MS));
        s.record_batch(20 * MS, 2 * MS, (5..=6).map(|i| i * MS));
        let sum = s.summary();
        assert_eq!(sum.served, 6);
        assert_eq!(sum.batches, 2);
        assert!((sum.mean_batch_fill - 3.0).abs() < 1e-12);
        assert!((sum.p50_ms - 3.0).abs() < 1e-9, "p50 of 1..6 ms = 3 (lower-nearest)");
        assert!((sum.p95_ms - 5.0).abs() < 1e-9);
        assert!((sum.p99_ms - 5.0).abs() < 1e-9, "p99 lower-nearest of 6 samples");
        // Span: first dispatch 10 ms, last end 22 ms ⇒ 6 req / 12 ms.
        assert!((sum.req_per_s - 500.0).abs() < 1e-6);
        // No decode activity ⇒ generation block zeroed out.
        assert_eq!(sum.tokens_out, 0);
        assert_eq!(sum.decode_p99_ms, 0.0);
    }

    #[test]
    fn latency_window_stays_bounded_but_counters_stay_exact() {
        let mut s = ServeStats::default();
        let n = LATENCY_WINDOW + 100;
        s.record_batch(Duration::ZERO, MS, (0..n).map(|_| MS));
        assert_eq!(s.served(), n, "served counts every request");
        assert!(s.requests.ms.len() <= LATENCY_WINDOW, "quantile window is bounded");
        assert!((s.summary().p50_ms - 1.0).abs() < 1e-9);
    }

    #[test]
    fn deadline_expiries_stay_out_of_latency_windows() {
        let mut s = ServeStats::default();
        s.record_batch(Duration::ZERO, MS, [10 * MS]);
        s.record_deadline_expired(3);
        let sum = s.summary();
        assert_eq!(sum.deadline_expired, 3);
        assert_eq!(sum.served, 1, "expiries are not served requests");
        assert!((sum.p99_ms - 10.0).abs() < 1e-9, "quantiles untouched by expiry");
        let rep = sum.report(1, 4);
        assert!(rep.contains("3 requests expired"), "{rep}");
        // No expiries ⇒ no deadlines line.
        let rep = ServeStats::default().summary().report(0, 4);
        assert!(!rep.contains("expired"), "{rep}");
    }

    #[test]
    fn kv_pool_gauges_track_latest_and_peak_and_gate_the_report_line() {
        use crate::runtime::KvPoolStats;
        let mut s = ServeStats::default();
        assert!(!s.summary().kv_recorded);
        s.record_kv_pool(&KvPoolStats {
            blocks_in_use: 4,
            blocks_allocated: 6,
            peak_blocks: 5,
            max_blocks: None,
            block_bytes: 1024,
            bytes_in_use: 4096,
            alloc_failures: 1,
            blocks_recycled: 2,
        });
        s.record_kv_pool(&KvPoolStats {
            blocks_in_use: 2,
            blocks_allocated: 6,
            peak_blocks: 5,
            max_blocks: None,
            block_bytes: 1024,
            bytes_in_use: 2048,
            alloc_failures: 3,
            blocks_recycled: 7,
        });
        let sum = s.summary();
        assert!(sum.kv_recorded);
        assert_eq!(sum.kv_blocks_in_use, 2, "gauges show the latest snapshot");
        assert_eq!(sum.kv_bytes_in_use, 2048);
        assert_eq!(sum.kv_peak_blocks, 5);
        assert_eq!(sum.kv_alloc_failures, 3);
        assert_eq!(sum.kv_blocks_recycled, 7);
        let rep = sum.report(0, 4);
        assert!(rep.contains("kv pool"), "{rep}");
        assert!(!ServeStats::default().summary().report(0, 4).contains("kv pool"),
                "no pool line for poolless backends");
    }

    #[test]
    fn prefix_cache_counters_copy_through_and_gate_the_report_line() {
        use crate::runtime::PrefixCacheStats;
        let mut s = ServeStats::default();
        assert!(!s.summary().prefix_recorded);
        s.record_prefix_cache(&PrefixCacheStats {
            lookups: 2,
            hits: 1,
            tokens_saved: 16,
            evictions: 0,
            cached_blocks: 3,
            max_cached_blocks: 64,
            shared_blocks: 1,
        });
        s.record_prefix_cache(&PrefixCacheStats {
            lookups: 4,
            hits: 3,
            tokens_saved: 48,
            evictions: 2,
            cached_blocks: 5,
            max_cached_blocks: 64,
            shared_blocks: 2,
        });
        let sum = s.summary();
        assert!(sum.prefix_recorded);
        assert_eq!(sum.prefix_lookups, 4, "monotone counters copy through");
        assert_eq!(sum.prefix_hits, 3);
        assert!((sum.prefix_hit_rate - 0.75).abs() < 1e-12);
        assert_eq!(sum.prefix_tokens_saved, 48);
        assert_eq!(sum.prefix_evictions, 2);
        assert_eq!(sum.prefix_cached_blocks, 5, "gauges show the latest snapshot");
        assert_eq!(sum.prefix_shared_blocks, 2);
        let rep = sum.report(0, 4);
        assert!(rep.contains("prefix"), "{rep}");
        assert!(rep.contains("3/4 hits (75%)"), "{rep}");
        assert!(!ServeStats::default().summary().report(0, 4).contains("prefix"),
                "no prefix line for cacheless backends");
    }

    #[test]
    fn cancellations_stay_out_of_latency_windows() {
        let mut s = ServeStats::default();
        s.record_generation(10 * MS);
        s.record_cancelled(2);
        let sum = s.summary();
        assert_eq!(sum.cancelled, 2);
        assert_eq!(sum.served, 1, "cancellations are not served requests");
        assert!((sum.p99_ms - 10.0).abs() < 1e-9, "quantiles untouched by cancels");
        let rep = sum.report(1, 4);
        assert!(rep.contains("2 requests cancelled"), "{rep}");
        assert!(!ServeStats::default().summary().report(0, 4).contains("cancelled"));
    }

    #[test]
    fn empty_stats_are_zero() {
        let sum = ServeStats::default().summary();
        assert_eq!(sum.served, 0);
        assert_eq!(sum.p50_ms, 0.0);
        assert_eq!(sum.req_per_s, 0.0);
        assert_eq!(sum.tok_per_s, 0.0);
    }

    #[test]
    fn single_batch_uses_compute_time_for_throughput() {
        let mut s = ServeStats::default();
        s.record_batch(Duration::ZERO, 4 * MS, [MS, MS]);
        // Span = 0 + 4ms compute end... first=0, last=4ms ⇒ span 4 ms.
        assert!((s.summary().req_per_s - 500.0).abs() < 1e-6);
    }

    #[test]
    fn decode_window_is_separate_from_request_window() {
        let mut s = ServeStats::default();
        // Two prefills, then three decode steps at fills 2, 2, 1 with step
        // times 1/2/3 ms; two generations complete at 10 and 20 ms.
        s.record_prefill(Duration::ZERO, MS);
        s.record_prefill(MS, MS);
        s.record_decode_step(2 * MS, MS, 2);
        s.record_decode_step(4 * MS, 2 * MS, 2);
        s.record_decode_step(7 * MS, 3 * MS, 1);
        s.record_generation(10 * MS);
        s.record_generation(20 * MS);
        let sum = s.summary();
        assert_eq!(sum.served, 2);
        assert_eq!(sum.batches, 0, "decode steps are not request batches");
        assert_eq!(sum.tokens_out, 5);
        assert_eq!(sum.decode_steps, 3);
        assert_eq!(sum.prefills, 2);
        assert!((sum.mean_decode_fill - 5.0 / 3.0).abs() < 1e-12);
        // Decode window: [1, 1, 2, 2, 3] ms ⇒ p50 = 2 ms.
        assert!((sum.decode_p50_ms - 2.0).abs() < 1e-9);
        assert!((sum.decode_p99_ms - 3.0).abs() < 1e-9);
        // Request window: [10, 20] ms — untouched by step samples.
        assert!((sum.p50_ms - 10.0).abs() < 1e-9);
        assert!((sum.p99_ms - 20.0).abs() < 1e-9);
        // Span: first dispatch 0, last 7+3 = 10 ms ⇒ 5 tokens / 10 ms.
        assert!((sum.tok_per_s - 500.0).abs() < 1e-6);
        // Report carries both distributions.
        let rep = sum.report(2, 4);
        assert!(rep.contains("tok latency"), "{rep}");
        assert!(rep.contains("decode rate"), "{rep}");
    }
}
