//! Serving telemetry: per-request latency quantiles, batch-fill, and
//! throughput — the measured counterpart of the paper's Table-2
//! inference-speedup claim, reported the way serving systems report it
//! (p50/p95/p99 + req/s) rather than as a single kernel median.

use std::time::Duration;

/// Latency samples retained for quantiles: a ring of the most recent
/// requests, so a long-lived engine's stats stay O(window) in memory
/// while counters (`served`, `batches`, throughput) remain exact.
const LATENCY_WINDOW: usize = 1 << 16;

/// Accumulated serving statistics (monotone; one per engine lifetime).
#[derive(Debug, Default)]
pub struct ServeStats {
    /// Ring buffer of the last [`LATENCY_WINDOW`] request latencies (ms).
    latencies_ms: Vec<f64>,
    /// Next ring slot to overwrite once the window is full.
    lat_next: usize,
    batches: usize,
    served: usize,
    compute: Duration,
    /// Engine-relative time of the first/last dispatch observed.
    first_dispatch: Option<Duration>,
    last_dispatch: Duration,
}

/// A point-in-time summary of [`ServeStats`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct StatsSummary {
    pub served: usize,
    pub batches: usize,
    /// Mean requests per dispatched batch.
    pub mean_batch_fill: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    /// Tail latency — the number the async admission front-end exists to
    /// measure under concurrent producers.
    pub p99_ms: f64,
    /// Requests per second over the dispatch span (compute-time based
    /// when the span is degenerate, e.g. a single batch).
    pub req_per_s: f64,
}

impl ServeStats {
    /// Record one dispatched batch: its fill, the compute wall time, and
    /// each request's end-to-end latency (queue wait + compute).
    pub fn record_batch(&mut self, now: Duration, compute: Duration,
                        latencies: impl IntoIterator<Item = Duration>) {
        for l in latencies {
            let ms = l.as_secs_f64() * 1e3;
            if self.latencies_ms.len() < LATENCY_WINDOW {
                self.latencies_ms.push(ms);
            } else {
                self.latencies_ms[self.lat_next] = ms;
                self.lat_next = (self.lat_next + 1) % LATENCY_WINDOW;
            }
            self.served += 1;
        }
        self.batches += 1;
        self.compute += compute;
        self.first_dispatch.get_or_insert(now);
        self.last_dispatch = self.last_dispatch.max(now + compute);
    }

    pub fn served(&self) -> usize {
        self.served
    }

    /// Latency quantile in milliseconds over the retained window (`p` in
    /// `[0, 1]`); 0 when empty.  Point query — [`ServeStats::summary`]
    /// computes all quantiles from one sort.
    pub fn quantile_ms(&self, p: f64) -> f64 {
        let mut sorted = self.latencies_ms.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        quantile_of_sorted(&sorted, p)
    }

    pub fn summary(&self) -> StatsSummary {
        let span = match self.first_dispatch {
            Some(first) => (self.last_dispatch.saturating_sub(first)).as_secs_f64(),
            None => 0.0,
        };
        let wall = if span > 0.0 { span } else { self.compute.as_secs_f64() };
        let mut sorted = self.latencies_ms.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        StatsSummary {
            served: self.served,
            batches: self.batches,
            mean_batch_fill: if self.batches == 0 {
                0.0
            } else {
                self.served as f64 / self.batches as f64
            },
            p50_ms: quantile_of_sorted(&sorted, 0.50),
            p95_ms: quantile_of_sorted(&sorted, 0.95),
            p99_ms: quantile_of_sorted(&sorted, 0.99),
            req_per_s: if wall > 0.0 { self.served as f64 / wall } else { 0.0 },
        }
    }
}

impl StatsSummary {
    /// The uniform multi-line serving report the CLI and the serving
    /// example both print — one definition, so their output cannot
    /// drift.  `served` is the caller's completed-response count and
    /// `max_batch` the effective coalescing cap.
    pub fn report(&self, served: usize, max_batch: usize) -> String {
        format!(
            "served     : {served} requests in {} batches\n\
             batch fill : {:.2} / {max_batch}\n\
             latency    : p50 {:.3} ms   p95 {:.3} ms   p99 {:.3} ms\n\
             throughput : {:.0} req/s",
            self.batches, self.mean_batch_fill, self.p50_ms, self.p95_ms, self.p99_ms,
            self.req_per_s
        )
    }
}

/// Lower-nearest quantile of an ascending slice; 0 when empty.
fn quantile_of_sorted(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    sorted[((sorted.len() - 1) as f64 * p) as usize]
}

#[cfg(test)]
mod tests {
    use super::*;

    const MS: Duration = Duration::from_millis(1);

    #[test]
    fn quantiles_and_fill() {
        let mut s = ServeStats::default();
        // Two batches: fills 4 and 2, latencies 1..=6 ms.
        s.record_batch(10 * MS, 2 * MS, (1..=4).map(|i| i * MS));
        s.record_batch(20 * MS, 2 * MS, (5..=6).map(|i| i * MS));
        let sum = s.summary();
        assert_eq!(sum.served, 6);
        assert_eq!(sum.batches, 2);
        assert!((sum.mean_batch_fill - 3.0).abs() < 1e-12);
        assert!((sum.p50_ms - 3.0).abs() < 1e-9, "p50 of 1..6 ms = 3 (lower-nearest)");
        assert!((sum.p95_ms - 5.0).abs() < 1e-9);
        assert!((sum.p99_ms - 5.0).abs() < 1e-9, "p99 lower-nearest of 6 samples");
        // Span: first dispatch 10 ms, last end 22 ms ⇒ 6 req / 12 ms.
        assert!((sum.req_per_s - 500.0).abs() < 1e-6);
    }

    #[test]
    fn latency_window_stays_bounded_but_counters_stay_exact() {
        let mut s = ServeStats::default();
        let n = LATENCY_WINDOW + 100;
        s.record_batch(Duration::ZERO, MS, (0..n).map(|_| MS));
        assert_eq!(s.served(), n, "served counts every request");
        assert!(s.latencies_ms.len() <= LATENCY_WINDOW, "quantile window is bounded");
        assert!((s.summary().p50_ms - 1.0).abs() < 1e-9);
    }

    #[test]
    fn empty_stats_are_zero() {
        let sum = ServeStats::default().summary();
        assert_eq!(sum.served, 0);
        assert_eq!(sum.p50_ms, 0.0);
        assert_eq!(sum.req_per_s, 0.0);
    }

    #[test]
    fn single_batch_uses_compute_time_for_throughput() {
        let mut s = ServeStats::default();
        s.record_batch(Duration::ZERO, 4 * MS, [MS, MS]);
        // Span = 0 + 4ms compute end... first=0, last=4ms ⇒ span 4 ms.
        assert!((s.summary().req_per_s - 500.0).abs() < 1e-6);
    }
}
