//! Async admission: concurrent producers over the externally-clocked
//! engine.
//!
//! [`ServeEngine`] is single-threaded by design (submit/poll under one
//! caller's clock), which keeps the batching policy deterministic and
//! testable — but a deployment has many producers.  [`Admission`] bridges
//! the two with the classic channel-fed dispatch-thread shape:
//!
//! * any number of [`AdmissionClient`]s (cheap to mint, `Send`) push
//!   requests into an mpsc queue, each tagged with a caller-chosen id;
//! * one dedicated dispatch thread owns the engine, draining the queue
//!   into [`ServeEngine::submit`] and polling on a short tick so
//!   `max_wait` deadlines fire between arrivals;
//! * completed [`Response`]s are routed back to the submitting client
//!   over its private reply channel.
//!
//! The engine is **built inside the dispatch thread** (the `spawn`
//! closure), not handed over: an [`crate::serve::AotModel`] holds a
//! thread-local cached `Session` and cannot cross threads, and the warm
//! kernel stack is cheaper to build where it will run anyway.
//!
//! Because every [`crate::serve::ServeModel`] is row-independent, the
//! nondeterministic coalescing that concurrency produces never changes
//! any response's payload — N concurrent producers get the same answers
//! serial submission would give them (pinned in
//! `tests/serve_model.rs`) — only the *latency distribution* moves, which
//! is exactly what `slope serve --producers N` measures (p50/p95/p99
//! under contention).
//!
//! Shutdown: drop every client, then call [`Admission::finish`] — the
//! dispatch thread sees the queue disconnect, flushes the engine, routes
//! the tail, and returns the final [`StatsSummary`].

use crate::serve::engine::{Response, ServeEngine};
use crate::serve::model::ServeModel;
use crate::serve::stats::StatsSummary;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// One routed reply: the client's tag plus the outcome.
pub type Reply = (u64, crate::Result<Response>);

enum Msg {
    Submit { tag: u64, input: Vec<f32>, reply: Sender<Reply> },
}

/// Handle to a running admission front-end (module docs).
pub struct Admission {
    tx: Option<Sender<Msg>>,
    dispatcher: Option<JoinHandle<crate::Result<StatsSummary>>>,
    /// Cleared (via a drop guard) when the dispatch thread exits for any
    /// reason — clients poll it so a dead dispatcher can never strand
    /// them in `recv` (each client holds its own reply sender, so the
    /// reply channel alone cannot signal disconnection).
    alive: Arc<AtomicBool>,
}

/// A producer-side handle: submit tagged inputs, receive tagged replies.
/// Mint one per producer thread with [`Admission::client`].
pub struct AdmissionClient {
    tx: Sender<Msg>,
    reply_tx: Sender<Reply>,
    reply_rx: Receiver<Reply>,
    alive: Arc<AtomicBool>,
}

impl Admission {
    /// Start the dispatch thread.  `build` runs on that thread and
    /// constructs the engine (see module docs for why); `tick` bounds how
    /// long the dispatcher sleeps between polls when no requests arrive —
    /// it should be a fraction of the batch policy's `max_wait` (see
    /// [`Admission::tick_for`]).
    pub fn spawn<M, F>(build: F, tick: Duration) -> Self
    where
        M: ServeModel + 'static,
        F: FnOnce() -> crate::Result<ServeEngine<M>> + Send + 'static,
    {
        let (tx, rx) = channel::<Msg>();
        let alive = Arc::new(AtomicBool::new(true));
        let alive_in_thread = Arc::clone(&alive);
        let dispatcher = std::thread::Builder::new()
            .name("slope-admission".into())
            .spawn(move || {
                // Clears the liveness flag however the thread exits
                // (return or panic).
                struct ClearOnExit(Arc<AtomicBool>);
                impl Drop for ClearOnExit {
                    fn drop(&mut self) {
                        self.0.store(false, Ordering::SeqCst);
                    }
                }
                let _clear = ClearOnExit(alive_in_thread);
                dispatch(build, rx, tick)
            })
            .expect("spawning admission dispatch thread");
        Self { tx: Some(tx), dispatcher: Some(dispatcher), alive }
    }

    /// A reasonable dispatch tick for a batch policy: a quarter of
    /// `max_wait`, clamped to [50 µs, 1 ms].
    pub fn tick_for(max_wait: Duration) -> Duration {
        (max_wait / 4).clamp(Duration::from_micros(50), Duration::from_millis(1))
    }

    /// Mint a producer handle (its own private reply channel).
    pub fn client(&self) -> AdmissionClient {
        let (reply_tx, reply_rx) = channel();
        AdmissionClient {
            tx: self.tx.as_ref().expect("admission already finished").clone(),
            reply_tx,
            reply_rx,
            alive: Arc::clone(&self.alive),
        }
    }

    /// Shut down: close the queue, let the dispatcher flush, and return
    /// the engine's final stats.  Every [`AdmissionClient`] must be
    /// dropped first (each holds a queue sender; the dispatcher only
    /// stops once all senders are gone).
    pub fn finish(mut self) -> crate::Result<StatsSummary> {
        drop(self.tx.take());
        match self.dispatcher.take().expect("admission finished twice").join() {
            Ok(result) => result,
            Err(_) => Err(crate::eyre!("admission dispatch thread panicked")),
        }
    }
}

impl AdmissionClient {
    /// Enqueue one input under a caller-chosen tag (echoed on the reply).
    /// Errors only if the admission queue has shut down.
    pub fn submit(&self, tag: u64, input: Vec<f32>) -> crate::Result<()> {
        self.tx
            .send(Msg::Submit { tag, input, reply: self.reply_tx.clone() })
            .map_err(|_| crate::eyre!("admission queue is closed"))
    }

    /// Block until the next reply for this client arrives.  Returns an
    /// error (instead of hanging) if the dispatcher has died with the
    /// request unanswered.
    pub fn recv(&self) -> crate::Result<(u64, Response)> {
        loop {
            match self.reply_rx.recv_timeout(Duration::from_millis(5)) {
                Ok((tag, result)) => return Ok((tag, result?)),
                Err(RecvTimeoutError::Timeout) => {
                    if !self.alive.load(Ordering::SeqCst) {
                        // One last non-blocking look: a reply routed just
                        // before shutdown must not be lost.
                        if let Ok((tag, result)) = self.reply_rx.try_recv() {
                            return Ok((tag, result?));
                        }
                        return Err(crate::eyre!("admission dispatcher is gone"));
                    }
                }
                Err(RecvTimeoutError::Disconnected) => {
                    return Err(crate::eyre!("admission dispatcher is gone"));
                }
            }
        }
    }
}

/// The dispatch thread body: run the loop, and on a fatal error reply
/// `Err` to every submission still sitting in the queue so no producer is
/// left blocking on a reply that will never come (submissions arriving
/// after this drain fail at `send` — the receiver is dropped with us).
fn dispatch<M, F>(build: F, rx: Receiver<Msg>, tick: Duration) -> crate::Result<StatsSummary>
where
    M: ServeModel,
    F: FnOnce() -> crate::Result<ServeEngine<M>>,
{
    let result = dispatch_loop(build, &rx, tick);
    if let Err(e) = &result {
        let why = e.to_string();
        while let Ok(Msg::Submit { tag, reply, .. }) = rx.try_recv() {
            let _ = reply.send((tag, Err(crate::eyre!("serve dispatch failed: {why}"))));
        }
    }
    result
}

/// The dispatch loop (runs on the dedicated thread).
fn dispatch_loop<M, F>(build: F, rx: &Receiver<Msg>,
                       tick: Duration) -> crate::Result<StatsSummary>
where
    M: ServeModel,
    F: FnOnce() -> crate::Result<ServeEngine<M>>,
{
    let mut engine = build()?;
    let start = Instant::now();
    let mut routes: HashMap<u64, (u64, Sender<Reply>)> = HashMap::new();
    let mut open = true;
    while open {
        match rx.recv_timeout(tick) {
            Ok(msg) => {
                admit(&mut engine, msg, start, &mut routes);
                // Drain whatever else queued up while we were busy, so a
                // burst coalesces into one batch instead of one per tick.
                while let Ok(msg) = rx.try_recv() {
                    admit(&mut engine, msg, start, &mut routes);
                }
            }
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => open = false,
        }
        // Dispatch EVERY due batch before sleeping again: a backlog must
        // drain at compute speed, not at one batch per tick (the tick
        // would otherwise dominate the tail latencies this front-end
        // exists to measure).
        loop {
            let due = engine.poll(start.elapsed());
            let drained = matches!(&due, Ok(v) if v.is_empty());
            route(due, &mut routes)?;
            if drained {
                break;
            }
        }
    }
    let tail = engine.flush(start.elapsed());
    route(tail, &mut routes)?;
    Ok(engine.stats().summary())
}

fn admit<M: ServeModel>(engine: &mut ServeEngine<M>, msg: Msg, start: Instant,
                        routes: &mut HashMap<u64, (u64, Sender<Reply>)>) {
    let Msg::Submit { tag, input, reply } = msg;
    match engine.submit(input, start.elapsed()) {
        Ok(id) => {
            routes.insert(id, (tag, reply));
        }
        Err(e) => {
            let _ = reply.send((tag, Err(e)));
        }
    }
}

/// Send completed responses to their submitters; on an engine error,
/// fail every outstanding route (the batch that died is unidentifiable
/// from here) and propagate.
fn route(result: crate::Result<Vec<Response>>,
         routes: &mut HashMap<u64, (u64, Sender<Reply>)>) -> crate::Result<()> {
    match result {
        Ok(responses) => {
            for resp in responses {
                if let Some((tag, reply)) = routes.remove(&resp.id) {
                    let _ = reply.send((tag, Ok(resp)));
                }
            }
            Ok(())
        }
        Err(e) => {
            let why = e.to_string();
            for (_, (tag, reply)) in routes.drain() {
                let _ = reply.send((tag, Err(crate::eyre!("serve dispatch failed: {why}"))));
            }
            Err(e)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{ParallelPolicy, SparseBackend, SpmmAlgo};
    use crate::serve::batcher::BatchPolicy;
    use crate::serve::model::ServeLayer;
    use crate::sparsity::{random_row_mask, NmScheme};
    use crate::tensor::Matrix;
    use crate::util::Rng;

    fn engine() -> crate::Result<ServeEngine> {
        let mut rng = Rng::seed_from_u64(0xADA);
        let w = Matrix::randn(8, 16, 1.0, &mut rng);
        let mask = random_row_mask(8, 16, NmScheme::TWO_FOUR, &mut rng);
        let be = SparseBackend::setup(&w, mask, NmScheme::TWO_FOUR, SpmmAlgo::RowMajor,
                                      ParallelPolicy::serial());
        ServeEngine::new(vec![ServeLayer::new(be, None)?],
                         BatchPolicy::new(4, Duration::from_micros(200)))
    }

    #[test]
    fn closed_loop_client_round_trips() {
        let adm = Admission::spawn(engine, Duration::from_micros(100));
        let client = adm.client();
        for tag in 0..10u64 {
            client.submit(tag, vec![tag as f32; 16]).unwrap();
            let (got, resp) = client.recv().unwrap();
            assert_eq!(got, tag);
            assert_eq!(resp.output.len(), 8);
        }
        drop(client);
        let stats = adm.finish().unwrap();
        assert_eq!(stats.served, 10);
    }

    #[test]
    fn bad_request_is_rejected_per_request_not_fatally() {
        let adm = Admission::spawn(engine, Duration::from_micros(100));
        let client = adm.client();
        client.submit(7, vec![0.0; 3]).unwrap(); // wrong d_in
        let (tag, result) = {
            let (tag, r) = client.reply_rx.recv().unwrap();
            (tag, r)
        };
        assert_eq!(tag, 7);
        assert!(result.is_err(), "dimension mismatch surfaces on the reply");
        // The queue stays serviceable.
        client.submit(8, vec![1.0; 16]).unwrap();
        let (tag, resp) = client.recv().unwrap();
        assert_eq!(tag, 8);
        assert_eq!(resp.output.len(), 8);
        drop(client);
        let stats = adm.finish().unwrap();
        assert_eq!(stats.served, 1);
    }

    #[test]
    fn engine_build_failure_surfaces_at_finish() {
        let adm = Admission::spawn(
            || -> crate::Result<ServeEngine> { Err(crate::eyre!("no model")) },
            Duration::from_micros(100),
        );
        let client = adm.client();
        // Submissions may race the dispatcher's death; either the send or
        // the reply fails, and finish reports the build error.
        let _ = client.submit(0, vec![0.0; 16]);
        drop(client);
        let err = adm.finish().unwrap_err();
        assert!(err.to_string().contains("no model"));
    }
}
