//! Async admission: concurrent producers over the externally-clocked
//! engines.
//!
//! [`ServeEngine`] and [`DecodeEngine`] are single-threaded by design
//! (submit/poll under one caller's clock), which keeps the batching
//! policy deterministic and testable — but a deployment has many
//! producers.  This module bridges the two with the classic channel-fed
//! dispatch-thread shape, twice:
//!
//! * [`Admission`] — one-shot requests over a [`ServeEngine`]: any number
//!   of [`AdmissionClient`]s push tagged inputs into the queue, one
//!   dedicated dispatch thread owns the engine, and completed
//!   [`Response`]s are routed back over each client's private reply
//!   channel.
//! * [`DecodeAdmission`] — generation requests over a [`DecodeEngine`]:
//!   same shape, but the dispatch thread runs the continuous-batching
//!   scheduler hot while sequences are in flight, and replies carry whole
//!   [`Generation`]s.
//!
//! **Backpressure** ([`QueuePolicy`]): the queue between producers and
//! dispatcher is unbounded by default — fine for experiments, unbounded
//! memory under overload.  A bounded policy (`--queue-cap N`) makes
//! overload explicit with two shed disciplines ([`Overload`]):
//! `Reject` fails the submit immediately ("load shed" — the producer
//! sees the error and can back off), `Block` parks the producer on the
//! bounded channel until the dispatcher drains (classic backpressure).
//! The dispatcher cooperates by not draining the channel while the
//! engine already holds `cap` queued requests, so the end-to-end buffer
//! is bounded by ~2·cap rather than growing with offered load — the
//! knob tail-latency experiments use to model overload instead of just
//! contention.
//!
//! Engines are **built inside the dispatch thread** (the `spawn`
//! closure), not handed over: an [`crate::serve::AotModel`] holds a
//! thread-local cached `Session` and cannot cross threads, and the warm
//! kernel stack is cheaper to build where it will run anyway.
//!
//! Because every model is row/sequence-independent, the nondeterministic
//! coalescing that concurrency produces never changes any payload — N
//! concurrent producers get the same answers serial submission would
//! give them (pinned in `tests/serve_model.rs` and `tests/decode.rs`) —
//! only the *latency distribution* moves, which is exactly what
//! `slope serve --producers N` measures.
//!
//! Shutdown: drop every client, then call `finish` — the dispatch thread
//! sees the queue disconnect, flushes the engine, routes the tail, and
//! returns the final [`StatsSummary`].

use crate::serve::engine::{DecodeEngine, Generation, Response, ServeEngine};
use crate::serve::model::{DecodeModel, ServeModel};
use crate::serve::stats::StatsSummary;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, sync_channel, Receiver, RecvTimeoutError, Sender, SyncSender,
                      TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// What a producer does when the bounded admission queue is full.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Overload {
    /// Fail the submit immediately (load shedding; the producer sees the
    /// error).
    Reject,
    /// Park the producer until the dispatcher drains (backpressure).
    Block,
}

/// Admission-queue bound + overload discipline (module docs).
#[derive(Clone, Copy, Debug)]
pub struct QueuePolicy {
    /// `None` = unbounded (the pre-backpressure behaviour).
    pub cap: Option<usize>,
    pub overload: Overload,
}

impl QueuePolicy {
    pub fn unbounded() -> Self {
        Self { cap: None, overload: Overload::Reject }
    }

    pub fn bounded(cap: usize, overload: Overload) -> Self {
        assert!(cap >= 1, "queue cap must be at least 1");
        Self { cap: Some(cap), overload }
    }
}

impl Default for QueuePolicy {
    fn default() -> Self {
        Self::unbounded()
    }
}

/// Producer-side sender honoring the queue policy.
enum Tx<T> {
    Unbounded(Sender<T>),
    Bounded(SyncSender<T>, Overload),
}

impl<T> Clone for Tx<T> {
    fn clone(&self) -> Self {
        match self {
            Tx::Unbounded(s) => Tx::Unbounded(s.clone()),
            Tx::Bounded(s, o) => Tx::Bounded(s.clone(), *o),
        }
    }
}

impl<T> Tx<T> {
    fn send(&self, msg: T) -> crate::Result<()> {
        match self {
            Tx::Unbounded(s) => {
                s.send(msg).map_err(|_| crate::eyre!("admission queue is closed"))
            }
            Tx::Bounded(s, Overload::Block) => {
                s.send(msg).map_err(|_| crate::eyre!("admission queue is closed"))
            }
            Tx::Bounded(s, Overload::Reject) => match s.try_send(msg) {
                Ok(()) => Ok(()),
                Err(TrySendError::Full(_)) => {
                    Err(crate::eyre!("admission queue full; request shed"))
                }
                Err(TrySendError::Disconnected(_)) => {
                    Err(crate::eyre!("admission queue is closed"))
                }
            },
        }
    }
}

fn queue_channel<T>(policy: QueuePolicy) -> (Tx<T>, Receiver<T>) {
    match policy.cap {
        None => {
            let (tx, rx) = channel();
            (Tx::Unbounded(tx), rx)
        }
        Some(cap) => {
            let (tx, rx) = sync_channel(cap);
            (Tx::Bounded(tx, policy.overload), rx)
        }
    }
}

/// One routed reply: the client's tag plus the outcome.
pub type Reply = (u64, crate::Result<Response>);

/// Slack added to a client's total `recv` wait on top of the configured
/// request timeout: the dispatcher enforces the real deadline and always
/// replies (expired ⇒ an error reply), so this bound only has to cover
/// dispatch/compute latency — it exists so a wedged dispatcher can never
/// strand a producer forever once a budget is configured.
const CLIENT_RECV_GRACE: Duration = Duration::from_secs(5);

enum Msg {
    Submit { tag: u64, input: Vec<f32>, reply: Sender<Reply> },
}

/// Handle to a running one-shot admission front-end (module docs).
pub struct Admission {
    tx: Option<Tx<Msg>>,
    dispatcher: Option<JoinHandle<crate::Result<StatsSummary>>>,
    /// Cleared (via a drop guard) when the dispatch thread exits for any
    /// reason — clients poll it so a dead dispatcher can never strand
    /// them in `recv` (each client holds its own reply sender, so the
    /// reply channel alone cannot signal disconnection).
    alive: Arc<AtomicBool>,
    /// Per-request deadline budget applied at admission (`None` = no
    /// deadlines); also bounds minted clients' total `recv` wait.
    request_timeout: Option<Duration>,
}

/// A producer-side handle: submit tagged inputs, receive tagged replies.
/// Mint one per producer thread with [`Admission::client`].
pub struct AdmissionClient {
    tx: Tx<Msg>,
    reply_tx: Sender<Reply>,
    reply_rx: Receiver<Reply>,
    alive: Arc<AtomicBool>,
    /// Total-wait bound on `recv` (request timeout + grace); `None` when
    /// the front-end runs without deadlines.
    max_wait: Option<Duration>,
}

/// RAII: clears the liveness flag however the dispatch thread exits
/// (return or panic).
struct ClearOnExit(Arc<AtomicBool>);

impl Drop for ClearOnExit {
    fn drop(&mut self) {
        self.0.store(false, Ordering::SeqCst);
    }
}

impl Admission {
    /// Start the dispatch thread with an unbounded queue.  `build` runs
    /// on that thread and constructs the engine (see module docs for
    /// why); `tick` bounds how long the dispatcher sleeps between polls
    /// when no requests arrive — it should be a fraction of the batch
    /// policy's `max_wait` (see [`Admission::tick_for`]).
    pub fn spawn<M, F>(build: F, tick: Duration) -> Self
    where
        M: ServeModel + 'static,
        F: FnOnce() -> crate::Result<ServeEngine<M>> + Send + 'static,
    {
        Self::spawn_with_queue(build, tick, QueuePolicy::unbounded())
    }

    /// [`Admission::spawn`] with an explicit admission-queue policy.
    pub fn spawn_with_queue<M, F>(build: F, tick: Duration, queue: QueuePolicy) -> Self
    where
        M: ServeModel + 'static,
        F: FnOnce() -> crate::Result<ServeEngine<M>> + Send + 'static,
    {
        Self::spawn_with_opts(build, tick, queue, None)
    }

    /// [`Admission::spawn_with_queue`] plus a per-request deadline
    /// budget: each request's deadline is its admission time plus
    /// `request_timeout` (channel wait is governed by the queue policy),
    /// expired requests are answered with an error reply and counted in
    /// the engine's `deadline_expired` stat, and minted clients bound
    /// their total `recv` wait instead of polling forever.
    pub fn spawn_with_opts<M, F>(build: F, tick: Duration, queue: QueuePolicy,
                                 request_timeout: Option<Duration>) -> Self
    where
        M: ServeModel + 'static,
        F: FnOnce() -> crate::Result<ServeEngine<M>> + Send + 'static,
    {
        let (tx, rx) = queue_channel::<Msg>(queue);
        let alive = Arc::new(AtomicBool::new(true));
        let alive_in_thread = Arc::clone(&alive);
        let dispatcher = std::thread::Builder::new()
            .name("slope-admission".into())
            .spawn(move || {
                let _clear = ClearOnExit(alive_in_thread);
                dispatch(build, rx, tick, queue, request_timeout)
            })
            .expect("spawning admission dispatch thread");
        Self { tx: Some(tx), dispatcher: Some(dispatcher), alive, request_timeout }
    }

    /// A reasonable dispatch tick for a batch policy: a quarter of
    /// `max_wait`, clamped to [50 µs, 1 ms].
    pub fn tick_for(max_wait: Duration) -> Duration {
        (max_wait / 4).clamp(Duration::from_micros(50), Duration::from_millis(1))
    }

    /// Mint a producer handle (its own private reply channel).
    pub fn client(&self) -> AdmissionClient {
        let (reply_tx, reply_rx) = channel();
        AdmissionClient {
            tx: self.tx.as_ref().expect("admission already finished").clone(),
            reply_tx,
            reply_rx,
            alive: Arc::clone(&self.alive),
            max_wait: self.request_timeout.map(|t| t + CLIENT_RECV_GRACE),
        }
    }

    /// Shut down: close the queue, let the dispatcher flush, and return
    /// the engine's final stats.  Every [`AdmissionClient`] must be
    /// dropped first (each holds a queue sender; the dispatcher only
    /// stops once all senders are gone).
    pub fn finish(mut self) -> crate::Result<StatsSummary> {
        drop(self.tx.take());
        match self.dispatcher.take().expect("admission finished twice").join() {
            Ok(result) => result,
            Err(_) => Err(crate::eyre!("admission dispatch thread panicked")),
        }
    }
}

impl AdmissionClient {
    /// Enqueue one input under a caller-chosen tag (echoed on the reply).
    /// Errors if the queue has shut down — or, under a bounded
    /// [`Overload::Reject`] policy, if the queue is full (the shed
    /// signal).
    pub fn submit(&self, tag: u64, input: Vec<f32>) -> crate::Result<()> {
        self.tx.send(Msg::Submit { tag, input, reply: self.reply_tx.clone() })
    }

    /// Block until the next reply for this client arrives.  Returns an
    /// error (instead of hanging) if the dispatcher has died with the
    /// request unanswered — or, under a configured request timeout, once
    /// the total wait exceeds the budget plus grace (instead of polling
    /// forever).
    pub fn recv(&self) -> crate::Result<(u64, Response)> {
        let waited = Instant::now();
        loop {
            match self.reply_rx.recv_timeout(Duration::from_millis(5)) {
                Ok((tag, result)) => return Ok((tag, result?)),
                Err(RecvTimeoutError::Timeout) => {
                    if !self.alive.load(Ordering::SeqCst) {
                        // One last non-blocking look: a reply routed just
                        // before shutdown must not be lost.
                        if let Ok((tag, result)) = self.reply_rx.try_recv() {
                            return Ok((tag, result?));
                        }
                        return Err(crate::eyre!("admission dispatcher is gone"));
                    }
                    if matches!(self.max_wait, Some(bound) if waited.elapsed() > bound) {
                        return Err(crate::eyre!(
                            "no reply within the request-timeout budget"
                        ));
                    }
                }
                Err(RecvTimeoutError::Disconnected) => {
                    return Err(crate::eyre!("admission dispatcher is gone"));
                }
            }
        }
    }
}

/// The dispatch thread body: run the loop, and on a fatal error reply
/// `Err` to every submission still sitting in the queue so no producer is
/// left blocking on a reply that will never come (submissions arriving
/// after this drain fail at `send` — the receiver is dropped with us).
fn dispatch<M, F>(build: F, rx: Receiver<Msg>, tick: Duration, queue: QueuePolicy,
                  request_timeout: Option<Duration>) -> crate::Result<StatsSummary>
where
    M: ServeModel,
    F: FnOnce() -> crate::Result<ServeEngine<M>>,
{
    let result = dispatch_loop(build, &rx, tick, queue, request_timeout);
    if let Err(e) = &result {
        let why = e.to_string();
        while let Ok(Msg::Submit { tag, reply, .. }) = rx.try_recv() {
            let _ = reply.send((tag, Err(crate::eyre!("serve dispatch failed: {why}"))));
        }
    }
    result
}

/// The dispatch loop (runs on the dedicated thread).
fn dispatch_loop<M, F>(build: F, rx: &Receiver<Msg>, tick: Duration, queue: QueuePolicy,
                       request_timeout: Option<Duration>) -> crate::Result<StatsSummary>
where
    M: ServeModel,
    F: FnOnce() -> crate::Result<ServeEngine<M>>,
{
    let mut engine = build()?;
    let start = Instant::now();
    let mut routes: HashMap<u64, (u64, Sender<Reply>)> = HashMap::new();
    let mut open = true;
    while open {
        // Backpressure: with the engine already holding `cap` queued
        // requests, leave arrivals in the (bounded) channel — producers
        // block or shed there — and let the poll below drain the engine.
        let room = |engine: &ServeEngine<M>| match queue.cap {
            Some(c) => engine.pending() < c,
            None => true,
        };
        if room(&engine) {
            match rx.recv_timeout(tick) {
                Ok(msg) => {
                    admit(&mut engine, msg, start, &mut routes, request_timeout);
                    // Drain whatever else queued up while we were busy, so
                    // a burst coalesces into one batch instead of one per
                    // tick — but never past the queue bound.
                    while room(&engine) {
                        match rx.try_recv() {
                            Ok(msg) => {
                                admit(&mut engine, msg, start, &mut routes, request_timeout)
                            }
                            Err(_) => break,
                        }
                    }
                }
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => open = false,
            }
        } else {
            std::thread::sleep(tick);
        }
        // Dispatch EVERY due batch before sleeping again: a backlog must
        // drain at compute speed, not at one batch per tick (the tick
        // would otherwise dominate the tail latencies this front-end
        // exists to measure).
        loop {
            let due = engine.poll(start.elapsed());
            let drained = matches!(&due, Ok(v) if v.is_empty());
            route(due, &mut routes)?;
            if drained {
                break;
            }
        }
    }
    let tail = engine.flush(start.elapsed());
    route(tail, &mut routes)?;
    Ok(engine.stats().summary())
}

fn admit<M: ServeModel>(engine: &mut ServeEngine<M>, msg: Msg, start: Instant,
                        routes: &mut HashMap<u64, (u64, Sender<Reply>)>,
                        request_timeout: Option<Duration>) {
    let Msg::Submit { tag, input, reply } = msg;
    // The deadline clock starts at admission (the engine's submit time);
    // time spent in the bounded channel is governed by the queue policy.
    let now = start.elapsed();
    match engine.submit_with_deadline(input, now, request_timeout.map(|t| now + t)) {
        Ok(id) => {
            routes.insert(id, (tag, reply));
        }
        Err(e) => {
            let _ = reply.send((tag, Err(e)));
        }
    }
}

/// Send completed responses to their submitters; on an engine error,
/// fail every outstanding route (the batch that died is unidentifiable
/// from here) and propagate.
fn route(result: crate::Result<Vec<Response>>,
         routes: &mut HashMap<u64, (u64, Sender<Reply>)>) -> crate::Result<()> {
    match result {
        Ok(responses) => {
            for resp in responses {
                if let Some((tag, reply)) = routes.remove(&resp.id) {
                    // An expired request is answered with an error, not a
                    // payload-less success.
                    let outcome = if resp.deadline_expired {
                        Err(crate::eyre!("request deadline expired before dispatch"))
                    } else {
                        Ok(resp)
                    };
                    let _ = reply.send((tag, outcome));
                }
            }
            Ok(())
        }
        Err(e) => {
            let why = e.to_string();
            for (_, (tag, reply)) in routes.drain() {
                let _ = reply.send((tag, Err(crate::eyre!("serve dispatch failed: {why}"))));
            }
            Err(e)
        }
    }
}

// ---- generation admission ----------------------------------------------

/// One routed generation reply: the client's tag plus the outcome.
pub type GenReply = (u64, crate::Result<Generation>);

enum GenMsg {
    Submit { tag: u64, prompt: Vec<i32>, max_new: Option<usize>, reply: Sender<GenReply> },
    Cancel { tag: u64 },
}

/// Handle to a running generation admission front-end: the async face of
/// the continuous-batching [`DecodeEngine`] (module docs).
pub struct DecodeAdmission {
    tx: Option<Tx<GenMsg>>,
    dispatcher: Option<JoinHandle<crate::Result<StatsSummary>>>,
    alive: Arc<AtomicBool>,
    /// Per-request deadline budget applied at admission (`None` = no
    /// deadlines); also bounds minted clients' total `recv` wait.
    request_timeout: Option<Duration>,
}

/// A producer-side handle for generation requests.
pub struct DecodeClient {
    tx: Tx<GenMsg>,
    reply_tx: Sender<GenReply>,
    reply_rx: Receiver<GenReply>,
    alive: Arc<AtomicBool>,
    /// Total-wait bound on `recv` (request timeout + grace); `None` when
    /// the front-end runs without deadlines.
    max_wait: Option<Duration>,
}

impl DecodeAdmission {
    /// Start the dispatch thread (engine built inside it).  While
    /// sequences are in flight the dispatcher steps the scheduler hot,
    /// draining arrivals non-blockingly between steps; idle, it parks on
    /// the queue with `tick` timeouts.
    pub fn spawn<M, F>(build: F, tick: Duration, queue: QueuePolicy) -> Self
    where
        M: DecodeModel + 'static,
        F: FnOnce() -> crate::Result<DecodeEngine<M>> + Send + 'static,
    {
        Self::spawn_with_opts(build, tick, queue, None)
    }

    /// [`DecodeAdmission::spawn`] plus a per-request deadline budget:
    /// each sequence's deadline is its admission time plus
    /// `request_timeout`; an expired sequence is dropped by the scheduler
    /// (waiting or mid-decode) and its client receives a
    /// [`crate::serve::FinishReason::Deadline`] generation.
    pub fn spawn_with_opts<M, F>(build: F, tick: Duration, queue: QueuePolicy,
                                 request_timeout: Option<Duration>) -> Self
    where
        M: DecodeModel + 'static,
        F: FnOnce() -> crate::Result<DecodeEngine<M>> + Send + 'static,
    {
        let (tx, rx) = queue_channel::<GenMsg>(queue);
        let alive = Arc::new(AtomicBool::new(true));
        let alive_in_thread = Arc::clone(&alive);
        let dispatcher = std::thread::Builder::new()
            .name("slope-decode-admission".into())
            .spawn(move || {
                let _clear = ClearOnExit(alive_in_thread);
                gen_dispatch(build, rx, tick, queue, request_timeout)
            })
            .expect("spawning decode admission dispatch thread");
        Self { tx: Some(tx), dispatcher: Some(dispatcher), alive, request_timeout }
    }

    /// Mint a producer handle (its own private reply channel).
    pub fn client(&self) -> DecodeClient {
        let (reply_tx, reply_rx) = channel();
        DecodeClient {
            tx: self.tx.as_ref().expect("admission already finished").clone(),
            reply_tx,
            reply_rx,
            alive: Arc::clone(&self.alive),
            max_wait: self.request_timeout.map(|t| t + CLIENT_RECV_GRACE),
        }
    }

    /// Shut down: close the queue, let the dispatcher run every in-flight
    /// generation to completion, and return the final stats.  Every
    /// [`DecodeClient`] must be dropped first.
    pub fn finish(mut self) -> crate::Result<StatsSummary> {
        drop(self.tx.take());
        match self.dispatcher.take().expect("admission finished twice").join() {
            Ok(result) => result,
            Err(_) => Err(crate::eyre!("decode admission dispatch thread panicked")),
        }
    }
}

impl DecodeClient {
    /// Enqueue one prompt under a caller-chosen tag (echoed on the
    /// reply); `max_new` caps this request's generated tokens (`None` =
    /// engine default).  Errors if the queue has shut down — or, under a
    /// bounded [`Overload::Reject`] policy, if the queue is full.
    pub fn submit(&self, tag: u64, prompt: Vec<i32>,
                  max_new: Option<usize>) -> crate::Result<()> {
        self.tx.send(GenMsg::Submit { tag, prompt, max_new, reply: self.reply_tx.clone() })
    }

    /// Request cancellation of a previously submitted generation by its
    /// tag (fire-and-forget).  The scheduler drops the request on its
    /// next step and the pending [`DecodeClient::recv`] receives the
    /// generation with [`crate::serve::FinishReason::Cancelled`] and
    /// whatever tokens were already decoded — its KV blocks and
    /// prefix-cache references are freed immediately.  Cancelling an
    /// unknown or already-finished tag is a silent no-op.  Errors only
    /// if the queue has shut down (or sheds the message under a full
    /// bounded [`Overload::Reject`] queue, like any submit).
    pub fn cancel(&self, tag: u64) -> crate::Result<()> {
        self.tx.send(GenMsg::Cancel { tag })
    }

    /// Block until this client's next completed generation arrives.
    /// Returns an error (instead of hanging) if the dispatcher has died
    /// with the request unanswered — or, under a configured request
    /// timeout, once the total wait exceeds the budget plus grace.
    pub fn recv(&self) -> crate::Result<(u64, Generation)> {
        let waited = Instant::now();
        loop {
            match self.reply_rx.recv_timeout(Duration::from_millis(5)) {
                Ok((tag, result)) => return Ok((tag, result?)),
                Err(RecvTimeoutError::Timeout) => {
                    if !self.alive.load(Ordering::SeqCst) {
                        if let Ok((tag, result)) = self.reply_rx.try_recv() {
                            return Ok((tag, result?));
                        }
                        return Err(crate::eyre!("decode admission dispatcher is gone"));
                    }
                    if matches!(self.max_wait, Some(bound) if waited.elapsed() > bound) {
                        return Err(crate::eyre!(
                            "no reply within the request-timeout budget"
                        ));
                    }
                }
                Err(RecvTimeoutError::Disconnected) => {
                    return Err(crate::eyre!("decode admission dispatcher is gone"));
                }
            }
        }
    }
}

fn gen_dispatch<M, F>(build: F, rx: Receiver<GenMsg>, tick: Duration, queue: QueuePolicy,
                      request_timeout: Option<Duration>) -> crate::Result<StatsSummary>
where
    M: DecodeModel,
    F: FnOnce() -> crate::Result<DecodeEngine<M>>,
{
    let result = gen_dispatch_loop(build, &rx, tick, queue, request_timeout);
    if let Err(e) = &result {
        let why = e.to_string();
        while let Ok(msg) = rx.try_recv() {
            if let GenMsg::Submit { tag, reply, .. } = msg {
                let _ = reply.send((tag, Err(crate::eyre!("decode dispatch failed: {why}"))));
            }
        }
    }
    result
}

fn gen_dispatch_loop<M, F>(build: F, rx: &Receiver<GenMsg>, tick: Duration,
                           queue: QueuePolicy,
                           request_timeout: Option<Duration>) -> crate::Result<StatsSummary>
where
    M: DecodeModel,
    F: FnOnce() -> crate::Result<DecodeEngine<M>>,
{
    let mut engine = build()?;
    let start = Instant::now();
    let mut routes: HashMap<u64, (u64, Sender<GenReply>)> = HashMap::new();
    let mut open = true;
    loop {
        if open {
            let room = |engine: &DecodeEngine<M>| match queue.cap {
                Some(c) => engine.pending() < c,
                None => true,
            };
            if room(&engine) {
                if engine.active() > 0 {
                    // Busy: drain ready arrivals without blocking; the
                    // scheduler below keeps the batch hot.
                    loop {
                        if !room(&engine) {
                            break;
                        }
                        match rx.try_recv() {
                            Ok(msg) => {
                                gen_admit(&mut engine, msg, start, &mut routes,
                                          request_timeout)
                            }
                            Err(std::sync::mpsc::TryRecvError::Empty) => break,
                            Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                                open = false;
                                break;
                            }
                        }
                    }
                } else {
                    match rx.recv_timeout(tick) {
                        Ok(msg) => {
                            gen_admit(&mut engine, msg, start, &mut routes, request_timeout);
                            while room(&engine) {
                                match rx.try_recv() {
                                    Ok(msg) => {
                                        gen_admit(&mut engine, msg, start, &mut routes,
                                                  request_timeout)
                                    }
                                    Err(_) => break,
                                }
                            }
                        }
                        Err(RecvTimeoutError::Timeout) => {}
                        Err(RecvTimeoutError::Disconnected) => open = false,
                    }
                }
            }
        }
        if engine.active() > 0 {
            let done = engine.step(start.elapsed());
            route_gen(done, &mut routes)?;
        } else if !open {
            break;
        }
    }
    Ok(engine.stats().summary())
}

fn gen_admit<M: DecodeModel>(engine: &mut DecodeEngine<M>, msg: GenMsg, start: Instant,
                             routes: &mut HashMap<u64, (u64, Sender<GenReply>)>,
                             request_timeout: Option<Duration>) {
    match msg {
        GenMsg::Submit { tag, prompt, max_new, reply } => {
            // The deadline clock starts at admission; time spent in the
            // bounded channel is governed by the queue policy.
            let now = start.elapsed();
            match engine.submit_with_deadline(prompt, max_new, now,
                                              request_timeout.map(|t| now + t)) {
                Ok(id) => {
                    routes.insert(id, (tag, reply));
                }
                Err(e) => {
                    let _ = reply.send((tag, Err(e)));
                }
            }
        }
        GenMsg::Cancel { tag } => {
            // Fire-and-forget: resolve the tag against the in-flight
            // route table (FIFO ordering guarantees the submit was
            // admitted first); unknown or already-routed tags are a
            // no-op.  The route entry stays — the Cancelled generation
            // is delivered through it on the scheduler's next step.
            let id = routes.iter().find(|(_, (t, _))| *t == tag).map(|(&id, _)| id);
            if let Some(id) = id {
                engine.cancel(id);
            }
        }
    }
}

fn route_gen(result: crate::Result<Vec<Generation>>,
             routes: &mut HashMap<u64, (u64, Sender<GenReply>)>) -> crate::Result<()> {
    match result {
        Ok(done) => {
            for gen in done {
                if let Some((tag, reply)) = routes.remove(&gen.id) {
                    let _ = reply.send((tag, Ok(gen)));
                }
            }
            Ok(())
        }
        Err(e) => {
            let why = e.to_string();
            for (_, (tag, reply)) in routes.drain() {
                let _ = reply.send((tag, Err(crate::eyre!("decode dispatch failed: {why}"))));
            }
            Err(e)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{ParallelPolicy, SparseBackend, SpmmAlgo};
    use crate::serve::batcher::BatchPolicy;
    use crate::serve::engine::DecodePolicy;
    use crate::serve::model::{KernelDecodeModel, SeqId, ServeLayer};
    use crate::sparsity::{random_row_mask, NmScheme};
    use crate::tensor::Matrix;
    use crate::util::Rng;

    fn engine() -> crate::Result<ServeEngine> {
        let mut rng = Rng::seed_from_u64(0xADA);
        let w = Matrix::randn(8, 16, 1.0, &mut rng);
        let mask = random_row_mask(8, 16, NmScheme::TWO_FOUR, &mut rng);
        let be = SparseBackend::setup(&w, mask, NmScheme::TWO_FOUR, SpmmAlgo::RowMajor,
                                      ParallelPolicy::serial());
        ServeEngine::new(vec![ServeLayer::new(be, None)?],
                         BatchPolicy::new(4, Duration::from_micros(200)))
    }

    #[test]
    fn closed_loop_client_round_trips() {
        let adm = Admission::spawn(engine, Duration::from_micros(100));
        let client = adm.client();
        for tag in 0..10u64 {
            client.submit(tag, vec![tag as f32; 16]).unwrap();
            let (got, resp) = client.recv().unwrap();
            assert_eq!(got, tag);
            assert_eq!(resp.output.len(), 8);
        }
        drop(client);
        let stats = adm.finish().unwrap();
        assert_eq!(stats.served, 10);
    }

    #[test]
    fn bad_request_is_rejected_per_request_not_fatally() {
        let adm = Admission::spawn(engine, Duration::from_micros(100));
        let client = adm.client();
        client.submit(7, vec![0.0; 3]).unwrap(); // wrong d_in
        let (tag, result) = {
            let (tag, r) = client.reply_rx.recv().unwrap();
            (tag, r)
        };
        assert_eq!(tag, 7);
        assert!(result.is_err(), "dimension mismatch surfaces on the reply");
        // The queue stays serviceable.
        client.submit(8, vec![1.0; 16]).unwrap();
        let (tag, resp) = client.recv().unwrap();
        assert_eq!(tag, 8);
        assert_eq!(resp.output.len(), 8);
        drop(client);
        let stats = adm.finish().unwrap();
        assert_eq!(stats.served, 1);
    }

    #[test]
    fn engine_build_failure_surfaces_at_finish() {
        let adm = Admission::spawn(
            || -> crate::Result<ServeEngine> { Err(crate::eyre!("no model")) },
            Duration::from_micros(100),
        );
        let client = adm.client();
        // Submissions may race the dispatcher's death; either the send or
        // the reply fails, and finish reports the build error.
        let _ = client.submit(0, vec![0.0; 16]);
        drop(client);
        let err = adm.finish().unwrap_err();
        assert!(err.to_string().contains("no model"));
    }

    #[test]
    fn bounded_reject_sheds_when_the_dispatcher_is_stalled() {
        // A build that parks the dispatcher long enough for the bounded
        // channel to fill deterministically: cap 2 ⇒ the third submit is
        // shed client-side with a queue-full error.
        let adm = Admission::spawn_with_queue(
            || -> crate::Result<ServeEngine> {
                std::thread::sleep(Duration::from_millis(150));
                engine()
            },
            Duration::from_micros(100),
            QueuePolicy::bounded(2, Overload::Reject),
        );
        let client = adm.client();
        client.submit(0, vec![1.0; 16]).unwrap();
        client.submit(1, vec![1.0; 16]).unwrap();
        let err = client.submit(2, vec![1.0; 16]).unwrap_err();
        assert!(err.to_string().contains("full"), "{err}");
        // The two admitted requests complete once the engine is up.
        let mut tags = vec![client.recv().unwrap().0, client.recv().unwrap().0];
        tags.sort_unstable();
        assert_eq!(tags, vec![0, 1]);
        drop(client);
        let stats = adm.finish().unwrap();
        assert_eq!(stats.served, 2);
    }

    #[test]
    fn bounded_block_backpressures_but_completes_everything() {
        let adm = Admission::spawn_with_queue(
            engine,
            Duration::from_micros(100),
            QueuePolicy::bounded(1, Overload::Block),
        );
        let n = 16u64;
        let submitter = {
            let c = adm.client();
            std::thread::spawn(move || {
                for tag in 0..n {
                    c.submit(tag, vec![0.5; 16]).unwrap();
                }
            })
        };
        // All submissions eventually land despite the cap-1 queue.
        submitter.join().expect("submitter");
        let stats = adm.finish().unwrap();
        assert_eq!(stats.served, n as usize, "blocking producers lose nothing");
    }

    #[test]
    fn expired_response_routes_back_as_an_error_reply() {
        // Deterministic unit check of the route conversion: a response
        // flagged `deadline_expired` must reach its submitter as an Err
        // reply, not a payload-less success.
        let (reply_tx, reply_rx) = channel::<Reply>();
        let mut routes: HashMap<u64, (u64, Sender<Reply>)> = HashMap::new();
        routes.insert(1, (41, reply_tx.clone()));
        routes.insert(2, (42, reply_tx));
        let responses = vec![
            Response {
                id: 1,
                output: vec![],
                queued: Duration::from_millis(9),
                latency: Duration::from_millis(9),
                deadline_expired: true,
            },
            Response {
                id: 2,
                output: vec![1.0; 8],
                queued: Duration::from_millis(1),
                latency: Duration::from_millis(2),
                deadline_expired: false,
            },
        ];
        route(Ok(responses), &mut routes).unwrap();
        assert!(routes.is_empty(), "both replies routed");
        let mut outcomes: Vec<(u64, bool)> = (0..2)
            .map(|_| {
                let (tag, r) = reply_rx.try_recv().unwrap();
                (tag, r.is_ok())
            })
            .collect();
        outcomes.sort_unstable();
        assert_eq!(outcomes, vec![(41, false), (42, true)],
                   "expired → Err, live → Ok");
    }

    #[test]
    fn request_timeout_expires_queued_work_with_an_error_reply() {
        // A zero budget puts the deadline at the admission instant, so
        // the request is deterministically expired by the time the batch
        // dispatches — no sleep-based timing in the test.
        let adm = Admission::spawn_with_opts(
            engine,
            Duration::from_micros(100),
            QueuePolicy::unbounded(),
            Some(Duration::ZERO),
        );
        let client = adm.client();
        client.submit(5, vec![1.0; 16]).unwrap();
        let err = client.recv().unwrap_err();
        assert!(err.to_string().contains("deadline expired"), "{err}");
        drop(client);
        let stats = adm.finish().unwrap();
        assert_eq!(stats.served, 0, "expired requests are not served");
        assert_eq!(stats.deadline_expired, 1);
    }

    /// A decode model that can never finish on its own (huge context, no
    /// EOS): the only way its generations complete is cancellation, so
    /// the client-cancel round trip below is free of finish races.
    #[derive(Default)]
    struct Endless {
        seqs: Vec<bool>,
    }

    impl DecodeModel for Endless {
        fn vocab(&self) -> usize {
            4
        }
        fn max_seq_len(&self) -> usize {
            1 << 30
        }
        fn validate_prompt(&self, prompt: &[i32]) -> crate::Result<()> {
            crate::ensure!(!prompt.is_empty(), "empty prompt");
            Ok(())
        }
        fn prefill(&mut self, _prompt: &[i32],
                   logits: &mut crate::tensor::Matrix) -> crate::Result<SeqId> {
            crate::backend::ensure_out(logits, 1, 4);
            logits.row_mut(0).fill(0.0);
            logits.row_mut(0)[0] = 1.0;
            self.seqs.push(true);
            Ok((self.seqs.len() - 1) as SeqId)
        }
        fn decode_step(&mut self, seqs: &[SeqId], _tokens: &[i32],
                       logits: &mut crate::tensor::Matrix) -> crate::Result<()> {
            crate::backend::ensure_out(logits, seqs.len(), 4);
            for i in 0..seqs.len() {
                logits.row_mut(i).fill(0.0);
                logits.row_mut(i)[0] = 1.0;
            }
            // Keep the hot dispatch loop from spinning flat out while
            // the test thread sends the cancel.
            std::thread::sleep(Duration::from_micros(200));
            Ok(())
        }
        fn free_seq(&mut self, seq: SeqId) -> crate::Result<()> {
            crate::ensure!(std::mem::take(&mut self.seqs[seq as usize]), "double free");
            Ok(())
        }
        fn seq_tokens(&self, seq: SeqId) -> Option<usize> {
            self.seqs.get(seq as usize).copied().then_some(2)
        }
        fn live_seqs(&self) -> usize {
            self.seqs.iter().filter(|s| **s).count()
        }
        fn describe_decode(&self) -> String {
            "endless".into()
        }
    }

    #[test]
    fn client_cancel_finishes_an_endless_generation() {
        use crate::serve::engine::FinishReason;
        let build = || -> crate::Result<DecodeEngine<Endless>> {
            DecodeEngine::new(
                Endless::default(),
                // A cap large enough that MaxTokens never fires within the
                // test's lifetime (each decode step sleeps 200 µs).
                DecodePolicy { max_batch: 2, max_new_tokens: 1 << 20, ..Default::default() },
            )
        };
        let adm = DecodeAdmission::spawn(build, Duration::from_micros(100),
                                         QueuePolicy::unbounded());
        let client = adm.client();
        client.submit(7, vec![1, 2], None).unwrap();
        client.cancel(7).unwrap();
        let (tag, gen) = client.recv().unwrap();
        assert_eq!(tag, 7);
        assert_eq!(gen.finish, FinishReason::Cancelled);
        assert!(gen.tokens.len() < 1 << 20, "cancelled well before the cap");
        // Cancelling an unknown or already-finished tag is a silent no-op.
        client.cancel(99).unwrap();
        client.cancel(7).unwrap();
        drop(client);
        let stats = adm.finish().unwrap();
        assert_eq!(stats.cancelled, 1);
        assert_eq!(stats.served, 0, "a cancelled generation is not served");
    }

    #[test]
    fn decode_admission_round_trips_generations() {
        let build = || -> crate::Result<DecodeEngine<KernelDecodeModel>> {
            let model = KernelDecodeModel::synthetic(64, 16, 32, 4, 12,
                                                     ParallelPolicy::serial(), 9)?;
            DecodeEngine::new(
                model,
                DecodePolicy { max_batch: 2, max_new_tokens: 4, ..Default::default() },
            )
        };
        let adm = DecodeAdmission::spawn(build, Duration::from_micros(100),
                                         QueuePolicy::unbounded());
        let client = adm.client();
        for tag in 0..6u64 {
            client.submit(tag, vec![(tag % 8) as i32, 3], None).unwrap();
        }
        let mut got = 0usize;
        for _ in 0..6 {
            let (_, gen) = client.recv().unwrap();
            assert_eq!(gen.tokens.len(), 4);
            assert_eq!(gen.prompt_len, 2);
            got += 1;
        }
        assert_eq!(got, 6);
        drop(client);
        let stats = adm.finish().unwrap();
        assert_eq!(stats.served, 6);
        assert_eq!(stats.prefills, 6);
        assert_eq!(stats.tokens_out, 6 * 3, "3 post-prefill tokens per request");
        assert!(stats.decode_p99_ms >= 0.0);
    }
}
