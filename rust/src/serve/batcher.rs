//! Coalescing request queue — the admission side of the serving engine.
//!
//! Requests arrive one at a time and are coalesced into forward batches
//! under a [`BatchPolicy`]: dispatch as soon as `max_batch` requests are
//! queued, or as soon as the *oldest* queued request has waited
//! `max_wait` (the latency/throughput trade every dynamic batcher makes).
//!
//! Time is an explicit [`Duration`]-since-engine-start parameter rather
//! than an internal clock read, so the policy logic is deterministic and
//! testable with synthetic timelines; the CLI and example simply pass
//! `start.elapsed()`.

use std::collections::VecDeque;
use std::time::Duration;

/// Dispatch policy for the coalescing queue.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BatchPolicy {
    /// Maximum requests per forward (dispatch immediately at this fill).
    pub max_batch: usize,
    /// Maximum time the oldest request may wait before a partial batch
    /// is dispatched anyway.
    pub max_wait: Duration,
}

impl BatchPolicy {
    pub fn new(max_batch: usize, max_wait: Duration) -> Self {
        assert!(max_batch >= 1, "max_batch must be at least 1");
        Self { max_batch, max_wait }
    }
}

impl Default for BatchPolicy {
    fn default() -> Self {
        Self { max_batch: 8, max_wait: Duration::from_millis(2) }
    }
}

/// One queued inference request: a single `d_in`-feature input row.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub input: Vec<f32>,
    /// Engine-relative submission time.
    pub submitted: Duration,
    /// Engine-relative deadline (`None` = no budget): a request still
    /// queued past this instant is expired at dispatch instead of
    /// consuming forward compute.
    pub deadline: Option<Duration>,
}

/// FIFO coalescing queue under a [`BatchPolicy`].
#[derive(Debug)]
pub struct Batcher {
    policy: BatchPolicy,
    queue: VecDeque<Request>,
}

impl Batcher {
    pub fn new(policy: BatchPolicy) -> Self {
        Self { policy, queue: VecDeque::new() }
    }

    pub fn policy(&self) -> BatchPolicy {
        self.policy
    }

    pub fn push(&mut self, req: Request) {
        self.queue.push_back(req);
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Would a batch dispatch at time `now`?  True when the queue holds a
    /// full `max_batch`, or when the oldest request has waited `max_wait`.
    pub fn ready(&self, now: Duration) -> bool {
        if self.queue.len() >= self.policy.max_batch {
            return true;
        }
        match self.queue.front() {
            Some(oldest) => now.saturating_sub(oldest.submitted) >= self.policy.max_wait,
            None => false,
        }
    }

    /// Pop the next batch (up to `max_batch` requests, FIFO).  Callers
    /// gate on [`Batcher::ready`]; `take_batch` itself just drains.
    pub fn take_batch(&mut self) -> Vec<Request> {
        let mut out = Vec::new();
        self.take_batch_into(&mut out);
        out
    }

    /// [`Batcher::take_batch`] into a reusable buffer (cleared first) —
    /// the engine's dispatch loop reuses one buffer across batches so
    /// steady-state dispatch allocates nothing.
    pub fn take_batch_into(&mut self, out: &mut Vec<Request>) {
        out.clear();
        let k = self.queue.len().min(self.policy.max_batch);
        out.extend(self.queue.drain(..k));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, at_ms: u64) -> Request {
        Request {
            id,
            input: vec![0.0; 4],
            submitted: Duration::from_millis(at_ms),
            deadline: None,
        }
    }

    #[test]
    fn full_batch_dispatches_immediately() {
        let mut b = Batcher::new(BatchPolicy::new(3, Duration::from_millis(100)));
        b.push(req(0, 0));
        b.push(req(1, 0));
        assert!(!b.ready(Duration::from_millis(1)), "2 of 3 and wait not exceeded");
        b.push(req(2, 1));
        assert!(b.ready(Duration::from_millis(1)), "full batch is ready at once");
        let batch = b.take_batch();
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2]);
        assert!(b.is_empty());
    }

    #[test]
    fn max_wait_flushes_partial_batch() {
        let mut b = Batcher::new(BatchPolicy::new(8, Duration::from_millis(10)));
        b.push(req(7, 5));
        assert!(!b.ready(Duration::from_millis(14)), "9 ms wait < 10 ms max_wait");
        assert!(b.ready(Duration::from_millis(15)), "10 ms wait hits max_wait");
        assert_eq!(b.take_batch().len(), 1);
    }

    #[test]
    fn overfull_queue_dispatches_in_policy_sized_chunks() {
        let mut b = Batcher::new(BatchPolicy::new(2, Duration::from_millis(1)));
        for i in 0..5 {
            b.push(req(i, 0));
        }
        assert_eq!(b.take_batch().len(), 2);
        assert_eq!(b.take_batch().len(), 2);
        assert_eq!(b.take_batch().len(), 1);
        assert!(!b.ready(Duration::from_millis(999)), "empty queue is never ready");
    }

    #[test]
    fn take_batch_into_reuses_the_buffer() {
        let mut b = Batcher::new(BatchPolicy::new(4, Duration::from_millis(1)));
        let mut buf = Vec::with_capacity(4);
        for round in 0..3u64 {
            for i in 0..4 {
                b.push(req(round * 4 + i, 0));
            }
            let cap_before = buf.capacity();
            b.take_batch_into(&mut buf);
            assert_eq!(buf.len(), 4);
            assert_eq!(buf[0].id, round * 4, "FIFO order per round");
            if round > 0 {
                assert_eq!(buf.capacity(), cap_before, "steady state must not regrow");
            }
        }
    }
}
