//! `slope::serve` — the first-class serving subsystem.
//!
//! SLoPe's headline inference claim (Table 2: up to 1.54× end-to-end
//! speedup) is a *serving* claim, so deployment gets a real subsystem.
//! Its spine is two traits over one admission shape:
//!
//! ```text
//!   producers ──mpsc──► [admission]  ──► ServeEngine<M: ServeModel>   ──► M
//!             (bounded,  dispatch        batcher + stats + staging        one-shot math
//!              shed or   thread
//!              block)             └────► DecodeEngine<M: DecodeModel> ──► M
//!                                        continuous batching              prefill +
//!                                        (join/leave per sequence)        KV-cached steps
//! ```
//!
//! * [`model`] — the contracts and their production implementations:
//!   * [`ServeModel`] — the one-shot coalesced-batch contract
//!     (`d_in`/`d_out`/`forward_batch_into`), served by
//!     [`KernelStackModel`] (warm compressed-2:4 [`ServeLayer`]s + fused
//!     LoRA) and [`AotModel`] (a checkpointed transformer behind a
//!     manifest — PJRT when the executables compile, the host kernel
//!     executor otherwise);
//!   * [`DecodeModel`] — the autoregressive contract (`prefill` /
//!     `decode_step` / `free_seq` over [`SeqId`] handles, plus the
//!     [`Sampler`] hook).  [`AotModel`] implements it over the host
//!     executor's per-sequence [`crate::runtime::KvCache`] (incremental,
//!     bit-identical to full recompute) with a padded-replay fallback on
//!     the PJRT route; [`KernelDecodeModel`] is the synthetic
//!     kernel-stack analog for tests and the no-checkpoint CLI path.
//! * [`engine`] — [`ServeEngine`] (externally-clocked one-shot admission
//!   core) and [`DecodeEngine`] (the continuous-batching scheduler:
//!   sequences join the running batch after prefill, share one coalesced
//!   decode step per tick, and leave individually on EOS/max-tokens).
//! * [`batcher`] — the coalescing queue: dispatch at `max_batch` fill or
//!   when the oldest request has waited `max_wait`.
//! * [`admission`] — the async front-ends ([`Admission`],
//!   [`DecodeAdmission`]): mpsc producers + a dedicated dispatch thread,
//!   now with an explicit [`QueuePolicy`] — bounded admission
//!   (`--queue-cap N`) that sheds ([`Overload::Reject`]) or
//!   backpressures ([`Overload::Block`]) instead of growing without
//!   bound.
//! * [`stats`] — split telemetry: per-request latency quantiles AND
//!   per-token decode-step quantiles, batch/decode fill, req/s and
//!   tok/s, plus KV block-pool occupancy (blocks/bytes in use, peak,
//!   recycle and exhaustion counters) on paged decode backends.
//!
//! Decode-route KV state lives in the paged
//! [`crate::runtime::KvBlockPool`] (`runtime/kvpool`): per-sequence
//! caches are block tables over a shared free-list, `--kv-dtype
//! f32|f16|int8` selects the plane storage, and pool exhaustion reaches
//! the scheduler as backpressure (admission waits for running sequences
//! to free blocks) instead of a panic or a dropped dispatch thread.
//! `--prefix-cache` turns on the pool's radix prefix cache: whole
//! prompt blocks are published to a trie keyed on token runs, later
//! prompts attach the longest cached chain by reference (copy-on-write,
//! per-block refcounts) and prefill computes only the unmatched suffix
//! — bit-identical to a cache-less prefill, with LRU eviction of
//! unreferenced chains feeding the same backpressure path so a hot pool
//! degrades to cache-miss rather than erroring.  Requests in flight can
//! be withdrawn cooperatively: [`DecodeClient::cancel`] (engine-side
//! [`DecodeEngine::cancel`]) finishes the generation with
//! [`FinishReason::Cancelled`] at the next tick, delivering the partial
//! tokens and releasing its blocks and prefix references.
//!
//! Every model is **row/sequence-independent** (a response never depends
//! on its batch-mates), so coalescing — however producers race, however
//! sequences join and leave mid-stream — is invisible in the payloads
//! and visible only in the latency quantiles.
//!
//! Entry points: `slope serve` (`--manifest` / synthetic stack /
//! `--producers` / `--decode` / `--queue-cap`), `slope generate`
//! (KV-cached continuous-batching generation from a checkpoint),
//! `examples/inference_serve.rs`, `examples/generate.rs`, and
//! `benches/bench_serve.rs` (kernel-stack + manifest + decode series).

pub mod admission;
pub mod batcher;
pub mod engine;
pub mod model;
pub mod stats;

pub use admission::{Admission, AdmissionClient, DecodeAdmission, DecodeClient, GenReply,
                    Overload, QueuePolicy, Reply};
pub use batcher::{BatchPolicy, Batcher, Request};
pub use engine::{DecodeEngine, DecodePolicy, FinishReason, Generation, Response, ServeEngine};
pub use model::{AotModel, AotPath, DecodeModel, KernelDecodeModel, KernelStackModel,
                LoraAdapter, Sampler, SeqId, ServeLayer, ServeModel};
pub use stats::{ServeStats, StatsSummary};
