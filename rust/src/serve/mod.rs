//! `slope::serve` — the first-class serving subsystem.
//!
//! SLoPe's headline inference claim (Table 2: up to 1.54× end-to-end
//! speedup) is a *serving* claim, so deployment gets a real subsystem
//! rather than an ad-hoc loop in an example:
//!
//! * [`batcher`] — the coalescing request queue: dispatch at `max_batch`
//!   fill or when the oldest request has waited `max_wait`;
//! * [`engine`]  — [`ServeEngine`], owning warm [`crate::backend::SparseBackend`]s
//!   (+ optional fused LoRA adapters) per layer and running coalesced
//!   forwards with zero steady-state allocations;
//! * [`stats`]   — p50/p95 latency, batch fill and throughput telemetry.
//!
//! The kernel engine underneath partitions a `batch = 1` forward across
//! **output-column stripes** (see [`crate::backend::pool`]), so
//! single-request latency-critical traffic scales with worker count too —
//! the combination this subsystem exists to exercise.  Entry points:
//! the `slope serve` CLI subcommand, `examples/inference_serve.rs`, and
//! `benches/bench_serve.rs`.

pub mod batcher;
pub mod engine;
pub mod stats;

pub use batcher::{BatchPolicy, Batcher, Request};
pub use engine::{LoraAdapter, Response, ServeEngine, ServeLayer};
pub use stats::{ServeStats, StatsSummary};
