//! `slope::serve` — the first-class serving subsystem.
//!
//! SLoPe's headline inference claim (Table 2: up to 1.54× end-to-end
//! speedup) is a *serving* claim, so deployment gets a real subsystem.
//! Its spine is one trait:
//!
//! ```text
//!   producers ──mpsc──► [admission]  ──► ServeEngine<M: ServeModel> ──► M
//!                        dispatch         batcher + stats + staging      the math
//! ```
//!
//! * [`model`] — [`ServeModel`], the coalesced-batch contract
//!   (`d_in`/`d_out`/`forward_batch_into` + `max_batch`/`describe`
//!   metadata), with two production implementations:
//!   [`KernelStackModel`] (warm compressed-2:4 [`ServeLayer`]s + fused
//!   LoRA, straight on the kernel engine) and [`AotModel`] (a
//!   checkpointed transformer behind a manifest — PJRT when the
//!   executables compile, the in-process host kernel executor
//!   ([`crate::runtime::host`]) otherwise; requests are token sequences,
//!   responses next-token logits);
//! * [`engine`] — [`ServeEngine`], the externally-clocked admission core:
//!   coalesces requests under a [`BatchPolicy`], stages them
//!   allocation-free, runs the model, and records telemetry;
//! * [`batcher`] — the coalescing queue: dispatch at `max_batch` fill or
//!   when the oldest request has waited `max_wait`;
//! * [`admission`] — the async front-end: mpsc producers + a dedicated
//!   dispatch thread, so `slope serve --producers N` measures tail
//!   latency under concurrent open-loop traffic;
//! * [`stats`] — p50/p95/p99 latency, batch fill and throughput.
//!
//! Every model is **row-independent** (a response never depends on its
//! batch-mates), so coalescing — however the producers race — is
//! invisible in the payloads and visible only in the latency quantiles.
//! The kernel engine underneath additionally stripes `batch = 1` forwards
//! across **output columns** (see [`crate::backend::pool`]), so
//! single-request latency-critical traffic scales with worker count too.
//!
//! Entry points: the `slope serve` CLI subcommand (`--manifest <dir>` for
//! checkpointed transformers, the synthetic kernel stack otherwise,
//! `--producers N` for concurrent admission), `examples/inference_serve.rs`,
//! and `benches/bench_serve.rs` (both backends × batch {1, 4, 16} ×
//! threads {1, 2, 4}).

pub mod admission;
pub mod batcher;
pub mod engine;
pub mod model;
pub mod stats;

pub use admission::{Admission, AdmissionClient, Reply};
pub use batcher::{BatchPolicy, Batcher, Request};
pub use engine::{Response, ServeEngine};
pub use model::{AotModel, AotPath, KernelStackModel, LoraAdapter, ServeLayer, ServeModel};
pub use stats::{ServeStats, StatsSummary};
