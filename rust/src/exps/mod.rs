//! Experiment harness: one module per paper table/figure (DESIGN.md §5).
//! Each prints paper-style rows to stdout and (optionally) dumps JSON next
//! to the run metrics so EXPERIMENTS.md numbers are regenerable.

pub mod accuracy;
pub mod perf_tables;

use crate::Result;

/// Dispatch an experiment by its paper id (`table2`, `fig3a`, …).
pub fn run(exp: &str, args: &ExpArgs) -> Result<()> {
    match exp {
        "table2" => perf_tables::table2(),
        "table3" => perf_tables::table3(),
        "table7" => perf_tables::table7(),
        "table8" => perf_tables::table8(),
        "table10" => perf_tables::table10(),
        "table12" => perf_tables::table12(),
        "fig3a" => perf_tables::fig3a(),
        "fig5" => perf_tables::fig5(),
        "fig6" => perf_tables::fig6(),
        "fig8" => perf_tables::fig8(),
        "mem" => perf_tables::memory_closed_forms(),
        "fig2" => accuracy::fig2(args),
        "table4" => accuracy::table4(args),
        "table5" => accuracy::table5(args),
        "table6" => accuracy::table6(args),
        "table9" => accuracy::table9(args),
        "fig3b" => accuracy::fig3b(args),
        "fig4" => accuracy::fig4(args),
        "fig7" => accuracy::fig7(args),
        "fig9" => accuracy::fig9(args),
        "fig10" => accuracy::fig10(args),
        // All accuracy experiments in ONE process so compiled sessions are
        // shared across experiments touching the same artifact config.
        "all-acc" => {
            for e in ["fig2", "fig4", "fig3b", "table9", "table6", "fig9",
                      "fig10", "table4", "table5", "fig7"] {
                println!("\n================ {e} ================");
                if let Err(err) = run(e, args) {
                    eprintln!("[exp] {e} FAILED: {err:#}");
                }
            }
            Ok(())
        }
        "all-perf" => {
            for e in ["table2", "table3", "table7", "table8", "table10", "table12",
                      "fig3a", "fig5", "fig6", "fig8", "mem"] {
                println!("\n================ {e} ================");
                run(e, args)?;
            }
            Ok(())
        }
        other => Err(crate::eyre!(
            "unknown experiment {other:?}; see DESIGN.md §5 for the index"
        )),
    }
}

/// Common knobs for the accuracy experiments (CPU-budget control).
#[derive(Clone, Debug)]
pub struct ExpArgs {
    pub artifacts: std::path::PathBuf,
    pub out_dir: std::path::PathBuf,
    /// Steps per accuracy run (scaled-down default; paper-shape preserved).
    pub steps: usize,
    pub seed: u64,
}

impl Default for ExpArgs {
    fn default() -> Self {
        Self {
            artifacts: "artifacts".into(),
            out_dir: "runs".into(),
            steps: 120,
            seed: 0,
        }
    }
}
