//! Analytic experiments: speedup/memory tables and kernel-level figures
//! regenerated from the calibrated A100 simulator and the memory model.
//! Paper-vs-measured comparisons are recorded in EXPERIMENTS.md.

use crate::config::zoo::{self, ModelShape, BIMASK_MODELS, SPEEDUP_MODELS};
use crate::memmodel;
use crate::perfmodel::{
    bimask_slowdown, cusparselt, dense_gemm_time, infer_time, sparse_gemm_time,
    train_step_time, Gemm, InferOpts, Sparsity, TrainOpts, A100,
};
use crate::sparsity::{lemma, NmScheme};
use crate::Result;

const BATCH: usize = 8;
const SEQ: usize = 2048;

fn train_opts(sp: Sparsity, fa: bool) -> TrainOpts {
    TrainOpts { sparsity: sp, flash_attention: fa, batch: BATCH, seq: SEQ }
}

fn infer_opts(sp: Sparsity, rank: usize, fused: bool, fa: bool) -> InferOpts {
    InferOpts { sparsity: sp, flash_attention: fa, batch: BATCH, seq: SEQ,
                adapter_rank: rank, fused_adapters: fused }
}

const SLOPE: Sparsity = Sparsity::Slope { tiled_upsample: true };
const SLOPE_UNTILED: Sparsity = Sparsity::Slope { tiled_upsample: false };
const FST: Sparsity = Sparsity::Fst { mask_interval: 128 };

fn train_speedup(m: &ModelShape, sp: Sparsity) -> f64 {
    train_step_time(&A100, m, &train_opts(Sparsity::Dense, true))
        / train_step_time(&A100, m, &train_opts(sp, true))
}

fn infer_speedup(m: &ModelShape, sp: Sparsity, rank_ratio: f64, fused: bool) -> f64 {
    let rank = (m.d_model as f64 * rank_ratio).round() as usize;
    infer_time(&A100, m, &infer_opts(Sparsity::Dense, 0, fused, true))
        / infer_time(&A100, m, &infer_opts(sp, rank, fused, true))
}

/// Table 2: end-to-end pretraining and inference speedup, SLoPe vs FST.
pub fn table2() -> Result<()> {
    println!("Table 2 — speedup (×) vs dense   [paper: SLoPe train 1.13–1.25, infer 1.31–1.54; FST train 1.06–1.11, infer 1.00]");
    println!("{:<17} {:<7} {:>8} {:>10} {:>14} {:>14}",
             "MODEL", "METHOD", "TRAIN", "INFER r=0", "INFER 1.56%", "INFER 6.25%");
    for m in SPEEDUP_MODELS {
        println!("{:<17} {:<7} {:>8.2} {:>10.2} {:>14.2} {:>14.2}",
                 m.name, "SLoPe",
                 train_speedup(&m, SLOPE),
                 infer_speedup(&m, SLOPE, 0.0, true),
                 infer_speedup(&m, SLOPE, 0.0156, true),
                 infer_speedup(&m, SLOPE, 0.0625, true));
        println!("{:<17} {:<7} {:>8.2} {:>10.2} {:>14.2} {:>14.2}",
                 "", "FST",
                 train_speedup(&m, FST),
                 infer_speedup(&m, FST, 0.0, true),
                 infer_speedup(&m, FST, 0.0156, true),
                 infer_speedup(&m, FST, 0.0625, true));
    }
    Ok(())
}

/// Table 3: end-to-end memory ratio (× of dense), training and inference.
pub fn table3() -> Result<()> {
    let s = NmScheme::TWO_FOUR;
    println!("Table 3 — memory (× of dense)   [paper: SLoPe train 0.63–0.68, infer 0.60–0.71; FST train 1.15–1.27]");
    println!("{:<17} {:<7} {:>8} {:>10} {:>14} {:>14}",
             "MODEL", "METHOD", "TRAIN", "INFER r=0", "INFER 1.56%", "INFER 6.25%");
    for m in SPEEDUP_MODELS {
        println!("{:<17} {:<7} {:>8.2} {:>10.2} {:>14.2} {:>14.2}",
                 m.name, "SLoPe",
                 memmodel::training_memory(&m, s).ratio(),
                 memmodel::inference_memory(&m, s, 0.0).ratio(),
                 memmodel::inference_memory(&m, s, 0.0156).ratio(),
                 memmodel::inference_memory(&m, s, 0.0625).ratio());
        println!("{:<17} {:<7} {:>8.2} {:>10.2} {:>14.2} {:>14.2}",
                 "", "FST",
                 memmodel::fst_training_memory(&m, s).ratio(), 1.0, 1.0, 1.0);
    }
    Ok(())
}

/// Table 7: naive vs fused adapter implementation (Appendix D).
pub fn table7() -> Result<()> {
    println!("Table 7 — inference speedup before→after adapter fusion   [paper: up to +6%]");
    println!("{:<17} {:>20} {:>20}", "MODEL", "1.56% naive→fused", "6.25% naive→fused");
    for m in [zoo::OPT_66B, zoo::OPT_30B, zoo::OPT_13B, zoo::OPT_6_6B, zoo::OPT_2_6B] {
        println!("{:<17} {:>9.2}→{:<9.2} {:>9.2}→{:<9.2}",
                 m.name,
                 infer_speedup(&m, SLOPE, 0.0156, false),
                 infer_speedup(&m, SLOPE, 0.0156, true),
                 infer_speedup(&m, SLOPE, 0.0625, false),
                 infer_speedup(&m, SLOPE, 0.0625, true));
    }
    Ok(())
}

/// Table 8: upsample square tiling before→after (Appendix E).
pub fn table8() -> Result<()> {
    println!("Table 8 — speedup before→after upsample tiling   [paper: train +4%, infer +12%]");
    println!("{:<17} {:>22} {:>22}", "MODEL", "TRAIN untiled→tiled", "INFER untiled→tiled");
    for m in [zoo::OPT_66B, zoo::OPT_30B, zoo::OPT_13B, zoo::OPT_6_6B, zoo::OPT_2_6B] {
        println!("{:<17} {:>10.2}→{:<10.2} {:>10.2}→{:<10.2}",
                 m.name,
                 train_speedup(&m, SLOPE_UNTILED), train_speedup(&m, SLOPE),
                 infer_speedup(&m, SLOPE_UNTILED, 0.0, true),
                 infer_speedup(&m, SLOPE, 0.0, true));
    }
    Ok(())
}

/// Table 10: Bi-Mask end-to-end slowdown vs dense (Appendix H).
pub fn table10() -> Result<()> {
    println!("Table 10 — Bi-Mask slowdown (×) vs dense   [paper: 3.01–8.41]");
    println!("{:<15} {:<10} {:>10}", "MODEL", "DATASET", "SLOWDOWN");
    for cnn in BIMASK_MODELS {
        println!("{:<15} {:<10} {:>10.2}", cnn.name, cnn.dataset, bimask_slowdown(&A100, cnn));
    }
    Ok(())
}

/// Table 12: SLoPe × FlashAttention-2 composition (Appendix M).
pub fn table12() -> Result<()> {
    println!("Table 12 — FA2/SLoPe composition, speedup vs dense-noFA   [paper: FA2 1.28–2.26 train; SLoPe+FA2 1.53–2.56]");
    println!("{:<12} {:>8} {:>8} {:>12} | {:>8} {:>10} {:>12}",
             "MODEL", "FA2", "SLoPe", "SLoPe+FA2", "inf FA2", "inf SLoPe", "inf S+FA2");
    for m in [zoo::OPT_66B, zoo::OPT_30B, zoo::OPT_13B, zoo::OPT_6_6B, zoo::OPT_2_6B] {
        let base_t = train_step_time(&A100, &m, &train_opts(Sparsity::Dense, false));
        let t = |sp, fa| base_t / train_step_time(&A100, &m, &train_opts(sp, fa));
        let base_i = infer_time(&A100, &m, &infer_opts(Sparsity::Dense, 0, true, false));
        let i = |sp, fa| base_i / infer_time(&A100, &m, &infer_opts(sp, 0, true, fa));
        println!("{:<12} {:>8.2} {:>8.2} {:>12.2} | {:>8.2} {:>10.2} {:>12.2}",
                 m.name,
                 t(Sparsity::Dense, true), t(SLOPE, false), t(SLOPE, true),
                 i(Sparsity::Dense, true), i(SLOPE, false), i(SLOPE, true));
    }
    Ok(())
}

/// Figure 3a: cuSPARSELt SpMM speedup vs hidden dim per tensor role.
pub fn fig3a() -> Result<()> {
    println!("Figure 3a — SpMM speedup vs dense (batch 2048)   [paper: rises toward 2×; upsample cliff ≈4000]");
    println!("{:>8} {:>12} {:>12} {:>12}", "hidden", "attention", "upsample", "downsample");
    for h in [512usize, 1024, 2048, 3072, 4096, 6144, 8192, 12288] {
        let sp = |g: Gemm| dense_gemm_time(&A100, &g) / sparse_gemm_time(&A100, &g, false);
        let att = sp(Gemm::new(2048, h, h));
        let up = sp(Gemm::new(2048, 4 * h, h));
        let down = sp(Gemm::new(2048, h / 4, h));
        println!("{:>8} {:>12.2} {:>12.2} {:>12.2}", h, att, up, down);
    }
    Ok(())
}

/// Figure 5: cuSPARSELt setup vs multiply time for square matrices.
pub fn fig5() -> Result<()> {
    println!("Figure 5 — setup vs multiply (ms), square matrices   [paper: setup ≫ multiply]");
    println!("{:>8} {:>12} {:>12} {:>8}", "dim", "setup", "multiply", "ratio");
    for d in [1024usize, 2048, 4096, 8192, 12288] {
        let setup = cusparselt::setup_time_s(d, d) * 1e3;
        let mult = sparse_gemm_time(&A100, &Gemm::new(d, d, d), false) * 1e3;
        println!("{:>8} {:>12.3} {:>12.3} {:>8.1}", d, setup, mult, setup / mult);
    }
    Ok(())
}

/// Figure 6: low-rank adapter speedup vs the ideal d/r scaling.
pub fn fig6() -> Result<()> {
    println!("Figure 6 — low-rank speedup vs ideal (batch 2048)   [paper: far below ideal at small r]");
    println!("{:>8} {:>6} {:>12} {:>10} {:>10}", "dim", "rank", "observed", "ideal", "frac");
    for d in [2048usize, 4096, 8192] {
        for r in [16usize, 64, 256, 1024] {
            let dense = dense_gemm_time(&A100, &Gemm::new(2048, d, d));
            let lora = dense_gemm_time(&A100, &Gemm::new(2048, r, d))
                + dense_gemm_time(&A100, &Gemm::new(2048, d, r));
            let obs = dense / lora;
            let ideal = d as f64 / (2.0 * r as f64);
            println!("{:>8} {:>6} {:>12.2} {:>10.1} {:>10.2}", d, r, obs, ideal, obs / ideal);
        }
    }
    Ok(())
}

/// Figure 8: imposed sparsity of the double-pruned backward pass.
pub fn fig8() -> Result<()> {
    let mut rng = crate::util::Rng::seed_from_u64(8);
    println!("Figure 8 — extra zeros imposed by double pruning   [Lemma 2.1 closed form + Monte Carlo]");
    println!("{:>6} {:>14} {:>14}", "N:M", "closed form", "monte carlo");
    for (n, m) in [(1usize, 2usize), (1, 4), (2, 4), (2, 8), (4, 8), (4, 16)] {
        let s = NmScheme::new(n, m);
        let cf = lemma::imposed_sparsity(s);
        let mc = lemma::monte_carlo_imposed_sparsity(s, 8 * m, 3, &mut rng);
        println!("{:>6} {:>13.2}% {:>13.2}%", format!("{n}:{m}"), cf * 100.0, mc * 100.0);
    }
    println!("note: paper prose quotes 3.39% for 2:8; its own Eq. 8 gives 5.84% (see EXPERIMENTS.md)");
    Ok(())
}

/// §3.1 closed-form memory ratios.
pub fn memory_closed_forms() -> Result<()> {
    let s = NmScheme::TWO_FOUR;
    println!("§3.1 closed forms (pure 2:4 linear):");
    println!("  training : {:.1}% of dense  (paper: ≈63–68%)",
             memmodel::theoretical_train_ratio(s) * 100.0);
    println!("  inference: {:.1}% of dense  (paper: ≈54–59%)",
             memmodel::theoretical_infer_ratio(s) * 100.0);
    Ok(())
}
