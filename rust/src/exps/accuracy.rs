//! Accuracy experiments: tiny-scale pretraining runs through the FULL
//! three-layer stack (Pallas kernels → AOT HLO → rust coordinator),
//! reproducing the paper's comparison *structure* (method sets, sweep
//! axes, metrics) at CPU-budget scale (DESIGN.md §6).
//!
//! Every function prints a paper-style table and writes the underlying run
//! metrics (loss curves etc.) as JSON under `--out-dir`.

use super::ExpArgs;
use crate::config::{Fig9Variant, Method, RunConfig};
use crate::coordinator::{checkpoint, Trainer};
use crate::Result;

fn run_cfg(args: &ExpArgs, model: &str, method: Method, lazy: f64) -> RunConfig {
    RunConfig {
        model: model.into(),
        method,
        steps: args.steps,
        lazy_fraction: lazy,
        eval_every: (args.steps / 6).max(1),
        eval_batches: 4,
        seed: args.seed,
        artifacts: args.artifacts.clone(),
        out_dir: args.out_dir.clone(),
        checkpoint_dir: None,
        resume: None,
        keep_checkpoints: 3,
        parallel: crate::backend::ParallelPolicy::auto(),
    }
}

struct RunResult {
    label: String,
    ppl_curve: Vec<(usize, f64)>,
    final_ppl: f64,
    cloze_acc: f64,
    trainer: Trainer,
}

fn run_one(args: &ExpArgs, model: &str, method: Method, lazy: f64, label: &str) -> Result<RunResult> {
    eprintln!("\n[exp] == run {label}: {model} {method:?} steps={} lazy={lazy} ==", args.steps);
    let mut t = Trainer::new(run_cfg(args, model, method, lazy))?;
    t.init()?;
    let outcome = t.train()?;
    let path = t.metrics.save(&args.out_dir)?;
    eprintln!("[exp] metrics → {}", path.display());
    Ok(RunResult {
        label: label.to_string(),
        ppl_curve: t.metrics.evals.iter().map(|e| (e.step, e.perplexity)).collect(),
        final_ppl: outcome.final_perplexity,
        cloze_acc: outcome.cloze_accuracy,
        trainer: t,
    })
}

fn print_ppl_table(title: &str, runs: &[RunResult]) {
    println!("\n{title}");
    print!("{:<26}", "METHOD");
    if let Some(r) = runs.first() {
        for (s, _) in &r.ppl_curve {
            print!(" {:>9}", format!("@{s}"));
        }
    }
    println!(" {:>9} {:>8}", "final", "cloze%");
    for r in runs {
        print!("{:<26}", r.label);
        for (_, p) in &r.ppl_curve {
            print!(" {:>9.2}", p);
        }
        println!(" {:>9.2} {:>8.1}", r.final_ppl, r.cloze_acc * 100.0);
    }
}

/// Figure 2: validation perplexity, GPT2-Small/Large stand-ins across
/// Dense / SLoPe / Extended SR-STE / Wanda.
pub fn fig2(args: &ExpArgs) -> Result<()> {
    println!("Figure 2 — validation perplexity (gpt-nano = GPT2-Small stand-in)");
    println!("[paper shape: dense < SLoPe < E-SR-STE ≈ Wanda; adapters narrow the gap]");
    let runs = vec![
        run_one(args, "gpt-nano", Method::Dense, 0.0, "Dense")?,
        run_one(args, "gpt-nano", Method::Slope, 0.05, "SLoPe (2:4, lazy r)")?,
        run_one(args, "gpt-nano", Method::Slope, 0.0, "SLoPe (2:4, r=0)")?,
        run_one(args, "gpt-nano", Method::Srste, 0.0, "Extended SR-STE")?,
        run_one(args, "gpt-nano", Method::Wanda, 0.0, "Wanda (one-shot)")?,
    ];
    print_ppl_table("gpt-nano (GPT2-Small stand-in):", &runs);
    Ok(())
}

/// Table 4: zero-shot (cloze) accuracy across methods × adapter ranks.
pub fn table4(args: &ExpArgs) -> Result<()> {
    println!("Table 4 — downstream probe accuracy, methods × adapter ranks");
    println!("[paper shape: SLoPe > E-SR-STE on most tasks; higher rank helps slightly]");
    let runs = vec![
        run_one(args, "gpt-nano", Method::Dense, 0.0, "Dense")?,
        run_one(args, "gpt-nano-r2", Method::Slope, 0.05, "SLoPe r=1.56%")?,
        run_one(args, "gpt-nano", Method::Slope, 0.05, "SLoPe r=6.25%")?,
        run_one(args, "gpt-nano", Method::Slope, 0.0, "SLoPe r=0")?,
        run_one(args, "gpt-nano", Method::SrsteLora, 0.05, "E-SR-STE r=6.25%")?,
        run_one(args, "gpt-nano", Method::Srste, 0.0, "E-SR-STE r=0")?,
    ];
    print_ppl_table("gpt-nano probe results:", &runs);
    Ok(())
}

/// Table 5 / rank sweep on the BERT-style two-phase stand-in.
pub fn table5(args: &ExpArgs) -> Result<()> {
    println!("Table 5 — adapter-rank sweep (bert-phase2 stand-in, d=128)");
    println!("[paper shape: accuracy improves monotonically with rank; r=0 worst]");
    let runs = vec![
        run_one(args, "bert-phase2", Method::Dense, 0.0, "Dense")?,
        run_one(args, "bert-phase2", Method::Slope, 0.0, "SLoPe r=0")?,
        run_one(args, "bert-phase2-r2", Method::Slope, 0.05, "SLoPe r=1.56%")?,
        run_one(args, "bert-phase2", Method::Slope, 0.05, "SLoPe r=6.25%")?,
        run_one(args, "bert-phase2-r32", Method::Slope, 0.05, "SLoPe r=25%")?,
    ];
    print_ppl_table("bert-phase2 rank sweep:", &runs);
    Ok(())
}

/// Table 6: mixed N:M sparsity (first-half vs second-half blocks).
pub fn table6(args: &ExpArgs) -> Result<()> {
    println!("Table 6 — mixed N:M sparsity, SLoPe vs Wanda");
    println!("[paper shape: 2:4-2:4 best; pruning FIRST blocks harder (2:8-2:4) hurts most]");
    let mut runs = vec![];
    for (model, tag) in [("gpt-nano", "2:4-2:4"), ("gpt-nano-24-28", "2:4-2:8"),
                         ("gpt-nano-28-24", "2:8-2:4")] {
        runs.push(run_one(args, model, Method::Slope, 0.05, &format!("SLoPe {tag}"))?);
        runs.push(run_one(args, model, Method::Wanda, 0.0, &format!("Wanda {tag}"))?);
    }
    print_ppl_table("mixed-sparsity results:", &runs);
    Ok(())
}

/// Table 9: module sensitivity (MLP-only vs MLP+attention pruning).
pub fn table9(args: &ExpArgs) -> Result<()> {
    println!("Table 9 — pruned-module sensitivity");
    println!("[paper shape: dense > MLP-only > MLP+attention, small gaps]");
    let runs = vec![
        run_one(args, "gpt-nano", Method::Dense, 0.0, "Dense")?,
        run_one(args, "gpt-nano-mlponly", Method::Slope, 0.05, "SLoPe MLP only")?,
        run_one(args, "gpt-nano", Method::Slope, 0.05, "SLoPe MLP+attn")?,
    ];
    print_ppl_table("module-scope results:", &runs);
    Ok(())
}

/// Figure 3b: adapter convergence (cosine similarity vs converged).
pub fn fig3b(args: &ExpArgs) -> Result<()> {
    println!("Figure 3b — lazy-adapter convergence (cosine sim to converged)");
    println!("[paper shape: downsample converges fast, upsample slower]");
    // Longer lazy tail so there is a trajectory to see.
    let r = run_one(args, "gpt-nano", Method::Slope, 0.4, "SLoPe lazy-40%")?;
    println!("{:>8} {:>12} {:>12}", "step", "cos(down)", "cos(up)");
    for a in &r.trainer.metrics.adapters {
        println!("{:>8} {:>12.4} {:>12.4}", a.step, a.cos_down, a.cos_up);
    }
    Ok(())
}

/// Figure 4: SR-STE mask churn vs the converged pattern.
pub fn fig4(args: &ExpArgs) -> Result<()> {
    println!("Figure 4 — SR-STE mask difference vs converged mask");
    println!("[paper shape: decreasing but non-zero churn → wasted updates]");
    let r = run_one(args, "gpt-nano", Method::Srste, 0.0, "E-SR-STE")?;
    println!("{:>8} {:>16} {:>16}", "step", "vs prev snap", "vs converged");
    for c in &r.trainer.metrics.churn {
        println!("{:>8} {:>15.2}% {:>15.2}%", c.step,
                 c.frac_changed_vs_prev * 100.0, c.frac_changed_vs_final * 100.0);
    }
    Ok(())
}

/// Figure 7: two-phase (BERT-style) pretraining loss, phase-1 checkpoint
/// transferred into phase-2 (longer sequences).
pub fn fig7(args: &ExpArgs) -> Result<()> {
    println!("Figure 7 — two-phase pretraining (seq 64 → 256), dense vs SLoPe");
    println!("[paper shape: sparse tracks dense with a persistent small gap in both phases]");
    for (method, label) in [(Method::Dense, "Dense"), (Method::Slope, "SLoPe 2:4")] {
        let mut p1 = Trainer::new(run_cfg(args, "bert-phase1", method, 0.0))?;
        p1.init()?;
        let o1 = p1.train()?;
        p1.metrics.save(&args.out_dir)?;
        // Transfer phase-1 params (+masks for SLoPe) into phase-2.
        let ckpt = args.out_dir.join(format!("fig7-{label}.slopeckpt"));
        std::fs::create_dir_all(&args.out_dir)?;
        checkpoint::save(&p1.store, &["params.", "masks."], &ckpt)?;
        let mut p2 = Trainer::new(run_cfg(args, "bert-phase2", method, 0.05))?;
        p2.init()?;
        checkpoint::load(&mut p2.store, &ckpt)?;
        let o2 = p2.train()?;
        p2.metrics.save(&args.out_dir)?;
        println!("{label:<12} phase1 final ppl {:>8.2} | phase2 final ppl {:>8.2} (cloze {:.1}%)",
                 o1.final_perplexity, o2.final_perplexity, o2.cloze_accuracy * 100.0);
        println!("  phase1 loss: {}", curve(&p1));
        println!("  phase2 loss: {}", curve(&p2));
    }
    Ok(())
}

fn curve(t: &Trainer) -> String {
    let pts: Vec<String> = t
        .metrics
        .steps
        .iter()
        .step_by((t.metrics.steps.len() / 8).max(1))
        .map(|s| format!("{:.2}@{}", s.loss, s.step))
        .collect();
    pts.join("  ")
}

/// Figure 9: choice of pruned matrix (weights/inputs/grad-output,
/// static/dynamic).
pub fn fig9(args: &ExpArgs) -> Result<()> {
    println!("Figure 9 — validation perplexity per pruning target");
    println!("[paper shape: static < dynamic; weights < inputs; grad-output diverges]");
    let runs = vec![
        run_one(args, "gpt-nano", Method::Dense, 0.0, "dense")?,
        run_one(args, "gpt-nano", Method::Fig9(Fig9Variant::WeightStatic), 0.0, "weight static")?,
        run_one(args, "gpt-nano", Method::Fig9(Fig9Variant::WeightDynamic), 0.0, "weight dynamic")?,
        run_one(args, "gpt-nano", Method::Fig9(Fig9Variant::InputStatic), 0.0, "input static")?,
        run_one(args, "gpt-nano", Method::Fig9(Fig9Variant::InputDynamic), 0.0, "input dynamic")?,
        run_one(args, "gpt-nano", Method::Fig9(Fig9Variant::GradoutDynamic), 0.0, "gradout dynamic")?,
    ];
    print_ppl_table("pruning-target results:", &runs);
    Ok(())
}

/// Figure 10 / Appendix S: depth vs width pruning.
pub fn fig10(args: &ExpArgs) -> Result<()> {
    println!("Figure 10 — depth vs width pruning (dense training of reduced models)");
    println!("[paper shape: no significant difference; depth-pruning sometimes ahead]");
    let runs = vec![
        run_one(args, "gpt-nano", Method::Dense, 0.0, "baseline")?,
        run_one(args, "gpt-nano-half-depth", Method::Dense, 0.0, "half depth")?,
        run_one(args, "gpt-nano-half-width", Method::Dense, 0.0, "half width (MLP)")?,
    ];
    print_ppl_table("depth/width results:", &runs);
    Ok(())
}
