//! Runtime: load an artifact directory (manifest + HLO text, or manifest
//! + checkpoint only) and drive its executables from the coordinator's
//! step loop through one backend-agnostic [`Session`].
//!
//! A session wraps an [`Executor`] — the run-executable-by-manifest-name
//! contract over a name-keyed [`Store`] — with two implementations:
//!
//! * **PJRT** ([`exec::PjrtExec`]): the HLO text was lowered once by
//!   `python/compile/aot.py` (`make artifacts`); executables compile
//!   lazily on the CPU client and are cached.  State round-trips through
//!   host literals (xla-rs 0.1.6 has no buffer donation); on the CPU
//!   backend that copy is a memcpy, measured < 3% of step time.
//! * **Host kernels** ([`exec::HostExec`]): the same executable
//!   semantics — including the full **double-pruned backward pass**
//!   (Eq. 4–6) and AdamW — implemented natively on the crate's sparse
//!   kernel engine ([`host_train::HostTrainModel`]).  No python, no XLA,
//!   no artifacts.
//!
//! [`Session::open`] picks the route: if the manifest's HLO files exist
//! beside it, PJRT; otherwise (a fabricated host-train config, or a
//! serving-checkpoint directory that carries no HLO) the host executor.
//! `slope train` therefore runs end-to-end on a clean checkout, and
//! `slope serve`/`slope generate` keep using [`host::HostModel`] — the
//! inference-tuned executor with KV-cached decode — behind the same
//! manifests.

pub mod exec;
pub mod host;
pub mod host_train;
pub mod kvpool;
pub mod manifest;
pub mod store;

pub use exec::{Executor, ExecutorKind, HostExec, PjrtExec, HOST_EXES};
pub use host::{write_host_train_artifact, write_synthetic_artifact, HostModel, SynthSpec};
pub use kvpool::{is_pool_exhausted, parse_prefix_cache, KvBlockPool, KvCache, KvDtype,
                 KvPoolConfig, KvPoolStats, PrefixCacheStats, DEFAULT_KV_BLOCK_TOKENS,
                 DEFAULT_PREFIX_CACHE_BLOCKS};
pub use host_train::{HostTrainModel, TrainStateBytes};
pub use manifest::{ExeSpec, Manifest, TensorSpec, SPARSE_WEIGHTS};
pub use store::Store;

use crate::backend::ParallelPolicy;
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

/// Shared session handle: XLA compiles are expensive (20–60 s for the
/// train steps), so sessions are cached per artifact directory and shared
/// across runs within a thread (`Session::open_cached`).  PJRT handles in
/// xla-rs 0.1.6 are `!Send`, so the cache is thread-local (the host
/// executor inherits the same discipline for simplicity).
pub type SessionHandle = Rc<RefCell<Session>>;

thread_local! {
    static SESSION_CACHE: RefCell<HashMap<PathBuf, SessionHandle>> =
        RefCell::new(HashMap::new());
}

/// An executor bundle for one model config (module docs).
pub struct Session {
    pub manifest: Manifest,
    exec: Box<dyn Executor>,
    /// Mirror of the most recent `set_parallel` (for `parallel()`).
    parallel: ParallelPolicy,
}

impl Session {
    /// Load the manifest for `artifacts/<config>` and resolve the
    /// execution route: PJRT when any of the manifest's HLO files exists
    /// on disk, else the host kernel executor.
    pub fn open(artifact_dir: &Path) -> crate::Result<Self> {
        let manifest = Manifest::load(artifact_dir)?;
        let has_hlo = manifest
            .executables
            .values()
            .any(|e| artifact_dir.join(&e.file).exists());
        let exec: Box<dyn Executor> = if has_hlo {
            Box::new(PjrtExec::new(manifest.clone())?)
        } else {
            Box::new(HostExec::new(manifest.clone()))
        };
        Ok(Self { manifest, exec, parallel: ParallelPolicy::serial() })
    }

    /// Which backend this session resolved to.
    pub fn executor_kind(&self) -> ExecutorKind {
        self.exec.kind()
    }

    /// Set the execution parallelism for this session: the kernel-engine
    /// policy on the host route, the intra-op hint on PJRT.  Cached
    /// sessions keep the most recent caller's policy.
    pub fn set_parallel(&mut self, policy: ParallelPolicy) {
        self.parallel = policy;
        self.exec.set_parallel(policy);
    }

    /// The session's execution-parallelism policy.
    pub fn parallel(&self) -> ParallelPolicy {
        self.parallel
    }

    /// Process-wide cached open: reuses compiled executables (and the
    /// host executor's resident operand state) across runs on the same
    /// artifact config.
    pub fn open_cached(artifact_dir: &Path) -> crate::Result<SessionHandle> {
        SESSION_CACHE.with(|cache| {
            let mut cache = cache.borrow_mut();
            if let Some(h) = cache.get(artifact_dir) {
                return Ok(h.clone());
            }
            let h = Rc::new(RefCell::new(Self::open(artifact_dir)?));
            cache.insert(artifact_dir.to_path_buf(), h.clone());
            Ok(h)
        })
    }

    pub fn platform(&self) -> String {
        match self.exec.kind() {
            ExecutorKind::Pjrt => "pjrt-cpu".to_string(),
            ExecutorKind::HostKernels => "host-kernels".to_string(),
        }
    }

    /// Pre-build an executable (PJRT: compile now so step wall-times
    /// measure execution; host: validate the name is implemented).
    pub fn prepare(&mut self, name: &str) -> crate::Result<()> {
        self.exec.prepare(name)
    }

    /// Run an executable by manifest name over the store (inputs read by
    /// name, outputs written back by name).
    pub fn run(&mut self, name: &str, store: &mut Store) -> crate::Result<()> {
        self.exec.run(name, store)
    }
}
