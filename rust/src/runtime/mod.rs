//! PJRT runtime: load AOT artifacts (`*.hlo.txt`), compile them once on
//! the CPU client, and drive them from the coordinator's step loop.
//!
//! Python never runs here — the HLO text was lowered once by
//! `python/compile/aot.py` (`make artifacts`); this module is the bridge
//! described in DESIGN.md §3 ("Runtime").
//!
//! Design notes:
//! * Executables are compiled lazily and cached (`Session::exe`); an
//!   accuracy experiment touching 3 of a config's 14 executables pays for 3.
//! * Step state lives in a name-keyed [`Store`] of literals.  The AOT
//!   signature convention (manifest input/output names) lets outputs feed
//!   the next step's inputs by name — `params.*`, `opt.*` round-trip,
//!   `tokens` is injected fresh each step by the data pipeline.
//! * xla-rs 0.1.6 returns tuple results as a single tuple literal (no
//!   buffer-level donation/untupling), so state round-trips through host
//!   literals; on the CPU PJRT backend device==host and the copy is a
//!   memcpy — measured < 3% of step time for every config we ship
//!   (EXPERIMENTS.md §Perf).
//! * [`host`] provides the **host kernel executor**: a checkpoint-backed
//!   implementation of the manifest's `forward`/`forward_lora` semantics
//!   running on the crate's own sparse kernel engine, used by
//!   `slope serve --manifest` wherever PJRT compile is unavailable (the
//!   offline stub, or a checkpoint directory without HLO files).

pub mod host;
pub mod manifest;
pub mod store;

pub use host::{write_synthetic_artifact, HostModel, KvCache, SynthSpec};
pub use manifest::{ExeSpec, Manifest, TensorSpec, SPARSE_WEIGHTS};
pub use store::Store;

use crate::backend::ParallelPolicy;
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

/// Shared session handle: XLA compiles are expensive (20–60 s for the
/// train steps), so sessions are cached per artifact directory and shared
/// across runs within a thread (`Session::open_cached`).  PJRT handles in
/// xla-rs 0.1.6 are `!Send`, so the cache is thread-local.
pub type SessionHandle = Rc<RefCell<Session>>;

thread_local! {
    static SESSION_CACHE: RefCell<HashMap<PathBuf, SessionHandle>> =
        RefCell::new(HashMap::new());
}

/// A compiled artifact bundle for one model config.
pub struct Session {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
    /// Execution parallelism for work dispatched through this session
    /// (`RunConfig.parallel` threads through here).  Consumed today by the
    /// host kernel executor ([`HostModel`]) behind manifest-backed
    /// serving; on a real PJRT backend it is the intra-op thread-count
    /// hint the client should be created with (xla-rs 0.1.6 exposes no
    /// knob, so there it is advisory).
    parallel: ParallelPolicy,
}

impl Session {
    /// Load the manifest for `artifacts/<config>` and create the CPU client.
    pub fn open(artifact_dir: &Path) -> crate::Result<Self> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| crate::eyre!("PJRT cpu client: {e}"))?;
        Ok(Self { manifest, client, cache: HashMap::new(), parallel: ParallelPolicy::serial() })
    }

    /// Set the execution parallelism for this session (see the `parallel`
    /// field docs).  Cached sessions keep the most recent caller's policy.
    pub fn set_parallel(&mut self, policy: ParallelPolicy) {
        self.parallel = policy;
    }

    /// The session's execution-parallelism policy.
    pub fn parallel(&self) -> ParallelPolicy {
        self.parallel
    }

    /// Process-wide cached open: reuses compiled executables across runs on
    /// the same artifact config (the experiment sweeps hit each config with
    /// several methods).
    pub fn open_cached(artifact_dir: &Path) -> crate::Result<SessionHandle> {
        SESSION_CACHE.with(|cache| {
            let mut cache = cache.borrow_mut();
            if let Some(h) = cache.get(artifact_dir) {
                return Ok(h.clone());
            }
            let h = Rc::new(RefCell::new(Self::open(artifact_dir)?));
            cache.insert(artifact_dir.to_path_buf(), h.clone());
            Ok(h)
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch the cached) executable by manifest name.
    pub fn exe(&mut self, name: &str) -> crate::Result<&xla::PjRtLoadedExecutable> {
        if !self.cache.contains_key(name) {
            let path = self.manifest.hlo_path(name)?;
            let t0 = std::time::Instant::now();
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| crate::eyre!("parsing {}: {e}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| crate::eyre!("compiling {name}: {e}"))?;
            eprintln!(
                "[runtime] compiled {name} ({} in / {} out) in {:.1}s",
                self.manifest.exe(name)?.inputs.len(),
                self.manifest.exe(name)?.outputs.len(),
                t0.elapsed().as_secs_f32()
            );
            self.cache.insert(name.to_string(), exe);
        }
        Ok(self.cache.get(name).unwrap())
    }

    /// Run an executable: gather inputs from the store by manifest order,
    /// execute, untuple, and scatter outputs back into the store by name.
    /// Returns the output names in order (for callers that want scalars).
    pub fn run(&mut self, name: &str, store: &mut Store) -> crate::Result<()> {
        let spec = self.manifest.exe(name)?.clone();
        let args: Vec<&xla::Literal> = spec
            .inputs
            .iter()
            .map(|t| store.get(&t.name))
            .collect::<crate::Result<_>>()?;
        let exe = self.exe(name)?;
        let result = exe
            .execute::<&xla::Literal>(&args)
            .map_err(|e| crate::eyre!("executing {name}: {e}"))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| crate::eyre!("fetching {name} result: {e}"))?;
        let outs = tuple
            .to_tuple()
            .map_err(|e| crate::eyre!("untupling {name} result: {e}"))?;
        if outs.len() != spec.outputs.len() {
            return Err(crate::eyre!(
                "{name}: manifest says {} outputs, HLO returned {}",
                spec.outputs.len(),
                outs.len()
            ));
        }
        for (t, lit) in spec.outputs.iter().zip(outs) {
            store.insert(&t.name, lit);
        }
        Ok(())
    }
}
