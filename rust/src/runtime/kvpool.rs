//! Paged, quantized KV-cache block pool — the serving-memory subsystem.
//!
//! The contiguous [`KvCache`] of PR 4 preallocated a full
//! `layers × 2 × seq_len × d_model` f32 slab per sequence, so a batch of
//! full-context caches rivals the fp16 weights
//! ([`crate::memmodel::kv_cache_bytes`]) — the serving-memory ceiling for
//! thousands of concurrent sequences.  This module replaces the slab with
//! vLLM-style **block paging**:
//!
//! * [`KvBlockPool`] — a process-wide arena of fixed-size token blocks
//!   (`block_tokens` positions × all layers × K and V planes per block)
//!   behind one shared free-list.  Allocation, append, and free are O(1)
//!   amortized; freed blocks are recycled before the arena grows, and an
//!   optional `max_blocks` bound turns exhaustion into a structured
//!   error (`kv pool exhausted`, see [`is_pool_exhausted`]) instead of
//!   unbounded growth — the signal `DecodeEngine` converts into
//!   admission backpressure.
//! * [`KvCache`] — now a per-sequence **view**: a block table plus a
//!   logical fill.  `reserve` grows the table, `truncate`/`reset` return
//!   whole blocks to the pool immediately (so `bytes()` shrinks with the
//!   fill — rollback no longer strands capacity), and `Drop` frees
//!   everything.
//! * [`KvDtype`] — the storage plane: `f32` (bit-identical to the
//!   contiguous cache: values round-trip the arena verbatim and the
//!   attention loop reads direct slices), `f16` (hand-rolled IEEE
//!   binary16 with round-to-nearest-even, exact vs. the reference
//!   conversion on every bit pattern), or `int8` (symmetric per-block
//!   per-plane scale `amax/127`; the scale only grows, requantizing the
//!   block in place, so a written row's dequantized value never depends
//!   on batch composition — the determinism the decode suites pin).
//!
//! Reads happen inside the decode attention loop through
//! [`KvLayerView`]: f32 returns arena slices directly (zero copy, zero
//! rounding), f16/int8 dequantize one head-slice at a time into a
//! caller-provided scratch row.  Writes and reads take the pool mutex
//! once per (sequence, layer) — uncontended in the single-threaded
//! scheduler, and the kernel-engine threads underneath never touch it.
//!
//! **Prefix caching** (PR 9): every block carries a reference count, so
//! one physical block can back the same prompt prefix in many
//! sequences.  A radix trie keyed on `block_tokens`-aligned token runs
//! maps prompt prefixes to already-filled block chains
//! ([`KvCache::attach_prefix`] / [`KvCache::publish_prefix`]); a shared
//! block is never written in place — the writer gets a private copy
//! first (copy-on-write) and the refcount drops.  The cache holds one
//! reference per cached block; chains no live sequence shares are
//! evicted LRU leaf-first, both at the configured capacity bound and —
//! crucially — under allocation pressure, so a hot pool degrades to
//! cache-miss behavior instead of reporting exhaustion.

use std::sync::{Arc, Mutex, MutexGuard};

/// Default block size in token positions — small enough that a short
/// generation wastes at most 15 trailing rows per layer-plane, large
/// enough that the block table stays tiny at full context.
pub const DEFAULT_KV_BLOCK_TOKENS: usize = 16;

/// Default capacity of the prefix cache, in blocks, when `--prefix-cache`
/// is enabled without an explicit bound.
pub const DEFAULT_PREFIX_CACHE_BLOCKS: usize = 512;

/// The error-message marker every pool-exhaustion failure carries.
const POOL_EXHAUSTED: &str = "kv pool exhausted";

/// True when `err` is a [`KvBlockPool`] exhaustion failure — the signal
/// the decode engine treats as backpressure (retry when running
/// sequences free their blocks) rather than a fatal dispatch error.
pub fn is_pool_exhausted(err: &crate::Error) -> bool {
    err.to_string().contains(POOL_EXHAUSTED)
}

/// Whole blocks needed to hold `tokens` positions.
#[inline]
fn blocks_for(tokens: usize, block_tokens: usize) -> usize {
    tokens / block_tokens + usize::from(tokens % block_tokens != 0)
}

// ---- dtype ------------------------------------------------------------

/// Storage format of the cached K/V planes (module docs).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum KvDtype {
    /// 4 bytes/elem, bit-identical to the pre-paging contiguous cache.
    #[default]
    F32,
    /// IEEE binary16, 2 bytes/elem, round-to-nearest-even.
    F16,
    /// Symmetric int8 with one f32 scale per (block, layer, K|V plane).
    Int8,
}

impl KvDtype {
    /// Parse a `--kv-dtype` / `SLOPE_KV_DTYPE` value.
    pub fn parse(s: &str) -> crate::Result<Self> {
        match s.trim().to_ascii_lowercase().as_str() {
            "f32" | "fp32" | "float32" => Ok(Self::F32),
            "f16" | "fp16" | "float16" | "half" => Ok(Self::F16),
            "int8" | "i8" => Ok(Self::Int8),
            other => Err(crate::eyre!(
                "unknown kv dtype {other:?} (expected f32 | f16 | int8)"
            )),
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            Self::F32 => "f32",
            Self::F16 => "f16",
            Self::Int8 => "int8",
        }
    }

    /// Bytes per stored element (int8 scales are charged separately).
    pub fn elem_bytes(self) -> usize {
        match self {
            Self::F32 => 4,
            Self::F16 => 2,
            Self::Int8 => 1,
        }
    }
}

// ---- configuration ----------------------------------------------------

/// Pool shape knobs, threaded from the CLI (`--kv-block`, `--kv-dtype`,
/// `--kv-pool-blocks`) or the environment into [`KvBlockPool::new`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KvPoolConfig {
    /// Token positions per block (every layer's K and V rows for those
    /// positions live in the same block).
    pub block_tokens: usize,
    /// Storage plane format.
    pub dtype: KvDtype,
    /// Hard bound on live blocks (`None` = grow on demand).  When the
    /// bound is hit, `reserve` fails with the structured exhaustion
    /// error instead of allocating.
    pub max_blocks: Option<usize>,
    /// Prefix cache: `Some(cap)` enables the radix index with at most
    /// `cap` cached blocks (LRU-evicted past that); `None` disables it.
    pub prefix_cache: Option<usize>,
}

impl Default for KvPoolConfig {
    fn default() -> Self {
        Self {
            block_tokens: DEFAULT_KV_BLOCK_TOKENS,
            dtype: KvDtype::F32,
            max_blocks: None,
            prefix_cache: None,
        }
    }
}

impl KvPoolConfig {
    /// Defaults overridden by `SLOPE_KV_DTYPE` / `SLOPE_KV_BLOCK` /
    /// `SLOPE_PREFIX_CACHE` — the env seam the CI int8 and prefix-cache
    /// decode legs use.  Unparsable values warn and keep the default
    /// (never a panic at model-open time).
    pub fn from_env() -> Self {
        let mut cfg = Self::default();
        if let Ok(v) = std::env::var("SLOPE_KV_DTYPE") {
            match KvDtype::parse(&v) {
                Ok(d) => cfg.dtype = d,
                Err(e) => eprintln!("[kvpool] ignoring SLOPE_KV_DTYPE: {e}"),
            }
        }
        if let Ok(v) = std::env::var("SLOPE_KV_BLOCK") {
            match v.trim().parse::<usize>() {
                Ok(n) if n > 0 => cfg.block_tokens = n,
                _ => eprintln!(
                    "[kvpool] ignoring SLOPE_KV_BLOCK={v:?} (want a positive integer)"
                ),
            }
        }
        if let Ok(v) = std::env::var("SLOPE_PREFIX_CACHE") {
            cfg.prefix_cache = match parse_prefix_cache(&v) {
                Ok(pc) => pc,
                Err(e) => {
                    eprintln!("[kvpool] ignoring SLOPE_PREFIX_CACHE: {e}");
                    cfg.prefix_cache
                }
            };
        }
        cfg
    }
}

/// Parse a `--prefix-cache` / `SLOPE_PREFIX_CACHE` value: `off`/`0`
/// disables, `on`/`1` enables at [`DEFAULT_PREFIX_CACHE_BLOCKS`], and a
/// block count enables with that capacity.
pub fn parse_prefix_cache(s: &str) -> crate::Result<Option<usize>> {
    match s.trim().to_ascii_lowercase().as_str() {
        "off" | "false" | "0" | "" => Ok(None),
        "on" | "true" => Ok(Some(DEFAULT_PREFIX_CACHE_BLOCKS)),
        other => match other.parse::<usize>() {
            Ok(n) => Ok(Some(n)),
            Err(_) => Err(crate::eyre!(
                "unknown prefix-cache value {s:?} (expected on | off | <blocks>)"
            )),
        },
    }
}

// ---- pool -------------------------------------------------------------

/// Occupancy snapshot of a [`KvBlockPool`] — the gauges `ServeStats`
/// records per decode step and `bench_serve` prints per kv series.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct KvPoolStats {
    /// Blocks currently held by live caches.
    pub blocks_in_use: usize,
    /// Blocks ever materialized in the arena (in use + free-listed).
    pub blocks_allocated: usize,
    /// High-water mark of `blocks_in_use`.
    pub peak_blocks: usize,
    /// Configured bound (`None` = unbounded).
    pub max_blocks: Option<usize>,
    /// Resident bytes per block (K+V planes for all layers, plus int8
    /// scales when quantized).
    pub block_bytes: usize,
    /// `blocks_in_use × block_bytes`.
    pub bytes_in_use: usize,
    /// `reserve` calls refused because the bound was hit.
    pub alloc_failures: u64,
    /// Allocations served by recycling a freed block (vs. arena growth).
    pub blocks_recycled: u64,
}

/// Prefix-cache counters — `Some` only when the pool was built with
/// `prefix_cache` configured; `ServeStats` gates its report line on it.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PrefixCacheStats {
    /// `attach_prefix` calls (one per cache-enabled prefill).
    pub lookups: u64,
    /// Lookups that matched at least one block.
    pub hits: u64,
    /// Prompt positions served from cached blocks instead of recompute.
    pub tokens_saved: u64,
    /// Cached chains dropped (capacity bound or allocation pressure).
    pub evictions: u64,
    /// Blocks currently pinned by the trie.
    pub cached_blocks: usize,
    /// Configured capacity bound on `cached_blocks`.
    pub max_cached_blocks: usize,
    /// Pool blocks with refcount > 1 (physically shared right now).
    pub shared_blocks: usize,
}

impl PrefixCacheStats {
    /// Fraction of lookups that hit (0 when no lookups yet).
    pub fn hit_rate(&self) -> f64 {
        if self.lookups == 0 { 0.0 } else { self.hits as f64 / self.lookups as f64 }
    }
}

/// One radix-trie edge: `block_tokens` token ids and the pool block
/// holding their K/V rows, plus the LRU stamp and deeper runs.
struct PrefixNode {
    key: Box<[i32]>,
    block: u32,
    last_used: u64,
    children: Vec<PrefixNode>,
}

/// Trie + counters, living inside the pool mutex so sharing, eviction,
/// and allocation see one consistent refcount state.
struct PrefixState {
    max_blocks: usize,
    roots: Vec<PrefixNode>,
    cached_blocks: usize,
    /// Logical LRU clock, bumped per lookup/insert.
    clock: u64,
    lookups: u64,
    hits: u64,
    tokens_saved: u64,
    evictions: u64,
}

/// Walk `chunks` down the trie, stamping and collecting the blocks of
/// every matched edge.
fn trie_lookup(nodes: &mut [PrefixNode], chunks: &[&[i32]], clock: u64, chain: &mut Vec<u32>) {
    let Some((first, rest)) = chunks.split_first() else { return };
    if let Some(n) = nodes.iter_mut().find(|n| n.key.as_ref() == *first) {
        n.last_used = clock;
        chain.push(n.block);
        trie_lookup(&mut n.children, rest, clock, chain);
    }
}

/// Insert `chunks` under `nodes`, reusing existing edges (an edge that
/// already maps this run keeps its block) and recording the blocks of
/// newly created edges in `added`.
fn trie_insert(nodes: &mut Vec<PrefixNode>, chunks: &[(&[i32], u32)], clock: u64,
               added: &mut Vec<u32>) {
    let Some(((key, block), rest)) = chunks.split_first() else { return };
    let i = match nodes.iter().position(|n| n.key.as_ref() == *key) {
        Some(i) => i,
        None => {
            nodes.push(PrefixNode {
                key: (*key).into(),
                block: *block,
                last_used: clock,
                children: Vec::new(),
            });
            added.push(*block);
            nodes.len() - 1
        }
    };
    nodes[i].last_used = clock;
    trie_insert(&mut nodes[i].children, rest, clock, added);
}

/// LRU stamp of the coldest evictable leaf: no children (chains evict
/// leaf-first) and a block only the cache holds (refcount 1).
fn trie_coldest_leaf(nodes: &[PrefixNode], refs: &[u32]) -> Option<u64> {
    let mut best: Option<u64> = None;
    for n in nodes {
        let cand = if n.children.is_empty() {
            (refs[n.block as usize] == 1).then_some(n.last_used)
        } else {
            trie_coldest_leaf(&n.children, refs)
        };
        if let Some(c) = cand {
            best = Some(best.map_or(c, |b| b.min(c)));
        }
    }
    best
}

/// Remove one evictable leaf carrying `stamp` and return its block.
fn trie_remove_leaf(nodes: &mut Vec<PrefixNode>, refs: &[u32], stamp: u64) -> Option<u32> {
    for i in 0..nodes.len() {
        if nodes[i].children.is_empty() {
            if refs[nodes[i].block as usize] == 1 && nodes[i].last_used == stamp {
                return Some(nodes.swap_remove(i).block);
            }
        } else if let Some(b) = trie_remove_leaf(&mut nodes[i].children, refs, stamp) {
            return Some(b);
        }
    }
    None
}

/// Collect every block in the trie (for `clear_prefix_cache`).
fn trie_drain(nodes: &mut Vec<PrefixNode>, out: &mut Vec<u32>) {
    for mut n in nodes.drain(..) {
        out.push(n.block);
        trie_drain(&mut n.children, out);
    }
}

/// Immutable pool shape, cached outside the mutex so accessors and
/// `bytes()` never lock.
#[derive(Clone, Copy)]
struct PoolShape {
    n_layer: usize,
    d_model: usize,
    block_tokens: usize,
    dtype: KvDtype,
    block_bytes: usize,
    max_blocks: Option<usize>,
    prefix_cache: Option<usize>,
}

impl PoolShape {
    /// Arena elements per block: all layers × {K, V} × block rows.
    fn group_elems(&self) -> usize {
        self.n_layer * 2 * self.block_tokens * self.d_model
    }
}

/// The storage arena, one flat vector per dtype; block `b` owns elements
/// `b·group_elems .. (b+1)·group_elems`.
enum KvStore {
    F32(Vec<f32>),
    F16(Vec<u16>),
    Int8 {
        q: Vec<i8>,
        /// One scale per (block, layer, plane): index
        /// `b·(n_layer·2) + layer·2 + plane`.  Zero = nothing written.
        scales: Vec<f32>,
    },
}

struct PoolInner {
    shape: PoolShape,
    store: KvStore,
    /// Recycled block ids, LIFO.
    free: Vec<u32>,
    /// Total blocks materialized in the arena.
    total: usize,
    /// Per-block reference count (sequences + one for the prefix
    /// cache).  0 for free-listed blocks.
    refs: Vec<u32>,
    /// The prefix-cache trie, when configured.
    prefix: Option<PrefixState>,
    peak_in_use: usize,
    alloc_failures: u64,
    blocks_recycled: u64,
}

impl PoolInner {
    fn in_use(&self) -> usize {
        self.total - self.free.len()
    }

    /// Offset of (layer, plane) inside a block group.
    fn plane_off(&self, layer: usize, plane: usize) -> usize {
        (layer * 2 + plane) * self.shape.block_tokens * self.shape.d_model
    }

    /// All-or-nothing: append `want` block ids to `table`, or fail
    /// without allocating anything.  Each handed-out block starts at
    /// refcount 1.  Under pressure, cold prefix-cache chains are
    /// evicted first, so a cacheful pool degrades to cache-miss
    /// behavior before it reports exhaustion.
    fn alloc_into(&mut self, want: usize, table: &mut Vec<u32>) -> crate::Result<()> {
        let headroom = match self.shape.max_blocks {
            Some(cap) => cap.saturating_sub(self.total),
            None => usize::MAX,
        };
        if want > self.free.len().saturating_add(headroom) && self.prefix.is_some() {
            self.prefix_evict_until_free(want.saturating_sub(headroom));
        }
        if want > self.free.len().saturating_add(headroom) {
            self.alloc_failures += 1;
            return Err(crate::eyre!(
                "{POOL_EXHAUSTED}: need {want} block(s), {} free of {} \
                 ({} in use; block {} tokens, {})",
                self.free.len(),
                self.shape.max_blocks.map_or_else(|| "unbounded".into(), |c| c.to_string()),
                self.in_use(),
                self.shape.block_tokens,
                self.shape.dtype.label(),
            ));
        }
        for _ in 0..want {
            let id = if let Some(id) = self.free.pop() {
                self.blocks_recycled += 1;
                id
            } else {
                let id = self.total as u32;
                self.total += 1;
                self.refs.push(0);
                let g = self.shape.group_elems();
                match &mut self.store {
                    KvStore::F32(a) => a.resize(a.len() + g, 0.0),
                    KvStore::F16(a) => a.resize(a.len() + g, 0),
                    KvStore::Int8 { q, scales } => {
                        q.resize(q.len() + g, 0);
                        scales.resize(scales.len() + self.shape.n_layer * 2, 0.0);
                    }
                }
                id
            };
            debug_assert_eq!(self.refs[id as usize], 0, "handed-out block must be unreferenced");
            self.refs[id as usize] = 1;
            table.push(id);
        }
        self.peak_in_use = self.peak_in_use.max(self.in_use());
        Ok(())
    }

    /// Drop one reference to a block; the last holder returns it to the
    /// free-list.  Int8 scales reset only then, so a recycled block
    /// quantizes exactly like a fresh one while sharers keep reading
    /// the live scales.
    fn free_block(&mut self, b: u32) {
        let r = &mut self.refs[b as usize];
        debug_assert!(*r > 0, "double free of block {b}");
        *r -= 1;
        if *r > 0 {
            return;
        }
        if let KvStore::Int8 { scales, .. } = &mut self.store {
            let stride = self.shape.n_layer * 2;
            let base = b as usize * stride;
            scales[base..base + stride].fill(0.0);
        }
        self.free.push(b);
    }

    /// Copy-on-write: materialize a private copy of block `b` (all
    /// layers, both planes, int8 scales), drop the writer's share of
    /// `b`, and return the fresh block.  Siblings keep reading `b`
    /// untouched.
    fn cow_block(&mut self, b: u32) -> crate::Result<u32> {
        let mut tbl = Vec::with_capacity(1);
        self.alloc_into(1, &mut tbl)?;
        let nb = tbl[0];
        let g = self.shape.group_elems();
        let (src, dst) = (b as usize * g, nb as usize * g);
        match &mut self.store {
            KvStore::F32(a) => a.copy_within(src..src + g, dst),
            KvStore::F16(a) => a.copy_within(src..src + g, dst),
            KvStore::Int8 { q, scales } => {
                q.copy_within(src..src + g, dst);
                let stride = self.shape.n_layer * 2;
                scales.copy_within(b as usize * stride..(b as usize + 1) * stride,
                                   nb as usize * stride);
            }
        }
        self.free_block(b);
        Ok(nb)
    }

    // ---- prefix cache (all under the one pool mutex) ------------------

    /// Share the longest cached whole-block chain matching `tokens`
    /// into `table` (refcount bumped per block); returns positions
    /// matched.
    fn prefix_lookup(&mut self, tokens: &[i32], table: &mut Vec<u32>) -> usize {
        let bt = self.shape.block_tokens;
        let Some(st) = self.prefix.as_mut() else { return 0 };
        st.lookups += 1;
        st.clock += 1;
        let chunks: Vec<&[i32]> = tokens.chunks_exact(bt).collect();
        let mut chain = Vec::new();
        trie_lookup(&mut st.roots, &chunks, st.clock, &mut chain);
        if chain.is_empty() {
            return 0;
        }
        st.hits += 1;
        st.tokens_saved += (chain.len() * bt) as u64;
        for &b in &chain {
            self.refs[b as usize] += 1;
        }
        let matched = chain.len() * bt;
        table.extend_from_slice(&chain);
        matched
    }

    /// Publish a sequence's whole-block prefix (`tokens` trimmed to
    /// full blocks, backed by `blocks`) into the trie.  Existing edges
    /// keep their blocks; new edges pin this sequence's blocks with one
    /// cache reference each.  Over-capacity chains evict immediately.
    fn prefix_insert(&mut self, tokens: &[i32], blocks: &[u32]) {
        let bt = self.shape.block_tokens;
        let added = {
            let Some(st) = self.prefix.as_mut() else { return };
            if st.max_blocks == 0 {
                return;
            }
            st.clock += 1;
            let chunks: Vec<(&[i32], u32)> =
                tokens.chunks_exact(bt).zip(blocks.iter().copied()).collect();
            let mut added = Vec::new();
            trie_insert(&mut st.roots, &chunks, st.clock, &mut added);
            st.cached_blocks += added.len();
            added
        };
        for b in added {
            self.refs[b as usize] += 1;
        }
        while self
            .prefix
            .as_ref()
            .is_some_and(|st| st.cached_blocks > st.max_blocks)
        {
            if !self.prefix_evict_one() {
                break;
            }
        }
    }

    /// Evict the coldest unshared leaf chain edge; false when nothing
    /// is evictable (every cached block is still shared by a live
    /// sequence, or the cache is empty).
    fn prefix_evict_one(&mut self) -> bool {
        let freed = {
            let Some(st) = self.prefix.as_mut() else { return false };
            let Some(stamp) = trie_coldest_leaf(&st.roots, &self.refs) else {
                return false;
            };
            let Some(b) = trie_remove_leaf(&mut st.roots, &self.refs, stamp) else {
                return false;
            };
            st.cached_blocks -= 1;
            st.evictions += 1;
            b
        };
        self.free_block(freed);
        true
    }

    /// Evict until at least `target` blocks sit on the free-list (or
    /// the cache runs dry) — the allocation-pressure release valve.
    fn prefix_evict_until_free(&mut self, target: usize) {
        while self.free.len() < target {
            if !self.prefix_evict_one() {
                return;
            }
        }
    }

    /// Drop every cached chain (test/teardown hook).
    fn prefix_clear(&mut self) {
        let drained = {
            let Some(st) = self.prefix.as_mut() else { return };
            let mut out = Vec::new();
            trie_drain(&mut st.roots, &mut out);
            st.evictions += out.len() as u64;
            st.cached_blocks = 0;
            out
        };
        for b in drained {
            self.free_block(b);
        }
    }

    fn prefix_stats(&self) -> Option<PrefixCacheStats> {
        let st = self.prefix.as_ref()?;
        Some(PrefixCacheStats {
            lookups: st.lookups,
            hits: st.hits,
            tokens_saved: st.tokens_saved,
            evictions: st.evictions,
            cached_blocks: st.cached_blocks,
            max_cached_blocks: st.max_blocks,
            shared_blocks: self.refs.iter().filter(|&&r| r > 1).count(),
        })
    }

    /// Store row `r` of block `b` for `layer`: K then V plane.
    fn write_row(&mut self, b: u32, layer: usize, r: usize, krow: &[f32], vrow: &[f32]) {
        let d = self.shape.d_model;
        let bt = self.shape.block_tokens;
        let base_b = b as usize * self.shape.group_elems();
        for (plane, row) in [(0usize, krow), (1, vrow)] {
            let plane_base = base_b + self.plane_off(layer, plane);
            let dst = plane_base + r * d;
            match &mut self.store {
                KvStore::F32(a) => a[dst..dst + d].copy_from_slice(row),
                KvStore::F16(a) => {
                    for (h, v) in a[dst..dst + d].iter_mut().zip(row) {
                        *h = f32_to_f16_bits(*v);
                    }
                }
                KvStore::Int8 { q, scales } => {
                    let si = b as usize * self.shape.n_layer * 2 + layer * 2 + plane;
                    let amax = row.iter().fold(0.0f32, |m, v| m.max(v.abs()));
                    let mut sc = scales[si];
                    if amax > sc * 127.0 {
                        // The scale only grows; requantize the block's
                        // already-written rows to the new grid so every
                        // stored row stays consistent with one scale.
                        let new_sc = amax / 127.0;
                        if sc > 0.0 {
                            let ratio = sc / new_sc;
                            for v in &mut q[plane_base..plane_base + bt * d] {
                                *v = ((*v as f32) * ratio).round().clamp(-127.0, 127.0)
                                    as i8;
                            }
                        }
                        sc = new_sc;
                        scales[si] = sc;
                    }
                    let out = &mut q[dst..dst + d];
                    if sc > 0.0 {
                        let inv = 1.0 / sc;
                        for (dq, v) in out.iter_mut().zip(row) {
                            *dq = (*v * inv).round().clamp(-127.0, 127.0) as i8;
                        }
                    } else {
                        out.fill(0);
                    }
                }
            }
        }
    }

    /// Read-side view of one layer's K and V planes over a block table.
    fn layer_view<'a>(&'a self, blocks: &'a [u32], layer: usize) -> KvLayerView<'a> {
        KvLayerView {
            store: &self.store,
            blocks,
            scale_base: layer * 2,
            scale_stride: self.shape.n_layer * 2,
            k_off: self.plane_off(layer, 0),
            v_off: self.plane_off(layer, 1),
            block_tokens: self.shape.block_tokens,
            d: self.shape.d_model,
            group_elems: self.shape.group_elems(),
        }
    }

    fn stats(&self, shape: &PoolShape) -> KvPoolStats {
        KvPoolStats {
            blocks_in_use: self.in_use(),
            blocks_allocated: self.total,
            peak_blocks: self.peak_in_use,
            max_blocks: shape.max_blocks,
            block_bytes: shape.block_bytes,
            bytes_in_use: self.in_use() * shape.block_bytes,
            alloc_failures: self.alloc_failures,
            blocks_recycled: self.blocks_recycled,
        }
    }
}

/// Process-wide paged KV arena (module docs).  Cloning the handle shares
/// the pool; every [`KvCache`] holds one.
#[derive(Clone)]
pub struct KvBlockPool {
    shape: PoolShape,
    inner: Arc<Mutex<PoolInner>>,
}

impl KvBlockPool {
    pub fn new(n_layer: usize, d_model: usize, cfg: KvPoolConfig) -> Self {
        assert!(
            n_layer > 0 && d_model > 0 && cfg.block_tokens > 0,
            "degenerate KvBlockPool shape"
        );
        let elem = cfg.dtype.elem_bytes();
        let group = n_layer * 2 * cfg.block_tokens * d_model;
        let scale_bytes = match cfg.dtype {
            KvDtype::Int8 => n_layer * 2 * 4,
            _ => 0,
        };
        let shape = PoolShape {
            n_layer,
            d_model,
            block_tokens: cfg.block_tokens,
            dtype: cfg.dtype,
            block_bytes: group * elem + scale_bytes,
            max_blocks: cfg.max_blocks,
            prefix_cache: cfg.prefix_cache,
        };
        let store = match cfg.dtype {
            KvDtype::F32 => KvStore::F32(Vec::new()),
            KvDtype::F16 => KvStore::F16(Vec::new()),
            KvDtype::Int8 => KvStore::Int8 { q: Vec::new(), scales: Vec::new() },
        };
        let prefix = cfg.prefix_cache.map(|max_blocks| PrefixState {
            max_blocks,
            roots: Vec::new(),
            cached_blocks: 0,
            clock: 0,
            lookups: 0,
            hits: 0,
            tokens_saved: 0,
            evictions: 0,
        });
        Self {
            shape,
            inner: Arc::new(Mutex::new(PoolInner {
                shape,
                store,
                free: Vec::new(),
                total: 0,
                refs: Vec::new(),
                prefix,
                peak_in_use: 0,
                alloc_failures: 0,
                blocks_recycled: 0,
            })),
        }
    }

    pub fn n_layer(&self) -> usize {
        self.shape.n_layer
    }

    pub fn d_model(&self) -> usize {
        self.shape.d_model
    }

    pub fn block_tokens(&self) -> usize {
        self.shape.block_tokens
    }

    pub fn dtype(&self) -> KvDtype {
        self.shape.dtype
    }

    /// Resident bytes per block (what `bytes()` charges per table entry).
    pub fn block_bytes(&self) -> usize {
        self.shape.block_bytes
    }

    /// An empty per-sequence cache view over this pool, bounded at
    /// `capacity` token positions (the model's `seq_len`).
    pub fn new_cache(&self, capacity: usize) -> KvCache {
        assert!(capacity > 0, "degenerate KvCache capacity");
        KvCache {
            pool: self.clone(),
            blocks: Vec::new(),
            len: 0,
            capacity,
        }
    }

    pub fn stats(&self) -> KvPoolStats {
        self.lock().stats(&self.shape)
    }

    /// Whether this pool was built with a prefix cache.
    pub fn prefix_enabled(&self) -> bool {
        self.shape.prefix_cache.is_some()
    }

    /// Prefix-cache counters (`None` when no cache is configured).
    pub fn prefix_stats(&self) -> Option<PrefixCacheStats> {
        self.lock().prefix_stats()
    }

    /// Drop every cached chain, releasing the cache's block references
    /// (teardown / test hook; live sequences keep their shares).
    pub fn clear_prefix_cache(&self) {
        self.lock().prefix_clear();
    }

    fn lock(&self) -> MutexGuard<'_, PoolInner> {
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }
}

impl std::fmt::Debug for KvBlockPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = self.stats();
        f.debug_struct("KvBlockPool")
            .field("n_layer", &self.shape.n_layer)
            .field("d_model", &self.shape.d_model)
            .field("block_tokens", &self.shape.block_tokens)
            .field("dtype", &self.shape.dtype)
            .field("blocks_in_use", &s.blocks_in_use)
            .field("blocks_allocated", &s.blocks_allocated)
            .finish()
    }
}

// ---- per-sequence cache view ------------------------------------------

/// Per-sequence decode state: a block table into a shared
/// [`KvBlockPool`] plus the logical fill.  Rows `len..` of the reserved
/// blocks are dead space a later write overwrites; `truncate`/`reset`
/// return whole blocks to the pool immediately, so `bytes()` always
/// charges exactly the blocks held.
pub struct KvCache {
    pool: KvBlockPool,
    blocks: Vec<u32>,
    len: usize,
    capacity: usize,
}

impl KvCache {
    /// Convenience constructor for standalone use (tests, memmodel):
    /// a private single-cache pool with the default f32 config.
    pub fn new(n_layer: usize, d_model: usize, capacity: usize) -> Self {
        KvBlockPool::new(n_layer, d_model, KvPoolConfig::default()).new_cache(capacity)
    }

    pub fn n_layer(&self) -> usize {
        self.pool.shape.n_layer
    }

    pub fn d_model(&self) -> usize {
        self.pool.shape.d_model
    }

    /// Maximum positions this cache may hold (the model's `seq_len`).
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Positions currently cached (prompt + decoded tokens).
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn dtype(&self) -> KvDtype {
        self.pool.shape.dtype
    }

    pub fn block_tokens(&self) -> usize {
        self.pool.shape.block_tokens
    }

    /// The pool this cache draws from.
    pub fn pool(&self) -> &KvBlockPool {
        &self.pool
    }

    /// Resident bytes of the blocks currently held — block-granular, so
    /// `truncate`/`reset` shrink the charge (the accounting `memmodel`
    /// and `ServeStats` report).
    pub fn bytes(&self) -> usize {
        self.blocks.len() * self.pool.shape.block_bytes
    }

    /// Ensure blocks exist for positions `0..tokens`.  All-or-nothing:
    /// on exhaustion nothing is allocated and the table is unchanged.
    pub fn reserve(&mut self, tokens: usize) -> crate::Result<()> {
        crate::ensure!(
            tokens <= self.capacity,
            "reserve({tokens}) beyond cache capacity {}",
            self.capacity
        );
        let needed = blocks_for(tokens, self.pool.shape.block_tokens);
        if needed > self.blocks.len() {
            let want = needed - self.blocks.len();
            self.pool.lock().alloc_into(want, &mut self.blocks)?;
        }
        Ok(())
    }

    /// Forget everything and return every block to the pool.
    pub fn reset(&mut self) {
        self.len = 0;
        self.free_beyond(0);
    }

    /// Roll the logical fill back to `len`; whole blocks past the new
    /// fill go back to the pool (the rollback hook the bench and a
    /// speculative-decode rejection use).
    pub fn truncate(&mut self, len: usize) {
        assert!(len <= self.len, "truncate({len}) beyond fill {}", self.len);
        self.len = len;
        self.free_beyond(len);
    }

    /// Drop blocks a failed multi-cache reserve left beyond the fill —
    /// the rollback that keeps an errored decode step side-effect free.
    pub(crate) fn release_spare(&mut self) {
        self.free_beyond(self.len);
    }

    fn free_beyond(&mut self, tokens: usize) {
        let keep = blocks_for(tokens, self.pool.shape.block_tokens);
        if self.blocks.len() > keep {
            let mut inner = self.pool.lock();
            for b in self.blocks.drain(keep..) {
                inner.free_block(b);
            }
        }
    }

    pub(crate) fn check(&self, n_layer: usize, d: usize) -> crate::Result<()> {
        crate::ensure!(
            self.n_layer() == n_layer && self.d_model() == d,
            "cache shape ({} layers, d {}) does not match the model ({n_layer}, {d})",
            self.n_layer(),
            self.d_model()
        );
        Ok(())
    }

    pub(crate) fn set_len(&mut self, len: usize) {
        debug_assert!(len <= self.blocks.len() * self.pool.shape.block_tokens);
        self.len = len;
    }

    pub(crate) fn advance(&mut self) {
        self.len += 1;
    }

    /// Attach the longest cached whole-block chain matching `tokens`
    /// (the cache must be empty).  Matched blocks are shared —
    /// refcounted, never written in place — and the fill advances past
    /// them; returns the number of positions attached.  Pass the prompt
    /// minus its last token so prefill always computes at least the
    /// logits position.
    pub fn attach_prefix(&mut self, tokens: &[i32]) -> usize {
        assert!(
            self.len == 0 && self.blocks.is_empty(),
            "attach_prefix on a non-empty cache"
        );
        let limit = tokens.len().min(self.capacity);
        let matched = self.pool.lock().prefix_lookup(&tokens[..limit], &mut self.blocks);
        self.len = matched;
        matched
    }

    /// Publish this sequence's whole-block prefix of `tokens` into the
    /// pool's prefix cache (no-op when the cache is disabled).  The
    /// cache pins the published blocks with its own reference, so they
    /// outlive this sequence until evicted.
    pub fn publish_prefix(&self, tokens: &[i32]) {
        let bt = self.pool.shape.block_tokens;
        let nfull = tokens.len() / bt;
        if nfull == 0 {
            return;
        }
        debug_assert!(self.len >= nfull * bt, "publish beyond the cache fill");
        self.pool
            .lock()
            .prefix_insert(&tokens[..nfull * bt], &self.blocks[..nfull]);
    }

    /// Give the block holding position `pos` a private copy if it is
    /// shared — the copy-on-write step a decode `reserve` applies ahead
    /// of the write so the hot loop never allocates.
    pub(crate) fn ensure_writable(&mut self, pos: usize) -> crate::Result<()> {
        let bi = pos / self.pool.shape.block_tokens;
        if bi >= self.blocks.len() {
            return Ok(());
        }
        let mut inner = self.pool.lock();
        let b = self.blocks[bi];
        if inner.refs[b as usize] > 1 {
            self.blocks[bi] = inner.cow_block(b)?;
        }
        Ok(())
    }

    /// Store position `t`'s K and V rows for `layer`.  The block for `t`
    /// must have been `reserve`d.  A shared block is copied-on-write
    /// first (fresh private block, sibling readers untouched), which can
    /// fail on a bounded pool under pressure.
    pub(crate) fn write_row(&mut self, layer: usize, t: usize, krow: &[f32], vrow: &[f32])
                            -> crate::Result<()> {
        let bt = self.pool.shape.block_tokens;
        debug_assert!(t / bt < self.blocks.len(), "write_row beyond reserved blocks");
        let bi = t / bt;
        let mut inner = self.pool.lock();
        let mut b = self.blocks[bi];
        if inner.refs[b as usize] > 1 {
            b = inner.cow_block(b)?;
            self.blocks[bi] = b;
        }
        inner.write_row(b, layer, t % bt, krow, vrow);
        Ok(())
    }

    /// Run `f` with a read view of one layer's K/V planes, holding the
    /// pool lock for the duration (one lock per attention call).
    pub(crate) fn with_layer<R>(&self, layer: usize,
                                f: impl FnOnce(KvLayerView<'_>) -> R) -> R {
        let inner = self.pool.lock();
        f(inner.layer_view(&self.blocks, layer))
    }
}

impl Drop for KvCache {
    fn drop(&mut self) {
        self.free_beyond(0);
    }
}

impl std::fmt::Debug for KvCache {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("KvCache")
            .field("len", &self.len)
            .field("capacity", &self.capacity)
            .field("blocks", &self.blocks.len())
            .field("dtype", &self.pool.shape.dtype)
            .finish()
    }
}

// ---- read view --------------------------------------------------------

/// One layer's K and V planes over a sequence's block table — what the
/// decode attention loop reads.  f32 rows come back as direct arena
/// slices (bit-identity with the contiguous cache); f16/int8 dequantize
/// into the caller's scratch row.
pub(crate) struct KvLayerView<'a> {
    store: &'a KvStore,
    blocks: &'a [u32],
    scale_base: usize,
    scale_stride: usize,
    k_off: usize,
    v_off: usize,
    block_tokens: usize,
    d: usize,
    group_elems: usize,
}

impl KvLayerView<'_> {
    /// Key head-slice `[off, off+n)` of position `t`.
    #[inline]
    pub(crate) fn k_row<'s>(&'s self, t: usize, off: usize, n: usize,
                            scratch: &'s mut [f32]) -> &'s [f32] {
        self.row(self.k_off, 0, t, off, n, scratch)
    }

    /// Value head-slice `[off, off+n)` of position `t`.
    #[inline]
    pub(crate) fn v_row<'s>(&'s self, t: usize, off: usize, n: usize,
                            scratch: &'s mut [f32]) -> &'s [f32] {
        self.row(self.v_off, 1, t, off, n, scratch)
    }

    #[inline]
    fn row<'s>(&'s self, plane_off: usize, plane: usize, t: usize, off: usize,
               n: usize, scratch: &'s mut [f32]) -> &'s [f32] {
        let b = self.blocks[t / self.block_tokens] as usize;
        let base =
            b * self.group_elems + plane_off + (t % self.block_tokens) * self.d + off;
        match self.store {
            KvStore::F32(a) => &a[base..base + n],
            KvStore::F16(a) => {
                for (s, h) in scratch[..n].iter_mut().zip(&a[base..base + n]) {
                    *s = f16_bits_to_f32(*h);
                }
                &scratch[..n]
            }
            KvStore::Int8 { q, scales } => {
                let sc = scales[b * self.scale_stride + self.scale_base + plane];
                for (s, v) in scratch[..n].iter_mut().zip(&q[base..base + n]) {
                    *s = (*v as f32) * sc;
                }
                &scratch[..n]
            }
        }
    }
}

// ---- f16 bit conversions ----------------------------------------------
//
// Hand-rolled IEEE binary16 (no external crates by design): encode is
// round-to-nearest-even with subnormal and overflow handling, decode is
// exact.  Both are property-tested below against round-trip and pinned
// bit patterns; the encode matches the reference conversion on every
// tested float and the decode on all 2^16 bit patterns.

/// `f32` → binary16 bits, round-to-nearest-even.
pub(crate) fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let man = bits & 0x007f_ffff;
    if exp == 0xff {
        // Inf / NaN; keep a NaN's payload non-zero.
        let pay = if man == 0 { 0 } else { 0x0200 | ((man >> 13) as u16 & 0x03ff) };
        return sign | 0x7c00 | pay;
    }
    let e = exp - 127;
    if e >= 16 {
        return sign | 0x7c00; // overflow → ±inf
    }
    if e >= -14 {
        // Normal half: 10-bit mantissa, round the 13 dropped bits.
        let mut m = man >> 13;
        let rem = man & 0x1fff;
        if rem > 0x1000 || (rem == 0x1000 && (m & 1) == 1) {
            m += 1;
        }
        let mut he = (e + 15) as u32;
        if m == 0x400 {
            // Mantissa carry bumps the exponent.
            m = 0;
            he += 1;
            if he >= 31 {
                return sign | 0x7c00;
            }
        }
        return sign | ((he << 10) as u16) | m as u16;
    }
    if e < -25 {
        return sign; // underflow → ±0
    }
    // Subnormal half: shift the full 24-bit significand into place.
    let full = 0x0080_0000 | man;
    let shift = (-(e + 1)) as u32; // 14..=24
    let mut m = full >> shift;
    let rem = full & ((1u32 << shift) - 1);
    let half = 1u32 << (shift - 1);
    if rem > half || (rem == half && (m & 1) == 1) {
        m += 1;
    }
    // A carry out of the subnormal mantissa lands exactly on the
    // smallest normal (bits 0x0400) — no special case needed.
    sign | m as u16
}

/// binary16 bits → `f32`, exact.
pub(crate) fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let man = (h & 0x03ff) as u32;
    let bits = if exp == 0 {
        if man == 0 {
            sign // ±0
        } else {
            // Subnormal: normalize into an f32 exponent.
            let mut e = 113u32;
            let mut m = man;
            while m & 0x400 == 0 {
                m <<= 1;
                e -= 1;
            }
            sign | (e << 23) | ((m & 0x3ff) << 13)
        }
    } else if exp == 31 {
        sign | 0x7f80_0000 | (man << 13) // ±inf / NaN
    } else {
        sign | ((exp + 112) << 23) | (man << 13)
    };
    f32::from_bits(bits)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn rand_f32(rng: &mut Rng, scale: f32) -> f32 {
        rng.normal_f32(scale)
    }

    #[test]
    fn f16_pinned_bit_patterns() {
        // (f32 input, expected binary16 bits) — reference values.
        let cases: [(f32, u16); 12] = [
            (0.0, 0x0000),
            (-0.0, 0x8000),
            (1.0, 0x3c00),
            (-2.0, 0xc000),
            (65504.0, 0x7bff),          // largest finite half
            (65520.0, 0x7c00),          // rounds to inf
            (f32::INFINITY, 0x7c00),
            (f32::NEG_INFINITY, 0xfc00),
            (6.103_515_6e-5, 0x0400),   // smallest normal half
            (5.960_464_5e-8, 0x0001),   // smallest subnormal half
            (2.980_232_2e-8, 0x0000),   // half of it: ties-to-even → 0
            (1.5, 0x3e00),
        ];
        for (x, want) in cases {
            assert_eq!(f32_to_f16_bits(x), want, "encode {x}");
        }
        // NaN survives as NaN.
        assert!(f16_bits_to_f32(f32_to_f16_bits(f32::NAN)).is_nan());
    }

    #[test]
    fn f16_roundtrip_error_bounded_and_decode_exact_on_exact_halves() {
        let mut rng = Rng::seed_from_u64(0xF16);
        for _ in 0..20_000 {
            let x = rand_f32(&mut rng, 8.0);
            let rt = f16_bits_to_f32(f32_to_f16_bits(x));
            // Half-precision ulp bound in the normal range: 2^-11.
            assert!(
                (rt - x).abs() <= x.abs() * 4.9e-4 + 1e-7,
                "roundtrip {x} -> {rt}"
            );
            // Re-encoding a decoded half is exact (idempotent).
            assert_eq!(f32_to_f16_bits(rt), f32_to_f16_bits(x), "idempotence at {x}");
        }
        // Every finite half decodes and re-encodes to the same bits.
        for h in 0..0x7c00u16 {
            assert_eq!(f32_to_f16_bits(f16_bits_to_f32(h)), h);
            let neg = h | 0x8000;
            assert_eq!(f32_to_f16_bits(f16_bits_to_f32(neg)), neg);
        }
    }

    fn pool(dtype: KvDtype, block_tokens: usize, max_blocks: Option<usize>) -> KvBlockPool {
        KvBlockPool::new(2, 8, KvPoolConfig {
            block_tokens,
            dtype,
            max_blocks,
            ..KvPoolConfig::default()
        })
    }

    /// Read back one full row through the layer view.
    fn read_row(cache: &KvCache, layer: usize, plane: usize, t: usize) -> Vec<f32> {
        let d = cache.d_model();
        let mut scratch = vec![0.0f32; d];
        cache.with_layer(layer, |view| match plane {
            0 => view.k_row(t, 0, d, &mut scratch).to_vec(),
            _ => view.v_row(t, 0, d, &mut scratch).to_vec(),
        })
    }

    #[test]
    fn f32_rows_roundtrip_bitwise_across_blocks() {
        let p = pool(KvDtype::F32, 3, None);
        let mut c = p.new_cache(10);
        c.reserve(10).unwrap();
        let mut rng = Rng::seed_from_u64(1);
        let rows: Vec<(Vec<f32>, Vec<f32>)> = (0..10)
            .map(|_| {
                let k: Vec<f32> = (0..8).map(|_| rand_f32(&mut rng, 3.0)).collect();
                let v: Vec<f32> = (0..8).map(|_| rand_f32(&mut rng, 3.0)).collect();
                (k, v)
            })
            .collect();
        for layer in 0..2 {
            for (t, (k, v)) in rows.iter().enumerate() {
                c.write_row(layer, t, k, v).unwrap();
            }
        }
        for layer in 0..2 {
            for (t, (k, v)) in rows.iter().enumerate() {
                assert_eq!(&read_row(&c, layer, 0, t), k, "k layer {layer} t {t}");
                assert_eq!(&read_row(&c, layer, 1, t), v, "v layer {layer} t {t}");
            }
        }
        assert_eq!(c.bytes(), 4 * p.block_bytes(), "10 tokens / 3-token blocks");
    }

    #[test]
    fn int8_rows_dequantize_within_block_amax_bound() {
        let p = pool(KvDtype::Int8, 4, None);
        let mut c = p.new_cache(8);
        c.reserve(8).unwrap();
        let mut rng = Rng::seed_from_u64(2);
        // Growing magnitudes force scale regrowth + in-place requant.
        let rows: Vec<Vec<f32>> = (0..8)
            .map(|t| (0..8).map(|_| rand_f32(&mut rng, 0.5 + t as f32)).collect())
            .collect();
        for (t, r) in rows.iter().enumerate() {
            c.write_row(0, t, r, r).unwrap();
        }
        for blk in 0..2 {
            let amax = rows[blk * 4..(blk + 1) * 4]
                .iter()
                .flatten()
                .fold(0.0f32, |m, v| m.max(v.abs()));
            // Requantization after a scale regrow costs at most two
            // roundings: 2 × amax/254 ≈ 0.0079·amax; measured 0.0133 worst.
            let tol = amax * 0.016;
            for t in blk * 4..(blk + 1) * 4 {
                let got = read_row(&c, 0, 0, t);
                for (g, w) in got.iter().zip(&rows[t]) {
                    assert!((g - w).abs() <= tol, "t={t}: {g} vs {w} (tol {tol})");
                }
            }
        }
    }

    #[test]
    fn truncate_and_reset_return_blocks_and_shrink_bytes() {
        let p = pool(KvDtype::F16, 2, None);
        let mut c = p.new_cache(9);
        c.reserve(7).unwrap();
        c.set_len(7);
        assert_eq!(c.bytes(), 4 * p.block_bytes());
        assert_eq!(p.stats().blocks_in_use, 4);
        c.truncate(3); // 3 tokens → 2 blocks
        assert_eq!((c.len(), c.bytes()), (3, 2 * p.block_bytes()));
        assert_eq!(p.stats().blocks_in_use, 2);
        c.reset();
        assert_eq!((c.len(), c.bytes()), (0, 0));
        let s = p.stats();
        assert_eq!(s.blocks_in_use, 0);
        assert_eq!(s.blocks_allocated, 4, "arena retained for recycling");
        assert_eq!(s.peak_blocks, 4);
        // Recycling: a fresh reserve reuses freed blocks, no arena growth.
        c.reserve(8).unwrap();
        let s = p.stats();
        assert_eq!(s.blocks_allocated, 4);
        assert_eq!(s.blocks_recycled, 4);
        drop(c);
        assert_eq!(p.stats().blocks_in_use, 0, "Drop returns blocks");
    }

    #[test]
    fn bounded_pool_exhaustion_is_structured_and_all_or_nothing() {
        let p = pool(KvDtype::F32, 2, Some(3));
        let mut a = p.new_cache(8);
        a.reserve(4).unwrap(); // 2 of 3 blocks
        let mut b = p.new_cache(8);
        let err = b.reserve(4).unwrap_err(); // needs 2, only 1 left
        assert!(is_pool_exhausted(&err), "{err}");
        assert_eq!(b.bytes(), 0, "failed reserve must not hold blocks");
        assert_eq!(p.stats().alloc_failures, 1);
        b.reserve(2).unwrap(); // the last block still fits
        a.reset();
        b.reserve(4).unwrap(); // freed blocks make room
        assert_eq!(p.stats().blocks_in_use, 2);
    }

    fn prefix_pool(block_tokens: usize, max_blocks: Option<usize>,
                   cache_blocks: usize) -> KvBlockPool {
        KvBlockPool::new(2, 8, KvPoolConfig {
            block_tokens,
            max_blocks,
            prefix_cache: Some(cache_blocks),
            ..KvPoolConfig::default()
        })
    }

    #[test]
    fn prefix_attach_shares_blocks_and_cow_keeps_siblings_bitwise() {
        let p = prefix_pool(2, None, 64);
        let mut rng = Rng::seed_from_u64(3);
        let prompt: Vec<i32> = (0..6).collect(); // 3 whole 2-token blocks
        // Seq A misses cold, computes the prompt, publishes its blocks.
        let mut a = p.new_cache(16);
        assert_eq!(a.attach_prefix(&prompt[..5]), 0, "cold cache misses");
        a.reserve(6).unwrap();
        let rows: Vec<Vec<f32>> = (0..6)
            .map(|_| (0..8).map(|_| rand_f32(&mut rng, 2.0)).collect())
            .collect();
        for layer in 0..2 {
            for (t, r) in rows.iter().enumerate() {
                a.write_row(layer, t, r, r).unwrap();
            }
        }
        a.set_len(6);
        a.publish_prefix(&prompt);
        let st = p.prefix_stats().unwrap();
        assert_eq!(st.cached_blocks, 3);
        assert_eq!((st.lookups, st.hits), (1, 0));
        // Seq B attaches prompt-minus-last: 5 positions → 2 whole blocks.
        let mut b = p.new_cache(16);
        assert_eq!(b.attach_prefix(&prompt[..5]), 4);
        let st = p.prefix_stats().unwrap();
        assert_eq!((st.lookups, st.hits, st.tokens_saved), (2, 1, 4));
        // Blocks 0, 1: A + cache + B; block 2: A + cache — all shared.
        assert_eq!(st.shared_blocks, 3);
        assert_eq!(p.stats().blocks_in_use, 3, "sharing allocates nothing");
        // B reads A's rows bit-for-bit through the shared blocks.
        for layer in 0..2 {
            for (t, r) in rows.iter().take(4).enumerate() {
                assert_eq!(&read_row(&b, layer, 0, t), r, "layer {layer} t {t}");
            }
        }
        // B overwrites position 0 → copy-on-write: B gets a private
        // block carrying the rest of the block's old bits; A and the
        // cache keep the original.
        let newrow: Vec<f32> = (0..8).map(|_| rand_f32(&mut rng, 2.0)).collect();
        b.write_row(0, 0, &newrow, &newrow).unwrap();
        assert_eq!(read_row(&b, 0, 0, 0), newrow);
        assert_eq!(&read_row(&b, 0, 0, 1), &rows[1], "COW copies the whole block");
        assert_eq!(&read_row(&a, 0, 0, 0), &rows[0], "sibling untouched by COW");
        assert_eq!(p.stats().blocks_in_use, 4, "COW materialized one block");
        // Teardown: sequences drop, the cache still pins its chain;
        // clearing it drains every refcount to zero.
        drop(a);
        drop(b);
        assert_eq!(p.stats().blocks_in_use, 3, "cache pins its chain");
        p.clear_prefix_cache();
        assert_eq!(p.stats().blocks_in_use, 0, "all refcounts drained");
        assert_eq!(p.prefix_stats().unwrap().cached_blocks, 0);
    }

    #[test]
    fn prefix_cache_capacity_evicts_lru_leaf_first() {
        let p = prefix_pool(2, None, 2);
        // Chain X: two blocks, then cache-only (sequence dropped).
        let mut a = p.new_cache(8);
        a.reserve(4).unwrap();
        a.set_len(4);
        a.publish_prefix(&[1, 2, 3, 4]);
        drop(a);
        assert_eq!(p.prefix_stats().unwrap().cached_blocks, 2);
        // Chain Y: one more block → over capacity → X's deepest (leaf)
        // block evicts; its root survives.
        let mut b = p.new_cache(8);
        b.reserve(2).unwrap();
        b.set_len(2);
        b.publish_prefix(&[9, 9]);
        drop(b);
        let st = p.prefix_stats().unwrap();
        assert_eq!((st.cached_blocks, st.evictions), (2, 1));
        let mut c = p.new_cache(8);
        assert_eq!(c.attach_prefix(&[1, 2, 3]), 2, "X's root block still cached");
        drop(c);
        let mut d = p.new_cache(8);
        assert_eq!(d.attach_prefix(&[9, 9, 0]), 2, "Y untouched by the eviction");
        drop(d);
        p.clear_prefix_cache();
        assert_eq!(p.stats().blocks_in_use, 0);
    }

    #[test]
    fn pool_pressure_evicts_cached_chains_before_erroring() {
        let p = prefix_pool(2, Some(4), 64);
        // A 4-block chain, cache-only after the sequence drops: the
        // bounded pool is now nominally full.
        let mut a = p.new_cache(8);
        a.reserve(8).unwrap();
        a.set_len(8);
        a.publish_prefix(&[1, 2, 3, 4, 5, 6, 7, 8]);
        drop(a);
        assert_eq!(p.stats().blocks_in_use, 4, "cache pins the whole chain");
        // A 3-block reserve: cold chain blocks evict to make room — the
        // hot pool degrades to cache-miss, not to an error.
        let mut b = p.new_cache(8);
        b.reserve(6).unwrap();
        let st = p.prefix_stats().unwrap();
        assert_eq!(st.evictions, 3, "evicted exactly what the reserve needed");
        assert_eq!(st.cached_blocks, 1);
        assert_eq!(p.stats().alloc_failures, 0);
        // Further pressure drains the cache, then fails structured —
        // live sequences' blocks are never stolen.
        let mut c = p.new_cache(8);
        let err = c.reserve(4).unwrap_err();
        assert!(is_pool_exhausted(&err), "{err}");
        assert_eq!(p.prefix_stats().unwrap().cached_blocks, 0);
        assert_eq!(p.stats().alloc_failures, 1);
        c.reserve(2).unwrap();
        drop(b);
        drop(c);
        assert_eq!(p.stats().blocks_in_use, 0);
    }

    #[test]
    fn config_parsing() {
        assert_eq!(KvDtype::parse("f32").unwrap(), KvDtype::F32);
        assert_eq!(KvDtype::parse("FP16").unwrap(), KvDtype::F16);
        assert_eq!(KvDtype::parse(" int8 ").unwrap(), KvDtype::Int8);
        assert!(KvDtype::parse("bf16").is_err());
        let d = KvPoolConfig::default();
        assert_eq!(d.block_tokens, DEFAULT_KV_BLOCK_TOKENS);
        assert_eq!(d.dtype, KvDtype::F32);
        assert_eq!(d.max_blocks, None);
        // int8 block bytes charge the per-(layer, plane) scales.
        let p8 = pool(KvDtype::Int8, 16, None);
        assert_eq!(p8.block_bytes(), 2 * 2 * 16 * 8 + 2 * 2 * 4);
        let p32 = pool(KvDtype::F32, 16, None);
        assert_eq!(p32.block_bytes(), 2 * 2 * 16 * 8 * 4);
        assert!(p32.block_bytes() >= 3 * p8.block_bytes(), "int8 ≥ 3× smaller");
    }
}
