//! Paged, quantized KV-cache block pool — the serving-memory subsystem.
//!
//! The contiguous [`KvCache`] of PR 4 preallocated a full
//! `layers × 2 × seq_len × d_model` f32 slab per sequence, so a batch of
//! full-context caches rivals the fp16 weights
//! ([`crate::memmodel::kv_cache_bytes`]) — the serving-memory ceiling for
//! thousands of concurrent sequences.  This module replaces the slab with
//! vLLM-style **block paging**:
//!
//! * [`KvBlockPool`] — a process-wide arena of fixed-size token blocks
//!   (`block_tokens` positions × all layers × K and V planes per block)
//!   behind one shared free-list.  Allocation, append, and free are O(1)
//!   amortized; freed blocks are recycled before the arena grows, and an
//!   optional `max_blocks` bound turns exhaustion into a structured
//!   error (`kv pool exhausted`, see [`is_pool_exhausted`]) instead of
//!   unbounded growth — the signal `DecodeEngine` converts into
//!   admission backpressure.
//! * [`KvCache`] — now a per-sequence **view**: a block table plus a
//!   logical fill.  `reserve` grows the table, `truncate`/`reset` return
//!   whole blocks to the pool immediately (so `bytes()` shrinks with the
//!   fill — rollback no longer strands capacity), and `Drop` frees
//!   everything.
//! * [`KvDtype`] — the storage plane: `f32` (bit-identical to the
//!   contiguous cache: values round-trip the arena verbatim and the
//!   attention loop reads direct slices), `f16` (hand-rolled IEEE
//!   binary16 with round-to-nearest-even, exact vs. the reference
//!   conversion on every bit pattern), or `int8` (symmetric per-block
//!   per-plane scale `amax/127`; the scale only grows, requantizing the
//!   block in place, so a written row's dequantized value never depends
//!   on batch composition — the determinism the decode suites pin).
//!
//! Reads happen inside the decode attention loop through
//! [`KvLayerView`]: f32 returns arena slices directly (zero copy, zero
//! rounding), f16/int8 dequantize one head-slice at a time into a
//! caller-provided scratch row.  Writes and reads take the pool mutex
//! once per (sequence, layer) — uncontended in the single-threaded
//! scheduler, and the kernel-engine threads underneath never touch it.

use std::sync::{Arc, Mutex, MutexGuard};

/// Default block size in token positions — small enough that a short
/// generation wastes at most 15 trailing rows per layer-plane, large
/// enough that the block table stays tiny at full context.
pub const DEFAULT_KV_BLOCK_TOKENS: usize = 16;

/// The error-message marker every pool-exhaustion failure carries.
const POOL_EXHAUSTED: &str = "kv pool exhausted";

/// True when `err` is a [`KvBlockPool`] exhaustion failure — the signal
/// the decode engine treats as backpressure (retry when running
/// sequences free their blocks) rather than a fatal dispatch error.
pub fn is_pool_exhausted(err: &crate::Error) -> bool {
    err.to_string().contains(POOL_EXHAUSTED)
}

/// Whole blocks needed to hold `tokens` positions.
#[inline]
fn blocks_for(tokens: usize, block_tokens: usize) -> usize {
    tokens / block_tokens + usize::from(tokens % block_tokens != 0)
}

// ---- dtype ------------------------------------------------------------

/// Storage format of the cached K/V planes (module docs).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum KvDtype {
    /// 4 bytes/elem, bit-identical to the pre-paging contiguous cache.
    #[default]
    F32,
    /// IEEE binary16, 2 bytes/elem, round-to-nearest-even.
    F16,
    /// Symmetric int8 with one f32 scale per (block, layer, K|V plane).
    Int8,
}

impl KvDtype {
    /// Parse a `--kv-dtype` / `SLOPE_KV_DTYPE` value.
    pub fn parse(s: &str) -> crate::Result<Self> {
        match s.trim().to_ascii_lowercase().as_str() {
            "f32" | "fp32" | "float32" => Ok(Self::F32),
            "f16" | "fp16" | "float16" | "half" => Ok(Self::F16),
            "int8" | "i8" => Ok(Self::Int8),
            other => Err(crate::eyre!(
                "unknown kv dtype {other:?} (expected f32 | f16 | int8)"
            )),
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            Self::F32 => "f32",
            Self::F16 => "f16",
            Self::Int8 => "int8",
        }
    }

    /// Bytes per stored element (int8 scales are charged separately).
    pub fn elem_bytes(self) -> usize {
        match self {
            Self::F32 => 4,
            Self::F16 => 2,
            Self::Int8 => 1,
        }
    }
}

// ---- configuration ----------------------------------------------------

/// Pool shape knobs, threaded from the CLI (`--kv-block`, `--kv-dtype`,
/// `--kv-pool-blocks`) or the environment into [`KvBlockPool::new`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KvPoolConfig {
    /// Token positions per block (every layer's K and V rows for those
    /// positions live in the same block).
    pub block_tokens: usize,
    /// Storage plane format.
    pub dtype: KvDtype,
    /// Hard bound on live blocks (`None` = grow on demand).  When the
    /// bound is hit, `reserve` fails with the structured exhaustion
    /// error instead of allocating.
    pub max_blocks: Option<usize>,
}

impl Default for KvPoolConfig {
    fn default() -> Self {
        Self {
            block_tokens: DEFAULT_KV_BLOCK_TOKENS,
            dtype: KvDtype::F32,
            max_blocks: None,
        }
    }
}

impl KvPoolConfig {
    /// Defaults overridden by `SLOPE_KV_DTYPE` / `SLOPE_KV_BLOCK` —
    /// the env seam the CI int8 decode leg uses.  Unparsable values warn
    /// and keep the default (never a panic at model-open time).
    pub fn from_env() -> Self {
        let mut cfg = Self::default();
        if let Ok(v) = std::env::var("SLOPE_KV_DTYPE") {
            match KvDtype::parse(&v) {
                Ok(d) => cfg.dtype = d,
                Err(e) => eprintln!("[kvpool] ignoring SLOPE_KV_DTYPE: {e}"),
            }
        }
        if let Ok(v) = std::env::var("SLOPE_KV_BLOCK") {
            match v.trim().parse::<usize>() {
                Ok(n) if n > 0 => cfg.block_tokens = n,
                _ => eprintln!(
                    "[kvpool] ignoring SLOPE_KV_BLOCK={v:?} (want a positive integer)"
                ),
            }
        }
        cfg
    }
}

// ---- pool -------------------------------------------------------------

/// Occupancy snapshot of a [`KvBlockPool`] — the gauges `ServeStats`
/// records per decode step and `bench_serve` prints per kv series.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct KvPoolStats {
    /// Blocks currently held by live caches.
    pub blocks_in_use: usize,
    /// Blocks ever materialized in the arena (in use + free-listed).
    pub blocks_allocated: usize,
    /// High-water mark of `blocks_in_use`.
    pub peak_blocks: usize,
    /// Configured bound (`None` = unbounded).
    pub max_blocks: Option<usize>,
    /// Resident bytes per block (K+V planes for all layers, plus int8
    /// scales when quantized).
    pub block_bytes: usize,
    /// `blocks_in_use × block_bytes`.
    pub bytes_in_use: usize,
    /// `reserve` calls refused because the bound was hit.
    pub alloc_failures: u64,
    /// Allocations served by recycling a freed block (vs. arena growth).
    pub blocks_recycled: u64,
}

/// Immutable pool shape, cached outside the mutex so accessors and
/// `bytes()` never lock.
#[derive(Clone, Copy)]
struct PoolShape {
    n_layer: usize,
    d_model: usize,
    block_tokens: usize,
    dtype: KvDtype,
    block_bytes: usize,
    max_blocks: Option<usize>,
}

impl PoolShape {
    /// Arena elements per block: all layers × {K, V} × block rows.
    fn group_elems(&self) -> usize {
        self.n_layer * 2 * self.block_tokens * self.d_model
    }
}

/// The storage arena, one flat vector per dtype; block `b` owns elements
/// `b·group_elems .. (b+1)·group_elems`.
enum KvStore {
    F32(Vec<f32>),
    F16(Vec<u16>),
    Int8 {
        q: Vec<i8>,
        /// One scale per (block, layer, plane): index
        /// `b·(n_layer·2) + layer·2 + plane`.  Zero = nothing written.
        scales: Vec<f32>,
    },
}

struct PoolInner {
    shape: PoolShape,
    store: KvStore,
    /// Recycled block ids, LIFO.
    free: Vec<u32>,
    /// Total blocks materialized in the arena.
    total: usize,
    peak_in_use: usize,
    alloc_failures: u64,
    blocks_recycled: u64,
}

impl PoolInner {
    fn in_use(&self) -> usize {
        self.total - self.free.len()
    }

    /// Offset of (layer, plane) inside a block group.
    fn plane_off(&self, layer: usize, plane: usize) -> usize {
        (layer * 2 + plane) * self.shape.block_tokens * self.shape.d_model
    }

    /// All-or-nothing: append `want` block ids to `table`, or fail
    /// without allocating anything.
    fn alloc_into(&mut self, want: usize, table: &mut Vec<u32>) -> crate::Result<()> {
        let headroom = match self.shape.max_blocks {
            Some(cap) => cap.saturating_sub(self.total),
            None => usize::MAX,
        };
        if want > self.free.len().saturating_add(headroom) {
            self.alloc_failures += 1;
            return Err(crate::eyre!(
                "{POOL_EXHAUSTED}: need {want} block(s), {} free of {} \
                 ({} in use; block {} tokens, {})",
                self.free.len(),
                self.shape.max_blocks.map_or_else(|| "unbounded".into(), |c| c.to_string()),
                self.in_use(),
                self.shape.block_tokens,
                self.shape.dtype.label(),
            ));
        }
        for _ in 0..want {
            let id = if let Some(id) = self.free.pop() {
                self.blocks_recycled += 1;
                id
            } else {
                let id = self.total as u32;
                self.total += 1;
                let g = self.shape.group_elems();
                match &mut self.store {
                    KvStore::F32(a) => a.resize(a.len() + g, 0.0),
                    KvStore::F16(a) => a.resize(a.len() + g, 0),
                    KvStore::Int8 { q, scales } => {
                        q.resize(q.len() + g, 0);
                        scales.resize(scales.len() + self.shape.n_layer * 2, 0.0);
                    }
                }
                id
            };
            table.push(id);
        }
        self.peak_in_use = self.peak_in_use.max(self.in_use());
        Ok(())
    }

    /// Return a block to the free-list.  Int8 scales reset so a recycled
    /// block quantizes exactly like a fresh one.
    fn free_block(&mut self, b: u32) {
        if let KvStore::Int8 { scales, .. } = &mut self.store {
            let stride = self.shape.n_layer * 2;
            let base = b as usize * stride;
            scales[base..base + stride].fill(0.0);
        }
        self.free.push(b);
    }

    /// Store row `r` of block `b` for `layer`: K then V plane.
    fn write_row(&mut self, b: u32, layer: usize, r: usize, krow: &[f32], vrow: &[f32]) {
        let d = self.shape.d_model;
        let bt = self.shape.block_tokens;
        let base_b = b as usize * self.shape.group_elems();
        for (plane, row) in [(0usize, krow), (1, vrow)] {
            let plane_base = base_b + self.plane_off(layer, plane);
            let dst = plane_base + r * d;
            match &mut self.store {
                KvStore::F32(a) => a[dst..dst + d].copy_from_slice(row),
                KvStore::F16(a) => {
                    for (h, v) in a[dst..dst + d].iter_mut().zip(row) {
                        *h = f32_to_f16_bits(*v);
                    }
                }
                KvStore::Int8 { q, scales } => {
                    let si = b as usize * self.shape.n_layer * 2 + layer * 2 + plane;
                    let amax = row.iter().fold(0.0f32, |m, v| m.max(v.abs()));
                    let mut sc = scales[si];
                    if amax > sc * 127.0 {
                        // The scale only grows; requantize the block's
                        // already-written rows to the new grid so every
                        // stored row stays consistent with one scale.
                        let new_sc = amax / 127.0;
                        if sc > 0.0 {
                            let ratio = sc / new_sc;
                            for v in &mut q[plane_base..plane_base + bt * d] {
                                *v = ((*v as f32) * ratio).round().clamp(-127.0, 127.0)
                                    as i8;
                            }
                        }
                        sc = new_sc;
                        scales[si] = sc;
                    }
                    let out = &mut q[dst..dst + d];
                    if sc > 0.0 {
                        let inv = 1.0 / sc;
                        for (dq, v) in out.iter_mut().zip(row) {
                            *dq = (*v * inv).round().clamp(-127.0, 127.0) as i8;
                        }
                    } else {
                        out.fill(0);
                    }
                }
            }
        }
    }

    /// Read-side view of one layer's K and V planes over a block table.
    fn layer_view<'a>(&'a self, blocks: &'a [u32], layer: usize) -> KvLayerView<'a> {
        KvLayerView {
            store: &self.store,
            blocks,
            scale_base: layer * 2,
            scale_stride: self.shape.n_layer * 2,
            k_off: self.plane_off(layer, 0),
            v_off: self.plane_off(layer, 1),
            block_tokens: self.shape.block_tokens,
            d: self.shape.d_model,
            group_elems: self.shape.group_elems(),
        }
    }

    fn stats(&self, shape: &PoolShape) -> KvPoolStats {
        KvPoolStats {
            blocks_in_use: self.in_use(),
            blocks_allocated: self.total,
            peak_blocks: self.peak_in_use,
            max_blocks: shape.max_blocks,
            block_bytes: shape.block_bytes,
            bytes_in_use: self.in_use() * shape.block_bytes,
            alloc_failures: self.alloc_failures,
            blocks_recycled: self.blocks_recycled,
        }
    }
}

/// Process-wide paged KV arena (module docs).  Cloning the handle shares
/// the pool; every [`KvCache`] holds one.
#[derive(Clone)]
pub struct KvBlockPool {
    shape: PoolShape,
    inner: Arc<Mutex<PoolInner>>,
}

impl KvBlockPool {
    pub fn new(n_layer: usize, d_model: usize, cfg: KvPoolConfig) -> Self {
        assert!(
            n_layer > 0 && d_model > 0 && cfg.block_tokens > 0,
            "degenerate KvBlockPool shape"
        );
        let elem = cfg.dtype.elem_bytes();
        let group = n_layer * 2 * cfg.block_tokens * d_model;
        let scale_bytes = match cfg.dtype {
            KvDtype::Int8 => n_layer * 2 * 4,
            _ => 0,
        };
        let shape = PoolShape {
            n_layer,
            d_model,
            block_tokens: cfg.block_tokens,
            dtype: cfg.dtype,
            block_bytes: group * elem + scale_bytes,
            max_blocks: cfg.max_blocks,
        };
        let store = match cfg.dtype {
            KvDtype::F32 => KvStore::F32(Vec::new()),
            KvDtype::F16 => KvStore::F16(Vec::new()),
            KvDtype::Int8 => KvStore::Int8 { q: Vec::new(), scales: Vec::new() },
        };
        Self {
            shape,
            inner: Arc::new(Mutex::new(PoolInner {
                shape,
                store,
                free: Vec::new(),
                total: 0,
                peak_in_use: 0,
                alloc_failures: 0,
                blocks_recycled: 0,
            })),
        }
    }

    pub fn n_layer(&self) -> usize {
        self.shape.n_layer
    }

    pub fn d_model(&self) -> usize {
        self.shape.d_model
    }

    pub fn block_tokens(&self) -> usize {
        self.shape.block_tokens
    }

    pub fn dtype(&self) -> KvDtype {
        self.shape.dtype
    }

    /// Resident bytes per block (what `bytes()` charges per table entry).
    pub fn block_bytes(&self) -> usize {
        self.shape.block_bytes
    }

    /// An empty per-sequence cache view over this pool, bounded at
    /// `capacity` token positions (the model's `seq_len`).
    pub fn new_cache(&self, capacity: usize) -> KvCache {
        assert!(capacity > 0, "degenerate KvCache capacity");
        KvCache {
            pool: self.clone(),
            blocks: Vec::new(),
            len: 0,
            capacity,
        }
    }

    pub fn stats(&self) -> KvPoolStats {
        self.lock().stats(&self.shape)
    }

    fn lock(&self) -> MutexGuard<'_, PoolInner> {
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }
}

impl std::fmt::Debug for KvBlockPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = self.stats();
        f.debug_struct("KvBlockPool")
            .field("n_layer", &self.shape.n_layer)
            .field("d_model", &self.shape.d_model)
            .field("block_tokens", &self.shape.block_tokens)
            .field("dtype", &self.shape.dtype)
            .field("blocks_in_use", &s.blocks_in_use)
            .field("blocks_allocated", &s.blocks_allocated)
            .finish()
    }
}

// ---- per-sequence cache view ------------------------------------------

/// Per-sequence decode state: a block table into a shared
/// [`KvBlockPool`] plus the logical fill.  Rows `len..` of the reserved
/// blocks are dead space a later write overwrites; `truncate`/`reset`
/// return whole blocks to the pool immediately, so `bytes()` always
/// charges exactly the blocks held.
pub struct KvCache {
    pool: KvBlockPool,
    blocks: Vec<u32>,
    len: usize,
    capacity: usize,
}

impl KvCache {
    /// Convenience constructor for standalone use (tests, memmodel):
    /// a private single-cache pool with the default f32 config.
    pub fn new(n_layer: usize, d_model: usize, capacity: usize) -> Self {
        KvBlockPool::new(n_layer, d_model, KvPoolConfig::default()).new_cache(capacity)
    }

    pub fn n_layer(&self) -> usize {
        self.pool.shape.n_layer
    }

    pub fn d_model(&self) -> usize {
        self.pool.shape.d_model
    }

    /// Maximum positions this cache may hold (the model's `seq_len`).
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Positions currently cached (prompt + decoded tokens).
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn dtype(&self) -> KvDtype {
        self.pool.shape.dtype
    }

    pub fn block_tokens(&self) -> usize {
        self.pool.shape.block_tokens
    }

    /// The pool this cache draws from.
    pub fn pool(&self) -> &KvBlockPool {
        &self.pool
    }

    /// Resident bytes of the blocks currently held — block-granular, so
    /// `truncate`/`reset` shrink the charge (the accounting `memmodel`
    /// and `ServeStats` report).
    pub fn bytes(&self) -> usize {
        self.blocks.len() * self.pool.shape.block_bytes
    }

    /// Ensure blocks exist for positions `0..tokens`.  All-or-nothing:
    /// on exhaustion nothing is allocated and the table is unchanged.
    pub fn reserve(&mut self, tokens: usize) -> crate::Result<()> {
        crate::ensure!(
            tokens <= self.capacity,
            "reserve({tokens}) beyond cache capacity {}",
            self.capacity
        );
        let needed = blocks_for(tokens, self.pool.shape.block_tokens);
        if needed > self.blocks.len() {
            let want = needed - self.blocks.len();
            self.pool.lock().alloc_into(want, &mut self.blocks)?;
        }
        Ok(())
    }

    /// Forget everything and return every block to the pool.
    pub fn reset(&mut self) {
        self.len = 0;
        self.free_beyond(0);
    }

    /// Roll the logical fill back to `len`; whole blocks past the new
    /// fill go back to the pool (the rollback hook the bench and a
    /// speculative-decode rejection use).
    pub fn truncate(&mut self, len: usize) {
        assert!(len <= self.len, "truncate({len}) beyond fill {}", self.len);
        self.len = len;
        self.free_beyond(len);
    }

    /// Drop blocks a failed multi-cache reserve left beyond the fill —
    /// the rollback that keeps an errored decode step side-effect free.
    pub(crate) fn release_spare(&mut self) {
        self.free_beyond(self.len);
    }

    fn free_beyond(&mut self, tokens: usize) {
        let keep = blocks_for(tokens, self.pool.shape.block_tokens);
        if self.blocks.len() > keep {
            let mut inner = self.pool.lock();
            for b in self.blocks.drain(keep..) {
                inner.free_block(b);
            }
        }
    }

    pub(crate) fn check(&self, n_layer: usize, d: usize) -> crate::Result<()> {
        crate::ensure!(
            self.n_layer() == n_layer && self.d_model() == d,
            "cache shape ({} layers, d {}) does not match the model ({n_layer}, {d})",
            self.n_layer(),
            self.d_model()
        );
        Ok(())
    }

    pub(crate) fn set_len(&mut self, len: usize) {
        debug_assert!(len <= self.blocks.len() * self.pool.shape.block_tokens);
        self.len = len;
    }

    pub(crate) fn advance(&mut self) {
        self.len += 1;
    }

    /// Store position `t`'s K and V rows for `layer`.  The block for `t`
    /// must have been `reserve`d.
    pub(crate) fn write_row(&mut self, layer: usize, t: usize, krow: &[f32], vrow: &[f32]) {
        let bt = self.pool.shape.block_tokens;
        debug_assert!(t / bt < self.blocks.len(), "write_row beyond reserved blocks");
        let b = self.blocks[t / bt];
        self.pool.lock().write_row(b, layer, t % bt, krow, vrow);
    }

    /// Run `f` with a read view of one layer's K/V planes, holding the
    /// pool lock for the duration (one lock per attention call).
    pub(crate) fn with_layer<R>(&self, layer: usize,
                                f: impl FnOnce(KvLayerView<'_>) -> R) -> R {
        let inner = self.pool.lock();
        f(inner.layer_view(&self.blocks, layer))
    }
}

impl Drop for KvCache {
    fn drop(&mut self) {
        self.free_beyond(0);
    }
}

impl std::fmt::Debug for KvCache {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("KvCache")
            .field("len", &self.len)
            .field("capacity", &self.capacity)
            .field("blocks", &self.blocks.len())
            .field("dtype", &self.pool.shape.dtype)
            .finish()
    }
}

// ---- read view --------------------------------------------------------

/// One layer's K and V planes over a sequence's block table — what the
/// decode attention loop reads.  f32 rows come back as direct arena
/// slices (bit-identity with the contiguous cache); f16/int8 dequantize
/// into the caller's scratch row.
pub(crate) struct KvLayerView<'a> {
    store: &'a KvStore,
    blocks: &'a [u32],
    scale_base: usize,
    scale_stride: usize,
    k_off: usize,
    v_off: usize,
    block_tokens: usize,
    d: usize,
    group_elems: usize,
}

impl KvLayerView<'_> {
    /// Key head-slice `[off, off+n)` of position `t`.
    #[inline]
    pub(crate) fn k_row<'s>(&'s self, t: usize, off: usize, n: usize,
                            scratch: &'s mut [f32]) -> &'s [f32] {
        self.row(self.k_off, 0, t, off, n, scratch)
    }

    /// Value head-slice `[off, off+n)` of position `t`.
    #[inline]
    pub(crate) fn v_row<'s>(&'s self, t: usize, off: usize, n: usize,
                            scratch: &'s mut [f32]) -> &'s [f32] {
        self.row(self.v_off, 1, t, off, n, scratch)
    }

    #[inline]
    fn row<'s>(&'s self, plane_off: usize, plane: usize, t: usize, off: usize,
               n: usize, scratch: &'s mut [f32]) -> &'s [f32] {
        let b = self.blocks[t / self.block_tokens] as usize;
        let base =
            b * self.group_elems + plane_off + (t % self.block_tokens) * self.d + off;
        match self.store {
            KvStore::F32(a) => &a[base..base + n],
            KvStore::F16(a) => {
                for (s, h) in scratch[..n].iter_mut().zip(&a[base..base + n]) {
                    *s = f16_bits_to_f32(*h);
                }
                &scratch[..n]
            }
            KvStore::Int8 { q, scales } => {
                let sc = scales[b * self.scale_stride + self.scale_base + plane];
                for (s, v) in scratch[..n].iter_mut().zip(&q[base..base + n]) {
                    *s = (*v as f32) * sc;
                }
                &scratch[..n]
            }
        }
    }
}

// ---- f16 bit conversions ----------------------------------------------
//
// Hand-rolled IEEE binary16 (no external crates by design): encode is
// round-to-nearest-even with subnormal and overflow handling, decode is
// exact.  Both are property-tested below against round-trip and pinned
// bit patterns; the encode matches the reference conversion on every
// tested float and the decode on all 2^16 bit patterns.

/// `f32` → binary16 bits, round-to-nearest-even.
pub(crate) fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let man = bits & 0x007f_ffff;
    if exp == 0xff {
        // Inf / NaN; keep a NaN's payload non-zero.
        let pay = if man == 0 { 0 } else { 0x0200 | ((man >> 13) as u16 & 0x03ff) };
        return sign | 0x7c00 | pay;
    }
    let e = exp - 127;
    if e >= 16 {
        return sign | 0x7c00; // overflow → ±inf
    }
    if e >= -14 {
        // Normal half: 10-bit mantissa, round the 13 dropped bits.
        let mut m = man >> 13;
        let rem = man & 0x1fff;
        if rem > 0x1000 || (rem == 0x1000 && (m & 1) == 1) {
            m += 1;
        }
        let mut he = (e + 15) as u32;
        if m == 0x400 {
            // Mantissa carry bumps the exponent.
            m = 0;
            he += 1;
            if he >= 31 {
                return sign | 0x7c00;
            }
        }
        return sign | ((he << 10) as u16) | m as u16;
    }
    if e < -25 {
        return sign; // underflow → ±0
    }
    // Subnormal half: shift the full 24-bit significand into place.
    let full = 0x0080_0000 | man;
    let shift = (-(e + 1)) as u32; // 14..=24
    let mut m = full >> shift;
    let rem = full & ((1u32 << shift) - 1);
    let half = 1u32 << (shift - 1);
    if rem > half || (rem == half && (m & 1) == 1) {
        m += 1;
    }
    // A carry out of the subnormal mantissa lands exactly on the
    // smallest normal (bits 0x0400) — no special case needed.
    sign | m as u16
}

/// binary16 bits → `f32`, exact.
pub(crate) fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let man = (h & 0x03ff) as u32;
    let bits = if exp == 0 {
        if man == 0 {
            sign // ±0
        } else {
            // Subnormal: normalize into an f32 exponent.
            let mut e = 113u32;
            let mut m = man;
            while m & 0x400 == 0 {
                m <<= 1;
                e -= 1;
            }
            sign | (e << 23) | ((m & 0x3ff) << 13)
        }
    } else if exp == 31 {
        sign | 0x7f80_0000 | (man << 13) // ±inf / NaN
    } else {
        sign | ((exp + 112) << 23) | (man << 13)
    };
    f32::from_bits(bits)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn rand_f32(rng: &mut Rng, scale: f32) -> f32 {
        rng.normal_f32(scale)
    }

    #[test]
    fn f16_pinned_bit_patterns() {
        // (f32 input, expected binary16 bits) — reference values.
        let cases: [(f32, u16); 12] = [
            (0.0, 0x0000),
            (-0.0, 0x8000),
            (1.0, 0x3c00),
            (-2.0, 0xc000),
            (65504.0, 0x7bff),          // largest finite half
            (65520.0, 0x7c00),          // rounds to inf
            (f32::INFINITY, 0x7c00),
            (f32::NEG_INFINITY, 0xfc00),
            (6.103_515_6e-5, 0x0400),   // smallest normal half
            (5.960_464_5e-8, 0x0001),   // smallest subnormal half
            (2.980_232_2e-8, 0x0000),   // half of it: ties-to-even → 0
            (1.5, 0x3e00),
        ];
        for (x, want) in cases {
            assert_eq!(f32_to_f16_bits(x), want, "encode {x}");
        }
        // NaN survives as NaN.
        assert!(f16_bits_to_f32(f32_to_f16_bits(f32::NAN)).is_nan());
    }

    #[test]
    fn f16_roundtrip_error_bounded_and_decode_exact_on_exact_halves() {
        let mut rng = Rng::seed_from_u64(0xF16);
        for _ in 0..20_000 {
            let x = rand_f32(&mut rng, 8.0);
            let rt = f16_bits_to_f32(f32_to_f16_bits(x));
            // Half-precision ulp bound in the normal range: 2^-11.
            assert!(
                (rt - x).abs() <= x.abs() * 4.9e-4 + 1e-7,
                "roundtrip {x} -> {rt}"
            );
            // Re-encoding a decoded half is exact (idempotent).
            assert_eq!(f32_to_f16_bits(rt), f32_to_f16_bits(x), "idempotence at {x}");
        }
        // Every finite half decodes and re-encodes to the same bits.
        for h in 0..0x7c00u16 {
            assert_eq!(f32_to_f16_bits(f16_bits_to_f32(h)), h);
            let neg = h | 0x8000;
            assert_eq!(f32_to_f16_bits(f16_bits_to_f32(neg)), neg);
        }
    }

    fn pool(dtype: KvDtype, block_tokens: usize, max_blocks: Option<usize>) -> KvBlockPool {
        KvBlockPool::new(2, 8, KvPoolConfig { block_tokens, dtype, max_blocks })
    }

    /// Read back one full row through the layer view.
    fn read_row(cache: &KvCache, layer: usize, plane: usize, t: usize) -> Vec<f32> {
        let d = cache.d_model();
        let mut scratch = vec![0.0f32; d];
        cache.with_layer(layer, |view| match plane {
            0 => view.k_row(t, 0, d, &mut scratch).to_vec(),
            _ => view.v_row(t, 0, d, &mut scratch).to_vec(),
        })
    }

    #[test]
    fn f32_rows_roundtrip_bitwise_across_blocks() {
        let p = pool(KvDtype::F32, 3, None);
        let mut c = p.new_cache(10);
        c.reserve(10).unwrap();
        let mut rng = Rng::seed_from_u64(1);
        let rows: Vec<(Vec<f32>, Vec<f32>)> = (0..10)
            .map(|_| {
                let k: Vec<f32> = (0..8).map(|_| rand_f32(&mut rng, 3.0)).collect();
                let v: Vec<f32> = (0..8).map(|_| rand_f32(&mut rng, 3.0)).collect();
                (k, v)
            })
            .collect();
        for layer in 0..2 {
            for (t, (k, v)) in rows.iter().enumerate() {
                c.write_row(layer, t, k, v);
            }
        }
        for layer in 0..2 {
            for (t, (k, v)) in rows.iter().enumerate() {
                assert_eq!(&read_row(&c, layer, 0, t), k, "k layer {layer} t {t}");
                assert_eq!(&read_row(&c, layer, 1, t), v, "v layer {layer} t {t}");
            }
        }
        assert_eq!(c.bytes(), 4 * p.block_bytes(), "10 tokens / 3-token blocks");
    }

    #[test]
    fn int8_rows_dequantize_within_block_amax_bound() {
        let p = pool(KvDtype::Int8, 4, None);
        let mut c = p.new_cache(8);
        c.reserve(8).unwrap();
        let mut rng = Rng::seed_from_u64(2);
        // Growing magnitudes force scale regrowth + in-place requant.
        let rows: Vec<Vec<f32>> = (0..8)
            .map(|t| (0..8).map(|_| rand_f32(&mut rng, 0.5 + t as f32)).collect())
            .collect();
        for (t, r) in rows.iter().enumerate() {
            c.write_row(0, t, r, r);
        }
        for blk in 0..2 {
            let amax = rows[blk * 4..(blk + 1) * 4]
                .iter()
                .flatten()
                .fold(0.0f32, |m, v| m.max(v.abs()));
            // Requantization after a scale regrow costs at most two
            // roundings: 2 × amax/254 ≈ 0.0079·amax; measured 0.0133 worst.
            let tol = amax * 0.016;
            for t in blk * 4..(blk + 1) * 4 {
                let got = read_row(&c, 0, 0, t);
                for (g, w) in got.iter().zip(&rows[t]) {
                    assert!((g - w).abs() <= tol, "t={t}: {g} vs {w} (tol {tol})");
                }
            }
        }
    }

    #[test]
    fn truncate_and_reset_return_blocks_and_shrink_bytes() {
        let p = pool(KvDtype::F16, 2, None);
        let mut c = p.new_cache(9);
        c.reserve(7).unwrap();
        c.set_len(7);
        assert_eq!(c.bytes(), 4 * p.block_bytes());
        assert_eq!(p.stats().blocks_in_use, 4);
        c.truncate(3); // 3 tokens → 2 blocks
        assert_eq!((c.len(), c.bytes()), (3, 2 * p.block_bytes()));
        assert_eq!(p.stats().blocks_in_use, 2);
        c.reset();
        assert_eq!((c.len(), c.bytes()), (0, 0));
        let s = p.stats();
        assert_eq!(s.blocks_in_use, 0);
        assert_eq!(s.blocks_allocated, 4, "arena retained for recycling");
        assert_eq!(s.peak_blocks, 4);
        // Recycling: a fresh reserve reuses freed blocks, no arena growth.
        c.reserve(8).unwrap();
        let s = p.stats();
        assert_eq!(s.blocks_allocated, 4);
        assert_eq!(s.blocks_recycled, 4);
        drop(c);
        assert_eq!(p.stats().blocks_in_use, 0, "Drop returns blocks");
    }

    #[test]
    fn bounded_pool_exhaustion_is_structured_and_all_or_nothing() {
        let p = pool(KvDtype::F32, 2, Some(3));
        let mut a = p.new_cache(8);
        a.reserve(4).unwrap(); // 2 of 3 blocks
        let mut b = p.new_cache(8);
        let err = b.reserve(4).unwrap_err(); // needs 2, only 1 left
        assert!(is_pool_exhausted(&err), "{err}");
        assert_eq!(b.bytes(), 0, "failed reserve must not hold blocks");
        assert_eq!(p.stats().alloc_failures, 1);
        b.reserve(2).unwrap(); // the last block still fits
        a.reset();
        b.reserve(4).unwrap(); // freed blocks make room
        assert_eq!(p.stats().blocks_in_use, 2);
    }

    #[test]
    fn config_parsing() {
        assert_eq!(KvDtype::parse("f32").unwrap(), KvDtype::F32);
        assert_eq!(KvDtype::parse("FP16").unwrap(), KvDtype::F16);
        assert_eq!(KvDtype::parse(" int8 ").unwrap(), KvDtype::Int8);
        assert!(KvDtype::parse("bf16").is_err());
        let d = KvPoolConfig::default();
        assert_eq!(d.block_tokens, DEFAULT_KV_BLOCK_TOKENS);
        assert_eq!(d.dtype, KvDtype::F32);
        assert_eq!(d.max_blocks, None);
        // int8 block bytes charge the per-(layer, plane) scales.
        let p8 = pool(KvDtype::Int8, 16, None);
        assert_eq!(p8.block_bytes(), 2 * 2 * 16 * 8 + 2 * 2 * 4);
        let p32 = pool(KvDtype::F32, 16, None);
        assert_eq!(p32.block_bytes(), 2 * 2 * 16 * 8 * 4);
        assert!(p32.block_bytes() >= 3 * p8.block_bytes(), "int8 ≥ 3× smaller");
    }
}
