//! The [`Executor`] abstraction: run-executable-by-manifest-name over a
//! [`Store`], with two interchangeable backends.
//!
//! * [`PjrtExec`] — the original route: parse the artifact's HLO text,
//!   compile once on the PJRT CPU client, marshal literals positionally
//!   per the manifest's input/output specs.
//! * [`HostExec`] — the **native** route: the manifest's `init` /
//!   `train_step` / `train_step_lora` / `lora_init` / `eval_step` /
//!   `eval_step_lora` / `forward` / `forward_lora` semantics implemented
//!   on the crate's own kernel engine via
//!   [`HostTrainModel`] — the double-pruned
//!   backward pass (Eq. 4–6) in pure rust, no python, no XLA, no
//!   artifacts.
//!
//! [`super::Session`] picks the backend at open time: a directory whose
//! manifest's HLO files exist routes to PJRT; a manifest without HLO
//! beside it (a fabricated host-train config, or a serving-checkpoint
//! directory) routes to the host executor.  The trainer is agnostic — it
//! only ever calls `Session::run(name, store)`.
//!
//! ## Store contract on the host route
//!
//! The AOT contract is state-in/state-out through the store by name; the
//! host executor honors it while keeping **resident operand state**
//! (packed weights, moments) so steady-state steps skip re-compression:
//! after every state-changing executable it writes the outputs back to
//! the store and records their [`Store`] versions; before every run it
//! diffs the tracked prefixes (`params.` / `opt.` / `masks.` / `lora.` /
//! `lora_opt.`) against those versions and rebuilds from the store only
//! when something else wrote them (e.g. the dense baseline's fabricated
//! ones-masks, or a checkpoint restore).  Per-step external writes
//! (`tokens`, `seed`) are untracked, so the hot loop never re-ingests.

use crate::backend::ParallelPolicy;
use crate::runtime::host_train::HostTrainModel;
use crate::runtime::{Manifest, Store};
use std::collections::HashMap;

/// Which backend a [`super::Session`] resolved to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecutorKind {
    /// Compiled AOT executables through the PJRT client.
    Pjrt,
    /// The crate's own kernel engine ([`HostTrainModel`]).
    HostKernels,
}

impl ExecutorKind {
    /// Human-readable route label for CLI notices.
    pub fn describe(&self) -> &'static str {
        match self {
            ExecutorKind::Pjrt => "pjrt (compiled AOT executables)",
            ExecutorKind::HostKernels => {
                "host kernels (double-pruned backward on the crate's engine)"
            }
        }
    }
}

/// Run-executable-by-manifest-name over a [`Store`].
pub trait Executor {
    fn kind(&self) -> ExecutorKind;

    /// Execution parallelism for subsequent work (kernel engine threads
    /// on the host route; the intra-op hint on a real PJRT backend).
    fn set_parallel(&mut self, policy: ParallelPolicy);

    /// Pre-build `name` so the first `run` measures steady-state work
    /// (PJRT: compile; host: validate the name is implemented).
    fn prepare(&mut self, name: &str) -> crate::Result<()>;

    /// Execute `name`: read its inputs from the store, write its outputs
    /// back by name.
    fn run(&mut self, name: &str, store: &mut Store) -> crate::Result<()>;
}

// ---- PJRT ---------------------------------------------------------------

/// The compiled-artifact executor (the pre-refactor `Session` internals).
pub struct PjrtExec {
    manifest: Manifest,
    client: xla::PjRtClient,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl PjrtExec {
    pub fn new(manifest: Manifest) -> crate::Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| crate::eyre!("PJRT cpu client: {e}"))?;
        Ok(Self { manifest, client, cache: HashMap::new() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch the cached) executable by manifest name.
    fn exe(&mut self, name: &str) -> crate::Result<&xla::PjRtLoadedExecutable> {
        if !self.cache.contains_key(name) {
            let path = self.manifest.hlo_path(name)?;
            let t0 = std::time::Instant::now();
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| crate::eyre!("parsing {}: {e}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| crate::eyre!("compiling {name}: {e}"))?;
            eprintln!(
                "[runtime] compiled {name} ({} in / {} out) in {:.1}s",
                self.manifest.exe(name)?.inputs.len(),
                self.manifest.exe(name)?.outputs.len(),
                t0.elapsed().as_secs_f32()
            );
            self.cache.insert(name.to_string(), exe);
        }
        Ok(self.cache.get(name).unwrap())
    }
}

impl Executor for PjrtExec {
    fn kind(&self) -> ExecutorKind {
        ExecutorKind::Pjrt
    }

    fn set_parallel(&mut self, _policy: ParallelPolicy) {
        // Advisory on xla-rs 0.1.6: the PJRT client exposes no intra-op
        // thread knob, so the policy only lives on the Session mirror
        // (ROADMAP "Session intra-op threads" tracks the real hookup).
    }

    fn prepare(&mut self, name: &str) -> crate::Result<()> {
        self.exe(name).map(|_| ())
    }

    /// Gather inputs from the store by manifest order, execute, untuple,
    /// scatter outputs back by name.
    fn run(&mut self, name: &str, store: &mut Store) -> crate::Result<()> {
        let spec = self.manifest.exe(name)?.clone();
        let args: Vec<&xla::Literal> = spec
            .inputs
            .iter()
            .map(|t| store.get(&t.name))
            .collect::<crate::Result<_>>()?;
        let exe = self.exe(name)?;
        let result = exe
            .execute::<&xla::Literal>(&args)
            .map_err(|e| crate::eyre!("executing {name}: {e}"))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| crate::eyre!("fetching {name} result: {e}"))?;
        let outs = tuple
            .to_tuple()
            .map_err(|e| crate::eyre!("untupling {name} result: {e}"))?;
        if outs.len() != spec.outputs.len() {
            return Err(crate::eyre!(
                "{name}: manifest says {} outputs, HLO returned {}",
                spec.outputs.len(),
                outs.len()
            ));
        }
        for (t, lit) in spec.outputs.iter().zip(outs) {
            store.insert(&t.name, lit);
        }
        Ok(())
    }
}

// ---- host kernels -------------------------------------------------------

/// Executable names the host route implements (the AOT "core" set).
/// Baseline variants (`train_step_srste`, `wanda_masks`, `fig9_*`) stay
/// PJRT-only for now — see ROADMAP §Training follow-ups.
pub const HOST_EXES: [&str; 8] = [
    "init",
    "train_step",
    "train_step_lora",
    "lora_init",
    "eval_step",
    "eval_step_lora",
    "forward",
    "forward_lora",
];

const TRACKED_PREFIXES: [&str; 5] = ["params.", "opt.", "masks.", "lora.", "lora_opt."];

/// The native training/inference executor (module docs).
pub struct HostExec {
    manifest: Manifest,
    policy: ParallelPolicy,
    model: Option<HostTrainModel>,
    /// Store identity + tracked-tensor versions the resident model
    /// reflects.
    store_id: u64,
    synced: HashMap<String, u64>,
    /// Reusable token staging.
    tokens: Vec<i32>,
}

impl HostExec {
    pub fn new(manifest: Manifest) -> Self {
        Self {
            manifest,
            policy: ParallelPolicy::serial(),
            model: None,
            store_id: 0,
            synced: HashMap::new(),
            tokens: Vec::new(),
        }
    }

    fn tracked(name: &str) -> bool {
        TRACKED_PREFIXES.iter().any(|p| name.starts_with(p))
    }

    /// Record the tracked tensors' versions after a sync point.
    fn record_synced(&mut self, store: &Store) {
        self.store_id = store.id();
        self.synced.clear();
        for (name, ver) in store.versions() {
            if Self::tracked(name) {
                self.synced.insert(name.to_string(), ver);
            }
        }
    }

    /// Rebuild the resident model from the store (external state change
    /// or first touch of this store).
    fn rebuild(&mut self, store: &Store) -> crate::Result<()> {
        let model =
            HostTrainModel::from_store(&self.manifest, store, self.policy).map_err(|e| {
                crate::eyre!(
                    "host executor: cannot build model state from the store ({e}); \
                     run the `init` executable (or restore a checkpoint) first"
                )
            })?;
        self.model = Some(model);
        self.record_synced(store);
        Ok(())
    }

    /// Make the resident model agree with the store's tracked tensors.
    fn ensure_synced(&mut self, store: &Store) -> crate::Result<()> {
        if self.model.is_none() || self.store_id != store.id() {
            return self.rebuild(store);
        }
        let mut tracked_now = 0usize;
        let mut dirty = false;
        for (name, ver) in store.versions() {
            if !Self::tracked(name) {
                continue;
            }
            tracked_now += 1;
            if self.synced.get(name) != Some(&ver) {
                dirty = true;
                break;
            }
        }
        if dirty || tracked_now != self.synced.len() {
            return self.rebuild(store);
        }
        if let Some(m) = self.model.as_mut() {
            m.set_policy(self.policy);
        }
        Ok(())
    }

    fn read_tokens(&mut self, store: &Store, rows: usize, cols: usize) -> crate::Result<()> {
        let mut tokens = std::mem::take(&mut self.tokens);
        let r = store.read_i32_into("tokens", &mut tokens);
        self.tokens = tokens;
        r?;
        crate::ensure!(
            self.tokens.len() == rows * cols,
            "tokens holds {} ids, expected {rows}x{cols}",
            self.tokens.len()
        );
        Ok(())
    }

    fn model_mut(&mut self) -> crate::Result<&mut HostTrainModel> {
        self.model
            .as_mut()
            .ok_or_else(|| crate::eyre!("host executor has no model state (run `init` first)"))
    }
}

impl Executor for HostExec {
    fn kind(&self) -> ExecutorKind {
        ExecutorKind::HostKernels
    }

    fn set_parallel(&mut self, policy: ParallelPolicy) {
        self.policy = policy;
        if let Some(m) = self.model.as_mut() {
            m.set_policy(policy);
        }
    }

    fn prepare(&mut self, name: &str) -> crate::Result<()> {
        crate::ensure!(
            HOST_EXES.contains(&name),
            "the host executor does not implement {name:?} (PJRT-only executable; \
             run `make artifacts` to use the compiled route)"
        );
        Ok(())
    }

    fn run(&mut self, name: &str, store: &mut Store) -> crate::Result<()> {
        self.prepare(name)?;
        let c = self.manifest.config.clone();
        let (b, s) = (c.batch_size, c.seq_len);
        match name {
            "init" => {
                let seed = store.read_scalar_i32("seed")? as i64 as u64;
                let mut model = HostTrainModel::init(&self.manifest, seed, self.policy)?;
                model.export_params(store)?;
                model.export_opt(store)?;
                model.export_masks(store)?;
                self.model = Some(model);
                self.record_synced(store);
            }
            "lora_init" => {
                self.ensure_synced(store)?;
                let seed = store.read_scalar_i32("seed")? as i64 as u64;
                let model = self.model_mut()?;
                model.lora_init(seed)?;
                model.export_lora(store)?;
                self.record_synced(store);
            }
            "train_step" | "train_step_lora" => {
                self.ensure_synced(store)?;
                self.read_tokens(store, b, s + 1)?;
                let lora = name == "train_step_lora";
                let model = self.model.as_mut().expect("synced");
                let loss = if lora {
                    model.train_step_lora(&self.tokens)?
                } else {
                    model.train_step(&self.tokens)?
                };
                store.put_f32("loss", &[], &[loss])?;
                model.export_params(store)?;
                model.export_opt(store)?;
                if lora {
                    model.export_lora(store)?;
                }
                self.record_synced(store);
            }
            "eval_step" | "eval_step_lora" => {
                self.ensure_synced(store)?;
                self.read_tokens(store, b, s + 1)?;
                let lora = name == "eval_step_lora";
                let model = self.model.as_mut().expect("synced");
                let loss = model.eval_loss(&self.tokens, lora)?;
                store.put_f32("loss", &[], &[loss])?;
            }
            "forward" | "forward_lora" => {
                self.ensure_synced(store)?;
                self.read_tokens(store, b, s)?;
                let lora = name == "forward_lora";
                let model = self.model.as_mut().expect("synced");
                let logits = model.forward_logits(&self.tokens, b, lora)?;
                store.put_f32("logits", &[b, s, c.vocab_size], &logits.data)?;
            }
            other => unreachable!("prepare() admitted {other}"),
        }
        Ok(())
    }
}
