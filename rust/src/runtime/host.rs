//! Host kernel executor for manifest-described SLoPe transformers.
//!
//! [`HostModel`] implements the semantics of the AOT `forward` /
//! `forward_lora` executables — the pre-LN GPT of
//! `python/compile/model.py` — directly on the crate's CPU kernel engine:
//! every pruned block linear runs as a packed 2:4/N:M SpMM
//! ([`crate::backend::spmm_rowmajor_into`]), adapters ride the Eq.-11
//! fused sequence ([`crate::backend::lora_fused_seq`]), and the dense
//! pieces (embeddings, LM head, unpruned linears) use the blocked GEMMs —
//! all under one [`ParallelPolicy`], so `--threads` governs manifest-backed
//! serving exactly as it governs the raw kernels.
//!
//! The executor is built from a **serving checkpoint** (see
//! [`crate::coordinator::checkpoint::save_model_checkpoint`]): the literal
//! store's `params.*` / `masks.*` / `lora.*` planes, plus — when the
//! format-v2 packed file is present — the pre-compressed
//! [`CompressedNm`] weight planes, which restore without re-running the
//! compress step.  [`crate::serve::AotModel`] selects this executor
//! whenever PJRT compilation is unavailable (the offline xla stub, or a
//! checkpoint directory that carries no HLO files); on a real-XLA build
//! the same checkpoint serves through `Session::run` instead and this
//! module doubles as the cross-implementation parity reference.
//!
//! Numerics follow the python model exactly: layer-norm with ε = 1e-5,
//! max-subtracted causal softmax, and the tanh-approximate GELU
//! (`jax.nn.gelu`'s default).  Forward math is row-independent per
//! sequence, so outputs do not depend on how requests were coalesced into
//! batches — the property the serving parity tests pin.
//!
//! **Autoregressive decode** splits the forward into a prefill and an
//! incremental step over a per-sequence [`KvCache`]: `prefill_into` runs
//! the prompt once and banks every layer's K/V rows; `decode_step_into`
//! then advances a coalesced batch of sequences one token each, attending
//! over the cached planes instead of recomputing the prefix — the same
//! arithmetic term-for-term, so KV-cached generation is bit-identical to
//! full-prefix recompute (pinned in `tests/decode.rs`).  Per token this
//! turns O(len·d²) recompute into O(d²) linears + O(len·d) attention —
//! the per-token hot path where the 2:4 SpMM speedup compounds.
//!
//! [`write_synthetic_artifact`] fabricates a self-contained artifact
//! directory (manifest + checkpoint) from a seed — the fixture the serve
//! tests and `benches/bench_serve.rs` use to exercise manifest-backed
//! serving without `make artifacts`.

use crate::backend::gemm::dot;
use crate::backend::{ensure_out, gemm_nt_acc_into, gemm_nt_into, lora_fused_seq,
                     lora_fused_seq_pre, spmm_prepacked_into, spmm_rowmajor_into,
                     ParallelPolicy, SpmmAlgo};
use crate::coordinator::checkpoint;
use crate::runtime::kvpool::{KvBlockPool, KvCache, KvLayerView, KvPoolConfig};
use crate::runtime::{Manifest, Store};
use crate::sparsity::{prepack_enabled, random_row_mask, CompressedNm, Mask, NmScheme,
                      PrepackedNm};
use crate::tensor::Matrix;
use crate::util::Rng;
use std::collections::HashMap;
use std::path::Path;

/// One block linear: a dense or packed-sparse weight, its bias, and an
/// optional low-rank adapter pair.
struct HostLinear {
    w: HostWeight,
    bias: Vec<f32>,
    /// `(up: (d_out, r), down: (r, d_in))` — Eq.-11 adapter factors.
    lora: Option<(Matrix, Matrix)>,
    /// Rank staging for the adapter path (grown once).
    t: Matrix,
}

enum HostWeight {
    Dense(Matrix),
    /// Packed N:M plane, plus the fused prepacked stream built once at
    /// model open/ingest (`None` under `SLOPE_PREPACK=off`).  Forward
    /// streams `pre` when present — bit-identical to the compressed
    /// path at the same SIMD level, so the toggle never changes output.
    Sparse { c: CompressedNm, pre: Option<PrepackedNm> },
}

/// Wrap a packed plane as a host weight, prepacking the fused stream
/// unless `SLOPE_PREPACK=off` disabled it.
fn sparse_weight(c: CompressedNm) -> HostWeight {
    let pre = prepack_enabled().then(|| PrepackedNm::prepack(&c));
    HostWeight::Sparse { c, pre }
}

impl HostLinear {
    fn d_out(&self) -> usize {
        match &self.w {
            HostWeight::Dense(m) => m.rows,
            HostWeight::Sparse { c, .. } => c.rows,
        }
    }

    /// `y = x · Wᵀ + x · Rᵀ · Lᵀ + b` through the kernel engine.
    fn forward_into(&mut self, x: &Matrix, y: &mut Matrix, policy: &ParallelPolicy) {
        ensure_out(y, x.rows, self.d_out());
        match (&self.w, &self.lora) {
            (HostWeight::Sparse { pre: Some(p), .. }, Some((up, down))) => {
                lora_fused_seq_pre(policy, p, x, up, down, &mut self.t, y);
            }
            (HostWeight::Sparse { c, pre: None }, Some((up, down))) => {
                lora_fused_seq(SpmmAlgo::RowMajor, policy, c, x, up, down, &mut self.t, y);
            }
            (HostWeight::Sparse { pre: Some(p), .. }, None) => {
                spmm_prepacked_into(x, p, y, policy)
            }
            (HostWeight::Sparse { c, pre: None }, None) => spmm_rowmajor_into(x, c, y, policy),
            (HostWeight::Dense(w), lora) => {
                gemm_nt_into(x, w, y, policy);
                if let Some((up, down)) = lora {
                    ensure_out(&mut self.t, x.rows, down.rows);
                    gemm_nt_into(x, down, &mut self.t, policy);
                    gemm_nt_acc_into(&self.t, up, y, policy);
                }
            }
        }
        for r in 0..y.rows {
            for (v, b) in y.row_mut(r).iter_mut().zip(&self.bias) {
                *v += *b;
            }
        }
    }
}

/// One transformer block's host-side state.
struct HostBlock {
    ln1_g: Vec<f32>,
    ln1_b: Vec<f32>,
    ln2_g: Vec<f32>,
    ln2_b: Vec<f32>,
    qkv: HostLinear,
    proj: HostLinear,
    up: HostLinear,
    down: HostLinear,
}

/// Reusable activation buffers — grown at the first batch of a given
/// fill, reused thereafter (zero steady-state allocations).
#[derive(Default)]
struct HostWs {
    /// Residual stream, `(k·S, d)`.
    h: Matrix,
    /// Layer-norm output staging.
    hn: Matrix,
    /// Fused QKV projection, `(k·S, 3d)`.
    qkv: Matrix,
    /// Attention output, `(k·S, d)`.
    att: Matrix,
    /// MLP upsample, `(k·S, d_ff)`.
    up: Matrix,
    /// Residual-branch staging (`proj` / `down` outputs), `(k·S, d)`.
    branch: Matrix,
    /// One query row's attention scores (`S` long).
    scores: Vec<f32>,
    /// Dequantization staging for one cached head-slice (`head_dim`
    /// long) — unused on the zero-copy f32 read path.
    kv_scratch: Vec<f32>,
    /// Last-position hidden states, `(k, d)`.
    last: Matrix,
}

/// Checkpoint-backed host executor for one manifest config (module docs).
pub struct HostModel {
    pub n_layer: usize,
    pub n_head: usize,
    pub d_model: usize,
    pub d_ff: usize,
    pub vocab: usize,
    pub seq_len: usize,
    /// Block-weight planes restored directly from packed v2 planes (the
    /// rest were re-compressed from `params.*` × `masks.*`).
    pub packed_restored: usize,
    policy: ParallelPolicy,
    tok_emb: Matrix,
    pos_emb: Matrix,
    lnf_g: Vec<f32>,
    lnf_b: Vec<f32>,
    /// Untied LM head; `None` = tied to `tok_emb` (the default configs).
    head_w: Option<Matrix>,
    blocks: Vec<HostBlock>,
    /// Paged KV arena every [`KvCache`] of this executor draws from
    /// (see [`crate::runtime::kvpool`]).
    kv_pool: KvBlockPool,
    ws: HostWs,
}

impl HostModel {
    /// Build the executor from checkpointed state: the literal store's
    /// `params.*`/`masks.*`/`lora.*` planes, plus any pre-packed
    /// [`CompressedNm`] planes (keyed by the weight's `params.…` name),
    /// which skip re-compression.
    pub fn from_store(manifest: &Manifest, store: &Store,
                      packed: &HashMap<String, CompressedNm>,
                      policy: ParallelPolicy) -> crate::Result<Self> {
        // Default f32 paging: bit-identical to the pre-paging contiguous
        // cache, so every direct-HostModel parity pin is dtype-agnostic.
        Self::from_store_with_kv(manifest, store, packed, policy, KvPoolConfig::default())
    }

    /// [`HostModel::from_store`] with an explicit KV-pool configuration
    /// (block size, storage dtype, optional block bound) — the seam
    /// `AotModel::open_with_kv` and the paged-decode suites use.
    pub fn from_store_with_kv(manifest: &Manifest, store: &Store,
                              packed: &HashMap<String, CompressedNm>,
                              policy: ParallelPolicy,
                              kv: KvPoolConfig) -> crate::Result<Self> {
        let c = &manifest.config;
        crate::ensure!(kv.block_tokens > 0, "kv block size must be positive");
        let tok_emb = store.read_matrix("params.tok_emb")?;
        crate::ensure!(
            tok_emb.rows == c.vocab_size && tok_emb.cols == c.d_model,
            "tok_emb is {}x{}, manifest says {}x{}",
            tok_emb.rows, tok_emb.cols, c.vocab_size, c.d_model
        );
        let pos_emb = store.read_matrix("params.pos_emb")?;
        crate::ensure!(
            pos_emb.rows >= c.seq_len && pos_emb.cols == c.d_model,
            "pos_emb ({}x{}) too short for seq_len {}",
            pos_emb.rows, pos_emb.cols, c.seq_len
        );
        let lnf_g = store.read_f32("params.lnf_g")?;
        let lnf_b = store.read_f32("params.lnf_b")?;
        let head_w = if store.contains("params.head_w") {
            Some(store.read_matrix("params.head_w")?)
        } else {
            None
        };
        let mut packed_restored = 0usize;
        let mut blocks = Vec::with_capacity(c.n_layer);
        for layer in 0..c.n_layer {
            let ln = |suffix: &str| store.read_f32(&format!("params.blocks.{layer}.{suffix}"));
            blocks.push(HostBlock {
                ln1_g: ln("ln1_g")?,
                ln1_b: ln("ln1_b")?,
                ln2_g: ln("ln2_g")?,
                ln2_b: ln("ln2_b")?,
                qkv: build_linear(manifest, store, packed, layer, "wqkv", "bqkv",
                                  &mut packed_restored)?,
                proj: build_linear(manifest, store, packed, layer, "wproj", "bproj",
                                   &mut packed_restored)?,
                up: build_linear(manifest, store, packed, layer, "wup", "bup",
                                 &mut packed_restored)?,
                down: build_linear(manifest, store, packed, layer, "wdown", "bdown",
                                   &mut packed_restored)?,
            });
        }
        Ok(Self {
            n_layer: c.n_layer,
            n_head: c.n_head,
            d_model: c.d_model,
            d_ff: c.d_ff,
            vocab: c.vocab_size,
            seq_len: c.seq_len,
            packed_restored,
            policy,
            tok_emb,
            pos_emb,
            lnf_g,
            lnf_b,
            head_w,
            blocks,
            kv_pool: KvBlockPool::new(c.n_layer, c.d_model, kv),
            ws: HostWs::default(),
        })
    }

    /// Next-token logits for `k` coalesced sequences: `tokens` holds
    /// `k × seq_len` ids row-major; writes the last position's logits —
    /// `(k, vocab)` — into `y`.  Steady state reuses every internal
    /// buffer, so repeat calls at a stable fill allocate nothing.
    pub fn forward_last_logits_into(&mut self, tokens: &[i32], k: usize,
                                    y: &mut Matrix) -> crate::Result<()> {
        self.forward_prefix(tokens, k, self.seq_len, None, y)
    }

    /// Full-recompute last-position logits for ONE sequence of arbitrary
    /// length `1..=seq_len` — the reference the KV-cached incremental
    /// path is pinned against (and the semantics a naive decode loop
    /// would recompute per token).
    pub fn forward_prefix_logits_into(&mut self, tokens: &[i32],
                                      y: &mut Matrix) -> crate::Result<()> {
        self.forward_prefix(tokens, 1, tokens.len(), None, y)
    }

    /// A fresh per-sequence [`KvCache`] view over this model's shared
    /// block pool, bounded at the context length (`seq_len` — the S of
    /// the manifest's `forward_tokens_shape`).  The cache holds no
    /// blocks until prefill reserves them.
    pub fn new_kv_cache(&self) -> KvCache {
        self.kv_pool.new_cache(self.seq_len)
    }

    /// The shared paged KV arena (occupancy stats, block shape).
    pub fn kv_pool(&self) -> &KvBlockPool {
        &self.kv_pool
    }

    /// Prefill: run one prompt (`1..=seq_len` tokens), populate `cache`
    /// with every layer's K/V rows for positions `0..len`, and write the
    /// last position's logits (`1 × vocab`) into `y`.  The cache is
    /// reset first, so re-prefilling a recycled cache is fine.  The
    /// stored planes are exactly the values the full forward computes,
    /// so subsequent [`HostModel::decode_step_into`] calls reproduce the
    /// full-recompute logits bit-for-bit.
    pub fn prefill_into(&mut self, tokens: &[i32], cache: &mut KvCache,
                        y: &mut Matrix) -> crate::Result<()> {
        self.prefill_into_saved(tokens, cache, y).map(|_| ())
    }

    /// [`HostModel::prefill_into`] with the pool's prefix cache
    /// consulted first: the longest cached whole-block chain matching
    /// the prompt (minus its last token — the logits position always
    /// computes) is attached by reference, only the un-matched suffix
    /// is embedded, projected, and attended (each suffix row through
    /// [`decode_attention_row`], the same term-for-term mirror of
    /// [`causal_attention_into`] the decode path is pinned on, over
    /// direct f32 arena slices), and the prompt's whole-block prefix is
    /// published back to the cache.  Cache-hit prefill is therefore
    /// bit-identical to a cache-miss (and cache-disabled) prefill.
    /// Returns the prompt positions served from the cache instead of
    /// recomputed (0 when the cache is disabled or missed).  On error
    /// the cache ends empty — shared references released, nothing
    /// stranded.
    pub fn prefill_into_saved(&mut self, tokens: &[i32], cache: &mut KvCache,
                              y: &mut Matrix) -> crate::Result<usize> {
        cache.reset();
        let mut matched = 0;
        if self.kv_pool.prefix_enabled() && tokens.len() > 1 {
            matched = cache.attach_prefix(&tokens[..tokens.len() - 1]);
        }
        let res = if matched == 0 {
            self.forward_prefix(tokens, 1, tokens.len(),
                                Some(std::slice::from_mut(cache)), y)
        } else {
            self.forward_suffix(tokens, matched, cache, y)
        };
        if let Err(e) = res {
            cache.reset();
            return Err(e);
        }
        if self.kv_pool.prefix_enabled() {
            cache.publish_prefix(tokens);
        }
        Ok(matched)
    }

    /// One incremental decode step for a coalesced batch of sequences:
    /// sequence `i` consumes `tokens[i]` at its cache's next position,
    /// attends over the cached K/V planes (plus the freshly appended
    /// row) instead of recomputing the prefix, and gets its next-token
    /// logits in row `i` of `y` (`k × vocab`).  Every non-attention op
    /// (layer norm, SpMM/GEMM linears, fused LoRA, GELU) is row-per-row
    /// identical to the full forward, and the attention loop mirrors
    /// [`causal_attention_into`] term-for-term, so the step is
    /// bit-identical to a full-prefix recompute.  All validation happens
    /// before any cache is touched: on error the caches are unchanged.
    ///
    /// Cost per token is O(d²) for the linears plus O(len·d) for
    /// attention — flat in generated-token count for the model sizes the
    /// bench sweeps — where the recompute path pays O(len·d²).
    pub fn decode_step_into(&mut self, tokens: &[i32], caches: &mut [KvCache],
                            y: &mut Matrix) -> crate::Result<()> {
        let kb = tokens.len();
        crate::ensure!(kb > 0, "empty decode batch");
        crate::ensure!(caches.len() == kb, "{} caches for {kb} tokens", caches.len());
        let (d, n_head, vocab) = (self.d_model, self.n_head, self.vocab);
        for (i, c) in caches.iter().enumerate() {
            c.check(self.n_layer, d)?;
            crate::ensure!(!c.is_empty(), "sequence {i}: decode_step before prefill");
            crate::ensure!(
                c.len() < c.capacity(),
                "sequence {i}: context window full ({} tokens)",
                c.capacity()
            );
        }
        for &tok in tokens {
            crate::ensure!(
                tok >= 0 && (tok as usize) < vocab,
                "token id {tok} outside vocab 0..{vocab}"
            );
        }
        // Reserve the appended position's block up front, all caches or
        // none: on pool exhaustion, spare blocks the earlier caches
        // acquired are returned and every cache is left untouched.  The
        // write position is un-shared here too (copy-on-write against a
        // prefix-cached block, e.g. after a truncate back into the
        // shared region), so the per-layer hot loop never allocates.
        for i in 0..caches.len() {
            let pos = caches[i].len();
            let res = match caches[i].reserve(pos + 1) {
                Ok(()) => caches[i].ensure_writable(pos),
                Err(e) => Err(e),
            };
            if let Err(e) = res {
                for c in caches.iter_mut() {
                    c.release_spare();
                }
                return Err(e);
            }
        }
        let policy = self.policy;
        let Self { ws, blocks, tok_emb, pos_emb, lnf_g, lnf_b, head_w, .. } = self;

        // Embedding: h[i] = tok_emb[token_i] + pos_emb[position_i].
        ensure_out(&mut ws.h, kb, d);
        for (i, cache) in caches.iter().enumerate() {
            let dst = ws.h.row_mut(i);
            let te = tok_emb.row(tokens[i] as usize);
            let pe = pos_emb.row(cache.len());
            for j in 0..d {
                dst[j] = te[j] + pe[j];
            }
        }

        for (li, blk) in blocks.iter_mut().enumerate() {
            // Attention sub-block: ln1 → qkv → cached attention → proj.
            layer_norm_into(&ws.h, &blk.ln1_g, &blk.ln1_b, &mut ws.hn);
            blk.qkv.forward_into(&ws.hn, &mut ws.qkv, &policy);
            ensure_out(&mut ws.att, kb, d);
            for (i, cache) in caches.iter_mut().enumerate() {
                let pos = cache.len();
                let row = ws.qkv.row(i);
                cache.write_row(li, pos, &row[d..2 * d], &row[2 * d..3 * d])?;
                cache.with_layer(li, |view| {
                    decode_attention_row(&view, &row[..d], pos, n_head,
                                         &mut ws.scores, &mut ws.kv_scratch,
                                         ws.att.row_mut(i));
                });
            }
            blk.proj.forward_into(&ws.att, &mut ws.branch, &policy);
            add_inplace(&mut ws.h, &ws.branch);
            // MLP sub-block: ln2 → up → gelu → down.
            layer_norm_into(&ws.h, &blk.ln2_g, &blk.ln2_b, &mut ws.hn);
            blk.up.forward_into(&ws.hn, &mut ws.up, &policy);
            gelu_tanh_inplace(&mut ws.up);
            blk.down.forward_into(&ws.up, &mut ws.branch, &policy);
            add_inplace(&mut ws.h, &ws.branch);
        }

        layer_norm_into(&ws.h, lnf_g, lnf_b, &mut ws.hn);
        let head: &Matrix = match &*head_w {
            Some(hw) => hw,
            None => &*tok_emb,
        };
        ensure_out(y, kb, vocab);
        gemm_nt_into(&ws.hn, head, y, &policy);
        for c in caches.iter_mut() {
            c.advance();
        }
        Ok(())
    }

    /// The shared forward core: `k` sequences of `s` tokens each
    /// (`1 <= s <= seq_len`), last-position logits into `y`
    /// (`k × vocab`).  With `caches`, every layer's K/V activations for
    /// positions `0..s` are written into the per-sequence cache — the
    /// prefill route.  Steady state reuses every internal buffer, so
    /// repeat calls at a stable `(k, s)` allocate nothing.
    fn forward_prefix(&mut self, tokens: &[i32], k: usize, s: usize,
                      mut caches: Option<&mut [KvCache]>,
                      y: &mut Matrix) -> crate::Result<()> {
        crate::ensure!(k > 0, "empty batch");
        crate::ensure!(
            s >= 1 && s <= self.seq_len,
            "prefix length {s} outside 1..={}",
            self.seq_len
        );
        crate::ensure!(
            tokens.len() == k * s,
            "expected {k}×{s} tokens, got {}",
            tokens.len()
        );
        if let Some(cs) = caches.as_deref_mut() {
            crate::ensure!(cs.len() == k, "{} caches for a batch of {k}", cs.len());
            for c in cs.iter() {
                c.check(self.n_layer, self.d_model)?;
                crate::ensure!(
                    c.capacity() >= s,
                    "cache capacity {} below prefix length {s}",
                    c.capacity()
                );
            }
        }
        let d = self.d_model;
        let rows = k * s;
        let (n_head, vocab) = (self.n_head, self.vocab);
        let policy = self.policy;
        let Self { ws, blocks, tok_emb, pos_emb, lnf_g, lnf_b, head_w, .. } = self;

        // Embedding: h[b·s + t] = tok_emb[token] + pos_emb[t].
        ensure_out(&mut ws.h, rows, d);
        for b in 0..k {
            for t in 0..s {
                let tok = tokens[b * s + t];
                crate::ensure!(
                    tok >= 0 && (tok as usize) < vocab,
                    "token id {tok} outside vocab 0..{vocab}"
                );
                let dst = ws.h.row_mut(b * s + t);
                let te = tok_emb.row(tok as usize);
                let pe = pos_emb.row(t);
                for j in 0..d {
                    dst[j] = te[j] + pe[j];
                }
            }
        }

        // Last fallible step: reserve pool blocks for the prefix, all
        // caches or none (spares roll back on exhaustion, so an errored
        // prefill leaves every cache unchanged).
        if let Some(cs) = caches.as_deref_mut() {
            for i in 0..cs.len() {
                if let Err(e) = cs[i].reserve(s) {
                    for c in cs.iter_mut() {
                        c.release_spare();
                    }
                    return Err(e);
                }
            }
        }

        for (li, blk) in blocks.iter_mut().enumerate() {
            // Attention sub-block: ln1 → qkv → causal attention → proj.
            layer_norm_into(&ws.h, &blk.ln1_g, &blk.ln1_b, &mut ws.hn);
            blk.qkv.forward_into(&ws.hn, &mut ws.qkv, &policy);
            if let Some(cs) = caches.as_deref_mut() {
                // Prefill: bank this layer's K/V rows for every position.
                for (b, cache) in cs.iter_mut().enumerate() {
                    for t in 0..s {
                        let row = ws.qkv.row(b * s + t);
                        cache.write_row(li, t, &row[d..2 * d], &row[2 * d..3 * d])?;
                    }
                }
            }
            causal_attention_into(&ws.qkv, k, s, d, n_head, &mut ws.scores, &mut ws.att);
            blk.proj.forward_into(&ws.att, &mut ws.branch, &policy);
            add_inplace(&mut ws.h, &ws.branch);
            // MLP sub-block: ln2 → up → gelu → down.
            layer_norm_into(&ws.h, &blk.ln2_g, &blk.ln2_b, &mut ws.hn);
            blk.up.forward_into(&ws.hn, &mut ws.up, &policy);
            gelu_tanh_inplace(&mut ws.up);
            blk.down.forward_into(&ws.up, &mut ws.branch, &policy);
            add_inplace(&mut ws.h, &ws.branch);
        }

        layer_norm_into(&ws.h, lnf_g, lnf_b, &mut ws.hn);
        ensure_out(&mut ws.last, k, d);
        for b in 0..k {
            let src_row = b * s + (s - 1);
            ws.last.row_mut(b).copy_from_slice(ws.hn.row(src_row));
        }
        let head: &Matrix = match &*head_w {
            Some(hw) => hw,
            None => &*tok_emb,
        };
        ensure_out(y, k, vocab);
        gemm_nt_into(&ws.last, head, y, &policy);
        if let Some(cs) = caches {
            for c in cs.iter_mut() {
                c.set_len(s);
            }
        }
        Ok(())
    }

    /// The cache-hit half of split prefill: positions `0..p` already
    /// sit in `cache` (a shared, `block_tokens`-aligned prefix chain);
    /// only rows `p..s` are embedded, projected, and banked, and each
    /// suffix row attends over prefix + suffix through the cache view.
    /// Every op is row-independent and the attention mirrors
    /// [`causal_attention_into`] term-for-term, so logits and banked
    /// rows are bit-identical to a full prefill of the same prompt.
    fn forward_suffix(&mut self, tokens: &[i32], p: usize, cache: &mut KvCache,
                      y: &mut Matrix) -> crate::Result<()> {
        let s = tokens.len();
        crate::ensure!(
            s >= 1 && s <= self.seq_len,
            "prefix length {s} outside 1..={}",
            self.seq_len
        );
        debug_assert!(p > 0 && p < s && p % cache.block_tokens() == 0,
                      "suffix split at {p} of {s}");
        cache.check(self.n_layer, self.d_model)?;
        crate::ensure!(
            cache.capacity() >= s,
            "cache capacity {} below prefix length {s}",
            cache.capacity()
        );
        let d = self.d_model;
        let q = s - p;
        let (n_head, vocab) = (self.n_head, self.vocab);
        let policy = self.policy;
        let Self { ws, blocks, tok_emb, pos_emb, lnf_g, lnf_b, head_w, .. } = self;

        // Embedding for the suffix rows only: h[i] = tok_emb + pos_emb
        // at absolute position p + i.
        ensure_out(&mut ws.h, q, d);
        for (i, t) in (p..s).enumerate() {
            let tok = tokens[t];
            crate::ensure!(
                tok >= 0 && (tok as usize) < vocab,
                "token id {tok} outside vocab 0..{vocab}"
            );
            let dst = ws.h.row_mut(i);
            let te = tok_emb.row(tok as usize);
            let pe = pos_emb.row(t);
            for j in 0..d {
                dst[j] = te[j] + pe[j];
            }
        }

        // Reserve the suffix blocks (the attached prefix blocks are
        // already in the table); spares roll back on exhaustion and the
        // caller resets the cache, releasing the shared prefix too.
        if let Err(e) = cache.reserve(s) {
            cache.release_spare();
            return Err(e);
        }

        for (li, blk) in blocks.iter_mut().enumerate() {
            // Attention sub-block: ln1 → qkv → bank → cached attention
            // → proj.  All suffix K/V rows are banked before any suffix
            // row attends: row p+i only reads positions 0..=p+i, so the
            // order is invisible in the result.
            layer_norm_into(&ws.h, &blk.ln1_g, &blk.ln1_b, &mut ws.hn);
            blk.qkv.forward_into(&ws.hn, &mut ws.qkv, &policy);
            for i in 0..q {
                let row = ws.qkv.row(i);
                cache.write_row(li, p + i, &row[d..2 * d], &row[2 * d..3 * d])?;
            }
            ensure_out(&mut ws.att, q, d);
            for i in 0..q {
                let row = ws.qkv.row(i);
                cache.with_layer(li, |view| {
                    decode_attention_row(&view, &row[..d], p + i, n_head,
                                         &mut ws.scores, &mut ws.kv_scratch,
                                         ws.att.row_mut(i));
                });
            }
            blk.proj.forward_into(&ws.att, &mut ws.branch, &policy);
            add_inplace(&mut ws.h, &ws.branch);
            // MLP sub-block: ln2 → up → gelu → down.
            layer_norm_into(&ws.h, &blk.ln2_g, &blk.ln2_b, &mut ws.hn);
            blk.up.forward_into(&ws.hn, &mut ws.up, &policy);
            gelu_tanh_inplace(&mut ws.up);
            blk.down.forward_into(&ws.up, &mut ws.branch, &policy);
            add_inplace(&mut ws.h, &ws.branch);
        }

        layer_norm_into(&ws.h, lnf_g, lnf_b, &mut ws.hn);
        ensure_out(&mut ws.last, 1, d);
        ws.last.row_mut(0).copy_from_slice(ws.hn.row(q - 1));
        let head: &Matrix = match &*head_w {
            Some(hw) => hw,
            None => &*tok_emb,
        };
        ensure_out(y, 1, vocab);
        gemm_nt_into(&ws.last, head, y, &policy);
        cache.set_len(s);
        Ok(())
    }

    /// The policy every kernel call of this executor runs under.
    pub fn policy(&self) -> ParallelPolicy {
        self.policy
    }
}

/// One query row's attention over a sequence's cached K/V planes (rows
/// `0..=pos`, the appended current position included) — the incremental
/// counterpart of [`causal_attention_into`], mirroring its max-subtracted
/// softmax term-for-term.  The planes are read through a paged
/// [`KvLayerView`]: f32 storage hands back direct arena slices (same
/// bits, same `dot` reduction order — so the paged decode path stays
/// bit-identical to the full recompute), f16/int8 dequantize each
/// head-slice into `scratch` first.  `q` is the `d`-wide fused-QKV query
/// slice; `out` the `d`-wide attention output row.
fn decode_attention_row(view: &KvLayerView<'_>, q: &[f32], pos: usize, n_head: usize,
                        scores: &mut Vec<f32>, scratch: &mut Vec<f32>,
                        out: &mut [f32]) {
    let d = q.len();
    let hd = d / n_head;
    let scale = 1.0 / (hd as f32).sqrt();
    if scores.len() < pos + 1 {
        scores.resize(pos + 1, 0.0);
    }
    if scratch.len() < hd {
        scratch.resize(hd, 0.0);
    }
    for h in 0..n_head {
        let off = h * hd;
        let qrow = &q[off..off + hd];
        let mut maxv = f32::NEG_INFINITY;
        for t in 0..=pos {
            let krow = view.k_row(t, off, hd, scratch);
            let sc = dot(qrow, krow, hd) * scale;
            scores[t] = sc;
            if sc > maxv {
                maxv = sc;
            }
        }
        let mut denom = 0.0f32;
        for sc in scores.iter_mut().take(pos + 1) {
            let e = (*sc - maxv).exp();
            *sc = e;
            denom += e;
        }
        let inv = 1.0 / denom;
        let orow = &mut out[off..off + hd];
        orow.fill(0.0);
        for t in 0..=pos {
            let wgt = scores[t] * inv;
            let vrow = view.v_row(t, off, hd, scratch);
            for j in 0..hd {
                orow[j] += wgt * vrow[j];
            }
        }
    }
}

/// Assemble one block linear from the store (+ optional packed plane).
fn build_linear(manifest: &Manifest, store: &Store, packed: &HashMap<String, CompressedNm>,
                layer: usize, wname: &str, bname: &str,
                packed_restored: &mut usize) -> crate::Result<HostLinear> {
    let pname = format!("params.blocks.{layer}.{wname}");
    let bias = store.read_f32(&format!("params.blocks.{layer}.{bname}"))?;
    let down_name = format!("lora.blocks.{layer}.{wname}_down");
    let up_name = format!("lora.blocks.{layer}.{wname}_up");
    let lora = if store.contains(&down_name) && store.contains(&up_name) {
        Some((store.read_matrix(&up_name)?, store.read_matrix(&down_name)?))
    } else {
        None
    };
    let w = if let Some(c) = packed.get(&pname) {
        *packed_restored += 1;
        sparse_weight(c.clone())
    } else if let Some(c) =
        checkpoint::packed_plane_from_store(store, manifest, layer, wname)?
    {
        // No pre-packed plane shipped (pre-packing checkpoint): compress
        // through the same rule the checkpoint writer uses.
        sparse_weight(c)
    } else {
        // Dense route — unpruned weight, or a non-N:M (dynamic-baseline)
        // mask.  Python's forward always multiplies by mask_r (ones
        // included), so apply any stored mask.
        let dense = store.read_matrix(&pname)?;
        let mname = format!("masks.blocks.{layer}.{wname}_r");
        if store.contains(&mname) {
            let mm = store.read_matrix(&mname)?;
            crate::ensure!(
                (mm.rows, mm.cols) == (dense.rows, dense.cols),
                "mask {mname} shape mismatch"
            );
            HostWeight::Dense(dense.hadamard(&mm))
        } else {
            HostWeight::Dense(dense)
        }
    };
    if let Some((up, down)) = &lora {
        let d_out = match &w {
            HostWeight::Dense(m) => m.rows,
            HostWeight::Sparse { c, .. } => c.rows,
        };
        let d_in = match &w {
            HostWeight::Dense(m) => m.cols,
            HostWeight::Sparse { c, .. } => c.cols,
        };
        crate::ensure!(
            up.rows == d_out && down.cols == d_in && up.cols == down.rows,
            "lora factors for {pname} do not fit ({}x{} / {}x{} vs {d_out}x{d_in})",
            up.rows, up.cols, down.rows, down.cols
        );
    }
    crate::ensure!(
        bias.len() == match &w {
            HostWeight::Dense(m) => m.rows,
            HostWeight::Sparse { c, .. } => c.rows,
        },
        "bias length mismatch for {pname}"
    );
    Ok(HostLinear { w, bias, lora, t: Matrix::zeros(0, 0) })
}

/// Row-wise layer norm (ε = 1e-5, matching python `layers.layer_norm`).
/// Shared with the host *training* executor ([`crate::runtime::host_train`]),
/// whose backward mirrors this definition term-for-term.
pub(crate) fn layer_norm_into(x: &Matrix, g: &[f32], b: &[f32], y: &mut Matrix) {
    ensure_out(y, x.rows, x.cols);
    let n = x.cols as f32;
    for r in 0..x.rows {
        let xr = x.row(r);
        let mut mu = 0.0f32;
        for v in xr {
            mu += *v;
        }
        mu /= n;
        let mut var = 0.0f32;
        for v in xr {
            let dv = *v - mu;
            var += dv * dv;
        }
        var /= n;
        let inv = 1.0 / (var + 1e-5).sqrt();
        let yr = y.row_mut(r);
        for j in 0..xr.len() {
            yr[j] = (xr[j] - mu) * inv * g[j] + b[j];
        }
    }
}

/// Standard causal multi-head attention over a fused-QKV activation:
/// `qkv` rows are `[q | k | v]` (`3d` wide); writes `(k·S, d)` into
/// `out`.  One query row at a time with max-subtracted softmax — the
/// same math as python `layers.causal_attention`.  Shared with the host
/// training executor, whose attention backward recomputes these softmax
/// rows from the forward tape.
pub(crate) fn causal_attention_into(qkv: &Matrix, batch: usize, s: usize, d: usize,
                                    n_head: usize, scores: &mut Vec<f32>,
                                    out: &mut Matrix) {
    ensure_out(out, batch * s, d);
    let hd = d / n_head;
    let scale = 1.0 / (hd as f32).sqrt();
    if scores.len() < s {
        scores.resize(s, 0.0);
    }
    for b in 0..batch {
        for h in 0..n_head {
            let qo = h * hd;
            let ko = d + h * hd;
            let vo = 2 * d + h * hd;
            for q in 0..s {
                let qrow = &qkv.row(b * s + q)[qo..qo + hd];
                let mut maxv = f32::NEG_INFINITY;
                for t in 0..=q {
                    let krow = &qkv.row(b * s + t)[ko..ko + hd];
                    let sc = dot(qrow, krow, hd) * scale;
                    scores[t] = sc;
                    if sc > maxv {
                        maxv = sc;
                    }
                }
                let mut denom = 0.0f32;
                for sc in scores.iter_mut().take(q + 1) {
                    let e = (*sc - maxv).exp();
                    *sc = e;
                    denom += e;
                }
                let inv = 1.0 / denom;
                let orow = &mut out.row_mut(b * s + q)[qo..qo + hd];
                orow.fill(0.0);
                for t in 0..=q {
                    let wgt = scores[t] * inv;
                    let vrow = &qkv.row(b * s + t)[vo..vo + hd];
                    for j in 0..hd {
                        orow[j] += wgt * vrow[j];
                    }
                }
            }
        }
    }
}

/// Element-wise residual add.
pub(crate) fn add_inplace(acc: &mut Matrix, rhs: &Matrix) {
    debug_assert_eq!((acc.rows, acc.cols), (rhs.rows, rhs.cols));
    for (a, r) in acc.data.iter_mut().zip(&rhs.data) {
        *a += *r;
    }
}

/// Tanh-approximate GELU — `jax.nn.gelu`'s default, which is what the
/// AOT executables compute.
pub(crate) fn gelu_tanh_inplace(m: &mut Matrix) {
    for v in m.data.iter_mut() {
        *v = gelu_tanh(*v);
    }
}

pub(crate) const GELU_SQRT_2_OVER_PI: f32 = 0.797_884_56;
pub(crate) const GELU_CUBIC: f32 = 0.044_715;

#[inline]
pub(crate) fn gelu_tanh(x: f32) -> f32 {
    let inner = GELU_SQRT_2_OVER_PI * (x + GELU_CUBIC * x * x * x);
    0.5 * x * (1.0 + inner.tanh())
}

/// d/dx of [`gelu_tanh`] — the training executor's GELU backward.
#[inline]
pub(crate) fn gelu_tanh_grad(x: f32) -> f32 {
    let u = GELU_SQRT_2_OVER_PI * (x + GELU_CUBIC * x * x * x);
    let t = u.tanh();
    let sech2 = 1.0 - t * t;
    0.5 * (1.0 + t) + 0.5 * x * sech2 * GELU_SQRT_2_OVER_PI * (1.0 + 3.0 * GELU_CUBIC * x * x)
}

// ---- synthetic artifact fixture ---------------------------------------

/// Shape of a synthetic (host-servable) artifact (see
/// [`write_synthetic_artifact`]).
#[derive(Clone, Debug)]
pub struct SynthSpec {
    pub name: String,
    pub vocab: usize,
    pub n_layer: usize,
    pub n_head: usize,
    pub d_model: usize,
    pub d_ff: usize,
    pub seq_len: usize,
    pub batch_size: usize,
    /// Adapter rank (0 disables the `lora.*` planes).
    pub rank: usize,
    pub seed: u64,
}

impl Default for SynthSpec {
    fn default() -> Self {
        Self {
            name: "synth-nano".into(),
            vocab: 96,
            n_layer: 2,
            n_head: 2,
            d_model: 32,
            d_ff: 64,
            seq_len: 12,
            batch_size: 16,
            rank: 4,
            seed: 0x51,
        }
    }
}

/// One `{"name", "shape", "dtype"}` manifest tensor spec.
fn tensor_spec_json(name: &str, shape: &[usize], dtype: &str) -> String {
    let dims: Vec<String> = shape.iter().map(|d| d.to_string()).collect();
    format!(r#"{{"name": "{name}", "shape": [{}], "dtype": "{dtype}"}}"#, dims.join(", "))
}

/// One manifest executable entry (`"file"` points at HLO that the
/// fixture never ships — that absence is what routes the session to the
/// host executor).
fn exe_entry_json(name: &str, inputs: &[String], outputs: &[String]) -> String {
    format!(
        "\"{name}\": {{\n   \"file\": \"{name}.hlo.txt\",\n   \"inputs\": [{}],\n   \"outputs\": [{}]\n  }}",
        inputs.join(",\n    "),
        outputs.join(",\n    ")
    )
}

/// The AOT "core" executable set (`python/compile/aot.py`) with full
/// input/output tensor specs — the schema the host executor implements
/// natively, so a fabricated manifest drives the exact store contract a
/// lowered one would.
fn core_executables_json(c: &crate::runtime::manifest::ModelConfig) -> String {
    use crate::runtime::host_train::{lora_leaves, mask_leaves, param_leaves};
    let prefixed = |prefix: &str, leaves: &[(String, Vec<usize>)]| -> Vec<String> {
        leaves
            .iter()
            .map(|(s, shape)| tensor_spec_json(&format!("{prefix}.{s}"), shape, "float32"))
            .collect()
    };
    let opt_planes = |prefix: &str, leaves: &[(String, Vec<usize>)]| -> Vec<String> {
        let mut out = Vec::new();
        for plane in ["m", "v"] {
            for (s, shape) in leaves {
                out.push(tensor_spec_json(&format!("{prefix}.{plane}.{s}"), shape, "float32"));
            }
        }
        out.push(tensor_spec_json(&format!("{prefix}.step"), &[], "float32"));
        out
    };
    let pl = param_leaves(c);
    let params = prefixed("params", &pl);
    let masks = prefixed("masks", &mask_leaves(c));
    let opt = opt_planes("opt", &pl);
    let seed = vec![tensor_spec_json("seed", &[], "int32")];
    let tok_train = vec![tensor_spec_json("tokens", &[c.batch_size, c.seq_len + 1], "int32")];
    let tok_infer = vec![tensor_spec_json("tokens", &[c.batch_size, c.seq_len], "int32")];
    let loss = vec![tensor_spec_json("loss", &[], "float32")];
    let logits =
        vec![tensor_spec_json("logits", &[c.batch_size, c.seq_len, c.vocab_size], "float32")];
    let cat = |lists: &[&Vec<String>]| -> Vec<String> {
        lists.iter().flat_map(|l| l.iter().cloned()).collect()
    };

    let mut entries = vec![
        exe_entry_json("init", &seed, &cat(&[&params, &opt, &masks])),
        exe_entry_json(
            "train_step",
            &cat(&[&tok_train, &params, &opt, &masks]),
            &cat(&[&loss, &params, &opt]),
        ),
        exe_entry_json("eval_step", &cat(&[&tok_train, &params, &masks]), &loss),
        exe_entry_json("forward", &cat(&[&tok_infer, &params, &masks]), &logits),
    ];
    if c.adapter_rank > 0 {
        let ll = lora_leaves(c);
        let lora = prefixed("lora", &ll);
        let lora_opt = opt_planes("lora_opt", &ll);
        entries.push(exe_entry_json("lora_init", &seed, &cat(&[&lora, &lora_opt])));
        entries.push(exe_entry_json(
            "train_step_lora",
            &cat(&[&tok_train, &params, &opt, &masks, &lora, &lora_opt]),
            &cat(&[&loss, &params, &opt, &lora, &lora_opt]),
        ));
        entries.push(exe_entry_json(
            "eval_step_lora",
            &cat(&[&tok_train, &params, &masks, &lora]),
            &loss,
        ));
        entries.push(exe_entry_json(
            "forward_lora",
            &cat(&[&tok_infer, &params, &masks, &lora]),
            &logits,
        ));
    }
    entries.join(",\n  ")
}

/// Write a `manifest.json` for the spec's shape (no HLO, no checkpoint):
/// config + train schedule + sparsity format + the full core executable
/// schema.  Loading the directory routes straight to the host executor.
fn write_manifest_json(dir: &Path, spec: &SynthSpec) -> crate::Result<Manifest> {
    std::fs::create_dir_all(dir)?;
    let (v, l, d, f, s, bsz) =
        (spec.vocab, spec.n_layer, spec.d_model, spec.d_ff, spec.seq_len, spec.batch_size);
    crate::ensure!(d % spec.n_head == 0, "d_model must divide by n_head");
    crate::ensure!(d % 4 == 0 && f % 4 == 0, "synthetic dims must be 2:4 groupable");
    let n_params = v * d + s * d + l * (3 * d * d + d * d + 2 * d * f);
    let cfg = crate::runtime::manifest::ModelConfig {
        name: spec.name.clone(),
        vocab_size: v,
        n_layer: l,
        n_head: spec.n_head,
        d_model: d,
        d_ff: f,
        seq_len: s,
        batch_size: bsz,
        adapter_rank: spec.rank,
        first_half_sparsity: (2, 4),
        second_half_sparsity: (2, 4),
        prune_attn: true,
        prune_mlp: true,
        n_params_dense: n_params,
    };
    let exes = core_executables_json(&cfg);
    let manifest_json = format!(
        r#"{{
  "config": {{
    "name": "{name}", "vocab_size": {v}, "n_layer": {l}, "n_head": {nh},
    "d_model": {d}, "d_ff": {f}, "seq_len": {s}, "batch_size": {bsz},
    "adapter_rank": {rank}, "first_half_sparsity": [2, 4],
    "second_half_sparsity": [2, 4], "prune_attn": true, "prune_mlp": true,
    "n_params_dense": {n_params}
  }},
  "train": {{
    "lr": 0.005, "beta1": 0.9, "beta2": 0.95, "weight_decay": 0.1,
    "grad_clip": 1.0, "warmup_steps": 5, "total_steps": 200,
    "lazy_fraction": 0.01, "srste_decay": 0.0002
  }},
  "sparsity_format": {{
    "layout": "eq7-packed-offsets-v1", "row_byte_aligned": true,
    "offset_bits_first_half": 2, "offset_bits_second_half": 2
  }},
  "executables": {{
  {exes}
  }}
}}
"#,
        name = spec.name,
        nh = spec.n_head,
        rank = spec.rank,
    );
    std::fs::write(dir.join("manifest.json"), manifest_json)?;
    Manifest::load(dir)
}

/// Fabricate a **host-trainable artifact**: manifest only, no weights —
/// the `init` executable creates the state.  This is what `slope train`
/// falls back to on a clean checkout (no `make artifacts`), making the
/// train → checkpoint → serve/generate pipeline self-contained.
pub fn write_host_train_artifact(dir: &Path, model_name: &str) -> crate::Result<()> {
    let spec = SynthSpec { name: model_name.to_string(), ..SynthSpec::default() };
    write_manifest_json(dir, &spec).map(|_| ())
}

/// Fabricate a self-contained artifact directory: a `manifest.json` plus a
/// serving checkpoint (store planes + packed v2 weight planes) for a
/// random model of the given shape — everything
/// [`crate::serve::AotModel`] needs, with no python or XLA involved.
/// Deviations from a trained artifact are deliberate and noted: adapter
/// `up` factors are non-zero (a freshly-initialized LoRA is an exact
/// no-op, which would leave the adapter path untested), and no HLO ships
/// beside the manifest, so sessions on the directory always route to the
/// host executor.  The manifest carries the full core executable schema,
/// so the same fixture drives training tests and benches.
pub fn write_synthetic_artifact(dir: &Path, spec: &SynthSpec) -> crate::Result<()> {
    let manifest = write_manifest_json(dir, spec)?;
    let (v, l, d, f, s, _bsz) =
        (spec.vocab, spec.n_layer, spec.d_model, spec.d_ff, spec.seq_len, spec.batch_size);

    let mut rng = Rng::seed_from_u64(spec.seed);
    let mut store = Store::new();
    let put = |store: &mut Store, name: &str, m: &Matrix| -> crate::Result<()> {
        store.put_f32(name, &[m.rows, m.cols], &m.data)
    };
    put(&mut store, "params.tok_emb", &Matrix::randn(v, d, 0.02, &mut rng))?;
    put(&mut store, "params.pos_emb", &Matrix::randn(s, d, 0.01, &mut rng))?;
    store.put_f32("params.lnf_g", &[d], &vec![1.0; d])?;
    store.put_f32("params.lnf_b", &[d], &vec![0.0; d])?;
    for layer in 0..l {
        for suffix in ["ln1_g", "ln2_g"] {
            store.put_f32(&format!("params.blocks.{layer}.{suffix}"), &[d], &vec![1.0; d])?;
        }
        for suffix in ["ln1_b", "ln2_b"] {
            store.put_f32(&format!("params.blocks.{layer}.{suffix}"), &[d], &vec![0.0; d])?;
        }
        let dims: [(&str, &str, usize, usize); 4] = [
            ("wqkv", "bqkv", 3 * d, d),
            ("wproj", "bproj", d, d),
            ("wup", "bup", f, d),
            ("wdown", "bdown", d, f),
        ];
        for (wname, bname, d_out, d_in) in dims {
            let scale = 0.25 / (d_in as f32).sqrt();
            let mut w = Matrix::randn(d_out, d_in, scale, &mut rng);
            let (n, m) = manifest.scheme_for_layer(layer);
            let scheme = NmScheme::new(n, m);
            let mask = if manifest.is_pruned(layer, wname) {
                random_row_mask(d_out, d_in, scheme, &mut rng)
            } else {
                Mask::ones(d_out, d_in)
            };
            // Training stores weights projected onto the support.
            w = mask.apply(&w);
            put(&mut store, &format!("params.blocks.{layer}.{wname}"), &w)?;
            put(&mut store, &format!("masks.blocks.{layer}.{wname}_r"),
                &mask.to_matrix())?;
            store.put_f32(
                &format!("params.blocks.{layer}.{bname}"),
                &[d_out],
                &Matrix::randn(1, d_out, 0.02, &mut rng).data,
            )?;
            if spec.rank > 0 {
                put(&mut store, &format!("lora.blocks.{layer}.{wname}_down"),
                    &Matrix::randn(spec.rank, d_in, 0.02, &mut rng))?;
                put(&mut store, &format!("lora.blocks.{layer}.{wname}_up"),
                    &Matrix::randn(d_out, spec.rank, 0.05, &mut rng))?;
            }
        }
    }
    crate::coordinator::checkpoint::save_model_checkpoint(&store, &manifest, dir)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_artifact_roundtrips_through_host_model() {
        let dir = std::env::temp_dir().join("slope_host_synth_test");
        let spec = SynthSpec { seed: 3, ..SynthSpec::default() };
        write_synthetic_artifact(&dir, &spec).unwrap();
        let manifest = Manifest::load(&dir).unwrap();
        let (store, packed) =
            crate::coordinator::checkpoint::load_model_checkpoint(&dir).unwrap();
        let mut hm = HostModel::from_store(&manifest, &store, &packed,
                                           ParallelPolicy::with_threads(2))
            .unwrap();
        // layer-0 wqkv stays dense ⇒ 2·4 − 1 packed planes.
        assert_eq!(hm.packed_restored, 2 * 4 - 1);
        let mut rng = Rng::seed_from_u64(9);
        let k = 3;
        let tokens: Vec<i32> =
            (0..k * spec.seq_len).map(|_| rng.below(spec.vocab) as i32).collect();
        let mut y = Matrix::zeros(0, 0);
        hm.forward_last_logits_into(&tokens, k, &mut y).unwrap();
        assert_eq!((y.rows, y.cols), (k, spec.vocab));
        assert!(y.data.iter().all(|v| v.is_finite()));
        // Same tokens, different coalescing: single rows must reproduce the
        // batched rows bit-for-bit (row-independent forward).
        for b in 0..k {
            let mut y1 = Matrix::zeros(0, 0);
            hm.forward_last_logits_into(&tokens[b * spec.seq_len..(b + 1) * spec.seq_len],
                                        1, &mut y1)
                .unwrap();
            assert_eq!(y1.row(0), y.row(b), "row {b} must not depend on batch fill");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn kv_cached_decode_matches_full_recompute_bit_for_bit() {
        let dir = std::env::temp_dir().join("slope_host_kv_parity_test");
        let spec = SynthSpec { seed: 5, ..SynthSpec::default() };
        write_synthetic_artifact(&dir, &spec).unwrap();
        let manifest = Manifest::load(&dir).unwrap();
        let (store, packed) =
            crate::coordinator::checkpoint::load_model_checkpoint(&dir).unwrap();
        let mut hm = HostModel::from_store(&manifest, &store, &packed,
                                           ParallelPolicy::with_threads(2))
            .unwrap();
        let mut rng = Rng::seed_from_u64(31);
        let prompt_len = 3usize;
        let mut toks: Vec<i32> =
            (0..prompt_len).map(|_| rng.below(spec.vocab) as i32).collect();
        let mut cache = hm.new_kv_cache();
        let mut y_inc = Matrix::zeros(0, 0);
        hm.prefill_into(&toks, &mut cache, &mut y_inc).unwrap();
        assert_eq!(cache.len(), prompt_len);
        let mut y_full = Matrix::zeros(0, 0);
        hm.forward_prefix_logits_into(&toks, &mut y_full).unwrap();
        assert_eq!(y_inc.data, y_full.data, "prefill logits must equal full recompute");
        // Greedy-extend to the context bound, pinning every step.
        while toks.len() < spec.seq_len {
            let next = y_inc
                .row(0)
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0 as i32;
            toks.push(next);
            hm.decode_step_into(&[next], std::slice::from_mut(&mut cache), &mut y_inc)
                .unwrap();
            hm.forward_prefix_logits_into(&toks, &mut y_full).unwrap();
            assert_eq!(
                y_inc.data, y_full.data,
                "decode at position {} must equal full recompute",
                toks.len() - 1
            );
        }
        // Cache full: the next step must refuse, leaving the cache intact.
        assert!(hm
            .decode_step_into(&[0], std::slice::from_mut(&mut cache), &mut y_inc)
            .is_err());
        assert_eq!(cache.len(), spec.seq_len);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn kv_cache_truncate_replays_identically() {
        let dir = std::env::temp_dir().join("slope_host_kv_truncate_test");
        let spec = SynthSpec { seed: 6, ..SynthSpec::default() };
        write_synthetic_artifact(&dir, &spec).unwrap();
        let manifest = Manifest::load(&dir).unwrap();
        let (store, packed) =
            crate::coordinator::checkpoint::load_model_checkpoint(&dir).unwrap();
        let mut hm =
            HostModel::from_store(&manifest, &store, &packed, ParallelPolicy::serial())
                .unwrap();
        let mut cache = hm.new_kv_cache();
        assert_eq!(cache.bytes(), 0, "a fresh paged cache holds no blocks");
        let mut y = Matrix::zeros(0, 0);
        hm.prefill_into(&[1, 2, 3], &mut cache, &mut y).unwrap();
        assert_eq!(
            cache.bytes(),
            hm.kv_pool().block_bytes(),
            "3 tokens fit one default block; charge must match the pool"
        );
        let mut first = Matrix::zeros(0, 0);
        hm.decode_step_into(&[5], std::slice::from_mut(&mut cache), &mut first)
            .unwrap();
        assert_eq!(cache.len(), 4);
        cache.truncate(3);
        let mut again = Matrix::zeros(0, 0);
        hm.decode_step_into(&[5], std::slice::from_mut(&mut cache), &mut again)
            .unwrap();
        assert_eq!(first.data, again.data, "rollback + replay must be bit-identical");
        cache.reset();
        assert_eq!(cache.bytes(), 0, "reset returns every block to the pool");
        assert_eq!(hm.kv_pool().stats().blocks_in_use, 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn out_of_range_tokens_rejected() {
        let dir = std::env::temp_dir().join("slope_host_synth_range_test");
        let spec = SynthSpec { seed: 4, ..SynthSpec::default() };
        write_synthetic_artifact(&dir, &spec).unwrap();
        let manifest = Manifest::load(&dir).unwrap();
        let (store, packed) =
            crate::coordinator::checkpoint::load_model_checkpoint(&dir).unwrap();
        let mut hm = HostModel::from_store(&manifest, &store, &packed,
                                           ParallelPolicy::serial())
            .unwrap();
        let mut y = Matrix::zeros(0, 0);
        let bad = vec![spec.vocab as i32; spec.seq_len];
        assert!(hm.forward_last_logits_into(&bad, 1, &mut y).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
