//! Name-keyed literal store: the runtime's training state.
//!
//! Keys are the manifest's dotted path names (`params.blocks.0.wqkv`,
//! `opt.m.lnf_g`, `tokens`, …).  The store also knows how to fabricate
//! structured constants the coordinator needs without an executable round
//! trip: all-ones masks (dense baseline), zero adapters, i32 token batches.

use super::manifest::TensorSpec;
use crate::tensor::Matrix;
use std::collections::HashMap;

pub struct Store {
    map: HashMap<String, xla::Literal>,
}

impl Default for Store {
    fn default() -> Self {
        Self::new()
    }
}

impl Store {
    pub fn new() -> Self {
        Self { map: HashMap::new() }
    }

    pub fn insert(&mut self, name: &str, lit: xla::Literal) {
        self.map.insert(name.to_string(), lit);
    }

    pub fn get(&self, name: &str) -> crate::Result<&xla::Literal> {
        self.map
            .get(name)
            .ok_or_else(|| crate::eyre!("store missing tensor {name:?}"))
    }

    pub fn contains(&self, name: &str) -> bool {
        self.map.contains_key(name)
    }

    pub fn remove(&mut self, name: &str) -> Option<xla::Literal> {
        self.map.remove(name)
    }

    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.map.keys().map(|s| s.as_str()).collect();
        v.sort();
        v
    }

    /// Copy a tensor under a new name (literals clone cheaply enough at our
    /// scales; used for snapshotting converged adapters in Fig 3b).
    pub fn duplicate(&mut self, from: &str, to: &str) -> crate::Result<()> {
        let v = self.get(from)?;
        let fresh = clone_literal(v)?;
        self.map.insert(to.to_string(), fresh);
        Ok(())
    }

    // -- constructors ------------------------------------------------------

    pub fn put_f32(&mut self, name: &str, shape: &[usize], data: &[f32]) -> crate::Result<()> {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        let dims: Vec<i64> = shape.iter().map(|d| *d as i64).collect();
        let lit = xla::Literal::vec1(data)
            .reshape(&dims)
            .map_err(|e| crate::eyre!("reshape {name}: {e}"))?;
        self.insert(name, lit);
        Ok(())
    }

    pub fn put_i32(&mut self, name: &str, shape: &[usize], data: &[i32]) -> crate::Result<()> {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        let dims: Vec<i64> = shape.iter().map(|d| *d as i64).collect();
        let lit = xla::Literal::vec1(data)
            .reshape(&dims)
            .map_err(|e| crate::eyre!("reshape {name}: {e}"))?;
        self.insert(name, lit);
        Ok(())
    }

    pub fn put_scalar_i32(&mut self, name: &str, v: i32) {
        self.insert(name, xla::Literal::scalar(v));
    }

    /// Fabricate a tensor of a constant value matching `spec` (used for
    /// ones-masks in the dense baseline and zero adapters).
    pub fn put_const(&mut self, spec: &TensorSpec, value: f32) -> crate::Result<()> {
        match spec.dtype.as_str() {
            "float32" => {
                self.put_f32(&spec.name, &spec.shape, &vec![value; spec.elem_count()])
            }
            "int32" => self.put_i32(&spec.name, &spec.shape, &vec![value as i32; spec.elem_count()]),
            other => Err(crate::eyre!("put_const: unsupported dtype {other}")),
        }
    }

    // -- readers -----------------------------------------------------------

    pub fn read_f32(&self, name: &str) -> crate::Result<Vec<f32>> {
        let lit = self.get(name)?;
        lit.to_vec::<f32>().map_err(|e| crate::eyre!("read {name}: {e}"))
    }

    pub fn read_scalar_f32(&self, name: &str) -> crate::Result<f32> {
        Ok(self.read_f32(name)?[0])
    }

    /// Read a tensor into a caller-owned buffer (cleared, then filled), so
    /// hot loops reuse the buffer's capacity across calls.  The literal
    /// API itself still materializes one transient host copy — that copy
    /// is inherent to PJRT host transfers, not to this call.
    pub fn read_f32_into(&self, name: &str, out: &mut Vec<f32>) -> crate::Result<()> {
        let v = self.read_f32(name)?;
        out.clear();
        out.extend_from_slice(&v);
        Ok(())
    }

    /// Read a rank-2 f32 tensor as a [`Matrix`] (the shape comes from the
    /// stored literal) — the bridge from checkpointed AOT state to the
    /// CPU kernel backend's operands.
    pub fn read_matrix(&self, name: &str) -> crate::Result<Matrix> {
        let lit = self.get(name)?;
        let shape = lit.array_shape().map_err(|e| crate::eyre!("shape of {name}: {e}"))?;
        let dims = shape.dims().to_vec();
        crate::ensure!(dims.len() == 2, "{name} is not rank-2 (dims {dims:?})");
        let data = lit.to_vec::<f32>().map_err(|e| crate::eyre!("read {name}: {e}"))?;
        Ok(Matrix::from_vec(dims[0] as usize, dims[1] as usize, data))
    }
}

fn clone_literal(lit: &xla::Literal) -> crate::Result<xla::Literal> {
    // Literal doesn't implement Clone in xla-rs 0.1.6; round-trip via host.
    let shape = lit.array_shape().map_err(|e| crate::eyre!("{e}"))?;
    let dims: Vec<i64> = shape.dims().iter().map(|d| *d as i64).collect();
    match lit.ty().map_err(|e| crate::eyre!("{e}"))? {
        xla::ElementType::F32 => {
            let v = lit.to_vec::<f32>().map_err(|e| crate::eyre!("{e}"))?;
            xla::Literal::vec1(&v).reshape(&dims).map_err(|e| crate::eyre!("{e}"))
        }
        xla::ElementType::S32 => {
            let v = lit.to_vec::<i32>().map_err(|e| crate::eyre!("{e}"))?;
            xla::Literal::vec1(&v).reshape(&dims).map_err(|e| crate::eyre!("{e}"))
        }
        other => Err(crate::eyre!("clone_literal: unsupported {other:?}")),
    }
}
