//! Name-keyed literal store: the runtime's training state.
//!
//! Keys are the manifest's dotted path names (`params.blocks.0.wqkv`,
//! `opt.m.lnf_g`, `tokens`, …).  The store also knows how to fabricate
//! structured constants the coordinator needs without an executable round
//! trip: all-ones masks (dense baseline), zero adapters, i32 token batches.
//!
//! Every write bumps a per-tensor **version** (and the store carries a
//! process-unique id), so an [`crate::runtime::Executor`] that keeps
//! resident operand state — the host kernel executor — can detect exactly
//! which tensors changed underneath it (e.g. the dense baseline's
//! fabricated ones-masks) and re-ingest only then, keeping the steady-state
//! step loop free of per-step re-compression.

use super::manifest::TensorSpec;
use crate::tensor::Matrix;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};

static STORE_IDS: AtomicU64 = AtomicU64::new(1);

pub struct Store {
    map: HashMap<String, (u64, xla::Literal)>,
    /// Monotone write counter — each insert stamps the tensor's version.
    counter: u64,
    /// Process-unique identity (executors cache per-store sync state).
    id: u64,
}

impl Default for Store {
    fn default() -> Self {
        Self::new()
    }
}

impl Store {
    pub fn new() -> Self {
        Self {
            map: HashMap::new(),
            counter: 0,
            id: STORE_IDS.fetch_add(1, Ordering::Relaxed),
        }
    }

    /// Process-unique store identity (stable for this store's lifetime).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Global write counter: unchanged ⇔ no tensor was (re)inserted.
    pub fn write_count(&self) -> u64 {
        self.counter
    }

    /// Version stamp of one tensor (bumped on every insert of that name).
    pub fn version_of(&self, name: &str) -> Option<u64> {
        self.map.get(name).map(|(v, _)| *v)
    }

    /// Iterate `(name, version)` pairs (unordered).
    pub fn versions(&self) -> impl Iterator<Item = (&str, u64)> {
        self.map.iter().map(|(k, (v, _))| (k.as_str(), *v))
    }

    pub fn insert(&mut self, name: &str, lit: xla::Literal) {
        self.counter += 1;
        self.map.insert(name.to_string(), (self.counter, lit));
    }

    pub fn get(&self, name: &str) -> crate::Result<&xla::Literal> {
        self.map
            .get(name)
            .map(|(_, l)| l)
            .ok_or_else(|| crate::eyre!("store missing tensor {name:?}"))
    }

    pub fn contains(&self, name: &str) -> bool {
        self.map.contains_key(name)
    }

    pub fn remove(&mut self, name: &str) -> Option<xla::Literal> {
        self.map.remove(name).map(|(_, l)| l)
    }

    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.map.keys().map(|s| s.as_str()).collect();
        v.sort();
        v
    }

    /// Move every tensor of `other` into this store (version-bumped like
    /// any insert).  Checkpoint restores parse into a scratch store first
    /// and absorb only on full success, so a corrupt file can never leave
    /// the live store partially populated.
    pub fn absorb(&mut self, other: Store) {
        for (name, (_, lit)) in other.map {
            self.counter += 1;
            self.map.insert(name, (self.counter, lit));
        }
    }

    /// Copy a tensor under a new name (literals clone cheaply enough at our
    /// scales; used for snapshotting converged adapters in Fig 3b).
    pub fn duplicate(&mut self, from: &str, to: &str) -> crate::Result<()> {
        let v = self.get(from)?;
        let fresh = clone_literal(v)?;
        self.insert(to, fresh);
        Ok(())
    }

    // -- constructors ------------------------------------------------------

    pub fn put_f32(&mut self, name: &str, shape: &[usize], data: &[f32]) -> crate::Result<()> {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        let dims: Vec<i64> = shape.iter().map(|d| *d as i64).collect();
        let lit = xla::Literal::vec1(data)
            .reshape(&dims)
            .map_err(|e| crate::eyre!("reshape {name}: {e}"))?;
        self.insert(name, lit);
        Ok(())
    }

    pub fn put_i32(&mut self, name: &str, shape: &[usize], data: &[i32]) -> crate::Result<()> {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        let dims: Vec<i64> = shape.iter().map(|d| *d as i64).collect();
        let lit = xla::Literal::vec1(data)
            .reshape(&dims)
            .map_err(|e| crate::eyre!("reshape {name}: {e}"))?;
        self.insert(name, lit);
        Ok(())
    }

    pub fn put_scalar_i32(&mut self, name: &str, v: i32) {
        self.insert(name, xla::Literal::scalar(v));
    }

    /// Fabricate a tensor of a constant value matching `spec` (used for
    /// ones-masks in the dense baseline and zero adapters).
    pub fn put_const(&mut self, spec: &TensorSpec, value: f32) -> crate::Result<()> {
        match spec.dtype.as_str() {
            "float32" => {
                self.put_f32(&spec.name, &spec.shape, &vec![value; spec.elem_count()])
            }
            "int32" => self.put_i32(&spec.name, &spec.shape, &vec![value as i32; spec.elem_count()]),
            other => Err(crate::eyre!("put_const: unsupported dtype {other}")),
        }
    }

    // -- readers -----------------------------------------------------------

    pub fn read_f32(&self, name: &str) -> crate::Result<Vec<f32>> {
        let lit = self.get(name)?;
        lit.to_vec::<f32>().map_err(|e| crate::eyre!("read {name}: {e}"))
    }

    pub fn read_scalar_f32(&self, name: &str) -> crate::Result<f32> {
        Ok(self.read_f32(name)?[0])
    }

    pub fn read_scalar_i32(&self, name: &str) -> crate::Result<i32> {
        let lit = self.get(name)?;
        let v = lit.to_vec::<i32>().map_err(|e| crate::eyre!("read {name}: {e}"))?;
        Ok(v[0])
    }

    /// Read a tensor into a caller-owned buffer (cleared, then filled), so
    /// hot loops reuse the buffer's capacity across calls.  The literal
    /// API itself still materializes one transient host copy — that copy
    /// is inherent to PJRT host transfers, not to this call.
    pub fn read_f32_into(&self, name: &str, out: &mut Vec<f32>) -> crate::Result<()> {
        let v = self.read_f32(name)?;
        out.clear();
        out.extend_from_slice(&v);
        Ok(())
    }

    /// Read an i32 tensor into a caller-owned buffer (cleared, refilled).
    pub fn read_i32_into(&self, name: &str, out: &mut Vec<i32>) -> crate::Result<()> {
        let lit = self.get(name)?;
        let v = lit.to_vec::<i32>().map_err(|e| crate::eyre!("read {name}: {e}"))?;
        out.clear();
        out.extend_from_slice(&v);
        Ok(())
    }

    /// Read a rank-2 f32 tensor as a [`Matrix`] (the shape comes from the
    /// stored literal) — the bridge from checkpointed AOT state to the
    /// CPU kernel backend's operands.
    pub fn read_matrix(&self, name: &str) -> crate::Result<Matrix> {
        let lit = self.get(name)?;
        let shape = lit.array_shape().map_err(|e| crate::eyre!("shape of {name}: {e}"))?;
        let dims = shape.dims().to_vec();
        crate::ensure!(dims.len() == 2, "{name} is not rank-2 (dims {dims:?})");
        let data = lit.to_vec::<f32>().map_err(|e| crate::eyre!("read {name}: {e}"))?;
        Ok(Matrix::from_vec(dims[0] as usize, dims[1] as usize, data))
    }
}

fn clone_literal(lit: &xla::Literal) -> crate::Result<xla::Literal> {
    // Literal doesn't implement Clone in xla-rs 0.1.6; round-trip via host.
    let shape = lit.array_shape().map_err(|e| crate::eyre!("{e}"))?;
    let dims: Vec<i64> = shape.dims().iter().map(|d| *d as i64).collect();
    match lit.ty().map_err(|e| crate::eyre!("{e}"))? {
        xla::ElementType::F32 => {
            let v = lit.to_vec::<f32>().map_err(|e| crate::eyre!("{e}"))?;
            xla::Literal::vec1(&v).reshape(&dims).map_err(|e| crate::eyre!("{e}"))
        }
        xla::ElementType::S32 => {
            let v = lit.to_vec::<i32>().map_err(|e| crate::eyre!("{e}"))?;
            xla::Literal::vec1(&v).reshape(&dims).map_err(|e| crate::eyre!("{e}"))
        }
        other => Err(crate::eyre!("clone_literal: unsupported {other:?}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn versions_track_writes() {
        let mut a = Store::new();
        let b = Store::new();
        assert_ne!(a.id(), b.id(), "stores must be distinguishable");
        a.put_f32("x", &[2], &[1.0, 2.0]).unwrap();
        let v1 = a.version_of("x").unwrap();
        a.put_f32("y", &[1], &[3.0]).unwrap();
        assert_eq!(a.version_of("x").unwrap(), v1, "unrelated writes leave x alone");
        a.put_f32("x", &[2], &[4.0, 5.0]).unwrap();
        assert!(a.version_of("x").unwrap() > v1, "overwrite bumps the version");
        assert_eq!(a.versions().count(), 2);
        assert!(a.write_count() >= 3);
        assert!(a.version_of("missing").is_none());
    }
}
