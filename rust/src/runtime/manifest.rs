//! `manifest.json` schema — written by `python/compile/aot.py`, consumed by
//! the parameter store and executor.  The manifest is the ONLY contract
//! between build-time python and the runtime: flattened input/output
//! orders (dotted path names), shapes and dtypes per executable.
//!
//! Parsed with the in-tree JSON parser ([`crate::util::json`]).

use crate::util::Json;
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// The four block weights the sparsity policy ranges over, in python's
/// `compile.model.SPARSE_WEIGHTS` order (checkpoint/store names are
/// `params.blocks.<i>.<wname>`).
pub const SPARSE_WEIGHTS: [&str; 4] = ["wqkv", "wproj", "wup", "wdown"];

#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn elem_count(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }

    pub fn dims_i64(&self) -> Vec<i64> {
        self.shape.iter().map(|d| *d as i64).collect()
    }

    fn from_json(j: &Json) -> crate::Result<Self> {
        Ok(Self {
            name: j.req_str("name")?.to_string(),
            shape: j
                .req("shape")?
                .as_arr()
                .ok_or_else(|| crate::eyre!("shape not an array"))?
                .iter()
                .map(|d| d.as_usize().unwrap_or(0))
                .collect(),
            dtype: j.req_str("dtype")?.to_string(),
        })
    }
}

#[derive(Clone, Debug)]
pub struct ExeSpec {
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

#[derive(Clone, Debug)]
pub struct ModelConfig {
    pub name: String,
    pub vocab_size: usize,
    pub n_layer: usize,
    pub n_head: usize,
    pub d_model: usize,
    pub d_ff: usize,
    pub seq_len: usize,
    pub batch_size: usize,
    pub adapter_rank: usize,
    pub first_half_sparsity: (usize, usize),
    pub second_half_sparsity: (usize, usize),
    pub prune_attn: bool,
    pub prune_mlp: bool,
    pub n_params_dense: usize,
}

/// How artifact checkpoints encode the compressed-weight index plane —
/// the Eq.-7 bit-packed layout shared bit-for-bit with
/// [`crate::sparsity::CompressedNm`] (written by `python/compile/aot.py`;
/// absent in pre-packing manifests).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SparsityFormat {
    /// Layout identifier (currently `"eq7-packed-offsets-v1"`).
    pub layout: String,
    pub row_byte_aligned: bool,
    /// `ceil(log2 M)` for the first-half / second-half schemes.
    pub offset_bits_first_half: u32,
    pub offset_bits_second_half: u32,
}

#[derive(Clone, Debug)]
pub struct TrainParams {
    pub lr: f64,
    pub weight_decay: f64,
    pub warmup_steps: usize,
    pub total_steps: usize,
    pub lazy_fraction: f64,
    pub srste_decay: f64,
    /// AdamW β₁/β₂ and the global-norm gradient clip — consumed by the
    /// host training executor; optional in the JSON (older manifests
    /// predate them), defaulting to python `TrainConfig`'s values.
    pub beta1: f64,
    pub beta2: f64,
    pub grad_clip: f64,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub config: ModelConfig,
    pub train: TrainParams,
    /// Packed-metadata layout descriptor (`None` for seed-era manifests
    /// that predate artifact metadata-plane shipping).
    pub sparsity_format: Option<SparsityFormat>,
    pub executables: HashMap<String, ExeSpec>,
    pub dir: PathBuf,
}

fn pair(j: &Json, key: &str) -> crate::Result<(usize, usize)> {
    let a = j.req(key)?;
    Ok((
        a.idx(0).and_then(|v| v.as_usize()).unwrap_or(2),
        a.idx(1).and_then(|v| v.as_usize()).unwrap_or(4),
    ))
}

impl Manifest {
    pub fn load(dir: &Path) -> crate::Result<Self> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .map_err(|e| crate::eyre!("reading {}/manifest.json: {e}", dir.display()))?;
        let j = Json::parse(&text)?;
        let c = j.req("config")?;
        let config = ModelConfig {
            name: c.req_str("name")?.to_string(),
            vocab_size: c.req_usize("vocab_size")?,
            n_layer: c.req_usize("n_layer")?,
            n_head: c.req_usize("n_head")?,
            d_model: c.req_usize("d_model")?,
            d_ff: c.req_usize("d_ff")?,
            seq_len: c.req_usize("seq_len")?,
            batch_size: c.req_usize("batch_size")?,
            adapter_rank: c.req_usize("adapter_rank")?,
            first_half_sparsity: pair(c, "first_half_sparsity")?,
            second_half_sparsity: pair(c, "second_half_sparsity")?,
            prune_attn: c.req_bool("prune_attn")?,
            prune_mlp: c.req_bool("prune_mlp")?,
            n_params_dense: c.req_usize("n_params_dense")?,
        };
        let t = j.req("train")?;
        let opt_f64 = |key: &str, default: f64| {
            t.get(key).and_then(|v| v.as_f64()).unwrap_or(default)
        };
        let train = TrainParams {
            lr: t.req_f64("lr")?,
            weight_decay: t.req_f64("weight_decay")?,
            warmup_steps: t.req_usize("warmup_steps")?,
            total_steps: t.req_usize("total_steps")?,
            lazy_fraction: t.req_f64("lazy_fraction")?,
            srste_decay: t.req_f64("srste_decay")?,
            beta1: opt_f64("beta1", 0.9),
            beta2: opt_f64("beta2", 0.95),
            grad_clip: opt_f64("grad_clip", 1.0),
        };
        // Optional (newer manifests ship the packed-metadata descriptor).
        let sparsity_format = j.get("sparsity_format").map(|sf| -> crate::Result<_> {
            Ok(SparsityFormat {
                layout: sf.req_str("layout")?.to_string(),
                row_byte_aligned: sf.req_bool("row_byte_aligned")?,
                offset_bits_first_half: sf.req_usize("offset_bits_first_half")? as u32,
                offset_bits_second_half: sf.req_usize("offset_bits_second_half")? as u32,
            })
        });
        let sparsity_format = match sparsity_format {
            Some(r) => Some(r?),
            None => None,
        };
        let mut executables = HashMap::new();
        for (name, e) in j
            .req("executables")?
            .as_obj()
            .ok_or_else(|| crate::eyre!("executables not an object"))?
        {
            let inputs = e
                .req("inputs")?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .map(TensorSpec::from_json)
                .collect::<crate::Result<Vec<_>>>()?;
            let outputs = e
                .req("outputs")?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .map(TensorSpec::from_json)
                .collect::<crate::Result<Vec<_>>>()?;
            executables.insert(
                name.clone(),
                ExeSpec { file: e.req_str("file")?.to_string(), inputs, outputs },
            );
        }
        Ok(Manifest { config, train, sparsity_format, executables, dir: dir.to_path_buf() })
    }

    /// Copy this manifest's `manifest.json` into `dst_dir` crash-safely
    /// (atomic replace via [`crate::util::faultfs::write_atomic`]) — the
    /// checkpoint writer's manifest-copy step, so a kill mid-copy can
    /// never leave a half-written manifest in a checkpoint directory.
    pub fn copy_into(&self, dst_dir: &Path) -> crate::Result<()> {
        let src = self.dir.join("manifest.json");
        let dst = dst_dir.join("manifest.json");
        if src == dst {
            return Ok(());
        }
        let bytes = std::fs::read(&src)
            .map_err(|e| crate::eyre!("reading {}: {e}", src.display()))?;
        crate::util::faultfs::write_atomic(&dst, &bytes)
    }

    pub fn exe(&self, name: &str) -> crate::Result<&ExeSpec> {
        self.executables
            .get(name)
            .ok_or_else(|| crate::eyre!("manifest {} has no executable {name:?}", self.config.name))
    }

    pub fn hlo_path(&self, name: &str) -> crate::Result<PathBuf> {
        Ok(self.dir.join(&self.exe(name)?.file))
    }

    /// Batch-of-tokens shape for the train/eval steps: (B, S+1).
    pub fn train_tokens_shape(&self) -> (usize, usize) {
        (self.config.batch_size, self.config.seq_len + 1)
    }

    /// Batch-of-tokens shape for the inference executables
    /// (`forward` / `forward_lora`): (B, S).
    pub fn forward_tokens_shape(&self) -> (usize, usize) {
        (self.config.batch_size, self.config.seq_len)
    }

    /// N:M scheme for block `layer` — the paper's per-half split
    /// (`first_half_sparsity` for blocks `[0, n_layer/2)`, the second-half
    /// scheme for the rest), mirroring python
    /// `ModelConfig.sparsity_for_layer` exactly.
    pub fn scheme_for_layer(&self, layer: usize) -> (usize, usize) {
        if layer < self.config.n_layer / 2 {
            self.config.first_half_sparsity
        } else {
            self.config.second_half_sparsity
        }
    }

    /// Whether block `layer`'s weight `wname` carries a real N:M mask —
    /// the mirror of python `compile.model._is_pruned` (§3.2: the first
    /// linear after the input and any module disabled by the
    /// `prune_attn` / `prune_mlp` ablation stay dense).
    pub fn is_pruned(&self, layer: usize, wname: &str) -> bool {
        if matches!(wname, "wqkv" | "wproj") && !self.config.prune_attn {
            return false;
        }
        if matches!(wname, "wup" | "wdown") && !self.config.prune_mlp {
            return false;
        }
        !(layer == 0 && wname == "wqkv")
    }
}
