//! Differentiable host training model — the paper's Eq. 4–6 recipe
//! executed natively on the crate's CPU kernel engine.
//!
//! [`HostTrainModel`] holds the full training state of one manifest config
//! (params, masks as packed patterns, AdamW moments, lazy adapters) and
//! implements the semantics of the AOT `train_step` / `train_step_lora` /
//! `eval_step` / `forward` executables:
//!
//! * **Forward** — the pre-LN GPT of `python/compile/model.py`, sharing
//!   [`super::host`]'s layer-norm / causal-attention / tanh-GELU kernels
//!   with the serving executor, every pruned linear an Eq.-4 packed SpMM.
//! * **Backward** — hand-derived reverse pass over the forward tape.  Per
//!   pruned linear it is exactly the paper's custom VJP
//!   (`python/compile/layers.slope_matmul`):
//!   - BWD-2 (Eq. 6): `∇X = ∇Y · W^{R,C}` through the **row-compressed
//!     double-pruned transpose** — the same packed SpMM kernel as the
//!     forward, NOT a dense `∇Y·Wᵀ`;
//!   - BWD-1 (Eq. 5, line 13): dense `∇Yᵀ·X` staged once, then
//!     masked+packed via [`crate::backend::prune_and_compress_into`] so
//!     gradients (and the Adam moments fed from them) never materialize
//!     for pruned slots.
//! * **Optimizer** — AdamW matching `python/compile/train.py` exactly:
//!   global-norm clip, linear-warmup→cosine LR, decoupled decay on
//!   matrices only, masked updates/moments (Algorithm 1 lines 15–18).
//!   For packed weights the whole update runs in compressed space: the
//!   moments are slot-aligned with `w.values` (the §3.1 2×-reduced Adam
//!   state), and the transpose operand is refreshed in place.
//! * **Lazy adapters** — `lora_init` (down ~ N(0, 0.02²), up = 0: an
//!   exact no-op at activation) and the adapter half of
//!   `train_step_lora` (plain autodiff through the fused Eq.-11 path,
//!   its own AdamW chain with an independent step counter, as python's
//!   second `adamw_update` call does).
//!
//! All matrix work routes through the [`crate::backend`] engine under one
//! [`ParallelPolicy`]; the kernels are bit-identical across thread counts
//! and every hand loop is serial, so a train step is **deterministic in
//! the thread count** (pinned in `tests/host_train.rs`).  Steady-state
//! steps reuse every tape/workspace buffer (shape-keyed pools for the
//! shapes that alternate), so the hot loop performs no per-step
//! allocations beyond the literal-store round-trip the AOT path also pays.

use crate::backend::gemm::dot;
use crate::backend::{ensure_out, gemm_into, gemm_nt_acc_into, gemm_nt_into, gemm_tn_into,
                     prune_and_compress_into, spmm_prepacked_into, spmm_rowmajor_into,
                     ParallelPolicy};
use crate::runtime::host::{add_inplace, causal_attention_into, gelu_tanh, gelu_tanh_grad,
                           layer_norm_into};
use crate::runtime::manifest::{ModelConfig, TrainParams};
use crate::runtime::{Manifest, Store, SPARSE_WEIGHTS};
use crate::sparsity::{double_prune_mask, prepack_enabled, random_row_mask, CompressedNm, Mask,
                      NmScheme, PrepackedNm};
use crate::tensor::Matrix;
use crate::util::Rng;

const ADAM_EPS: f32 = 1e-8;

// ---- state-tree enumeration (shared with the manifest fabricator) -----

/// `(suffix, shape)` of every parameter leaf, in a stable order.  Store
/// names are `params.<suffix>`; optimizer planes `opt.m.<suffix>` /
/// `opt.v.<suffix>`.
pub(crate) fn param_leaves(c: &ModelConfig) -> Vec<(String, Vec<usize>)> {
    let (d, f, v, s) = (c.d_model, c.d_ff, c.vocab_size, c.seq_len);
    let mut out: Vec<(String, Vec<usize>)> = vec![
        ("tok_emb".into(), vec![v, d]),
        ("pos_emb".into(), vec![s, d]),
        ("lnf_g".into(), vec![d]),
        ("lnf_b".into(), vec![d]),
    ];
    for i in 0..c.n_layer {
        let p = |suffix: &str| format!("blocks.{i}.{suffix}");
        out.push((p("ln1_g"), vec![d]));
        out.push((p("ln1_b"), vec![d]));
        out.push((p("wqkv"), vec![3 * d, d]));
        out.push((p("bqkv"), vec![3 * d]));
        out.push((p("wproj"), vec![d, d]));
        out.push((p("bproj"), vec![d]));
        out.push((p("ln2_g"), vec![d]));
        out.push((p("ln2_b"), vec![d]));
        out.push((p("wup"), vec![f, d]));
        out.push((p("bup"), vec![f]));
        out.push((p("wdown"), vec![d, f]));
        out.push((p("bdown"), vec![d]));
    }
    out
}

/// Dense shape of one block weight.
pub(crate) fn weight_dims(c: &ModelConfig, wname: &str) -> (usize, usize) {
    let (d, f) = (c.d_model, c.d_ff);
    match wname {
        "wqkv" => (3 * d, d),
        "wproj" => (d, d),
        "wup" => (f, d),
        "wdown" => (d, f),
        other => unreachable!("unknown block weight {other}"),
    }
}

/// `(suffix, shape)` of every mask leaf (`masks.<suffix>`).
pub(crate) fn mask_leaves(c: &ModelConfig) -> Vec<(String, Vec<usize>)> {
    let mut out = Vec::new();
    for i in 0..c.n_layer {
        for wname in SPARSE_WEIGHTS {
            let (d_out, d_in) = weight_dims(c, wname);
            out.push((format!("blocks.{i}.{wname}_r"), vec![d_out, d_in]));
            out.push((format!("blocks.{i}.{wname}_rc"), vec![d_out, d_in]));
        }
    }
    out
}

/// `(suffix, shape)` of every adapter leaf (`lora.<suffix>`;
/// `lora_opt.{m,v}.<suffix>`).
pub(crate) fn lora_leaves(c: &ModelConfig) -> Vec<(String, Vec<usize>)> {
    let r = c.adapter_rank;
    let mut out = Vec::new();
    for i in 0..c.n_layer {
        for wname in SPARSE_WEIGHTS {
            let (d_out, d_in) = weight_dims(c, wname);
            out.push((format!("blocks.{i}.{wname}_down"), vec![r, d_in]));
            out.push((format!("blocks.{i}.{wname}_up"), vec![d_out, r]));
        }
    }
    out
}

/// Decoupled-weight-decay coefficient for a leaf (python `_decay_coeff`):
/// biases, norm gains/shifts and the positional embedding never decay.
fn decay_of(suffix: &str, wd: f32) -> f32 {
    let leaf = suffix.rsplit('.').next().unwrap_or(suffix);
    if leaf.starts_with('b') || leaf.ends_with("_g") || leaf.ends_with("_b") || leaf == "pos_emb"
    {
        0.0
    } else {
        wd
    }
}

// ---- per-leaf state ----------------------------------------------------

/// Dense vector parameter (norm gain/shift, bias) with grads + moments.
struct VecParam {
    suffix: String,
    w: Vec<f32>,
    g: Vec<f32>,
    m: Vec<f32>,
    v: Vec<f32>,
}

impl VecParam {
    fn new(suffix: &str, w: Vec<f32>) -> Self {
        let n = w.len();
        Self { suffix: suffix.into(), w, g: vec![0.0; n], m: vec![0.0; n], v: vec![0.0; n] }
    }
}

/// Dense matrix parameter (embeddings) with grads + moments.
struct MatParam {
    suffix: String,
    w: Matrix,
    g: Matrix,
    m: Matrix,
    v: Matrix,
}

impl MatParam {
    fn new(suffix: &str, w: Matrix) -> Self {
        let (r, c) = (w.rows, w.cols);
        Self {
            suffix: suffix.into(),
            w,
            g: Matrix::zeros(r, c),
            m: Matrix::zeros(r, c),
            v: Matrix::zeros(r, c),
        }
    }
}

/// Packed operand set of a pruned linear — the Table-3 training-memory
/// layout: both weight copies bit-packed, packed masked gradient, masked
/// (slot-aligned) Adam moments.  No dense mirror is kept; masks live only
/// as the packed patterns (and are re-derived for store export).
struct SparseOps {
    scheme: NmScheme,
    /// Row-compressed `W^R` (Eq. 4 forward operand; also the grad pattern).
    w: CompressedNm,
    /// Row-compressed transpose of `W^{R,C}` (Eq. 6 backward operand).
    w_t: CompressedNm,
    /// Pad slots of `w_t` (bitset): column groups of the double-pruned
    /// mask with fewer than N survivors; those slots stay exactly 0.
    wt_pad: Vec<u64>,
    /// Packed masked gradient (Eq. 5 / Algorithm 1 line 13).
    gw: CompressedNm,
    /// Masked Adam moments, slot-aligned with `w.values` (§3.1: sparse
    /// optimizer state).
    m: Vec<f32>,
    v: Vec<f32>,
    /// Fused prepacked stream of `w` for the forward SpMM (`None` under
    /// `SLOPE_PREPACK=off`).  Values are refreshed in place after every
    /// optimizer step; the metadata never changes (masks are static).
    pre: Option<PrepackedNm>,
}

impl SparseOps {
    #[inline]
    fn pad(&self, slot: usize) -> bool {
        (self.wt_pad[slot / 64] >> (slot % 64)) & 1 == 1
    }

    /// Refresh the BWD-2 operand from the updated forward operand: for
    /// every non-pad `w_t` slot `(r', c')`, gather `W[c', r']` out of
    /// row `c'` of `w` by scanning its ≤N in-group candidates.  O(nnz·N),
    /// allocation-free, and needs no dense mirror or gather map.
    fn refresh_wt(&mut self) {
        let (n, m) = (self.scheme.n, self.scheme.m);
        let kc = self.w.kcols();
        let kc_t = self.w_t.kcols();
        for rt in 0..self.w_t.rows {
            for k in 0..kc_t {
                let slot = rt * kc_t + k;
                if self.pad(slot) {
                    continue; // stays exactly 0 (rc-pruned slot)
                }
                let ct = self.w_t.index(rt, k);
                // W[ct, rt]: scan row `ct` of `w`, group rt/m (≤N probes;
                // mask_rc ⊆ mask_r guarantees a hit for non-pad slots).
                let g = rt / m;
                let mut val = 0.0;
                for j in 0..n {
                    let wslot = g * n + j;
                    if self.w.index(ct, wslot) == rt {
                        val = self.w.values[ct * kc + wslot];
                        break;
                    }
                }
                self.w_t.values[slot] = val;
            }
        }
    }

    /// Scatter packed values (grad/moments) to a dense staging matrix.
    fn scatter(&self, values: &[f32], out: &mut Matrix) {
        ensure_out(out, self.w.rows, self.w.cols);
        out.data.fill(0.0);
        let kc = self.w.kcols();
        for r in 0..self.w.rows {
            for (k, c) in self.w.row_indices(r).enumerate() {
                out.data[r * self.w.cols + c] = values[r * kc + k];
            }
        }
    }

    /// Re-derive `mask_r` (exact by construction) as a dense 0/1 matrix.
    fn mask_r_dense(&self, out: &mut Matrix) {
        ensure_out(out, self.w.rows, self.w.cols);
        out.data.fill(0.0);
        for r in 0..self.w.rows {
            for c in self.w.row_indices(r) {
                out.data[r * self.w.cols + c] = 1.0;
            }
        }
    }

    /// Re-derive `mask_rc` (pads excluded) as a dense 0/1 matrix in the
    /// original `d_out × d_in` layout.
    fn mask_rc_dense(&self, out: &mut Matrix) {
        ensure_out(out, self.w.rows, self.w.cols);
        out.data.fill(0.0);
        let kc_t = self.w_t.kcols();
        for rt in 0..self.w_t.rows {
            for (k, ct) in self.w_t.row_indices(rt).enumerate() {
                if !self.pad(rt * kc_t + k) {
                    out.data[ct * self.w.cols + rt] = 1.0;
                }
            }
        }
    }
}

/// Dense operand set: unpruned weights, and the dense-baseline /
/// non-N:M-mask route (python's single executable covers both via masks).
struct DenseOps {
    w: Matrix,
    /// Row mask (`None` = all-ones / absent).  Kept dense-boolean here —
    /// this route is not the one the §3.1 memory claims charge.
    mask_r: Option<Mask>,
    mask_rc: Option<Mask>,
    /// Masked forward / backward operands (refreshed after each update;
    /// empty when the masks are trivial).
    wm_r: Matrix,
    wm_rc: Matrix,
    /// Dense (masked) gradient.
    gw: Matrix,
    m: Matrix,
    v: Matrix,
}

impl DenseOps {
    fn refresh_masked(&mut self) {
        if let Some(mask) = &self.mask_r {
            ensure_out(&mut self.wm_r, self.w.rows, self.w.cols);
            for (o, (wv, k)) in
                self.wm_r.data.iter_mut().zip(self.w.data.iter().zip(&mask.keep))
            {
                *o = if *k { *wv } else { 0.0 };
            }
        }
        if let Some(mask) = &self.mask_rc {
            ensure_out(&mut self.wm_rc, self.w.rows, self.w.cols);
            for (o, (wv, k)) in
                self.wm_rc.data.iter_mut().zip(self.w.data.iter().zip(&mask.keep))
            {
                *o = if *k { *wv } else { 0.0 };
            }
        }
    }

    fn fwd_operand(&self) -> &Matrix {
        if self.mask_r.is_some() {
            &self.wm_r
        } else {
            &self.w
        }
    }

    fn bwd_operand(&self) -> &Matrix {
        if self.mask_rc.is_some() {
            &self.wm_rc
        } else {
            &self.w
        }
    }
}

enum LinOps {
    Sparse(SparseOps),
    Dense(DenseOps),
}

/// One block linear: weight operands (packed or dense), bias, grads,
/// moments.
struct TrainLinear {
    /// Store suffix of the weight, e.g. `blocks.0.wqkv`.
    wsuffix: String,
    d_out: usize,
    d_in: usize,
    ops: LinOps,
    bias: VecParam,
}

/// One lazy adapter pair (`down` = R: (r, d_in), `up` = L: (d_out, r)).
struct LoraPair {
    wsuffix: String,
    down: MatParam,
    up: MatParam,
    /// Taped rank intermediate `T = X·Rᵀ` from the forward.
    t: Matrix,
}

struct LoraState {
    /// `n_layer × 4` pairs, indexed `layer*4 + SPARSE_WEIGHTS position`.
    pairs: Vec<LoraPair>,
    step: f32,
}

struct NormParam {
    g: VecParam,
    b: VecParam,
}

struct TrainBlock {
    ln1: NormParam,
    ln2: NormParam,
    qkv: TrainLinear,
    proj: TrainLinear,
    up: TrainLinear,
    down: TrainLinear,
}

// ---- tape + workspace --------------------------------------------------

/// Per-layer forward activations retained for the backward pass.
#[derive(Default)]
struct LayerTape {
    /// Residual stream entering the block.
    x_in: Matrix,
    /// ln1 output (qkv input).
    h1: Matrix,
    /// Fused QKV activation.
    qkv: Matrix,
    /// Attention output (proj input).
    att: Matrix,
    /// Residual after the attention branch.
    x_mid: Matrix,
    /// ln2 output (up input).
    h2: Matrix,
    /// Pre-GELU upsample.
    up: Matrix,
    /// Post-GELU (down input).
    gel: Matrix,
}

#[derive(Default)]
struct Tape {
    layers: Vec<LayerTape>,
    /// Final residual stream.
    x_out: Matrix,
    /// lnf output.
    hf: Matrix,
    /// Full-position logits `(k·S, V)`.
    logits: Matrix,
}

/// Shape-keyed buffer pool: the backward staging shapes alternate
/// (`(3d,d)/(d,d)/(f,d)/(d,f)` for ∇W, `(rows,d)/(rows,f)` for adapter
/// input-grads), so a single `ensure_out` buffer would reallocate every
/// call; the pool holds one warm buffer per distinct shape instead.
#[derive(Default)]
struct ShapePool {
    bufs: Vec<Matrix>,
}

impl ShapePool {
    fn get(&mut self, rows: usize, cols: usize) -> &mut Matrix {
        if let Some(i) = self.bufs.iter().position(|m| m.rows == rows && m.cols == cols) {
            return &mut self.bufs[i];
        }
        self.bufs.push(Matrix::zeros(rows, cols));
        self.bufs.last_mut().expect("just pushed")
    }

    fn bytes(&self) -> usize {
        self.bufs.iter().map(|m| m.data.len() * 4).sum()
    }
}

#[derive(Default)]
struct TrainWs {
    /// Residual-stream gradient (rows, d).
    d_res: Matrix,
    /// LN input-grad staging (rows, d).
    d_branch: Matrix,
    /// Grad wrt lnf output (rows, d).
    d_hf: Matrix,
    /// Grad wrt ln2 output (rows, d).
    d_h2: Matrix,
    /// Grad wrt post-GELU (rows, f).
    d_gel: Matrix,
    /// Grad wrt pre-GELU (rows, f).
    d_up: Matrix,
    /// Grad wrt fused QKV (rows, 3d).
    d_qkv: Matrix,
    /// Grad wrt attention output (rows, d).
    d_att: Matrix,
    /// Grad wrt ln1 output (rows, d).
    d_h1: Matrix,
    /// Adapter rank grad (rows, r).
    d_t: Matrix,
    /// Softmax CE gradient (rows, V).
    dlogits: Matrix,
    /// Forward branch staging (rows, d).
    fwd_branch: Matrix,
    /// Attention scratch (probability / dot rows + one dq accumulator).
    scores: Vec<f32>,
    att_dw: Vec<f32>,
    att_dq: Vec<f32>,
    /// Dense ∇W staging shared across all linears (shape-keyed).
    gw_pool: ShapePool,
    /// Adapter input-grad staging (shape-keyed).
    lin_pool: ShapePool,
    /// Store-export scratch.
    export: Matrix,
}

// ---- the model ---------------------------------------------------------

/// Live training-state accounting of a built model (see
/// [`HostTrainModel::state_bytes`]) — the measured counterpart of the
/// `memmodel` closed forms.
#[derive(Clone, Copy, Debug)]
pub struct TrainStateBytes {
    /// Packed state of the pruned linears: both weight planes (+ the
    /// `w_t` pad bitset), the packed gradient, and the masked moments.
    pub pruned_bytes: usize,
    /// What dense f32 training state for the same linears would hold
    /// (weight + gradient + two moments).
    pub pruned_dense_bytes: usize,
    /// Dense remainder state (embeddings, norms, biases, unpruned
    /// linears), with grads + moments.
    pub dense_rest_bytes: usize,
    /// Transient shared workspaces (dense ∇W staging pool) — reused
    /// across every linear, so they amortize instead of scaling with
    /// parameter count.
    pub workspace_bytes: usize,
}

/// Checkpoint-backed / seed-initialized host training executor for one
/// manifest config (module docs).
pub struct HostTrainModel {
    cfg: ModelConfig,
    train: TrainParams,
    policy: ParallelPolicy,
    tok_emb: MatParam,
    pos_emb: MatParam,
    lnf: NormParam,
    blocks: Vec<TrainBlock>,
    lora: Option<LoraState>,
    opt_step: f32,
    tape: Tape,
    ws: TrainWs,
    /// Token ids of the taped forward (embedding-scatter backward).
    fwd_tokens: Vec<i32>,
    /// Reusable input-token staging (targets stripped off).
    inp_buf: Vec<i32>,
}

impl HostTrainModel {
    // ---- construction --------------------------------------------------

    /// Seed-initialize model + masks + optimizer — the `init` executable.
    /// Mirrors python `model.init_params` / `init_masks(scheme="random")` /
    /// `project_params` (values differ — the offline RNG is not jax's —
    /// but distributions, shapes, projection and the double-pruned mask
    /// rule match).
    pub fn init(manifest: &Manifest, seed: u64, policy: ParallelPolicy) -> crate::Result<Self> {
        let c = manifest.config.clone();
        crate::ensure!(c.d_model % c.n_head == 0, "d_model must divide by n_head");
        let mut rng = Rng::seed_from_u64(seed);
        let (d, f) = (c.d_model, c.d_ff);
        let tok_emb = MatParam::new("tok_emb", Matrix::randn(c.vocab_size, d, 0.02, &mut rng));
        let pos_emb = MatParam::new("pos_emb", Matrix::randn(c.seq_len, d, 0.01, &mut rng));
        let lnf = norm_param("lnf_g", "lnf_b", vec![1.0; d], vec![0.0; d]);
        let proj_scale = 0.02 / (2.0 * c.n_layer as f32).sqrt();
        let mut blocks = Vec::with_capacity(c.n_layer);
        for layer in 0..c.n_layer {
            let p = |s: &str| format!("blocks.{layer}.{s}");
            let ln1 = norm_param(&p("ln1_g"), &p("ln1_b"), vec![1.0; d], vec![0.0; d]);
            let ln2 = norm_param(&p("ln2_g"), &p("ln2_b"), vec![1.0; d], vec![0.0; d]);
            let mut linear = |wname: &str, bname: &str, scale: f32| -> crate::Result<TrainLinear> {
                let (d_out, d_in) = weight_dims(&c, wname);
                let w = Matrix::randn(d_out, d_in, scale, &mut rng);
                let (n, m) = manifest.scheme_for_layer(layer);
                let scheme = NmScheme::new(n, m);
                let (mask_r, mask_rc) = if manifest.is_pruned(layer, wname) {
                    crate::ensure!(
                        d_in % m == 0 && d_out % m == 0,
                        "{} is {d_out}x{d_in}: not {n}:{m} groupable",
                        p(wname)
                    );
                    let mr = random_row_mask(d_out, d_in, scheme, &mut rng);
                    let mrc = double_prune_mask(&w, &mr, scheme);
                    (Some(mr), Some(mrc))
                } else {
                    (None, None)
                };
                build_linear(
                    &p(wname),
                    &p(bname),
                    w,
                    vec![0.0; d_out],
                    mask_r,
                    mask_rc,
                    scheme,
                    None,
                )
            };
            let qkv = linear("wqkv", "bqkv", 0.02)?;
            let proj = linear("wproj", "bproj", proj_scale)?;
            let up = linear("wup", "bup", 0.02)?;
            let down = linear("wdown", "bdown", proj_scale)?;
            blocks.push(TrainBlock { ln1, ln2, qkv, proj, up, down });
        }
        let mut me = Self {
            cfg: c,
            train: manifest.train.clone(),
            policy,
            tok_emb,
            pos_emb,
            lnf,
            blocks,
            lora: None,
            opt_step: 0.0,
            tape: Tape::default(),
            ws: TrainWs::default(),
            fwd_tokens: Vec::new(),
            inp_buf: Vec::new(),
        };
        me.tape.layers = (0..me.cfg.n_layer).map(|_| LayerTape::default()).collect();
        Ok(me)
    }

    /// Rebuild the full training state from a literal store (`params.*`
    /// required; `masks.*` / `opt.*` / `lora.*` / `lora_opt.*` optional).
    /// The route per weight follows the checkpoint rule: a present,
    /// shape-matched, **exact**-N:M row mask (with a column-valid
    /// `mask_rc`) restores packed; everything else restores dense with the
    /// stored masks applied in forward/backward.
    pub fn from_store(manifest: &Manifest, store: &Store,
                      policy: ParallelPolicy) -> crate::Result<Self> {
        let c = manifest.config.clone();
        crate::ensure!(
            !store.contains("params.head_w"),
            "host trainer supports tied embeddings only (params.head_w present)"
        );
        let read_vec = |suffix: &str| store.read_f32(&format!("params.{suffix}"));
        let tok_emb = mat_param_from_store(store, "tok_emb", c.vocab_size, c.d_model)?;
        let pos = store.read_matrix("params.pos_emb")?;
        crate::ensure!(
            pos.rows >= c.seq_len && pos.cols == c.d_model,
            "pos_emb ({}x{}) too short for seq_len {}",
            pos.rows, pos.cols, c.seq_len
        );
        let mut pos_emb = MatParam::new("pos_emb", pos);
        ingest_moments_mat(store, &mut pos_emb)?;
        let lnf = norm_param_from_store(store, "lnf_g", "lnf_b", read_vec("lnf_g")?,
                                        read_vec("lnf_b")?, c.d_model)?;
        let mut blocks = Vec::with_capacity(c.n_layer);
        for layer in 0..c.n_layer {
            let p = |s: &str| format!("blocks.{layer}.{s}");
            let ln1 = norm_param_from_store(store, &p("ln1_g"), &p("ln1_b"),
                                            read_vec(&p("ln1_g"))?, read_vec(&p("ln1_b"))?,
                                            c.d_model)?;
            let ln2 = norm_param_from_store(store, &p("ln2_g"), &p("ln2_b"),
                                            read_vec(&p("ln2_g"))?, read_vec(&p("ln2_b"))?,
                                            c.d_model)?;
            let mut linear = |wname: &str, bname: &str| -> crate::Result<TrainLinear> {
                let (d_out, d_in) = weight_dims(&c, wname);
                let w = store.read_matrix(&format!("params.{}", p(wname)))?;
                crate::ensure!(
                    (w.rows, w.cols) == (d_out, d_in),
                    "params.{} is {}x{}, expected {d_out}x{d_in}",
                    p(wname), w.rows, w.cols
                );
                let bias = read_vec(&p(bname))?;
                crate::ensure!(bias.len() == d_out, "bias length mismatch for {}", p(bname));
                let (n, m) = manifest.scheme_for_layer(layer);
                let scheme = NmScheme::new(n, m);
                let mask_r = read_mask(store, &format!("masks.{}_r", p(wname)), d_out, d_in)?;
                let mask_rc = read_mask(store, &format!("masks.{}_rc", p(wname)), d_out, d_in)?;
                build_linear(&p(wname), &p(bname), w, bias, mask_r, mask_rc, scheme,
                             Some(store))
            };
            let qkv = linear("wqkv", "bqkv")?;
            let proj = linear("wproj", "bproj")?;
            let up = linear("wup", "bup")?;
            let down = linear("wdown", "bdown")?;
            blocks.push(TrainBlock { ln1, ln2, qkv, proj, up, down });
        }
        let opt_step = if store.contains("opt.step") {
            store.read_scalar_f32("opt.step")?
        } else {
            0.0
        };
        let lora = ingest_lora(&c, store)?;
        let mut me = Self {
            cfg: c,
            train: manifest.train.clone(),
            policy,
            tok_emb,
            pos_emb,
            lnf,
            blocks,
            lora,
            opt_step,
            tape: Tape::default(),
            ws: TrainWs::default(),
            fwd_tokens: Vec::new(),
            inp_buf: Vec::new(),
        };
        me.tape.layers = (0..me.cfg.n_layer).map(|_| LayerTape::default()).collect();
        Ok(me)
    }

    /// The policy every kernel call runs under.
    pub fn policy(&self) -> ParallelPolicy {
        self.policy
    }

    /// Re-point the kernel-engine policy (results are bit-identical at
    /// any thread count, so this never changes the training trajectory).
    pub fn set_policy(&mut self, policy: ParallelPolicy) {
        self.policy = policy;
    }

    pub fn has_lora(&self) -> bool {
        self.lora.is_some()
    }

    pub fn opt_step(&self) -> f32 {
        self.opt_step
    }

    /// Materialize the lazy adapters (python `lora_init`): down ~
    /// N(0, 0.02²), up = 0 — an exact no-op at activation — with a fresh
    /// optimizer chain (own step counter, as python's separate
    /// `adamw_update` call implies).
    pub fn lora_init(&mut self, seed: u64) -> crate::Result<()> {
        crate::ensure!(self.cfg.adapter_rank > 0, "adapter_rank is 0: no adapters to init");
        let r = self.cfg.adapter_rank;
        let mut rng = Rng::seed_from_u64(seed);
        let mut pairs = Vec::with_capacity(self.cfg.n_layer * SPARSE_WEIGHTS.len());
        for layer in 0..self.cfg.n_layer {
            for wname in SPARSE_WEIGHTS {
                let (d_out, d_in) = weight_dims(&self.cfg, wname);
                let wsuffix = format!("blocks.{layer}.{wname}");
                pairs.push(LoraPair {
                    down: MatParam::new(&format!("{wsuffix}_down"),
                                        Matrix::randn(r, d_in, 0.02, &mut rng)),
                    up: MatParam::new(&format!("{wsuffix}_up"), Matrix::zeros(d_out, r)),
                    t: Matrix::zeros(0, 0),
                    wsuffix,
                });
            }
        }
        self.lora = Some(LoraState { pairs, step: 0.0 });
        Ok(())
    }

    // ---- forward / loss ------------------------------------------------

    /// Full-position logits for `k` sequences of `s` tokens (`(k·s, V)`),
    /// retained on the tape.  `s` must equal the config's `seq_len` (the
    /// AOT executables are shaped that way too).
    pub fn forward_logits(&mut self, tokens: &[i32], k: usize,
                          with_lora: bool) -> crate::Result<&Matrix> {
        self.forward_tape(tokens, k, with_lora)?;
        Ok(&self.tape.logits)
    }

    /// Validate a full `(B, S+1)` train/eval batch — the target column
    /// included, which `forward_tape` never sees but `loss_and_dlogits`
    /// indexes with.
    fn check_train_batch(&self, tokens: &[i32]) -> crate::Result<()> {
        let (b, s1, vocab) = (self.cfg.batch_size, self.cfg.seq_len + 1, self.cfg.vocab_size);
        crate::ensure!(
            tokens.len() == b * s1,
            "expected {b}x{s1} tokens, got {}",
            tokens.len()
        );
        for &t in tokens {
            crate::ensure!(
                t >= 0 && (t as usize) < vocab,
                "token id {t} outside vocab 0..{vocab}"
            );
        }
        Ok(())
    }

    /// Mean causal-LM cross-entropy over a `(B, S+1)` token batch
    /// (`eval_step` semantics: no state change).
    pub fn eval_loss(&mut self, tokens: &[i32], with_lora: bool) -> crate::Result<f32> {
        let (b, s1) = (self.cfg.batch_size, self.cfg.seq_len + 1);
        self.check_train_batch(tokens)?;
        let inp = self.stage_inputs(tokens, b, s1);
        let fwd = self.forward_tape(&inp, b, with_lora);
        self.inp_buf = inp;
        fwd?;
        Ok(self.loss_and_dlogits(tokens, b, false))
    }

    /// Forward + loss + full backward: fills every gradient buffer and
    /// returns the loss.  Public so the finite-difference suite can probe
    /// analytic gradients directly.
    pub fn loss_and_grad(&mut self, tokens: &[i32], with_lora: bool) -> crate::Result<f32> {
        let (b, s1) = (self.cfg.batch_size, self.cfg.seq_len + 1);
        self.check_train_batch(tokens)?;
        crate::ensure!(!with_lora || self.lora.is_some(), "adapters not initialized");
        let inp = self.stage_inputs(tokens, b, s1);
        let fwd = self.forward_tape(&inp, b, with_lora);
        self.inp_buf = inp;
        fwd?;
        let loss = self.loss_and_dlogits(tokens, b, true);
        self.backward(b, with_lora);
        Ok(loss)
    }

    /// One sparse-phase step (`train_step`): Eq. 4–6 fwd/bwd + AdamW.
    pub fn train_step(&mut self, tokens: &[i32]) -> crate::Result<f32> {
        let loss = self.loss_and_grad(tokens, false)?;
        self.adam_step_params();
        Ok(loss)
    }

    /// One lazy-phase step (`train_step_lora`): base weights AND adapters
    /// train, each under its own global-norm clip + AdamW chain.
    pub fn train_step_lora(&mut self, tokens: &[i32]) -> crate::Result<f32> {
        crate::ensure!(self.lora.is_some(), "train_step_lora before lora_init");
        let loss = self.loss_and_grad(tokens, true)?;
        self.adam_step_params();
        self.adam_step_lora();
        Ok(loss)
    }

    /// Strip the shifted targets off a `(B, S+1)` batch into the reusable
    /// staging buffer (taken out of `self` so `forward_tape` can borrow).
    fn stage_inputs(&mut self, tokens: &[i32], b: usize, s1: usize) -> Vec<i32> {
        let s = s1 - 1;
        let mut inp = std::mem::take(&mut self.inp_buf);
        inp.clear();
        inp.reserve(b * s);
        for row in 0..b {
            inp.extend_from_slice(&tokens[row * s1..row * s1 + s]);
        }
        inp
    }

    /// Shared forward with activation taping: `k` sequences × `seq_len`
    /// tokens, full-position logits into `tape.logits`.
    fn forward_tape(&mut self, tokens: &[i32], k: usize, with_lora: bool) -> crate::Result<()> {
        let (d, s, vocab) = (self.cfg.d_model, self.cfg.seq_len, self.cfg.vocab_size);
        crate::ensure!(k > 0, "empty batch");
        crate::ensure!(
            tokens.len() == k * s,
            "expected {k}x{s} tokens, got {}",
            tokens.len()
        );
        crate::ensure!(!with_lora || self.lora.is_some(), "adapters not initialized");
        for &t in tokens {
            crate::ensure!(
                t >= 0 && (t as usize) < vocab,
                "token id {t} outside vocab 0..{vocab}"
            );
        }
        let rows = k * s;
        let n_head = self.cfg.n_head;
        let policy = self.policy;
        self.fwd_tokens.clear();
        self.fwd_tokens.extend_from_slice(tokens);
        let Self { tape, ws, blocks, lora, tok_emb, pos_emb, lnf, .. } = self;
        let mut lora_pairs = lora.as_mut().filter(|_| with_lora).map(|l| &mut l.pairs);

        // Embedding.
        {
            let x0 = &mut tape.layers[0].x_in;
            ensure_out(x0, rows, d);
            for bi in 0..k {
                for ti in 0..s {
                    let tok = tokens[bi * s + ti] as usize;
                    let dst = x0.row_mut(bi * s + ti);
                    let te = tok_emb.w.row(tok);
                    let pe = pos_emb.w.row(ti);
                    for (o, (a, b)) in dst.iter_mut().zip(te.iter().zip(pe)) {
                        *o = a + b;
                    }
                }
            }
        }

        for li in 0..blocks.len() {
            // Split the tape so layer li's fields and layer li+1's x_in
            // are simultaneously borrowable.
            let (cur, rest) = tape.layers[li..].split_first_mut().expect("layer tape");
            let blk = &mut blocks[li];
            // Attention sub-block.
            layer_norm_into(&cur.x_in, &blk.ln1.g.w, &blk.ln1.b.w, &mut cur.h1);
            {
                let lp = lora_pairs_get_mut(&mut lora_pairs, li, 0);
                linear_forward(&mut blk.qkv, lp, &cur.h1, &mut cur.qkv, &policy);
            }
            causal_attention_into(&cur.qkv, k, s, d, n_head, &mut ws.scores, &mut cur.att);
            {
                let lp = lora_pairs_get_mut(&mut lora_pairs, li, 1);
                linear_forward(&mut blk.proj, lp, &cur.att, &mut ws.fwd_branch, &policy);
            }
            ensure_out(&mut cur.x_mid, rows, d);
            cur.x_mid.data.copy_from_slice(&cur.x_in.data);
            add_inplace(&mut cur.x_mid, &ws.fwd_branch);
            // MLP sub-block.
            layer_norm_into(&cur.x_mid, &blk.ln2.g.w, &blk.ln2.b.w, &mut cur.h2);
            {
                let lp = lora_pairs_get_mut(&mut lora_pairs, li, 2);
                linear_forward(&mut blk.up, lp, &cur.h2, &mut cur.up, &policy);
            }
            ensure_out(&mut cur.gel, rows, cur.up.cols);
            for (o, x) in cur.gel.data.iter_mut().zip(&cur.up.data) {
                *o = gelu_tanh(*x);
            }
            {
                let lp = lora_pairs_get_mut(&mut lora_pairs, li, 3);
                linear_forward(&mut blk.down, lp, &cur.gel, &mut ws.fwd_branch, &policy);
            }
            let x_next: &mut Matrix = match rest.first_mut() {
                Some(next) => &mut next.x_in,
                None => &mut tape.x_out,
            };
            ensure_out(x_next, rows, d);
            x_next.data.copy_from_slice(&cur.x_mid.data);
            add_inplace(x_next, &ws.fwd_branch);
        }

        layer_norm_into(&tape.x_out, &lnf.g.w, &lnf.b.w, &mut tape.hf);
        ensure_out(&mut tape.logits, rows, vocab);
        gemm_nt_into(&tape.hf, &tok_emb.w, &mut tape.logits, &policy);
        Ok(())
    }

    /// Mean NLL over all `B·S` positions; when `fill_dlogits`, also write
    /// `(softmax − onehot)/(B·S)` into `ws.dlogits`.
    fn loss_and_dlogits(&mut self, tokens: &[i32], b: usize, fill_dlogits: bool) -> f32 {
        let (s, vocab) = (self.cfg.seq_len, self.cfg.vocab_size);
        let s1 = s + 1;
        let rows = b * s;
        let inv_rows = 1.0 / rows as f32;
        if fill_dlogits {
            ensure_out(&mut self.ws.dlogits, rows, vocab);
        }
        let mut loss = 0.0f64;
        for bi in 0..b {
            for ti in 0..s {
                let r = bi * s + ti;
                let tgt = tokens[bi * s1 + ti + 1] as usize;
                let lrow = self.tape.logits.row(r);
                let mut maxv = f32::NEG_INFINITY;
                for &v in lrow {
                    if v > maxv {
                        maxv = v;
                    }
                }
                let mut denom = 0.0f32;
                for &v in lrow {
                    denom += (v - maxv).exp();
                }
                loss -= ((lrow[tgt] - maxv) as f64) - (denom as f64).ln();
                if fill_dlogits {
                    let inv = inv_rows / denom;
                    let drow = self.ws.dlogits.row_mut(r);
                    for (o, &v) in drow.iter_mut().zip(lrow) {
                        *o = (v - maxv).exp() * inv;
                    }
                    drow[tgt] -= inv_rows;
                }
            }
        }
        (loss / rows as f64) as f32
    }

    /// Reverse pass over the tape: fills every parameter gradient.
    fn backward(&mut self, k: usize, with_lora: bool) {
        let (d, s, n_head) = (self.cfg.d_model, self.cfg.seq_len, self.cfg.n_head);
        let rows = k * s;
        let policy = self.policy;
        let Self { tape, ws, blocks, lora, tok_emb, pos_emb, lnf, fwd_tokens, .. } = self;
        let mut lora_pairs = lora.as_mut().filter(|_| with_lora).map(|l| &mut l.pairs);

        // Tied head: ∇tok_emb ← dlogitsᵀ·hf (overwrite), scatter added later.
        gemm_tn_into(&ws.dlogits, &tape.hf, &mut tok_emb.g, &policy);
        // d_hf = dlogits · tok_emb.
        ensure_out(&mut ws.d_hf, rows, d);
        gemm_into(&ws.dlogits, &tok_emb.w, &mut ws.d_hf, &policy);
        // Final layer norm.
        ln_backward(&tape.x_out, &ws.d_hf, &lnf.g.w, &mut lnf.g.g, &mut lnf.b.g,
                    &mut ws.d_res);

        for li in (0..blocks.len()).rev() {
            let cur = &tape.layers[li];
            let blk = &mut blocks[li];
            // MLP branch: d_res holds dx_out = dx_mid (residual) and the
            // branch gradient feeding `down`.
            {
                let lp = lora_pairs_get_mut(&mut lora_pairs, li, 3);
                linear_backward(&mut blk.down, lp, &cur.gel, &ws.d_res, &mut ws.d_gel,
                                &mut ws.d_t, &mut ws.gw_pool, &mut ws.lin_pool, &policy);
            }
            ensure_out(&mut ws.d_up, rows, cur.up.cols);
            for (o, (g, x)) in ws.d_up.data.iter_mut().zip(ws.d_gel.data.iter().zip(&cur.up.data))
            {
                *o = g * gelu_tanh_grad(*x);
            }
            {
                let lp = lora_pairs_get_mut(&mut lora_pairs, li, 2);
                linear_backward(&mut blk.up, lp, &cur.h2, &ws.d_up, &mut ws.d_h2,
                                &mut ws.d_t, &mut ws.gw_pool, &mut ws.lin_pool, &policy);
            }
            ln_backward(&cur.x_mid, &ws.d_h2, &blk.ln2.g.w, &mut blk.ln2.g.g,
                        &mut blk.ln2.b.g, &mut ws.d_branch);
            add_inplace(&mut ws.d_res, &ws.d_branch);
            // Attention branch: d_res now holds dx_mid.
            {
                let lp = lora_pairs_get_mut(&mut lora_pairs, li, 1);
                linear_backward(&mut blk.proj, lp, &cur.att, &ws.d_res, &mut ws.d_att,
                                &mut ws.d_t, &mut ws.gw_pool, &mut ws.lin_pool, &policy);
            }
            attention_backward(&cur.qkv, &ws.d_att, k, s, d, n_head, &mut ws.scores,
                               &mut ws.att_dw, &mut ws.att_dq, &mut ws.d_qkv);
            {
                let lp = lora_pairs_get_mut(&mut lora_pairs, li, 0);
                linear_backward(&mut blk.qkv, lp, &cur.h1, &ws.d_qkv, &mut ws.d_h1,
                                &mut ws.d_t, &mut ws.gw_pool, &mut ws.lin_pool, &policy);
            }
            ln_backward(&cur.x_in, &ws.d_h1, &blk.ln1.g.w, &mut blk.ln1.g.g,
                        &mut blk.ln1.b.g, &mut ws.d_branch);
            add_inplace(&mut ws.d_res, &ws.d_branch);
        }

        // Embedding scatter (tok_emb.g already holds the tied-head term;
        // `x = tok_emb[tok] + pos_emb[t]` routes d_res to both tables).
        pos_emb.g.data.fill(0.0);
        for bi in 0..k {
            for ti in 0..s {
                let r = bi * s + ti;
                let src = ws.d_res.row(r);
                let tok = fwd_tokens[r] as usize;
                for (o, v) in tok_emb.g.row_mut(tok).iter_mut().zip(src) {
                    *o += *v;
                }
                for (o, v) in pos_emb.g.row_mut(ti).iter_mut().zip(src) {
                    *o += *v;
                }
            }
        }
    }

    // ---- optimizer -----------------------------------------------------

    /// Linear warmup → cosine decay (python `lr_schedule`), bias
    /// corrections included.
    fn schedule(train: &TrainParams, step: f32) -> (f32, f32, f32) {
        let warm = (step / (train.warmup_steps.max(1) as f32)).min(1.0);
        let denom = (train.total_steps as f32 - train.warmup_steps as f32).max(1.0);
        let prog = ((step - train.warmup_steps as f32) / denom).clamp(0.0, 1.0);
        let cos = 0.55 + 0.45 * (std::f32::consts::PI * prog).cos();
        let lr = train.lr as f32 * warm * cos;
        let bc1 = 1.0 - (train.beta1 as f32).powf(step);
        let bc2 = 1.0 - (train.beta2 as f32).powf(step);
        (lr, bc1, bc2)
    }

    /// AdamW over the base parameters (masked updates for packed weights).
    fn adam_step_params(&mut self) {
        let mut sq = 0.0f64;
        self.for_each_param_grad(|g| sq += g.iter().map(|v| (*v as f64) * (*v as f64)).sum::<f64>());
        let gnorm = (sq + 1e-12).sqrt() as f32;
        let clip = (self.train.grad_clip as f32 / gnorm).min(1.0);
        self.opt_step += 1.0;
        let step = self.opt_step;
        let (lr, bc1, bc2) = Self::schedule(&self.train, step);
        let (b1, b2) = (self.train.beta1 as f32, self.train.beta2 as f32);
        let wd = self.train.weight_decay as f32;

        let upd_dense = |w: &mut [f32], g: &[f32], m: &mut [f32], v: &mut [f32], decay: f32| {
            for i in 0..w.len() {
                let gi = clip * g[i];
                m[i] = b1 * m[i] + (1.0 - b1) * gi;
                v[i] = b2 * v[i] + (1.0 - b2) * gi * gi;
                let upd = lr * ((m[i] / bc1) / ((v[i] / bc2).sqrt() + ADAM_EPS) + decay * w[i]);
                w[i] -= upd;
            }
        };

        upd_dense(&mut self.tok_emb.w.data, &self.tok_emb.g.data, &mut self.tok_emb.m.data,
                  &mut self.tok_emb.v.data, decay_of("tok_emb", wd));
        upd_dense(&mut self.pos_emb.w.data, &self.pos_emb.g.data, &mut self.pos_emb.m.data,
                  &mut self.pos_emb.v.data, 0.0);
        for np in [&mut self.lnf] {
            upd_dense(&mut np.g.w, &np.g.g, &mut np.g.m, &mut np.g.v, 0.0);
            upd_dense(&mut np.b.w, &np.b.g, &mut np.b.m, &mut np.b.v, 0.0);
        }
        for blk in &mut self.blocks {
            for np in [&mut blk.ln1, &mut blk.ln2] {
                upd_dense(&mut np.g.w, &np.g.g, &mut np.g.m, &mut np.g.v, 0.0);
                upd_dense(&mut np.b.w, &np.b.g, &mut np.b.m, &mut np.b.v, 0.0);
            }
            for lin in [&mut blk.qkv, &mut blk.proj, &mut blk.up, &mut blk.down] {
                let decay = decay_of(&lin.wsuffix, wd);
                match &mut lin.ops {
                    LinOps::Sparse(ops) => {
                        // Compressed-space AdamW: Algorithm 1 lines 15–18 —
                        // the (1/γ)·∇W + α·W combine and the masked update
                        // in one pass over the packed support.
                        for i in 0..ops.w.values.len() {
                            let gi = clip * ops.gw.values[i];
                            ops.m[i] = b1 * ops.m[i] + (1.0 - b1) * gi;
                            ops.v[i] = b2 * ops.v[i] + (1.0 - b2) * gi * gi;
                            let upd = lr
                                * ((ops.m[i] / bc1) / ((ops.v[i] / bc2).sqrt() + ADAM_EPS)
                                    + decay * ops.w.values[i]);
                            ops.w.values[i] -= upd;
                        }
                        ops.refresh_wt();
                        if let Some(pre) = &mut ops.pre {
                            pre.refresh_values(&ops.w);
                        }
                    }
                    LinOps::Dense(ops) => {
                        // python masks update AND moments by mask_r.
                        match &ops.mask_r {
                            Some(mask) => {
                                for i in 0..ops.w.data.len() {
                                    if !mask.keep[i] {
                                        ops.m.data[i] = 0.0;
                                        ops.v.data[i] = 0.0;
                                        continue;
                                    }
                                    let gi = clip * ops.gw.data[i];
                                    ops.m.data[i] = b1 * ops.m.data[i] + (1.0 - b1) * gi;
                                    ops.v.data[i] = b2 * ops.v.data[i] + (1.0 - b2) * gi * gi;
                                    let upd = lr
                                        * ((ops.m.data[i] / bc1)
                                            / ((ops.v.data[i] / bc2).sqrt() + ADAM_EPS)
                                            + decay * ops.w.data[i]);
                                    ops.w.data[i] -= upd;
                                }
                            }
                            None => upd_dense(&mut ops.w.data, &ops.gw.data, &mut ops.m.data,
                                              &mut ops.v.data, decay),
                        }
                        ops.refresh_masked();
                    }
                }
                upd_dense(&mut lin.bias.w, &lin.bias.g, &mut lin.bias.m, &mut lin.bias.v, 0.0);
            }
        }
    }

    /// AdamW over the adapters (their own clip + step counter).
    fn adam_step_lora(&mut self) {
        let Some(lora) = self.lora.as_mut() else {
            return;
        };
        let mut sq = 0.0f64;
        for pair in &lora.pairs {
            for g in [&pair.down.g, &pair.up.g] {
                sq += g.data.iter().map(|v| (*v as f64) * (*v as f64)).sum::<f64>();
            }
        }
        let gnorm = (sq + 1e-12).sqrt() as f32;
        let clip = (self.train.grad_clip as f32 / gnorm).min(1.0);
        lora.step += 1.0;
        let (lr, bc1, bc2) = Self::schedule(&self.train, lora.step);
        let (b1, b2) = (self.train.beta1 as f32, self.train.beta2 as f32);
        let wd = self.train.weight_decay as f32;
        for pair in &mut lora.pairs {
            for mp in [&mut pair.down, &mut pair.up] {
                let decay = decay_of(&mp.suffix, wd);
                for i in 0..mp.w.data.len() {
                    let gi = clip * mp.g.data[i];
                    mp.m.data[i] = b1 * mp.m.data[i] + (1.0 - b1) * gi;
                    mp.v.data[i] = b2 * mp.v.data[i] + (1.0 - b2) * gi * gi;
                    let upd = lr
                        * ((mp.m.data[i] / bc1) / ((mp.v.data[i] / bc2).sqrt() + ADAM_EPS)
                            + decay * mp.w.data[i]);
                    mp.w.data[i] -= upd;
                }
            }
        }
    }

    /// Visit every base-parameter gradient slice (clip-norm accumulation).
    fn for_each_param_grad(&self, mut f: impl FnMut(&[f32])) {
        f(&self.tok_emb.g.data);
        f(&self.pos_emb.g.data);
        f(&self.lnf.g.g);
        f(&self.lnf.b.g);
        for blk in &self.blocks {
            for np in [&blk.ln1, &blk.ln2] {
                f(&np.g.g);
                f(&np.b.g);
            }
            for lin in [&blk.qkv, &blk.proj, &blk.up, &blk.down] {
                match &lin.ops {
                    LinOps::Sparse(ops) => f(&ops.gw.values),
                    LinOps::Dense(ops) => f(&ops.gw.data),
                }
                f(&lin.bias.g);
            }
        }
    }
}

impl HostTrainModel {
    // ---- store export / inspection -------------------------------------

    /// Write every `params.*` plane (dense shapes; packed weights are
    /// decompressed through the export scratch).
    pub fn export_params(&mut self, store: &mut Store) -> crate::Result<()> {
        let put_mat = |store: &mut Store, suffix: &str, m: &Matrix| {
            store.put_f32(&format!("params.{suffix}"), &[m.rows, m.cols], &m.data)
        };
        put_mat(store, &self.tok_emb.suffix, &self.tok_emb.w)?;
        put_mat(store, &self.pos_emb.suffix, &self.pos_emb.w)?;
        for vp in [&self.lnf.g, &self.lnf.b] {
            store.put_f32(&format!("params.{}", vp.suffix), &[vp.w.len()], &vp.w)?;
        }
        // Split borrows: the export scratch lives in ws.
        let Self { ws, blocks, .. } = self;
        for blk in blocks.iter() {
            for np in [&blk.ln1, &blk.ln2] {
                for vp in [&np.g, &np.b] {
                    store.put_f32(&format!("params.{}", vp.suffix), &[vp.w.len()], &vp.w)?;
                }
            }
            for lin in [&blk.qkv, &blk.proj, &blk.up, &blk.down] {
                match &lin.ops {
                    LinOps::Sparse(ops) => {
                        ops.w.decompress_into(&mut ws.export);
                        store.put_f32(&format!("params.{}", lin.wsuffix),
                                      &[lin.d_out, lin.d_in], &ws.export.data)?;
                    }
                    LinOps::Dense(ops) => {
                        store.put_f32(&format!("params.{}", lin.wsuffix),
                                      &[lin.d_out, lin.d_in], &ops.w.data)?;
                    }
                }
                store.put_f32(&format!("params.{}", lin.bias.suffix),
                              &[lin.bias.w.len()], &lin.bias.w)?;
            }
        }
        Ok(())
    }

    /// Write every `opt.m.*` / `opt.v.*` plane plus `opt.step` (packed
    /// moments scatter to their dense shapes).
    pub fn export_opt(&mut self, store: &mut Store) -> crate::Result<()> {
        store.put_f32("opt.step", &[], &[self.opt_step])?;
        let put_mat = |store: &mut Store, plane: &str, suffix: &str, m: &Matrix| {
            store.put_f32(&format!("opt.{plane}.{suffix}"), &[m.rows, m.cols], &m.data)
        };
        for mp in [&self.tok_emb, &self.pos_emb] {
            put_mat(store, "m", &mp.suffix, &mp.m)?;
            put_mat(store, "v", &mp.suffix, &mp.v)?;
        }
        let put_vec = |store: &mut Store, vp: &VecParam| -> crate::Result<()> {
            store.put_f32(&format!("opt.m.{}", vp.suffix), &[vp.m.len()], &vp.m)?;
            store.put_f32(&format!("opt.v.{}", vp.suffix), &[vp.v.len()], &vp.v)?;
            Ok(())
        };
        put_vec(store, &self.lnf.g)?;
        put_vec(store, &self.lnf.b)?;
        let Self { ws, blocks, .. } = self;
        for blk in blocks.iter() {
            for np in [&blk.ln1, &blk.ln2] {
                put_vec(store, &np.g)?;
                put_vec(store, &np.b)?;
            }
            for lin in [&blk.qkv, &blk.proj, &blk.up, &blk.down] {
                match &lin.ops {
                    LinOps::Sparse(ops) => {
                        for (plane, vals) in [("m", &ops.m), ("v", &ops.v)] {
                            ops.scatter(vals, &mut ws.export);
                            store.put_f32(&format!("opt.{plane}.{}", lin.wsuffix),
                                          &[lin.d_out, lin.d_in], &ws.export.data)?;
                        }
                    }
                    LinOps::Dense(ops) => {
                        put_mat(store, "m", &lin.wsuffix, &ops.m)?;
                        put_mat(store, "v", &lin.wsuffix, &ops.v)?;
                    }
                }
                put_vec(store, &lin.bias)?;
            }
        }
        Ok(())
    }

    /// Write every `masks.*` plane.  Packed routes re-derive the masks
    /// from the pattern planes (no dense masks are retained); dense
    /// routes write their stored masks (ones when trivial).
    pub fn export_masks(&mut self, store: &mut Store) -> crate::Result<()> {
        let Self { ws, blocks, .. } = self;
        for blk in blocks.iter() {
            for lin in [&blk.qkv, &blk.proj, &blk.up, &blk.down] {
                for (kind, rc) in [("_r", false), ("_rc", true)] {
                    let name = format!("masks.{}{kind}", lin.wsuffix);
                    match &lin.ops {
                        LinOps::Sparse(ops) => {
                            if rc {
                                ops.mask_rc_dense(&mut ws.export);
                            } else {
                                ops.mask_r_dense(&mut ws.export);
                            }
                            store.put_f32(&name, &[lin.d_out, lin.d_in], &ws.export.data)?;
                        }
                        LinOps::Dense(ops) => {
                            let mask = if rc { &ops.mask_rc } else { &ops.mask_r };
                            ensure_out(&mut ws.export, lin.d_out, lin.d_in);
                            match mask {
                                Some(m) => {
                                    for (o, k) in ws.export.data.iter_mut().zip(&m.keep) {
                                        *o = if *k { 1.0 } else { 0.0 };
                                    }
                                }
                                None => ws.export.data.fill(1.0),
                            }
                            store.put_f32(&name, &[lin.d_out, lin.d_in], &ws.export.data)?;
                        }
                    }
                }
            }
        }
        Ok(())
    }

    /// Write every `lora.*` / `lora_opt.*` plane (no-op when adapters are
    /// not initialized).
    pub fn export_lora(&mut self, store: &mut Store) -> crate::Result<()> {
        let Some(lora) = &self.lora else {
            return Ok(());
        };
        store.put_f32("lora_opt.step", &[], &[lora.step])?;
        for pair in &lora.pairs {
            for mp in [&pair.down, &pair.up] {
                store.put_f32(&format!("lora.{}", mp.suffix),
                              &[mp.w.rows, mp.w.cols], &mp.w.data)?;
                store.put_f32(&format!("lora_opt.m.{}", mp.suffix),
                              &[mp.m.rows, mp.m.cols], &mp.m.data)?;
                store.put_f32(&format!("lora_opt.v.{}", mp.suffix),
                              &[mp.v.rows, mp.v.cols], &mp.v.data)?;
            }
        }
        Ok(())
    }

    /// Dense-shaped gradient of one parameter by store suffix (packed
    /// gradients scatter; `lora.`-prefixed suffixes address adapters) —
    /// the finite-difference suite's probe point.  `None` for unknown
    /// names.
    pub fn grad_dense(&self, suffix: &str) -> Option<Matrix> {
        if let Some(rest) = suffix.strip_prefix("lora.") {
            let lora = self.lora.as_ref()?;
            for pair in &lora.pairs {
                for mp in [&pair.down, &pair.up] {
                    if mp.suffix == rest {
                        return Some(mp.g.clone());
                    }
                }
            }
            return None;
        }
        match suffix {
            "tok_emb" => return Some(self.tok_emb.g.clone()),
            "pos_emb" => return Some(self.pos_emb.g.clone()),
            "lnf_g" => return Some(vec_as_row(&self.lnf.g.g)),
            "lnf_b" => return Some(vec_as_row(&self.lnf.b.g)),
            _ => {}
        }
        for blk in &self.blocks {
            for np in [&blk.ln1, &blk.ln2] {
                for vp in [&np.g, &np.b] {
                    if vp.suffix == suffix {
                        return Some(vec_as_row(&vp.g));
                    }
                }
            }
            for lin in [&blk.qkv, &blk.proj, &blk.up, &blk.down] {
                if lin.bias.suffix == suffix {
                    return Some(vec_as_row(&lin.bias.g));
                }
                if lin.wsuffix == suffix {
                    return Some(match &lin.ops {
                        LinOps::Sparse(ops) => {
                            let mut out = Matrix::zeros(0, 0);
                            ops.scatter(&ops.gw.values, &mut out);
                            out
                        }
                        LinOps::Dense(ops) => ops.gw.clone(),
                    });
                }
            }
        }
        None
    }

    /// Live training-state byte accounting (module docs; the measured
    /// side of `memmodel::host_train_bits_per_elem`).
    pub fn state_bytes(&self) -> TrainStateBytes {
        let mut pruned = 0usize;
        let mut pruned_dense = 0usize;
        let mut rest = 0usize;
        let vec_state = |v: &VecParam| (v.w.len() + v.g.len() + v.m.len() + v.v.len()) * 4;
        let mat_state = |m: &MatParam| {
            (m.w.data.len() + m.g.data.len() + m.m.data.len() + m.v.data.len()) * 4
        };
        rest += mat_state(&self.tok_emb) + mat_state(&self.pos_emb);
        rest += vec_state(&self.lnf.g) + vec_state(&self.lnf.b);
        for blk in &self.blocks {
            for np in [&blk.ln1, &blk.ln2] {
                rest += vec_state(&np.g) + vec_state(&np.b);
            }
            for lin in [&blk.qkv, &blk.proj, &blk.up, &blk.down] {
                rest += vec_state(&lin.bias);
                match &lin.ops {
                    LinOps::Sparse(ops) => {
                        let plane = |c: &CompressedNm| c.values.len() * 4 + c.meta.len();
                        pruned += plane(&ops.w) + plane(&ops.w_t) + plane(&ops.gw);
                        pruned += ops.wt_pad.len() * 8;
                        pruned += (ops.m.len() + ops.v.len()) * 4;
                        if let Some(pre) = &ops.pre {
                            pruned += pre.stream_bytes();
                        }
                        pruned_dense += lin.d_out * lin.d_in * 4 * 4;
                    }
                    LinOps::Dense(ops) => {
                        rest += (ops.w.data.len()
                            + ops.gw.data.len()
                            + ops.m.data.len()
                            + ops.v.data.len())
                            * 4;
                        rest += (ops.wm_r.data.len() + ops.wm_rc.data.len()) * 4;
                    }
                }
            }
        }
        if let Some(lora) = &self.lora {
            for pair in &lora.pairs {
                rest += mat_state(&pair.down) + mat_state(&pair.up);
            }
        }
        TrainStateBytes {
            pruned_bytes: pruned,
            pruned_dense_bytes: pruned_dense,
            dense_rest_bytes: rest,
            workspace_bytes: self.ws.gw_pool.bytes() + self.ws.lin_pool.bytes(),
        }
    }
}

fn vec_as_row(v: &[f32]) -> Matrix {
    Matrix::from_vec(1, v.len(), v.to_vec())
}

/// Mutable access to the adapter pair of `(layer, SPARSE_WEIGHTS index)`
/// when adapters are active (`None` otherwise).
fn lora_pairs_get_mut<'a>(pairs: &'a mut Option<&mut Vec<LoraPair>>, li: usize,
                          wi: usize) -> Option<&'a mut LoraPair> {
    pairs.as_mut().map(|p| &mut p[li * SPARSE_WEIGHTS.len() + wi])
}

fn norm_param(gsuffix: &str, bsuffix: &str, g: Vec<f32>, b: Vec<f32>) -> NormParam {
    NormParam { g: VecParam::new(gsuffix, g), b: VecParam::new(bsuffix, b) }
}

fn norm_param_from_store(store: &Store, gsuffix: &str, bsuffix: &str, g: Vec<f32>,
                         b: Vec<f32>, expect: usize) -> crate::Result<NormParam> {
    crate::ensure!(
        g.len() == expect && b.len() == expect,
        "params.{gsuffix}/{bsuffix}: length {}/{} != {expect}",
        g.len(), b.len()
    );
    let mut np = norm_param(gsuffix, bsuffix, g, b);
    ingest_moments_vec(store, &mut np.g)?;
    ingest_moments_vec(store, &mut np.b)?;
    Ok(np)
}

fn mat_param_from_store(store: &Store, suffix: &str, rows: usize,
                        cols: usize) -> crate::Result<MatParam> {
    let w = store.read_matrix(&format!("params.{suffix}"))?;
    crate::ensure!(
        (w.rows, w.cols) == (rows, cols),
        "params.{suffix} is {}x{}, expected {rows}x{cols}",
        w.rows, w.cols
    );
    let mut mp = MatParam::new(suffix, w);
    ingest_moments_mat(store, &mut mp)?;
    Ok(mp)
}

fn ingest_moments_vec(store: &Store, p: &mut VecParam) -> crate::Result<()> {
    for (plane, dst_is_m) in [("m", true), ("v", false)] {
        let name = format!("opt.{plane}.{}", p.suffix);
        if store.contains(&name) {
            let v = store.read_f32(&name)?;
            crate::ensure!(v.len() == p.w.len(), "{name} length mismatch");
            if dst_is_m {
                p.m = v;
            } else {
                p.v = v;
            }
        }
    }
    Ok(())
}

fn ingest_moments_mat(store: &Store, p: &mut MatParam) -> crate::Result<()> {
    for (plane, dst_is_m) in [("m", true), ("v", false)] {
        let name = format!("opt.{plane}.{}", p.suffix);
        if store.contains(&name) {
            let v = store.read_matrix(&name)?;
            crate::ensure!(
                (v.rows, v.cols) == (p.w.rows, p.w.cols),
                "{name} shape mismatch"
            );
            if dst_is_m {
                p.m = v;
            } else {
                p.v = v;
            }
        }
    }
    Ok(())
}

fn read_mask(store: &Store, name: &str, rows: usize, cols: usize)
             -> crate::Result<Option<Mask>> {
    if !store.contains(name) {
        return Ok(None);
    }
    let mm = store.read_matrix(name)?;
    crate::ensure!(
        (mm.rows, mm.cols) == (rows, cols),
        "{name} is {}x{}, weight is {rows}x{cols}",
        mm.rows, mm.cols
    );
    Ok(Some(Mask { rows, cols, keep: mm.data.iter().map(|v| *v != 0.0).collect() }))
}

fn ingest_lora(c: &ModelConfig, store: &Store) -> crate::Result<Option<LoraState>> {
    let any = store.names().iter().any(|n| n.starts_with("lora."));
    if !any {
        return Ok(None);
    }
    let r = c.adapter_rank;
    crate::ensure!(r > 0, "store carries lora.* planes but adapter_rank is 0");
    let mut pairs = Vec::with_capacity(c.n_layer * SPARSE_WEIGHTS.len());
    for layer in 0..c.n_layer {
        for wname in SPARSE_WEIGHTS {
            let (d_out, d_in) = weight_dims(c, wname);
            let wsuffix = format!("blocks.{layer}.{wname}");
            let down = store.read_matrix(&format!("lora.{wsuffix}_down"))?;
            let up = store.read_matrix(&format!("lora.{wsuffix}_up"))?;
            crate::ensure!(
                (down.rows, down.cols) == (r, d_in) && (up.rows, up.cols) == (d_out, r),
                "lora factors for {wsuffix} do not fit ({}x{} / {}x{})",
                down.rows, down.cols, up.rows, up.cols
            );
            let mut dp = MatParam::new(&format!("{wsuffix}_down"), down);
            let mut upp = MatParam::new(&format!("{wsuffix}_up"), up);
            for (plane, p) in [("lora_opt.m", 0), ("lora_opt.v", 1)] {
                for mp in [&mut dp, &mut upp] {
                    let name = format!("{plane}.{}", mp.suffix);
                    if store.contains(&name) {
                        let v = store.read_matrix(&name)?;
                        crate::ensure!(
                            (v.rows, v.cols) == (mp.w.rows, mp.w.cols),
                            "{name} shape mismatch"
                        );
                        if p == 0 {
                            mp.m = v;
                        } else {
                            mp.v = v;
                        }
                    }
                }
            }
            pairs.push(LoraPair { down: dp, up: upp, t: Matrix::zeros(0, 0), wsuffix });
        }
    }
    let step = if store.contains("lora_opt.step") {
        store.read_scalar_f32("lora_opt.step")?
    } else {
        0.0
    };
    Ok(Some(LoraState { pairs, step }))
}

/// Assemble one block linear, choosing the packed or dense route.
#[allow(clippy::too_many_arguments)]
fn build_linear(wsuffix: &str, bsuffix: &str, w: Matrix, bias: Vec<f32>,
                mask_r: Option<Mask>, mask_rc: Option<Mask>, scheme: NmScheme,
                store: Option<&Store>) -> crate::Result<TrainLinear> {
    let (d_out, d_in) = (w.rows, w.cols);
    let sparse_ok = match (&mask_r, &mask_rc) {
        (Some(mr), Some(mrc)) => {
            d_in % scheme.m == 0
                && d_out % scheme.m == 0
                && mr.is_exact_row_nm(scheme)
                && mrc.check_col_nm(scheme)
                && subset(mrc, mr)
        }
        _ => false,
    };
    let m_name = format!("opt.m.{wsuffix}");
    let v_name = format!("opt.v.{wsuffix}");
    let read_moment = |name: &str| -> crate::Result<Option<Matrix>> {
        match store {
            Some(st) if st.contains(name) => {
                let v = st.read_matrix(name)?;
                crate::ensure!((v.rows, v.cols) == (d_out, d_in), "{name} shape mismatch");
                Ok(Some(v))
            }
            _ => Ok(None),
        }
    };
    let m_dense = read_moment(&m_name)?;
    let v_dense = read_moment(&v_name)?;

    let ops = if sparse_ok {
        let mr = mask_r.expect("checked");
        let mrc = mask_rc.expect("checked");
        // Project (training state stores weights on the support).
        let wp = mr.apply(&w);
        let w_c = CompressedNm::compress(&wp, &mr, scheme);
        // Transpose view for BWD-2: rows of W^{R,C}ᵀ are columns of W.
        let wt_dense = mrc.apply(&wp).transpose();
        let mrc_t = Mask {
            rows: mrc.cols,
            cols: mrc.rows,
            keep: {
                let mt = mrc.to_matrix().transpose();
                mt.data.iter().map(|v| *v != 0.0).collect()
            },
        };
        let w_t = CompressedNm::compress(&wt_dense, &mrc_t, scheme);
        // Pad bitset: slots whose decoded column is not kept by mask_rc_t.
        let kc_t = w_t.kcols();
        let n_slots = w_t.rows * kc_t;
        let mut wt_pad = vec![0u64; (n_slots + 63) / 64];
        for rt in 0..w_t.rows {
            for (k, ct) in w_t.row_indices(rt).enumerate() {
                if !mrc_t.at(rt, ct) {
                    let slot = rt * kc_t + k;
                    wt_pad[slot / 64] |= 1 << (slot % 64);
                }
            }
        }
        let nnz = w_c.values.len();
        let gather = |dense: Option<Matrix>| -> Vec<f32> {
            match dense {
                None => vec![0.0; nnz],
                Some(dm) => {
                    let kc = w_c.kcols();
                    let mut out = vec![0.0; nnz];
                    for r in 0..w_c.rows {
                        for (k, c) in w_c.row_indices(r).enumerate() {
                            out[r * kc + k] = dm.data[r * w_c.cols + c];
                        }
                    }
                    out
                }
            }
        };
        let m_packed = gather(m_dense);
        let v_packed = gather(v_dense);
        drop(gather);
        let gw = w_c.clone();
        // Prepack the forward operand once at ingest; subsequent steps
        // only rewrite the value slots (`refresh_values`).
        let pre = prepack_enabled().then(|| PrepackedNm::prepack(&w_c));
        LinOps::Sparse(SparseOps {
            scheme,
            m: m_packed,
            v: v_packed,
            w: w_c,
            w_t,
            wt_pad,
            gw,
            pre,
        })
    } else {
        // All-ones masks are trivial: drop them so the dense route runs
        // unmasked (the dense baseline's fast path).  A present mask_r
        // without a usable mask_rc falls back to mask_r for the backward
        // operand — the exact gradient of the masked forward.
        let trivial = |m: &Option<Mask>| m.as_ref().map(|x| x.keep.iter().all(|k| *k));
        let mask_r = if trivial(&mask_r) == Some(true) { None } else { mask_r };
        let mask_rc = if trivial(&mask_rc) == Some(true) { None } else { mask_rc };
        let mask_rc = mask_rc.or_else(|| mask_r.clone());
        let mut ops = DenseOps {
            gw: Matrix::zeros(d_out, d_in),
            m: m_dense.unwrap_or_else(|| Matrix::zeros(d_out, d_in)),
            v: v_dense.unwrap_or_else(|| Matrix::zeros(d_out, d_in)),
            wm_r: Matrix::zeros(0, 0),
            wm_rc: Matrix::zeros(0, 0),
            mask_r,
            mask_rc,
            w,
        };
        ops.refresh_masked();
        LinOps::Dense(ops)
    };
    Ok(TrainLinear {
        wsuffix: wsuffix.into(),
        d_out,
        d_in,
        ops,
        bias: VecParam::new(bsuffix, bias),
    })
}

fn subset(inner: &Mask, outer: &Mask) -> bool {
    inner.keep.iter().zip(&outer.keep).all(|(i, o)| !*i || *o)
}

/// `y = x·Wᵀ (+ x·Rᵀ·Lᵀ) + b` — Eq. 4 through the packed SpMM on the
/// sparse route; the rank intermediate `T` is taped on the pair.
fn linear_forward(lin: &mut TrainLinear, lora: Option<&mut LoraPair>, x: &Matrix,
                  y: &mut Matrix, policy: &ParallelPolicy) {
    ensure_out(y, x.rows, lin.d_out);
    match &lin.ops {
        LinOps::Sparse(SparseOps { pre: Some(p), .. }) => spmm_prepacked_into(x, p, y, policy),
        LinOps::Sparse(ops) => spmm_rowmajor_into(x, &ops.w, y, policy),
        LinOps::Dense(ops) => gemm_nt_into(x, ops.fwd_operand(), y, policy),
    }
    if let Some(pair) = lora {
        ensure_out(&mut pair.t, x.rows, pair.down.w.rows);
        gemm_nt_into(x, &pair.down.w, &mut pair.t, policy);
        gemm_nt_acc_into(&pair.t, &pair.up.w, y, policy);
    }
    for r in 0..y.rows {
        for (v, b) in y.row_mut(r).iter_mut().zip(&lin.bias.w) {
            *v += *b;
        }
    }
}

/// The paper's custom VJP for one linear (+ plain autodiff for the
/// adapter factors):
/// * `∇X = ∇Y · W^{R,C}` — packed SpMM through the compressed transpose
///   (Eq. 6; the dense route multiplies by `mask_rc ⊙ W`);
/// * `∇W = (∇Yᵀ·X) ⊙ mask_r`, packed (Eq. 5 / line 13);
/// * `∇b = Σ_rows ∇Y`;
/// * adapters: `∇L = ∇Yᵀ·T`, `∇R = ∇Tᵀ·X`, `∇X += ∇T·R`.
#[allow(clippy::too_many_arguments)]
fn linear_backward(lin: &mut TrainLinear, lora: Option<&mut LoraPair>, x: &Matrix,
                   dy: &Matrix, dx: &mut Matrix, d_t: &mut Matrix, gw_pool: &mut ShapePool,
                   lin_pool: &mut ShapePool, policy: &ParallelPolicy) {
    ensure_out(dx, dy.rows, lin.d_in);
    // BWD-2 (Eq. 6).
    match &mut lin.ops {
        LinOps::Sparse(ops) => spmm_rowmajor_into(dy, &ops.w_t, dx, policy),
        LinOps::Dense(ops) => gemm_into(dy, ops.bwd_operand(), dx, policy),
    }
    // BWD-1 (Eq. 5 + line 13): dense staging shared across linears.
    let stage = gw_pool.get(lin.d_out, lin.d_in);
    gemm_tn_into(dy, x, stage, policy);
    match &mut lin.ops {
        LinOps::Sparse(ops) => prune_and_compress_into(stage, &ops.w, &mut ops.gw),
        LinOps::Dense(ops) => {
            ops.gw.data.copy_from_slice(&stage.data);
            if let Some(mask) = &ops.mask_r {
                for (g, k) in ops.gw.data.iter_mut().zip(&mask.keep) {
                    if !*k {
                        *g = 0.0;
                    }
                }
            }
        }
    }
    // Bias.
    lin.bias.g.fill(0.0);
    for r in 0..dy.rows {
        for (gb, v) in lin.bias.g.iter_mut().zip(dy.row(r)) {
            *gb += *v;
        }
    }
    // Adapters (dense autodiff, matching python's matmul/matmul_add VJPs).
    if let Some(pair) = lora {
        ensure_out(d_t, dy.rows, pair.down.w.rows);
        gemm_into(dy, &pair.up.w, d_t, policy);
        gemm_tn_into(dy, &pair.t, &mut pair.up.g, policy);
        gemm_tn_into(d_t, x, &mut pair.down.g, policy);
        let stage = lin_pool.get(dy.rows, lin.d_in);
        gemm_into(d_t, &pair.down.w, stage, policy);
        add_inplace(dx, stage);
    }
}

/// Layer-norm backward (ε = 1e-5, mirroring [`layer_norm_into`]):
/// `dx = inv·(dŷ − mean(dŷ) − x̂·mean(dŷ⊙x̂))` with `dŷ = dy⊙g`;
/// `∇g = Σ dy⊙x̂`, `∇b = Σ dy` (overwritten each call — every norm is
/// used exactly once per step).
fn ln_backward(x: &Matrix, dy: &Matrix, g: &[f32], gg: &mut [f32], gb: &mut [f32],
               dx: &mut Matrix) {
    ensure_out(dx, x.rows, x.cols);
    gg.fill(0.0);
    gb.fill(0.0);
    let n = x.cols as f32;
    for r in 0..x.rows {
        let xr = x.row(r);
        let dyr = dy.row(r);
        let mut mu = 0.0f32;
        for v in xr {
            mu += *v;
        }
        mu /= n;
        let mut var = 0.0f32;
        for v in xr {
            let dv = *v - mu;
            var += dv * dv;
        }
        var /= n;
        let inv = 1.0 / (var + 1e-5).sqrt();
        let mut s1 = 0.0f32;
        let mut s2 = 0.0f32;
        for j in 0..xr.len() {
            let xh = (xr[j] - mu) * inv;
            let dxh = dyr[j] * g[j];
            s1 += dxh;
            s2 += dxh * xh;
            gg[j] += dyr[j] * xh;
            gb[j] += dyr[j];
        }
        s1 /= n;
        s2 /= n;
        let dxr = dx.row_mut(r);
        for j in 0..xr.len() {
            let xh = (xr[j] - mu) * inv;
            let dxh = dyr[j] * g[j];
            dxr[j] = inv * (dxh - s1 - xh * s2);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::host::write_host_train_artifact;

    fn fixture(tag: &str) -> (std::path::PathBuf, Manifest) {
        let dir = std::env::temp_dir().join(format!("slope_host_train_unit_{tag}"));
        std::fs::remove_dir_all(&dir).ok();
        write_host_train_artifact(&dir, &format!("unit-{tag}")).unwrap();
        let manifest = Manifest::load(&dir).unwrap();
        (dir, manifest)
    }

    /// Assert the Eq.-6 operand invariant for every packed linear:
    /// `decompress(w_t)ᵀ == mask_rc ⊙ decompress(w)` — i.e. the BWD-2
    /// SpMM operand IS `W^{R,C}`, pads contributing exact zeros.
    /// Returns how many packed linears were checked.
    fn assert_wt_invariant(model: &HostTrainModel) -> usize {
        let mut checked = 0;
        for blk in &model.blocks {
            for lin in [&blk.qkv, &blk.proj, &blk.up, &blk.down] {
                let LinOps::Sparse(ops) = &lin.ops else {
                    continue;
                };
                let w_dense = ops.w.decompress();
                let mut mask_rc = Matrix::zeros(0, 0);
                ops.mask_rc_dense(&mut mask_rc);
                let want = w_dense.hadamard(&mask_rc);
                let got = ops.w_t.decompress().transpose();
                assert_eq!(
                    got.data, want.data,
                    "{}: w_t is not the packed W^(R,C) transpose",
                    lin.wsuffix
                );
                checked += 1;
            }
        }
        checked
    }

    #[test]
    fn packed_bwd2_operand_is_masked_transpose_at_init_and_after_steps() {
        let (dir, manifest) = fixture("wtpin");
        let mut model = HostTrainModel::init(&manifest, 3, ParallelPolicy::serial()).unwrap();
        // The invariant holds at construction...
        let linears = assert_wt_invariant(&model);
        assert_eq!(linears, 2 * 4 - 1, "layer-0 qkv stays dense");
        // ...and double pruning is active (mask_rc strictly below mask_r
        // somewhere), so the pin is not vacuous.
        let mut removed = 0usize;
        for blk in &model.blocks {
            for lin in [&blk.qkv, &blk.proj, &blk.up, &blk.down] {
                if let LinOps::Sparse(ops) = &lin.ops {
                    let mut mr = Matrix::zeros(0, 0);
                    let mut mrc = Matrix::zeros(0, 0);
                    ops.mask_r_dense(&mut mr);
                    ops.mask_rc_dense(&mut mrc);
                    removed += mr
                        .data
                        .iter()
                        .zip(&mrc.data)
                        .filter(|(r, rc)| **r == 1.0 && **rc == 0.0)
                        .count();
                }
            }
        }
        assert!(removed > 0, "double pruning removed nothing");
        // ...and survives optimizer updates (the refresh_wt scan-gather).
        let c = &manifest.config;
        let mut rng = Rng::seed_from_u64(1);
        let tokens: Vec<i32> = (0..c.batch_size * (c.seq_len + 1))
            .map(|_| rng.below(c.vocab_size) as i32)
            .collect();
        for _ in 0..3 {
            model.train_step(&tokens).unwrap();
        }
        assert_wt_invariant(&model);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn out_of_vocab_targets_error_instead_of_panicking() {
        let (dir, manifest) = fixture("badtgt");
        let c = manifest.config.clone();
        let mut model = HostTrainModel::init(&manifest, 5, ParallelPolicy::serial()).unwrap();
        // Valid inputs, poisoned final (target-only) column.
        let mut tokens = vec![1i32; c.batch_size * (c.seq_len + 1)];
        tokens[c.seq_len] = c.vocab_size as i32; // row 0's last column
        assert!(model.eval_loss(&tokens, false).is_err());
        assert!(model.train_step(&tokens).is_err());
        tokens[c.seq_len] = -3;
        assert!(model.eval_loss(&tokens, false).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}

/// Causal multi-head attention backward over the fused-QKV tape,
/// recomputing each query row's softmax exactly as the forward did
/// (max-subtracted, same traversal), then applying the standard
/// softmax/score chain.  Serial — bit-identical at every thread count.
#[allow(clippy::too_many_arguments)]
fn attention_backward(qkv: &Matrix, d_att: &Matrix, batch: usize, s: usize, d: usize,
                      n_head: usize, probs: &mut Vec<f32>, dw: &mut Vec<f32>,
                      dq: &mut Vec<f32>, d_qkv: &mut Matrix) {
    ensure_out(d_qkv, batch * s, 3 * d);
    d_qkv.data.fill(0.0);
    let hd = d / n_head;
    let scale = 1.0 / (hd as f32).sqrt();
    if probs.len() < s {
        probs.resize(s, 0.0);
    }
    if dw.len() < s {
        dw.resize(s, 0.0);
    }
    if dq.len() < hd {
        dq.resize(hd, 0.0);
    }
    for b in 0..batch {
        for h in 0..n_head {
            let qo = h * hd;
            let ko = d + h * hd;
            let vo = 2 * d + h * hd;
            for q in 0..s {
                // Recompute this query's softmax row (forward-identical).
                let qrow = &qkv.row(b * s + q)[qo..qo + hd];
                let mut maxv = f32::NEG_INFINITY;
                for t in 0..=q {
                    let sc = dot(qrow, &qkv.row(b * s + t)[ko..ko + hd], hd) * scale;
                    probs[t] = sc;
                    if sc > maxv {
                        maxv = sc;
                    }
                }
                let mut denom = 0.0f32;
                for p in probs.iter_mut().take(q + 1) {
                    let e = (*p - maxv).exp();
                    *p = e;
                    denom += e;
                }
                let invd = 1.0 / denom;
                for p in probs.iter_mut().take(q + 1) {
                    *p *= invd;
                }
                let dout = &d_att.row(b * s + q)[qo..qo + hd];
                // dw_t = dout·v_t ; softmax backward needs Σ w_t·dw_t.
                let mut sum_wdw = 0.0f32;
                for t in 0..=q {
                    dw[t] = dot(dout, &qkv.row(b * s + t)[vo..vo + hd], hd);
                    sum_wdw += probs[t] * dw[t];
                }
                for v in dq.iter_mut() {
                    *v = 0.0;
                }
                for t in 0..=q {
                    let wt = probs[t];
                    let ds = wt * (dw[t] - sum_wdw) * scale;
                    // dq += ds·k_t (accumulated locally: row t may be q).
                    {
                        let kslice = &qkv.row(b * s + t)[ko..ko + hd];
                        for (acc, kv) in dq.iter_mut().zip(kslice) {
                            *acc += ds * kv;
                        }
                    }
                    let trow = d_qkv.row_mut(b * s + t);
                    // dv_t += w_t·dout.
                    for (o, dv) in trow[vo..vo + hd].iter_mut().zip(dout) {
                        *o += wt * dv;
                    }
                    // dk_t += ds·q.
                    for (o, qv) in trow[ko..ko + hd].iter_mut().zip(qrow) {
                        *o += ds * qv;
                    }
                }
                let qrow_out = d_qkv.row_mut(b * s + q);
                for (o, v) in qrow_out[qo..qo + hd].iter_mut().zip(dq.iter()) {
                    *o += *v;
                }
            }
        }
    }
}
