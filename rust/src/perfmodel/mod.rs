//! Analytical A100 + cuSPARSELt performance simulator.
//!
//! No GPU exists on this testbed (repro band 0/5 — hardware gate), so the
//! paper's speedup tables are regenerated from a *calibrated analytical
//! model* (DESIGN.md §2): a roofline engine (Williams et al. [59]) over
//! per-kernel GEMM shapes with
//! * dense/sparse tensor-core peaks and HBM bandwidth of an A100-40GB,
//! * a cuSPARSELt efficiency curve shaped to the paper's own Figure 3a
//!   (speedup grows with size toward 2×; *upsample* aspect ratios fall off
//!   a cliff around hidden≈4000 unless square-tiled — §2.4),
//! * kernel-launch overheads (the Appendix C/D low-rank arithmetic-
//!   intensity effect falls out of the roofline automatically),
//! * the cuSPARSELt *setup/compress* cost (Figure 5 / Appendix B) that
//!   static masks amortize and dynamic-mask methods pay per step.
//!
//! One constant set drives every table — no per-table knobs (DESIGN.md §7.5).

pub mod cusparselt;
pub mod transformer;

pub use cusparselt::{cusparselt_efficiency, setup_time_s, SPARSE_SPEEDUP_CAP};
pub use transformer::{
    bimask_slowdown, infer_time, train_step_time, InferOpts, ModelShape, Sparsity,
    TrainOpts,
};

/// A100-SXM4-40GB machine constants (public spec sheet values).
#[derive(Clone, Copy, Debug)]
pub struct Machine {
    /// Dense fp16/bf16 tensor-core peak, FLOP/s.
    pub dense_peak: f64,
    /// 2:4 sparse tensor-core peak, FLOP/s.
    pub sparse_peak: f64,
    /// HBM2e bandwidth, B/s.
    pub hbm_bw: f64,
    /// Per-kernel launch overhead, seconds.
    pub launch_overhead: f64,
    /// Streaming multiprocessors (occupancy model).
    pub sms: usize,
}

pub const A100: Machine = Machine {
    dense_peak: 312e12,
    sparse_peak: 624e12,
    hbm_bw: 1.555e12,
    launch_overhead: 4.5e-6,
    sms: 108,
};

/// One GEMM: `C(m×n) = A(m×k) · B(k×n)` at the given operand byte widths.
#[derive(Clone, Copy, Debug)]
pub struct Gemm {
    pub m: usize,
    pub n: usize,
    pub k: usize,
}

impl Gemm {
    pub fn new(m: usize, n: usize, k: usize) -> Self {
        Self { m, n, k }
    }

    pub fn flops(&self) -> f64 {
        2.0 * self.m as f64 * self.n as f64 * self.k as f64
    }

    /// HBM traffic in bytes assuming fp16 operands, one-pass streaming with
    /// cache-resident tiles (lower bound, which large GEMMs approach).
    pub fn bytes(&self) -> f64 {
        2.0 * (self.m as f64 * self.k as f64
            + self.k as f64 * self.n as f64
            + self.m as f64 * self.n as f64)
    }

    /// Arithmetic intensity (FLOP/byte).
    pub fn intensity(&self) -> f64 {
        self.flops() / self.bytes()
    }
}

/// Tensor-core tile-quantization + occupancy utilization for a dense GEMM.
///
/// Two effects: (a) dims quantize to 128×128×32 tiles; (b) small grids
/// cannot occupy all SMs (wave quantization).  Saturates at ~0.9 of peak
/// for the large LLM GEMMs, which matches cuBLAS reality.
pub fn dense_utilization(mach: &Machine, g: &Gemm) -> f64 {
    const TM: f64 = 128.0;
    const TN: f64 = 128.0;
    let quant = |d: f64, t: f64| d / (t * (d / t).ceil());
    let q = quant(g.m as f64, TM) * quant(g.n as f64, TN) * quant(g.k as f64, 32.0);
    let tiles = (g.m as f64 / TM).ceil() * (g.n as f64 / TN).ceil();
    let occ = (tiles / (2.0 * mach.sms as f64)).min(1.0);
    // cuBLAS asymptote ≈ 0.9 of spec peak.
    0.9 * q * occ.powf(0.5)
}

/// Roofline time for a dense GEMM (seconds), including launch overhead.
pub fn dense_gemm_time(mach: &Machine, g: &Gemm) -> f64 {
    let util = dense_utilization(mach, g).max(1e-3);
    let compute = g.flops() / (mach.dense_peak * util);
    let memory = g.bytes() / mach.hbm_bw;
    compute.max(memory) + mach.launch_overhead
}

/// Roofline time for a 2:4 sparse GEMM through cuSPARSELt: the weight
/// operand (`k×n`) is compressed (half values + Eq.-7 metadata), flops
/// halve, and the shape-dependent efficiency curve of Figure 3a applies.
/// `square_tiled` models the §2.4 upsample tiling (extra launches, no
/// aspect-ratio cliff).
pub fn sparse_gemm_time(mach: &Machine, g: &Gemm, square_tiled: bool) -> f64 {
    if !square_tiled {
        let eff = cusparselt_efficiency(g, false);
        let util = dense_utilization(mach, g).max(1e-3);
        let compute = (g.flops() / 2.0) / (mach.sparse_peak / 2.0 * eff * util);
        let weight_bytes = 2.0 * (g.k as f64 * g.n as f64) * (0.5 + 3.0 / 32.0);
        let bytes = 2.0 * (g.m as f64 * g.k as f64 + g.m as f64 * g.n as f64) + weight_bytes;
        return compute.max(bytes / mach.hbm_bw) + mach.launch_overhead;
    }
    // Square tiling: split the n dimension into square k×k tiles (the
    // paper found square optimal), each its own launch, results concatenated
    // (a bandwidth-only pass, usually fused into the epilogue).
    let tile = g.k.min(g.n);
    let tiles = (g.n + tile - 1) / tile;
    let sub = Gemm::new(g.m, tile, g.k);
    let each = {
        // Tiling sidesteps the aspect cliff but pays boundary/concat
        // overhead: ~92% of the ideal square-tile efficiency (Table 8's
        // partial recovery).
        let eff = 0.92 * cusparselt_efficiency(&sub, true);
        let util = dense_utilization(mach, &sub).max(1e-3);
        let compute = (sub.flops() / 2.0) / (mach.sparse_peak / 2.0 * eff * util);
        let weight_bytes = 2.0 * (sub.k as f64 * sub.n as f64) * (0.5 + 3.0 / 32.0);
        let bytes =
            2.0 * (sub.m as f64 * sub.k as f64 + sub.m as f64 * sub.n as f64) + weight_bytes;
        compute.max(bytes / mach.hbm_bw) + mach.launch_overhead
    };
    tiles as f64 * each
}

/// Element-wise / reduction pass over `n` fp16 elements: bandwidth-bound,
/// `passes` full sweeps (e.g. Adam update = read w,g,m,v + write w,m,v).
pub fn elementwise_time(mach: &Machine, n: f64, passes: f64) -> f64 {
    (n * 2.0 * passes) / mach.hbm_bw + mach.launch_overhead
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn big_gemm_is_compute_bound_small_is_latency_bound() {
        let big = Gemm::new(8192, 8192, 8192);
        let t_big = dense_gemm_time(&A100, &big);
        // 2*8192^3 / 312e12*0.9 ≈ 3.9 ms
        assert!(t_big > 1e-3 && t_big < 2e-2, "{t_big}");
        let small = Gemm::new(16, 16, 16);
        let t_small = dense_gemm_time(&A100, &small);
        assert!(t_small < 1.2 * (A100.launch_overhead + 1e-6) * 10.0);
    }

    #[test]
    fn sparse_beats_dense_on_large_square_gemms() {
        for d in [2048usize, 4096, 8192] {
            let g = Gemm::new(2048, d, d);
            let sp = dense_gemm_time(&A100, &g) / sparse_gemm_time(&A100, &g, false);
            assert!(sp > 1.3 && sp <= SPARSE_SPEEDUP_CAP, "d={d}: {sp}");
        }
    }

    #[test]
    fn sparse_speedup_grows_with_size_fig3a() {
        let s = |d: usize| {
            let g = Gemm::new(2048, d, d);
            dense_gemm_time(&A100, &g) / sparse_gemm_time(&A100, &g, false)
        };
        assert!(s(1024) < s(2048) && s(2048) < s(4096), "{} {} {}", s(1024), s(2048), s(4096));
    }

    #[test]
    fn upsample_cliff_and_tiling_rescue() {
        // Upsample (n = 4k) at hidden ≥ 4096: untiled efficiency falls off
        // (Fig 3a); square tiling recovers most of it (Table 8).
        let g = Gemm::new(2048, 4 * 5120, 5120);
        let untiled = dense_gemm_time(&A100, &g) / sparse_gemm_time(&A100, &g, false);
        let tiled = dense_gemm_time(&A100, &g) / sparse_gemm_time(&A100, &g, true);
        assert!(tiled > untiled, "tiled {tiled} vs untiled {untiled}");
    }

    #[test]
    fn tiny_gemm_speedup_is_poor() {
        // Fig 6: low-rank adapters (small k) get nowhere near ideal speedup.
        let dense = Gemm::new(2048, 4096, 4096);
        let lora = Gemm::new(2048, 4096, 64); // rank-64 upsample
        let ratio = dense_gemm_time(&A100, &dense) / dense_gemm_time(&A100, &lora);
        let ideal = 4096.0 / 64.0;
        assert!(ratio < 0.5 * ideal, "ratio {ratio} vs ideal {ideal}");
    }
}
