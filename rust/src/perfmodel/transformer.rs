//! Per-layer timing walk for full-size transformers: regenerates the
//! end-to-end speedup tables (2, 7, 8, 12) and the FST/Bi-Mask baseline
//! overheads from the roofline + cuSPARSELt models.

use super::cusparselt::setup_time_s;
use super::{dense_gemm_time, elementwise_time, sparse_gemm_time, Gemm, Machine};
pub use crate::config::zoo::ModelShape;

/// Sparsification method for the timing walk.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Sparsity {
    Dense,
    /// SLoPe: all block linears 2:4, static masks (setup amortized to zero).
    Slope {
        /// §2.4 square tiling of upsample weights.
        tiled_upsample: bool,
    },
    /// FST (Hu et al. '24): MLP-only 2:4 with dynamic transposable masks —
    /// pays the cuSPARSELt setup every `mask_interval` steps, and its final
    /// 17% dense fine-tune makes *inference* dense (speedup 1.0).
    Fst { mask_interval: usize },
}

#[derive(Clone, Copy, Debug)]
pub struct TrainOpts {
    pub sparsity: Sparsity,
    pub flash_attention: bool,
    pub batch: usize,
    pub seq: usize,
}

#[derive(Clone, Copy, Debug)]
pub struct InferOpts {
    pub sparsity: Sparsity,
    pub flash_attention: bool,
    pub batch: usize,
    pub seq: usize,
    /// LoRA rank as absolute columns (0 = none).
    pub adapter_rank: usize,
    /// Eq.-11 fused adapter kernels vs naive 4-launch (Appendix D).
    pub fused_adapters: bool,
}

/// The per-block linear GEMM inventory: (m, n, k) with weight (k → n),
/// tagged with whether it is an MLP weight and whether it is "upsample"
/// shaped (n ≥ 2k — the Fig-3a cliff candidates).
struct Lin {
    g: Gemm,
    is_mlp: bool,
    upsample: bool,
}

fn block_linears(s: &ModelShape, t: usize) -> Vec<Lin> {
    let d = s.d_model;
    let kv = s.n_kv_head * s.head_dim();
    // q/k/v as separate projections (HF OPT/LLaMA layout — the layout the
    // paper's kernels wrap), so the attention GEMMs stay square and only
    // MLP upsamples hit the Fig-3a aspect cliff.
    let mut v = vec![
        Lin { g: Gemm::new(t, d, d), is_mlp: false, upsample: false }, // q
        Lin { g: Gemm::new(t, kv, d), is_mlp: false, upsample: false }, // k
        Lin { g: Gemm::new(t, kv, d), is_mlp: false, upsample: false }, // v
        Lin { g: Gemm::new(t, d, d), is_mlp: false, upsample: false }, // proj
    ];
    if s.gated_mlp {
        v.push(Lin { g: Gemm::new(t, 2 * s.d_ff, d), is_mlp: true, upsample: true });
        v.push(Lin { g: Gemm::new(t, d, s.d_ff), is_mlp: true, upsample: false });
    } else {
        v.push(Lin { g: Gemm::new(t, s.d_ff, d), is_mlp: true, upsample: true });
        v.push(Lin { g: Gemm::new(t, d, s.d_ff), is_mlp: true, upsample: false });
    }
    v
}

/// Attention core time (scores + softmax + AV), fwd, per *all* layers'
/// worth of one layer (caller multiplies by n_layer).
fn attention_time(mach: &Machine, s: &ModelShape, batch: usize, seq: usize, flash: bool) -> f64 {
    let h = s.n_head as f64;
    let hd = s.head_dim() as f64;
    let b = batch as f64;
    let sq = seq as f64;
    let flops = 2.0 * b * h * sq * sq * hd * 2.0; // QKᵀ + AV
    if flash {
        // FlashAttention-2: compute-bound at ~0.6 of dense peak, no S×S
        // materialization, one kernel.
        flops / (mach.dense_peak * 0.6) + mach.launch_overhead
    } else {
        // Unfused: materialize + re-read the S×S attention matrix ~4×
        // (scores write, softmax read/write, AV read) in fp16.
        let att_bytes = 4.0 * b * h * sq * sq * 2.0;
        let compute = flops / (mach.dense_peak * 0.5);
        compute.max(att_bytes / mach.hbm_bw) + 4.0 * mach.launch_overhead
    }
}

/// Unfused epilogues (bias, activation, LN, residual) + framework dispatch
/// per block, charged identically to dense and sparse paths.  Calibrated so
/// end-to-end speedups land in the paper's Table-2 bands rather than at the
/// isolated-SpMM ceiling of Figure 3a.
fn block_epilogue_time(mach: &Machine, s: &ModelShape, t: usize) -> f64 {
    let d = (t * s.d_model) as f64;
    let ff = (t * s.d_ff) as f64;
    // ~14 activation-sized passes (LN read/write ×2, residuals, biases,
    // activation fn, KV-cache writes) + 3 ffn-sized passes, fp16.
    (14.0 * d + 3.0 * ff) * 2.0 / mach.hbm_bw + 14.0 * mach.launch_overhead
}

fn linear_time(mach: &Machine, lin: &Lin, sp: Sparsity, transpose_pass: bool) -> f64 {
    match sp {
        Sparsity::Dense => dense_gemm_time(mach, &lin.g),
        Sparsity::Slope { tiled_upsample } => {
            sparse_gemm_time(mach, &lin.g, tiled_upsample && lin.upsample)
        }
        Sparsity::Fst { .. } => {
            if lin.is_mlp {
                // FST's transposable masks are column-constrained too; its
                // backward (transpose_pass) runs at reduced efficiency
                // (paper: transposable masks "reduce accuracy and add
                // runtime overheads").
                let t = sparse_gemm_time(mach, &lin.g, false);
                if transpose_pass { t * 1.15 } else { t }
            } else {
                dense_gemm_time(mach, &lin.g)
            }
        }
    }
}

/// One optimizer + bookkeeping pass over the block-linear parameters.
fn optimizer_time(mach: &Machine, s: &ModelShape, sp: Sparsity) -> f64 {
    let p = (s.n_layer * s.block_linear_params()) as f64;
    match sp {
        // Adam: read w,g,m,v; write w,m,v ⇒ ~7 passes over fp16/fp32 mix.
        Sparsity::Dense => elementwise_time(mach, p, 8.0),
        Sparsity::Slope { .. } => {
            // Sparse states halve traffic; add prune&compress of ∇W (1 dense
            // read + 0.5 write) and the double write-back (w + wᵀ).
            elementwise_time(mach, p, 8.0 * 0.5) + elementwise_time(mach, p, 2.0)
        }
        Sparsity::Fst { .. } => elementwise_time(mach, p, 8.0) + elementwise_time(mach, p, 1.0),
    }
}

/// End-to-end training step time (fwd + bwd + optimizer), seconds.
pub fn train_step_time(mach: &Machine, s: &ModelShape, o: &TrainOpts) -> f64 {
    let t = o.batch * o.seq;
    let lins = block_linears(s, t);
    let mut time = 0.0;
    for lin in &lins {
        // FWD (Eq. 4): weight-sparse GEMM.
        time += linear_time(mach, lin, o.sparsity, false);
        // BWD-2 (Eq. 6): ∇X = ∇Y·W — also weight-sparse under SLoPe's
        // double-pruned formulation (swap n and k: reduction dim = d_out).
        let b2 = Lin { g: Gemm::new(t, lin.g.k, lin.g.n), is_mlp: lin.is_mlp,
                       upsample: lin.g.k >= 2 * lin.g.n };
        time += linear_time(mach, &b2, o.sparsity, true);
        // BWD-1 (Eq. 5): ∇W = ∇Yᵀ·X — dense in every method.
        time += dense_gemm_time(mach, &Gemm::new(lin.g.n, lin.g.k, t));
    }
    time *= s.n_layer as f64;
    // Attention fwd + bwd (≈ 2.5× fwd) per layer.
    time += s.n_layer as f64
        * attention_time(mach, s, o.batch, o.seq, o.flash_attention)
        * 3.5;
    // Epilogues fwd + bwd.
    time += 3.0 * s.n_layer as f64 * block_epilogue_time(mach, s, t);
    // LM head fwd + its two backward GEMMs (always dense).
    let head = Gemm::new(t, s.vocab, s.d_model);
    time += dense_gemm_time(mach, &head)
        + dense_gemm_time(mach, &Gemm::new(t, s.d_model, s.vocab))
        + dense_gemm_time(mach, &Gemm::new(s.vocab, s.d_model, t));
    time += optimizer_time(mach, s, o.sparsity);
    // Dynamic-mask methods pay the cuSPARSELt setup per refresh interval
    // (Appendix B); static SLoPe pays zero here (amortized over the run).
    if let Sparsity::Fst { mask_interval } = o.sparsity {
        let per_block: f64 = lins
            .iter()
            .filter(|l| l.is_mlp)
            .map(|l| 2.0 * setup_time_s(l.g.k, l.g.n)) // W and Wᵀ
            .sum();
        time += s.n_layer as f64 * per_block / mask_interval.max(1) as f64;
    }
    time
}

/// End-to-end inference (forward-only) time, seconds.
pub fn infer_time(mach: &Machine, s: &ModelShape, o: &InferOpts) -> f64 {
    let t = o.batch * o.seq;
    // FST serves a dense model (final dense fine-tune) — force dense.
    let sp = match o.sparsity {
        Sparsity::Fst { .. } => Sparsity::Dense,
        x => x,
    };
    let lins = block_linears(s, t);
    let mut time = 0.0;
    for lin in &lins {
        time += linear_time(mach, lin, sp, false);
        if o.adapter_rank > 0 && matches!(sp, Sparsity::Slope { .. }) {
            time += adapter_time(mach, t, lin.g.k, lin.g.n, o.adapter_rank, o.fused_adapters);
        }
    }
    time *= s.n_layer as f64;
    time += s.n_layer as f64 * attention_time(mach, s, o.batch, o.seq, o.flash_attention);
    time += s.n_layer as f64 * block_epilogue_time(mach, s, t);
    time += dense_gemm_time(mach, &Gemm::new(t, s.vocab, s.d_model));
    time
}

/// Extra inference time of one LoRA adapter on a (k → n) linear.
///
/// Naive (Appendix C/D "before"): T·Rᵀ, then ·Lᵀ, then an add pass — three
/// extra launches of *low-intensity* GEMMs.  Fused (Eq. 11): the
/// downsample rides the sparse GEMM (its marginal cost is the extra flops
/// at the big GEMM's efficiency) and the upsample fuses with the add.
fn adapter_time(mach: &Machine, t: usize, k: usize, n: usize, r: usize, fused: bool) -> f64 {
    if fused {
        // Marginal cost of r extra output columns on the main GEMM …
        let base = sparse_gemm_time(mach, &Gemm::new(t, n, k), false);
        let widened = sparse_gemm_time(mach, &Gemm::new(t, n + 2 * r, k), false);
        let ride_along = (widened - base).max(0.0);
        // … plus one fused matmul+add (no separate add launch).
        ride_along + dense_gemm_time(mach, &Gemm::new(t, n, r))
    } else {
        dense_gemm_time(mach, &Gemm::new(t, r, k))
            + dense_gemm_time(mach, &Gemm::new(t, n, r))
            + elementwise_time(mach, (t * n) as f64, 3.0)
    }
}

/// Bi-Mask (Zhang et al. '23) per-step overhead on a CNN: every iteration
/// re-derives forward and transposable backward masks by scoring candidate
/// permutations/convolution outputs over each weight — a host-path search
/// that Appendix H measures as 3–8.4× end-to-end *slowdowns* vs dense (the
/// released code emulates sparsity, so compute stays dense and the search
/// is pure overhead).
pub fn bimask_slowdown(mach: &Machine, cnn: &crate::config::zoo::CnnShape) -> f64 {
    const SEARCH_PASSES: f64 = 10.0; // permutation-scoring sweeps per weight
    const HOST_BW: f64 = 22e9; // host-path effective bandwidth
    const PER_LAYER_FIXED: f64 = 1.2e-4; // mask bookkeeping per layer call
    let mut dense = 0.0;
    let mut overhead = 0.0;
    for (l, count) in cnn.layers {
        let c = *count as f64;
        let g = Gemm::new(l.m, l.n, l.k);
        let fwd = dense_gemm_time(mach, &g);
        dense += c * 3.0 * fwd; // fwd + ~2× bwd
        let weight_bytes = (l.n * l.k) as f64 * 2.0;
        overhead += c * (SEARCH_PASSES * weight_bytes / HOST_BW + PER_LAYER_FIXED);
    }
    (dense + overhead) / dense
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::zoo::*;
    use crate::perfmodel::A100;

    const TRAIN: TrainOpts = TrainOpts {
        sparsity: Sparsity::Dense, flash_attention: true, batch: 8, seq: 2048,
    };

    fn train_speedup(s: &ModelShape, sp: Sparsity) -> f64 {
        let dense = train_step_time(&A100, s, &TRAIN);
        let m = TrainOpts { sparsity: sp, ..TRAIN };
        dense / train_step_time(&A100, s, &m)
    }

    fn infer_speedup(s: &ModelShape, sp: Sparsity, rank_ratio: f64, fused: bool) -> f64 {
        let base = InferOpts { sparsity: Sparsity::Dense, flash_attention: true,
                               batch: 8, seq: 2048, adapter_rank: 0, fused_adapters: fused };
        let dense = infer_time(&A100, s, &base);
        let o = InferOpts { sparsity: sp,
                            adapter_rank: (s.d_model as f64 * rank_ratio) as usize, ..base };
        dense / infer_time(&A100, s, &o)
    }

    #[test]
    fn slope_training_speedup_band() {
        // Table 2: SLoPe train speedups 1.13–1.25 across the sweep set.
        for m in SPEEDUP_MODELS {
            let sp = train_speedup(&m, Sparsity::Slope { tiled_upsample: true });
            assert!(sp > 1.05 && sp < 1.45, "{}: {sp:.3}", m.name);
        }
    }

    #[test]
    fn slope_inference_speedup_band_and_ordering() {
        // Table 2: inference 1.31–1.54 at r=0; shrinks as rank grows.
        for m in SPEEDUP_MODELS {
            let s0 = infer_speedup(&m, Sparsity::Slope { tiled_upsample: true }, 0.0, true);
            let s1 = infer_speedup(&m, Sparsity::Slope { tiled_upsample: true }, 0.0156, true);
            let s6 = infer_speedup(&m, Sparsity::Slope { tiled_upsample: true }, 0.0625, true);
            assert!(s0 > 1.15 && s0 < 1.75, "{}: r0 {s0:.3}", m.name);
            assert!(s0 >= s1 && s1 >= s6, "{}: {s0:.3} {s1:.3} {s6:.3}", m.name);
        }
    }

    #[test]
    fn fst_training_speedup_smaller_than_slope_and_inference_is_one() {
        for m in [OPT_66B, OPT_13B, LLAMA3_8B] {
            let fst = train_speedup(&m, Sparsity::Fst { mask_interval: 128 });
            let slope = train_speedup(&m, Sparsity::Slope { tiled_upsample: true });
            assert!(fst < slope, "{}: fst {fst:.3} slope {slope:.3}", m.name);
            assert!(fst > 0.95, "{}: fst {fst:.3}", m.name);
            let inf = infer_speedup(&m, Sparsity::Fst { mask_interval: 128 }, 0.0, true);
            assert!((inf - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn fused_adapters_beat_naive() {
        // Table 7: fusion buys up to ~6% end-to-end.
        for m in [OPT_66B, OPT_30B] {
            let f = infer_speedup(&m, Sparsity::Slope { tiled_upsample: true }, 0.0156, true);
            let n = infer_speedup(&m, Sparsity::Slope { tiled_upsample: true }, 0.0156, false);
            assert!(f > n, "{}: fused {f:.3} naive {n:.3}", m.name);
        }
    }

    #[test]
    fn tiling_helps_large_models() {
        // Table 8: tiling matters most where the upsample cliff bites.
        let t = infer_speedup(&OPT_66B, Sparsity::Slope { tiled_upsample: true }, 0.0, true);
        let u = infer_speedup(&OPT_66B, Sparsity::Slope { tiled_upsample: false }, 0.0, true);
        assert!(t > u, "tiled {t:.3} untiled {u:.3}");
    }

    #[test]
    fn flash_and_slope_compose_table12() {
        let base = TrainOpts { sparsity: Sparsity::Dense, flash_attention: false, batch: 8, seq: 2048 };
        let d_nofa = train_step_time(&A100, &OPT_13B, &base);
        let d_fa = train_step_time(&A100, &OPT_13B, &TrainOpts { flash_attention: true, ..base });
        let s_fa = train_step_time(&A100, &OPT_13B, &TrainOpts {
            sparsity: Sparsity::Slope { tiled_upsample: true }, flash_attention: true, ..base });
        let fa_only = d_nofa / d_fa;
        let both = d_nofa / s_fa;
        assert!(fa_only > 1.05, "fa {fa_only:.3}");
        assert!(both > fa_only, "composition must add: {both:.3} vs {fa_only:.3}");
    }

    #[test]
    fn bimask_slows_down_3_to_9x() {
        for cnn in BIMASK_MODELS {
            let s = bimask_slowdown(&A100, cnn);
            assert!(s > 1.5 && s < 12.0, "{}: {s:.2}", cnn.name);
        }
    }
}
