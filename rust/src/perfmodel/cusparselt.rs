//! cuSPARSELt behavioural model: the shape-dependent efficiency curve of
//! Figure 3a and the setup/compress cost of Figure 5 (Appendix B).
//!
//! Calibration targets (from the paper's own measurements, A100):
//! * SpMM speedup vs cuBLAS rises with size and saturates near 2× for
//!   square ("attention") and downsample shapes;
//! * upsample shapes (n ≈ 4k) *lose* their speedup beyond hidden ≈ 4000
//!   unless split into square tiles (§2.4, Table 8 recovers 9–12%);
//! * the setup (descriptor init + prune + compress) phase costs one to two
//!   orders of magnitude more than a single multiply at equal size
//!   (Figure 5), which is why dynamic-mask methods (FST, Bi-Mask, SR-STE)
//!   bleed time (Appendix B/H) while SLoPe's static masks pay it once.

use super::Gemm;

/// Ratio of achieved sparse-TC efficiency to dense-TC efficiency at equal
/// shape; 1.0 would mean the full 2× sparse speedup materializes.
pub const SPARSE_SPEEDUP_CAP: f64 = 2.0;

/// Shape-dependent cuSPARSELt efficiency ∈ (0, 1].
///
/// `eff = size_term × aspect_term`:
/// * `size_term` — saturating in the weight's K dimension: small reduction
///   dims can't amortize the metadata decode pipeline;
/// * `aspect_term` — the Fig-3a upsample cliff: wide outputs (n/k ≥ 2) at
///   k ≥ ~4000 fall to ≈60% efficiency; square tiling (`tiled = true`)
///   sidesteps it by construction.
pub fn cusparselt_efficiency(g: &Gemm, tiled: bool) -> f64 {
    let k = g.k as f64;
    // Saturating size response: ~0.55 at k=512, ~0.82 at k=2048, →0.95.
    let size_term = 0.95 * (1.0 - (-k / 1400.0).exp()).max(0.3);
    let aspect = g.n as f64 / g.k as f64;
    let aspect_term = if !tiled && aspect >= 2.0 && g.k >= 3500 {
        // Upsample cliff (Fig 3a): worsens with size past the knee.
        let over = ((g.k as f64 - 3500.0) / 3500.0).min(1.5);
        (1.0 - 0.42 * over).max(0.50)
    } else {
        1.0
    };
    (size_term * aspect_term).clamp(0.05, 1.0)
}

/// cuSPARSELt setup time (descriptor init + prune + compress) for a weight
/// of `k × n` fp16 values — Figure 5's "setup" series.
///
/// Model: a fixed planner cost (the auto-tuning kernel search) plus the
/// prune/compress/metadata rewrite, which cuSPARSELt executes largely on
/// the host path at host-memory rates (this is why Figure 5's setup curve
/// sits 1–2 orders of magnitude above the multiply).
pub fn setup_time_s(k: usize, n: usize) -> f64 {
    const PLANNER_S: f64 = 1.1e-3; // fixed algorithm-search cost
    const HOST_EFFECTIVE_BW: f64 = 22e9; // host-side rewrite path, B/s
    let bytes = 2.0 * k as f64 * n as f64;
    PLANNER_S + 3.0 * bytes / HOST_EFFECTIVE_BW
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perfmodel::{dense_gemm_time, sparse_gemm_time, A100};

    #[test]
    fn efficiency_monotone_in_k_for_square() {
        let e = |k: usize| cusparselt_efficiency(&Gemm::new(2048, k, k), false);
        assert!(e(512) < e(1024) && e(1024) < e(4096));
        assert!(e(8192) <= 0.95);
    }

    #[test]
    fn upsample_cliff_only_without_tiling() {
        let g = Gemm::new(2048, 4 * 6144, 6144);
        assert!(cusparselt_efficiency(&g, false) < cusparselt_efficiency(&g, true));
        // Below the knee no cliff applies.
        let small = Gemm::new(2048, 4 * 1024, 1024);
        assert_eq!(
            cusparselt_efficiency(&small, false),
            cusparselt_efficiency(&small, true)
        );
    }

    #[test]
    fn setup_dwarfs_single_multiply_fig5() {
        // Figure 5: setup is 1–2 orders of magnitude above one multiply.
        for d in [1024usize, 4096, 8192] {
            let setup = setup_time_s(d, d);
            let mult = sparse_gemm_time(&A100, &Gemm::new(d, d, d), false);
            assert!(setup / mult > 3.0, "d={d}: setup={setup:.2e} mult={mult:.2e}");
        }
    }

    #[test]
    fn static_amortization_beats_dynamic() {
        // Over a 1000-multiply run, static setup-once ≪ dynamic setup-always.
        let d = 4096;
        let mult = sparse_gemm_time(&A100, &Gemm::new(2048, d, d), false);
        let dense = dense_gemm_time(&A100, &Gemm::new(2048, d, d));
        let static_total = setup_time_s(d, d) + 1000.0 * mult;
        let dynamic_total = 1000.0 * (setup_time_s(d, d) + mult);
        assert!(static_total < 1000.0 * dense, "static sparse must beat dense");
        assert!(dynamic_total > 1000.0 * dense, "dynamic setup must erase the win");
    }
}
