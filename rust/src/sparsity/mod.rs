//! N:M structured-sparsity math (paper §2.1): mask generation, double
//! pruning, Lemma 2.1 analytics, and the compressed storage format.
//!
//! Conventions match the paper and the python side exactly: for a weight
//! `W ∈ R^{d_out × d_in}` used as `Y = X·Wᵀ`,
//! * *row-wise* pruning (`W^R`) constrains every group of M consecutive
//!   elements **within a row** (along `d_in`) to ≤ N non-zeros — the
//!   reduction dim of FWD (Eq. 4);
//! * *double* pruning (`W^{R,C}`) additionally constrains columns (along
//!   `d_out`) — the reduction dim of BWD-2 (Eq. 6).

pub mod compressed;
pub mod lemma;
pub mod prepacked;

pub use compressed::CompressedNm;
pub use prepacked::{prepack_enabled, PrepackedNm};
pub use lemma::{imposed_sparsity, monte_carlo_imposed_sparsity};

use crate::tensor::Matrix;
use crate::util::Rng;

/// An N:M sparsity scheme: at most `n` of every `m` consecutive elements
/// along the constrained dimension are non-zero.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct NmScheme {
    pub n: usize,
    pub m: usize,
}

impl NmScheme {
    pub const fn new(n: usize, m: usize) -> Self {
        assert!(n >= 1 && n <= m);
        Self { n, m }
    }

    /// 2:4 — the scheme NVIDIA sparse tensor cores accelerate.
    pub const TWO_FOUR: NmScheme = NmScheme { n: 2, m: 4 };

    pub fn density(&self) -> f64 {
        self.n as f64 / self.m as f64
    }

    /// Bits of index metadata per kept element (Eq. 7):
    /// `ceil(log2(C(M, N))) / N`.
    pub fn index_bits_per_group(&self) -> u32 {
        (binom(self.m as u64, self.n as u64) as f64).log2().ceil() as u32
    }

    /// Bits per kept value of the *packed in-RAM* metadata layout:
    /// `ceil(log2(M))` — one intra-group column offset per kept value,
    /// the hardware-friendly rounding of the Eq.-7 entropy bound (2 bits
    /// for 2:4 vs. the 1.5-bit bound; still 8× less than a `u16` index).
    pub fn offset_bits(&self) -> u32 {
        usize::BITS - (self.m - 1).leading_zeros()
    }
}

impl std::fmt::Display for NmScheme {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}", self.n, self.m)
    }
}

pub(crate) fn binom(m: u64, n: u64) -> u64 {
    let n = n.min(m - n);
    let mut num = 1u64;
    let mut den = 1u64;
    for i in 0..n {
        num *= m - i;
        den *= i + 1;
    }
    num / den
}

/// 0/1 mask with `rows × cols` layout; `true` = kept.
#[derive(Clone, Debug, PartialEq)]
pub struct Mask {
    pub rows: usize,
    pub cols: usize,
    pub keep: Vec<bool>,
}

impl Mask {
    pub fn ones(rows: usize, cols: usize) -> Self {
        Self { rows, cols, keep: vec![true; rows * cols] }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> bool {
        self.keep[r * self.cols + c]
    }

    pub fn density(&self) -> f64 {
        self.keep.iter().filter(|k| **k).count() as f64 / self.keep.len().max(1) as f64
    }

    pub fn to_matrix(&self) -> Matrix {
        Matrix::from_vec(
            self.rows,
            self.cols,
            self.keep.iter().map(|k| if *k { 1.0 } else { 0.0 }).collect(),
        )
    }

    /// Apply to a weight matrix (zero out pruned slots).
    pub fn apply(&self, w: &Matrix) -> Matrix {
        assert_eq!((w.rows, w.cols), (self.rows, self.cols));
        let data = w
            .data
            .iter()
            .zip(&self.keep)
            .map(|(v, k)| if *k { *v } else { 0.0 })
            .collect();
        Matrix { rows: w.rows, cols: w.cols, data }
    }

    /// Number of positions where `self` keeps but `other` prunes or vice
    /// versa — the Figure-4 mask-churn metric.
    pub fn hamming(&self, other: &Mask) -> usize {
        assert_eq!(self.keep.len(), other.keep.len());
        self.keep
            .iter()
            .zip(&other.keep)
            .filter(|(a, b)| a != b)
            .count()
    }

    /// Verify the N:M constraint along rows (groups of `m` within a row).
    pub fn check_row_nm(&self, scheme: NmScheme) -> bool {
        assert_eq!(self.cols % scheme.m, 0);
        for r in 0..self.rows {
            for g in 0..self.cols / scheme.m {
                let kept = (0..scheme.m)
                    .filter(|i| self.at(r, g * scheme.m + i))
                    .count();
                if kept > scheme.n {
                    return false;
                }
            }
        }
        true
    }

    /// Verify the row constraint holds with *exactly* `n` kept per group —
    /// the shape every init-time mask has, and the precondition for the
    /// pad-free packed layout the host training executor's in-place
    /// optimizer updates rely on (an under-full group would compress with
    /// pad slots whose gathered gradients are not zero).
    pub fn is_exact_row_nm(&self, scheme: NmScheme) -> bool {
        if self.cols % scheme.m != 0 {
            return false;
        }
        for r in 0..self.rows {
            for g in 0..self.cols / scheme.m {
                let kept = (0..scheme.m)
                    .filter(|i| self.at(r, g * scheme.m + i))
                    .count();
                if kept != scheme.n {
                    return false;
                }
            }
        }
        true
    }

    /// Verify the N:M constraint along columns (groups of `m` within a col).
    pub fn check_col_nm(&self, scheme: NmScheme) -> bool {
        assert_eq!(self.rows % scheme.m, 0);
        for c in 0..self.cols {
            for g in 0..self.rows / scheme.m {
                let kept = (0..scheme.m)
                    .filter(|i| self.at(g * scheme.m + i, c))
                    .count();
                if kept > scheme.n {
                    return false;
                }
            }
        }
        true
    }
}

/// SLoPe's init-time policy (§2.1): a *random* static N:M row mask —
/// every element equally likely to survive, then frozen for all of
/// pretraining.
pub fn random_row_mask(rows: usize, cols: usize, scheme: NmScheme, rng: &mut Rng) -> Mask {
    assert_eq!(cols % scheme.m, 0, "cols must be divisible by M");
    let mut keep = vec![false; rows * cols];
    let mut positions: Vec<usize> = (0..scheme.m).collect();
    for r in 0..rows {
        for g in 0..cols / scheme.m {
            rng.shuffle(&mut positions);
            for &p in positions.iter().take(scheme.n) {
                keep[r * cols + g * scheme.m + p] = true;
            }
        }
    }
    Mask { rows, cols, keep }
}

/// Magnitude row mask: keep the top-N |w| per group (SR-STE / re-masking).
pub fn magnitude_row_mask(w: &Matrix, scheme: NmScheme) -> Mask {
    score_row_mask(w, scheme, |v, _c| v.abs())
}

/// Wanda mask: score = |W[r,c]| · act_norm[c] (one-shot pruning).
pub fn wanda_row_mask(w: &Matrix, act_norm: &[f32], scheme: NmScheme) -> Mask {
    assert_eq!(act_norm.len(), w.cols);
    score_row_mask(w, scheme, |v, c| v.abs() * act_norm[c])
}

fn score_row_mask(w: &Matrix, scheme: NmScheme, score: impl Fn(f32, usize) -> f32) -> Mask {
    assert_eq!(w.cols % scheme.m, 0);
    let mut keep = vec![false; w.rows * w.cols];
    let mut idx: Vec<usize> = Vec::with_capacity(scheme.m);
    for r in 0..w.rows {
        for g in 0..w.cols / scheme.m {
            idx.clear();
            idx.extend(0..scheme.m);
            // Stable sort by descending score; earlier position wins ties.
            idx.sort_by(|&a, &b| {
                let ca = g * scheme.m + a;
                let cb = g * scheme.m + b;
                score(w.at(r, cb), cb)
                    .partial_cmp(&score(w.at(r, ca), ca))
                    .unwrap()
                    .then(a.cmp(&b))
            });
            for &p in idx.iter().take(scheme.n) {
                keep[r * w.cols + g * scheme.m + p] = true;
            }
        }
    }
    Mask { rows: w.rows, cols: w.cols, keep }
}

/// Double pruning (§2.1): transpose the row-pruned weight and impose N:M
/// along the new last dim (`d_out`) by magnitude; intersect with the row
/// mask (double pruning only removes).  Returns the `W^{R,C}` mask in the
/// original `d_out × d_in` layout.
pub fn double_prune_mask(w: &Matrix, row_mask: &Mask, scheme: NmScheme) -> Mask {
    assert_eq!(w.rows % scheme.m, 0, "rows must be divisible by M for column pruning");
    let wr = row_mask.apply(w);
    let wr_t = wr.transpose(); // (d_in, d_out): prune along d_out
    let col_t = magnitude_row_mask(&wr_t, scheme);
    let mut keep = vec![false; w.rows * w.cols];
    for r in 0..w.rows {
        for c in 0..w.cols {
            keep[r * w.cols + c] = row_mask.at(r, c) && col_t.at(c, r);
        }
    }
    Mask { rows: w.rows, cols: w.cols, keep }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn index_bits_match_eq7() {
        // C(4,2)=6 → 3 bits; C(2,1)=2 → 1 bit; C(8,2)=28 → 5 bits.
        assert_eq!(NmScheme::new(2, 4).index_bits_per_group(), 3);
        assert_eq!(NmScheme::new(1, 2).index_bits_per_group(), 1);
        assert_eq!(NmScheme::new(2, 8).index_bits_per_group(), 5);
    }

    #[test]
    fn offset_bits_are_log2_m() {
        assert_eq!(NmScheme::new(1, 2).offset_bits(), 1);
        assert_eq!(NmScheme::new(2, 4).offset_bits(), 2);
        assert_eq!(NmScheme::new(2, 8).offset_bits(), 3);
        assert_eq!(NmScheme::new(4, 8).offset_bits(), 3);
        assert_eq!(NmScheme::new(1, 1).offset_bits(), 0);
        assert_eq!(NmScheme::new(3, 6).offset_bits(), 3); // non-pow2 M rounds up
    }

    #[test]
    fn random_mask_is_exact_nm() {
        let mut rng = Rng::seed_from_u64(0);
        for (n, m) in [(1usize, 2usize), (2, 4), (2, 8)] {
            let s = NmScheme::new(n, m);
            let mask = random_row_mask(16, 8 * m, s, &mut rng);
            assert!(mask.check_row_nm(s));
            assert!((mask.density() - s.density()).abs() < 1e-9);
        }
    }

    #[test]
    fn magnitude_mask_keeps_largest() {
        let w = Matrix::from_vec(1, 4, vec![0.1, -3.0, 2.0, 0.5]);
        let mask = magnitude_row_mask(&w, NmScheme::TWO_FOUR);
        assert_eq!(mask.keep, vec![false, true, true, false]);
    }

    #[test]
    fn wanda_respects_activation_norms() {
        let w = Matrix::from_vec(1, 4, vec![0.01, 1.0, 1.0, 1.0]);
        let act = vec![1000.0, 1.0, 1.0, 1.0];
        let mask = wanda_row_mask(&w, &act, NmScheme::TWO_FOUR);
        assert!(mask.at(0, 0), "huge activation norm must rescue small weight");
    }

    #[test]
    fn double_prune_is_subset_and_col_nm() {
        let mut rng = Rng::seed_from_u64(7);
        let s = NmScheme::TWO_FOUR;
        let w = Matrix::randn(32, 32, 1.0, &mut rng);
        let mr = random_row_mask(32, 32, s, &mut rng);
        let mrc = double_prune_mask(&w, &mr, s);
        for i in 0..mr.keep.len() {
            assert!(!mrc.keep[i] || mr.keep[i], "double prune must only remove");
        }
        // Column constraint holds on the transpose view.
        let t = Mask { rows: mrc.cols, cols: mrc.rows,
                       keep: mrc.to_matrix().transpose().data.iter().map(|v| *v != 0.0).collect() };
        assert!(t.check_row_nm(s));
        assert!(mrc.density() <= mr.density());
    }

    #[test]
    fn mask_churn_hamming() {
        let a = Mask::ones(2, 4);
        let mut b = Mask::ones(2, 4);
        b.keep[0] = false;
        b.keep[5] = false;
        assert_eq!(a.hamming(&b), 2);
    }
}
