//! Fused ("prepacked") N:M operand layout: one contiguous stream per row
//! interleaving the kept values with their decode metadata, built once
//! from a [`CompressedNm`] — the DeepSparse-style CPU analogue of the
//! compressed-operand format sparse tensor cores stream from HBM.
//!
//! [`CompressedNm`] keeps two planes (`values`, `meta`) plus — on the
//! AVX2 path — a 256-entry lane-permute LUT consulted per metadata byte.
//! That is three streams per weight row.  [`PrepackedNm`] fuses them: at
//! prepack time every 2:4 metadata byte is decoded **once** into the
//! `vpermps` lane indices the gather kernel needs, and those indices are
//! interleaved with the eight values they gather for, so the hot loop
//! reads a single forward-moving stream and never touches the LUT.
//!
//! # 2:4 fused row layout (`u32` slots, little-endian bytes)
//!
//! ```text
//!  ┌ per metadata-byte PAIR (16 dense cols, 8 kept values): 10 slots ┐
//!  │ v0 v1 v2 v3 v4 v5 v6 v7 │ i00 i01 i02 i03 │ i10 i11 i12 i13 │   │
//!  │   8 × f32 (as bits)     │  slot 8: 4 × u8 │  slot 9: 4 × u8 │   │
//!  └──────────────────────────────────────────────────────────────────┘
//!  [ + one 5-slot unit (4 values, 4 lane bytes) if the byte count is odd ]
//!  [ + one 3-slot unit (2 values, 2 offset bytes) for a half-byte tail  ]
//! ```
//!
//! `i0j`/`i1j` are exactly the `IDX24`-style window lane indices
//! (`[b & 3, (b >> 2) & 3, 4 + ((b >> 4) & 3), 4 + ((b >> 6) & 3)]` for
//! metadata byte `b`): slots 8–9 are eight consecutive bytes, so one
//! `vpmovzxbd` widens them into the full 8-lane `vpermps` index vector —
//! no table lookup in the loop.  Other schemes (1:2, 2:8) fuse the raw
//! packed metadata bytes behind the row's values (`kcols` value slots +
//! `ceil(row_meta_bytes/4)` metadata slots); their kernels decode with
//! the same bit arithmetic as the compressed path.
//!
//! # Contract
//!
//! * `unpack(prepack(c)) == c` exactly, for every scheme — the layout is
//!   a pure re-encoding, pinned by the round-trip suite.
//! * SpMM over the prepacked plane is **bit-identical** to SpMM over the
//!   source `CompressedNm` at the same [`crate::backend::SimdLevel`]:
//!   the prepacked kernels replay the per-element reduction order of the
//!   compressed-plane kernels and only re-arrange where operands are
//!   loaded from (`tests/simd_parity.rs` pins this across levels,
//!   thread counts, and partition strategies).
//! * [`PrepackedNm::refresh_values`] rewrites the interleaved value slots
//!   in place from an updated `CompressedNm` with the same pattern — the
//!   cheap O(nnz) path the training executor takes after each in-place
//!   optimizer step (the pattern, hence the index slots, never changes
//!   under static masks).
//!
//! `SLOPE_PREPACK=off` ([`prepack_enabled`]) disables prepacking
//! process-wide so the compressed-plane path stays an always-available
//! pinned ground truth (CI runs the decode + parity suites both ways).

use super::{CompressedNm, NmScheme};
use std::sync::OnceLock;

/// Process-wide prepack gate, read once from `SLOPE_PREPACK`
/// (`off|0|false` disables; anything else, including unset, enables).
/// Mirrors the `SLOPE_SIMD` dispatch pattern: decided at first use,
/// constant for the life of the process.
pub fn prepack_enabled() -> bool {
    static ON: OnceLock<bool> = OnceLock::new();
    *ON.get_or_init(|| match std::env::var("SLOPE_PREPACK").unwrap_or_default().as_str() {
        "off" | "0" | "false" => false,
        "" | "on" | "auto" | "1" => true,
        other => {
            eprintln!("[prepack] unknown SLOPE_PREPACK={other:?} (want on|off); using on");
            true
        }
    })
}

/// A compressed N:M plane re-encoded as one interleaved value+metadata
/// stream per row (module docs hold the byte layout).
#[derive(Clone, Debug, PartialEq)]
pub struct PrepackedNm {
    pub rows: usize,
    /// Original (dense) number of columns.
    pub cols: usize,
    pub scheme: NmScheme,
    /// `rows × row_stride` fused slots; values stored as `f32::to_bits`,
    /// index/metadata bytes packed little-endian.
    stream: Vec<u32>,
    row_stride: usize,
}

/// The four window lane indices of one 2:4 metadata byte — entries 2/3
/// carry the second group's +4 window bias (same encoding as the AVX2
/// `IDX24` LUT, stored here so the kernel never consults the table).
#[inline]
fn lanes24(b: u8) -> [u8; 4] {
    [b & 3, (b >> 2) & 3, 4 + ((b >> 4) & 3), 4 + ((b >> 6) & 3)]
}

/// Inverse of [`lanes24`]: recover the metadata byte (half-byte tails
/// pass `half = true` and only restore the low nibble).
#[inline]
fn byte_from_lanes(l: [u8; 4], half: bool) -> u8 {
    let lo = (l[0] & 3) | ((l[1] & 3) << 2);
    if half {
        lo
    } else {
        lo | ((l[2] - 4) << 4) | ((l[3] - 4) << 6)
    }
}

/// Read metadata byte `j` out of the little-endian byte stream packed
/// into `u32` slots (the generic-scheme fused metadata region).
#[inline]
pub(crate) fn slot_meta_byte(slots: &[u32], j: usize) -> u8 {
    (slots[j / 4] >> (8 * (j % 4))) as u8
}

/// [`unpack_offset`] over slot-packed metadata bytes — identical bit
/// arithmetic, different backing store.
#[inline]
pub(crate) fn unpack_offset_slots(slots: &[u32], k: usize, bits: u32) -> usize {
    if bits == 0 {
        return 0;
    }
    let bitpos = k * bits as usize;
    let byte = bitpos >> 3;
    let sh = (bitpos & 7) as u32;
    let mut w = (slot_meta_byte(slots, byte) as u32) >> sh;
    if sh + bits > 8 {
        w |= (slot_meta_byte(slots, byte + 1) as u32) << (8 - sh);
    }
    (w & ((1u32 << bits) - 1)) as usize
}

impl PrepackedNm {
    /// Whether this plane uses the fused 2:4 lane-index layout (vs. the
    /// generic values+metadata concatenation).
    #[inline]
    pub fn is_fused24(&self) -> bool {
        self.scheme.n == 2 && self.scheme.m == 4
    }

    /// Kept entries per row (same as the source plane).
    #[inline]
    pub fn kcols(&self) -> usize {
        self.cols / self.scheme.m * self.scheme.n
    }

    /// Fused `u32` slots per row.
    #[inline]
    pub fn row_stride(&self) -> usize {
        self.row_stride
    }

    /// One row's fused stream.
    #[inline]
    pub fn row(&self, r: usize) -> &[u32] {
        &self.stream[r * self.row_stride..(r + 1) * self.row_stride]
    }

    /// Bytes of the fused stream actually resident — what `memmodel`
    /// charges for a prepacked plane.
    #[inline]
    pub fn stream_bytes(&self) -> usize {
        self.stream.len() * 4
    }

    /// Fused slots per row for a `cols`-wide plane under `s` — the pure
    /// size function `memmodel::prepacked_plane_bytes` mirrors.
    pub fn row_stride_for(cols: usize, s: NmScheme) -> usize {
        let kc = cols / s.m * s.n;
        if s.n == 2 && s.m == 4 {
            let pairs = kc / 4;
            pairs / 2 * 10 + pairs % 2 * 5 + if kc % 4 == 2 { 3 } else { 0 }
        } else {
            let rmb = (kc * s.offset_bits() as usize).div_ceil(8);
            kc + rmb.div_ceil(4)
        }
    }

    /// Build the fused plane from a compressed one (decode-once: for 2:4
    /// every metadata byte becomes its four window lane indices here, so
    /// the SpMM loop never touches a LUT).
    pub fn prepack(c: &CompressedNm) -> Self {
        let kc = c.kcols();
        let rmb = c.row_meta_bytes();
        let row_stride = Self::row_stride_for(c.cols, c.scheme);
        let mut stream = vec![0u32; c.rows * row_stride];
        let fused24 = c.scheme.n == 2 && c.scheme.m == 4;
        for r in 0..c.rows {
            let vals = &c.values[r * kc..(r + 1) * kc];
            let meta = &c.meta[r * rmb..(r + 1) * rmb];
            let out = &mut stream[r * row_stride..(r + 1) * row_stride];
            if fused24 {
                let pairs = kc / 4;
                let mut slot = 0;
                let mut byte = 0;
                while byte + 2 <= pairs {
                    for (j, v) in vals[byte * 4..byte * 4 + 8].iter().enumerate() {
                        out[slot + j] = v.to_bits();
                    }
                    out[slot + 8] = u32::from_le_bytes(lanes24(meta[byte]));
                    out[slot + 9] = u32::from_le_bytes(lanes24(meta[byte + 1]));
                    slot += 10;
                    byte += 2;
                }
                if byte < pairs {
                    for (j, v) in vals[byte * 4..byte * 4 + 4].iter().enumerate() {
                        out[slot + j] = v.to_bits();
                    }
                    out[slot + 4] = u32::from_le_bytes(lanes24(meta[byte]));
                    slot += 5;
                    byte += 1;
                }
                if kc % 4 == 2 {
                    out[slot] = vals[kc - 2].to_bits();
                    out[slot + 1] = vals[kc - 1].to_bits();
                    let l = lanes24(meta[byte]);
                    out[slot + 2] = u32::from_le_bytes([l[0], l[1], 0, 0]);
                }
            } else {
                for (j, v) in vals.iter().enumerate() {
                    out[j] = v.to_bits();
                }
                for (j, b) in meta.iter().enumerate() {
                    out[kc + j / 4] |= (*b as u32) << (8 * (j % 4));
                }
            }
        }
        Self { rows: c.rows, cols: c.cols, scheme: c.scheme, stream, row_stride }
    }

    /// Invert [`Self::prepack`] exactly — the round-trip the suite pins.
    pub fn unpack(&self) -> CompressedNm {
        let kc = self.kcols();
        let s = self.scheme;
        let rmb = (kc * s.offset_bits() as usize).div_ceil(8);
        let mut values = vec![0.0f32; self.rows * kc];
        let mut meta = vec![0u8; self.rows * rmb];
        for r in 0..self.rows {
            let row = self.row(r);
            let vals = &mut values[r * kc..(r + 1) * kc];
            let mrow = &mut meta[r * rmb..(r + 1) * rmb];
            if self.is_fused24() {
                let pairs = kc / 4;
                let mut slot = 0;
                let mut byte = 0;
                while byte + 2 <= pairs {
                    for (j, v) in vals[byte * 4..byte * 4 + 8].iter_mut().enumerate() {
                        *v = f32::from_bits(row[slot + j]);
                    }
                    mrow[byte] = byte_from_lanes(row[slot + 8].to_le_bytes(), false);
                    mrow[byte + 1] = byte_from_lanes(row[slot + 9].to_le_bytes(), false);
                    slot += 10;
                    byte += 2;
                }
                if byte < pairs {
                    for (j, v) in vals[byte * 4..byte * 4 + 4].iter_mut().enumerate() {
                        *v = f32::from_bits(row[slot + j]);
                    }
                    mrow[byte] = byte_from_lanes(row[slot + 4].to_le_bytes(), false);
                    slot += 5;
                    byte += 1;
                }
                if kc % 4 == 2 {
                    vals[kc - 2] = f32::from_bits(row[slot]);
                    vals[kc - 1] = f32::from_bits(row[slot + 1]);
                    mrow[byte] = byte_from_lanes(row[slot + 2].to_le_bytes(), true);
                }
            } else {
                for (j, v) in vals.iter_mut().enumerate() {
                    *v = f32::from_bits(row[j]);
                }
                for (j, b) in mrow.iter_mut().enumerate() {
                    *b = slot_meta_byte(&row[kc..], j);
                }
            }
        }
        CompressedNm { rows: self.rows, cols: self.cols, scheme: s, values, meta }
    }

    /// Rewrite the interleaved value slots from `c` (same shape, scheme,
    /// and — by the static-mask contract — the same pattern), leaving the
    /// index slots untouched.  O(nnz); the post-optimizer-step refresh.
    pub fn refresh_values(&mut self, c: &CompressedNm) {
        assert_eq!(
            (self.rows, self.cols, self.scheme),
            (c.rows, c.cols, c.scheme),
            "refresh_values: plane shape/scheme mismatch"
        );
        let kc = self.kcols();
        let stride = self.row_stride;
        for r in 0..self.rows {
            let vals = &c.values[r * kc..(r + 1) * kc];
            let out = &mut self.stream[r * stride..(r + 1) * stride];
            if self.scheme.n == 2 && self.scheme.m == 4 {
                let pairs = kc / 4;
                let mut slot = 0;
                let mut byte = 0;
                while byte + 2 <= pairs {
                    for (j, v) in vals[byte * 4..byte * 4 + 8].iter().enumerate() {
                        out[slot + j] = v.to_bits();
                    }
                    slot += 10;
                    byte += 2;
                }
                if byte < pairs {
                    for (j, v) in vals[byte * 4..byte * 4 + 4].iter().enumerate() {
                        out[slot + j] = v.to_bits();
                    }
                    slot += 5;
                }
                if kc % 4 == 2 {
                    out[slot] = vals[kc - 2].to_bits();
                    out[slot + 1] = vals[kc - 1].to_bits();
                }
            } else {
                for (j, v) in vals.iter().enumerate() {
                    out[j] = v.to_bits();
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparsity::{random_row_mask, Mask};
    use crate::tensor::Matrix;
    use crate::util::Rng;

    #[test]
    fn prepack_roundtrips_all_schemes_and_tails() {
        let mut rng = Rng::seed_from_u64(0x9E);
        for (n, m) in [(1usize, 2usize), (2, 4), (2, 8)] {
            let s = NmScheme::new(n, m);
            // Group counts hitting every tail shape: even pairs, odd
            // trailing byte, half-byte tail, and single-group rows.
            for groups in [1usize, 2, 3, 4, 5, 7, 8, 9] {
                let cols = groups * m;
                let w = Matrix::randn(5, cols, 1.0, &mut rng);
                let mask = random_row_mask(5, cols, s, &mut rng);
                let c = CompressedNm::compress(&w, &mask, s);
                let p = PrepackedNm::prepack(&c);
                assert_eq!(p.row_stride(), PrepackedNm::row_stride_for(cols, s));
                assert_eq!(p.unpack(), c, "{s} groups={groups}");
            }
        }
    }

    #[test]
    fn fused24_interleaves_values_with_lane_indexes() {
        // 2:4 offsets [1, 3 | 0, 2] → metadata byte 0b1000_1101 (the
        // golden-byte pin in `compressed`); its fused lane word must hold
        // [1, 3, 4+0, 4+2].
        let mask = Mask {
            rows: 1,
            cols: 8,
            keep: vec![false, true, false, true, true, false, true, false],
        };
        let w = Matrix::from_vec(1, 8, (1..=8).map(|v| v as f32).collect());
        let c = CompressedNm::compress(&w, &mask, NmScheme::TWO_FOUR);
        assert_eq!(c.meta, vec![0b1000_1101]);
        let p = PrepackedNm::prepack(&c);
        // One metadata byte ⇒ one trailing 5-slot unit: 4 values + lanes.
        assert_eq!(p.row_stride(), 5);
        let row = p.row(0);
        assert_eq!(f32::from_bits(row[0]), 2.0);
        assert_eq!(f32::from_bits(row[3]), 7.0);
        assert_eq!(row[4].to_le_bytes(), [1, 3, 4, 6]);
    }

    #[test]
    fn refresh_values_tracks_in_place_updates() {
        let mut rng = Rng::seed_from_u64(0x9F);
        for (n, m) in [(1usize, 2usize), (2, 4), (2, 8)] {
            let s = NmScheme::new(n, m);
            let w = Matrix::randn(4, 5 * m, 1.0, &mut rng);
            let mask = random_row_mask(4, 5 * m, s, &mut rng);
            let mut c = CompressedNm::compress(&w, &mask, s);
            let mut p = PrepackedNm::prepack(&c);
            let w2 = Matrix::randn(4, 5 * m, 1.0, &mut rng);
            c.update_from_dense(&w2);
            p.refresh_values(&c);
            assert_eq!(p.unpack(), c, "{s}");
            assert_eq!(p, PrepackedNm::prepack(&c), "{s}: refresh == full prepack");
        }
    }

    #[test]
    fn stream_bytes_matches_layout_arithmetic() {
        let mut rng = Rng::seed_from_u64(0xA0);
        // 2:4, 7 groups = 14 kept = 3 full metadata bytes + a half byte:
        // one pair unit (10 slots) + one trailing-byte unit (5) + the
        // half-byte tail (3) — all three unit kinds in one row.
        let w = Matrix::randn(3, 28, 1.0, &mut rng);
        let mask = random_row_mask(3, 28, NmScheme::TWO_FOUR, &mut rng);
        let c = CompressedNm::compress(&w, &mask, NmScheme::TWO_FOUR);
        let p = PrepackedNm::prepack(&c);
        assert_eq!(p.row_stride(), 18);
        assert_eq!(p.stream_bytes(), 3 * 18 * 4);
    }

    #[test]
    fn prepack_env_gate_defaults_on() {
        // The test binary never sets SLOPE_PREPACK, so the cached gate
        // resolves from the ambient environment; CI's escape-hatch leg
        // exports SLOPE_PREPACK=off and flips this expectation.
        let want = !matches!(
            std::env::var("SLOPE_PREPACK").unwrap_or_default().as_str(),
            "off" | "0" | "false"
        );
        assert_eq!(prepack_enabled(), want);
    }
}
