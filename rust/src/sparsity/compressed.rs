//! Compressed N:M storage (the cuSPARSELt "compressed matrix" role).
//!
//! Layout matches `python/compile/sparsity.compress_nm` semantics for the
//! values plane: for a `d_out × d_in` weight under an N:M row mask, store
//! `values`: `d_out × (d_in·N/M)` kept values, group-major, padded with
//! zeros when a group has fewer than N survivors.
//!
//! The index plane is the Eq.-7 **bit-packed** layout: one intra-group
//! column offset of `ceil(log2(M))` bits per kept value, packed LSB-first
//! into `u8` words with every row starting byte-aligned.  For 2:4 that is
//! 2 bits per kept value — 4 bits per group against the 32 bits the old
//! `u16` absolute-index plane spent, an 8× metadata-traffic reduction —
//! and the layout no longer caps `cols` at 65 536.  Offsets are strictly
//! increasing within each group (the kernels' monotonicity assumption),
//! and the absolute column is recovered inline in the SpMM gather loop as
//! `group·M + offset`.  `storage_bits()` still accounts metadata at the
//! information-theoretic Eq.-7 rate (3 bits per 2:4 group — what the
//! paper's §3.1 memory model charges); `packed_storage_bits()` accounts
//! the real in-RAM plane.

use super::{Mask, NmScheme};
use crate::tensor::Matrix;

/// A matrix compressed under an N:M row scheme.
#[derive(Clone, Debug, PartialEq)]
pub struct CompressedNm {
    pub rows: usize,
    /// Original (dense) number of columns.
    pub cols: usize,
    pub scheme: NmScheme,
    /// `rows × cols·N/M` kept values, row-major, group-major within a row.
    pub values: Vec<f32>,
    /// Bit-packed intra-group offsets: `scheme.offset_bits()` bits per
    /// kept value, LSB-first, rows byte-aligned ([`Self::row_meta_bytes`]).
    pub meta: Vec<u8>,
}

/// Decode the `k`-th `bits`-wide offset from a row's packed metadata.
/// Entries may straddle a byte boundary (e.g. 3-bit offsets for M=8); the
/// second byte is only touched when the entry actually extends into it,
/// which by construction is then inside the row's span.
#[inline]
pub fn unpack_offset(meta: &[u8], k: usize, bits: u32) -> usize {
    if bits == 0 {
        return 0;
    }
    let bitpos = k * bits as usize;
    let byte = bitpos >> 3;
    let sh = (bitpos & 7) as u32;
    let mut w = (meta[byte] as u32) >> sh;
    if sh + bits > 8 {
        w |= (meta[byte + 1] as u32) << (8 - sh);
    }
    (w & ((1u32 << bits) - 1)) as usize
}

/// Write the `k`-th `bits`-wide offset into zero-initialized packed
/// metadata (OR-composed, so each slot must be written at most once).
#[inline]
fn pack_offset(meta: &mut [u8], k: usize, bits: u32, off: usize) {
    if bits == 0 {
        return;
    }
    debug_assert!(off < (1usize << bits));
    let bitpos = k * bits as usize;
    let byte = bitpos >> 3;
    let sh = (bitpos & 7) as u32;
    meta[byte] |= ((off as u32) << sh) as u8;
    if sh + bits > 8 {
        meta[byte + 1] |= ((off as u32) >> (8 - sh)) as u8;
    }
}

impl CompressedNm {
    /// Kept entries per row.
    #[inline]
    pub fn kcols(&self) -> usize {
        self.cols / self.scheme.m * self.scheme.n
    }

    /// Packed metadata bytes per row (rows are byte-aligned).
    #[inline]
    pub fn row_meta_bytes(&self) -> usize {
        (self.kcols() * self.scheme.offset_bits() as usize + 7) / 8
    }

    /// Total packed metadata bytes actually stored (the plane the SpMM
    /// kernels stream — what the memmodel's packed rate charges).
    #[inline]
    pub fn meta_bytes(&self) -> usize {
        self.meta.len()
    }

    /// Absolute dense column of the `k`-th kept entry of row `r`
    /// (cold-path accessor; the kernels decode inline instead).
    #[inline]
    pub fn index(&self, r: usize, k: usize) -> usize {
        let rmb = self.row_meta_bytes();
        let row = &self.meta[r * rmb..(r + 1) * rmb];
        (k / self.scheme.n) * self.scheme.m + unpack_offset(row, k, self.scheme.offset_bits())
    }

    /// Iterate the absolute dense columns of row `r`'s kept entries.
    #[inline]
    pub fn row_indices(&self, r: usize) -> impl Iterator<Item = usize> + '_ {
        let bits = self.scheme.offset_bits();
        let rmb = self.row_meta_bytes();
        let row = &self.meta[r * rmb..(r + 1) * rmb];
        let (n, m) = (self.scheme.n, self.scheme.m);
        (0..self.kcols()).map(move |k| (k / n) * m + unpack_offset(row, k, bits))
    }

    /// Compress `w` under `mask` (the cuSPARSELt *setup/compress* phase;
    /// its cost is what Figure 5 profiles vs. the multiply).
    pub fn compress(w: &Matrix, mask: &Mask, scheme: NmScheme) -> Self {
        assert_eq!((w.rows, w.cols), (mask.rows, mask.cols));
        assert_eq!(w.cols % scheme.m, 0);
        assert!(scheme.offset_bits() <= 8, "packed layout supports M ≤ 256");
        let groups = w.cols / scheme.m;
        let kc = groups * scheme.n;
        let rmb = (kc * scheme.offset_bits() as usize + 7) / 8;
        let mut values = vec![0.0f32; w.rows * kc];
        let mut meta = vec![0u8; w.rows * rmb];
        // Scratch for one group: (offset, value) pairs.
        let mut pairs: Vec<(usize, f32)> = Vec::with_capacity(scheme.n);
        for r in 0..w.rows {
            let mrow = &mut meta[r * rmb..(r + 1) * rmb];
            for g in 0..groups {
                pairs.clear();
                // First pass: kept positions in order.
                for i in 0..scheme.m {
                    if mask.at(r, g * scheme.m + i) && pairs.len() < scheme.n {
                        pairs.push((i, w.at(r, g * scheme.m + i)));
                    }
                }
                // Pad under-full groups with zeros pointing at pruned slots
                // (value 0 ⇒ decompress-insensitive), then restore strict
                // in-group offset ordering for the kernels' monotonicity
                // assumption (pads may interleave with kept positions).
                let mut pad_i = 0;
                while pairs.len() < scheme.n {
                    while mask.at(r, g * scheme.m + pad_i) {
                        pad_i += 1;
                    }
                    pairs.push((pad_i, 0.0));
                    pad_i += 1;
                }
                pairs.sort_by_key(|p| p.0);
                for (slot, (off, v)) in pairs.iter().enumerate() {
                    let k = g * scheme.n + slot;
                    values[r * kc + k] = *v;
                    pack_offset(mrow, k, scheme.offset_bits(), *off);
                }
            }
        }
        Self { rows: w.rows, cols: w.cols, scheme, values, meta }
    }

    /// Expand back to dense (test / checkpoint path).
    pub fn decompress(&self) -> Matrix {
        let mut out = Matrix::zeros(self.rows, self.cols);
        self.decompress_into(&mut out);
        out
    }

    /// Expand into a caller-owned dense buffer (resized once, zeroed and
    /// scattered each call) — the allocation-free export path the host
    /// training executor uses to round-trip packed weights through the
    /// literal store every step.
    pub fn decompress_into(&self, out: &mut Matrix) {
        if (out.rows, out.cols) != (self.rows, self.cols) {
            *out = Matrix::zeros(self.rows, self.cols);
        } else {
            out.data.fill(0.0);
        }
        let kc = self.kcols();
        for r in 0..self.rows {
            for (k, c) in self.row_indices(r).enumerate() {
                out.data[r * self.cols + c] += self.values[r * kc + k];
            }
        }
    }

    /// Overwrite values in-place from a dense matrix with the *same* mask
    /// (Algorithm 1 lines 17–18: `updateSparseMatrix`).  No re-indexing —
    /// that is the whole point of static masks (Appendix B).
    pub fn update_from_dense(&mut self, w: &Matrix) {
        assert_eq!((w.rows, w.cols), (self.rows, self.cols));
        let kc = self.kcols();
        let bits = self.scheme.offset_bits();
        let rmb = self.row_meta_bytes();
        let (n, m) = (self.scheme.n, self.scheme.m);
        for r in 0..self.rows {
            let mrow = &self.meta[r * rmb..(r + 1) * rmb];
            let wrow = w.row(r);
            for k in 0..kc {
                let c = (k / n) * m + unpack_offset(mrow, k, bits);
                self.values[r * kc + k] = wrow[c];
            }
        }
    }

    /// `β·self + γ·other` over values planes that share a sparsity pattern
    /// (Algorithm 1 line 15 — the paper's custom sparse-add kernel).
    pub fn sparse_add(&self, other: &CompressedNm, beta: f32, gamma: f32) -> CompressedNm {
        assert_eq!((self.rows, self.cols, self.scheme), (other.rows, other.cols, other.scheme));
        assert_eq!(self.meta, other.meta, "sparse_add requires identical patterns");
        let values = self
            .values
            .iter()
            .zip(&other.values)
            .map(|(a, b)| beta * a + gamma * b)
            .collect();
        CompressedNm { values, ..self.clone() }
    }

    /// Bits of storage at the information-theoretic Eq.-7 metadata rate
    /// (values at `value_bits` + `⌈log₂C(M,N)⌉` per group) — what the
    /// §3.1 memory model charges.
    pub fn storage_bits(&self, value_bits: u64) -> u64 {
        let kept = (self.rows * self.kcols()) as u64;
        let groups = (self.rows * (self.cols / self.scheme.m)) as u64;
        kept * value_bits + groups * self.scheme.index_bits_per_group() as u64
    }

    /// Bits of storage at the *packed in-RAM* rate actually held by this
    /// struct: values plus the byte-aligned packed offset plane.
    pub fn packed_storage_bits(&self, value_bits: u64) -> u64 {
        (self.rows * self.kcols()) as u64 * value_bits + 8 * self.meta_bytes() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparsity::{magnitude_row_mask, random_row_mask};
    use crate::util::Rng;

    #[test]
    fn compress_roundtrip_random_mask() {
        let mut rng = Rng::seed_from_u64(3);
        for (n, m) in [(1usize, 2usize), (2, 4), (2, 8)] {
            let s = NmScheme::new(n, m);
            let w = Matrix::randn(8, 4 * m, 1.0, &mut rng);
            let mask = random_row_mask(8, 4 * m, s, &mut rng);
            let c = CompressedNm::compress(&w, &mask, s);
            assert_eq!(c.decompress(), mask.apply(&w));
        }
    }

    #[test]
    fn compress_roundtrip_magnitude_mask() {
        let mut rng = Rng::seed_from_u64(4);
        let w = Matrix::randn(16, 32, 1.0, &mut rng);
        let mask = magnitude_row_mask(&w, NmScheme::TWO_FOUR);
        let c = CompressedNm::compress(&w, &mask, NmScheme::TWO_FOUR);
        assert_eq!(c.decompress(), mask.apply(&w));
    }

    #[test]
    fn update_in_place_keeps_pattern() {
        let mut rng = Rng::seed_from_u64(5);
        let w = Matrix::randn(8, 16, 1.0, &mut rng);
        let mask = random_row_mask(8, 16, NmScheme::TWO_FOUR, &mut rng);
        let mut c = CompressedNm::compress(&w, &mask, NmScheme::TWO_FOUR);
        let w2 = Matrix::randn(8, 16, 1.0, &mut rng);
        c.update_from_dense(&w2);
        assert_eq!(c.decompress(), mask.apply(&w2));
    }

    #[test]
    fn sparse_add_linear_combination() {
        let mut rng = Rng::seed_from_u64(6);
        let w = Matrix::randn(4, 8, 1.0, &mut rng);
        let mask = random_row_mask(4, 8, NmScheme::TWO_FOUR, &mut rng);
        let a = CompressedNm::compress(&w, &mask, NmScheme::TWO_FOUR);
        let out = a.sparse_add(&a, 0.5, 2.0);
        for (o, v) in out.values.iter().zip(&a.values) {
            assert!((o - 2.5 * v).abs() < 1e-6);
        }
    }

    #[test]
    fn storage_bits_2to4_example() {
        // 2:4 over fp16: per 4-elem group, 2×16-bit values + 3 index bits.
        let w = Matrix::zeros(1, 4);
        let mask = Mask { rows: 1, cols: 4, keep: vec![true, true, false, false] };
        let c = CompressedNm::compress(&w, &mask, NmScheme::TWO_FOUR);
        assert_eq!(c.storage_bits(16), 2 * 16 + 3);
        // Packed plane: 2 offsets × 2 bits = 4 bits → 1 byte-aligned byte.
        assert_eq!(c.meta_bytes(), 1);
        assert_eq!(c.packed_storage_bits(16), 2 * 16 + 8);
    }

    #[test]
    fn indices_monotone_with_padding() {
        // A mask with an under-full group (only 1 kept in a 2:4 group).
        let mask = Mask { rows: 1, cols: 8,
                          keep: vec![false, true, false, false, true, true, false, false] };
        let w = Matrix::from_vec(1, 8, (1..=8).map(|v| v as f32).collect());
        let c = CompressedNm::compress(&w, &mask, NmScheme::TWO_FOUR);
        for g in 0..2 {
            assert!(c.index(0, g * 2) < c.index(0, g * 2 + 1));
        }
        assert_eq!(c.decompress(), mask.apply(&w));
    }

    #[test]
    fn packed_offsets_decode_to_in_group_columns() {
        let mut rng = Rng::seed_from_u64(7);
        for (n, m) in [(1usize, 2usize), (2, 4), (2, 8), (4, 8)] {
            let s = NmScheme::new(n, m);
            let w = Matrix::randn(5, 3 * m, 1.0, &mut rng);
            let mask = random_row_mask(5, 3 * m, s, &mut rng);
            let c = CompressedNm::compress(&w, &mask, s);
            for r in 0..c.rows {
                for (k, col) in c.row_indices(r).enumerate() {
                    let g = k / n;
                    assert!(col >= g * m && col < (g + 1) * m, "{s} r{r} k{k}");
                }
            }
        }
    }

    #[test]
    fn golden_bytes_match_python_packed_layout() {
        // Cross-language byte pin (python/tests/test_sparsity.py holds the
        // mirror): 2:4 offsets [1, 3 | 0, 2] pack LSB-first to 0b10001101.
        let mask = Mask { rows: 1, cols: 8,
                          keep: vec![false, true, false, true, true, false, true, false] };
        let w = Matrix::from_vec(1, 8, (1..=8).map(|v| v as f32).collect());
        let c = CompressedNm::compress(&w, &mask, NmScheme::TWO_FOUR);
        assert_eq!(c.meta, vec![0b1000_1101]);
        // 2:8 (3-bit offsets, straddling a byte): offsets [5, 7 | 1, 6]
        // → bytes [0b01111101, 0b00001100].
        let mut keep = vec![false; 16];
        for i in [5usize, 7, 8 + 1, 8 + 6] {
            keep[i] = true;
        }
        let mask8 = Mask { rows: 1, cols: 16, keep };
        let w8 = Matrix::from_vec(1, 16, (1..=16).map(|v| v as f32).collect());
        let c8 = CompressedNm::compress(&w8, &mask8, NmScheme::new(2, 8));
        assert_eq!(c8.meta, vec![0b0111_1101, 0b0000_1100]);
    }

    #[test]
    fn meta_plane_is_8x_smaller_than_u16_indices_for_2_4() {
        let mut rng = Rng::seed_from_u64(8);
        let w = Matrix::randn(64, 256, 1.0, &mut rng);
        let mask = random_row_mask(64, 256, NmScheme::TWO_FOUR, &mut rng);
        let c = CompressedNm::compress(&w, &mask, NmScheme::TWO_FOUR);
        let kept = c.rows * c.kcols();
        assert_eq!(c.meta_bytes() * 8, kept * 2); // 2 bits per kept value
        let u16_plane_bytes = kept * 2; // the old absolute-index layout
        assert_eq!(u16_plane_bytes / c.meta_bytes(), 8);
    }
}
