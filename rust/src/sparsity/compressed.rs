//! Compressed N:M storage (the cuSPARSELt "compressed matrix" role).
//!
//! Layout matches `python/compile/sparsity.compress_nm` semantics: for a
//! `d_out × d_in` weight under an N:M row mask, store
//! * `values`:  `d_out × (d_in·N/M)` kept values, group-major, padded with
//!   zeros when a group has fewer than N survivors;
//! * `indices`: same shape, the absolute column index of each value
//!   (strictly increasing within each group).
//!
//! `index_bits()` accounts metadata at the Eq.-7 rate (e.g. 3 bits per
//! kept pair for 2:4), which is what the memory model charges; the in-RAM
//! representation uses `u16` for simplicity (cols < 65536 in every model
//! we instantiate on CPU).

use super::{Mask, NmScheme};
use crate::tensor::Matrix;

/// A matrix compressed under an N:M row scheme.
#[derive(Clone, Debug)]
pub struct CompressedNm {
    pub rows: usize,
    /// Original (dense) number of columns.
    pub cols: usize,
    pub scheme: NmScheme,
    /// `rows × cols·N/M` kept values, row-major.
    pub values: Vec<f32>,
    /// Absolute dense column index per kept value.
    pub indices: Vec<u16>,
}

impl CompressedNm {
    /// Kept entries per row.
    #[inline]
    pub fn kcols(&self) -> usize {
        self.cols / self.scheme.m * self.scheme.n
    }

    /// Compress `w` under `mask` (the cuSPARSELt *setup/compress* phase;
    /// its cost is what Figure 5 profiles vs. the multiply).
    pub fn compress(w: &Matrix, mask: &Mask, scheme: NmScheme) -> Self {
        assert_eq!((w.rows, w.cols), (mask.rows, mask.cols));
        assert_eq!(w.cols % scheme.m, 0);
        assert!(w.cols < u16::MAX as usize, "u16 index range");
        let groups = w.cols / scheme.m;
        let kc = groups * scheme.n;
        let mut values = vec![0.0f32; w.rows * kc];
        let mut indices = vec![0u16; w.rows * kc];
        for r in 0..w.rows {
            for g in 0..groups {
                let mut slot = 0;
                // First pass: kept positions in order.
                for i in 0..scheme.m {
                    let c = g * scheme.m + i;
                    if mask.at(r, c) && slot < scheme.n {
                        values[r * kc + g * scheme.n + slot] = w.at(r, c);
                        indices[r * kc + g * scheme.n + slot] = c as u16;
                        slot += 1;
                    }
                }
                // Pad under-full groups with zeros pointing at pruned slots
                // (value 0 ⇒ decompress-insensitive), keeping indices
                // strictly increasing for the kernel's monotonicity
                // assumption.
                let mut pad_c = g * scheme.m;
                while slot < scheme.n {
                    while mask.at(r, pad_c) {
                        pad_c += 1;
                    }
                    values[r * kc + g * scheme.n + slot] = 0.0;
                    indices[r * kc + g * scheme.n + slot] = pad_c as u16;
                    pad_c += 1;
                    slot += 1;
                }
                // Restore in-group ordering (pads may interleave).
                let s = r * kc + g * scheme.n;
                let mut pairs: Vec<(u16, f32)> = (0..scheme.n)
                    .map(|i| (indices[s + i], values[s + i]))
                    .collect();
                pairs.sort_by_key(|p| p.0);
                for (i, (ix, v)) in pairs.into_iter().enumerate() {
                    indices[s + i] = ix;
                    values[s + i] = v;
                }
            }
        }
        Self { rows: w.rows, cols: w.cols, scheme, values, indices }
    }

    /// Expand back to dense (test / checkpoint path).
    pub fn decompress(&self) -> Matrix {
        let mut out = Matrix::zeros(self.rows, self.cols);
        let kc = self.kcols();
        for r in 0..self.rows {
            for k in 0..kc {
                let c = self.indices[r * kc + k] as usize;
                out.data[r * self.cols + c] += self.values[r * kc + k];
            }
        }
        out
    }

    /// Overwrite values in-place from a dense matrix with the *same* mask
    /// (Algorithm 1 lines 17–18: `updateSparseMatrix`).  No re-indexing —
    /// that is the whole point of static masks (Appendix B).
    pub fn update_from_dense(&mut self, w: &Matrix) {
        assert_eq!((w.rows, w.cols), (self.rows, self.cols));
        let kc = self.kcols();
        for r in 0..self.rows {
            for k in 0..kc {
                let c = self.indices[r * kc + k] as usize;
                self.values[r * kc + k] = w.at(r, c);
            }
        }
    }

    /// `β·self + γ·other` over values planes that share a sparsity pattern
    /// (Algorithm 1 line 15 — the paper's custom sparse-add kernel).
    pub fn sparse_add(&self, other: &CompressedNm, beta: f32, gamma: f32) -> CompressedNm {
        assert_eq!(self.indices, other.indices, "sparse_add requires identical patterns");
        let values = self
            .values
            .iter()
            .zip(&other.values)
            .map(|(a, b)| beta * a + gamma * b)
            .collect();
        CompressedNm { values, ..self.clone() }
    }

    /// Bits of storage (values at `value_bits` + Eq.-7 index metadata).
    pub fn storage_bits(&self, value_bits: u64) -> u64 {
        let kept = (self.rows * self.kcols()) as u64;
        let groups = (self.rows * (self.cols / self.scheme.m)) as u64;
        kept * value_bits + groups * self.scheme.index_bits_per_group() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparsity::{magnitude_row_mask, random_row_mask};
    use crate::util::Rng;

    #[test]
    fn compress_roundtrip_random_mask() {
        let mut rng = Rng::seed_from_u64(3);
        for (n, m) in [(1usize, 2usize), (2, 4), (2, 8)] {
            let s = NmScheme::new(n, m);
            let w = Matrix::randn(8, 4 * m, 1.0, &mut rng);
            let mask = random_row_mask(8, 4 * m, s, &mut rng);
            let c = CompressedNm::compress(&w, &mask, s);
            assert_eq!(c.decompress(), mask.apply(&w));
        }
    }

    #[test]
    fn compress_roundtrip_magnitude_mask() {
        let mut rng = Rng::seed_from_u64(4);
        let w = Matrix::randn(16, 32, 1.0, &mut rng);
        let mask = magnitude_row_mask(&w, NmScheme::TWO_FOUR);
        let c = CompressedNm::compress(&w, &mask, NmScheme::TWO_FOUR);
        assert_eq!(c.decompress(), mask.apply(&w));
    }

    #[test]
    fn update_in_place_keeps_pattern() {
        let mut rng = Rng::seed_from_u64(5);
        let w = Matrix::randn(8, 16, 1.0, &mut rng);
        let mask = random_row_mask(8, 16, NmScheme::TWO_FOUR, &mut rng);
        let mut c = CompressedNm::compress(&w, &mask, NmScheme::TWO_FOUR);
        let w2 = Matrix::randn(8, 16, 1.0, &mut rng);
        c.update_from_dense(&w2);
        assert_eq!(c.decompress(), mask.apply(&w2));
    }

    #[test]
    fn sparse_add_linear_combination() {
        let mut rng = Rng::seed_from_u64(6);
        let w = Matrix::randn(4, 8, 1.0, &mut rng);
        let mask = random_row_mask(4, 8, NmScheme::TWO_FOUR, &mut rng);
        let a = CompressedNm::compress(&w, &mask, NmScheme::TWO_FOUR);
        let out = a.sparse_add(&a, 0.5, 2.0);
        for (o, v) in out.values.iter().zip(&a.values) {
            assert!((o - 2.5 * v).abs() < 1e-6);
        }
    }

    #[test]
    fn storage_bits_2to4_example() {
        // 2:4 over fp16: per 4-elem group, 2×16-bit values + 3 index bits.
        let w = Matrix::zeros(1, 4);
        let mask = Mask { rows: 1, cols: 4, keep: vec![true, true, false, false] };
        let c = CompressedNm::compress(&w, &mask, NmScheme::TWO_FOUR);
        assert_eq!(c.storage_bits(16), 2 * 16 + 3);
    }

    #[test]
    fn indices_monotone_with_padding() {
        // A mask with an under-full group (only 1 kept in a 2:4 group).
        let mask = Mask { rows: 1, cols: 8,
                          keep: vec![false, true, false, false, true, true, false, false] };
        let w = Matrix::from_vec(1, 8, (1..=8).map(|v| v as f32).collect());
        let c = CompressedNm::compress(&w, &mask, NmScheme::TWO_FOUR);
        let kc = c.kcols();
        for g in 0..2 {
            assert!(c.indices[g * 2] < c.indices[g * 2 + 1], "{:?}", c.indices);
        }
        assert_eq!(c.decompress(), mask.apply(&w));
        let _ = kc;
    }
}
