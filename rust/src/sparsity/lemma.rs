//! Lemma 2.1 (Eq. 8): the expected extra sparsity imposed by double
//! pruning a randomly-initialized row-pruned matrix, in closed form and by
//! Monte Carlo.  Regenerates Figure 8 (`slope exp fig8`).

use super::{binom, random_row_mask, NmScheme};
use crate::util::Rng;

/// Closed-form Eq. 8: `D(A^R) − D(A^{R,C}) = Σ_{j=N+1}^{M} C(M,j) s^j
/// (1−s)^{M−j} (j−N)/M` with `s = N/M`.
///
/// Values: 1:2 → 12.5%, 2:4 → 9.375%, 2:8 → 5.84% (the paper's prose says
/// 3.39% for 2:8, but its own Eq. 8 — and our Monte Carlo — give 5.84%; we
/// follow the equation and flag the discrepancy in EXPERIMENTS.md).
pub fn imposed_sparsity(scheme: NmScheme) -> f64 {
    let (n, m) = (scheme.n as u64, scheme.m as u64);
    let s = n as f64 / m as f64;
    let mut total = 0.0;
    for j in (n + 1)..=m {
        total += binom(m, j) as f64
            * s.powi(j as i32)
            * (1.0 - s).powi((m - j) as i32)
            * (j - n) as f64
            / m as f64;
    }
    total
}

/// Monte Carlo estimate: draw a random N:M row mask on a `dim × dim`
/// matrix, then prune columns with a *random* N:M pass restricted to
/// surviving elements (the lemma's uniform-position setting), and measure
/// the density drop.
pub fn monte_carlo_imposed_sparsity(
    scheme: NmScheme,
    dim: usize,
    trials: usize,
    rng: &mut Rng,
) -> f64 {
    assert_eq!(dim % scheme.m, 0);
    let mut acc = 0.0;
    for _ in 0..trials {
        let row = random_row_mask(dim, dim, scheme, rng);
        // Column pass: per column group of M, keep min(n, survivors),
        // chosen uniformly among survivors.
        let mut extra_zeros = 0usize;
        for c in 0..dim {
            for g in 0..dim / scheme.m {
                let live: Vec<usize> = (0..scheme.m)
                    .map(|i| g * scheme.m + i)
                    .filter(|&r| row.at(r, c))
                    .collect();
                if live.len() > scheme.n {
                    extra_zeros += live.len() - scheme.n;
                }
            }
        }
        acc += extra_zeros as f64 / (dim * dim) as f64;
    }
    acc / trials as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn closed_form_paper_values() {
        assert!((imposed_sparsity(NmScheme::new(1, 2)) - 0.125).abs() < 1e-12);
        assert!((imposed_sparsity(NmScheme::new(2, 4)) - 0.09375).abs() < 1e-12);
        assert!((imposed_sparsity(NmScheme::new(2, 8)) - 0.05843).abs() < 1e-4);
    }

    #[test]
    fn monte_carlo_matches_closed_form() {
        let mut rng = Rng::seed_from_u64(11);
        for (n, m) in [(1usize, 2usize), (2, 4), (2, 8)] {
            let s = NmScheme::new(n, m);
            let mc = monte_carlo_imposed_sparsity(s, 8 * m, 4, &mut rng);
            let cf = imposed_sparsity(s);
            assert!((mc - cf).abs() < 0.01, "{s}: mc={mc} cf={cf}");
        }
    }

    #[test]
    fn larger_m_imposes_less_extra_sparsity_at_same_ratio() {
        // §2.1: as M grows at fixed N/M, the surplus zeros diminish.
        let a = imposed_sparsity(NmScheme::new(1, 2));
        let b = imposed_sparsity(NmScheme::new(2, 4));
        let c = imposed_sparsity(NmScheme::new(4, 8));
        assert!(a > b && b > c);
    }
}
