//! Zipf–Markov synthetic corpus generator, splits and batcher.

use crate::util::Rng;

/// Corpus hyperparameters.
#[derive(Clone, Copy, Debug)]
pub struct CorpusSpec {
    pub vocab: usize,
    /// Total training tokens.
    pub train_tokens: usize,
    /// Validation tokens.
    pub val_tokens: usize,
    /// Zipf exponent for the unigram marginal.
    pub zipf_s: f64,
    /// Probability the bigram grammar fires (next = σ(prev)).
    pub link_p: f64,
    /// Probability of switching topic state per token.
    pub topic_switch_p: f64,
    pub seed: u64,
}

impl CorpusSpec {
    pub fn for_vocab(vocab: usize, seed: u64) -> Self {
        Self {
            vocab,
            train_tokens: 1 << 18, // 262k tokens — plenty for 10⁵ step·token budgets
            val_tokens: 1 << 14,
            zipf_s: 1.1,
            link_p: 0.55,
            topic_switch_p: 0.01,
            seed,
        }
    }
}

/// A (B, S+1) int32 token batch (inputs + shifted targets share storage).
#[derive(Clone, Debug)]
pub struct Batch {
    pub batch: usize,
    pub seq_plus_1: usize,
    pub tokens: Vec<i32>,
}

/// Generated corpus with train/val splits and the grammar tables needed by
/// the cloze probe.
pub struct Corpus {
    pub spec: CorpusSpec,
    pub train: Vec<u32>,
    pub val: Vec<u32>,
    /// Topic permutations σ₀, σ₁ (the "grammar" the model must learn).
    pub sigma: [Vec<u32>; 2],
    zipf_cdf: Vec<f64>,
    rng: Rng,
}

impl Corpus {
    pub fn generate(spec: CorpusSpec) -> Self {
        let mut rng = Rng::seed_from_u64(spec.seed);
        // Zipf CDF over the vocab.
        let mut w: Vec<f64> = (1..=spec.vocab).map(|r| 1.0 / (r as f64).powf(spec.zipf_s)).collect();
        let total: f64 = w.iter().sum();
        let mut acc = 0.0;
        for v in w.iter_mut() {
            acc += *v / total;
            *v = acc;
        }
        let zipf_cdf = w;
        // Two topic permutations.
        let sigma = [random_perm(spec.vocab, &mut rng), random_perm(spec.vocab, &mut rng)];
        let mut c = Corpus { spec, train: vec![], val: vec![], sigma, zipf_cdf, rng };
        c.train = c.sample_stream(spec.train_tokens);
        c.val = c.sample_stream(spec.val_tokens);
        c
    }

    fn zipf_sample(&mut self) -> u32 {
        let u: f64 = self.rng.f64();
        match self.zipf_cdf.binary_search_by(|p| p.partial_cmp(&u).unwrap()) {
            Ok(i) | Err(i) => i.min(self.spec.vocab - 1) as u32,
        }
    }

    fn sample_stream(&mut self, len: usize) -> Vec<u32> {
        let mut out = Vec::with_capacity(len);
        let mut topic = 0usize;
        let mut prev = self.zipf_sample();
        out.push(prev);
        while out.len() < len {
            if self.rng.chance(self.spec.topic_switch_p) {
                topic = 1 - topic;
            }
            let next = if self.rng.chance(self.spec.link_p) {
                self.sigma[topic][prev as usize]
            } else {
                self.zipf_sample()
            };
            out.push(next);
            prev = next;
        }
        out
    }

    /// Entropy floor estimate (nats/token) of the generating process —
    /// perplexities below exp(H) are impossible, giving the loss curves an
    /// interpretable asymptote.
    pub fn entropy_floor(&self) -> f64 {
        // H = link_p·H(topic mix) + (1−link_p)·H(zipf); approximate the
        // mixture as: -p·ln(p·…) — use a simple plug-in over the val split.
        let mut counts = vec![0f64; self.spec.vocab];
        for &t in &self.val {
            counts[t as usize] += 1.0;
        }
        let n: f64 = self.val.len() as f64;
        let h_uni: f64 = counts
            .iter()
            .filter(|c| **c > 0.0)
            .map(|c| {
                let p = c / n;
                -p * p.ln()
            })
            .sum();
        let p = self.spec.link_p;
        // Conditional entropy given prev ≈ mix of the deterministic link
        // (plus topic uncertainty ≈ 1 bit worst case) and the Zipf draw.
        -(p * (p.ln())) - ((1.0 - p) * (1.0 - p).ln()) + (1.0 - p) * h_uni
    }

    /// Random training batch of shape (B, S+1).
    pub fn train_batch(&self, b: usize, s: usize, rng: &mut Rng) -> Batch {
        self.batch_from(&self.train, b, s, rng)
    }

    /// Random training batch written into a reusable buffer — the
    /// allocation-free form the step loop uses (`tokens` is cleared and
    /// refilled with the same draws `train_batch` would make).
    pub fn train_batch_into(&self, b: usize, s: usize, rng: &mut Rng, tokens: &mut Vec<i32>) {
        let need = s + 1;
        tokens.clear();
        tokens.reserve(b * need);
        for _ in 0..b {
            let start = rng.below(self.train.len() - need);
            tokens.extend(self.train[start..start + need].iter().map(|t| *t as i32));
        }
    }

    /// Deterministic validation batches covering the val split.
    pub fn val_batch(&self, b: usize, s: usize, index: usize) -> Batch {
        let need = s + 1;
        let mut tokens = Vec::with_capacity(b * need);
        let stride = (self.val.len() - need) / b.max(1);
        for row in 0..b {
            let start = (row * stride + index * need) % (self.val.len() - need);
            tokens.extend(self.val[start..start + need].iter().map(|t| *t as i32));
        }
        Batch { batch: b, seq_plus_1: need, tokens }
    }

    fn batch_from(&self, data: &[u32], b: usize, s: usize, rng: &mut Rng) -> Batch {
        let need = s + 1;
        let mut tokens = Vec::with_capacity(b * need);
        for _ in 0..b {
            let start = rng.below(data.len() - need);
            tokens.extend(data[start..start + need].iter().map(|t| *t as i32));
        }
        Batch { batch: b, seq_plus_1: need, tokens }
    }

    /// Cloze probe batch: (B, S) contexts drawn from val such that the
    /// *last* transition observed many σ-link firings for the final token;
    /// `answers[i]` = σ_topic(last token).  A model that learned the
    /// grammar predicts answers at the final position.
    pub fn cloze_batch(&self, b: usize, s: usize, index: usize) -> (Batch, Vec<i32>) {
        let mut tokens = Vec::with_capacity(b * s);
        let mut answers = Vec::with_capacity(b);
        let mut pos = (index * 7919) % (self.val.len() - s - 2);
        let mut rows = 0;
        while rows < b {
            // Find a window whose final transition fired the grammar.
            let end = pos + s;
            if end + 1 >= self.val.len() {
                pos = 0;
                continue;
            }
            let last = self.val[end - 1] as usize;
            let next = self.val[end];
            if self.sigma[0][last] == next || self.sigma[1][last] == next {
                tokens.extend(self.val[pos..end].iter().map(|t| *t as i32));
                answers.push(next as i32);
                rows += 1;
            }
            pos = (pos + s / 2 + 1) % (self.val.len() - s - 2);
        }
        (Batch { batch: b, seq_plus_1: s, tokens }, answers)
    }
}

fn random_perm(n: usize, rng: &mut Rng) -> Vec<u32> {
    let mut v: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut v);
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> Corpus {
        Corpus::generate(CorpusSpec {
            train_tokens: 20_000,
            val_tokens: 4_000,
            ..CorpusSpec::for_vocab(128, 7)
        })
    }

    #[test]
    fn tokens_in_range_and_lengths() {
        let c = small();
        assert_eq!(c.train.len(), 20_000);
        assert_eq!(c.val.len(), 4_000);
        assert!(c.train.iter().all(|t| (*t as usize) < 128));
    }

    #[test]
    fn zipf_marginal_is_skewed() {
        let c = small();
        let mut counts = vec![0usize; 128];
        for &t in &c.train {
            counts[t as usize] += 1;
        }
        let mut sorted = counts.clone();
        sorted.sort_unstable_by(|a, b| b.cmp(a));
        let top10: usize = sorted[..10].iter().sum();
        assert!(top10 as f64 / c.train.len() as f64 > 0.25, "head mass too small");
    }

    #[test]
    fn bigram_structure_present() {
        let c = small();
        let mut fired = 0usize;
        for w in c.train.windows(2) {
            if c.sigma[0][w[0] as usize] == w[1] || c.sigma[1][w[0] as usize] == w[1] {
                fired += 1;
            }
        }
        let rate = fired as f64 / (c.train.len() - 1) as f64;
        assert!(rate > 0.45 && rate < 0.75, "link rate {rate}");
    }

    #[test]
    fn batches_shape_and_determinism() {
        let c = small();
        let mut rng = Rng::seed_from_u64(0);
        let b = c.train_batch(4, 32, &mut rng);
        assert_eq!(b.tokens.len(), 4 * 33);
        let v1 = c.val_batch(2, 16, 3);
        let v2 = c.val_batch(2, 16, 3);
        assert_eq!(v1.tokens, v2.tokens, "val batches must be deterministic");
    }

    #[test]
    fn cloze_answers_follow_grammar() {
        let c = small();
        let (batch, answers) = c.cloze_batch(8, 24, 0);
        assert_eq!(answers.len(), 8);
        for row in 0..8 {
            let last = batch.tokens[row * 24 + 23] as usize;
            let a = answers[row] as u32;
            assert!(c.sigma[0][last] == a || c.sigma[1][last] == a);
        }
    }

    #[test]
    fn entropy_floor_reasonable() {
        let c = small();
        let h = c.entropy_floor();
        assert!(h > 0.5 && h < (128f64).ln() + 1.0, "H = {h}");
    }
}
