//! Synthetic pretraining corpus + batcher (DESIGN.md substitution: no
//! OpenWebText on this testbed).
//!
//! The generator produces a *learnable* language with controlled structure,
//! so perplexity and probe accuracy measure genuine model capacity:
//!
//! * **Zipfian unigram marginal** — like natural text, a few tokens carry
//!   most of the mass (this is what makes dense > sparse gaps visible at
//!   tiny scale: the tail needs capacity).
//! * **Bigram grammar** — with probability `link_p`, token `t` is followed
//!   by its partner `σ(t)` under a fixed random permutation σ.  A model
//!   must learn the full V-entry table to reach the entropy floor.
//! * **Topic states** — a slow 2-state Markov switch between two different
//!   permutations, adding longer-range structure.
//!
//! The **cloze probe** (our zero-shot stand-in, §DESIGN 2) asks the model
//! for `σ(t)` at positions where the grammar fired — a downstream-style
//! accuracy signal distinct from raw perplexity.

pub mod corpus;

pub use corpus::{Batch, Corpus, CorpusSpec};
