//! The Algorithm-1 sparse kernel backend — the role cuSPARSELt + the
//! paper's custom CUDA kernels play, implemented for CPU as a parallel
//! kernel engine.
//!
//! API mirrors Algorithm 1 of the paper:
//! * [`SparseBackend::setup`]            — compress a pruned weight (line 3–4)
//! * [`SparseBackend::spmm`]             — `X · Wᵀ` with compressed W (line 8/11)
//! * [`gemm`] / [`gemm_nt`]              — dense GEMMs (line 12)
//! * [`prune_and_compress`]              — mask + pack gradients (line 13)
//! * [`CompressedNm::sparse_add`]        — weight-decay combine (line 15)
//! * [`CompressedNm::update_from_dense`] — write back updates (line 17–18)
//!
//! # Threading model
//!
//! All six kernels (`gemm`, `gemm_nt`, `gemm_nt_acc`, `gemm_tn`,
//! `spmm_rowmajor`, `spmm_tiled`) run on the [`pool`] engine — a
//! **persistent park/unpark worker set** spawned once per process and
//! reused by every parallel region thereafter (the `serve_and_pool`
//! suite pins ≥ 1000 regions with zero new thread spawns).  A region is
//! a fixed set of index-addressed tasks whose partition is a pure
//! function of (shape, policy); workers claim indices dynamically, but
//! since each task computes exactly what it would compute serially,
//! parallel results are **bit-identical** to serial at any thread count —
//! the property `tests/parallel_and_packed.rs` pins across {1, 2, 4, 7}
//! threads and ragged shapes.
//!
//! Two partition strategies exist, selected per call by
//! [`ParallelPolicy::resolve`] under the policy's [`PartitionStrategy`]
//! knob:
//! * **Row ranges** — contiguous output rows per task (GEMM weight/output
//!   rows, SpMM batch rows); the right split whenever the output has
//!   enough rows to occupy every worker.
//! * **Column stripes** — contiguous output *columns* (weight rows) per
//!   task, every task touching every row: the `batch = 1` serving split,
//!   which lets a single-request forward through `spmm_rowmajor`,
//!   `spmm_tiled` and `gemm_nt` saturate the pool.  `Auto` (the default)
//!   picks rows when the row split saturates, else columns.
//!
//! [`ParallelPolicy`] (worker count, fork-granularity floor, partition
//! strategy) persists on [`SparseBackend`] and
//! [`crate::config::RunConfig`] and flows through every entry point;
//! `*_with` variants parallelize, the bare seed names stay serial.  The
//! [`crate::serve`] subsystem drives these kernels through warm
//! [`SparseBackend`]s for the deployment path.
//!
//! # SIMD dispatch ([`simd`])
//!
//! Every kernel additionally routes through a process-wide
//! [`SimdLevel`], detected once (`is_x86_feature_detected!` on x86_64;
//! scalar elsewhere) and overridable via `SLOPE_SIMD=auto|avx2|scalar`.
//! At `Avx2` the 2:4 gather-dot, the dense inner product, and the rank-1
//! row update run `#[target_feature(enable = "avx2,fma")]` microkernels;
//! at `Scalar` the original safe-Rust loops run byte-for-byte unchanged.
//! The contract, pinned by `tests/simd_parity.rs`:
//!
//! * **bitwise within a level, across thread counts and traversals** —
//!   at a fixed level every output element is computed by one
//!   per-element function in one reduction order, whatever the
//!   partition, tile, or entry point, so all determinism pins
//!   (parallel-vs-serial, tiled-vs-rowmajor, decode-vs-recompute,
//!   crash-recovery byte compares) hold unchanged at either level;
//! * **tolerance across levels** — FMA contraction reassociates the
//!   float reduction, so `Avx2` vs `Scalar` is pinned to tight relative
//!   tolerance, and **bitwise** on small-integer inputs where no
//!   rounding occurs (an end-to-end check of the gather indexing).
//!
//! `*_at(level, ...)` variants pin a level explicitly (parity tests,
//! level-split benches); levels are clamped to hardware capability, so
//! requesting `Avx2` without AVX2 runs scalar rather than UB.
//!
//! ## Prepacked operand layout ([`crate::sparsity::prepacked`])
//!
//! [`CompressedNm`] keeps values and metadata in two planes, and the
//! AVX2 gather-dot consults a 256-entry permute LUT per metadata byte.
//! [`PrepackedNm`] fuses all three streams at **prepack time** — once
//! per plane, not per SpMM: each 2:4 metadata byte is decoded into the
//! `vpermps` lane indices the kernel needs and interleaved with the
//! eight values it gathers for.  The 2:4 fused row, in `u32` slots
//! (little-endian bytes):
//!
//! ```text
//! per metadata-byte PAIR (16 dense cols):   10 slots
//!   [ v0 v1 v2 v3 v4 v5 v6 v7 | i0₀ i0₁ i0₂ i0₃ | i1₀ i1₁ i1₂ i1₃ ]
//!     8 × f32 (as bits)         slot 8: 4 lane    slot 9: 4 lane
//!                               bytes for byte 0  bytes for byte 1
//! [+ 5-slot unit (4 values + 1 lane slot) when the byte count is odd]
//! [+ 3-slot unit (2 values + 1 offset slot) for a half-byte tail    ]
//! ```
//!
//! Slots 8–9 are eight consecutive bytes: one `vpmovzxbd` widens them
//! into the full permute index — no LUT in the loop.  Generic schemes
//! (1:2, 2:8) append the raw packed metadata bytes after the row's
//! values.  [`spmm_prepacked`] consumes the fused plane with
//! register-blocked micro-tiles (four weight rows share each `x` window
//! load; `gemm_nt` pairs output columns the same way via `x86::dot2`)
//! whose per-element reduction order is **identical** to the
//! compressed-plane kernels at the same level — so prepacked output is
//! bit-identical to `spmm_rowmajor*`, across threads, partitions, and
//! traversals, and all cross-level tolerance/small-integer pins carry
//! over verbatim (`tests/simd_parity.rs`).
//!
//! Prepacking happens **once per plane version**: `HostModel` prepacks
//! each pruned linear at `AotModel::open`/store ingest, `HostTrainModel`
//! at build and on `HostExec`'s tracked-version rebuild, and the
//! training loop refreshes only the value slots after in-place optimizer
//! steps ([`PrepackedNm::refresh_values`] — the pattern is static, so
//! index slots never change).  `SLOPE_PREPACK=off` disables the fused
//! path process-wide (the compressed plane stays resident and remains
//! the pinned ground truth; CI runs the parity suites both ways);
//! `memmodel::prepacked_plane_bytes` charges the extra resident stream.
//!
//! # Packed metadata (Eq. 7 accounting)
//!
//! [`CompressedNm`] stores its index plane bit-packed: one intra-group
//! offset of `ceil(log2 M)` bits per kept value, decoded inline in the
//! SpMM gather loop as `group·M + offset`.  For 2:4 that is 2 bits per
//! kept value = 4 bits per group, vs. 32 bits per group for the old
//! `u16` absolute indices (8× less metadata traffic) and vs. the
//! `⌈log₂ C(4,2)⌉ = 3` bits per group of the paper's Eq.-7 entropy bound
//! — the packed layout is the hardware rounding of that bound, exactly
//! what sparse tensor cores store.  `CompressedNm::storage_bits` charges
//! the Eq.-7 rate (§3.1 memory model); `packed_storage_bits` charges the
//! real plane; `memmodel` exposes both rates.
//!
//! # Allocation-free hot paths
//!
//! Every kernel has an `*_into` out-param form, and [`SparseBackend`]
//! carries a reusable [`Workspace`] (`forward_ws`, `grad_input_ws`,
//! `grad_weight_ws`, `lora_fused_ws`) so steady-state training steps and
//! the serving batcher perform zero heap allocations per call.
//!
//! Two SpMM execution strategies are provided because the §2.4 tiling
//! ablation (Table 8) needs both: [`spmm_rowmajor`] (straight traversal)
//! and [`spmm_tiled`] (square output tiles — the paper's upsample-tensor
//! tiling, which on CPU buys L1/L2 locality instead of cuSPARSELt shape
//! sweet-spots).

pub mod gemm;
pub mod pool;
pub mod simd;
pub mod spmm;

pub use gemm::{dot, dot_at, dot_scalar, gemm, gemm_into, gemm_into_at, gemm_nt, gemm_nt_acc,
               gemm_nt_acc_into, gemm_nt_acc_into_at, gemm_nt_into, gemm_nt_into_at,
               gemm_nt_with, gemm_tn, gemm_tn_into, gemm_tn_into_at, gemm_tn_with, gemm_with};
pub use pool::{parallel_over_col_stripes, parallel_over_rows, spawned_thread_count,
               ParallelPolicy, Partition, PartitionStrategy, WorkerPool};
pub use simd::{avx2_available, simd_level, SimdLevel};
pub use spmm::{sparse_dot, sparse_dot_at, sparse_dot_scalar, spmm_prepacked,
               spmm_prepacked_into, spmm_prepacked_into_at, spmm_prepacked_with,
               spmm_prepacked_with_at, spmm_rowmajor, spmm_rowmajor_into, spmm_rowmajor_into_at,
               spmm_rowmajor_with, spmm_rowmajor_with_at, spmm_tiled, spmm_tiled_into,
               spmm_tiled_into_at, spmm_tiled_with, spmm_tiled_with_at, SpmmAlgo};

use crate::sparsity::{CompressedNm, Mask, NmScheme, PrepackedNm};
use crate::tensor::Matrix;

/// Grow-once output buffer helper: (re)shape `buf` only when the target
/// shape changes; the `*_into` kernels overwrite every element.
#[inline]
pub(crate) fn ensure_out(buf: &mut Matrix, rows: usize, cols: usize) {
    if buf.rows != rows || buf.cols != cols {
        *buf = Matrix::zeros(rows, cols);
    }
}

/// Dispatch an SpMM by algorithm into a caller-owned output.
fn spmm_into_algo(algo: SpmmAlgo, policy: &ParallelPolicy, x: &Matrix, w: &CompressedNm,
                  y: &mut Matrix) {
    match algo {
        SpmmAlgo::RowMajor => spmm_rowmajor_into(x, w, y, policy),
        SpmmAlgo::Tiled { tile } => spmm_tiled_into(x, w, tile, y, policy),
    }
}

/// Reusable kernel outputs for the Algorithm-1 step loop and the serving
/// path — all buffers are grown once and overwritten thereafter.
#[derive(Debug, Default)]
pub struct Workspace {
    fwd: Matrix,
    gin: Matrix,
    gw_stage: Matrix,
    gw: Option<CompressedNm>,
    lora_t: Matrix,
    lora_y: Matrix,
}

/// Stateful backend handle mirroring Algorithm 1's `backend.*` object.
///
/// Holds the compressed weight and its compressed transpose — SLoPe stores
/// both (forward uses `Wᵀ`-as-stored = row-compressed `W`; BWD-2 uses the
/// double-pruned transpose), which is exactly the 2× weight term in the
/// Table-3 memory model — plus the parallel policy and the reusable
/// workspace for allocation-free stepping.
pub struct SparseBackend {
    pub scheme: NmScheme,
    /// Row-compressed `W` (drives FWD, Eq. 4).
    pub w: CompressedNm,
    /// Row-compressed `Wᵀ` under the double-pruned mask (drives BWD-2, Eq. 6).
    pub w_t: CompressedNm,
    /// The static row mask (Algorithm 1 line 5).
    pub mask_r: Mask,
    /// The double-pruned mask in `W` layout.
    pub mask_rc: Mask,
    pub algo: SpmmAlgo,
    /// Kernel-engine configuration; flows into every kernel call.
    pub policy: ParallelPolicy,
    ws: Workspace,
}

impl SparseBackend {
    /// `backend.setup(...)` for both W and its double-pruned transpose.
    pub fn setup(w: &Matrix, mask_r: Mask, scheme: NmScheme, algo: SpmmAlgo,
                 policy: ParallelPolicy) -> Self {
        let mask_rc = crate::sparsity::double_prune_mask(w, &mask_r, scheme);
        let w_c = CompressedNm::compress(w, &mask_r, scheme);
        // Transpose view for BWD-2: rows of Wᵀ are columns of W; the
        // double-pruned mask guarantees N:M along that dimension.
        let w_rc = mask_rc.apply(w).transpose();
        let mask_rc_t = Mask {
            rows: mask_rc.cols,
            cols: mask_rc.rows,
            keep: {
                let mt = mask_rc.to_matrix().transpose();
                mt.data.iter().map(|v| *v != 0.0).collect()
            },
        };
        let w_t = CompressedNm::compress(&w_rc, &mask_rc_t, scheme);
        Self { scheme, w: w_c, w_t, mask_r, mask_rc, algo, policy,
               ws: Workspace::default() }
    }

    /// FWD (Eq. 4): `Y = X · (W^R)ᵀ` — `x: (b, d_in)` → `(b, d_out)`.
    pub fn forward(&self, x: &Matrix) -> Matrix {
        self.spmm(x, &self.w)
    }

    /// FWD into a caller-owned output.
    pub fn forward_into(&self, x: &Matrix, y: &mut Matrix) {
        ensure_out(y, x.rows, self.w.rows);
        spmm_into_algo(self.algo, &self.policy, x, &self.w, y);
    }

    /// Allocation-free FWD: reuses the backend workspace buffer.
    pub fn forward_ws(&mut self, x: &Matrix) -> &Matrix {
        ensure_out(&mut self.ws.fwd, x.rows, self.w.rows);
        spmm_into_algo(self.algo, &self.policy, x, &self.w, &mut self.ws.fwd);
        &self.ws.fwd
    }

    /// BWD-2 (Eq. 6): `∇X = ∇Y · W^{R,C}` — `gy: (b, d_out)` → `(b, d_in)`.
    pub fn grad_input(&self, gy: &Matrix) -> Matrix {
        self.spmm(gy, &self.w_t)
    }

    /// BWD-2 into a caller-owned output.
    pub fn grad_input_into(&self, gy: &Matrix, gx: &mut Matrix) {
        ensure_out(gx, gy.rows, self.w_t.rows);
        spmm_into_algo(self.algo, &self.policy, gy, &self.w_t, gx);
    }

    /// Allocation-free BWD-2: reuses the backend workspace buffer.
    pub fn grad_input_ws(&mut self, gy: &Matrix) -> &Matrix {
        ensure_out(&mut self.ws.gin, gy.rows, self.w_t.rows);
        spmm_into_algo(self.algo, &self.policy, gy, &self.w_t, &mut self.ws.gin);
        &self.ws.gin
    }

    /// BWD-1 (Eq. 5) + line 13: dense `∇Yᵀ·X`, masked and packed.
    pub fn grad_weight(&self, gy: &Matrix, x: &Matrix) -> CompressedNm {
        let gw = gemm_tn_with(gy, x, &self.policy); // (d_out, d_in)
        prune_and_compress(&gw, &self.w)
    }

    /// Allocation-free BWD-1: dense staging and packed output both live in
    /// the workspace (the packed pattern is cloned from `w` once).
    pub fn grad_weight_ws(&mut self, gy: &Matrix, x: &Matrix) -> &CompressedNm {
        ensure_out(&mut self.ws.gw_stage, gy.cols, x.cols);
        gemm_tn_into(gy, x, &mut self.ws.gw_stage, &self.policy);
        if self.ws.gw.is_none() {
            self.ws.gw = Some(self.w.clone());
        }
        let out = self.ws.gw.as_mut().unwrap();
        prune_and_compress_into(&self.ws.gw_stage, &self.w, out);
        out
    }

    /// `backend.spmm` with the configured algorithm and policy.
    pub fn spmm(&self, x: &Matrix, w: &CompressedNm) -> Matrix {
        match self.algo {
            SpmmAlgo::RowMajor => spmm_rowmajor_with(x, w, &self.policy),
            SpmmAlgo::Tiled { tile } => spmm_tiled_with(x, w, tile, &self.policy),
        }
    }

    /// Fused LoRA serving call (Eq. 11) through the workspace: zero
    /// allocations per call once shapes are warm.
    pub fn lora_fused_ws(&mut self, x: &Matrix, lo_up: &Matrix, lo_down: &Matrix) -> &Matrix {
        let (algo, policy) = (self.algo, self.policy);
        lora_fused_seq(algo, &policy, &self.w, x, lo_up, lo_down,
                       &mut self.ws.lora_t, &mut self.ws.lora_y);
        &self.ws.lora_y
    }

    /// Optimizer epilogue for one step (Algorithm 1 lines 15–18):
    /// `g = (1/γ)·∇W + α·W` in compressed space, then a caller-provided
    /// update rule writes new values back into both stored operands.
    pub fn optimizer_combine(&self, grad: &CompressedNm, inv_gamma: f32, alpha: f32) -> CompressedNm {
        grad.sparse_add(&self.w, inv_gamma, alpha)
    }

    /// Write updated dense weights back into both compressed operands.
    pub fn update(&mut self, w_new: &Matrix) {
        self.w.update_from_dense(w_new);
        self.w_t
            .update_from_dense(&self.mask_rc.apply(w_new).transpose());
    }

    /// Dense-equivalent weight (decompressed forward operand) — test hook.
    pub fn dense_weight(&self) -> Matrix {
        self.w.decompress()
    }
}

/// Algorithm 1 line 13: mask a dense gradient with the weight's static
/// pattern and pack it (the paper's custom prune-and-compress kernel).
pub fn prune_and_compress(g: &Matrix, pattern: &CompressedNm) -> CompressedNm {
    let mut out = pattern.clone();
    prune_and_compress_into(g, pattern, &mut out);
    out
}

/// Out-param form of [`prune_and_compress`]: gathers `g` through the
/// pattern's packed offsets into `out.values`, reusing `out`'s buffers
/// (re-cloned from `pattern` only on shape/scheme change).
pub fn prune_and_compress_into(g: &Matrix, pattern: &CompressedNm, out: &mut CompressedNm) {
    assert_eq!((g.rows, g.cols), (pattern.rows, pattern.cols));
    if (out.rows, out.cols, out.scheme) != (pattern.rows, pattern.cols, pattern.scheme) {
        *out = pattern.clone();
    } else if out.meta != pattern.meta {
        out.meta.clone_from(&pattern.meta);
    }
    let kc = pattern.kcols();
    for r in 0..pattern.rows {
        let grow = g.row(r);
        for (k, c) in pattern.row_indices(r).enumerate() {
            out.values[r * kc + k] = grow[c];
        }
    }
}

/// The Eq.-11 fused serving sequence into caller-owned staging (`t`, the
/// rank intermediate) and output (`y`), both grown once: sparse `X·Wᵀ`,
/// then the LoRA down-projection, then the up-projection fused with the
/// add.  The single definition shared by [`SparseBackend::lora_fused_ws`]
/// and the [`crate::serve`] engine's per-layer forward.
pub fn lora_fused_seq(algo: SpmmAlgo, policy: &ParallelPolicy, w: &CompressedNm, x: &Matrix,
                      lo_up: &Matrix, lo_down: &Matrix, t: &mut Matrix, y: &mut Matrix) {
    ensure_out(y, x.rows, w.rows);
    ensure_out(t, x.rows, lo_down.rows);
    spmm_into_algo(algo, policy, x, w, y);
    gemm_nt_into(x, lo_down, t, policy);
    gemm_nt_acc_into(t, lo_up, y, policy);
}

/// [`lora_fused_seq`] over the fused prepacked plane — the serving
/// executor's Eq.-11 hot path once a linear has been prepacked.  The
/// SpMM streams the interleaved operand through the register-blocked
/// micro-tiles; per element the reduction equals the compressed path's,
/// so swapping planes is invisible to every output bit at a fixed level.
/// (Prepacked SpMM is row-major only; the tiled §2.4 ablation keeps the
/// compressed plane.)
pub fn lora_fused_seq_pre(policy: &ParallelPolicy, w: &PrepackedNm, x: &Matrix, lo_up: &Matrix,
                          lo_down: &Matrix, t: &mut Matrix, y: &mut Matrix) {
    ensure_out(y, x.rows, w.rows);
    ensure_out(t, x.rows, lo_down.rows);
    spmm_prepacked_into(x, w, y, policy);
    gemm_nt_into(x, lo_down, t, policy);
    gemm_nt_acc_into(t, lo_up, y, policy);
}

/// Naive LoRA inference path (4 kernel calls — Appendix D "before").
pub fn lora_naive(x: &Matrix, w: &CompressedNm, lo_up: &Matrix, lo_down: &Matrix,
                  algo: SpmmAlgo, policy: &ParallelPolicy) -> Matrix {
    let y1 = match algo {
        SpmmAlgo::RowMajor => spmm_rowmajor_with(x, w, policy),
        SpmmAlgo::Tiled { tile } => spmm_tiled_with(x, w, tile, policy),
    };
    let t = gemm_nt_with(x, lo_down, policy); // (b, r) = x · Rᵀ
    let y2 = gemm_nt_with(&t, lo_up, policy); // (b, d_out) = t · Lᵀ
    let mut y = y1;
    for (o, v) in y.data.iter_mut().zip(&y2.data) {
        *o += v;
    }
    y
}

/// Fused LoRA inference path (Eq. 11, 2 calls — Appendix D "after"):
/// the downsample factor rides along the SpMM as dense trailing rows, and
/// the upsample multiply is fused with the addition.
pub fn lora_fused(x: &Matrix, w: &CompressedNm, lo_up: &Matrix, lo_down: &Matrix,
                  algo: SpmmAlgo, policy: &ParallelPolicy) -> Matrix {
    // Call 1: [Y1|T] = X · [Wᵀ|Rᵀ]. We emulate the concatenated operand by
    // one pass over X shared by both products (single traversal = the
    // arithmetic-intensity win the paper measures).
    let mut y1 = match algo {
        SpmmAlgo::RowMajor => spmm_rowmajor_with(x, w, policy),
        SpmmAlgo::Tiled { tile } => spmm_tiled_with(x, w, tile, policy),
    };
    let t = gemm_nt_with(x, lo_down, policy);
    // Call 2: fused Y = T·Lᵀ + Y1 (one traversal, accumulate into Y1).
    gemm_nt_acc_into(&t, lo_up, &mut y1, policy);
    y1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparsity::random_row_mask;
    use crate::util::Rng;

    fn setup(b: usize, d_out: usize, d_in: usize, seed: u64) -> (Matrix, Matrix, SparseBackend) {
        let mut rng = Rng::seed_from_u64(seed);
        let x = Matrix::randn(b, d_in, 1.0, &mut rng);
        let w = Matrix::randn(d_out, d_in, 1.0, &mut rng);
        let mask = random_row_mask(d_out, d_in, NmScheme::TWO_FOUR, &mut rng);
        let be = SparseBackend::setup(&w, mask, NmScheme::TWO_FOUR, SpmmAlgo::RowMajor,
                                      ParallelPolicy::serial());
        (x, w, be)
    }

    #[test]
    fn forward_matches_masked_dense() {
        let (x, w, be) = setup(8, 16, 32, 0);
        let want = gemm_nt(&x, &be.mask_r.apply(&w));
        assert!(be.forward(&x).max_abs_diff(&want) < 1e-4);
    }

    #[test]
    fn grad_input_uses_double_pruned_weight() {
        let (_, w, be) = setup(8, 16, 32, 1);
        let mut rng = Rng::seed_from_u64(9);
        let gy = Matrix::randn(8, 16, 1.0, &mut rng);
        // ∇X = ∇Y · W^{R,C}: gemm with the NON-transposed double-pruned W.
        let want = gemm(&gy, &be.mask_rc.apply(&w));
        assert!(be.grad_input(&gy).max_abs_diff(&want) < 1e-4);
    }

    #[test]
    fn grad_weight_masked_and_packed() {
        let (x, _, be) = setup(8, 16, 32, 2);
        let mut rng = Rng::seed_from_u64(10);
        let gy = Matrix::randn(8, 16, 1.0, &mut rng);
        let gw = be.grad_weight(&gy, &x);
        let dense = gemm_tn(&gy, &x);
        assert!(gw.decompress().max_abs_diff(&be.mask_r.apply(&dense)) < 1e-4);
    }

    #[test]
    fn workspace_paths_match_allocating_paths() {
        let (x, _, mut be) = setup(8, 16, 32, 6);
        let mut rng = Rng::seed_from_u64(13);
        let gy = Matrix::randn(8, 16, 1.0, &mut rng);

        let want_y = be.forward(&x);
        let want_gx = be.grad_input(&gy);
        let want_gw = be.grad_weight(&gy, &x);

        assert_eq!(*be.forward_ws(&x), want_y);
        assert_eq!(*be.grad_input_ws(&gy), want_gx);
        assert_eq!(*be.grad_weight_ws(&gy, &x), want_gw);

        // Steady state: repeat calls reuse the same buffers (no realloc).
        let p0 = be.forward_ws(&x).data.as_ptr();
        let p1 = be.forward_ws(&x).data.as_ptr();
        assert_eq!(p0, p1, "workspace must not reallocate at a stable shape");
    }

    #[test]
    fn into_paths_resize_on_shape_change() {
        let (x, _, be) = setup(8, 16, 32, 7);
        let mut y = Matrix::zeros(1, 1); // wrong shape: must be regrown
        be.forward_into(&x, &mut y);
        assert_eq!(y, be.forward(&x));
    }

    #[test]
    fn full_training_iteration_preserves_support() {
        // One Algorithm-1 iteration: fwd, bwd, combine, SGD update, write
        // back — the dense-equivalent weight must stay inside the mask.
        let (x, w, mut be) = setup(4, 8, 16, 3);
        let mut rng = Rng::seed_from_u64(11);
        let gy = Matrix::randn(4, 8, 1.0, &mut rng);
        let _y = be.forward(&x);
        let _gx = be.grad_input(&gy);
        let gw = be.grad_weight(&gy, &x);
        let g = be.optimizer_combine(&gw, 1.0, 0.1);
        // SGD: w_new = w - lr * g (dense staging, as the optimizer does).
        let mut w_new = be.dense_weight();
        let g_dense = g.decompress();
        for (wv, gv) in w_new.data.iter_mut().zip(&g_dense.data) {
            *wv -= 0.01 * gv;
        }
        be.update(&w_new);
        let after = be.dense_weight();
        for (i, k) in be.mask_r.keep.iter().enumerate() {
            if !*k {
                assert_eq!(after.data[i], 0.0);
            }
        }
        // And kept values actually moved.
        assert!(after.max_abs_diff(&be.mask_r.apply(&w)) > 0.0);
    }

    #[test]
    fn lora_fused_equals_naive() {
        let (x, _, mut be) = setup(8, 16, 32, 4);
        let mut rng = Rng::seed_from_u64(12);
        let lo_up = Matrix::randn(16, 4, 0.5, &mut rng); // L: (d_out, r)
        let lo_down = Matrix::randn(4, 32, 0.5, &mut rng); // R: (r, d_in)
        let p = ParallelPolicy::serial();
        let a = lora_naive(&x, &be.w, &lo_up, &lo_down, SpmmAlgo::RowMajor, &p);
        let b = lora_fused(&x, &be.w, &lo_up, &lo_down, SpmmAlgo::RowMajor, &p);
        assert!(a.max_abs_diff(&b) < 1e-4);
        // The workspace serving call matches the fused path exactly.
        assert_eq!(*be.lora_fused_ws(&x, &lo_up, &lo_down), b);
    }

    #[test]
    fn tiled_and_rowmajor_agree() {
        let (x, _, be) = setup(16, 64, 64, 5);
        let a = spmm_rowmajor(&x, &be.w);
        for tile in [8, 16, 32, 64, 128] {
            let b = spmm_tiled(&x, &be.w, tile);
            assert!(a.max_abs_diff(&b) < 1e-4, "tile={tile}");
        }
    }
}
