//! The Algorithm-1 sparse kernel backend — the role cuSPARSELt + the
//! paper's custom CUDA kernels play, implemented for CPU.
//!
//! API mirrors Algorithm 1 of the paper:
//! * [`SparseBackend::setup`]            — compress a pruned weight (line 3–4)
//! * [`SparseBackend::spmm`]             — `X · Wᵀ` with compressed W (line 8/11)
//! * [`gemm`] / [`gemm_nt`]              — dense GEMMs (line 12)
//! * [`prune_and_compress`]              — mask + pack gradients (line 13)
//! * [`CompressedNm::sparse_add`]        — weight-decay combine (line 15)
//! * [`CompressedNm::update_from_dense`] — write back updates (line 17–18)
//!
//! Two SpMM execution strategies are provided because the §2.4 tiling
//! ablation (Table 8) needs both: [`spmm_rowmajor`] (straight traversal)
//! and [`spmm_tiled`] (square output tiles — the paper's upsample-tensor
//! tiling, which on CPU buys L1/L2 locality instead of cuSPARSELt shape
//! sweet-spots).

pub mod gemm;
pub mod spmm;

pub use gemm::{gemm, gemm_nt, gemm_tn};
pub use spmm::{spmm_rowmajor, spmm_tiled, SpmmAlgo};

use crate::sparsity::{CompressedNm, Mask, NmScheme};
use crate::tensor::Matrix;

/// Stateful backend handle mirroring Algorithm 1's `backend.*` object.
///
/// Holds the compressed weight and its compressed transpose — SLoPe stores
/// both (forward uses `Wᵀ`-as-stored = row-compressed `W`; BWD-2 uses the
/// double-pruned transpose), which is exactly the 2× weight term in the
/// Table-3 memory model.
pub struct SparseBackend {
    pub scheme: NmScheme,
    /// Row-compressed `W` (drives FWD, Eq. 4).
    pub w: CompressedNm,
    /// Row-compressed `Wᵀ` under the double-pruned mask (drives BWD-2, Eq. 6).
    pub w_t: CompressedNm,
    /// The static row mask (Algorithm 1 line 5).
    pub mask_r: Mask,
    /// The double-pruned mask in `W` layout.
    pub mask_rc: Mask,
    pub algo: SpmmAlgo,
}

impl SparseBackend {
    /// `backend.setup(...)` for both W and its double-pruned transpose.
    pub fn setup(w: &Matrix, mask_r: Mask, scheme: NmScheme, algo: SpmmAlgo) -> Self {
        let mask_rc = crate::sparsity::double_prune_mask(w, &mask_r, scheme);
        let w_c = CompressedNm::compress(w, &mask_r, scheme);
        // Transpose view for BWD-2: rows of Wᵀ are columns of W; the
        // double-pruned mask guarantees N:M along that dimension.
        let w_rc = mask_rc.apply(w).transpose();
        let mask_rc_t = Mask {
            rows: mask_rc.cols,
            cols: mask_rc.rows,
            keep: {
                let mt = mask_rc.to_matrix().transpose();
                mt.data.iter().map(|v| *v != 0.0).collect()
            },
        };
        let w_t = CompressedNm::compress(&w_rc, &mask_rc_t, scheme);
        Self { scheme, w: w_c, w_t, mask_r, mask_rc, algo }
    }

    /// FWD (Eq. 4): `Y = X · (W^R)ᵀ` — `x: (b, d_in)` → `(b, d_out)`.
    pub fn forward(&self, x: &Matrix) -> Matrix {
        self.spmm(x, &self.w)
    }

    /// BWD-2 (Eq. 6): `∇X = ∇Y · W^{R,C}` — `gy: (b, d_out)` → `(b, d_in)`.
    pub fn grad_input(&self, gy: &Matrix) -> Matrix {
        self.spmm(gy, &self.w_t)
    }

    /// BWD-1 (Eq. 5) + line 13: dense `∇Yᵀ·X`, masked and packed.
    pub fn grad_weight(&self, gy: &Matrix, x: &Matrix) -> CompressedNm {
        let gw = gemm_tn(gy, x); // (d_out, d_in)
        prune_and_compress(&gw, &self.w)
    }

    /// `backend.spmm` with the configured algorithm.
    pub fn spmm(&self, x: &Matrix, w: &CompressedNm) -> Matrix {
        match self.algo {
            SpmmAlgo::RowMajor => spmm_rowmajor(x, w),
            SpmmAlgo::Tiled { tile } => spmm_tiled(x, w, tile),
        }
    }

    /// Optimizer epilogue for one step (Algorithm 1 lines 15–18):
    /// `g = (1/γ)·∇W + α·W` in compressed space, then a caller-provided
    /// update rule writes new values back into both stored operands.
    pub fn optimizer_combine(&self, grad: &CompressedNm, inv_gamma: f32, alpha: f32) -> CompressedNm {
        grad.sparse_add(&self.w, inv_gamma, alpha)
    }

    /// Write updated dense weights back into both compressed operands.
    pub fn update(&mut self, w_new: &Matrix) {
        self.w.update_from_dense(w_new);
        self.w_t
            .update_from_dense(&self.mask_rc.apply(w_new).transpose());
    }

    /// Dense-equivalent weight (decompressed forward operand) — test hook.
    pub fn dense_weight(&self) -> Matrix {
        self.w.decompress()
    }
}

/// Algorithm 1 line 13: mask a dense gradient with the weight's static
/// pattern and pack it (the paper's custom prune-and-compress kernel).
pub fn prune_and_compress(g: &Matrix, pattern: &CompressedNm) -> CompressedNm {
    assert_eq!((g.rows, g.cols), (pattern.rows, pattern.cols));
    let kc = pattern.kcols();
    let mut values = vec![0.0f32; pattern.rows * kc];
    for r in 0..pattern.rows {
        let grow = g.row(r);
        for k in 0..kc {
            values[r * kc + k] = grow[pattern.indices[r * kc + k] as usize];
        }
    }
    CompressedNm { values, ..pattern.clone() }
}

/// Naive LoRA inference path (4 kernel calls — Appendix D "before").
pub fn lora_naive(x: &Matrix, w: &CompressedNm, lo_up: &Matrix, lo_down: &Matrix,
                  algo: SpmmAlgo) -> Matrix {
    let y1 = match algo {
        SpmmAlgo::RowMajor => spmm_rowmajor(x, w),
        SpmmAlgo::Tiled { tile } => spmm_tiled(x, w, tile),
    };
    let t = gemm_nt(x, lo_down); // (b, r) = x · Rᵀ
    let y2 = gemm_nt(&t, lo_up); // (b, d_out) = t · Lᵀ
    let mut y = y1;
    for (o, v) in y.data.iter_mut().zip(&y2.data) {
        *o += v;
    }
    y
}

/// Fused LoRA inference path (Eq. 11, 2 calls — Appendix D "after"):
/// the downsample factor rides along the SpMM as dense trailing rows, and
/// the upsample multiply is fused with the addition.
pub fn lora_fused(x: &Matrix, w: &CompressedNm, lo_up: &Matrix, lo_down: &Matrix,
                  algo: SpmmAlgo) -> Matrix {
    // Call 1: [Y1|T] = X · [Wᵀ|Rᵀ]. We emulate the concatenated operand by
    // one pass over X shared by both products (single traversal = the
    // arithmetic-intensity win the paper measures).
    let y1 = match algo {
        SpmmAlgo::RowMajor => spmm_rowmajor(x, w),
        SpmmAlgo::Tiled { tile } => spmm_tiled(x, w, tile),
    };
    let t = gemm_nt(x, lo_down);
    // Call 2: fused Y = T·Lᵀ + Y1 (one traversal, accumulate into Y1).
    gemm::gemm_nt_acc(&t, lo_up, y1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparsity::random_row_mask;
    use crate::util::Rng;

    fn setup(b: usize, d_out: usize, d_in: usize, seed: u64) -> (Matrix, Matrix, SparseBackend) {
        let mut rng = Rng::seed_from_u64(seed);
        let x = Matrix::randn(b, d_in, 1.0, &mut rng);
        let w = Matrix::randn(d_out, d_in, 1.0, &mut rng);
        let mask = random_row_mask(d_out, d_in, NmScheme::TWO_FOUR, &mut rng);
        let be = SparseBackend::setup(&w, mask, NmScheme::TWO_FOUR, SpmmAlgo::RowMajor);
        (x, w, be)
    }

    #[test]
    fn forward_matches_masked_dense() {
        let (x, w, be) = setup(8, 16, 32, 0);
        let want = gemm_nt(&x, &be.mask_r.apply(&w));
        assert!(be.forward(&x).max_abs_diff(&want) < 1e-4);
    }

    #[test]
    fn grad_input_uses_double_pruned_weight() {
        let (_, w, be) = setup(8, 16, 32, 1);
        let mut rng = Rng::seed_from_u64(9);
        let gy = Matrix::randn(8, 16, 1.0, &mut rng);
        // ∇X = ∇Y · W^{R,C}: gemm with the NON-transposed double-pruned W.
        let want = gemm(&gy, &be.mask_rc.apply(&w));
        assert!(be.grad_input(&gy).max_abs_diff(&want) < 1e-4);
    }

    #[test]
    fn grad_weight_masked_and_packed() {
        let (x, _, be) = setup(8, 16, 32, 2);
        let mut rng = Rng::seed_from_u64(10);
        let gy = Matrix::randn(8, 16, 1.0, &mut rng);
        let gw = be.grad_weight(&gy, &x);
        let dense = gemm_tn(&gy, &x);
        assert!(gw.decompress().max_abs_diff(&be.mask_r.apply(&dense)) < 1e-4);
    }

    #[test]
    fn full_training_iteration_preserves_support() {
        // One Algorithm-1 iteration: fwd, bwd, combine, SGD update, write
        // back — the dense-equivalent weight must stay inside the mask.
        let (x, w, mut be) = setup(4, 8, 16, 3);
        let mut rng = Rng::seed_from_u64(11);
        let gy = Matrix::randn(4, 8, 1.0, &mut rng);
        let _y = be.forward(&x);
        let _gx = be.grad_input(&gy);
        let gw = be.grad_weight(&gy, &x);
        let g = be.optimizer_combine(&gw, 1.0, 0.1);
        // SGD: w_new = w - lr * g (dense staging, as the optimizer does).
        let mut w_new = be.dense_weight();
        let g_dense = g.decompress();
        for (wv, gv) in w_new.data.iter_mut().zip(&g_dense.data) {
            *wv -= 0.01 * gv;
        }
        be.update(&w_new);
        let after = be.dense_weight();
        for (i, k) in be.mask_r.keep.iter().enumerate() {
            if !*k {
                assert_eq!(after.data[i], 0.0);
            }
        }
        // And kept values actually moved.
        assert!(after.max_abs_diff(&be.mask_r.apply(&w)) > 0.0);
    }

    #[test]
    fn lora_fused_equals_naive() {
        let (x, _, be) = setup(8, 16, 32, 4);
        let mut rng = Rng::seed_from_u64(12);
        let lo_up = Matrix::randn(16, 4, 0.5, &mut rng); // L: (d_out, r)
        let lo_down = Matrix::randn(4, 32, 0.5, &mut rng); // R: (r, d_in)
        let a = lora_naive(&x, &be.w, &lo_up, &lo_down, SpmmAlgo::RowMajor);
        let b = lora_fused(&x, &be.w, &lo_up, &lo_down, SpmmAlgo::RowMajor);
        assert!(a.max_abs_diff(&b) < 1e-4);
    }

    #[test]
    fn tiled_and_rowmajor_agree() {
        let (x, _, be) = setup(16, 64, 64, 5);
        let a = spmm_rowmajor(&x, &be.w);
        for tile in [8, 16, 32, 64, 128] {
            let b = spmm_tiled(&x, &be.w, tile);
            assert!(a.max_abs_diff(&b) < 1e-4, "tile={tile}");
        }
    }
}
