//! Dense GEMM kernels (the cuBLAS role on CPU).
//!
//! `gemm_nt` is the hot path (it is the dense half of Algorithm 1 and the
//! baseline every SpMM speedup is measured against), so it is blocked for
//! L1 reuse with an 8-wide unrolled inner loop over the shared reduction
//! dimension.  Everything is safe rust; the optimizer auto-vectorizes the
//! inner loops (checked in the §Perf pass).
//!
//! Every kernel comes in three forms wired to the same per-row core:
//! * `gemm*(a, b)` — allocating, serial (the seed API, kept for tests
//!   and cold paths);
//! * `gemm*_with(a, b, policy)` — allocating, parallel;
//! * `gemm*_into(a, b, &mut c, policy)` — out-param, parallel,
//!   allocation-free once the caller's buffer is warm.
//!
//! `gemm` / `gemm_tn` partition *output rows* (their outputs are
//! weight-sized, so rows always saturate the pool).  `gemm_nt` — the
//! LoRA serving kernel, whose output has only `batch` rows — additionally
//! honors the policy's [`crate::backend::pool::PartitionStrategy`]: under
//! a column split each
//! task computes a disjoint stripe of output columns for every row, so a
//! `batch = 1` call still spreads across the pool.  Per output element
//! the reduction order never depends on the partition, so parallel
//! results are bit-identical to serial at any thread count.
//!
//! All entry points dispatch through a [`SimdLevel`]
//! ([`crate::backend::simd`]): the row-dot inner product ([`dot`]) and
//! the rank-1 row update run AVX2+FMA microkernels on capable hardware
//! and the original safe-Rust loops otherwise (or under
//! `SLOPE_SIMD=scalar`).  The per-element reduction order at a given
//! level never depends on the partition, so the bit-identical-to-serial
//! contract holds at both levels; across levels FMA reassociation is
//! tolerance-pinned in `tests/simd_parity.rs`.

use crate::backend::pool::{parallel_over_col_stripes, parallel_over_rows, ParallelPolicy,
                           Partition, StripedOut};
use crate::backend::simd::{self, SimdLevel};
use crate::tensor::Matrix;
use std::ops::Range;

/// Cache-block edge for the K dimension (f32 lines; 256×4B = 1 KiB rows).
const KB: usize = 256;
/// Output-tile edge.
const JB: usize = 64;

// ---- C = A · B --------------------------------------------------------

/// `C = A · B` — `a: (m, k)`, `b: (k, n)`.  Serial.
pub fn gemm(a: &Matrix, b: &Matrix) -> Matrix {
    gemm_with(a, b, &ParallelPolicy::serial())
}

/// `C = A · B`, parallel over output rows.
pub fn gemm_with(a: &Matrix, b: &Matrix, policy: &ParallelPolicy) -> Matrix {
    let mut c = Matrix::zeros(a.rows, b.cols);
    gemm_into(a, b, &mut c, policy);
    c
}

/// `C = A · B` into a caller-owned output (overwritten).
pub fn gemm_into(a: &Matrix, b: &Matrix, c: &mut Matrix, policy: &ParallelPolicy) {
    gemm_into_at(simd::simd_level(), a, b, c, policy);
}

/// `C = A · B` at an explicit [`SimdLevel`] (clamped to hardware).
pub fn gemm_into_at(level: SimdLevel, a: &Matrix, b: &Matrix, c: &mut Matrix,
                    policy: &ParallelPolicy) {
    let level = simd::effective(level);
    assert_eq!(a.cols, b.rows, "gemm shape mismatch");
    assert_eq!((c.rows, c.cols), (a.rows, b.cols), "gemm output shape");
    c.data.fill(0.0);
    parallel_over_rows(policy, &mut c.data, b.cols, |range, chunk| {
        gemm_rows(level, a, b, range, chunk);
    });
}

/// Per-row-chunk core: rows of `C` in `range`, written into `out`
/// (`range.len() × n`).  The kk block stays OUTER across the chunk's rows
/// so the streamed `b[kk..kend]` slice is reused by every row of the
/// chunk (the seed kernel's L2 blocking, now per worker).  Per output row
/// the update order is still (kk ascending, p ascending) regardless of
/// the partition, so parallel results stay bit-identical to serial.  The
/// inner j-loop is branch-free (a zero-skip here mispredicts on dense
/// operands and starves the vector units).
fn gemm_rows(level: SimdLevel, a: &Matrix, b: &Matrix, range: Range<usize>, out: &mut [f32]) {
    let (k, n) = (a.cols, b.cols);
    for kk in (0..k).step_by(KB) {
        let kend = (kk + KB).min(k);
        for (local, i) in range.clone().enumerate() {
            let arow = a.row(i);
            let crow = &mut out[local * n..(local + 1) * n];
            for p in kk..kend {
                axpy_at(level, arow[p], b.row(p), crow, n);
            }
        }
    }
}

// ---- C = A · Bᵀ -------------------------------------------------------

/// `C = A · Bᵀ` — `a: (m, k)`, `b: (n, k)`.  Row-dot-row form: unit-stride
/// on both operands, the fastest layout for row-major data.  Serial.
pub fn gemm_nt(a: &Matrix, b: &Matrix) -> Matrix {
    gemm_nt_with(a, b, &ParallelPolicy::serial())
}

/// `C = A · Bᵀ`, parallel per the policy's partition strategy.
pub fn gemm_nt_with(a: &Matrix, b: &Matrix, policy: &ParallelPolicy) -> Matrix {
    let mut c = Matrix::zeros(a.rows, b.rows);
    gemm_nt_into(a, b, &mut c, policy);
    c
}

/// `C = A · Bᵀ` into a caller-owned output (overwritten).
pub fn gemm_nt_into(a: &Matrix, b: &Matrix, c: &mut Matrix, policy: &ParallelPolicy) {
    c.data.fill(0.0);
    gemm_nt_acc_into(a, b, c, policy);
}

/// `C = A · Bᵀ` at an explicit [`SimdLevel`] (clamped to hardware).
pub fn gemm_nt_into_at(level: SimdLevel, a: &Matrix, b: &Matrix, c: &mut Matrix,
                       policy: &ParallelPolicy) {
    c.data.fill(0.0);
    gemm_nt_acc_into_at(level, a, b, c, policy);
}

/// `C += A · Bᵀ` accumulating into an existing output — the fused
/// matmul+add of §2.4 (Eq. 11-right): one traversal, no extra pass.
/// By-value form kept for the seed API.
pub fn gemm_nt_acc(a: &Matrix, b: &Matrix, mut c: Matrix) -> Matrix {
    gemm_nt_acc_into(a, b, &mut c, &ParallelPolicy::serial());
    c
}

/// `C += A · Bᵀ` into a caller-owned accumulator, parallel per the
/// policy's partition strategy (row ranges, or column stripes when the
/// batch is too small to occupy the pool).
pub fn gemm_nt_acc_into(a: &Matrix, b: &Matrix, c: &mut Matrix, policy: &ParallelPolicy) {
    gemm_nt_acc_into_at(simd::simd_level(), a, b, c, policy);
}

/// `C += A · Bᵀ` at an explicit [`SimdLevel`] (clamped to hardware).
pub fn gemm_nt_acc_into_at(level: SimdLevel, a: &Matrix, b: &Matrix, c: &mut Matrix,
                           policy: &ParallelPolicy) {
    let level = simd::effective(level);
    assert_eq!(a.cols, b.cols, "gemm_nt shape mismatch");
    assert_eq!((c.rows, c.cols), (a.rows, b.rows));
    match policy.resolve(a.rows, b.rows) {
        Partition::Serial => gemm_nt_rows(level, a, b, 0..a.rows, &mut c.data),
        Partition::Rows(_) => {
            parallel_over_rows(policy, &mut c.data, b.rows, |range, chunk| {
                gemm_nt_rows(level, a, b, range, chunk);
            });
        }
        Partition::Cols(tasks) => {
            let k = a.cols;
            let out = StripedOut::new(&mut c.data, b.rows);
            parallel_over_col_stripes(tasks, b.rows, |stripe| {
                for i in 0..a.rows {
                    let arow = a.row(i);
                    // SAFETY: stripes of distinct tasks are disjoint.
                    let dst = unsafe { out.row_stripe(i, stripe.clone()) };
                    // Paired micro-tile over the stripe; per element the
                    // computation equals the row path's single dot ⇒
                    // bit-identical results regardless of stripe bounds.
                    gemm_nt_cols_pair(level, arow, b, stripe.clone(), dst, k);
                }
            });
        }
    }
}

/// `dst[local] += a·b[j]ᵀ` for `j` in `stripe`, two output columns per
/// pass through the [`dot2_at`] micro-tile (shared `a` loads); `dot2`'s
/// per-output reduction is bitwise [`dot_at`]'s, so pairing — and where
/// the pairing starts — cannot change any element.
fn gemm_nt_cols_pair(level: SimdLevel, arow: &[f32], b: &Matrix, stripe: Range<usize>,
                     dst: &mut [f32], k: usize) {
    let mut j = stripe.start;
    let mut local = 0;
    while j + 2 <= stripe.end {
        let (s0, s1) = dot2_at(level, arow, b.row(j), b.row(j + 1), k);
        dst[local] += s0;
        dst[local + 1] += s1;
        j += 2;
        local += 2;
    }
    if j < stripe.end {
        dst[local] += dot_at(level, arow, b.row(j), k);
    }
}

fn gemm_nt_rows(level: SimdLevel, a: &Matrix, b: &Matrix, range: Range<usize>, out: &mut [f32]) {
    let k = a.cols;
    let n = b.rows;
    for (local, i) in range.enumerate() {
        let arow = a.row(i);
        let crow = &mut out[local * n..(local + 1) * n];
        for jb in (0..n).step_by(JB) {
            let jend = (jb + JB).min(n);
            // Register-blocked pairing inside the JB block: two output
            // columns share each `a` load (x86::dot2); odd remainder
            // falls back to the single dot.  Bitwise identical to the
            // unpaired loop by dot2's contract.
            let mut j = jb;
            while j + 2 <= jend {
                let (s0, s1) = dot2_at(level, arow, b.row(j), b.row(j + 1), k);
                crow[j] += s0;
                crow[j + 1] += s1;
                j += 2;
            }
            if j < jend {
                crow[j] += dot_at(level, arow, b.row(j), k);
            }
        }
    }
}

// ---- C = Aᵀ · B -------------------------------------------------------

/// `C = Aᵀ · B` — `a: (k, m)`, `b: (k, n)` → `(m, n)`.  Used for
/// `∇W = ∇Yᵀ · X` (Algorithm 1 line 12).  Serial.
pub fn gemm_tn(a: &Matrix, b: &Matrix) -> Matrix {
    gemm_tn_with(a, b, &ParallelPolicy::serial())
}

/// `C = Aᵀ · B`, parallel over output rows.
pub fn gemm_tn_with(a: &Matrix, b: &Matrix, policy: &ParallelPolicy) -> Matrix {
    let mut c = Matrix::zeros(a.cols, b.cols);
    gemm_tn_into(a, b, &mut c, policy);
    c
}

/// `C = Aᵀ · B` into a caller-owned output (overwritten).
pub fn gemm_tn_into(a: &Matrix, b: &Matrix, c: &mut Matrix, policy: &ParallelPolicy) {
    gemm_tn_into_at(simd::simd_level(), a, b, c, policy);
}

/// `C = Aᵀ · B` at an explicit [`SimdLevel`] (clamped to hardware).
pub fn gemm_tn_into_at(level: SimdLevel, a: &Matrix, b: &Matrix, c: &mut Matrix,
                       policy: &ParallelPolicy) {
    let level = simd::effective(level);
    assert_eq!(a.rows, b.rows, "gemm_tn shape mismatch");
    assert_eq!((c.rows, c.cols), (a.cols, b.cols), "gemm_tn output shape");
    c.data.fill(0.0);
    parallel_over_rows(policy, &mut c.data, b.cols, |range, chunk| {
        gemm_tn_rows(level, a, b, range, chunk);
    });
}

/// Per-row core for the transposed-A form: output row `i` accumulates
/// `a[p, i] · b[p, :]` for `p` ascending — the same per-row order as the
/// rank-1-update serial loop, so parallel results stay bit-identical.
/// The j-loop is branch-free (no zero-skip; see `gemm_rows`).
fn gemm_tn_rows(level: SimdLevel, a: &Matrix, b: &Matrix, range: Range<usize>, out: &mut [f32]) {
    let (k, n) = (a.rows, b.cols);
    for (local, i) in range.enumerate() {
        let crow = &mut out[local * n..(local + 1) * n];
        for p in 0..k {
            axpy_at(level, a.data[p * a.cols + i], b.row(p), crow, n);
        }
    }
}

/// Inner product at the process-wide [`SimdLevel`]: FMA microkernel on
/// AVX2 hardware, the 8-wide unrolled scalar loop otherwise.
#[inline]
pub fn dot(a: &[f32], b: &[f32], k: usize) -> f32 {
    dot_at(simd::simd_level(), a, b, k)
}

/// [`dot`] at an explicit [`SimdLevel`] (clamped to hardware).
#[inline]
pub fn dot_at(level: SimdLevel, a: &[f32], b: &[f32], k: usize) -> f32 {
    let level = simd::effective(level);
    #[cfg(target_arch = "x86_64")]
    if level == SimdLevel::Avx2 {
        // SAFETY: `effective` verified AVX2+FMA for this level; the
        // callers' slices hold at least `k` elements (asserted scalar-side
        // too via indexing).
        return unsafe { simd::x86::dot(a, b, k) };
    }
    #[cfg(not(target_arch = "x86_64"))]
    let _ = level;
    dot_scalar(a, b, k)
}

/// Two inner products sharing the `a` operand, at a (pre-clamped) level:
/// the AVX2 micro-tile loads each `a` vector once for both outputs; the
/// scalar twin is literally two [`dot_scalar`] calls.  Per output the
/// result is bitwise [`dot_at`]'s — the property that lets `gemm_nt`
/// pair its column loop without touching any determinism pin.
#[inline]
fn dot2_at(level: SimdLevel, a: &[f32], b0: &[f32], b1: &[f32], k: usize) -> (f32, f32) {
    #[cfg(target_arch = "x86_64")]
    if level == SimdLevel::Avx2 {
        // SAFETY: callers only pass Avx2 after `effective` clamping at
        // the kernel entry point.
        return unsafe { simd::x86::dot2(a, b0, b1, k) };
    }
    #[cfg(not(target_arch = "x86_64"))]
    let _ = level;
    (dot_scalar(a, b0, k), dot_scalar(a, b1, k))
}

/// Reference 8-wide unrolled dot product (auto-vectorizes without FMA
/// contraction) — the pinned scalar-level reduction.
#[inline]
pub fn dot_scalar(a: &[f32], b: &[f32], k: usize) -> f32 {
    let mut acc = [0.0f32; 8];
    let chunks = k / 8;
    for c in 0..chunks {
        let o = c * 8;
        for l in 0..8 {
            acc[l] += a[o + l] * b[o + l];
        }
    }
    let mut s: f32 = acc.iter().sum();
    for i in chunks * 8..k {
        s += a[i] * b[i];
    }
    s
}

/// Rank-1 row update `crow[..n] += av · brow[..n]` at a (pre-clamped)
/// level — the inner loop of `gemm` / `gemm_tn`.  The scalar body is the
/// exact branch-free loop those kernels always ran.
#[inline]
fn axpy_at(level: SimdLevel, av: f32, brow: &[f32], crow: &mut [f32], n: usize) {
    #[cfg(target_arch = "x86_64")]
    if level == SimdLevel::Avx2 {
        // SAFETY: callers only pass Avx2 after `effective` clamping at
        // the kernel entry point.
        unsafe { simd::x86::axpy(av, brow, crow, n) };
        return;
    }
    #[cfg(not(target_arch = "x86_64"))]
    let _ = level;
    for (c, &bv) in crow[..n].iter_mut().zip(&brow[..n]) {
        *c += av * bv;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn naive(a: &Matrix, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut s = 0.0;
                for p in 0..a.cols {
                    s += a.at(i, p) * b.at(p, j);
                }
                *c.at_mut(i, j) = s;
            }
        }
        c
    }

    #[test]
    fn gemm_variants_match_naive() {
        let mut rng = Rng::seed_from_u64(0);
        for (m, k, n) in [(3, 5, 7), (8, 16, 8), (17, 33, 9), (64, 128, 64)] {
            let a = Matrix::randn(m, k, 1.0, &mut rng);
            let b = Matrix::randn(k, n, 1.0, &mut rng);
            let want = naive(&a, &b);
            assert!(gemm(&a, &b).max_abs_diff(&want) < 1e-3, "gemm {m}x{k}x{n}");
            assert!(gemm_nt(&a, &b.transpose()).max_abs_diff(&want) < 1e-3);
            assert!(gemm_tn(&a.transpose(), &b).max_abs_diff(&want) < 1e-3);
        }
    }

    #[test]
    fn gemm_nt_acc_accumulates() {
        let mut rng = Rng::seed_from_u64(1);
        let a = Matrix::randn(4, 8, 1.0, &mut rng);
        let b = Matrix::randn(6, 8, 1.0, &mut rng);
        let c0 = Matrix::randn(4, 6, 1.0, &mut rng);
        let got = gemm_nt_acc(&a, &b, c0.clone());
        let mut want = gemm_nt(&a, &b);
        for (w, c) in want.data.iter_mut().zip(&c0.data) {
            *w += c;
        }
        assert!(got.max_abs_diff(&want) < 1e-4);
    }

    #[test]
    fn parallel_matches_serial_bit_for_bit() {
        let mut rng = Rng::seed_from_u64(2);
        for (m, k, n) in [(1, 8, 5), (13, 37, 11), (64, 96, 32)] {
            let a = Matrix::randn(m, k, 1.0, &mut rng);
            let b = Matrix::randn(k, n, 1.0, &mut rng);
            let bt = b.transpose();
            let at = a.transpose();
            for threads in [2usize, 4, 7] {
                let p = ParallelPolicy {
                    threads,
                    min_rows_per_task: 1,
                    ..ParallelPolicy::serial()
                };
                assert_eq!(gemm_with(&a, &b, &p), gemm(&a, &b), "gemm t={threads}");
                assert_eq!(gemm_nt_with(&a, &bt, &p), gemm_nt(&a, &bt), "nt t={threads}");
                assert_eq!(gemm_tn_with(&at, &b, &p), gemm_tn(&at, &b), "tn t={threads}");
                // Forced column stripes must also match exactly.
                let pc = p.with_partition(crate::backend::pool::PartitionStrategy::Cols);
                assert_eq!(gemm_nt_with(&a, &bt, &pc), gemm_nt(&a, &bt), "nt cols t={threads}");
            }
        }
    }

    #[test]
    fn into_variants_overwrite_stale_buffers() {
        let mut rng = Rng::seed_from_u64(3);
        let a = Matrix::randn(6, 12, 1.0, &mut rng);
        let b = Matrix::randn(12, 9, 1.0, &mut rng);
        let mut c = Matrix::randn(6, 9, 1.0, &mut rng); // stale garbage
        gemm_into(&a, &b, &mut c, &ParallelPolicy::serial());
        assert_eq!(c, gemm(&a, &b), "gemm_into must overwrite stale data");
        gemm_into(&a, &b, &mut c, &ParallelPolicy::with_threads(3));
        assert_eq!(c, gemm(&a, &b), "parallel reuse of the same buffer");

        let bt = b.transpose(); // (9, 12)
        let mut cnt = Matrix::randn(6, 9, 1.0, &mut rng);
        gemm_nt_into(&a, &bt, &mut cnt, &ParallelPolicy::with_threads(2));
        assert_eq!(cnt, gemm_nt(&a, &bt));

        let d = Matrix::randn(6, 9, 1.0, &mut rng);
        let mut ctn = Matrix::randn(12, 9, 1.0, &mut rng);
        gemm_tn_into(&a, &d, &mut ctn, &ParallelPolicy::with_threads(2));
        assert_eq!(ctn, gemm_tn(&a, &d));
    }

    #[test]
    fn paired_dot_matches_single_bitwise() {
        // dot2's whole contract: per output, bitwise equal to dot —
        // across every remainder shape (chains / cleanup / scalar tail).
        let mut rng = Rng::seed_from_u64(9);
        for k in [1usize, 7, 8, 19, 31, 32, 33, 40, 64, 100] {
            let a = Matrix::randn(1, k, 1.0, &mut rng);
            let b = Matrix::randn(2, k, 1.0, &mut rng);
            for level in [SimdLevel::Scalar, SimdLevel::Avx2] {
                let level = simd::effective(level);
                let (s0, s1) = dot2_at(level, a.row(0), b.row(0), b.row(1), k);
                let d0 = dot_at(level, a.row(0), b.row(0), k);
                let d1 = dot_at(level, a.row(0), b.row(1), k);
                assert_eq!(s0.to_bits(), d0.to_bits(), "k={k} {level}");
                assert_eq!(s1.to_bits(), d1.to_bits(), "k={k} {level}");
            }
        }
    }

    #[test]
    fn dot_handles_remainders() {
        let a: Vec<f32> = (0..19).map(|v| v as f32).collect();
        let b = vec![1.0f32; 19];
        assert_eq!(dot(&a, &b, 19), (0..19).sum::<i32>() as f32);
    }
}
