//! Dense GEMM kernels (the cuBLAS role on CPU).
//!
//! `gemm_nt` is the hot path (it is the dense half of Algorithm 1 and the
//! baseline every SpMM speedup is measured against), so it is blocked for
//! L1 reuse with an 8-wide unrolled inner loop over the shared reduction
//! dimension.  Everything is safe rust; the optimizer auto-vectorizes the
//! inner loops (checked in the §Perf pass).

use crate::tensor::Matrix;

/// Cache-block edge for the K dimension (f32 lines; 256×4B = 1 KiB rows).
const KB: usize = 256;
/// Output-tile edge.
const JB: usize = 64;

/// `C = A · B` — `a: (m, k)`, `b: (k, n)`.
pub fn gemm(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols, b.rows, "gemm shape mismatch");
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let mut c = Matrix::zeros(m, n);
    for kk in (0..k).step_by(KB) {
        let kend = (kk + KB).min(k);
        for i in 0..m {
            let arow = a.row(i);
            let crow = c.row_mut(i);
            for p in kk..kend {
                let av = arow[p];
                if av == 0.0 {
                    continue;
                }
                let brow = b.row(p);
                for j in 0..n {
                    crow[j] += av * brow[j];
                }
            }
        }
    }
    c
}

/// `C = A · Bᵀ` — `a: (m, k)`, `b: (n, k)`.  Row-dot-row form: unit-stride
/// on both operands, the fastest layout for row-major data.
pub fn gemm_nt(a: &Matrix, b: &Matrix) -> Matrix {
    gemm_nt_acc(a, b, Matrix::zeros(a.rows, b.rows))
}

/// `C += A · Bᵀ` accumulating into an existing output — the fused
/// matmul+add of §2.4 (Eq. 11-right): one traversal, no extra pass.
pub fn gemm_nt_acc(a: &Matrix, b: &Matrix, mut c: Matrix) -> Matrix {
    assert_eq!(a.cols, b.cols, "gemm_nt shape mismatch");
    assert_eq!((c.rows, c.cols), (a.rows, b.rows));
    let k = a.cols;
    for i in 0..a.rows {
        let arow = a.row(i);
        let crow = c.row_mut(i);
        for jb in (0..b.rows).step_by(JB) {
            let jend = (jb + JB).min(b.rows);
            for j in jb..jend {
                crow[j] += dot(arow, b.row(j), k);
            }
        }
    }
    c
}

/// `C = Aᵀ · B` — `a: (k, m)`, `b: (k, n)` → `(m, n)`.  Used for
/// `∇W = ∇Yᵀ · X` (Algorithm 1 line 12).
pub fn gemm_tn(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.rows, b.rows, "gemm_tn shape mismatch");
    let (k, m, n) = (a.rows, a.cols, b.cols);
    let mut c = Matrix::zeros(m, n);
    // Accumulate rank-1 updates row-by-row of the shared dim: unit stride
    // on b and c.
    for p in 0..k {
        let arow = a.row(p);
        let brow = b.row(p);
        for i in 0..m {
            let av = arow[i];
            if av == 0.0 {
                continue;
            }
            let crow = c.row_mut(i);
            for j in 0..n {
                crow[j] += av * brow[j];
            }
        }
    }
    c
}

/// 8-wide unrolled dot product (auto-vectorizes to SIMD).
#[inline]
pub fn dot(a: &[f32], b: &[f32], k: usize) -> f32 {
    let mut acc = [0.0f32; 8];
    let chunks = k / 8;
    for c in 0..chunks {
        let o = c * 8;
        for l in 0..8 {
            acc[l] += a[o + l] * b[o + l];
        }
    }
    let mut s: f32 = acc.iter().sum();
    for i in chunks * 8..k {
        s += a[i] * b[i];
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn naive(a: &Matrix, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut s = 0.0;
                for p in 0..a.cols {
                    s += a.at(i, p) * b.at(p, j);
                }
                *c.at_mut(i, j) = s;
            }
        }
        c
    }

    #[test]
    fn gemm_variants_match_naive() {
        let mut rng = Rng::seed_from_u64(0);
        for (m, k, n) in [(3, 5, 7), (8, 16, 8), (17, 33, 9), (64, 128, 64)] {
            let a = Matrix::randn(m, k, 1.0, &mut rng);
            let b = Matrix::randn(k, n, 1.0, &mut rng);
            let want = naive(&a, &b);
            assert!(gemm(&a, &b).max_abs_diff(&want) < 1e-3, "gemm {m}x{k}x{n}");
            assert!(gemm_nt(&a, &b.transpose()).max_abs_diff(&want) < 1e-3);
            assert!(gemm_tn(&a.transpose(), &b).max_abs_diff(&want) < 1e-3);
        }
    }

    #[test]
    fn gemm_nt_acc_accumulates() {
        let mut rng = Rng::seed_from_u64(1);
        let a = Matrix::randn(4, 8, 1.0, &mut rng);
        let b = Matrix::randn(6, 8, 1.0, &mut rng);
        let c0 = Matrix::randn(4, 6, 1.0, &mut rng);
        let got = gemm_nt_acc(&a, &b, c0.clone());
        let mut want = gemm_nt(&a, &b);
        for (w, c) in want.data.iter_mut().zip(&c0.data) {
            *w += c;
        }
        assert!(got.max_abs_diff(&want) < 1e-4);
    }

    #[test]
    fn dot_handles_remainders() {
        let a: Vec<f32> = (0..19).map(|v| v as f32).collect();
        let b = vec![1.0f32; 19];
        assert_eq!(dot(&a, &b, 19), (0..19).sum::<i32>() as f32);
    }
}
