//! Structured SpMM over the compressed N:M layout: `Y = X · Wᵀ` with `W`
//! stored as (values, packed offsets) — the computational core of SLoPe's
//! FWD and BWD-2.
//!
//! The N:M structure is what makes this fast: within a group of M dense
//! columns the kernel touches exactly N values whose intra-group offsets
//! are decoded inline from the Eq.-7 bit-packed metadata plane
//! (`ceil(log2 M)` bits per kept value — 8× less metadata traffic than
//! the old `u16` absolute indices for 2:4).  The inner loop is a short
//! gather-multiply-accumulate with perfect value locality — the CPU
//! analogue of the metadata decode sparse tensor cores do in hardware.
//! Compared to the dense `gemm_nt`, it performs `N/M` of the
//! multiply-adds and streams `N/M` of the weight bytes.
//!
//! All kernels partition **batch rows** across the
//! [`crate::backend::pool`] engine; each worker runs the identical
//! per-row loop, so parallel outputs are bit-identical to serial ones at
//! any thread count.  `spmm_rowmajor` and `spmm_tiled` also agree
//! bit-for-bit with each other: every output element is one
//! group-ascending `sparse_dot`, and tiling only reorders whole elements.

use crate::backend::pool::{parallel_over_rows, ParallelPolicy};
use crate::sparsity::{compressed::unpack_offset, CompressedNm};
use crate::tensor::Matrix;
use std::ops::Range;

/// Execution strategy for SpMM (the §2.4 tiling ablation toggle).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpmmAlgo {
    /// Straight row-major traversal.
    RowMajor,
    /// Square output tiles of the given edge (paper's upsample tiling).
    Tiled { tile: usize },
}

// ---- row-major --------------------------------------------------------

/// `Y[b, o] = Σ_k X[b, col(o,k)] · vals[o,k]` — row-major traversal,
/// serial (the seed API).
pub fn spmm_rowmajor(x: &Matrix, w: &CompressedNm) -> Matrix {
    spmm_rowmajor_with(x, w, &ParallelPolicy::serial())
}

/// Row-major SpMM, parallel over batch rows.
pub fn spmm_rowmajor_with(x: &Matrix, w: &CompressedNm, policy: &ParallelPolicy) -> Matrix {
    let mut y = Matrix::zeros(x.rows, w.rows);
    spmm_rowmajor_into(x, w, &mut y, policy);
    y
}

/// Row-major SpMM into a caller-owned output (overwritten; every element
/// is stored, so no pre-zeroing is needed).
///
/// §Perf iteration (EXPERIMENTS.md §Perf/L3): gathers don't
/// auto-vectorize, so the kernel processes FOUR weight rows per pass —
/// the four accumulator chains give the out-of-order core independent
/// gather streams (ILP) and reuse the cached x row.
pub fn spmm_rowmajor_into(x: &Matrix, w: &CompressedNm, y: &mut Matrix, policy: &ParallelPolicy) {
    assert_eq!(x.cols, w.cols, "spmm: x cols must equal dense weight cols");
    assert_eq!((y.rows, y.cols), (x.rows, w.rows), "spmm output shape");
    parallel_over_rows(policy, &mut y.data, w.rows, |range, chunk| {
        spmm_rowmajor_rows(x, w, range, chunk);
    });
}

fn spmm_rowmajor_rows(x: &Matrix, w: &CompressedNm, range: Range<usize>, out: &mut [f32]) {
    let kc = w.kcols();
    let rmb = w.row_meta_bytes();
    let (n, m) = (w.scheme.n, w.scheme.m);
    let bits = w.scheme.offset_bits();
    let groups = if n == 0 { 0 } else { kc / n };
    let quads = w.rows / 4 * 4;
    for (local, b) in range.enumerate() {
        let xrow = x.row(b);
        let yrow = &mut out[local * w.rows..(local + 1) * w.rows];
        let mut o = 0;
        while o < quads {
            let v = &w.values[o * kc..(o + 4) * kc];
            let (v0, v1, v2, v3) = (&v[..kc], &v[kc..2 * kc], &v[2 * kc..3 * kc], &v[3 * kc..]);
            let mt = &w.meta[o * rmb..(o + 4) * rmb];
            let (m0, m1, m2, m3) =
                (&mt[..rmb], &mt[rmb..2 * rmb], &mt[2 * rmb..3 * rmb], &mt[3 * rmb..]);
            let mut acc = [0.0f32; 4];
            let mut k = 0;
            let mut base = 0;
            for _ in 0..groups {
                for j in 0..n {
                    acc[0] += xrow[base + unpack_offset(m0, k + j, bits)] * v0[k + j];
                    acc[1] += xrow[base + unpack_offset(m1, k + j, bits)] * v1[k + j];
                    acc[2] += xrow[base + unpack_offset(m2, k + j, bits)] * v2[k + j];
                    acc[3] += xrow[base + unpack_offset(m3, k + j, bits)] * v3[k + j];
                }
                k += n;
                base += m;
            }
            yrow[o..o + 4].copy_from_slice(&acc);
            o += 4;
        }
        for o in quads..w.rows {
            let vals = &w.values[o * kc..(o + 1) * kc];
            let meta = &w.meta[o * rmb..(o + 1) * rmb];
            yrow[o] = sparse_dot(xrow, vals, meta, n, m, bits);
        }
    }
}

// ---- tiled ------------------------------------------------------------

/// Square-tiled traversal (paper §2.4 / Appendix E), serial.
pub fn spmm_tiled(x: &Matrix, w: &CompressedNm, tile: usize) -> Matrix {
    spmm_tiled_with(x, w, tile, &ParallelPolicy::serial())
}

/// Tiled SpMM, parallel over batch rows.
pub fn spmm_tiled_with(x: &Matrix, w: &CompressedNm, tile: usize,
                       policy: &ParallelPolicy) -> Matrix {
    let mut y = Matrix::zeros(x.rows, w.rows);
    spmm_tiled_into(x, w, tile, &mut y, policy);
    y
}

/// Tiled SpMM into a caller-owned output: process `tile × tile` output
/// blocks so the active slice of `X` stays cache-resident while a block
/// of weight rows streams through — the CPU analogue of splitting the
/// upsample weight into square sub-matrices for cuSPARSELt.  Each worker
/// tiles its own batch-row range; since every output element is an
/// independent `sparse_dot`, the traversal order never changes values.
pub fn spmm_tiled_into(x: &Matrix, w: &CompressedNm, tile: usize, y: &mut Matrix,
                       policy: &ParallelPolicy) {
    assert_eq!(x.cols, w.cols);
    assert_eq!((y.rows, y.cols), (x.rows, w.rows), "spmm output shape");
    assert!(tile > 0);
    parallel_over_rows(policy, &mut y.data, w.rows, |range, chunk| {
        spmm_tiled_rows(x, w, tile, range, chunk);
    });
}

fn spmm_tiled_rows(x: &Matrix, w: &CompressedNm, tile: usize, range: Range<usize>,
                   out: &mut [f32]) {
    let kc = w.kcols();
    let rmb = w.row_meta_bytes();
    let (n, m) = (w.scheme.n, w.scheme.m);
    let bits = w.scheme.offset_bits();
    let rows = range.len();
    for bt in (0..rows).step_by(tile) {
        let bend = (bt + tile).min(rows);
        for ot in (0..w.rows).step_by(tile) {
            let oend = (ot + tile).min(w.rows);
            for local in bt..bend {
                let xrow = x.row(range.start + local);
                let yrow = &mut out[local * w.rows..(local + 1) * w.rows];
                for o in ot..oend {
                    let vals = &w.values[o * kc..(o + 1) * kc];
                    let meta = &w.meta[o * rmb..(o + 1) * rmb];
                    yrow[o] = sparse_dot(xrow, vals, meta, n, m, bits);
                }
            }
        }
    }
}

/// Gather-dot over one compressed weight row: group-ascending traversal,
/// decoding the packed intra-group offset inline (`group·M + offset`).
/// All loads are ordinary bounds-checked slice indexing — safe rust, no
/// `unsafe` fast path; offsets are `< M` by construction at compress
/// time, so `base + offset` always lands inside `xrow`.
#[inline]
fn sparse_dot(xrow: &[f32], vals: &[f32], meta: &[u8], n: usize, m: usize, bits: u32) -> f32 {
    let kc = vals.len();
    let groups = if n == 0 { 0 } else { kc / n };
    let mut s = 0.0f32;
    let mut k = 0;
    let mut base = 0;
    for _ in 0..groups {
        for j in 0..n {
            s += xrow[base + unpack_offset(meta, k + j, bits)] * vals[k + j];
        }
        k += n;
        base += m;
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::gemm_nt;
    use crate::sparsity::{random_row_mask, NmScheme};
    use crate::util::Rng;

    #[test]
    fn spmm_matches_dense_on_masked_weight() {
        let mut rng = Rng::seed_from_u64(0);
        for (n, m) in [(1usize, 2usize), (2, 4), (2, 8)] {
            let s = NmScheme::new(n, m);
            let x = Matrix::randn(8, 8 * m, 1.0, &mut rng);
            let w = Matrix::randn(16, 8 * m, 1.0, &mut rng);
            let mask = random_row_mask(16, 8 * m, s, &mut rng);
            let c = CompressedNm::compress(&w, &mask, s);
            let want = gemm_nt(&x, &mask.apply(&w));
            assert!(spmm_rowmajor(&x, &c).max_abs_diff(&want) < 1e-4, "{s}");
        }
    }

    #[test]
    fn tiled_matches_rowmajor_ragged_tiles() {
        let mut rng = Rng::seed_from_u64(1);
        let x = Matrix::randn(13, 32, 1.0, &mut rng); // non-multiple rows
        let w = Matrix::randn(29, 32, 1.0, &mut rng); // non-multiple outs
        let mask = random_row_mask(29, 32, NmScheme::TWO_FOUR, &mut rng);
        let c = CompressedNm::compress(&w, &mask, NmScheme::TWO_FOUR);
        let a = spmm_rowmajor(&x, &c);
        for tile in [1, 3, 7, 16, 64] {
            // Same sparse_dot per element ⇒ exact agreement.
            assert_eq!(spmm_tiled(&x, &c, tile), a, "tile {tile}");
        }
    }

    #[test]
    fn parallel_matches_serial_bit_for_bit() {
        let mut rng = Rng::seed_from_u64(2);
        let x = Matrix::randn(23, 64, 1.0, &mut rng); // ragged batch
        let w = Matrix::randn(37, 64, 1.0, &mut rng); // ragged outs
        let mask = random_row_mask(37, 64, NmScheme::TWO_FOUR, &mut rng);
        let c = CompressedNm::compress(&w, &mask, NmScheme::TWO_FOUR);
        let serial = spmm_rowmajor(&x, &c);
        let serial_t = spmm_tiled(&x, &c, 8);
        for threads in [2usize, 4, 7] {
            let p = ParallelPolicy { threads, min_rows_per_task: 1 };
            assert_eq!(spmm_rowmajor_with(&x, &c, &p), serial, "t={threads}");
            assert_eq!(spmm_tiled_with(&x, &c, 8, &p), serial_t, "tiled t={threads}");
        }
    }
}
